#!/usr/bin/env bash
# Mirror of .github/workflows/ci.yml so contributors can run the exact
# CI gate locally.
#
#   scripts/ci-local.sh            # everything, in workflow order; runs ALL
#                                  # gates even after a failure and prints a
#                                  # PASS/FAIL summary table (exit nonzero if
#                                  # any gate failed)
#   scripts/ci-local.sh fmt        # cargo fmt --check
#   scripts/ci-local.sh clippy     # cargo clippy --all-targets -D warnings
#   scripts/ci-local.sh build      # cargo build --release
#   scripts/ci-local.sh test      # cargo test -q
#   scripts/ci-local.sh bench      # cargo bench --no-run (compile only)
#   scripts/ci-local.sh smoke      # deterministic smoke matrices (plain +
#                                  # transfer oracle + transfer tree + sweep
#                                  # + hostile fault profile + serve load
#                                  # generator) + golden diffs. The matrix
#                                  # lanes run the full 9-searcher zoo
#                                  # (incl. ga/de/dual_annealing and the
#                                  # profile+ga combinator) — widening the
#                                  # zoo regenerates the matrix goldens via
#                                  # `bless`
#   scripts/ci-local.sh largespace # fast large-space smoke: tune the
#                                  # synthetic 4^10 (>1M config) benchmark
#                                  # end-to-end through the on-demand
#                                  # recorder; gated on --jobs 1 vs
#                                  # --jobs 8 byte-identity only (no
#                                  # golden — the six goldens above stay
#                                  # untouched by this lane)
#   scripts/ci-local.sh registry   # experiment-registry trend gate: append
#                                  # the six smoke reports to a scratch
#                                  # registry, check the append→query
#                                  # round-trip, compare KPIs against
#                                  # rust/testdata/registry_baseline.csv
#                                  # (warn-only until that baseline is
#                                  # blessed)
#   scripts/ci-local.sh bless      # regenerate all six goldens:
#                                  #   rust/testdata/smoke_golden.json
#                                  #     (pcat matrix --smoke)
#                                  #   rust/testdata/transfer_golden.json
#                                  #     (pcat transfer --smoke: oracle model,
#                                  #      incl. cross-input + cross-generation
#                                  #      cells, step+time curves and
#                                  #      model-quality metrics)
#                                  #   rust/testdata/transfer_tree_golden.json
#                                  #     (pcat transfer --smoke --model tree:
#                                  #      trained decision-tree source)
#                                  #   rust/testdata/sweep_golden.json
#                                  #     (pcat sweep --smoke: the
#                                  #      sample-efficiency sensitivity sweep)
#                                  #   rust/testdata/faults_golden.json
#                                  #     (pcat matrix --smoke --fault-profile
#                                  #      hostile: deterministic fault
#                                  #      injection + failure accounting)
#                                  #   rust/testdata/serve_golden.json
#                                  #     (pcat serve --smoke: the
#                                  #      tuning-as-a-service load generator)
#                                  # and derives the registry KPI baseline
#                                  #   rust/testdata/registry_baseline.csv
#                                  # from the just-blessed reports
set -euo pipefail
# Absolute self-path BEFORE the cd: run_all re-invokes each gate as
# `"$SELF" <gate>` in a child process, and a relative $0 (e.g.
# `cd scripts && ./ci-local.sh`) would no longer resolve from the repo
# root we cd into next.
SELF="$(cd "$(dirname "$0")" && pwd)/$(basename "$0")"
cd "$(dirname "$0")/.."

GOLDEN=rust/testdata/smoke_golden.json
TRANSFER_GOLDEN=rust/testdata/transfer_golden.json
TRANSFER_TREE_GOLDEN=rust/testdata/transfer_tree_golden.json
SWEEP_GOLDEN=rust/testdata/sweep_golden.json
FAULTS_GOLDEN=rust/testdata/faults_golden.json
SERVE_GOLDEN=rust/testdata/serve_golden.json
REGISTRY_BASELINE=rust/testdata/registry_baseline.csv
SMOKE_OUT=rust/target/smoke
REGISTRY_SCRATCH=rust/target/registry/pcat.csv

run_fmt() { (cd rust && cargo fmt --check); }
run_clippy() { (cd rust && cargo clippy --all-targets -- -D warnings); }
run_build() { (cd rust && cargo build --release); }
run_test() { (cd rust && cargo test -q); }
run_bench() { (cd rust && cargo bench --no-run); }

smoke_report() {
    # $1 = lane (matrix|transfer|transfer-tree|sweep|faults|serve),
    # $2 = jobs, $3 = output
    case "$1" in
        matrix)
            rust/target/release/pcat matrix --smoke --seed 0 \
                --jobs "$2" --out "$3" ;;
        faults)
            rust/target/release/pcat matrix --smoke --seed 0 \
                --fault-profile hostile --jobs "$2" --out "$3" ;;
        transfer)
            rust/target/release/pcat transfer --smoke --seed 0 \
                --jobs "$2" --out "$3" ;;
        transfer-tree)
            rust/target/release/pcat transfer --smoke --model tree \
                --seed 0 --jobs "$2" --out "$3" ;;
        sweep)
            rust/target/release/pcat sweep --smoke --seed 0 \
                --jobs "$2" --out "$3" ;;
        serve)
            rust/target/release/pcat serve --smoke --seed 0 \
                --jobs "$2" --out "$3" ;;
        *)
            echo "unknown smoke lane $1" >&2; exit 2 ;;
    esac
}

smoke_gate() {
    # $1 = subcommand, $2 = golden path — determinism + golden diff for
    # one smoke flavour
    local cmd="$1" golden="$2"
    smoke_report "$cmd" 1 "$SMOKE_OUT/$cmd.jobs1.json"
    smoke_report "$cmd" 8 "$SMOKE_OUT/$cmd.jobs8.json"
    # determinism gate: serial and parallel runs must be byte-identical
    cmp "$SMOKE_OUT/$cmd.jobs1.json" "$SMOKE_OUT/$cmd.jobs8.json"
    echo "smoke[$cmd]: --jobs 1 and --jobs 8 reports are byte-identical"
    if [ -f "$golden" ]; then
        # Drift against the committed golden is a hard failure.
        cmp "$SMOKE_OUT/$cmd.jobs8.json" "$golden"
        echo "smoke[$cmd]: report matches $golden"
    elif [ -n "${CI:-}" ]; then
        # In CI the drift gate is armed unconditionally: a missing
        # golden is a hard failure, never a self-bless (that would make
        # the gate vacuous) and no longer a warning (that let the
        # bootstrap state linger). Bless locally and commit the file.
        echo "::error::$golden is missing — run scripts/ci-local.sh" \
             "bless locally and commit it"
        exit 1
    else
        mkdir -p "$(dirname "$golden")"
        cp "$SMOKE_OUT/$cmd.jobs8.json" "$golden"
        echo "smoke[$cmd]: bootstrapped $golden — review and commit it"
    fi
}

run_smoke() {
    run_build
    mkdir -p "$SMOKE_OUT"
    smoke_gate matrix "$GOLDEN"
    smoke_gate transfer "$TRANSFER_GOLDEN"
    smoke_gate transfer-tree "$TRANSFER_TREE_GOLDEN"
    smoke_gate sweep "$SWEEP_GOLDEN"
    smoke_gate faults "$FAULTS_GOLDEN"
    smoke_gate serve "$SERVE_GOLDEN"
}

# Large-space smoke: a >1M-config matrix cell runs end to end through
# the on-demand recorder (nothing space-sized is ever materialized) and
# stays byte-identical across worker counts. Deliberately golden-less:
# the lane proves determinism and bounded memory, while the six blessed
# goldens above keep gating the eager paths byte-for-byte.
run_largespace() {
    run_build
    mkdir -p "$SMOKE_OUT"
    local flags=(--seed 0 --seeds 2 --budget 18
                 --benchmarks synth-grid --gpus gtx1070
                 --searchers profile,random)
    rust/target/release/pcat matrix "${flags[@]}" \
        --jobs 1 --out "$SMOKE_OUT/largespace.jobs1.json"
    rust/target/release/pcat matrix "${flags[@]}" \
        --jobs 8 --out "$SMOKE_OUT/largespace.jobs8.json"
    cmp "$SMOKE_OUT/largespace.jobs1.json" "$SMOKE_OUT/largespace.jobs8.json"
    echo "largespace: >1M-config tune is byte-identical at --jobs 1 and 8"
}

# Append the six smoke reports (jobs 8) to a fresh scratch registry.
# The faults lane lands under its own plan name (matrix-hostile), so
# its failure/retry KPIs get a trend series without shadowing the
# fault-free matrix lane.
# $1 = scratch CSV path.
build_scratch_registry() {
    rm -f "$1"
    mkdir -p "$SMOKE_OUT"
    local lane
    for lane in matrix transfer transfer-tree sweep faults serve; do
        smoke_report "$lane" 8 "$SMOKE_OUT/registry-$lane.json"
        rust/target/release/pcat registry append \
            "$SMOKE_OUT/registry-$lane.json" --registry "$1"
    done
}

run_registry() {
    run_build
    build_scratch_registry "$REGISTRY_SCRATCH"
    # append → query round-trip: two reads render byte-identically
    rust/target/release/pcat registry query \
        --registry "$REGISTRY_SCRATCH" > rust/target/registry/query1.txt
    rust/target/release/pcat registry query \
        --registry "$REGISTRY_SCRATCH" > rust/target/registry/query2.txt
    cmp rust/target/registry/query1.txt rust/target/registry/query2.txt
    echo "registry: append + query round-trip is deterministic"
    if [ -f "$REGISTRY_BASELINE" ]; then
        # KPI trend gate: out-of-tolerance drift vs the blessed
        # baseline is a hard failure (pcat exits nonzero)
        rust/target/release/pcat registry compare \
            --baseline "$REGISTRY_BASELINE" --registry "$REGISTRY_SCRATCH"
        echo "registry: KPIs within tolerance of $REGISTRY_BASELINE"
    else
        # warn-only until the first baseline is blessed; the compare
        # still runs (against the scratch rows themselves) so the gate
        # code path is exercised end to end
        rust/target/release/pcat registry compare \
            --baseline "$REGISTRY_SCRATCH" --registry "$REGISTRY_SCRATCH"
        echo "registry: WARN — $REGISTRY_BASELINE missing (self-compare" \
             "only); run scripts/ci-local.sh bless and commit it"
    fi
}

run_bless() {
    run_build
    mkdir -p "$(dirname "$GOLDEN")" "$(dirname "$TRANSFER_GOLDEN")"
    smoke_report matrix 8 "$GOLDEN"
    smoke_report transfer 8 "$TRANSFER_GOLDEN"
    smoke_report transfer-tree 8 "$TRANSFER_TREE_GOLDEN"
    smoke_report sweep 8 "$SWEEP_GOLDEN"
    smoke_report faults 8 "$FAULTS_GOLDEN"
    smoke_report serve 8 "$SERVE_GOLDEN"
    echo "blessed $GOLDEN, $TRANSFER_GOLDEN, $TRANSFER_TREE_GOLDEN," \
         "$SWEEP_GOLDEN, $FAULTS_GOLDEN and $SERVE_GOLDEN"
    # registry KPI baseline, derived from the just-blessed reports so
    # the two artifacts can never disagree
    local bless_csv=rust/target/registry/bless.csv
    rm -f "$bless_csv"
    local report
    for report in "$GOLDEN" "$TRANSFER_GOLDEN" "$TRANSFER_TREE_GOLDEN" \
                  "$SWEEP_GOLDEN" "$FAULTS_GOLDEN" "$SERVE_GOLDEN"; do
        rust/target/release/pcat registry append "$report" \
            --registry "$bless_csv"
    done
    cp "$bless_csv" "$REGISTRY_BASELINE"
    echo "blessed $REGISTRY_BASELINE"
}

# Run every gate even when one fails (each in its own process so
# `set -e` semantics inside a gate are preserved — a bash function
# called from an `if` would have -e silently disabled), record PASS /
# FAIL per gate, print a summary table and exit nonzero if anything
# failed. This is what lets one CI round report *all* broken gates
# instead of only the first.
run_all() {
    local gates=(fmt clippy build test bench smoke largespace registry)
    local names=() statuses=() failed=0
    for gate in "${gates[@]}"; do
        echo
        echo "=== ci-local: $gate ==="
        if "$SELF" "$gate"; then
            names+=("$gate"); statuses+=("PASS")
        else
            names+=("$gate"); statuses+=("FAIL"); failed=1
        fi
    done
    echo
    echo "=== ci-local summary ==="
    printf '%-10s %s\n' "gate" "status"
    printf '%-10s %s\n' "----" "------"
    local i
    for i in "${!names[@]}"; do
        printf '%-10s %s\n' "${names[$i]}" "${statuses[$i]}"
    done
    if [ "$failed" -ne 0 ]; then
        echo "ci-local: FAILED (see table above)"
        return 1
    fi
    echo "ci-local: all gates passed"
}

case "${1:-all}" in
    fmt) run_fmt ;;
    clippy) run_clippy ;;
    build) run_build ;;
    test) run_test ;;
    bench) run_bench ;;
    smoke) run_smoke ;;
    largespace) run_largespace ;;
    registry) run_registry ;;
    bless) run_bless ;;
    all) run_all ;;
    *)
        echo "usage: $0 [all|fmt|clippy|build|test|bench|smoke|largespace|registry|bless]" >&2
        exit 2
        ;;
esac
