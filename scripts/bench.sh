#!/usr/bin/env bash
# Perf-trajectory runner: the hot-path benches plus a timed smoke
# matrix, assembled into one machine-readable report.
#
#   scripts/bench.sh [OUT.json]     # default: BENCH_scoring.json
#
# The report captures the columnar-scoring-engine before/after numbers
# (AoS + linear-scan baseline vs matrix + Fenwick engine — see the
# README "Performance" section) so successive PRs can compare against a
# recorded baseline instead of folklore. It also carries the
# large-space lane: streaming enumeration of the >1M-config synthetic
# grid, serial-vs-batched score_all (asserted bit-identical), and a
# lazy on-demand tune whose visited-config count is the bounded-memory
# acceptance number (`lazy_visited_fraction` in the derived block).
set -euo pipefail
cd "$(dirname "$0")/.."

OUT=${1:-BENCH_scoring.json}
RAW=rust/target/bench_scoring_raw.json

(cd rust && cargo build --release)

# registry-grade provenance: the bench report is never golden-gated, so
# (unlike the smoke reports) it carries the real commit/toolchain/time
export PCAT_COMMIT="${PCAT_COMMIT:-$(git rev-parse HEAD 2>/dev/null || echo unknown)}"
export PCAT_TOOLCHAIN="${PCAT_TOOLCHAIN:-$(rustc -V 2>/dev/null | tr ' ' '-' || echo unknown)}"
export PCAT_CREATED_AT="${PCAT_CREATED_AT:-$(python3 -c 'import datetime; print(datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"))')}"

echo "== hotpaths bench (emitting $RAW) =="
(cd rust && BENCH_JSON=target/bench_scoring_raw.json cargo bench --bench hotpaths)

echo "== timed smoke matrix =="
SMOKE_OUT=rust/target/smoke-bench.json

# timing lives in python: `date +%s.%N` is GNU-only and the first
# toolchain-equipped machine may well be a mac
python3 - "$RAW" "$OUT" "$SMOKE_OUT" <<'EOF'
import json, subprocess, sys, time

raw_path, out_path, smoke_out = sys.argv[1:4]
cmd = [
    "rust/target/release/pcat", "matrix", "--smoke",
    "--seed", "0", "--jobs", "8", "--out", smoke_out,
]
t0 = time.monotonic()
subprocess.run(cmd, check=True)
wall = time.monotonic() - t0

with open(raw_path) as f:
    doc = json.load(f)
doc["smoke_matrix"] = {
    "command": " ".join(cmd[1:]),
    "wall_s": round(wall, 3),
}
with open(out_path, "w") as f:
    json.dump(doc, f, indent=1)
    f.write("\n")
print(f"wrote {out_path}")
EOF
