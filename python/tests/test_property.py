"""Hypothesis sweeps over kernel shapes/tiles vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import coulomb, gemm, transpose
from compile.kernels.ref import coulomb_ref, gemm_ref, transpose_ref

_pow2 = st.sampled_from([4, 8, 16, 32])


@settings(max_examples=20, deadline=None)
@given(
    mk=_pow2, nk=_pow2, kk=_pow2,
    mt=st.integers(1, 3), nt=st.integers(1, 3), kt=st.integers(1, 3),
    seed=st.integers(0, 2 ** 16),
)
def test_gemm_any_tile_divides(mk, nk, kk, mt, nt, kt, seed):
    m, n, k = mk * mt, nk * nt, kk * kt
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = gemm.gemm_pallas(a, b, mwg=mk, nwg=nk, kwg=kk)
    np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    tx=_pow2, ty=_pow2, rt=st.integers(1, 4), ct=st.integers(1, 4),
    seed=st.integers(0, 2 ** 16),
)
def test_transpose_any_tile_divides(tx, ty, rt, ct, seed):
    rows, cols = ty * rt, tx * ct
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)).astype(np.float32))
    got = transpose.transpose_pallas(x, tile_x=tx, tile_y=ty)
    np.testing.assert_array_equal(got, transpose_ref(x))


@settings(max_examples=10, deadline=None)
@given(
    zi=st.sampled_from([1, 2, 4, 8]),
    bx=st.sampled_from([2, 4, 8]),
    by=st.sampled_from([1, 2, 8]),
    n_atoms=st.integers(1, 24),
    seed=st.integers(0, 2 ** 16),
)
def test_coulomb_any_config(zi, bx, by, n_atoms, seed):
    grid = 8
    rng = np.random.default_rng(seed)
    atoms = rng.uniform(0.2, 3.8, size=(n_atoms, 4)).astype(np.float32)
    atoms[:, :3] += 0.111  # keep off lattice points
    atoms = jnp.asarray(atoms)
    got = coulomb.coulomb_pallas(atoms, grid, 0.5, block_x=bx, block_y=by,
                                 z_iter=zi)
    want = coulomb_ref(atoms, grid, 0.5)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-4)
