"""L2 model shape checks + AOT lowering round-trip (HLO text emission)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestVariants:
    def test_variant_sets_nonempty(self):
        for bench, builder in model.ALL_VARIANTS.items():
            vs = builder()
            assert len(vs) >= 6, bench
            names = [v.name() for v in vs]
            assert len(set(names)) == len(names), f"dup names in {bench}"

    def test_coulomb_variant_runs(self):
        v = model.coulomb_model(8, 5, 0.5,
                                {"z_iter": 2, "block_x": 8, "block_y": 4})
        atoms = jnp.asarray(
            np.random.default_rng(0).uniform(0.2, 3.3, (5, 4)),
            dtype=jnp.float32)
        grid, checksum = jax.jit(v.fn)(atoms)
        assert grid.shape == (8, 8, 8)
        np.testing.assert_allclose(checksum, jnp.sum(grid), rtol=1e-5)

    def test_gemm_variant_runs(self):
        v = model.gemm_model(32, 32, 32, {"mwg": 16, "nwg": 16, "kwg": 16})
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((32, 32)), dtype=jnp.float32)
        b = jnp.asarray(rng.standard_normal((32, 32)), dtype=jnp.float32)
        c, checksum = jax.jit(v.fn)(a, b)
        np.testing.assert_allclose(c, a @ b, rtol=1e-3, atol=1e-3)

    def test_ops_metadata_positive(self):
        for builder in model.ALL_VARIANTS.values():
            for v in builder():
                assert v.ops["threads"] > 0
                assert all(val >= 0 for val in v.ops.values()), v.name()

    def test_gemm_coarsening_reduces_threads(self):
        small = model.gemm_model(128, 128, 128,
                                 {"mwg": 16, "nwg": 16, "kwg": 16})
        big = model.gemm_model(128, 128, 128,
                               {"mwg": 64, "nwg": 64, "kwg": 16})
        assert big.ops["threads"] < small.ops["threads"]


class TestAot:
    def test_lower_variant_emits_hlo_text(self):
        v = model.gemm_model(32, 32, 32, {"mwg": 16, "nwg": 16, "kwg": 16})
        text = aot.lower_variant(v)
        assert "HloModule" in text
        assert "f32[32,32]" in text

    def test_manifest_written(self, tmp_path, monkeypatch):
        # restrict to one tiny benchmark for speed
        monkeypatch.setattr(
            model, "ALL_VARIANTS",
            {"gemm": lambda: [model.gemm_model(
                32, 32, 32, {"mwg": 16, "nwg": 16, "kwg": 16})]})
        monkeypatch.setattr(
            "sys.argv", ["aot", "--out-dir", str(tmp_path)])
        aot.main()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert len(manifest) == 1
        entry = manifest[0]
        assert (tmp_path / entry["path"]).exists()
        assert entry["config"] == {"mwg": 16, "nwg": 16, "kwg": 16}
        assert entry["args"][0]["dtype"] == "float32"
