"""Kernel-vs-oracle allclose: the core correctness signal for L1.

Deterministic sweeps over the tuning axes; hypothesis shape/dtype sweeps
live in test_property.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import coulomb, gemm, transpose
from compile.kernels.ref import coulomb_ref, gemm_ref, transpose_ref


def _atoms(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.3, 7.7, size=(n, 4)).astype(np.float32)
    a[:, 3] = rng.uniform(0.1, 1.0, size=n)  # charges
    # offset off the grid lattice so no r_ij is ever ~0
    a[:, :3] += 0.123
    return jnp.asarray(a)


class TestCoulomb:
    @pytest.mark.parametrize("z_iter", [1, 2, 4, 8, 16])
    def test_z_coarsening(self, z_iter):
        atoms = _atoms(17)
        got = coulomb.coulomb_pallas(atoms, 16, 0.5, block_x=8, block_y=4,
                                     z_iter=z_iter)
        want = coulomb_ref(atoms, 16, 0.5)
        np.testing.assert_allclose(got, want, rtol=2e-4)

    @pytest.mark.parametrize("bx,by", [(4, 1), (4, 4), (8, 2), (16, 16),
                                       (16, 1)])
    def test_block_shapes(self, bx, by):
        atoms = _atoms(9, seed=3)
        got = coulomb.coulomb_pallas(atoms, 16, 0.25, block_x=bx,
                                     block_y=by, z_iter=2)
        want = coulomb_ref(atoms, 16, 0.25)
        np.testing.assert_allclose(got, want, rtol=2e-4)

    def test_single_atom_inverse_distance(self):
        # One unit charge: V = 1/r exactly.
        atoms = jnp.asarray([[1.1, 1.1, 1.1, 1.0]], dtype=jnp.float32)
        got = coulomb.coulomb_pallas(atoms, 8, 1.0, block_x=4, block_y=4,
                                     z_iter=1)
        r = np.sqrt(3 * (1.1 - 2.0) ** 2)
        np.testing.assert_allclose(got[2, 2, 2], 1.0 / r, rtol=1e-4)

    def test_indivisible_tile_raises(self):
        with pytest.raises(ValueError):
            coulomb.coulomb_pallas(_atoms(4), 16, 0.5, block_x=5,
                                   block_y=4, z_iter=1)

    def test_charge_linearity(self):
        atoms = _atoms(8)
        v1 = coulomb.coulomb_pallas(atoms, 8, 0.5, block_x=8, block_y=8,
                                    z_iter=1)
        atoms2 = atoms.at[:, 3].multiply(2.0)
        v2 = coulomb.coulomb_pallas(atoms2, 8, 0.5, block_x=8, block_y=8,
                                    z_iter=1)
        np.testing.assert_allclose(v2, 2.0 * v1, rtol=1e-5)


class TestGemm:
    @pytest.mark.parametrize("mwg,nwg,kwg", [
        (8, 8, 8), (16, 16, 16), (32, 32, 16), (16, 64, 8), (64, 16, 32),
        (64, 64, 64),
    ])
    def test_tiles(self, mwg, nwg, kwg):
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
        b = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
        got = gemm.gemm_pallas(a, b, mwg=min(mwg, 64), nwg=min(nwg, 64),
                               kwg=min(kwg, 64))
        np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-4,
                                   atol=1e-4)

    def test_rectangular(self):
        rng = np.random.default_rng(11)
        a = jnp.asarray(rng.standard_normal((32, 128), dtype=np.float32))
        b = jnp.asarray(rng.standard_normal((128, 16), dtype=np.float32))
        got = gemm.gemm_pallas(a, b, mwg=16, nwg=16, kwg=32)
        np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-4,
                                   atol=1e-4)

    def test_identity(self):
        eye = jnp.eye(32, dtype=jnp.float32)
        x = jnp.arange(32 * 32, dtype=jnp.float32).reshape(32, 32)
        got = gemm.gemm_pallas(eye, x, mwg=8, nwg=8, kwg=8)
        np.testing.assert_allclose(got, x, rtol=1e-6)

    def test_shape_mismatch_raises(self):
        a = jnp.zeros((8, 8), jnp.float32)
        b = jnp.zeros((16, 8), jnp.float32)
        with pytest.raises(ValueError):
            gemm.gemm_pallas(a, b)

    def test_indivisible_tile_raises(self):
        a = jnp.zeros((24, 24), jnp.float32)
        with pytest.raises(ValueError):
            gemm.gemm_pallas(a, a, mwg=16, nwg=8, kwg=8)


class TestTranspose:
    @pytest.mark.parametrize("tx,ty", [(8, 8), (16, 32), (32, 16),
                                       (64, 8), (64, 64)])
    def test_tiles(self, tx, ty):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((128, 64), dtype=np.float32))
        got = transpose.transpose_pallas(x, tile_x=min(tx, 64),
                                         tile_y=min(ty, 128))
        np.testing.assert_array_equal(got, transpose_ref(x))

    def test_involution(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.standard_normal((64, 32), dtype=np.float32))
        y = transpose.transpose_pallas(x, tile_x=16, tile_y=16)
        z = transpose.transpose_pallas(y, tile_x=16, tile_y=16)
        np.testing.assert_array_equal(z, x)

    def test_indivisible_tile_raises(self):
        x = jnp.zeros((30, 30), jnp.float32)
        with pytest.raises(ValueError):
            transpose.transpose_pallas(x, tile_x=16, tile_y=16)


class TestNBody:
    @pytest.mark.parametrize("bi,bj", [(32, 32), (32, 128), (64, 64),
                                       (128, 32), (128, 128)])
    def test_tiles(self, bi, bj):
        import jax.numpy as jnp
        from compile.kernels.nbody import nbody_pallas
        from compile.kernels.ref import nbody_ref
        rng = np.random.default_rng(17)
        b = jnp.asarray(rng.uniform(-1, 1, (128, 4)).astype(np.float32))
        b = b.at[:, 3].set(jnp.abs(b[:, 3]) + 0.1)
        got = nbody_pallas(b, block_i=bi, block_j=bj)
        np.testing.assert_allclose(got, nbody_ref(b), rtol=2e-3, atol=2e-4)

    def test_two_body_symmetry(self):
        import jax.numpy as jnp
        from compile.kernels.nbody import nbody_pallas
        # equal masses, accelerations opposite (softening-symmetric)
        b = jnp.asarray([[0.0, 0.0, 0.0, 1.0],
                         [1.0, 0.0, 0.0, 1.0]], dtype=jnp.float32)
        acc = nbody_pallas(b, block_i=2, block_j=2)
        np.testing.assert_allclose(acc[0], -acc[1], rtol=1e-5)
        assert acc[0, 0] > 0  # pulled toward +x

    def test_indivisible_raises(self):
        import jax.numpy as jnp
        from compile.kernels.nbody import nbody_pallas
        with pytest.raises(ValueError):
            nbody_pallas(jnp.zeros((100, 4), jnp.float32), block_i=64,
                         block_j=32)
