"""AOT compiler: lower every benchmark variant to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the xla_extension 0.5.1 bundled with the Rust ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Output layout (consumed by rust/src/runtime/artifact.rs):

    artifacts/
      manifest.json               # [{benchmark, name, config, path,
                                  #   args: [{shape, dtype}], ops}]
      coulomb/<name>.hlo.txt
      gemm/<name>.hlo.txt
      transpose/<name>.hlo.txt

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_variant(variant: model.Variant) -> str:
    lowered = jax.jit(variant.fn).lower(*variant.example_args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--benchmark", action="append", default=None,
                    help="restrict to the named benchmark(s)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    benchmarks = args.benchmark or sorted(model.ALL_VARIANTS)

    manifest = []
    for bench in benchmarks:
        bench_dir = out_dir / bench
        bench_dir.mkdir(exist_ok=True)
        variants = model.ALL_VARIANTS[bench]()
        for v in variants:
            path = bench_dir / f"{v.name()}.hlo.txt"
            path.write_text(lower_variant(v))
            manifest.append({
                "benchmark": v.benchmark,
                "name": v.name(),
                "config": v.config,
                "path": str(path.relative_to(out_dir)),
                "args": [
                    {"shape": list(a.shape), "dtype": a.dtype.name}
                    for a in v.example_args
                ],
                "ops": v.ops,
            })
            print(f"  wrote {path}")
        print(f"{bench}: {len(variants)} variants")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"manifest: {len(manifest)} artifacts -> {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
