"""L2: JAX compute graphs over the L1 Pallas kernels.

One "model" per benchmark: a jax function, parameterized by a tuning
configuration, that lowers (kernel included) into a single HLO module.
``aot.py`` lowers every configuration in the AOT variant set; the Rust
runtime (`rust/src/runtime/`) loads and times them as the empirical-test
path of the autotuner -- Python never runs at tuning time.

The functions here deliberately contain the small amount of surrounding
graph the paper's kernels have in KTT (output reduction used for result
checks), so the artifact is more than a bare kernel and exercises XLA
fusion around the Pallas body.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import coulomb, gemm, nbody, transpose


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT-compiled tuning configuration of one benchmark."""

    benchmark: str
    config: Dict[str, int]
    #: example inputs for lowering (ShapeDtypeStructs)
    example_args: Tuple[jax.ShapeDtypeStruct, ...]
    #: the jax callable of this configuration
    fn: Callable[..., Any]
    #: analytic PC_ops metadata stamped into the manifest
    ops: Dict[str, int]

    def name(self) -> str:
        tail = "_".join(f"{k}{v}" for k, v in sorted(self.config.items()))
        return f"{self.benchmark}_{tail}"


# ---------------------------------------------------------------------------
# Benchmark model builders
# ---------------------------------------------------------------------------

def coulomb_model(grid_size: int, n_atoms: int, grid_spacing: float,
                  cfg: Dict[str, int]) -> Variant:
    def fwd(atoms):
        grid = coulomb.coulomb_pallas(
            atoms, grid_size, grid_spacing,
            block_x=cfg["block_x"], block_y=cfg["block_y"],
            z_iter=cfg["z_iter"])
        # KTT-style residual used by the result checker: cheap reduction
        # fused by XLA around the kernel.
        return grid, jnp.sum(grid)

    args = (jax.ShapeDtypeStruct((n_atoms, 4), jnp.float32),)
    ops = {
        "INST_F32": coulomb.flops(grid_size, n_atoms) // max(1, 1),
        "TEX_RWT": grid_size ** 3 * n_atoms * 16
        // (cfg["z_iter"] * 128),
        "DRAM_WT": grid_size ** 3 * 4 // 32,
        "threads": grid_size ** 3 // cfg["z_iter"],
    }
    return Variant("coulomb", dict(cfg), args, fwd, ops)


def gemm_model(m: int, n: int, k: int, cfg: Dict[str, int]) -> Variant:
    def fwd(a, b):
        c = gemm.gemm_pallas(a, b, mwg=cfg["mwg"], nwg=cfg["nwg"],
                             kwg=cfg["kwg"])
        return c, jnp.sum(c)

    args = (jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32))
    ops = {
        "INST_F32": gemm.flops(m, n, k),
        "DRAM_RT": (m * k // cfg["mwg"] + k * n // cfg["nwg"]) * 4 // 32,
        "DRAM_WT": m * n * 4 // 32,
        "threads": (m // cfg["mwg"]) * (n // cfg["nwg"]),
        "vmem_bytes": gemm.vmem_bytes(cfg["mwg"], cfg["nwg"], cfg["kwg"]),
    }
    return Variant("gemm", dict(cfg), args, fwd, ops)


def nbody_model(n: int, cfg: Dict[str, int]) -> Variant:
    def fwd(bodies):
        acc = nbody.nbody_pallas(
            bodies, block_i=cfg["block_i"], block_j=cfg["block_j"])
        return acc, jnp.sum(acc * acc)

    args = (jax.ShapeDtypeStruct((n, 4), jnp.float32),)
    ops = {
        "INST_F32": nbody.flops(n),
        "DRAM_RT": (n // cfg["block_i"]) * n * 16 // 32,
        "DRAM_WT": n * 12 // 32,
        "threads": n,
        "j_panel": cfg["block_j"],
    }
    return Variant("nbody", dict(cfg), args, fwd, ops)


def transpose_model(rows: int, cols: int, cfg: Dict[str, int]) -> Variant:
    def fwd(x):
        y = transpose.transpose_pallas(
            x, tile_x=cfg["tile_x"], tile_y=cfg["tile_y"])
        return y, jnp.sum(y[0])

    args = (jax.ShapeDtypeStruct((rows, cols), jnp.float32),)
    ops = {
        "DRAM_RT": rows * cols * 4 // 32,
        "DRAM_WT": rows * cols * 4 // 32,
        "threads": (rows // cfg["tile_y"]) * (cols // cfg["tile_x"]),
    }
    return Variant("transpose", dict(cfg), args, fwd, ops)


# ---------------------------------------------------------------------------
# AOT variant sets (the subset of each simulated space that is compiled to
# real artifacts and empirically executed by the Rust runtime).
# ---------------------------------------------------------------------------

#: default problem sizes for the AOT path -- small enough that the
#: interpret-mode HLO compiles and runs in milliseconds on the CPU PJRT
#: client, large enough that tile-shape differences are measurable.
COULOMB_GRID = 32
COULOMB_ATOMS = 64
COULOMB_SPACING = 0.5
GEMM_M = GEMM_N = GEMM_K = 128
TRANSPOSE_ROWS = TRANSPOSE_COLS = 512
NBODY_N = 1024


def coulomb_variants() -> List[Variant]:
    out = []
    for zi in coulomb.TUNING_SPACE["z_iter"]:
        for bx, by in [(16, 16), (32, 4), (8, 8)]:
            if COULOMB_GRID % zi or COULOMB_GRID % bx or COULOMB_GRID % by:
                continue
            out.append(coulomb_model(
                COULOMB_GRID, COULOMB_ATOMS, COULOMB_SPACING,
                {"z_iter": zi, "block_x": bx, "block_y": by}))
    return out


def gemm_variants() -> List[Variant]:
    out = []
    for mwg in [16, 32, 64]:
        for nwg in [16, 32, 64]:
            for kwg in [16, 32]:
                # CLBlast-style constraint: keep the VMEM tile bounded.
                if gemm.vmem_bytes(mwg, nwg, kwg) > 64 * 1024:
                    continue
                out.append(gemm_model(GEMM_M, GEMM_N, GEMM_K,
                                      {"mwg": mwg, "nwg": nwg, "kwg": kwg}))
    return out


def nbody_variants() -> List[Variant]:
    out = []
    for bi in nbody.TUNING_SPACE["block_i"]:
        for bj in nbody.TUNING_SPACE["block_j"]:
            if NBODY_N % bi or NBODY_N % bj:
                continue
            # keep the pairwise tile bounded (VMEM analogue of the
            # shared-memory j-panel constraint)
            if bi * bj > 32 * 1024:
                continue
            out.append(nbody_model(NBODY_N, {"block_i": bi, "block_j": bj}))
    return out


def transpose_variants() -> List[Variant]:
    out = []
    for tx in transpose.TUNING_SPACE["tile_x"]:
        for ty in transpose.TUNING_SPACE["tile_y"]:
            out.append(transpose_model(TRANSPOSE_ROWS, TRANSPOSE_COLS,
                                       {"tile_x": tx, "tile_y": ty}))
    return out


ALL_VARIANTS: Dict[str, Callable[[], List[Variant]]] = {
    "coulomb": coulomb_variants,
    "gemm": gemm_variants,
    "nbody": nbody_variants,
    "transpose": transpose_variants,
}
