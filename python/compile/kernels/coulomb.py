"""L1 Pallas kernel: Direct Coulomb Summation (paper §2, Listing 1).

The electrostatic potential on a regular 3D grid:

    V_i = sum_j w_j / r_ij

Tuning parameters (mirroring the paper's CUDA kernel):
  * ``z_iter``   -- thread-coarsening along Z (the paper's Z_ITERATIONS):
                    one program instance computes ``z_iter`` grid slices,
                    amortizing the atom load and the invariant dx^2+dy^2.
  * ``block_x``, ``block_y`` -- the (X, Y) tile computed per program
                    instance, expressed as the Pallas BlockSpec block shape
                    (the TPU analogue of the CUDA thread-block shape: it
                    fixes the VMEM-resident output tile).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): CUDA thread blocks
become BlockSpec tiles; the atom array is broadcast to every tile (the
analogue of the read-only/texture-cache path in the paper).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime loads (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _coulomb_kernel(atoms_ref, out_ref, *, grid_spacing, block_x, block_y,
                    z_iter):
    """Compute one (z_iter, block_y, block_x) tile of the potential grid."""
    zi = pl.program_id(0)
    yi = pl.program_id(1)
    xi = pl.program_id(2)

    shape = (z_iter, block_y, block_x)
    # Real-space coordinates of every grid point in this tile.
    fz = (zi * z_iter + jax.lax.broadcasted_iota(jnp.float32, shape, 0)) \
        * grid_spacing
    fy = (yi * block_y + jax.lax.broadcasted_iota(jnp.float32, shape, 1)) \
        * grid_spacing
    fx = (xi * block_x + jax.lax.broadcasted_iota(jnp.float32, shape, 2)) \
        * grid_spacing

    atoms = atoms_ref[...]  # (n_atoms, 4): x, y, z, w -- one VMEM load
    n_atoms = atoms.shape[0]

    def body(i, acc):
        a = atoms[i]  # lowered to a dynamic_slice row load
        dx = fx - a[0]
        dy = fy - a[1]
        dz = fz - a[2]
        rd = jax.lax.rsqrt(dx * dx + dy * dy + dz * dz)
        return acc + a[3] * rd

    acc = jax.lax.fori_loop(0, n_atoms, body,
                            jnp.zeros(shape, jnp.float32))
    out_ref[...] = acc


def coulomb_pallas(atoms: jax.Array, grid_size: int, grid_spacing: float,
                   *, block_x: int = 16, block_y: int = 16,
                   z_iter: int = 1) -> jax.Array:
    """Direct Coulomb summation on a ``grid_size^3`` grid.

    ``atoms`` is ``(n, 4)`` float32 rows of ``(x, y, z, w)`` where ``w``
    already folds in ``1/(4*pi*eps0)`` as in the paper's Listing 1.
    """
    if grid_size % z_iter or grid_size % block_y or grid_size % block_x:
        raise ValueError(
            f"grid_size={grid_size} not divisible by tile "
            f"({z_iter},{block_y},{block_x})")
    n_atoms = atoms.shape[0]
    kernel = functools.partial(
        _coulomb_kernel, grid_spacing=grid_spacing,
        block_x=block_x, block_y=block_y, z_iter=z_iter)
    return pl.pallas_call(
        kernel,
        grid=(grid_size // z_iter, grid_size // block_y,
              grid_size // block_x),
        in_specs=[pl.BlockSpec((n_atoms, 4), lambda z, y, x: (0, 0))],
        out_specs=pl.BlockSpec((z_iter, block_y, block_x),
                               lambda z, y, x: (z, y, x)),
        out_shape=jax.ShapeDtypeStruct(
            (grid_size, grid_size, grid_size), jnp.float32),
        interpret=True,
    )(atoms)


#: Tuning-space axes exported to aot.py / the Rust coordinator.
TUNING_SPACE = {
    "z_iter": [1, 2, 4, 8, 16, 32],
    "block_x": [4, 8, 16, 32],
    "block_y": [1, 2, 4, 8, 16],
}


def flops(grid_size: int, n_atoms: int) -> int:
    """FP32 op count (paper counts ~11 flops per atom-gridpoint pair)."""
    return 11 * grid_size ** 3 * n_atoms
