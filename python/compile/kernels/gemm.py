"""L1 Pallas kernel: tiled GEMM (the paper's GEMM / GEMM-full benchmark).

Tuning parameters map the CLBlast/CLTune space onto a TPU-shaped tiling
(DESIGN.md §Hardware-Adaptation):

  * ``mwg``, ``nwg`` -- output tile computed per program instance (the
    CLBlast work-group tile; here it is the MXU-facing VMEM block).
  * ``kwg``          -- K-panel depth staged through VMEM per grid step
    (the CLBlast KWG shared-memory panel).

The grid iterates (M/mwg, N/nwg, K/kwg) with K innermost, accumulating in
the output block -- the canonical Pallas matmul schedule: the HBM->VMEM
movement that CLBlast expressed via local-memory staging is expressed by
the three BlockSpecs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def gemm_pallas(a: jax.Array, b: jax.Array, *, mwg: int = 32, nwg: int = 32,
                kwg: int = 16) -> jax.Array:
    """C = A @ B with an (mwg, nwg, kwg) block schedule."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    if m % mwg or n % nwg or k % kwg:
        raise ValueError(
            f"({m},{n},{k}) not divisible by tile ({mwg},{nwg},{kwg})")
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // mwg, n // nwg, k // kwg),
        in_specs=[
            pl.BlockSpec((mwg, kwg), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((kwg, nwg), lambda i, j, ks: (ks, j)),
        ],
        out_specs=pl.BlockSpec((mwg, nwg), lambda i, j, ks: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


TUNING_SPACE = {
    "mwg": [8, 16, 32, 64],
    "nwg": [8, 16, 32, 64],
    "kwg": [8, 16, 32],
}


def flops(m: int, n: int, k: int) -> int:
    return 2 * m * n * k


def vmem_bytes(mwg: int, nwg: int, kwg: int) -> int:
    """VMEM footprint of one grid step (A panel + B panel + C tile), f32."""
    return 4 * (mwg * kwg + kwg * nwg + mwg * nwg)
