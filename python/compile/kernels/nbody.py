"""L1 Pallas kernel: all-pairs n-body acceleration (the paper's N-body
benchmark).

Tuning parameters:
  * ``block_i`` -- i-body tile computed per program instance (the CUDA
    thread-block analogue);
  * ``block_j`` -- j-body panel staged per grid step (the shared-memory
    tile of the classic GPU n-body kernel, expressed as the second grid
    dimension + BlockSpec, accumulating into the output tile like the
    GEMM K loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nbody_kernel(bi_ref, bj_ref, o_ref, *, softening):
    j_step = pl.program_id(1)

    @pl.when(j_step == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    pi = bi_ref[...]  # (block_i, 4): x, y, z, m
    pj = bj_ref[...]  # (block_j, 4)
    # pairwise displacement vectors, (block_i, block_j)
    dx = pj[None, :, 0] - pi[:, None, 0]
    dy = pj[None, :, 1] - pi[:, None, 1]
    dz = pj[None, :, 2] - pi[:, None, 2]
    r2 = dx * dx + dy * dy + dz * dz + softening
    inv_r3 = jax.lax.rsqrt(r2) / r2
    w = pj[None, :, 3] * inv_r3  # m_j / r^3
    o_ref[..., 0] += jnp.sum(w * dx, axis=1)
    o_ref[..., 1] += jnp.sum(w * dy, axis=1)
    o_ref[..., 2] += jnp.sum(w * dz, axis=1)


def nbody_pallas(bodies: jax.Array, *, block_i: int = 64,
                 block_j: int = 128,
                 softening: float = 1e-3) -> jax.Array:
    """Gravitational accelerations, ``(n, 3)``, for ``(n, 4)`` bodies."""
    n = bodies.shape[0]
    if n % block_i or n % block_j:
        raise ValueError(f"n={n} not divisible by ({block_i},{block_j})")
    kernel = functools.partial(_nbody_kernel, softening=softening)
    return pl.pallas_call(
        kernel,
        grid=(n // block_i, n // block_j),
        in_specs=[
            pl.BlockSpec((block_i, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 3), jnp.float32),
        interpret=True,
    )(bodies, bodies)


TUNING_SPACE = {
    "block_i": [32, 64, 128, 256],
    "block_j": [32, 64, 128, 256],
}


def flops(n: int) -> int:
    """~20 flops per pairwise interaction."""
    return 20 * n * n
