"""L1: Pallas kernels for the paper's benchmark hot-spots.

Each module exports the kernel entrypoint, its ``TUNING_SPACE`` (the axes
the Rust coordinator tunes on the real-execution path) and an analytic
op-count helper used to stamp PC_ops metadata into the artifact manifest.
"""

from .coulomb import coulomb_pallas  # noqa: F401
from .gemm import gemm_pallas  # noqa: F401
from .transpose import transpose_pallas  # noqa: F401
from . import ref  # noqa: F401
