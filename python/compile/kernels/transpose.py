"""L1 Pallas kernel: tiled matrix transpose (the paper's Transpose bench).

Tuning parameters:
  * ``tile_x``, ``tile_y`` -- the VMEM tile staged per program instance.
    The CUDA version tunes the shared-memory tile + padding to avoid bank
    conflicts; on the Pallas/TPU side the same locality decision is the
    BlockSpec tile shape (padding has no analogue under interpret mode, so
    it is tuned only in the simulated space on the Rust side).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def transpose_pallas(x: jax.Array, *, tile_x: int = 32,
                     tile_y: int = 32) -> jax.Array:
    """Return x.T, staged through (tile_y, tile_x) input tiles."""
    rows, cols = x.shape
    if rows % tile_y or cols % tile_x:
        raise ValueError(
            f"({rows},{cols}) not divisible by tile ({tile_y},{tile_x})")
    return pl.pallas_call(
        _transpose_kernel,
        grid=(cols // tile_x, rows // tile_y),
        # output block (i, j) of shape (tile_x, tile_y) reads input block
        # (j, i) of shape (tile_y, tile_x).
        in_specs=[pl.BlockSpec((tile_y, tile_x), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((tile_x, tile_y), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cols, rows), x.dtype),
        interpret=True,
    )(x)


TUNING_SPACE = {
    "tile_x": [8, 16, 32, 64],
    "tile_y": [8, 16, 32, 64],
}


def bytes_moved(rows: int, cols: int, itemsize: int = 4) -> int:
    return 2 * rows * cols * itemsize
