"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package has a reference here, written with no Pallas
and no tiling so the tuning parameters cannot perturb the semantics.
pytest/hypothesis assert allclose between kernel and oracle across the
tuning axes -- the core correctness signal of the build path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def coulomb_ref(atoms: jax.Array, grid_size: int,
                grid_spacing: float) -> jax.Array:
    """Direct Coulomb summation: V[z,y,x] = sum_j w_j / r_j."""
    idx = jnp.arange(grid_size, dtype=jnp.float32) * grid_spacing
    fz = idx[:, None, None, None]
    fy = idx[None, :, None, None]
    fx = idx[None, None, :, None]
    dx = fx - atoms[None, None, None, :, 0]
    dy = fy - atoms[None, None, None, :, 1]
    dz = fz - atoms[None, None, None, :, 2]
    rd = jax.lax.rsqrt(dx * dx + dy * dy + dz * dz)
    return jnp.sum(atoms[None, None, None, :, 3] * rd, axis=-1)


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def transpose_ref(x: jax.Array) -> jax.Array:
    return x.T


def nbody_ref(bodies: jax.Array, softening: float = 1e-3) -> jax.Array:
    """All-pairs gravitational accelerations, (n, 3)."""
    d = bodies[None, :, :3] - bodies[:, None, :3]  # (i, j, 3)
    r2 = jnp.sum(d * d, axis=-1) + softening
    inv_r3 = jax.lax.rsqrt(r2) / r2
    w = bodies[None, :, 3] * inv_r3  # (i, j)
    return jnp.sum(w[..., None] * d, axis=1)
