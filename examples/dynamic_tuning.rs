//! Dynamic re-tuning on input change (the Table 7 scenario): a service
//! tunes GEMM for large square matrices, then the workload shifts to
//! skinny rectangular products — re-tune with the *same* model, no
//! retraining.
//!
//! ```bash
//! cargo run --release --example dynamic_tuning
//! ```

use pcat::benchmarks::{record_space, Benchmark, Gemm, Input};
use pcat::coordinator::{SearcherChoice, Tuner};
use pcat::gpusim::GpuSpec;
use pcat::model::{dataset_from_recorded, DecisionTreeModel, PrecomputedModel};
use pcat::searcher::{Budget, CostModel};
use pcat::util::rng::Rng;

fn main() {
    let bench = Gemm;
    let gpu = GpuSpec::gtx1070();

    // Model trained once, on the original (square, compute-bound) input.
    let train_input = Input::new("2048x2048", &[2048, 2048, 2048]);
    let rec_train = record_space(&bench, &gpu, &train_input);
    let mut rng = Rng::new(5);
    let ds = dataset_from_recorded(&rec_train, 1.0, &mut rng);
    let dtm = DecisionTreeModel::train(&ds, "gtx1070/2048", &mut rng);
    println!("model trained on {} ({} configs)", train_input.name, rec_train.space.len());

    // The workload shifts: re-tune per input with the same model.
    for input in bench.inputs() {
        let rec = record_space(&bench, &gpu, &input);
        let best = rec.best_time();
        let model = PrecomputedModel::over(&rec.space, &dtm);
        let mut tuner = Tuner::replay(rec, gpu.clone(), CostModel::default())
            .with_budget(Budget::tests(60))
            .with_seed(11);
        let r = tuner.run(SearcherChoice::Profile {
            model: &model,
            inst_reaction: 0.7,
        });
        println!(
            "{:<10} 60-test best {:>9.4} ms  (exhaustive best {:>9.4} ms, \
             gap {:>5.1}%)",
            input.name,
            r.best_ms,
            best,
            (r.best_ms / best - 1.0) * 100.0
        );
    }
    println!("\n(no model retraining between inputs — §4.5)");
}
