//! Quickstart: tune the Coulomb-summation kernel on a simulated GTX 1070
//! with the paper's profile-based searcher, and compare against random
//! search.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pcat::benchmarks::{record_space, Benchmark, Coulomb};
use pcat::coordinator::{SearcherChoice, Tuner};
use pcat::gpusim::GpuSpec;
use pcat::model::OracleModel;
use pcat::searcher::{Budget, CostModel};

fn main() {
    let bench = Coulomb;
    let gpu = GpuSpec::gtx1070();
    let input = bench.default_input();

    // 1. Exhaustively record the space once (the paper's replay
    //    methodology) — in a real deployment this is the tuning run.
    let rec = record_space(&bench, &gpu, &input);
    println!(
        "space: {} configurations over {} tuning parameters",
        rec.space.len(),
        rec.space.dims()
    );
    println!("exhaustive best: {:.4} ms", rec.best_time());

    // 2. Profile-based search, using exact recorded counters as the
    //    TP→PC model (the §4.3 setting).
    let oracle = OracleModel::new(&rec);
    let mut tuner = Tuner::replay(rec.clone(), gpu.clone(), CostModel::default())
        .with_budget(Budget::tests(40))
        .with_seed(7);
    let result = tuner.run(SearcherChoice::Profile {
        model: &oracle,
        inst_reaction: 0.5,
    });
    println!(
        "\nprofile searcher: best {:.4} ms after {} tests ({} profiled)",
        result.best_ms, result.tests, result.profiled_tests
    );
    print!("  best config:");
    for (p, v) in rec.space.params.iter().zip(&result.best_config.0) {
        print!(" {}={v}", p.name);
    }
    println!();

    // 3. Random search with the same budget, for contrast.
    let mut tuner = Tuner::replay(rec, gpu, CostModel::default())
        .with_budget(Budget::tests(40))
        .with_seed(7);
    let random = tuner.run(SearcherChoice::Random);
    println!(
        "random searcher:  best {:.4} ms after {} tests",
        random.best_ms, random.tests
    );
}
