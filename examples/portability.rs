//! Hardware portability (the Table 6 scenario): train the TP→PC model on
//! one simulated GPU, then use it to steer tuning on every other GPU —
//! including architectures with a different counter generation.
//!
//! ```bash
//! cargo run --release --example portability
//! ```

use pcat::benchmarks::{record_space, Benchmark, Gemm};
use pcat::gpusim::GpuSpec;
use pcat::harness::avg_steps_to_well_performing;
use pcat::model::{dataset_from_recorded, DecisionTreeModel, PrecomputedModel};
use pcat::searcher::{ProfileSearcher, RandomSearcher};
use pcat::util::rng::Rng;

fn main() {
    let bench = Gemm;
    let input = bench.default_input();
    let model_gpu = GpuSpec::gtx1070();
    let reps = 200;

    // Train once, on GTX 1070 data.
    println!("training TP→PC decision-tree model on {} …", model_gpu.name);
    let rec_model = record_space(&bench, &model_gpu, &input);
    let mut rng = Rng::new(1);
    let ds = dataset_from_recorded(&rec_model, 1.0, &mut rng);
    let dtm = DecisionTreeModel::train(&ds, model_gpu.name, &mut rng);

    // Tune everywhere, including the unseen RTX 2080.
    println!("\n{:<10} {:>8} {:>9} {:>12}", "tune GPU", "random", "profile", "improvement");
    for gpu in GpuSpec::all() {
        let rec = record_space(&bench, &gpu, &input);
        let model = PrecomputedModel::over(&rec.space, &dtm);
        let rand = avg_steps_to_well_performing(&rec, &gpu, reps, 0, |s| {
            Box::new(RandomSearcher::new(s))
        });
        let prof = avg_steps_to_well_performing(&rec, &gpu, reps, 99, |s| {
            Box::new(ProfileSearcher::new(&model, 0.7, s))
        });
        println!(
            "{:<10} {:>8.1} {:>9.1} {:>11.2}×",
            gpu.name,
            rand,
            prof,
            rand / prof.max(1.0)
        );
    }
    println!(
        "\n(model trained once on {}; no retraining per device — the \
         paper's headline capability)",
        model_gpu.name
    );
}
