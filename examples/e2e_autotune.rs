//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled JAX/Pallas artifacts (`make artifacts`),
//! executes every variant on the PJRT CPU client from Rust, wall-clock
//! times each empirical test, and runs the paper's profile-based
//! searcher against random search over the *really executing* kernel
//! space. PC_ops come from the manifest's analytic op counts; stress
//! counters are synthesized from measured runtime (DESIGN.md §2).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_autotune
//! ```
//!
//! The headline metric (empirical tests + wall-clock to a
//! well-performing configuration) is recorded in EXPERIMENTS.md §E2E.

use std::path::PathBuf;
use std::time::Instant;

use pcat::model::PrecomputedModel;
use pcat::runtime::{load_manifest, PjrtEnv};
use pcat::searcher::{
    Budget, EvalEnv, ProfileSearcher, RandomSearcher, Searcher,
};

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let manifest = load_manifest(&dir)?;
    println!("manifest: {} artifacts", manifest.len());

    for bench in ["coulomb", "gemm", "nbody", "transpose"] {
        let entries: Vec<_> = manifest
            .iter()
            .filter(|e| e.benchmark == bench)
            .cloned()
            .collect();
        println!(
            "\n=== {bench}: {} AOT variants (compiling…) ===",
            entries.len()
        );
        let t0 = Instant::now();
        let mut env = PjrtEnv::new(&entries)?;
        env.reps = 2;
        println!("compiled in {:.1}s", t0.elapsed().as_secs_f64());

        // exhaustive ground truth (this is a real execution of every
        // variant — small spaces by construction)
        let n = env.space().len();
        let mut truth = Vec::with_capacity(n);
        for i in 0..n {
            truth.push(env.measure(i, false).runtime_ms);
        }
        let best = truth.iter().cloned().fold(f64::INFINITY, f64::min);
        let thr = best * 1.1;
        let wp = truth.iter().filter(|&&t| t <= thr).count();
        println!(
            "exhaustive: best {best:.3} ms, {wp}/{n} within 1.1× \
             ({:.1}s full sweep)",
            env.cost_so_far()
        );

        // the TP→PC model on the real path: manifest op counts
        let space = env.space().clone();
        let model = PrecomputedModel::from_pairs(
            space
                .configs
                .iter()
                .cloned()
                .zip(env.ops_counters_all())
                .collect(),
            "manifest-ops",
        );

        // random vs profile over fresh measurements, budget = half space
        let budget = Budget::until(thr, n);
        for (name, searcher) in [
            (
                "random",
                &mut RandomSearcher::new(3) as &mut dyn Searcher,
            ),
            (
                "profile",
                &mut ProfileSearcher::new(&model, 0.5, 3) as &mut dyn Searcher,
            ),
        ] {
            let mut env = PjrtEnv::new(&entries)?;
            env.reps = 2;
            let t0 = Instant::now();
            let trace = searcher.run(&mut env, &budget);
            let steps = trace
                .tests_to_threshold(thr)
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!(">{}", trace.len()));
            println!(
                "{name:>8}: {steps} tests to 1.1× best \
                 (best found {:.3} ms, wall {:.1}s)",
                trace.best_within(usize::MAX),
                t0.elapsed().as_secs_f64()
            );
        }
    }
    Ok(())
}
