//! Simulated device specifications mirroring the paper's Table 3 GPUs.
//!
//! Numbers are the public datasheet values of the real devices (SM
//! counts, clocks, bandwidths, cache sizes); internal bandwidths are
//! datasheet-derived estimates. The absolute values matter less than the
//! *ratios* (flop-to-byte, cache capacities), which is what moves optima
//! between devices.

use crate::counters::CounterSet;

/// GPU micro-architecture generation (paper Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Kepler,
    Maxwell,
    Pascal,
    Turing,
}

/// A simulated GPU device.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    pub arch: Arch,
    pub sm_count: u32,
    pub cores_per_sm: u32,
    pub clock_ghz: f64,
    /// DRAM bandwidth, GB/s.
    pub dram_bw: f64,
    /// Aggregate L2 bandwidth, GB/s.
    pub l2_bw: f64,
    /// Aggregate texture/L1 read path bandwidth, GB/s.
    pub tex_bw: f64,
    /// Aggregate shared-memory bandwidth, GB/s.
    pub shared_bw: f64,
    /// L2 cache size, bytes (device-wide).
    pub l2_size: u64,
    /// Texture/read-only cache size per SM, bytes.
    pub tex_size_per_sm: u64,
    /// 32-bit registers per SM.
    pub regs_per_sm: u64,
    pub max_threads_per_sm: u32,
    pub max_blocks_per_sm: u32,
    /// Shared memory per SM, bytes.
    pub shared_per_sm: u64,
    /// FP64 throughput as a fraction of FP32.
    pub fp64_ratio: f64,
    /// Can the SM dual-issue INT and FP32 in parallel (Volta+)?
    pub dual_issue: bool,
}

impl GpuSpec {
    pub fn cores(&self) -> u64 {
        self.sm_count as u64 * self.cores_per_sm as u64
    }

    /// Peak FP32 instruction rate, Gops/s (1 op per core-cycle; FMA
    /// counting as 2 flops is a workload-side convention).
    pub fn fp32_gips(&self) -> f64 {
        self.cores() as f64 * self.clock_ghz
    }

    /// Counter-name generation exposed by this device (changed at Volta).
    pub fn counter_set(&self) -> CounterSet {
        match self.arch {
            Arch::Turing => CounterSet::VoltaPlus,
            _ => CounterSet::PreVolta,
        }
    }

    pub fn gtx680() -> GpuSpec {
        GpuSpec {
            name: "GTX680",
            arch: Arch::Kepler,
            sm_count: 8,
            cores_per_sm: 192,
            clock_ghz: 1.058,
            dram_bw: 192.0,
            l2_bw: 512.0,
            // Kepler's read-only data path (LDG/tex) was notoriously weak
            tex_bw: 350.0,
            shared_bw: 1300.0,
            l2_size: 512 * 1024,
            tex_size_per_sm: 48 * 1024,
            regs_per_sm: 65_536,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 16,
            shared_per_sm: 48 * 1024,
            fp64_ratio: 1.0 / 24.0,
            dual_issue: false,
        }
    }

    pub fn gtx750() -> GpuSpec {
        GpuSpec {
            name: "GTX750",
            arch: Arch::Maxwell,
            sm_count: 4,
            cores_per_sm: 128,
            clock_ghz: 1.020,
            dram_bw: 80.0,
            l2_bw: 280.0,
            tex_bw: 380.0,
            shared_bw: 700.0,
            l2_size: 2 * 1024 * 1024,
            tex_size_per_sm: 24 * 1024,
            regs_per_sm: 65_536,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            shared_per_sm: 64 * 1024,
            fp64_ratio: 1.0 / 32.0,
            dual_issue: false,
        }
    }

    pub fn gtx1070() -> GpuSpec {
        GpuSpec {
            name: "GTX1070",
            arch: Arch::Pascal,
            sm_count: 15,
            cores_per_sm: 128,
            clock_ghz: 1.506,
            dram_bw: 256.0,
            l2_bw: 1100.0,
            tex_bw: 2200.0,
            shared_bw: 3100.0,
            l2_size: 2 * 1024 * 1024,
            tex_size_per_sm: 48 * 1024,
            regs_per_sm: 65_536,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            shared_per_sm: 96 * 1024,
            fp64_ratio: 1.0 / 32.0,
            dual_issue: false,
        }
    }

    pub fn rtx2080() -> GpuSpec {
        GpuSpec {
            name: "RTX2080",
            arch: Arch::Turing,
            sm_count: 46,
            cores_per_sm: 64,
            clock_ghz: 1.515,
            dram_bw: 448.0,
            l2_bw: 2100.0,
            tex_bw: 4200.0,
            shared_bw: 5800.0,
            l2_size: 4 * 1024 * 1024,
            tex_size_per_sm: 64 * 1024,
            regs_per_sm: 65_536,
            max_threads_per_sm: 1024,
            max_blocks_per_sm: 16,
            shared_per_sm: 64 * 1024,
            fp64_ratio: 1.0 / 32.0,
            dual_issue: true,
        }
    }

    /// The paper's Table 3 testbed, in release order.
    pub fn all() -> Vec<GpuSpec> {
        vec![
            Self::gtx680(),
            Self::gtx750(),
            Self::gtx1070(),
            Self::rtx2080(),
        ]
    }

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        let needle = name.to_ascii_lowercase().replace(['-', '_', ' '], "");
        Self::all()
            .into_iter()
            .find(|g| g.name.to_ascii_lowercase() == needle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_devices_match_paper_table3() {
        let all = GpuSpec::all();
        assert_eq!(all.len(), 4);
        assert_eq!(all[0].arch, Arch::Kepler);
        assert_eq!(all[3].arch, Arch::Turing);
    }

    #[test]
    fn lookup_by_name_is_forgiving() {
        assert!(GpuSpec::by_name("gtx1070").is_some());
        assert!(GpuSpec::by_name("GTX-1070").is_some());
        assert!(GpuSpec::by_name("RTX 2080").is_some());
        assert!(GpuSpec::by_name("titan").is_none());
    }

    #[test]
    fn counter_set_flips_at_volta() {
        assert_eq!(
            GpuSpec::gtx1070().counter_set(),
            crate::counters::CounterSet::PreVolta
        );
        assert_eq!(
            GpuSpec::rtx2080().counter_set(),
            crate::counters::CounterSet::VoltaPlus
        );
    }

    #[test]
    fn peak_rates_ordered_by_generation() {
        // flop-to-byte ratio grows from 680 to 2080 — the property that
        // flips compute/memory-bound classification across the testbed.
        let r680 = GpuSpec::gtx680().fp32_gips() / GpuSpec::gtx680().dram_bw;
        let r2080 =
            GpuSpec::rtx2080().fp32_gips() / GpuSpec::rtx2080().dram_bw;
        assert!(r2080 > r680);
    }
}
