//! Analytic GPU performance-counter simulator.
//!
//! This is the substitution for the paper's physical testbed (DESIGN.md
//! §2): four NVIDIA GPUs and CUPTI profiling. Given a [`Workload`]
//! descriptor (what a kernel configuration *does*: instruction mix,
//! memory traffic, parallelism shape) and a [`GpuSpec`] (what the device
//! *can do*), the engine produces a runtime and the full Table-1 counter
//! vector.
//!
//! Design constraints, in order of importance:
//!
//! 1. **PC_ops must depend only weakly on the device** — the paper's
//!    Eq. 4. Instruction counts and request-level transaction counts are
//!    computed from the workload alone; only cache-miss-derived traffic
//!    (L2↔DRAM) depends on device cache capacities, mirroring the
//!    paper's observed imprecision near capacity thresholds (§3.1).
//! 2. **PC_stress must depend strongly on the device and input** — they
//!    are utilizations from a roofline-style timing model, so a kernel
//!    that is compute-bound on a bandwidth-rich GPU becomes memory-bound
//!    on a bandwidth-poor one, flipping the detected bottleneck.
//! 3. The induced optimum must move across devices and inputs, so the
//!    portability experiments (Tables 6–7) are non-trivial.

mod engine;
mod spec;
mod workload;

pub use engine::{simulate, Occupancy, SimResult};
pub use spec::{Arch, GpuSpec};
pub use workload::Workload;
