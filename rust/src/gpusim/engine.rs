//! The simulation engine: Workload × GpuSpec → (runtime, counters).
//!
//! A roofline-style model with occupancy-driven latency hiding. The
//! counter emission keeps the paper's PC_ops/PC_stress asymmetry:
//! operation counts are workload-derived (device-weak), utilizations are
//! timing-derived (device-strong). All counters are reported in the
//! *pre-Volta scale* (utilization ranks in 0–10, efficiencies in 0–100);
//! for Volta+ devices this corresponds to KTT applying the Table 1
//! conversion ratios at measurement time.

use crate::counters::{Counter, CounterVec};

use super::{GpuSpec, Workload};

/// Occupancy analysis of one launch configuration.
#[derive(Debug, Clone)]
pub struct Occupancy {
    pub blocks_per_sm: u32,
    /// Resident threads / max threads, in [0, 1].
    pub occupancy: f64,
    /// Which resource limited the residency.
    pub limiter: &'static str,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub runtime_ms: f64,
    pub counters: CounterVec,
    pub occupancy: Occupancy,
}

/// Architectural per-thread register ceiling (beyond it, compilers spill).
const REG_LIMIT_PER_THREAD: f64 = 255.0;
/// Kernel launch + driver overhead.
const LAUNCH_OVERHEAD_S: f64 = 3.0e-6;

/// Cache hit rate for a read working set against a capacity.
/// Near-perfect while the footprint fits; decays with the ratio beyond
/// (conflict/capacity misses). This is the one deliberately
/// device-dependent PC_ops pathway (paper §3.1 imprecision note).
fn hit_rate(footprint: f64, capacity: f64) -> f64 {
    if footprint <= 0.0 {
        return 0.0;
    }
    if footprint <= capacity {
        0.95
    } else {
        0.95 * capacity / footprint
    }
}

/// Compute residency limits per SM.
pub fn occupancy(spec: &GpuSpec, w: &Workload) -> Occupancy {
    if w.block_size <= 0.0 {
        return Occupancy {
            blocks_per_sm: 0,
            occupancy: 0.0,
            limiter: "empty launch",
        };
    }
    let mut limit = spec.max_blocks_per_sm as f64;
    let mut limiter = "blocks";

    let by_threads = spec.max_threads_per_sm as f64 / w.block_size;
    if by_threads < limit {
        limit = by_threads;
        limiter = "threads";
    }
    let regs_per_block = w.regs_per_thread.max(16.0) * w.block_size;
    let by_regs = spec.regs_per_sm as f64 / regs_per_block;
    if by_regs < limit {
        limit = by_regs;
        limiter = "registers";
    }
    if w.shared_bytes_per_block > 0.0 {
        let by_shared = spec.shared_per_sm as f64 / w.shared_bytes_per_block;
        if by_shared < limit {
            limit = by_shared;
            limiter = "shared memory";
        }
    }
    let blocks_per_sm = limit.floor().max(1.0) as u32;
    let occ = (blocks_per_sm as f64 * w.block_size
        / spec.max_threads_per_sm as f64)
        .min(1.0);
    Occupancy {
        blocks_per_sm,
        occupancy: occ,
        limiter,
    }
}

/// Run the analytic model.
pub fn simulate(spec: &GpuSpec, workload: &Workload) -> SimResult {
    let mut w = workload.clone();
    w.apply_spilling(REG_LIMIT_PER_THREAD);

    let occ = occupancy(spec, &w);

    // ---- divergence / warp efficiency --------------------------------
    let warp_e_frac = (1.0 - w.divergence * (31.0 / 32.0)).clamp(1.0 / 32.0, 1.0);
    let total_inst = w.total_inst().max(1.0);
    // warp-level issued instructions (divergent warps issue for all lanes)
    let inst_exe = total_inst / 32.0 / warp_e_frac;

    // ---- cache hierarchy ----------------------------------------------
    let tex_read = w.gread * w.tex_fraction.clamp(0.0, 1.0);
    let tex_hit = hit_rate(w.tex_footprint_per_sm, spec.tex_size_per_sm as f64);
    let local_rd = w.local_bytes * 0.5;
    let local_wr = w.local_bytes * 0.5;
    let l2_read =
        tex_read * (1.0 - tex_hit) + (w.gread - tex_read) + local_rd;
    let l2_hit = hit_rate(w.l2_footprint, spec.l2_size as f64);
    let dram_read = l2_read * (1.0 - l2_hit);
    let l2_write = w.gwrite + local_wr;
    // write-back: dirty lines eventually reach DRAM; streaming writes
    // mostly miss.
    let dram_write = l2_write * (1.0 - 0.5 * l2_hit);

    // ---- subsystem busy times (seconds, device-wide) ------------------
    let thread_rate = spec.fp32_gips() * 1e9; // thread-level ops/s
    let div = warp_e_frac; // divergence inflates issue time
    let t_fp32 = w.fp32 / thread_rate / div;
    let t_fp64 = w.fp64 / (thread_rate * spec.fp64_ratio) / div;
    let t_int = w.int / thread_rate / div;
    let t_ldst = w.ldst / (thread_rate * 0.25) / div;
    let t_other = (w.misc + w.cont + w.bconv) / (thread_rate * 0.5) / div;
    let t_compute = if spec.dual_issue {
        t_fp32.max(t_int) + t_fp64 + t_ldst + t_other
    } else {
        t_fp32 + t_int + t_fp64 + t_ldst + t_other
    };

    let t_dram = (dram_read + dram_write) / (spec.dram_bw * 1e9);
    let t_l2 = (l2_read + l2_write) / (spec.l2_bw * 1e9);
    let t_tex = tex_read / (spec.tex_bw * 1e9);
    let t_shared =
        (w.shared_load_bytes + w.shared_store_bytes) / (spec.shared_bw * 1e9);

    let times = [t_compute, t_dram, t_l2, t_tex, t_shared];
    let t_max = times.iter().fold(0.0f64, |a, &b| a.max(b));
    let t_sum: f64 = times.iter().sum();
    // imperfect overlap of the non-dominant subsystems
    let mut t = t_max + 0.30 * (t_sum - t_max);

    // ---- parallelism & latency hiding -----------------------------------
    // Latency hiding is a *per-SM* property: below ~1/3 occupancy, the
    // warp scheduler cannot cover pipeline/memory latencies. The
    // *achieved* occupancy is bounded both by the residency limits
    // (registers/shared/threads — `occ`) and by how many blocks the
    // launch actually provides per SM.
    let total_blocks = w.blocks().max(1.0);
    let actual_bps = (total_blocks / spec.sm_count as f64)
        .min(occ.blocks_per_sm as f64);
    let occ_actual = (actual_bps * w.block_size
        / spec.max_threads_per_sm as f64)
        .min(1.0);
    let lat = (occ_actual * 3.0).clamp(0.08, 1.0);
    t /= lat;

    // Throughput is a *device coverage* property: SMs with no resident
    // block contribute nothing to the device-wide rates assumed above.
    let sm_cov = (total_blocks / spec.sm_count as f64).min(1.0);
    t /= sm_cov.max(0.02);

    // multi-wave tail quantization: the last wave runs partially full
    let one_wave_blocks =
        (spec.sm_count as f64) * occ.blocks_per_sm as f64;
    let waves = total_blocks / one_wave_blocks;
    if waves > 1.0 {
        t *= waves.ceil() / waves;
    }

    // SM efficiency counter: coverage × tail
    let sm_e = if waves > 1.0 {
        sm_cov * (waves / waves.ceil())
    } else {
        sm_cov
    };

    t += LAUNCH_OVERHEAD_S;

    // ---- counter emission ----------------------------------------------
    let mut c = CounterVec::new();
    // PC_ops: memory transactions (32-byte sectors)
    c.set(Counter::DramRt, dram_read / 32.0);
    c.set(Counter::DramWt, dram_write / 32.0);
    c.set(Counter::L2Rt, l2_read / 32.0);
    c.set(Counter::L2Wt, l2_write / 32.0);
    c.set(Counter::TexRwt, tex_read / 32.0);
    c.set(Counter::ShrLt, w.shared_load_bytes / 128.0);
    c.set(Counter::ShrWt, w.shared_store_bytes / 128.0);
    // LOC_O: local traffic relative to overall L1 traffic, in percent
    let l1_total = w.gread + w.gwrite + w.local_bytes;
    let loc_o = if l1_total > 0.0 {
        100.0 * w.local_bytes / l1_total
    } else {
        0.0
    };
    c.set(Counter::LocO, loc_o);
    // PC_ops: instruction counts (thread-level)
    c.set(Counter::InstF32, w.fp32);
    c.set(Counter::InstF64, w.fp64);
    c.set(Counter::InstInt, w.int);
    c.set(Counter::InstMisc, w.misc);
    c.set(Counter::InstLdst, w.ldst);
    c.set(Counter::InstCont, w.cont);
    c.set(Counter::InstBconv, w.bconv);
    c.set(Counter::InstExe, inst_exe);
    c.set(
        Counter::InstIssueU,
        (100.0 * t_compute / t).clamp(0.0, 100.0),
    );
    // PC_stress: utilizations (pre-Volta 0..10 rank scale)
    c.set(Counter::DramU, (10.0 * t_dram / t).clamp(0.0, 10.0));
    c.set(Counter::L2U, (10.0 * t_l2 / t).clamp(0.0, 10.0));
    c.set(Counter::TexU, (10.0 * t_tex / t).clamp(0.0, 10.0));
    c.set(Counter::ShrU, (10.0 * t_shared / t).clamp(0.0, 10.0));
    c.set(Counter::SmE, 100.0 * sm_e);
    c.set(Counter::WarpE, 100.0 * warp_e_frac);
    c.set(Counter::WarpNpE, (100.0 * warp_e_frac * 0.99).max(1.0));
    c.set(Counter::Threads, w.threads);

    SimResult {
        runtime_ms: t * 1e3,
        counters: c,
        occupancy: occ,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compute_bound_workload() -> Workload {
        Workload {
            threads: (1u32 << 20) as f64,
            block_size: 256.0,
            regs_per_thread: 32.0,
            fp32: 4e9,
            int: 2e8,
            ldst: 1e7,
            gread: 64e6,
            gwrite: 4e6,
            tex_fraction: 0.9,
            tex_footprint_per_sm: 4096.0,
            l2_footprint: 1e6,
            ..Default::default()
        }
    }

    #[test]
    fn runtime_positive_and_finite() {
        for spec in GpuSpec::all() {
            let r = simulate(&spec, &compute_bound_workload());
            assert!(r.runtime_ms.is_finite() && r.runtime_ms > 0.0);
        }
    }

    #[test]
    fn utilizations_bounded() {
        for spec in GpuSpec::all() {
            let r = simulate(&spec, &compute_bound_workload());
            for c in [
                Counter::DramU,
                Counter::L2U,
                Counter::TexU,
                Counter::ShrU,
            ] {
                let v = r.counters.get(c);
                assert!((0.0..=10.0).contains(&v), "{c}={v}");
            }
            for c in [Counter::SmE, Counter::WarpE, Counter::InstIssueU] {
                let v = r.counters.get(c);
                assert!((0.0..=100.0).contains(&v), "{c}={v}");
            }
        }
    }

    #[test]
    fn faster_gpu_is_faster() {
        let w = compute_bound_workload();
        let slow = simulate(&GpuSpec::gtx750(), &w).runtime_ms;
        let fast = simulate(&GpuSpec::rtx2080(), &w).runtime_ms;
        assert!(fast < slow, "fast={fast} slow={slow}");
    }

    #[test]
    fn pc_ops_device_weak_pc_stress_device_strong() {
        // The paper's core asymmetry (Eq. 4): instruction PC_ops must be
        // identical across devices, stress counters must differ. Use a
        // mixed workload so neither subsystem saturates on both devices.
        let w = Workload {
            threads: (1u32 << 22) as f64,
            block_size: 256.0,
            regs_per_thread: 32.0,
            fp32: 50e9,
            ldst: 1e8,
            gread: 2e9,
            gwrite: 1e9,
            tex_fraction: 0.0,
            l2_footprint: 4e9,
            ..Default::default()
        };
        let a = simulate(&GpuSpec::gtx750(), &w).counters;
        let b = simulate(&GpuSpec::rtx2080(), &w).counters;
        assert_eq!(a.get(Counter::InstF32), b.get(Counter::InstF32));
        assert_eq!(a.get(Counter::TexRwt), b.get(Counter::TexRwt));
        assert!(
            (a.get(Counter::DramU) - b.get(Counter::DramU)).abs() > 0.2,
            "stress counters should differ across devices"
        );
    }

    #[test]
    fn occupancy_limited_by_registers() {
        let w = Workload {
            threads: 1e6,
            block_size: 256.0,
            regs_per_thread: 255.0,
            fp32: 1e6,
            ..Default::default()
        };
        let o = occupancy(&GpuSpec::gtx1070(), &w);
        assert_eq!(o.limiter, "registers");
        assert!(o.occupancy < 0.3);
    }

    #[test]
    fn low_occupancy_hurts_runtime() {
        let mut w = compute_bound_workload();
        w.regs_per_thread = 32.0;
        let fast = simulate(&GpuSpec::gtx1070(), &w).runtime_ms;
        w.regs_per_thread = 250.0; // same work, low occupancy
        let slow = simulate(&GpuSpec::gtx1070(), &w).runtime_ms;
        assert!(slow > fast);
    }

    #[test]
    fn memory_bound_detected_on_weak_bandwidth() {
        // a streaming workload: DRAM_U should dominate on every device
        let w = Workload {
            threads: (1u32 << 22) as f64,
            block_size: 256.0,
            regs_per_thread: 32.0,
            fp32: 1e7,
            ldst: 4e8,
            gread: 2e9,
            gwrite: 2e9,
            tex_fraction: 0.0,
            l2_footprint: 4e9,
            ..Default::default()
        };
        let r = simulate(&GpuSpec::gtx750(), &w);
        assert!(r.counters.get(Counter::DramU) > 7.0);
        assert!(r.counters.get(Counter::InstIssueU) < 50.0);
    }

    #[test]
    fn spilling_produces_local_traffic() {
        let w = Workload {
            threads: 1e6,
            block_size: 128.0,
            regs_per_thread: 300.0,
            fp32: 1e8,
            gread: 1e6,
            gwrite: 1e6,
            ..Default::default()
        };
        let r = simulate(&GpuSpec::gtx1070(), &w);
        assert!(r.counters.get(Counter::LocO) > 0.0);
    }

    #[test]
    fn divergence_lowers_warp_efficiency_and_slows() {
        let mut w = compute_bound_workload();
        let base = simulate(&GpuSpec::gtx1070(), &w);
        w.divergence = 0.5;
        let div = simulate(&GpuSpec::gtx1070(), &w);
        assert!(div.counters.get(Counter::WarpE) < base.counters.get(Counter::WarpE));
        assert!(div.runtime_ms > base.runtime_ms);
    }

    #[test]
    fn input_scaling_keeps_ops_ratios_stable() {
        // Eq. 5: scaling the input scales PC_ops ~linearly, so the
        // *ratio* between two configurations is stable.
        let w1 = compute_bound_workload();
        let w2 = {
            let mut w = compute_bound_workload();
            w.fp32 *= 0.5; // a "coarsened" variant
            w
        };
        let spec = GpuSpec::gtx1070();
        let r_small = simulate(&spec, &w1).counters.get(Counter::InstF32)
            / simulate(&spec, &w2).counters.get(Counter::InstF32);
        let r_big = simulate(&spec, &w1.scaled(8.0))
            .counters
            .get(Counter::InstF32)
            / simulate(&spec, &w2.scaled(8.0)).counters.get(Counter::InstF32);
        assert!((r_small - r_big).abs() < 1e-9);
    }
}
