//! Workload descriptor: the device-independent characterization of what
//! one kernel configuration does. Benchmarks (`crate::benchmarks`)
//! produce these analytically from (tuning configuration, input).

/// What a kernel launch does, independent of the device it runs on.
///
/// Instruction counts are *thread-level* totals (like the CUPTI
/// `inst_fp_32` family); memory traffic is request-level bytes after
/// coalescing but before caches.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    /// Total CUDA threads launched.
    pub threads: f64,
    /// Threads per block.
    pub block_size: f64,
    /// Registers per thread demanded by the configuration (drives
    /// occupancy and — beyond 255 — spilling).
    pub regs_per_thread: f64,
    /// Shared memory per block, bytes.
    pub shared_bytes_per_block: f64,

    // --- thread-level instruction totals ---
    pub fp32: f64,
    pub fp64: f64,
    pub int: f64,
    pub misc: f64,
    pub ldst: f64,
    pub cont: f64,
    pub bconv: f64,

    // --- request-level global memory traffic, bytes ---
    pub gread: f64,
    pub gwrite: f64,
    /// Fraction of global reads served through the texture/read-only
    /// path (the rest bypass straight to L2).
    pub tex_fraction: f64,
    /// Read working set per SM relevant to the texture cache, bytes.
    pub tex_footprint_per_sm: f64,
    /// Read working set relevant to L2 (device-wide), bytes.
    pub l2_footprint: f64,

    // --- shared memory traffic, bytes ---
    pub shared_load_bytes: f64,
    pub shared_store_bytes: f64,

    /// Local-memory (register spill) traffic, bytes. Usually derived
    /// from `regs_per_thread` by [`Workload::apply_spilling`].
    pub local_bytes: f64,

    /// Branch-divergence factor in [0, 1): 0 = perfectly converged
    /// warps; 0.5 ≈ half the lanes idle on average.
    pub divergence: f64,
}

impl Workload {
    /// Total thread-level instructions across all classes.
    pub fn total_inst(&self) -> f64 {
        self.fp32 + self.fp64 + self.int + self.misc + self.ldst + self.cont
            + self.bconv
    }

    /// Number of thread blocks.
    pub fn blocks(&self) -> f64 {
        if self.block_size > 0.0 {
            (self.threads / self.block_size).ceil()
        } else {
            0.0
        }
    }

    /// Model register spilling against a per-thread register budget:
    /// registers beyond `limit` become local-memory traffic (8 bytes of
    /// ld+st per excess register per thread, a CUDA rule of thumb) and
    /// extra ld/st instructions.
    pub fn apply_spilling(&mut self, limit: f64) {
        if self.regs_per_thread > limit {
            let excess = self.regs_per_thread - limit;
            // each spilled register is stored + reloaded ~once per use
            self.local_bytes += 8.0 * excess * self.threads;
            self.ldst += 2.0 * excess;
            self.regs_per_thread = limit;
        }
    }

    /// Scale every input-size-proportional quantity by `s` — used by
    /// property tests to check the paper's Eq. 5 stability claim.
    pub fn scaled(&self, s: f64) -> Workload {
        let mut w = self.clone();
        w.threads *= s;
        w.fp32 *= s;
        w.fp64 *= s;
        w.int *= s;
        w.misc *= s;
        w.ldst *= s;
        w.cont *= s;
        w.bconv *= s;
        w.gread *= s;
        w.gwrite *= s;
        w.shared_load_bytes *= s;
        w.shared_store_bytes *= s;
        w.local_bytes *= s;
        w.l2_footprint *= s;
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spilling_only_beyond_limit() {
        let mut w = Workload {
            regs_per_thread: 64.0,
            threads: 100.0,
            ..Default::default()
        };
        w.apply_spilling(128.0);
        assert_eq!(w.local_bytes, 0.0);
        w.regs_per_thread = 160.0;
        w.apply_spilling(128.0);
        assert_eq!(w.local_bytes, 8.0 * 32.0 * 100.0);
        assert_eq!(w.regs_per_thread, 128.0);
        assert_eq!(w.ldst, 64.0);
    }

    #[test]
    fn blocks_rounds_up() {
        let w = Workload {
            threads: 1000.0,
            block_size: 256.0,
            ..Default::default()
        };
        assert_eq!(w.blocks(), 4.0);
    }

    #[test]
    fn scaled_preserves_structure() {
        let w = Workload {
            threads: 10.0,
            fp32: 100.0,
            gread: 4000.0,
            divergence: 0.25,
            regs_per_thread: 32.0,
            ..Default::default()
        };
        let s = w.scaled(3.0);
        assert_eq!(s.fp32, 300.0);
        assert_eq!(s.gread, 12000.0);
        // per-thread shape is invariant
        assert_eq!(s.divergence, w.divergence);
        assert_eq!(s.regs_per_thread, w.regs_per_thread);
        assert!((s.fp32 / s.threads - w.fp32 / w.threads).abs() < 1e-12);
    }
}
