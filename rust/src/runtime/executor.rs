//! PJRT executor: load HLO text → compile once → execute + time.
//!
//! Follows /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects in proto form; the text parser reassigns ids).

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::rng::Rng;

use super::ArtifactEntry;

/// A compiled kernel variant ready to run on the PJRT CPU client.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    args: Vec<xla::Literal>,
}

impl Executor {
    /// Compile one artifact and materialize its synthetic inputs.
    pub fn compile(
        client: &xla::PjRtClient,
        entry: &ArtifactEntry,
        seed: u64,
    ) -> Result<Executor> {
        let exe = Self::compile_hlo(client, &entry.path)?;
        let mut rng = Rng::new(seed);
        let args = entry
            .arg_shapes
            .iter()
            .map(|shape| synth_input(shape, &mut rng))
            .collect::<Result<_>>()?;
        Ok(Executor { exe, args })
    }

    fn compile_hlo(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// One full execution (inputs already device-resident as literals);
    /// returns wall-clock milliseconds. Output is materialized to keep
    /// lazy backends honest.
    pub fn run_once(&self) -> Result<f64> {
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&self.args)?;
        let _ = result[0][0].to_literal_sync()?;
        Ok(t0.elapsed().as_secs_f64() * 1e3)
    }

    /// Median-of-`reps` timing after one warmup run.
    pub fn time_ms(&self, reps: usize) -> Result<f64> {
        self.run_once()?; // warmup
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            times.push(self.run_once()?);
        }
        times.sort_by(f64::total_cmp);
        Ok(times[times.len() / 2])
    }
}

/// Synthetic float32 input in [0.1, 1.1) — strictly positive so rsqrt
/// paths stay finite.
fn synth_input(shape: &[usize], rng: &mut Rng) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    let data: Vec<f32> =
        (0..n).map(|_| 0.1 + rng.f64() as f32).collect();
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&data)
        .reshape(&dims)
        .context("reshaping synthetic input")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::load_manifest;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn compiles_and_runs_a_real_artifact() {
        let Some(dir) = artifacts() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        let entries = load_manifest(&dir).unwrap();
        let entry = entries
            .iter()
            .find(|e| e.benchmark == "transpose")
            .unwrap();
        let client = xla::PjRtClient::cpu().unwrap();
        let exe = Executor::compile(&client, entry, 1).unwrap();
        let ms = exe.time_ms(3).unwrap();
        assert!(ms > 0.0 && ms < 60_000.0, "{ms} ms");
    }

    #[test]
    fn synth_input_shape() {
        let mut rng = Rng::new(1);
        let lit = synth_input(&[4, 2], &mut rng).unwrap();
        let v = lit.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), 8);
        assert!(v.iter().all(|x| *x > 0.0));
    }
}
