//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json;

/// One AOT-compiled kernel variant.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub benchmark: String,
    pub name: String,
    /// Tuning configuration (param → value).
    pub config: BTreeMap<String, i64>,
    /// HLO text file, absolute.
    pub path: PathBuf,
    /// Input shapes (all float32).
    pub arg_shapes: Vec<Vec<usize>>,
    /// Analytic op counts stamped by the L2 model (PC_ops source).
    pub ops: BTreeMap<String, f64>,
}

/// Parse `artifacts/manifest.json`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactEntry>> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading manifest in {}", dir.display()))?;
    let v = json::parse(&text)?;
    let mut out = Vec::new();
    for e in v.as_arr().context("manifest must be an array")? {
        let config = e
            .get("config")?
            .as_obj()
            .context("config")?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_i64().unwrap_or(0)))
            .collect();
        let arg_shapes = e
            .get("args")?
            .as_arr()
            .context("args")?
            .iter()
            .map(|a| {
                Ok(a.get("shape")?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_i64().unwrap_or(0) as usize)
                    .collect())
            })
            .collect::<Result<_>>()?;
        let ops = e
            .get("ops")?
            .as_obj()
            .context("ops")?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(0.0)))
            .collect();
        out.push(ArtifactEntry {
            benchmark: e.get("benchmark")?.as_str().unwrap_or("").to_string(),
            name: e.get("name")?.as_str().unwrap_or("").to_string(),
            config,
            path: dir.join(e.get("path")?.as_str().unwrap_or("")),
            arg_shapes,
            ops,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn parses_built_manifest() {
        let Some(dir) = manifest_dir() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        let entries = load_manifest(&dir).unwrap();
        assert!(entries.len() >= 30, "{}", entries.len());
        let benches: std::collections::BTreeSet<_> =
            entries.iter().map(|e| e.benchmark.clone()).collect();
        assert!(benches.contains("coulomb"));
        assert!(benches.contains("gemm"));
        assert!(benches.contains("transpose"));
        for e in &entries {
            assert!(e.path.exists(), "{}", e.path.display());
            assert!(!e.config.is_empty());
            assert!(!e.arg_shapes.is_empty());
        }
    }

    #[test]
    fn gemm_entries_have_tile_configs() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let entries = load_manifest(&dir).unwrap();
        let gemm: Vec<_> =
            entries.iter().filter(|e| e.benchmark == "gemm").collect();
        assert!(!gemm.is_empty());
        for e in gemm {
            assert!(e.config.contains_key("mwg"));
            assert!(e.ops.get("INST_F32").copied().unwrap_or(0.0) > 0.0);
        }
    }
}
