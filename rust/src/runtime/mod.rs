//! PJRT runtime: the *real* empirical-measurement path.
//!
//! Loads the HLO-text artifacts produced at build time by
//! `python/compile/aot.py` (L2 JAX models wrapping L1 Pallas kernels),
//! compiles them on the PJRT CPU client via the `xla` crate, executes
//! them with synthetic inputs and wall-clock-times each run. Python is
//! never on this path.
//!
//! [`PjrtEnv`] adapts a benchmark's artifact set into an [`EvalEnv`], so
//! every searcher can tune over *really executing* kernels
//! (examples/e2e_autotune.rs). Counter synthesis for the real path is
//! documented in DESIGN.md §2: PC_ops come from the manifest's analytic
//! op counts; PC_stress are derived from measured runtime against
//! calibrated host rates.

// The manifest loader is dependency-free and always available; the
// executor and environment need the `xla` crate (PJRT bindings), which
// only exists where the prebuilt xla toolchain is installed — they are
// gated behind the off-by-default `xla` feature (see Cargo.toml).
mod artifact;
#[cfg(feature = "xla")]
mod executor;
#[cfg(feature = "xla")]
mod pjrt_env;

pub use artifact::{load_manifest, ArtifactEntry};
#[cfg(feature = "xla")]
pub use executor::Executor;
#[cfg(feature = "xla")]
pub use pjrt_env::{host_spec, PjrtEnv};
