//! [`PjrtEnv`]: adapt a benchmark's AOT artifact set into an [`EvalEnv`]
//! so every searcher can tune over really-executing kernels.
//!
//! Counter synthesis (DESIGN.md §2 substitution): PC_ops come from the
//! manifest's analytic op counts (which is exactly what PC_ops *are*);
//! PC_stress utilizations are derived by comparing measured wall-clock
//! against calibrated host throughputs, so the expert system sees the
//! same "which subsystem dominates" signal a profiler would give.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::counters::{Counter, CounterVec};
use crate::gpusim::{Arch, GpuSpec};
use crate::searcher::{EvalEnv, Measurement};
use crate::tuning::{Config, ParamDef, Space};

use super::{ArtifactEntry, Executor};

/// A pseudo device spec for the host CPU running the PJRT client: the
/// expert system only consumes `cores()` (Eq. 14) and the counter
/// generation.
pub fn host_spec() -> GpuSpec {
    GpuSpec {
        name: "HOSTCPU",
        arch: Arch::Pascal, // pre-Volta counter semantics
        sm_count: 1,
        cores_per_sm: std::thread::available_parallelism()
            .map(|n| n.get() as u32)
            .unwrap_or(4),
        clock_ghz: 3.0,
        dram_bw: 10.0,
        l2_bw: 50.0,
        tex_bw: 50.0,
        shared_bw: 100.0,
        l2_size: 32 * 1024 * 1024,
        tex_size_per_sm: 512 * 1024,
        regs_per_sm: 1 << 20,
        max_threads_per_sm: 1 << 16,
        max_blocks_per_sm: 1 << 10,
        shared_per_sm: 1 << 20,
        fp64_ratio: 0.5,
        dual_issue: false,
    }
}

/// Calibrated host rates for stress synthesis (interpret-mode Pallas on
/// the CPU PJRT client is far from peak native throughput).
const HOST_GFLOPS: f64 = 2.0;
const HOST_GBS: f64 = 4.0;

/// Real-execution environment over one benchmark's artifact set.
pub struct PjrtEnv {
    space: Space,
    executors: Vec<Executor>,
    ops: Vec<CounterVec>,
    gpu: GpuSpec,
    spent_s: f64,
    /// wall-clock measurement repetitions per test
    pub reps: usize,
}

impl PjrtEnv {
    /// Build from the manifest entries of one benchmark. Compiles every
    /// variant eagerly (compile time is charged to setup, not to the
    /// search — mirroring KTT's per-test compile being part of the cost
    /// model instead).
    pub fn new(entries: &[ArtifactEntry]) -> Result<PjrtEnv> {
        if entries.is_empty() {
            bail!("no artifact entries");
        }
        let bench = &entries[0].benchmark;
        if entries.iter().any(|e| &e.benchmark != bench) {
            bail!("mixed benchmarks in one PjrtEnv");
        }

        // Space: parameters = sorted config keys; configs = entries.
        let keys: Vec<String> = entries[0].config.keys().cloned().collect();
        let mut values: HashMap<&str, Vec<i64>> = HashMap::new();
        for e in entries {
            for (k, v) in &e.config {
                let vs = values.entry(k.as_str()).or_default();
                if !vs.contains(v) {
                    vs.push(*v);
                }
            }
        }
        let params: Vec<ParamDef> = keys
            .iter()
            .map(|k| {
                let mut vs = values.remove(k.as_str()).unwrap_or_default();
                vs.sort_unstable();
                ParamDef::new(k, &vs)
            })
            .collect();
        let configs: Vec<Config> = entries
            .iter()
            .map(|e| Config(keys.iter().map(|k| e.config[k]).collect()))
            .collect();
        let space = Space::from_configs(bench, params, configs);

        let client = xla::PjRtClient::cpu()?;
        let mut executors = Vec::with_capacity(entries.len());
        let mut ops = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            executors.push(Executor::compile(&client, e, 42 + i as u64)?);
            ops.push(ops_counters(e));
        }
        Ok(PjrtEnv {
            space,
            executors,
            ops,
            gpu: host_spec(),
            spent_s: 0.0,
            reps: 3,
        })
    }

    /// The manifest-derived PC_ops for each configuration — usable as an
    /// oracle TP→PC model on the real path.
    pub fn ops_counters_all(&self) -> Vec<CounterVec> {
        self.ops.clone()
    }
}

/// PC_ops from the manifest's analytic op counts.
fn ops_counters(e: &ArtifactEntry) -> CounterVec {
    let mut c = CounterVec::new();
    for (k, v) in &e.ops {
        let counter = match k.as_str() {
            "INST_F32" => Some(Counter::InstF32),
            "DRAM_RT" => Some(Counter::DramRt),
            "DRAM_WT" => Some(Counter::DramWt),
            "TEX_RWT" => Some(Counter::TexRwt),
            "threads" => Some(Counter::Threads),
            _ => None,
        };
        if let Some(counter) = counter {
            c.set(counter, *v);
        }
    }
    // derived totals
    let f32c = c.get(Counter::InstF32);
    c.set(Counter::InstExe, (f32c / 32.0).max(1.0));
    c.set(Counter::WarpE, 100.0);
    c.set(Counter::WarpNpE, 100.0);
    c
}

/// PC_stress synthesis from a measured runtime (see module docs).
fn add_stress(c: &mut CounterVec, runtime_ms: f64) {
    let secs = (runtime_ms / 1e3).max(1e-9);
    let flops = c.get(Counter::InstF32);
    let bytes = (c.get(Counter::DramRt) + c.get(Counter::DramWt)) * 32.0;
    let tex_bytes = c.get(Counter::TexRwt) * 32.0;
    let inst_u = (flops / secs / (HOST_GFLOPS * 1e9)).min(1.0);
    let dram_u = (bytes / secs / (HOST_GBS * 1e9)).min(1.0);
    let tex_u = (tex_bytes / secs / (HOST_GBS * 1e9)).min(1.0);
    c.set(Counter::InstIssueU, 100.0 * inst_u);
    c.set(Counter::DramU, 10.0 * dram_u);
    c.set(Counter::TexU, 10.0 * tex_u);
    c.set(Counter::L2U, 10.0 * tex_u.max(dram_u) * 0.8);
    c.set(Counter::SmE, 100.0 * inst_u.max(dram_u).max(tex_u));
}

impl EvalEnv for PjrtEnv {
    fn space(&self) -> &Space {
        &self.space
    }

    fn measure(&mut self, idx: usize, profile: bool) -> Measurement {
        let reps = if profile { self.reps * 2 } else { self.reps };
        let runtime_ms = self.executors[idx]
            .time_ms(reps)
            .expect("artifact execution failed");
        self.spent_s += runtime_ms / 1e3 * (reps + 1) as f64;
        let counters = profile.then(|| {
            let mut c = self.ops[idx].clone();
            add_stress(&mut c, runtime_ms);
            c
        });
        Measurement::ok(runtime_ms, counters)
    }

    fn cost_so_far(&self) -> f64 {
        self.spent_s
    }

    fn gpu(&self) -> &GpuSpec {
        &self.gpu
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::load_manifest;
    use std::path::PathBuf;

    fn entries(bench: &str) -> Option<Vec<ArtifactEntry>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let all = load_manifest(&dir).unwrap();
        Some(
            all.into_iter()
                .filter(|e| e.benchmark == bench)
                .collect(),
        )
    }

    #[test]
    fn real_space_measures_and_profiles() {
        let Some(es) = entries("transpose") else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        let mut env = PjrtEnv::new(&es).unwrap();
        env.reps = 1;
        assert_eq!(env.space().len(), es.len());
        let plain = env.measure(0, false);
        assert!(plain.runtime_ms > 0.0);
        assert!(plain.counters.is_none());
        let prof = env.measure(1, true);
        let c = prof.counters.unwrap();
        assert!(c.get(Counter::DramRt) > 0.0);
        assert!(c.get(Counter::SmE) > 0.0);
        assert!(env.cost_so_far() > 0.0);
    }

    #[test]
    fn host_spec_is_prevolta_counterset() {
        assert_eq!(
            host_spec().counter_set(),
            crate::counters::CounterSet::PreVolta
        );
        assert!(host_spec().cores() >= 1);
    }
}
