//! Exhaustively-recorded tuning spaces.
//!
//! The paper's §4.1: *"instead of running kernels many times, it performs
//! an exhaustive exploration of the entire tuning space and saves the
//! tuning results (kernel runtimes and PCs); then we can perform
//! autotuning space search faster, i.e. simply load the kernel runtimes
//! and PCs from files."* `RecordedSpace` is exactly that artifact: one
//! (runtime, counter-vector) record per configuration, serializable to
//! JSON so the searcher-step experiments are replayable.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Space;
use crate::counters::CounterVec;
use crate::util::json::{self, obj, Value};

/// The measurement recorded for one tuning configuration.
#[derive(Debug, Clone)]
pub struct Record {
    pub runtime_ms: f64,
    pub counters: CounterVec,
}

/// A tuning space together with the full measurement of every
/// configuration on one (GPU, input) pair.
#[derive(Debug, Clone)]
pub struct RecordedSpace {
    pub space: Space,
    pub records: Vec<Record>,
    /// GPU the records were measured on (spec name).
    pub gpu: String,
    /// Free-form input descriptor (e.g. "2048x2048").
    pub input: String,
}

impl RecordedSpace {
    pub fn new(space: Space, records: Vec<Record>, gpu: &str, input: &str) -> Self {
        assert_eq!(space.len(), records.len());
        RecordedSpace {
            space,
            records,
            gpu: gpu.to_string(),
            input: input.to_string(),
        }
    }

    /// Best (lowest) runtime over the whole space.
    pub fn best_time(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.runtime_ms)
            .fold(f64::INFINITY, f64::min)
    }

    pub fn best_index(&self) -> usize {
        let mut best = 0;
        for (i, r) in self.records.iter().enumerate() {
            if r.runtime_ms < self.records[best].runtime_ms {
                best = i;
            }
        }
        best
    }

    /// Is configuration `idx` "well-performing" — within `factor`× of the
    /// exhaustive-search best (the paper uses 1.1×, §4.1)?
    pub fn is_well_performing(&self, idx: usize, factor: f64) -> bool {
        self.records[idx].runtime_ms <= self.best_time() * factor
    }

    /// Number of well-performing configurations (difficulty measure).
    pub fn well_performing_count(&self, factor: f64) -> usize {
        let cut = self.best_time() * factor;
        self.records
            .iter()
            .filter(|r| r.runtime_ms <= cut)
            .count()
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("gpu", Value::from(self.gpu.clone())),
            ("input", Value::from(self.input.clone())),
            ("space", self.space.to_json()),
            (
                "records",
                Value::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("runtime_ms", Value::from(r.runtime_ms)),
                                ("counters", r.counters.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RecordedSpace> {
        let space = Space::from_json(v.get("space")?)?;
        let records: Vec<Record> = v
            .get("records")?
            .as_arr()
            .unwrap_or_default()
            .iter()
            .map(|r| {
                Ok(Record {
                    runtime_ms: r
                        .get("runtime_ms")?
                        .as_f64()
                        .context("runtime_ms")?,
                    counters: CounterVec::from_json(r.get("counters")?)?,
                })
            })
            .collect::<Result<_>>()?;
        if records.len() != space.len() {
            bail!(
                "record count {} != space size {}",
                records.len(),
                space.len()
            );
        }
        Ok(RecordedSpace {
            space,
            records,
            gpu: v.get("gpu")?.as_str().unwrap_or_default().to_string(),
            input: v.get("input")?.as_str().unwrap_or_default().to_string(),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty(1))
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<RecordedSpace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        RecordedSpace::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::counters::Counter;
    use crate::tuning::{Config, ParamDef};

    fn toy() -> RecordedSpace {
        let space = Space::enumerate(
            "toy",
            vec![ParamDef::new("a", &[1, 2, 3, 4])],
            |_| true,
        );
        let records = (0..4)
            .map(|i| {
                let mut c = CounterVec::new();
                c.set(Counter::InstF32, 100.0 * (i + 1) as f64);
                Record {
                    runtime_ms: [4.0, 1.0, 1.05, 2.0][i],
                    counters: c,
                }
            })
            .collect();
        RecordedSpace::new(space, records, "sim", "toy-input")
    }

    #[test]
    fn best_and_well_performing() {
        let r = toy();
        assert_eq!(r.best_time(), 1.0);
        assert_eq!(r.best_index(), 1);
        assert!(r.is_well_performing(1, 1.1));
        assert!(r.is_well_performing(2, 1.1));
        assert!(!r.is_well_performing(0, 1.1));
        assert_eq!(r.well_performing_count(1.1), 2);
    }

    #[test]
    fn json_roundtrip() {
        let r = toy();
        let back = RecordedSpace::from_json(&r.to_json()).unwrap();
        assert_eq!(back.records.len(), 4);
        assert_eq!(back.gpu, "sim");
        assert_eq!(back.records[3].runtime_ms, 2.0);
        assert_eq!(
            back.records[2].counters.get(Counter::InstF32),
            300.0
        );
    }

    #[test]
    fn save_load_file() {
        let r = toy();
        let dir = std::env::temp_dir().join("pcat_test_recorded");
        let path = dir.join("toy.json");
        r.save(&path).unwrap();
        let back = RecordedSpace::load(&path).unwrap();
        assert_eq!(back.space.len(), r.space.len());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let space = Space::enumerate(
            "t",
            vec![ParamDef::new("a", &[1, 2])],
            |_| true,
        );
        let _ = RecordedSpace::new(space, vec![], "g", "i");
    }

    #[test]
    fn mismatched_json_rejected() {
        let r = toy();
        let mut v = r.to_json();
        if let Value::Obj(o) = &mut v {
            if let Some(Value::Arr(recs)) = o.get_mut("records") {
                recs.pop();
            }
        }
        assert!(RecordedSpace::from_json(&v).is_err());
    }

    #[test]
    fn config_values_survive_roundtrip() {
        let r = toy();
        let back = RecordedSpace::from_json(&r.to_json()).unwrap();
        assert_eq!(back.space.configs[2], Config(vec![3]));
    }
}
