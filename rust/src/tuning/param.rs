//! Tuning parameters and configurations.

use crate::util::json::Value;

/// One tuning parameter: a named, ordered set of discrete values the
/// autotuner may assign (paper §1: "each tuning parameter can take one of
/// a pre-defined set of discrete values").
#[derive(Debug, Clone, PartialEq)]
pub struct ParamDef {
    pub name: String,
    pub values: Vec<i64>,
}

impl ParamDef {
    pub fn new(name: &str, values: &[i64]) -> Self {
        assert!(!values.is_empty(), "parameter {name} has no values");
        ParamDef {
            name: name.to_string(),
            values: values.to_vec(),
        }
    }

    /// Binary parameters split the regression-model subspaces (§3.4.1).
    pub fn is_binary(&self) -> bool {
        self.values.len() == 2
    }

    pub fn to_json(&self) -> Value {
        crate::util::json::obj(vec![
            ("name", Value::from(self.name.clone())),
            (
                "values",
                Value::Arr(self.values.iter().map(|&v| v.into()).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let name = v
            .get("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("param name must be a string"))?
            .to_string();
        let values = v
            .get("values")?
            .as_arr()
            .ok_or_else(|| {
                anyhow::anyhow!("param {name:?} values must be an array")
            })?
            .iter()
            .map(|x| {
                x.as_i64().ok_or_else(|| {
                    anyhow::anyhow!("param {name:?} has a non-integer value")
                })
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(ParamDef { name, values })
    }
}

/// One tuning configuration: an assignment of a value to every parameter,
/// stored positionally (parallel to `Space::params`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Config(pub Vec<i64>);

/// Lets `HashMap<Config, _>` be probed with a borrowed value slice —
/// the neighbourhood index looks up candidate configurations without
/// allocating a `Config` per probe. Sound because `Vec<i64>` hashes and
/// compares exactly like `[i64]`.
impl std::borrow::Borrow<[i64]> for Config {
    fn borrow(&self) -> &[i64] {
        &self.0
    }
}

impl Config {
    #[inline]
    pub fn get(&self, i: usize) -> i64 {
        self.0[i]
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Hamming distance in parameter space — the neighbourhood metric
    /// used by the local-search baselines.
    pub fn hamming(&self, other: &Config) -> usize {
        self.0
            .iter()
            .zip(&other.0)
            .filter(|(a, b)| a != b)
            .count()
    }

    pub fn to_json(&self) -> Value {
        Value::Arr(self.0.iter().map(|&v| v.into()).collect())
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Config(
            v.as_arr()
                .ok_or_else(|| anyhow::anyhow!("config must be an array"))?
                .iter()
                .map(|x| {
                    x.as_i64().ok_or_else(|| {
                        anyhow::anyhow!("config has a non-integer value")
                    })
                })
                .collect::<anyhow::Result<_>>()?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_detection() {
        assert!(ParamDef::new("b", &[0, 1]).is_binary());
        assert!(!ParamDef::new("t", &[1, 2, 4]).is_binary());
    }

    #[test]
    fn hamming_distance() {
        let a = Config(vec![1, 2, 3]);
        let b = Config(vec![1, 5, 4]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn json_roundtrip() {
        let p = ParamDef::new("x", &[1, 2, 4]);
        let back = ParamDef::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        let c = Config(vec![4, -1, 0]);
        assert_eq!(Config::from_json(&c.to_json()).unwrap(), c);
    }

    #[test]
    #[should_panic]
    fn empty_values_panic() {
        ParamDef::new("bad", &[]);
    }

    #[test]
    fn from_json_rejects_mistyped_values() {
        use crate::util::json::{obj, Value};
        // regression: non-integer values used to be silently dropped
        let bad = obj(vec![
            ("name", Value::from("x")),
            (
                "values",
                Value::Arr(vec![Value::from(1i64), Value::from("two")]),
            ),
        ]);
        assert!(ParamDef::from_json(&bad).is_err());
        let bad_name = obj(vec![
            ("name", Value::from(1i64)),
            ("values", Value::Arr(vec![Value::from(1i64)])),
        ]);
        assert!(ParamDef::from_json(&bad_name).is_err());
        let bad_cfg = Value::Arr(vec![Value::from(1i64), Value::from("x")]);
        assert!(Config::from_json(&bad_cfg).is_err());
    }
}
