//! Tuning-space enumeration: cross product of parameter values pruned by
//! constraints, with index↔configuration mapping and an indexed
//! Hamming-ball neighbourhood generator.
//!
//! Two storage modes back a [`Space`]:
//!
//! - **Dense** — every configuration is materialized in `configs`
//!   (enumeration order). This is the historical mode; all recorded /
//!   serialized spaces are dense, and `configs` stays a public field so
//!   existing callers are untouched.
//! - **Implicit** — the space is a *full* cross product in odometer
//!   order and holds no per-configuration storage at all: `config_at`
//!   decodes any index with stride arithmetic in O(dims). This is the
//!   ≥1M-config mode — a million-configuration space costs a handful of
//!   `ParamDef`s, not hundreds of MB.
//!
//! Enumeration itself is exposed as [`ConfigStream`], a lazy iterator
//! over the constraint-pruned cross product; `Space::enumerate` is now a
//! thin `collect()` over it, so the eager and streaming paths are
//! byte-identical by construction.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};

use super::{Config, ParamDef};
use crate::util::json::Value;

/// Lazy odometer enumeration of a constraint-pruned cross product.
///
/// Yields exactly the configurations `Space::enumerate` materializes, in
/// exactly the same (row-major, last-parameter-fastest) order — the
/// eager path is implemented on top of this iterator, and a property
/// test pins the equivalence. A parameter with an *empty* value list
/// makes the cross product empty: the stream yields nothing instead of
/// panicking (historically `enumerate` indexed `values[0]` and died).
pub struct ConfigStream<'p, F>
where
    F: Fn(&[i64]) -> bool,
{
    params: &'p [ParamDef],
    constraint: F,
    idx: Vec<usize>,
    cur: Vec<i64>,
    /// Current tuple exists but has not been constraint-tested yet.
    pending: bool,
    done: bool,
}

impl<'p, F> ConfigStream<'p, F>
where
    F: Fn(&[i64]) -> bool,
{
    pub fn new(params: &'p [ParamDef], constraint: F) -> Self {
        let empty_axis = params.iter().any(|p| p.values.is_empty());
        ConfigStream {
            idx: vec![0; params.len()],
            cur: params
                .iter()
                .map(|p| p.values.first().copied().unwrap_or(0))
                .collect(),
            params,
            constraint,
            pending: !empty_axis,
            done: empty_axis,
        }
    }

    /// Odometer increment; `false` once every tuple has been visited.
    fn advance(&mut self) -> bool {
        for d in (0..self.params.len()).rev() {
            self.idx[d] += 1;
            if self.idx[d] < self.params[d].values.len() {
                self.cur[d] = self.params[d].values[self.idx[d]];
                return true;
            }
            self.idx[d] = 0;
            self.cur[d] = self.params[d].values[0];
        }
        false
    }

    /// Append up to `max` configurations to `out`, returning how many
    /// were produced (0 ⇔ exhausted). The chunked form of the stream:
    /// callers that want cache-friendly batches without a full
    /// materialization drain the space `max` configs at a time through
    /// one reused buffer.
    pub fn next_chunk(&mut self, max: usize, out: &mut Vec<Config>) -> usize {
        let before = out.len();
        for cfg in self.by_ref().take(max) {
            out.push(cfg);
        }
        out.len() - before
    }
}

impl<'p, F> Iterator for ConfigStream<'p, F>
where
    F: Fn(&[i64]) -> bool,
{
    type Item = Config;

    fn next(&mut self) -> Option<Config> {
        while !self.done {
            if self.pending {
                self.pending = false;
            } else if !self.advance() {
                self.done = true;
                break;
            }
            if (self.constraint)(&self.cur) {
                return Some(Config(self.cur.clone()));
            }
        }
        None
    }
}

/// Implicit full-cross-product geometry: total length plus odometer
/// strides, enough to decode any index in O(dims) without storing a
/// single configuration.
#[derive(Debug, Clone)]
struct ImplicitGrid {
    len: usize,
    strides: Vec<usize>,
}

impl ImplicitGrid {
    fn of(params: &[ParamDef]) -> Option<ImplicitGrid> {
        let mut strides = vec![0usize; params.len()];
        let mut len = 1usize;
        for d in (0..params.len()).rev() {
            strides[d] = len;
            len = len.checked_mul(params[d].values.len())?;
        }
        Some(ImplicitGrid { len, strides })
    }

    fn decode_into(&self, params: &[ParamDef], i: usize, out: &mut Vec<i64>) {
        out.clear();
        for d in 0..params.len() {
            let card = params[d].values.len();
            out.push(params[d].values[i / self.strides[d] % card]);
        }
    }
}

/// An enumerated (constraint-pruned) tuning space.
#[derive(Debug, Clone)]
pub struct Space {
    pub name: String,
    pub params: Vec<ParamDef>,
    /// Dense storage: every configuration in enumeration order. Empty
    /// for implicit spaces — use [`Space::config_at`] / [`Space::len`]
    /// instead of touching this field when the space may be implicit.
    pub configs: Vec<Config>,
    /// `Some` ⇔ the space is an implicit full cross product.
    implicit: Option<ImplicitGrid>,
    by_name: HashMap<String, usize>,
    /// Lazily built neighbourhood index, shared across clones (the
    /// profile searcher clones the space per run for its local variant).
    nb_index: OnceLock<Arc<NeighbourIndex>>,
}

impl Space {
    /// Enumerate the cross product of `params`, keeping configurations
    /// accepted by `constraint`. Enumeration order is row-major with the
    /// *last* parameter fastest (odometer order), which makes the index
    /// of a configuration deterministic. A parameter with no values
    /// yields an empty space (the cross product with an empty axis is
    /// empty) rather than panicking.
    pub fn enumerate<F>(name: &str, params: Vec<ParamDef>, constraint: F) -> Space
    where
        F: Fn(&[i64]) -> bool,
    {
        let configs = ConfigStream::new(&params, constraint).collect();
        Space::from_configs(name, params, configs)
    }

    /// The lazy counterpart of [`enumerate`](Space::enumerate) for
    /// callers that stream instead of materializing.
    pub fn stream<F>(params: &[ParamDef], constraint: F) -> ConfigStream<'_, F>
    where
        F: Fn(&[i64]) -> bool,
    {
        ConfigStream::new(params, constraint)
    }

    /// An implicit full-cross-product space: no constraint, no stored
    /// configurations — `config_at` decodes indices on demand. This is
    /// how ≥1M-config spaces stay a few hundred bytes. Falls back to
    /// (dense) `enumerate` if the product overflows `usize` (can't
    /// happen for realistic spaces) so `len()` is always exact.
    pub fn enumerate_implicit(name: &str, params: Vec<ParamDef>) -> Space {
        if params.iter().any(|p| p.values.is_empty()) {
            return Space::from_configs(name, params, Vec::new());
        }
        match ImplicitGrid::of(&params) {
            Some(grid) => {
                let by_name = params
                    .iter()
                    .enumerate()
                    .map(|(i, p)| (p.name.clone(), i))
                    .collect();
                Space {
                    name: name.to_string(),
                    params,
                    configs: Vec::new(),
                    implicit: Some(grid),
                    by_name,
                    nb_index: OnceLock::new(),
                }
            }
            None => Space::enumerate(name, params, |_| true),
        }
    }

    pub fn from_configs(
        name: &str,
        params: Vec<ParamDef>,
        configs: Vec<Config>,
    ) -> Space {
        let by_name = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), i))
            .collect();
        Space {
            name: name.to_string(),
            params,
            configs,
            implicit: None,
            by_name,
            nb_index: OnceLock::new(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.implicit {
            Some(grid) => grid.len,
            None => self.configs.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the space stores configurations implicitly (odometer
    /// decode) rather than densely.
    pub fn is_implicit(&self) -> bool {
        self.implicit.is_some()
    }

    /// The configuration at enumeration index `i`, regardless of storage
    /// mode. Dense spaces clone the stored configuration; implicit
    /// spaces decode it with stride arithmetic. Storage-agnostic callers
    /// (searchers, the coordinator, on-demand recording) go through
    /// this; eager-only code may keep indexing `configs` directly.
    pub fn config_at(&self, i: usize) -> Config {
        match &self.implicit {
            Some(grid) => {
                let mut v = Vec::with_capacity(self.params.len());
                grid.decode_into(&self.params, i, &mut v);
                Config(v)
            }
            None => self.configs[i].clone(),
        }
    }

    /// Number of tuning parameters ("dimensions" in the paper's Table 2).
    pub fn dims(&self) -> usize {
        self.params.len()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    /// Value of named parameter within a configuration.
    pub fn value(&self, cfg: &Config, name: &str) -> i64 {
        cfg.get(self.param_index(name).unwrap_or_else(|| {
            panic!("unknown tuning parameter {name:?} in space {}", self.name)
        }))
    }

    /// Indices of configurations at Hamming distance ≤ `radius` from
    /// `from` (excluding `from` itself) — the neighbourhood for the
    /// local-search baselines and the profile searcher's §3.9.1 variant.
    ///
    /// Served by a lazily built per-dimension index that generates the
    /// radius-`r` ball combinatorially (odometer arithmetic on full
    /// cross products, hash lookups on constraint-pruned spaces) instead
    /// of Hamming-scanning all N configurations per call. Returns
    /// exactly the same ascending index list as [`neighbours_scan`].
    ///
    /// [`neighbours_scan`]: Space::neighbours_scan
    pub fn neighbours(&self, from: &Config, radius: usize) -> Vec<usize> {
        self.neighbour_index().neighbours(self, from, radius)
    }

    /// Reference implementation of [`neighbours`](Space::neighbours):
    /// linear Hamming scan over the whole space, O(N·dims) per call.
    /// Kept as the fallback for degenerate spaces and as the ground
    /// truth the property tests compare the index against.
    pub fn neighbours_scan(&self, from: &Config, radius: usize) -> Vec<usize> {
        match &self.implicit {
            None => self
                .configs
                .iter()
                .enumerate()
                .filter(|(_, c)| {
                    let d = c.hamming(from);
                    d > 0 && d <= radius
                })
                .map(|(i, _)| i)
                .collect(),
            Some(grid) => {
                let mut out = Vec::new();
                let mut scratch = Vec::with_capacity(self.params.len());
                for i in 0..grid.len {
                    grid.decode_into(&self.params, i, &mut scratch);
                    let d = scratch
                        .iter()
                        .zip(&from.0)
                        .filter(|(a, b)| a != b)
                        .count();
                    if d > 0 && d <= radius {
                        out.push(i);
                    }
                }
                out
            }
        }
    }

    /// The index of a configuration, or `None` when the candidate is
    /// not in the space (pruned by a constraint, wrong dimensionality,
    /// or a value outside a parameter's domain).
    ///
    /// This is the inverse of [`config_at`](Space::config_at) — the
    /// population searchers (GA/DE) synthesize candidate configurations
    /// by recombining parents' parameter values and need them mapped
    /// back onto space indices. Served by the same lazily built
    /// [`NeighbourIndex`] as [`neighbours`](Space::neighbours):
    /// odometer arithmetic on full cross products, hash lookups on
    /// pruned spaces, a linear scan on degenerate ones.
    pub fn index_of(&self, cfg: &Config) -> Option<usize> {
        self.neighbour_index().index_of(self, cfg)
    }

    /// The space's neighbourhood index, built on first use and shared
    /// across clones.
    pub fn neighbour_index(&self) -> &NeighbourIndex {
        &**self
            .nb_index
            .get_or_init(|| Arc::new(NeighbourIndex::build(self)))
    }

    pub fn to_json(&self) -> Value {
        crate::util::json::obj(vec![
            ("name", Value::from(self.name.clone())),
            (
                "params",
                Value::Arr(self.params.iter().map(|p| p.to_json()).collect()),
            ),
            (
                "configs",
                Value::Arr(
                    (0..self.len()).map(|i| self.config_at(i).to_json()).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Space> {
        use anyhow::Context;
        let name = v
            .get("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("space name must be a string"))?
            .to_string();
        let params: Vec<ParamDef> = v
            .get("params")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("space params must be an array"))?
            .iter()
            .enumerate()
            .map(|(i, p)| {
                ParamDef::from_json(p)
                    .with_context(|| format!("space param {i}"))
            })
            .collect::<anyhow::Result<_>>()?;
        let configs: Vec<Config> = v
            .get("configs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("space configs must be an array"))?
            .iter()
            .enumerate()
            .map(|(i, c)| {
                Config::from_json(c).with_context(|| format!("space config {i}"))
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(Space::from_configs(&name, params, configs))
    }
}

/// How the neighbourhood index maps a generated candidate configuration
/// back to its space index.
#[derive(Debug)]
enum Lookup {
    /// The space is the *full* cross product in odometer order: the
    /// index is pure stride arithmetic over per-dimension value
    /// positions — no hashing, no per-candidate allocation.
    Odometer { strides: Vec<usize> },
    /// Constraint-pruned (or re-ordered) space: configuration → index.
    /// Probed with borrowed `[i64]` slices, so candidate generation
    /// never allocates.
    Hash(HashMap<Config, usize>),
    /// Degenerate space (duplicate parameter values or duplicate
    /// configurations): index lookups would be ambiguous, so every call
    /// falls back to the linear Hamming scan.
    Scan,
}

/// Precomputed per-dimension index behind [`Space::neighbours`] (§Perf).
///
/// A Hamming ball of radius `r` around `from` is, by definition, every
/// way of substituting 1..=r coordinates with alternative parameter
/// values. The pre-index implementation *scanned all N configurations*
/// computing a full Hamming distance each — O(N·dims) per call, paid
/// every local-search step and every §3.9.1 profiling round. This index
/// generates the ball combinatorially instead: O(ball·dims), where the
/// ball is typically orders of magnitude smaller than the space. When a
/// pruned space makes the combinatorial ball *larger* than the space
/// (tiny spaces, huge radii), the call transparently degrades to the
/// scan, so it is never asymptotically worse.
#[derive(Debug)]
pub struct NeighbourIndex {
    /// Per dimension: value → position in `ParamDef::values`.
    value_pos: Vec<HashMap<i64, usize>>,
    lookup: Lookup,
}

impl NeighbourIndex {
    fn build(space: &Space) -> NeighbourIndex {
        let dims = space.dims();
        let mut value_pos = Vec::with_capacity(dims);
        let mut dup_value = false;
        for p in &space.params {
            let mut m = HashMap::with_capacity(p.values.len());
            for (i, &v) in p.values.iter().enumerate() {
                if m.insert(v, i).is_some() {
                    dup_value = true;
                }
            }
            value_pos.push(m);
        }
        if dup_value {
            // two positions share one value: "the" index of a candidate
            // is ambiguous, and the scan (which sees both copies) is the
            // only faithful answer
            return NeighbourIndex {
                value_pos,
                lookup: Lookup::Scan,
            };
        }

        // Implicit spaces are odometer-ordered full cross products by
        // construction — no materialized configurations to verify.
        if let Some(grid) = &space.implicit {
            return NeighbourIndex {
                value_pos,
                lookup: Lookup::Odometer {
                    strides: grid.strides.clone(),
                },
            };
        }

        // Full cross product in odometer order ⇒ stride arithmetic.
        let full = space
            .params
            .iter()
            .try_fold(1usize, |a, p| a.checked_mul(p.values.len()));
        if full == Some(space.len()) && !space.is_empty() {
            let mut strides = vec![0usize; dims];
            let mut s = 1usize;
            for d in (0..dims).rev() {
                strides[d] = s;
                s = s.saturating_mul(space.params[d].values.len());
            }
            let odometer_order =
                space.configs.iter().enumerate().all(|(i, c)| {
                    (0..dims).all(|d| {
                        let card = space.params[d].values.len();
                        let pos = i / strides[d] % card;
                        space.params[d].values[pos] == c.0[d]
                    })
                });
            if odometer_order {
                return NeighbourIndex {
                    value_pos,
                    lookup: Lookup::Odometer { strides },
                };
            }
        }

        // Constraint-pruned: hash every configuration once.
        let mut map: HashMap<Config, usize> =
            HashMap::with_capacity(space.len());
        let mut dup_config = false;
        for (i, c) in space.configs.iter().enumerate() {
            if map.insert(c.clone(), i).is_some() {
                dup_config = true;
            }
        }
        let lookup = if dup_config {
            Lookup::Scan
        } else {
            Lookup::Hash(map)
        };
        NeighbourIndex { value_pos, lookup }
    }

    /// The Hamming ball of `from`, ascending — exactly the set (and
    /// order) [`Space::neighbours_scan`] returns.
    pub fn neighbours(
        &self,
        space: &Space,
        from: &Config,
        radius: usize,
    ) -> Vec<usize> {
        let dims = space.dims();
        if matches!(self.lookup, Lookup::Scan) {
            return space.neighbours_scan(from, radius);
        }
        if radius == 0 || dims == 0 {
            return Vec::new();
        }
        // Degenerate `from` configurations (wrong length, values outside
        // the space's domain) have no well-defined per-dimension
        // alternatives — defer to the scan so both paths always agree.
        if from.len() != dims {
            return space.neighbours_scan(from, radius);
        }
        for d in 0..dims {
            if !self.value_pos[d].contains_key(&from.0[d]) {
                return space.neighbours_scan(from, radius);
            }
        }
        if self.ball_candidates(space, radius) > space.len() as u128 {
            // pruning made the combinatorial ball the bigger job
            return space.neighbours_scan(from, radius);
        }

        let mut out = Vec::new();
        let mut cur: Vec<i64> = from.0.clone();
        self.gen(space, from, radius, 0, false, &mut cur, &mut out);
        out.sort_unstable();
        out
    }

    /// Number of candidate substitutions a radius-`r` ball enumerates:
    /// Σ_{j=1..r} e_j(card_1 − 1, …, card_dims − 1), via the elementary
    /// symmetric polynomial DP (saturating — only compared against N).
    fn ball_candidates(&self, space: &Space, radius: usize) -> u128 {
        let rmax = radius.min(space.dims());
        let mut coeff = vec![0u128; rmax + 1];
        coeff[0] = 1;
        for p in &space.params {
            let a = (p.values.len() - 1) as u128;
            if a == 0 {
                continue;
            }
            for j in (1..=rmax).rev() {
                coeff[j] =
                    coeff[j].saturating_add(coeff[j - 1].saturating_mul(a));
            }
        }
        coeff[1..]
            .iter()
            .fold(0u128, |s, &c| s.saturating_add(c))
    }

    /// DFS over dimensions: at each dimension either keep `from`'s value
    /// or substitute one alternative (consuming one unit of radius).
    /// `cur[d..]` always equals `from` on entry, so hitting the radius
    /// budget completes the candidate immediately.
    #[allow(clippy::too_many_arguments)]
    fn gen(
        &self,
        space: &Space,
        from: &Config,
        remaining: usize,
        d: usize,
        changed: bool,
        cur: &mut Vec<i64>,
        out: &mut Vec<usize>,
    ) {
        if remaining == 0 || d == space.dims() {
            if changed {
                if let Some(i) = self.lookup_index(cur) {
                    out.push(i);
                }
            }
            return;
        }
        // keep this dimension
        self.gen(space, from, remaining, d + 1, changed, cur, out);
        // substitute each alternative value
        for &v in &space.params[d].values {
            if v == from.0[d] {
                continue;
            }
            cur[d] = v;
            self.gen(space, from, remaining - 1, d + 1, true, cur, out);
        }
        cur[d] = from.0[d];
    }

    fn lookup_index(&self, cur: &[i64]) -> Option<usize> {
        match &self.lookup {
            Lookup::Odometer { strides } => {
                let mut idx = 0usize;
                for (d, v) in cur.iter().enumerate() {
                    idx += self.value_pos[d][v] * strides[d];
                }
                Some(idx)
            }
            Lookup::Hash(map) => map.get(cur).copied(),
            Lookup::Scan => unreachable!("scan spaces never generate"),
        }
    }

    /// Checked configuration → index lookup behind
    /// [`Space::index_of`]. Unlike the ball generator's internal
    /// `lookup_index` (whose candidates are in-domain by construction),
    /// arbitrary synthesized configurations may use values no parameter
    /// defines, so every coordinate is validated before the odometer
    /// arithmetic runs.
    pub fn index_of(&self, space: &Space, cfg: &Config) -> Option<usize> {
        if cfg.0.len() != space.dims() {
            return None;
        }
        match &self.lookup {
            Lookup::Scan => {
                // ambiguous spaces: first match, same answer every call
                (0..space.len()).find(|&i| space.config_at(i).0 == cfg.0)
            }
            _ => {
                for d in 0..space.dims() {
                    if !self.value_pos[d].contains_key(&cfg.0[d]) {
                        return None;
                    }
                }
                self.lookup_index(&cfg.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Space {
        Space::enumerate(
            "toy",
            vec![
                ParamDef::new("a", &[1, 2, 3]),
                ParamDef::new("b", &[0, 1]),
            ],
            |_| true,
        )
    }

    #[test]
    fn full_cross_product_count() {
        assert_eq!(toy().len(), 6);
        assert_eq!(toy().dims(), 2);
    }

    #[test]
    fn enumeration_order_is_odometer() {
        let s = toy();
        assert_eq!(s.configs[0], Config(vec![1, 0]));
        assert_eq!(s.configs[1], Config(vec![1, 1]));
        assert_eq!(s.configs[5], Config(vec![3, 1]));
    }

    #[test]
    fn constraint_prunes() {
        let s = Space::enumerate(
            "c",
            vec![
                ParamDef::new("a", &[1, 2, 3, 4]),
                ParamDef::new("b", &[1, 2, 3, 4]),
            ],
            |v| v[0] * v[1] <= 4,
        );
        // (1,1)(1,2)(1,3)(1,4)(2,1)(2,2)(3,1)(4,1)
        assert_eq!(s.len(), 8);
        for c in &s.configs {
            assert!(c.get(0) * c.get(1) <= 4);
        }
    }

    #[test]
    fn index_of_inverts_config_at() {
        // full cross product (odometer), pruned (hash), implicit grid
        let pruned = Space::enumerate(
            "p",
            vec![
                ParamDef::new("a", &[1, 2, 3, 4]),
                ParamDef::new("b", &[1, 2, 3, 4]),
            ],
            |v| v[0] * v[1] <= 4,
        );
        let implicit = Space::enumerate_implicit(
            "i",
            vec![ParamDef::new("a", &[1, 2, 3]), ParamDef::new("b", &[0, 1])],
        );
        for s in [&toy(), &pruned, &implicit] {
            for i in 0..s.len() {
                assert_eq!(s.index_of(&s.config_at(i)), Some(i));
            }
        }
        // pruned-out, out-of-domain, and wrong-arity candidates
        assert_eq!(pruned.index_of(&Config(vec![4, 4])), None);
        assert_eq!(pruned.index_of(&Config(vec![1, 99])), None);
        assert_eq!(pruned.index_of(&Config(vec![1])), None);
        // degenerate (duplicate values → scan lookup): first match wins
        let dup = Space::enumerate(
            "dup",
            vec![ParamDef::new("a", &[1, 1, 2])],
            |_| true,
        );
        assert_eq!(dup.index_of(&Config(vec![1])), Some(0));
        assert_eq!(dup.index_of(&Config(vec![2])), Some(2));
    }

    #[test]
    fn streaming_enumeration_matches_eager_byte_for_byte() {
        let params = vec![
            ParamDef::new("a", &[1, 2, 3, 4]),
            ParamDef::new("b", &[1, 2, 3, 4]),
            ParamDef::new("c", &[0, 1]),
        ];
        let constraint = |v: &[i64]| v[0] * v[1] <= 6;
        let eager =
            Space::enumerate("s", params.clone(), constraint);
        let streamed: Vec<Config> =
            Space::stream(&params, constraint).collect();
        assert_eq!(eager.configs, streamed);
    }

    #[test]
    fn chunked_streaming_matches_eager() {
        let params = vec![
            ParamDef::new("a", &[1, 2, 3, 4, 5]),
            ParamDef::new("b", &[1, 2, 3]),
        ];
        let constraint = |v: &[i64]| (v[0] + v[1]) % 2 == 0;
        let eager = Space::enumerate("s", params.clone(), constraint);
        let mut stream = Space::stream(&params, constraint);
        let mut chunked: Vec<Config> = Vec::new();
        while stream.next_chunk(3, &mut chunked) > 0 {}
        assert_eq!(eager.configs, chunked);
    }

    #[test]
    fn empty_value_list_yields_empty_space_not_panic() {
        // regression: `enumerate` used to index `values[0]` and die
        let params = vec![
            ParamDef::new("a", &[1, 2]),
            ParamDef {
                name: "empty".to_string(),
                values: Vec::new(),
            },
        ];
        let s = Space::enumerate("degenerate", params.clone(), |_| true);
        assert!(s.is_empty());
        assert_eq!(Space::stream(&params, |_| true).count(), 0);
        let implicit = Space::enumerate_implicit("degenerate-imp", params);
        assert!(implicit.is_empty());
    }

    #[test]
    fn zero_dim_space_has_one_empty_config() {
        let s = Space::enumerate("nil", Vec::new(), |_| true);
        assert_eq!(s.len(), 1);
        assert!(s.configs[0].is_empty());
    }

    #[test]
    fn implicit_space_matches_dense_enumeration() {
        let params = vec![
            ParamDef::new("a", &[1, 2, 3]),
            ParamDef::new("b", &[0, 1]),
            ParamDef::new("c", &[7, 8, 9, 10]),
        ];
        let dense = Space::enumerate("d", params.clone(), |_| true);
        let lazy = Space::enumerate_implicit("d", params);
        assert!(lazy.is_implicit());
        assert!(lazy.configs.is_empty(), "implicit spaces store nothing");
        assert_eq!(lazy.len(), dense.len());
        for i in 0..dense.len() {
            assert_eq!(lazy.config_at(i), dense.configs[i], "index {i}");
            assert_eq!(dense.config_at(i), dense.configs[i]);
        }
    }

    #[test]
    fn implicit_neighbours_match_dense() {
        let params = vec![
            ParamDef::new("a", &[1, 2, 3]),
            ParamDef::new("b", &[0, 1]),
            ParamDef::new("c", &[7, 8, 9]),
        ];
        let dense = Space::enumerate("d", params.clone(), |_| true);
        let lazy = Space::enumerate_implicit("d", params);
        for radius in 1..=2 {
            for i in (0..dense.len()).step_by(5) {
                let from = dense.configs[i].clone();
                assert_eq!(
                    lazy.neighbours(&from, radius),
                    dense.neighbours(&from, radius),
                    "radius {radius}, index {i}"
                );
                assert_eq!(
                    lazy.neighbours_scan(&from, radius),
                    dense.neighbours_scan(&from, radius),
                );
            }
        }
    }

    #[test]
    fn value_by_name() {
        let s = toy();
        assert_eq!(s.value(&s.configs[4], "a"), 3);
        assert_eq!(s.value(&s.configs[4], "b"), 0);
        assert_eq!(s.param_index("nope"), None);
    }

    #[test]
    fn neighbours_radius_one() {
        let s = toy();
        let n = s.neighbours(&s.configs[0], 1);
        // (1,0): neighbours at d=1 are (1,1), (2,0), (3,0)
        assert_eq!(n.len(), 3);
    }

    #[test]
    fn indexed_neighbours_match_scan_on_full_space() {
        let s = toy();
        for radius in 0..=3 {
            for from in &s.configs {
                assert_eq!(
                    s.neighbours(from, radius),
                    s.neighbours_scan(from, radius),
                    "radius {radius}, from {from:?}"
                );
            }
        }
    }

    #[test]
    fn indexed_neighbours_match_scan_on_pruned_space() {
        let s = Space::enumerate(
            "pruned",
            vec![
                ParamDef::new("a", &[1, 2, 3, 4]),
                ParamDef::new("b", &[1, 2, 3, 4]),
                ParamDef::new("c", &[0, 1]),
            ],
            |v| v[0] * v[1] <= 6,
        );
        assert!(s.len() < 32, "constraint must actually prune");
        for radius in 1..=3 {
            for from in s.configs.iter().step_by(3) {
                assert_eq!(
                    s.neighbours(from, radius),
                    s.neighbours_scan(from, radius),
                    "radius {radius}, from {from:?}"
                );
            }
        }
    }

    #[test]
    fn neighbours_of_foreign_config_fall_back_to_scan() {
        let s = toy();
        // a configuration whose values are outside the space's domain
        let foreign = Config(vec![99, 0]);
        assert_eq!(
            s.neighbours(&foreign, 1),
            s.neighbours_scan(&foreign, 1)
        );
    }

    #[test]
    fn clones_share_the_built_index() {
        let s = toy();
        let _ = s.neighbours(&s.configs[0], 1); // force the build
        let c = s.clone();
        assert!(std::ptr::eq(s.neighbour_index(), c.neighbour_index()));
    }

    #[test]
    fn json_roundtrip_preserves_neighbourhoods() {
        let s = toy();
        let back = Space::from_json(&s.to_json()).unwrap();
        assert_eq!(
            back.neighbours(&back.configs[2], 2),
            s.neighbours(&s.configs[2], 2)
        );
    }

    #[test]
    fn json_roundtrip() {
        let s = toy();
        let back = Space::from_json(&s.to_json()).unwrap();
        assert_eq!(back.len(), s.len());
        assert_eq!(back.params, s.params);
        assert_eq!(back.configs, s.configs);
    }

    #[test]
    fn from_json_rejects_mistyped_fields() {
        use crate::util::json::{obj, Value};
        // regression: mistyped name/params/configs used to
        // `unwrap_or_default()` into an empty space (silent data loss)
        let bad_name = obj(vec![
            ("name", Value::from(3.0)),
            ("params", Value::Arr(Vec::new())),
            ("configs", Value::Arr(Vec::new())),
        ]);
        assert!(Space::from_json(&bad_name).is_err());
        let bad_params = obj(vec![
            ("name", Value::from("s".to_string())),
            ("params", Value::from("not-an-array".to_string())),
            ("configs", Value::Arr(Vec::new())),
        ]);
        assert!(Space::from_json(&bad_params).is_err());
        let bad_configs = obj(vec![
            ("name", Value::from("s".to_string())),
            ("params", Value::Arr(Vec::new())),
            ("configs", Value::from(1.0)),
        ]);
        assert!(Space::from_json(&bad_configs).is_err());
    }
}
