//! Tuning-space enumeration: cross product of parameter values pruned by
//! constraints, with index↔configuration mapping.

use std::collections::HashMap;

use super::{Config, ParamDef};
use crate::util::json::Value;

/// An enumerated (constraint-pruned) tuning space.
#[derive(Debug, Clone)]
pub struct Space {
    pub name: String,
    pub params: Vec<ParamDef>,
    pub configs: Vec<Config>,
    by_name: HashMap<String, usize>,
}

impl Space {
    /// Enumerate the cross product of `params`, keeping configurations
    /// accepted by `constraint`. Enumeration order is row-major with the
    /// *last* parameter fastest (odometer order), which makes the index
    /// of a configuration deterministic.
    pub fn enumerate<F>(name: &str, params: Vec<ParamDef>, constraint: F) -> Space
    where
        F: Fn(&[i64]) -> bool,
    {
        let mut configs = Vec::new();
        let mut idx = vec![0usize; params.len()];
        let mut cur: Vec<i64> = params.iter().map(|p| p.values[0]).collect();
        'outer: loop {
            if constraint(&cur) {
                configs.push(Config(cur.clone()));
            }
            // odometer increment
            for d in (0..params.len()).rev() {
                idx[d] += 1;
                if idx[d] < params[d].values.len() {
                    cur[d] = params[d].values[idx[d]];
                    continue 'outer;
                }
                idx[d] = 0;
                cur[d] = params[d].values[0];
            }
            break;
        }
        Space::from_configs(name, params, configs)
    }

    pub fn from_configs(
        name: &str,
        params: Vec<ParamDef>,
        configs: Vec<Config>,
    ) -> Space {
        let by_name = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), i))
            .collect();
        Space {
            name: name.to_string(),
            params,
            configs,
            by_name,
        }
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// Number of tuning parameters ("dimensions" in the paper's Table 2).
    pub fn dims(&self) -> usize {
        self.params.len()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    /// Value of named parameter within a configuration.
    pub fn value(&self, cfg: &Config, name: &str) -> i64 {
        cfg.get(self.param_index(name).unwrap_or_else(|| {
            panic!("unknown tuning parameter {name:?} in space {}", self.name)
        }))
    }

    /// Indices of configurations at Hamming distance ≤ `radius` from
    /// `from` (excluding `from` itself) — the neighbourhood for local
    /// search baselines.
    pub fn neighbours(&self, from: &Config, radius: usize) -> Vec<usize> {
        self.configs
            .iter()
            .enumerate()
            .filter(|(_, c)| {
                let d = c.hamming(from);
                d > 0 && d <= radius
            })
            .map(|(i, _)| i)
            .collect()
    }

    pub fn to_json(&self) -> Value {
        crate::util::json::obj(vec![
            ("name", Value::from(self.name.clone())),
            (
                "params",
                Value::Arr(self.params.iter().map(|p| p.to_json()).collect()),
            ),
            (
                "configs",
                Value::Arr(self.configs.iter().map(|c| c.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Space> {
        let name = v.get("name")?.as_str().unwrap_or_default().to_string();
        let params: Vec<ParamDef> = v
            .get("params")?
            .as_arr()
            .unwrap_or_default()
            .iter()
            .map(ParamDef::from_json)
            .collect::<anyhow::Result<_>>()?;
        let configs: Vec<Config> = v
            .get("configs")?
            .as_arr()
            .unwrap_or_default()
            .iter()
            .map(Config::from_json)
            .collect::<anyhow::Result<_>>()?;
        Ok(Space::from_configs(&name, params, configs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Space {
        Space::enumerate(
            "toy",
            vec![
                ParamDef::new("a", &[1, 2, 3]),
                ParamDef::new("b", &[0, 1]),
            ],
            |_| true,
        )
    }

    #[test]
    fn full_cross_product_count() {
        assert_eq!(toy().len(), 6);
        assert_eq!(toy().dims(), 2);
    }

    #[test]
    fn enumeration_order_is_odometer() {
        let s = toy();
        assert_eq!(s.configs[0], Config(vec![1, 0]));
        assert_eq!(s.configs[1], Config(vec![1, 1]));
        assert_eq!(s.configs[5], Config(vec![3, 1]));
    }

    #[test]
    fn constraint_prunes() {
        let s = Space::enumerate(
            "c",
            vec![
                ParamDef::new("a", &[1, 2, 3, 4]),
                ParamDef::new("b", &[1, 2, 3, 4]),
            ],
            |v| v[0] * v[1] <= 4,
        );
        // (1,1)(1,2)(1,3)(1,4)(2,1)(2,2)(3,1)(4,1)
        assert_eq!(s.len(), 8);
        for c in &s.configs {
            assert!(c.get(0) * c.get(1) <= 4);
        }
    }

    #[test]
    fn value_by_name() {
        let s = toy();
        assert_eq!(s.value(&s.configs[4], "a"), 3);
        assert_eq!(s.value(&s.configs[4], "b"), 0);
        assert_eq!(s.param_index("nope"), None);
    }

    #[test]
    fn neighbours_radius_one() {
        let s = toy();
        let n = s.neighbours(&s.configs[0], 1);
        // (1,0): neighbours at d=1 are (1,1), (2,0), (3,0)
        assert_eq!(n.len(), 3);
    }

    #[test]
    fn json_roundtrip() {
        let s = toy();
        let back = Space::from_json(&s.to_json()).unwrap();
        assert_eq!(back.len(), s.len());
        assert_eq!(back.params, s.params);
        assert_eq!(back.configs, s.configs);
    }
}
