//! Tuning spaces: parameters, configurations, constraints, enumeration
//! and exhaustively-recorded spaces (the paper's §4.1 replay methodology).

mod param;
mod recorded;
mod space;

pub use param::{Config, ParamDef};
pub use recorded::{Record, RecordedSpace};
pub use space::{NeighbourIndex, Space};
