//! Performance-counter taxonomy — the paper's Table 1.
//!
//! Two fundamentally different counter categories drive the method:
//!
//! * **`PC_ops`** — amounts of operations performed on a subsystem
//!   (transaction counts, instruction counts). Their relation to the
//!   tuning parameters is *stable* across GPUs and inputs (paper §3.1,
//!   Eqs. 3–5), so a model of TP→PC_ops trained once is portable.
//! * **`PC_stress`** — relative utilization of a subsystem. Strongly
//!   GPU- and input-dependent; measured live during tuning and fed to
//!   the bottleneck expert system.
//!
//! Counter *names* changed completely with Volta; [`Counter::cuda_name`]
//! returns the pre-Volta (CUPTI event) or Volta+ (Nsight metric) string,
//! with the paper's documented conversion ratios captured in
//! [`Counter::new_counter_scale`].

use std::fmt;

/// One hardware performance counter (plus the paper's `threads`
/// pseudo-counter, which KTT appends to the counter set for the
/// parallelism reaction — §3.5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(usize)]
pub enum Counter {
    // --- PC_ops: memory transaction counts ---
    DramRt,
    DramWt,
    L2Rt,
    L2Wt,
    TexRwt,
    LocO,
    ShrLt,
    ShrWt,
    // --- PC_ops: instruction counts ---
    InstF32,
    InstF64,
    InstInt,
    InstMisc,
    InstLdst,
    InstCont,
    InstBconv,
    InstExe,
    InstIssueU,
    // --- PC_stress: utilizations ---
    DramU,
    L2U,
    TexU,
    ShrU,
    SmE,
    WarpE,
    WarpNpE,
    // --- pseudo-counter (KTT-reported) ---
    Threads,
}

/// Counter category per the paper's Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CounterKind {
    Ops,
    Stress,
}

pub const NUM_COUNTERS: usize = 25;

/// All counters in Table 1 order.
pub const ALL_COUNTERS: [Counter; NUM_COUNTERS] = [
    Counter::DramRt,
    Counter::DramWt,
    Counter::L2Rt,
    Counter::L2Wt,
    Counter::TexRwt,
    Counter::LocO,
    Counter::ShrLt,
    Counter::ShrWt,
    Counter::InstF32,
    Counter::InstF64,
    Counter::InstInt,
    Counter::InstMisc,
    Counter::InstLdst,
    Counter::InstCont,
    Counter::InstBconv,
    Counter::InstExe,
    Counter::InstIssueU,
    Counter::DramU,
    Counter::L2U,
    Counter::TexU,
    Counter::ShrU,
    Counter::SmE,
    Counter::WarpE,
    Counter::WarpNpE,
    Counter::Threads,
];

/// The instruction-count counters an instruction-utilization bottleneck
/// is derived from (Eq. 10 "analogous computations").
pub const INST_COUNTERS: [Counter; 7] = [
    Counter::InstF32,
    Counter::InstF64,
    Counter::InstInt,
    Counter::InstMisc,
    Counter::InstLdst,
    Counter::InstCont,
    Counter::InstBconv,
];

impl Counter {
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Option<Counter> {
        ALL_COUNTERS.get(i).copied()
    }

    /// PC_ops vs PC_stress per Table 1. `INST_ISSUE_U` is classified as
    /// Ops by the paper (it quantifies issue-cycle usage), `Threads` is a
    /// pseudo-ops counter.
    pub fn kind(self) -> CounterKind {
        use Counter::*;
        match self {
            DramU | L2U | TexU | ShrU | SmE | WarpE | WarpNpE => {
                CounterKind::Stress
            }
            _ => CounterKind::Ops,
        }
    }

    /// Short abbreviation used throughout the paper (Table 1).
    pub fn abbr(self) -> &'static str {
        use Counter::*;
        match self {
            DramRt => "DRAM_RT",
            DramWt => "DRAM_WT",
            L2Rt => "L2_RT",
            L2Wt => "L2_WT",
            TexRwt => "TEX_RWT",
            LocO => "LOC_O",
            ShrLt => "SHR_LT",
            ShrWt => "SHR_WT",
            InstF32 => "INST_F32",
            InstF64 => "INST_F64",
            InstInt => "INST_INT",
            InstMisc => "INST_MISC",
            InstLdst => "INST_LDST",
            InstCont => "INST_CONT",
            InstBconv => "INST_BCONV",
            InstExe => "INST_EXE",
            InstIssueU => "INST_ISSUE_U",
            DramU => "DRAM_U",
            L2U => "L2_U",
            TexU => "TEX_U",
            ShrU => "SHR_U",
            SmE => "SM_E",
            WarpE => "WARP_E",
            WarpNpE => "WARP_NP_E",
            Threads => "THREADS",
        }
    }

    pub fn from_abbr(s: &str) -> Option<Counter> {
        ALL_COUNTERS.iter().copied().find(|c| c.abbr() == s)
    }

    /// CUDA counter name for the given counter-name generation.
    pub fn cuda_name(self, set: CounterSet) -> &'static str {
        use Counter::*;
        match (self, set) {
            (DramRt, CounterSet::PreVolta) => "dram_read_transactions",
            (DramRt, CounterSet::VoltaPlus) => "dram__sectors_read.sum",
            (DramWt, CounterSet::PreVolta) => "dram_write_transactions",
            (DramWt, CounterSet::VoltaPlus) => "dram__sectors_write.sum",
            (L2Rt, CounterSet::PreVolta) => "l2_read_transactions",
            (L2Rt, CounterSet::VoltaPlus) => "lts__t_sectors_op_read.sum",
            (L2Wt, CounterSet::PreVolta) => "l2_write_transactions",
            (L2Wt, CounterSet::VoltaPlus) => "lts__t_sectors_op_write.sum",
            (TexRwt, CounterSet::PreVolta) => "tex_cache_transactions",
            (TexRwt, CounterSet::VoltaPlus) => {
                "l1tex__t_requests_pipe_lsu_mem_global_op_ld.sum"
            }
            (LocO, CounterSet::PreVolta) => "local_memory_overhead",
            (LocO, CounterSet::VoltaPlus) => {
                "l1tex__t_sectors_pipe_lsu_mem_local_op_st.sum"
            }
            (ShrLt, CounterSet::PreVolta) => "shared_load_transactions",
            (ShrLt, CounterSet::VoltaPlus) => {
                "l1tex__data_pipe_lsu_wavefronts_mem_shared_op_ld.sum"
            }
            (ShrWt, CounterSet::PreVolta) => "shared_store_transactions",
            (ShrWt, CounterSet::VoltaPlus) => {
                "l1tex__data_pipe_lsu_wavefronts_mem_shared_op_st.sum"
            }
            (InstF32, CounterSet::PreVolta) => "inst_fp_32",
            (InstF32, CounterSet::VoltaPlus) => {
                "smsp__sass_thread_inst_executed_op_fp32_pred_on.sum"
            }
            (InstF64, CounterSet::PreVolta) => "inst_fp_64",
            (InstF64, CounterSet::VoltaPlus) => {
                "smsp__sass_thread_inst_executed_op_fp64_pred_on.sum"
            }
            (InstInt, CounterSet::PreVolta) => "inst_integer",
            (InstInt, CounterSet::VoltaPlus) => {
                "smsp__sass_thread_inst_executed_op_integer_pred_on.sum"
            }
            (InstMisc, CounterSet::PreVolta) => "inst_misc",
            (InstMisc, CounterSet::VoltaPlus) => {
                "smsp__sass_thread_inst_executed_op_misc_pred_on.sum"
            }
            (InstLdst, CounterSet::PreVolta) => "inst_compute_ld_st",
            (InstLdst, CounterSet::VoltaPlus) => {
                "smsp__sass_thread_inst_executed_op_memory_pred_on.sum"
            }
            (InstCont, CounterSet::PreVolta) => "inst_control",
            (InstCont, CounterSet::VoltaPlus) => {
                "smsp__sass_thread_inst_executed_op_control_pred_on.sum"
            }
            (InstBconv, CounterSet::PreVolta) => "inst_bit_convert",
            (InstBconv, CounterSet::VoltaPlus) => {
                "smsp__sass_thread_inst_executed_op_conversion_pred_on.sum"
            }
            (InstExe, CounterSet::PreVolta) => "inst_executed",
            (InstExe, CounterSet::VoltaPlus) => "smsp__inst_executed.sum",
            (InstIssueU, CounterSet::PreVolta) => "issue_slot_utilization",
            (InstIssueU, CounterSet::VoltaPlus) => {
                "smsp__issue_active.avg.pct_of_peak_sustained_active"
            }
            (DramU, CounterSet::PreVolta) => "dram_utilization",
            (DramU, CounterSet::VoltaPlus) => {
                "dram__throughput.avg.pct_of_peak_sustained_elapsed"
            }
            (L2U, CounterSet::PreVolta) => "l2_utilization",
            (L2U, CounterSet::VoltaPlus) => {
                "lts__t_sectors.avg.pct_of_peak_sustained_elapsed"
            }
            (TexU, CounterSet::PreVolta) => "tex_utilization",
            (TexU, CounterSet::VoltaPlus) => {
                "l1tex__t_requests_pipe_lsu_mem_global_op_ld.avg.pct_of_peak_sustained_active"
            }
            (ShrU, CounterSet::PreVolta) => "shared_utilization",
            (ShrU, CounterSet::VoltaPlus) => {
                "l1tex__data_pipe_lsu_wavefronts_mem_shared.avg.pct_of_peak_sustained_elapsed"
            }
            (SmE, CounterSet::PreVolta) => "sm_efficiency",
            (SmE, CounterSet::VoltaPlus) => {
                "smsp__cycles_active.avg.pct_of_peak_sustained_elapsed"
            }
            (WarpE, CounterSet::PreVolta) => "warp_execution_efficiency",
            (WarpE, CounterSet::VoltaPlus) => {
                "smsp__thread_inst_executed_per_inst_executed.ratio"
            }
            (WarpNpE, CounterSet::PreVolta) => {
                "warp_nonpred_execution_efficiency"
            }
            (WarpNpE, CounterSet::VoltaPlus) => {
                "smsp__thread_inst_executed_per_inst_executed.pct"
            }
            (Threads, _) => "ktt_threads",
        }
    }

    /// Conversion ratio applied to Volta+ counters so they line up with
    /// the pre-Volta scale used by the expert system (Table 1 notes:
    /// utilization ranks are <0,10> pre-Volta vs percent <0,100> after;
    /// WARP_E is a ratio ·100 : 32 on Volta+).
    pub fn new_counter_scale(self) -> f64 {
        use Counter::*;
        match self {
            DramU | TexU | ShrU => 1.0 / 10.0,
            WarpE => 100.0 / 32.0,
            _ => 1.0,
        }
    }
}

impl fmt::Display for Counter {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.abbr())
    }
}

/// Which counter-name generation a GPU exposes (changed with Volta).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CounterSet {
    PreVolta,
    VoltaPlus,
}

impl CounterSet {
    /// Does this generation provide `c` with semantics *comparable
    /// across the Volta generation boundary*?
    ///
    /// Almost every Table 1 counter survives the Volta renaming with a
    /// documented conversion ratio ([`Counter::new_counter_scale`]).
    /// The exception is `LOC_O`: pre-Volta `local_memory_overhead` is a
    /// *percentage* of memory traffic (the Eq. 8 bottleneck divides it
    /// by 100), while the closest Volta+ metric is a raw local-store
    /// sector count with no fixed scale relation to it.
    ///
    /// `supports(c) == false` therefore means "this generation's `c`
    /// does not line up with the other generation's `c`" — it does
    /// *not* forbid same-generation use: a Volta+ model steering a
    /// Volta+ tuner shares one self-consistent metric and scores it
    /// freely. Only *cross*-generation transfer drops the counter from
    /// scoring (see [`PredictionMatrix::restricted_to`] and the
    /// transfer runner, which applies the restriction exactly when the
    /// two generations differ).
    ///
    /// [`PredictionMatrix::restricted_to`]:
    ///     crate::model::PredictionMatrix::restricted_to
    pub fn supports(self, c: Counter) -> bool {
        match self {
            CounterSet::PreVolta => true,
            CounterSet::VoltaPlus => c != Counter::LocO,
        }
    }
}

/// A dense vector of counter values, indexed by [`Counter`].
#[derive(Debug, Clone, PartialEq)]
pub struct CounterVec(pub [f64; NUM_COUNTERS]);

impl Default for CounterVec {
    fn default() -> Self {
        CounterVec([0.0; NUM_COUNTERS])
    }
}

impl CounterVec {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn get(&self, c: Counter) -> f64 {
        self.0[c.index()]
    }

    #[inline]
    pub fn set(&mut self, c: Counter, v: f64) {
        self.0[c.index()] = v;
    }

    /// Iterate (counter, value) pairs.
    pub fn iter(&self) -> impl Iterator<Item = (Counter, f64)> + '_ {
        ALL_COUNTERS.iter().map(move |&c| (c, self.get(c)))
    }

    /// Only the PC_ops components (the model targets).
    pub fn ops(&self) -> impl Iterator<Item = (Counter, f64)> + '_ {
        self.iter().filter(|(c, _)| c.kind() == CounterKind::Ops)
    }

    pub fn to_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::Obj(
            self.iter()
                .map(|(c, v)| (c.abbr().to_string(), v.into()))
                .collect(),
        )
    }

    pub fn from_json(v: &crate::util::json::Value) -> anyhow::Result<Self> {
        let mut out = CounterVec::new();
        let o = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("counter vec must be an object"))?;
        for (k, val) in o {
            if let Some(c) = Counter::from_abbr(k) {
                out.set(
                    c,
                    val.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("{k} not a number"))?,
                );
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_dense_and_stable() {
        for (i, c) in ALL_COUNTERS.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(Counter::from_index(i), Some(*c));
        }
        assert_eq!(Counter::from_index(NUM_COUNTERS), None);
    }

    #[test]
    fn table1_taxonomy() {
        // Exactly 7 stress counters per Table 1.
        let stress = ALL_COUNTERS
            .iter()
            .filter(|c| c.kind() == CounterKind::Stress)
            .count();
        assert_eq!(stress, 7);
        assert_eq!(Counter::InstIssueU.kind(), CounterKind::Ops);
        assert_eq!(Counter::Threads.kind(), CounterKind::Ops);
    }

    #[test]
    fn abbr_roundtrip() {
        for c in ALL_COUNTERS {
            assert_eq!(Counter::from_abbr(c.abbr()), Some(c));
        }
        assert_eq!(Counter::from_abbr("NOPE"), None);
    }

    #[test]
    fn cuda_names_differ_across_generations() {
        for c in ALL_COUNTERS {
            if c == Counter::Threads {
                continue;
            }
            assert_ne!(
                c.cuda_name(CounterSet::PreVolta),
                c.cuda_name(CounterSet::VoltaPlus),
                "{c}"
            );
        }
    }

    #[test]
    fn counter_set_support_is_a_strict_subset_at_volta() {
        // VoltaPlus ⊂ PreVolta: everything Volta+ provides, pre-Volta
        // provides too…
        for c in ALL_COUNTERS {
            if CounterSet::VoltaPlus.supports(c) {
                assert!(CounterSet::PreVolta.supports(c), "{c}");
            }
        }
        // …and exactly LOC_O is lost at the generation boundary.
        let lost: Vec<Counter> = ALL_COUNTERS
            .iter()
            .copied()
            .filter(|&c| !CounterSet::VoltaPlus.supports(c))
            .collect();
        assert_eq!(lost, vec![Counter::LocO]);
    }

    #[test]
    fn countervec_roundtrip_json() {
        let mut v = CounterVec::new();
        v.set(Counter::DramRt, 1234.0);
        v.set(Counter::SmE, 87.5);
        let j = v.to_json();
        let back = CounterVec::from_json(&j).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn ops_iterator_excludes_stress() {
        let v = CounterVec::new();
        assert!(v.ops().all(|(c, _)| c.kind() == CounterKind::Ops));
        assert_eq!(v.ops().count(), NUM_COUNTERS - 7);
    }
}
