//! pcat CLI — the KTT-like launcher.
//!
//! ```text
//! pcat list                                  # benchmarks, GPUs, experiments
//! pcat record  --benchmark gemm --gpu gtx1070 [--input NAME] --out rec.json
//! pcat train   --data rec.json --out model.json
//! pcat tune    --benchmark gemm --gpu rtx2080 --searcher profile \
//!              [--model model.json] [--budget 200] [--seed 1]
//! pcat tune-real --benchmark gemm --artifacts artifacts [--searcher profile]
//! pcat experiment <id|all> [--out results] [--reps N] [--time-reps N] \
//!              [--jobs N]
//! pcat matrix  [--smoke] [--jobs N] [--seed S] [--seeds K] [--budget B] \
//!              [--benchmarks a,b] [--gpus x,y] [--inputs i,j] \
//!              [--searchers p,q] [--traces] \
//!              [--patience K] [--epsilon E] \
//!              [--fault-profile none|flaky|noisy|hostile] \
//!              [--out report.json]
//! pcat transfer [--smoke] [--jobs N] [--seed S] [--seeds K] [--budget B] \
//!              [--benchmarks a,b] [--sources x,y] [--targets x,y] \
//!              [--inputs i,j] [--source-inputs i,j] [--target-inputs i,j] \
//!              [--model oracle|tree] [--train-fraction F] \
//!              [--searchers p,q] [--curves] \
//!              [--fault-profile none|flaky|noisy|hostile] \
//!              [--out TRANSFER_REPORT.json]
//! pcat sweep   [--smoke] [--jobs N] [--seed S] [--seeds K] [--budget B] \
//!              [--benchmarks a,b] [--source g] [--target g] \
//!              [--fractions 0.1,0.25,1.0] [--models tree,oracle] \
//!              [--searchers p,q] [--out SWEEP_REPORT.json]
//! pcat registry append <report.json> [--registry registry/pcat.csv] \
//!              [--plan NAME]
//! pcat registry query [--registry PATH] [--plan NAME] [--kpi K]
//! pcat registry compare --baseline baseline.csv [--registry PATH] \
//!              [--plan NAME]
//! pcat registry hash <report.json>
//! pcat serve   [--smoke] [--jobs N] [--seed S] [--requests R] \
//!              [--benchmarks a,b] [--gpus x,y] [--inputs i,j] \
//!              [--zipf S] [--miss-ratio F] [--budget B] [--store PATH] \
//!              [--out SERVE_REPORT.json]
//! pcat serve-query --benchmark gemm [--gpu gtx1070] [--input NAME] \
//!              [--store PATH] [--seed S] [--budget B]
//! pcat cache export --store PATH [--out store.json]
//! pcat cache import <store.json> --store PATH
//! ```
//!
//! `matrix` runs an [`ExperimentPlan`] (benchmark × GPU × input ×
//! searcher × seed; `--inputs` takes the same selectors as `transfer`
//! and a default-input plan reproduces pre-input-axis reports
//! bit-for-bit) across the worker pool and writes a deterministic
//! JSON report. The searcher axis takes full [`SearcherSpec`] strings
//! (`ga:pop=20,mutation=0.1`, `profile+de`, …) — see `pcat list` for
//! the registry. `--patience K` (with `--epsilon E`) arms the
//! stopping criteria from arxiv 2203.13577: each job then reports the
//! reason it stopped (threshold/patience/tests/cost/exhausted) and the
//! aggregates count stop reasons per cell;
//! `--smoke` selects the tiny CI matrix whose report is byte-compared
//! against `rust/testdata/smoke_golden.json`. `--jobs N` bounds worker
//! threads everywhere (serial and parallel runs produce identical
//! reports). `--fault-profile` wraps every measurement in the
//! deterministic fault injector ([`pcat::searcher::FaultyEnv`]):
//! persistent/transient config failures, runtime noise and counter
//! dropout, with failure/retry/wasted-cost accounting in the report and
//! a robustness table on stdout; the `--smoke --fault-profile hostile`
//! lane is gated against `rust/testdata/faults_golden.json`.
//!
//! `transfer` runs a [`TransferPlan`] — the paper's train-on-A /
//! tune-on-B portability experiment over **both** axes the paper
//! claims: the profile searcher's model matrix is built from each
//! *source* (GPU, input) recording (`--model oracle` exact PCs, or
//! `--model tree` per-counter decision trees trained on
//! `--train-fraction` of the source — a deterministic stratified
//! sample) while the search replays each *target* (GPU, input) — and
//! writes the schema-v3 `TRANSFER_REPORT.json` (per-endpoint
//! MAE/RMSE/R² model-quality metrics always embedded; step- and
//! time-domain best-so-far curves under `--curves`) under the same
//! `--jobs`-invariant byte-identity contract. `--inputs` takes
//! selectors (`default`, `alt`, or concrete input names) and sets both
//! axes; `--source-inputs`/`--target-inputs` override one side.
//! `--smoke` is gated against `rust/testdata/transfer_golden.json`
//! (oracle) and `rust/testdata/transfer_tree_golden.json`
//! (`--model tree`).
//!
//! `sweep` runs a [`SweepPlan`] — the sample-efficiency sensitivity
//! sweep crossing `--fractions × --models × --benchmarks` on one
//! source → target GPU pair, writing `SWEEP_REPORT.json`
//! (convergence-vs-fraction cells with bootstrap CIs, model quality
//! per fraction, aggregated step curves). `--smoke` is gated against
//! `rust/testdata/sweep_golden.json`.
//!
//! `registry` maintains the append-only experiment registry
//! (`registry/pcat.csv` by default): `append` flattens a report's KPIs
//! into plan-hash + provenance-stamped rows (`PCAT_COMMIT` /
//! `PCAT_CREATED_AT` / `PCAT_TOOLCHAIN` override the embedded
//! provenance at append time), `query` filters and prints them,
//! `compare` gates the registry's latest rows against a blessed
//! baseline under typed per-KPI tolerances and exits nonzero on any
//! out-of-tolerance KPI, and `hash` prints a report's plan hash.
//!
//! `serve` runs the tuning-as-a-service load generator: a seeded Zipf
//! request mix over the benchmark × GPU × input endpoint universe
//! against a [`pcat::harness::ServeEngine`], reporting throughput, hit
//! rate and p50/p95/p99 (simulated) latency as the registry-stamped
//! `SERVE_REPORT.json` — byte-identical at any `--jobs`. `--store PATH`
//! backs the engine with a persistent JSON store instead of memory.
//! `--smoke` is gated against `rust/testdata/serve_golden.json`.
//! `serve-query` answers one endpoint query (search-on-miss, persisted
//! when `--store` is given); `cache export|import` moves a store
//! between files for pre-warming deployments.
//!
//! (clap is unavailable in the offline build; flags are parsed by hand.)

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use pcat::benchmarks::{
    self, cached_recorder, cached_space, Benchmark, RecordingMode,
};
use pcat::coordinator::Tuner;
use pcat::gpusim::GpuSpec;
use pcat::harness::{
    export_store, import_store, model_quality_matrix, render_store,
    robustness_table, run_experiment, run_load_plan, run_plan, run_sweep_plan,
    run_transfer_plan, searcher_ranking, sweep_matrix, transfer_input_matrix,
    transfer_matrix,
    ExperimentOpts, ExperimentPlan, JsonFileStore, LoadPlan, MemTuningStore,
    ModelSource, ServeConfig, ServeEngine, ServeKey, SweepPlan, TransferPlan,
    TuningStore, ALL_EXPERIMENTS,
};
use pcat::model::{
    dataset_from_recorded, DecisionTreeModel, OracleModel, PrecomputedModel,
    PredictionMatrix, TpPcModel,
};
use pcat::searcher::{
    augment_params, registry, Budget, CellCtx, CostModel, FaultProfile,
    ModelCtx, SearcherSpec,
};
use pcat::tuning::RecordedSpace;
use pcat::util::pool;
use pcat::util::rng::Rng;

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Minimal flag parser: positionals + `--key value`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it
                    .next_if(|n| !n.starts_with("--"))
                    .unwrap_or_else(|| "true".to_string());
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn need(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing --{key}"))
    }

    fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }
}

/// Parse a CSV axis flag (`--key a,b,c`), falling back to the plan's
/// default axis. Shared by `matrix` and `transfer` so the parsing
/// conventions cannot drift between the two subcommands.
fn axis_arg(args: &Args, key: &str, plan_axis: &[String]) -> Vec<String> {
    match args.get(key) {
        None => plan_axis.to_vec(),
        Some(csv) => csv
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
    }
}

/// Canonicalize user-supplied GPU names to the plan spelling
/// (lower-case spec name): `GpuSpec::by_name` forgives case, dashes
/// and spaces, but plan names feed RNG stream tags and report keys
/// verbatim — `--gpus GTX-1070` must produce the same streams (and the
/// same same-GPU reproduction guarantees) as `--gpus gtx1070`. Unknown
/// names pass through untouched so validation still reports them.
fn canon_gpus(names: Vec<String>) -> Vec<String> {
    names
        .into_iter()
        .map(|n| match GpuSpec::by_name(&n) {
            Some(g) => g.name.to_ascii_lowercase(),
            None => n,
        })
        .collect()
}

/// Same for benchmark names (`by_name` forgives case).
fn canon_benchmarks(names: Vec<String>) -> Vec<String> {
    names
        .into_iter()
        .map(|n| match benchmarks::by_name(&n) {
            Some(b) => b.name().to_string(),
            None => n,
        })
        .collect()
}

/// Resolve `--fault-profile` for the matrix/transfer runners. Unknown
/// names are a typed error listing the valid profiles.
fn fault_profile_arg(args: &Args) -> Result<FaultProfile> {
    match args.get("fault-profile") {
        None => Ok(FaultProfile::None),
        Some(s) => FaultProfile::parse(s).ok_or_else(|| {
            let names: Vec<&str> =
                FaultProfile::ALL.iter().map(|p| p.name()).collect();
            anyhow!(
                "--fault-profile expects one of {}, got {s:?}",
                names.join("|")
            )
        }),
    }
}

/// Resolve `--jobs` (0 = all available cores) for the plan runners.
fn jobs_arg(args: &Args) -> Result<usize> {
    Ok(match args.num("jobs", 0usize)? {
        0 => pool::default_jobs(),
        n => n,
    })
}

fn bench_arg(args: &Args) -> Result<Box<dyn Benchmark>> {
    let name = args.need("benchmark")?;
    benchmarks::by_name(name)
        .ok_or_else(|| anyhow!("unknown benchmark {name:?} (see `pcat list`)"))
}

fn gpu_arg(args: &Args) -> Result<GpuSpec> {
    let name = args.get("gpu").unwrap_or("gtx1070");
    GpuSpec::by_name(name)
        .ok_or_else(|| anyhow!("unknown GPU {name:?} (see `pcat list`)"))
}

fn input_arg(args: &Args, bench: &dyn Benchmark) -> Result<benchmarks::Input> {
    match args.get("input") {
        None => Ok(bench.default_input()),
        // same selector vocabulary as the plan axes: "default", "alt",
        // or a concrete input name
        Some(name) => benchmarks::resolve_input(bench, name).ok_or_else(|| {
            anyhow!("unknown input {name:?} for this benchmark (see `pcat list`)")
        }),
    }
}

fn run() -> Result<()> {
    let args = Args::parse();
    // global worker-count override: 0 (default) = all available cores
    let jobs = args.num("jobs", 0usize)?;
    pool::set_default_jobs(jobs);
    match args.positional.first().map(|s| s.as_str()) {
        Some("list") => cmd_list(),
        Some("record") => cmd_record(&args),
        Some("train") => cmd_train(&args),
        Some("tune") => cmd_tune(&args),
        Some("tune-real") => cmd_tune_real(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("matrix") => cmd_matrix(&args),
        Some("transfer") => cmd_transfer(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("registry") => cmd_registry(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-query") => cmd_serve_query(&args),
        Some("cache") => cmd_cache(&args),
        Some("diag") => cmd_diag(&args),
        _ => {
            eprintln!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "pcat — performance-counter-aided autotuning (paper \
reproduction)\n\ncommands:\n  list        benchmarks, GPUs, searchers, \
experiments\n  \
record      exhaustively record a tuning space on a simulated GPU\n  train       \
train a TP→PC decision-tree model from a recording\n  tune        search a \
tuning space (replayed/simulated; --searcher takes any\n              \
registry spec: ga:pop=20, profile+de, … — see `pcat list`)\n  tune-real   \
search over really-executing \
PJRT artifacts\n  experiment  regenerate a paper table/figure (or `all`)\n  \
matrix      run a benchmark × GPU × input × searcher × seed job matrix in \
parallel\n              (--smoke = the tiny deterministic CI matrix;\n              \
--patience K [--epsilon E] arms early stopping and per-job\n              \
stop-reason accounting;\n              \
--fault-profile none|flaky|noisy|hostile injects deterministic\n              \
measurement faults and reports failure/retry accounting)\n  \
transfer    train-on-(GPU,input)-A / tune-on-B portability matrix; writes\n              \
paper-style tables (GPU×GPU + input×input + model quality) +\n              \
TRANSFER_REPORT.json (--model oracle|tree picks the source model;\n              \
--train-fraction F trains on a stratified sample; --inputs widens\n              \
the input axes; --smoke = the tiny CI matrix)\n  \
sweep       sample-efficiency sensitivity sweep (train-fraction × model ×\n              \
benchmark convergence curves); writes SWEEP_REPORT.json\n              \
(--fractions 0.1,0.25,1.0; --models tree,oracle; --smoke = the\n              \
tiny CI sweep)\n  \
registry    append-only experiment registry + KPI trend gate\n              \
(append <report.json> | query [--plan P] [--kpi K] |\n              \
compare --baseline rows.csv | hash <report.json>;\n              \
--registry PATH, default registry/pcat.csv)\n  \
serve       tuning-as-a-service load generator: seeded Zipf request mix\n              \
against the persistent tuning cache; writes SERVE_REPORT.json\n              \
with throughput/hit-rate/latency-percentile KPIs (--smoke = the\n              \
tiny CI workload; --store PATH = persistent JSON store)\n  \
serve-query answer one (benchmark, GPU, input) -> best-config query,\n              \
searching on miss (--store PATH persists the answer)\n  \
cache       export | import a tuning store file for pre-warming\n              \
(export --store PATH [--out FILE] | import <FILE> --store PATH)\n\nglobal \
flags: --jobs N caps worker threads (results are identical at any N).\nOther \
flags are shown in main.rs docs and README.";

fn cmd_list() -> Result<()> {
    println!("benchmarks:");
    for b in benchmarks::all() {
        let s = b.space();
        let inputs: Vec<String> =
            b.inputs().iter().map(|i| i.name.clone()).collect();
        println!(
            "  {:<12} {} params, {} configurations; inputs: {}",
            b.name(),
            s.dims(),
            s.len(),
            inputs.join(", ")
        );
    }
    println!("\nGPUs (simulated, paper Table 3):");
    for g in GpuSpec::all() {
        println!(
            "  {:<8} {:?}, {} SMs × {} cores, {} GB/s",
            g.name, g.arch, g.sm_count, g.cores_per_sm, g.dram_bw
        );
    }
    // rendered straight off the spec registry, so this listing can
    // never drift from what `--searcher` actually parses
    println!("\nsearchers (--searcher NAME[:param=value,...]):");
    for e in registry() {
        let aug = if e.augmentable { "  [profile+]" } else { "" };
        println!("  {:<14} {}{}", e.name, e.doc, aug);
        for p in e.params {
            println!(
                "      {:<14} default {:<6} {}",
                p.name, p.default, p.doc
            );
        }
    }
    println!(
        "  profile+BASE   wrap any [profile+] base searcher with \
         PC-model guidance (Eq. 16)"
    );
    for p in augment_params() {
        println!(
            "      {:<14} default {:<6} {}",
            p.name, p.default, p.doc
        );
    }
    println!("\nexperiments: {}", ALL_EXPERIMENTS.join(" "));
    Ok(())
}

fn cmd_record(args: &Args) -> Result<()> {
    let bench = bench_arg(args)?;
    let gpu = gpu_arg(args)?;
    let input = input_arg(args, bench.as_ref())?;
    let out = PathBuf::from(args.need("out")?);
    let rec = cached_space(bench.as_ref(), &gpu, &input);
    rec.save(&out)?;
    println!(
        "recorded {} configs of {} on {} ({}) -> {}",
        rec.space.len(),
        bench.name(),
        gpu.name,
        input.name,
        out.display()
    );
    println!("best runtime: {:.4} ms", rec.best_time());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.need("data")?);
    let out = PathBuf::from(args.need("out")?);
    let rec = RecordedSpace::load(&data)?;
    let mut rng = Rng::new(args.num("seed", 0u64)?);
    let ds = dataset_from_recorded(&rec, args.num("fraction", 1.0f64)?, &mut rng);
    let model = DecisionTreeModel::train(&ds, &rec.gpu, &mut rng);
    model.save(&out)?;
    println!(
        "trained decision-tree model on {} samples from {} -> {}",
        ds.len(),
        rec.gpu,
        out.display()
    );
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let bench = bench_arg(args)?;
    let gpu = gpu_arg(args)?;
    let input = input_arg(args, bench.as_ref())?;
    let budget = Budget::tests(args.num("budget", 200usize)?);
    let seed = args.num("seed", 0u64)?;
    let searcher = args.get("searcher").unwrap_or("profile");
    // any registry spec works here: "ga:pop=20", "profile+de", …
    let spec = SearcherSpec::parse(searcher)
        .map_err(|e| anyhow!("--searcher: {e}"))?;

    // On-demand benchmarks (§4.6 large spaces) are never exhaustively
    // recorded: tune through the lazy recorder, which simulates only
    // the configurations the search actually visits. Model-reading
    // specs profile through the same recorder instead of a densified
    // matrix.
    if bench.recording_mode() == RecordingMode::OnDemand {
        let recorder = cached_recorder(bench.as_ref(), &gpu, &input);
        let ir = if bench.instruction_bound() { 0.5 } else { 0.7 };
        let ctx = CellCtx::new(
            ModelCtx::Lazy {
                recorder: Arc::clone(&recorder),
            },
            ir,
            0,
        );
        let mut tuner =
            Tuner::on_demand(Arc::clone(&recorder), CostModel::default())
                .with_budget(budget)
                .with_seed(seed);
        let result = tuner.run(&spec, &ctx);
        println!(
            "tuned {} on {} ({}) with {} [on-demand: {} of {} configs \
             simulated]",
            bench.name(),
            gpu.name,
            input.name,
            result.searcher,
            recorder.visited(),
            recorder.space().len(),
        );
        println!(
            "  tests: {} ({} profiled), simulated tuning cost {:.1}s",
            result.tests, result.profiled_tests, result.cost_s
        );
        println!(
            "  best: {:.4} ms (exhaustive best unknown: space is never \
             fully recorded)",
            result.best_ms
        );
        print!("  config:");
        for (p, v) in
            recorder.space().params.iter().zip(&result.best_config.0)
        {
            print!(" {}={}", p.name, v);
        }
        println!();
        return Ok(());
    }

    let rec = cached_space(bench.as_ref(), &gpu, &input);
    let best = rec.best_time();
    let ir = if bench.instruction_bound() { 0.5 } else { 0.7 };

    // model: from --model file, or an oracle over the recorded space
    let loaded: Option<DecisionTreeModel> = match args.get("model") {
        Some(path) => Some(DecisionTreeModel::load(&PathBuf::from(path))?),
        None => None,
    };
    let oracle;
    let pre;
    let model_ref: &dyn TpPcModel = match &loaded {
        Some(m) => {
            pre = PrecomputedModel::over(&rec.space, m);
            &pre
        }
        None => {
            oracle = OracleModel::new(&rec);
            &oracle
        }
    };

    // model-reading specs densify the TP→PC model into a prediction
    // matrix once; model-free zoo members skip the build entirely
    let model_ctx = if spec.reads_model() {
        ModelCtx::Eager {
            matrix: Arc::new(PredictionMatrix::build(&rec.space, model_ref)),
        }
    } else {
        ModelCtx::None
    };
    let ctx = CellCtx::new(model_ctx, ir, 0);

    let mut tuner = Tuner::replay(rec, gpu.clone(), CostModel::default())
        .with_budget(budget)
        .with_seed(seed);
    let result = tuner.run(&spec, &ctx);

    println!(
        "tuned {} on {} ({}) with {}",
        bench.name(),
        gpu.name,
        input.name,
        result.searcher
    );
    println!(
        "  tests: {} ({} profiled), simulated tuning cost {:.1}s",
        result.tests, result.profiled_tests, result.cost_s
    );
    println!(
        "  best: {:.4} ms ({:.1}% over exhaustive best {:.4} ms)",
        result.best_ms,
        (result.best_ms / best - 1.0) * 100.0,
        best
    );
    print!("  config:");
    for (p, v) in
        bench.space().params.iter().zip(&result.best_config.0)
    {
        print!(" {}={}", p.name, v);
    }
    println!();
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_tune_real(args: &Args) -> Result<()> {
    use anyhow::Context;
    use pcat::runtime::{load_manifest, PjrtEnv};
    use pcat::searcher::EvalEnv;

    let bench_name = args.need("benchmark")?;
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let entries: Vec<_> = load_manifest(&dir)
        .context("artifacts not built? run `make artifacts`")?
        .into_iter()
        .filter(|e| e.benchmark == bench_name)
        .collect();
    if entries.is_empty() {
        bail!("no artifacts for benchmark {bench_name:?} in {}", dir.display());
    }
    println!(
        "compiling {} PJRT variants of {bench_name}…",
        entries.len()
    );
    let env = PjrtEnv::new(&entries)?;
    let space = env.space().clone();
    let ops = env.ops_counters_all();
    let model = PrecomputedModel::from_pairs(
        space.configs.iter().cloned().zip(ops).collect(),
        "manifest-ops",
    );
    let searcher = args.get("searcher").unwrap_or("profile");
    let spec = SearcherSpec::parse(searcher)
        .map_err(|e| anyhow!("--searcher: {e}"))?;
    let budget = Budget::tests(
        args.num("budget", space.len().min(space.len()))?,
    );
    let mut tuner = Tuner::over(Box::new(env))
        .with_budget(budget)
        .with_seed(args.num("seed", 0u64)?);
    let ctx = if spec.reads_model() {
        CellCtx::new(
            ModelCtx::Eager {
                matrix: Arc::new(PredictionMatrix::build(&space, &model)),
            },
            0.5,
            0,
        )
    } else {
        CellCtx::modelless(0)
    };
    let result = tuner.run(&spec, &ctx);
    println!(
        "real-execution tuning of {bench_name}: {} tests, best {:.3} ms",
        result.tests, result.best_ms
    );
    print!("  config:");
    for (p, v) in space.params.iter().zip(&result.best_config.0) {
        print!(" {}={}", p.name, v);
    }
    println!();
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_tune_real(_args: &Args) -> Result<()> {
    bail!(
        "this binary was built without the `xla` feature; rebuild with \
         `--features xla` (and the xla toolchain installed) to tune over \
         really-executing PJRT artifacts"
    )
}

/// Run an [`ExperimentPlan`] job matrix in parallel and write the
/// deterministic JSON report.
fn cmd_matrix(args: &Args) -> Result<()> {
    let seed = args.num("seed", 0u64)?;
    // fault injection composes with both plan shapes; the smoke matrix
    // stays pinned otherwise, so CI gates `--smoke` and `--smoke
    // --fault-profile hostile` as separate golden lanes
    let fault_profile = fault_profile_arg(args)?;
    // stopping criteria (arxiv 2203.13577): --patience K arms
    // patience-based early stopping; --epsilon E sets the relative
    // improvement a test must make to reset the patience counter.
    // Unset = pre-stopping report bytes, including the smoke goldens.
    let patience = args
        .get("patience")
        .map(|v| {
            v.parse::<usize>().map_err(|_| {
                anyhow!("--patience expects a number, got {v:?}")
            })
        })
        .transpose()?;
    let epsilon = args.num("epsilon", 0.0f64)?;
    let plan = if args.get("smoke").is_some() {
        ExperimentPlan {
            fault_profile,
            patience,
            epsilon,
            ..ExperimentPlan::smoke(seed)
        }
    } else {
        let base = ExperimentPlan::full(args.num("seeds", 100usize)?, seed);
        ExperimentPlan {
            benchmarks: canon_benchmarks(axis_arg(
                args,
                "benchmarks",
                &base.benchmarks,
            )),
            gpus: canon_gpus(axis_arg(args, "gpus", &base.gpus)),
            // selectors resolve per benchmark, so they are deliberately
            // NOT canonicalized here — ExperimentPlan::jobs resolves
            // them to concrete names before any RNG tag; a ["default"]
            // axis reproduces pre-input-axis reports bit-for-bit
            inputs: axis_arg(args, "inputs", &base.inputs),
            searchers: axis_arg(args, "searchers", &base.searchers),
            max_tests: args.num("budget", base.max_tests)?,
            include_traces: args.get("traces").is_some(),
            fault_profile,
            patience,
            epsilon,
            ..base
        }
    };
    let jobs = jobs_arg(args)?;
    let n_jobs = plan.jobs().len();
    let out = PathBuf::from(args.get("out").unwrap_or("results/matrix.json"));

    let t0 = std::time::Instant::now();
    let report = run_plan(&plan, jobs)?;
    report.write_to(&out)?;

    println!(
        "ran {n_jobs} jobs on {jobs} worker(s) in {:.1}s -> {}",
        t0.elapsed().as_secs_f64(),
        out.display()
    );
    for line in report.summary_lines() {
        println!("  {line}");
    }
    let ranking = searcher_ranking(&report);
    if !ranking.is_empty() {
        println!("{ranking}");
    }
    let robustness = robustness_table(&report);
    if !robustness.is_empty() {
        println!("{robustness}");
    }
    Ok(())
}

/// Run a [`TransferPlan`] (train-on-(GPU, input)-A / tune-on-B matrix)
/// in parallel, write the deterministic `TRANSFER_REPORT.json` and
/// print the paper-style source × target tables (GPU × GPU, and
/// input × input when the plan has an input dimension).
fn cmd_transfer(args: &Args) -> Result<()> {
    let seed = args.num("seed", 0u64)?;
    let model = match args.get("model") {
        None => ModelSource::Oracle,
        Some(s) => ModelSource::parse(s)
            .ok_or_else(|| anyhow!("--model expects oracle|tree, got {s:?}"))?,
    };
    // sampling knob for the tree source; 1.0 = full recording (the
    // pre-fraction behaviour, also the smoke/golden setting)
    let train_fraction = args.num("train-fraction", 1.0f64)?;
    let fault_profile = fault_profile_arg(args)?;
    let plan = if args.get("smoke").is_some() {
        // the smoke matrix is pinned except for the model source, the
        // training fraction and the fault profile (CI invokes it
        // without --train-fraction), so CI gates `--smoke` and
        // `--smoke --model tree` as two lanes
        TransferPlan {
            model,
            train_fraction,
            fault_profile,
            ..TransferPlan::smoke(seed)
        }
    } else {
        let base = TransferPlan::full(args.num("seeds", 100usize)?, seed);
        // --inputs sets both axes; --source-inputs/--target-inputs
        // override one side (selectors resolve per benchmark, so they
        // are deliberately NOT canonicalized here — TransferPlan::jobs
        // resolves them to concrete names before any RNG tag)
        let both_inputs = axis_arg(args, "inputs", &base.source_inputs);
        TransferPlan {
            benchmarks: canon_benchmarks(axis_arg(
                args,
                "benchmarks",
                &base.benchmarks,
            )),
            source_gpus: canon_gpus(axis_arg(args, "sources", &base.source_gpus)),
            source_inputs: axis_arg(args, "source-inputs", &both_inputs),
            target_gpus: canon_gpus(axis_arg(args, "targets", &base.target_gpus)),
            target_inputs: axis_arg(args, "target-inputs", &both_inputs),
            model,
            train_fraction,
            searchers: axis_arg(args, "searchers", &base.searchers),
            max_tests: args.num("budget", base.max_tests)?,
            include_curves: args.get("curves").is_some(),
            fault_profile,
            ..base
        }
    };
    let jobs = jobs_arg(args)?;
    let n_jobs = plan.jobs().len();
    let out = PathBuf::from(
        args.get("out").unwrap_or("results/TRANSFER_REPORT.json"),
    );

    let t0 = std::time::Instant::now();
    let report = run_transfer_plan(&plan, jobs)?;
    report.write_to(&out)?;

    println!(
        "ran {n_jobs} transfer jobs on {jobs} worker(s) in {:.1}s -> {}",
        t0.elapsed().as_secs_f64(),
        out.display()
    );
    for line in report.summary_lines() {
        println!("  {line}");
    }
    println!("{}", transfer_matrix(&report));
    let input_grid = transfer_input_matrix(&report);
    if !input_grid.is_empty() {
        println!("{input_grid}");
    }
    let quality_grid = model_quality_matrix(&report);
    if !quality_grid.is_empty() {
        println!("{quality_grid}");
    }
    Ok(())
}

/// Run a [`SweepPlan`] (sample-efficiency sensitivity sweep:
/// train-fraction × model × benchmark) in parallel, write the
/// deterministic `SWEEP_REPORT.json` and print the
/// convergence-vs-fraction grid.
fn cmd_sweep(args: &Args) -> Result<()> {
    let seed = args.num("seed", 0u64)?;
    let plan = if args.get("smoke").is_some() {
        SweepPlan::smoke(seed)
    } else {
        let base = SweepPlan::full(args.num("seeds", 100usize)?, seed);
        let fractions = match args.get("fractions") {
            None => base.fractions.clone(),
            Some(csv) => csv
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<f64>().map_err(|_| {
                        anyhow!("--fractions expects numbers, got {s:?}")
                    })
                })
                .collect::<Result<Vec<f64>>>()?,
        };
        let models = match args.get("models") {
            None => base.models.clone(),
            Some(csv) => csv
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    ModelSource::parse(s).ok_or_else(|| {
                        anyhow!("--models expects oracle|tree, got {s:?}")
                    })
                })
                .collect::<Result<Vec<ModelSource>>>()?,
        };
        SweepPlan {
            benchmarks: canon_benchmarks(axis_arg(
                args,
                "benchmarks",
                &base.benchmarks,
            )),
            source_gpu: canon_gpus(vec![args
                .get("source")
                .unwrap_or(base.source_gpu.as_str())
                .to_string()])
            .remove(0),
            target_gpu: canon_gpus(vec![args
                .get("target")
                .unwrap_or(base.target_gpu.as_str())
                .to_string()])
            .remove(0),
            fractions,
            models,
            searchers: axis_arg(args, "searchers", &base.searchers),
            max_tests: args.num("budget", base.max_tests)?,
            ..base
        }
    };
    let jobs = jobs_arg(args)?;
    let n_combos = plan.combos().len();
    let out =
        PathBuf::from(args.get("out").unwrap_or("results/SWEEP_REPORT.json"));

    let t0 = std::time::Instant::now();
    let report = run_sweep_plan(&plan, jobs)?;
    report.write_to(&out)?;

    println!(
        "swept {n_combos} (model, fraction) combinations on {jobs} \
         worker(s) in {:.1}s -> {}",
        t0.elapsed().as_secs_f64(),
        out.display()
    );
    for line in report.summary_lines() {
        println!("  {line}");
    }
    println!("{}", sweep_matrix(&report));
    Ok(())
}

/// Maintain the append-only experiment registry and run the KPI trend
/// gate (`pcat registry append|query|compare|hash`).
fn cmd_registry(args: &Args) -> Result<()> {
    use pcat::harness::{
        compare_rows, default_tolerances, extract_rows, plan_hash,
        registry_compare_table, registry_query_table, CompareStatus,
        CsvStore, RegistryStore,
    };
    use pcat::util::json;

    let store_path =
        PathBuf::from(args.get("registry").unwrap_or("registry/pcat.csv"));
    let report_arg = |action: &str| -> Result<pcat::util::json::Value> {
        let path = args.positional.get(2).ok_or_else(|| {
            anyhow!("usage: pcat registry {action} <report.json>")
        })?;
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))
    };

    match args.positional.get(1).map(|s| s.as_str()) {
        Some("append") => {
            let report = report_arg("append")?;
            let rows = extract_rows(&report, args.get("plan"))?;
            let mut store = CsvStore::new(&store_path);
            store.append(&rows)?;
            println!(
                "appended {} row(s) ({}, plan_hash {}) -> {}",
                rows.len(),
                rows.first().map(|r| r.plan.as_str()).unwrap_or("empty"),
                rows.first().map(|r| r.plan_hash.as_str()).unwrap_or("-"),
                store_path.display()
            );
            Ok(())
        }
        Some("query") => {
            let mut rows = CsvStore::new(&store_path).load()?;
            if let Some(plan) = args.get("plan") {
                rows.retain(|r| r.plan == plan);
            }
            if let Some(kpi) = args.get("kpi") {
                rows.retain(|r| r.kpi == kpi);
            }
            println!("{}", registry_query_table(&rows));
            println!("{} row(s)", rows.len());
            Ok(())
        }
        Some("compare") => {
            let mut baseline =
                CsvStore::new(PathBuf::from(args.need("baseline")?)).load()?;
            let mut current = CsvStore::new(&store_path).load()?;
            if let Some(plan) = args.get("plan") {
                baseline.retain(|r| r.plan == plan);
                current.retain(|r| r.plan == plan);
            }
            let findings =
                compare_rows(&baseline, &current, &default_tolerances());
            println!("{}", registry_compare_table(&findings));
            let fails = findings
                .iter()
                .filter(|f| f.status == CompareStatus::Fail)
                .count();
            if fails > 0 {
                bail!("{fails} KPI(s) out of tolerance (see table above)");
            }
            println!(
                "registry compare: {} key(s), all within tolerance",
                findings.len()
            );
            Ok(())
        }
        Some("hash") => {
            let report = report_arg("hash")?;
            let schema = report.get("schema")?.as_str().ok_or_else(|| {
                anyhow!("report \"schema\" field is not a string")
            })?;
            println!("{}", plan_hash(schema, report.get("plan")?));
            Ok(())
        }
        other => bail!(
            "unknown registry action {other:?}; expected \
             append|query|compare|hash"
        ),
    }
}

/// Pick the tuning-store backend shared by the serving subcommands:
/// `--store PATH` opens (or creates) a persistent JSON store, no flag
/// means in-memory.
fn store_arg(args: &Args) -> Result<Arc<dyn TuningStore>> {
    Ok(match args.get("store") {
        Some(path) => Arc::new(JsonFileStore::open(&PathBuf::from(path))?),
        None => Arc::new(MemTuningStore::new()),
    })
}

/// Run the tuning-as-a-service load generator ([`LoadPlan`]) and write
/// the deterministic `SERVE_REPORT.json`.
fn cmd_serve(args: &Args) -> Result<()> {
    let seed = args.num("seed", 0u64)?;
    let plan = if args.get("smoke").is_some() {
        LoadPlan::smoke(seed)
    } else {
        let base = LoadPlan::full(seed);
        LoadPlan {
            benchmarks: canon_benchmarks(axis_arg(
                args,
                "benchmarks",
                &base.benchmarks,
            )),
            gpus: canon_gpus(axis_arg(args, "gpus", &base.gpus)),
            // selectors resolve per benchmark (same contract as the
            // plan runners), so they are deliberately NOT canonicalized
            inputs: axis_arg(args, "inputs", &base.inputs),
            requests: args.num("requests", base.requests)?,
            zipf_s: args.num("zipf", base.zipf_s)?,
            miss_ratio: args.num("miss-ratio", base.miss_ratio)?,
            max_tests: args.num("budget", base.max_tests)?,
            ..base
        }
    };
    let jobs = jobs_arg(args)?;
    let store = store_arg(args)?;
    let out = PathBuf::from(
        args.get("out").unwrap_or("results/SERVE_REPORT.json"),
    );

    let t0 = std::time::Instant::now();
    let report = run_load_plan(&plan, store, jobs)?;
    report.write_to(&out)?;

    println!(
        "served {} requests on {jobs} worker(s) in {:.1}s -> {}",
        plan.requests,
        t0.elapsed().as_secs_f64(),
        out.display()
    );
    for line in report.summary_lines() {
        println!("  {line}");
    }
    Ok(())
}

/// Answer one endpoint query through the serve engine: store hit, or a
/// bounded profile search persisted back to the store.
fn cmd_serve_query(args: &Args) -> Result<()> {
    let benchmark = args.need("benchmark")?;
    let gpu = args.get("gpu").unwrap_or("gtx1070");
    let input = args
        .get("input")
        .unwrap_or(benchmarks::DEFAULT_INPUT_SELECTOR);
    let key = ServeKey::resolve(benchmark, gpu, input)?;
    let engine = ServeEngine::new(store_arg(args)?, ServeConfig {
        base_seed: args.num("seed", 0u64)?,
        max_tests: args.num("budget", 400usize)?,
    });
    let out = engine.query(&key)?;
    println!(
        "{}: {} — best {:.4} ms after {} tests ({} profiled), \
         search cost {:.1}s",
        out.key,
        if out.hit { "cache hit" } else { "miss, searched" },
        out.entry.best_ms,
        out.entry.tests,
        out.entry.profiled_tests,
        out.entry.cost_s,
    );
    let bench = benchmarks::by_name(&out.key.benchmark)
        .ok_or_else(|| anyhow!("unknown benchmark in key"))?;
    print!("  config:");
    for (p, v) in bench.space().params.iter().zip(&out.entry.config) {
        print!(" {}={}", p.name, v);
    }
    println!();
    println!(
        "  plan_hash {}  (searcher {}, budget {}, seed {})",
        out.entry.plan_hash,
        out.entry.searcher,
        out.entry.max_tests,
        out.entry.base_seed,
    );
    Ok(())
}

/// Move a tuning store between files (`pcat cache export|import`) so a
/// deployment can ship pre-warmed answers.
fn cmd_cache(args: &Args) -> Result<()> {
    use pcat::util::json;

    match args.positional.get(1).map(|s| s.as_str()) {
        Some("export") => {
            let store =
                JsonFileStore::open(&PathBuf::from(args.need("store")?))?;
            let text = render_store(&export_store(&store));
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &text)
                        .map_err(|e| anyhow!("writing {path}: {e}"))?;
                    println!(
                        "exported {} entr{} -> {path}",
                        store.len(),
                        if store.len() == 1 { "y" } else { "ies" },
                    );
                }
                None => print!("{text}"),
            }
            Ok(())
        }
        Some("import") => {
            let doc_path = args.positional.get(2).ok_or_else(|| {
                anyhow!("usage: pcat cache import <store.json> --store PATH")
            })?;
            let text = std::fs::read_to_string(doc_path)
                .map_err(|e| anyhow!("reading {doc_path}: {e}"))?;
            let doc = json::parse(&text)
                .map_err(|e| anyhow!("parsing {doc_path}: {e}"))?;
            let store =
                JsonFileStore::open(&PathBuf::from(args.need("store")?))?;
            let n = import_store(&store, &doc)?;
            println!(
                "imported {n} entr{} -> {}",
                if n == 1 { "y" } else { "ies" },
                store.path().display()
            );
            Ok(())
        }
        other => {
            bail!("unknown cache action {other:?}; expected export|import")
        }
    }
}

/// Hidden diagnostic: random vs profile-with-oracle steps on one
/// (benchmark, gpu, input) cell, plus a look at the best configs and the
/// score rank the searcher assigns them.
fn cmd_diag(args: &Args) -> Result<()> {
    use pcat::expert::{analyze, normalize_scores, react, score};
    use pcat::harness::avg_steps_to_well_performing;
    use pcat::searcher::{ProfileSearcher, RandomSearcher};

    let bench = bench_arg(args)?;
    let gpu = gpu_arg(args)?;
    let input = input_arg(args, bench.as_ref())?;
    let reps = args.num("reps", 50usize)?;
    let rec = cached_space(bench.as_ref(), &gpu, &input);
    let oracle = OracleModel::new(&rec);
    let ir = if bench.instruction_bound() { 0.5 } else { 0.7 };

    let rand = avg_steps_to_well_performing(&rec, &gpu, reps, 0, |s| {
        Box::new(RandomSearcher::new(s))
    });
    let prof = avg_steps_to_well_performing(&rec, &gpu, reps, 1, |s| {
        Box::new(ProfileSearcher::new(&oracle, ir, s))
    });
    println!(
        "{} on {} ({}): space={} wp={} random={rand:.1} profile-oracle={prof:.1} imp={:.2}x",
        bench.name(),
        gpu.name,
        input.name,
        rec.space.len(),
        rec.well_performing_count(1.1),
        rand / prof.max(1.0)
    );

    // score-rank analysis: profile the median config, see where the best
    // config lands in the resulting score distribution
    let best = rec.best_index();
    let median_idx = {
        let mut order: Vec<usize> = (0..rec.space.len()).collect();
        order.sort_by(|&a, &b| {
            rec.records[a]
                .runtime_ms
                .total_cmp(&rec.records[b].runtime_ms)
        });
        order[rec.space.len() / 2]
    };
    let counters = &rec.records[median_idx].counters;
    let b = analyze(counters, &gpu);
    let delta = react(&b, ir);
    println!("profiled median config bottlenecks (max {:.2}):", b.max());
    for (c, d) in delta.active() {
        println!("  delta {c} = {d:+.3}");
    }
    use pcat::model::TpPcModel as _;
    let pred_prof = oracle.predict(&rec.space.configs[median_idx]);
    let mut scores: Vec<f64> = rec
        .space
        .configs
        .iter()
        .map(|c| score(&delta, &pred_prof, &oracle.predict(c)))
        .collect();
    let raw_best = scores[best];
    normalize_scores(&mut scores);
    let rank = scores
        .iter()
        .filter(|&&s| s > scores[best])
        .count();
    let total_w: f64 = scores.iter().sum();
    println!(
        "best config: raw score {raw_best:.3}, rank {rank}/{} by weight, \
         p(select)={:.4}",
        rec.space.len(),
        scores[best] / total_w
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let opts = ExperimentOpts {
        reps: args.num("reps", 1000usize)?,
        time_reps: args.num("time-reps", 100usize)?,
        seed: args.num("seed", 0u64)?,
    };
    let ids: Vec<&str> = if id == "all" {
        ALL_EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let report = run_experiment(id, &opts)?;
        report.write_to(&out)?;
        println!(
            "{id}: wrote {}/{id}.md (+{} csv) in {:.1}s",
            out.display(),
            report.csvs.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}
