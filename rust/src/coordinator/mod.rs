//! L3 coordinator: the KTT-like public tuner API.
//!
//! [`Tuner`] wires a tuning space (simulated benchmark, recorded replay,
//! or the PJRT real-execution adapter) to a searcher and a budget, runs
//! the search, and reports a [`TuningResult`] with the best
//! configuration and the full trace. This is the entry point a
//! downstream user of the library touches; the experiment harness and
//! the CLI are built on it.

use std::sync::Arc;

use crate::benchmarks::{cached_space, Benchmark, Input, OnDemandRecorder};
use crate::gpusim::GpuSpec;
use crate::model::{PredictionMatrix, TpPcModel};
use crate::searcher::{
    BasinHopping, Budget, CostModel, EvalEnv, LazyProfileSearcher,
    OnDemandEnv, ProfileSearcher, RandomSearcher, ReplayEnv, Searcher,
    SearchTrace, SimulatedAnnealing, Starchart,
};
use crate::tuning::{Config, RecordedSpace};

/// Which search strategy to use.
pub enum SearcherChoice<'m> {
    Random,
    /// Profile-based with a TP→PC model and an `inst_reaction` threshold
    /// (the model is densified into a [`PredictionMatrix`] at the start
    /// of the run).
    Profile {
        model: &'m dyn TpPcModel,
        inst_reaction: f64,
    },
    /// Profile-based over a prebuilt prediction matrix shared across
    /// runs — the harness builds one matrix per (benchmark, GPU) cell
    /// and every seed-repetition scores against the same `Arc` (§Perf).
    ProfileShared {
        matrix: Arc<PredictionMatrix>,
        inst_reaction: f64,
    },
    /// Profile-based over an on-demand recorder — the large-space arm:
    /// neighbourhood-only scoring with lazily simulated predictions,
    /// for spaces too big to densify into a matrix.
    ProfileLazy {
        recorder: Arc<OnDemandRecorder>,
        inst_reaction: f64,
    },
    BasinHopping,
    Starchart,
    Annealing,
}

impl SearcherChoice<'_> {
    pub fn name(&self) -> &'static str {
        match self {
            SearcherChoice::Random => "random",
            SearcherChoice::Profile { .. }
            | SearcherChoice::ProfileShared { .. }
            | SearcherChoice::ProfileLazy { .. } => "profile",
            SearcherChoice::BasinHopping => "basin_hopping",
            SearcherChoice::Starchart => "starchart",
            SearcherChoice::Annealing => "annealing",
        }
    }
}

/// Outcome of one tuning session.
#[derive(Debug, Clone)]
pub struct TuningResult {
    pub space_name: String,
    pub searcher: &'static str,
    pub best_config: Config,
    pub best_ms: f64,
    pub trace: SearchTrace,
    /// Empirical tests performed.
    pub tests: usize,
    /// Tests run with profiling enabled.
    pub profiled_tests: usize,
    /// Total tuning cost, seconds.
    pub cost_s: f64,
}

/// The autotuner façade.
pub struct Tuner {
    env: Box<dyn EvalEnv>,
    budget: Budget,
    seed: u64,
}

impl Tuner {
    /// Tune a benchmark on a simulated GPU (records the space first —
    /// exactly the paper's replay methodology). The recording comes from
    /// the process-wide space cache, so repeated tuner construction for
    /// the same (benchmark, GPU, input) enumerates the space only once.
    pub fn simulated(
        bench: &dyn Benchmark,
        gpu: GpuSpec,
        input: &Input,
        cost: CostModel,
    ) -> Tuner {
        let rec = cached_space(bench, &gpu, input);
        Tuner::replay(rec, gpu, cost)
    }

    /// Tune over a pre-recorded space (owned, or shared via `Arc` from
    /// the cache).
    pub fn replay(
        rec: impl Into<Arc<RecordedSpace>>,
        gpu: GpuSpec,
        cost: CostModel,
    ) -> Tuner {
        Tuner {
            env: Box::new(ReplayEnv::new(rec, gpu, cost)),
            budget: Budget::tests(usize::MAX),
            seed: 0,
        }
    }

    /// Tune a large space lazily: configurations are simulated on
    /// first visit through the shared on-demand recorder, so nothing
    /// space-sized is ever materialized.
    pub fn on_demand(recorder: Arc<OnDemandRecorder>, cost: CostModel) -> Tuner {
        Tuner::over(Box::new(OnDemandEnv::new(recorder, cost)))
    }

    /// Tune over any environment (e.g. the PJRT adapter).
    pub fn over(env: Box<dyn EvalEnv>) -> Tuner {
        Tuner {
            env,
            budget: Budget::tests(usize::MAX),
            seed: 0,
        }
    }

    pub fn with_budget(mut self, budget: Budget) -> Tuner {
        self.budget = budget;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Tuner {
        self.seed = seed;
        self
    }

    pub fn space_len(&self) -> usize {
        self.env.space().len()
    }

    /// Run a search strategy to completion.
    pub fn run(&mut self, choice: SearcherChoice<'_>) -> TuningResult {
        let name = choice.name();
        let trace = match choice {
            SearcherChoice::Random => {
                RandomSearcher::new(self.seed).run(&mut *self.env, &self.budget)
            }
            SearcherChoice::Profile {
                model,
                inst_reaction,
            } => ProfileSearcher::new(model, inst_reaction, self.seed)
                .run(&mut *self.env, &self.budget),
            SearcherChoice::ProfileShared {
                matrix,
                inst_reaction,
            } => ProfileSearcher::shared(matrix, inst_reaction, self.seed)
                .run(&mut *self.env, &self.budget),
            SearcherChoice::ProfileLazy {
                recorder,
                inst_reaction,
            } => LazyProfileSearcher::new(recorder, inst_reaction, self.seed)
                .run(&mut *self.env, &self.budget),
            SearcherChoice::BasinHopping => {
                BasinHopping::new(self.seed).run(&mut *self.env, &self.budget)
            }
            SearcherChoice::Starchart => {
                Starchart::new(self.seed).run(&mut *self.env, &self.budget)
            }
            SearcherChoice::Annealing => SimulatedAnnealing::new(self.seed)
                .run(&mut *self.env, &self.budget),
        };

        let (best_idx, best_ms) = trace
            .steps
            .iter()
            .map(|s| (s.idx, s.runtime_ms))
            .fold((0, f64::INFINITY), |acc, cur| {
                if cur.1 < acc.1 {
                    cur
                } else {
                    acc
                }
            });
        TuningResult {
            space_name: self.env.space().name.clone(),
            searcher: name,
            best_config: self.env.space().config_at(best_idx),
            best_ms,
            tests: trace.len(),
            profiled_tests: trace.steps.iter().filter(|s| s.profiled).count(),
            cost_s: self.env.cost_so_far(),
            trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Coulomb;
    use crate::model::OracleModel;

    #[test]
    fn tuner_runs_random_end_to_end() {
        let mut t = Tuner::simulated(
            &Coulomb,
            GpuSpec::gtx1070(),
            &Coulomb.default_input(),
            CostModel::default(),
        )
        .with_budget(Budget::tests(50))
        .with_seed(1);
        let r = t.run(SearcherChoice::Random);
        assert_eq!(r.tests, 50);
        assert_eq!(r.searcher, "random");
        assert!(r.best_ms.is_finite());
        assert!(r.cost_s > 0.0);
        assert_eq!(r.profiled_tests, 0);
    }

    #[test]
    fn tuner_runs_profile_end_to_end() {
        let gpu = GpuSpec::gtx1070();
        let rec = cached_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        let mut t = Tuner::replay(rec, gpu, CostModel::default())
            .with_budget(Budget::tests(30))
            .with_seed(2);
        let r = t.run(SearcherChoice::Profile {
            model: &oracle,
            inst_reaction: 0.5,
        });
        assert_eq!(r.tests, 30);
        assert!(r.profiled_tests >= 4);
        assert_eq!(r.best_config.len(), 7);
    }

    #[test]
    fn shared_matrix_choice_matches_model_choice() {
        let gpu = GpuSpec::gtx1070();
        let rec = cached_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        let matrix = Arc::new(PredictionMatrix::from_recorded(&rec));
        let run = |choice: SearcherChoice<'_>| {
            Tuner::replay(Arc::clone(&rec), gpu.clone(), CostModel::default())
                .with_budget(Budget::tests(30))
                .with_seed(5)
                .run(choice)
        };
        let a = run(SearcherChoice::Profile {
            model: &oracle,
            inst_reaction: 0.5,
        });
        let b = run(SearcherChoice::ProfileShared {
            matrix,
            inst_reaction: 0.5,
        });
        assert_eq!(a.searcher, "profile");
        assert_eq!(b.searcher, "profile");
        assert_eq!(a.best_ms, b.best_ms);
        let idx = |r: &TuningResult| {
            r.trace.steps.iter().map(|s| s.idx).collect::<Vec<_>>()
        };
        assert_eq!(idx(&a), idx(&b));
    }

    #[test]
    fn tuner_runs_on_demand_end_to_end() {
        let bench = crate::benchmarks::by_name("synth-grid").unwrap();
        let recorder = crate::benchmarks::cached_recorder(
            &*bench,
            &GpuSpec::gtx1070(),
            &bench.default_input(),
        );
        let mut t =
            Tuner::on_demand(Arc::clone(&recorder), CostModel::default())
                .with_budget(Budget::tests(20))
                .with_seed(11);
        assert!(t.space_len() > 1_000_000);
        let r = t.run(SearcherChoice::ProfileLazy {
            recorder: Arc::clone(&recorder),
            inst_reaction: 0.5,
        });
        assert_eq!(r.tests, 20);
        assert_eq!(r.searcher, "profile");
        assert_eq!(r.best_config.len(), 10);
        assert!(r.best_ms.is_finite());
        // On-demand means only the visited corner of the space was
        // ever simulated.
        assert!(recorder.visited() < 10_000);
    }

    #[test]
    fn best_config_matches_best_runtime() {
        let mut t = Tuner::simulated(
            &Coulomb,
            GpuSpec::gtx750(),
            &Coulomb.default_input(),
            CostModel::default(),
        )
        .with_budget(Budget::tests(40))
        .with_seed(3);
        let r = t.run(SearcherChoice::BasinHopping);
        let best_step = r
            .trace
            .steps
            .iter()
            .min_by(|a, b| a.runtime_ms.partial_cmp(&b.runtime_ms).unwrap())
            .unwrap();
        assert_eq!(r.best_ms, best_step.runtime_ms);
    }
}
