//! L3 coordinator: the KTT-like public tuner API.
//!
//! [`Tuner`] wires a tuning space (simulated benchmark, recorded replay,
//! or the PJRT real-execution adapter) to a searcher and a budget, runs
//! the search, and reports a [`TuningResult`] with the best
//! configuration and the full trace. This is the entry point a
//! downstream user of the library touches; the experiment harness and
//! the CLI are built on it.
//!
//! Strategies are selected by [`SearcherSpec`] — lifetime-free, parsed
//! from the CLI axis syntax (`"ga:pop=20"`, `"profile+de"`), and built
//! against a [`CellCtx`] carrying the model state (a prediction matrix
//! or an on-demand recorder) that model-reading searchers score with.
//! The pre-spec `SearcherChoice` enum is gone: every construction path
//! now goes through [`SearcherSpec::build`].

use std::sync::Arc;

use crate::benchmarks::{cached_space, Benchmark, Input, OnDemandRecorder};
use crate::gpusim::GpuSpec;
use crate::searcher::{
    Budget, CellCtx, CostModel, EvalEnv, OnDemandEnv, ReplayEnv,
    SearcherSpec, SearchTrace,
};
use crate::tuning::{Config, RecordedSpace};

/// Outcome of one tuning session.
#[derive(Debug, Clone)]
pub struct TuningResult {
    pub space_name: String,
    pub searcher: &'static str,
    pub best_config: Config,
    pub best_ms: f64,
    pub trace: SearchTrace,
    /// Empirical tests performed.
    pub tests: usize,
    /// Tests run with profiling enabled.
    pub profiled_tests: usize,
    /// Total tuning cost, seconds.
    pub cost_s: f64,
}

/// The autotuner façade.
pub struct Tuner {
    env: Box<dyn EvalEnv>,
    budget: Budget,
    seed: u64,
}

impl Tuner {
    /// Tune a benchmark on a simulated GPU (records the space first —
    /// exactly the paper's replay methodology). The recording comes from
    /// the process-wide space cache, so repeated tuner construction for
    /// the same (benchmark, GPU, input) enumerates the space only once.
    pub fn simulated(
        bench: &dyn Benchmark,
        gpu: GpuSpec,
        input: &Input,
        cost: CostModel,
    ) -> Tuner {
        let rec = cached_space(bench, &gpu, input);
        Tuner::replay(rec, gpu, cost)
    }

    /// Tune over a pre-recorded space (owned, or shared via `Arc` from
    /// the cache).
    pub fn replay(
        rec: impl Into<Arc<RecordedSpace>>,
        gpu: GpuSpec,
        cost: CostModel,
    ) -> Tuner {
        Tuner {
            env: Box::new(ReplayEnv::new(rec, gpu, cost)),
            budget: Budget::tests(usize::MAX),
            seed: 0,
        }
    }

    /// Tune a large space lazily: configurations are simulated on
    /// first visit through the shared on-demand recorder, so nothing
    /// space-sized is ever materialized.
    pub fn on_demand(recorder: Arc<OnDemandRecorder>, cost: CostModel) -> Tuner {
        Tuner::over(Box::new(OnDemandEnv::new(recorder, cost)))
    }

    /// Tune over any environment (e.g. the PJRT adapter).
    pub fn over(env: Box<dyn EvalEnv>) -> Tuner {
        Tuner {
            env,
            budget: Budget::tests(usize::MAX),
            seed: 0,
        }
    }

    pub fn with_budget(mut self, budget: Budget) -> Tuner {
        self.budget = budget;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Tuner {
        self.seed = seed;
        self
    }

    pub fn space_len(&self) -> usize {
        self.env.space().len()
    }

    /// Run a search strategy to completion. The tuner's own seed
    /// overrides the context's, so `with_seed` keeps meaning what it
    /// always meant regardless of how the context was assembled.
    pub fn run(&mut self, spec: &SearcherSpec, ctx: &CellCtx) -> TuningResult {
        let mut searcher = spec.build(&ctx.clone().with_seed(self.seed));
        let name = searcher.name();
        let trace = searcher.run(&mut *self.env, &self.budget);

        let (best_idx, best_ms) = trace
            .steps
            .iter()
            .map(|s| (s.idx, s.runtime_ms))
            .fold((0, f64::INFINITY), |acc, cur| {
                if cur.1 < acc.1 {
                    cur
                } else {
                    acc
                }
            });
        TuningResult {
            space_name: self.env.space().name.clone(),
            searcher: name,
            best_config: self.env.space().config_at(best_idx),
            best_ms,
            tests: trace.len(),
            profiled_tests: trace.steps.iter().filter(|s| s.profiled).count(),
            cost_s: self.env.cost_so_far(),
            trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Coulomb;
    use crate::model::{OracleModel, PredictionMatrix};
    use crate::searcher::ModelCtx;

    fn spec(s: &str) -> SearcherSpec {
        SearcherSpec::parse(s).unwrap()
    }

    #[test]
    fn tuner_runs_random_end_to_end() {
        let mut t = Tuner::simulated(
            &Coulomb,
            GpuSpec::gtx1070(),
            &Coulomb.default_input(),
            CostModel::default(),
        )
        .with_budget(Budget::tests(50))
        .with_seed(1);
        let r = t.run(&spec("random"), &CellCtx::modelless(0));
        assert_eq!(r.tests, 50);
        assert_eq!(r.searcher, "random");
        assert!(r.best_ms.is_finite());
        assert!(r.cost_s > 0.0);
        assert_eq!(r.profiled_tests, 0);
    }

    #[test]
    fn tuner_runs_profile_end_to_end() {
        let gpu = GpuSpec::gtx1070();
        let rec = cached_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        // a borrowed model densifies into a matrix up front — the spec
        // layer is lifetime-free by design
        let ctx = CellCtx::new(
            ModelCtx::Eager {
                matrix: Arc::new(PredictionMatrix::build(&rec.space, &oracle)),
            },
            0.5,
            0,
        );
        let mut t = Tuner::replay(rec, gpu, CostModel::default())
            .with_budget(Budget::tests(30))
            .with_seed(2);
        let r = t.run(&spec("profile"), &ctx);
        assert_eq!(r.tests, 30);
        assert!(r.profiled_tests >= 4);
        assert_eq!(r.best_config.len(), 7);
    }

    #[test]
    fn densified_model_matches_recorded_matrix() {
        let gpu = GpuSpec::gtx1070();
        let rec = cached_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        let run = |ctx: CellCtx| {
            Tuner::replay(Arc::clone(&rec), gpu.clone(), CostModel::default())
                .with_budget(Budget::tests(30))
                .with_seed(5)
                .run(&spec("profile"), &ctx)
        };
        let a = run(CellCtx::new(
            ModelCtx::Eager {
                matrix: Arc::new(PredictionMatrix::build(&rec.space, &oracle)),
            },
            0.5,
            0,
        ));
        let b = run(CellCtx::new(
            ModelCtx::Eager {
                matrix: Arc::new(PredictionMatrix::from_recorded(&rec)),
            },
            0.5,
            0,
        ));
        assert_eq!(a.searcher, "profile");
        assert_eq!(b.searcher, "profile");
        assert_eq!(a.best_ms, b.best_ms);
        let idx = |r: &TuningResult| {
            r.trace.steps.iter().map(|s| s.idx).collect::<Vec<_>>()
        };
        assert_eq!(idx(&a), idx(&b));
    }

    #[test]
    fn tuner_runs_on_demand_end_to_end() {
        let bench = crate::benchmarks::by_name("synth-grid").unwrap();
        let recorder = crate::benchmarks::cached_recorder(
            &*bench,
            &GpuSpec::gtx1070(),
            &bench.default_input(),
        );
        let ctx = CellCtx::new(
            ModelCtx::Lazy {
                recorder: Arc::clone(&recorder),
            },
            0.5,
            0,
        );
        let mut t =
            Tuner::on_demand(Arc::clone(&recorder), CostModel::default())
                .with_budget(Budget::tests(20))
                .with_seed(11);
        assert!(t.space_len() > 1_000_000);
        let r = t.run(&spec("profile"), &ctx);
        assert_eq!(r.tests, 20);
        assert_eq!(r.searcher, "profile");
        assert_eq!(r.best_config.len(), 10);
        assert!(r.best_ms.is_finite());
        // On-demand means only the visited corner of the space was
        // ever simulated.
        assert!(recorder.visited() < 10_000);
    }

    #[test]
    fn best_config_matches_best_runtime() {
        let mut t = Tuner::simulated(
            &Coulomb,
            GpuSpec::gtx750(),
            &Coulomb.default_input(),
            CostModel::default(),
        )
        .with_budget(Budget::tests(40))
        .with_seed(3);
        let r = t.run(&spec("basin_hopping"), &CellCtx::modelless(0));
        let best_step = r
            .trace
            .steps
            .iter()
            .min_by(|a, b| a.runtime_ms.partial_cmp(&b.runtime_ms).unwrap())
            .unwrap();
        assert_eq!(r.best_ms, best_step.runtime_ms);
    }

    #[test]
    fn zoo_specs_run_through_the_tuner() {
        for name in ["ga", "de", "dual_annealing", "annealing", "starchart"] {
            let mut t = Tuner::simulated(
                &Coulomb,
                GpuSpec::gtx1070(),
                &Coulomb.default_input(),
                CostModel::default(),
            )
            .with_budget(Budget::tests(25))
            .with_seed(7);
            let r = t.run(&spec(name), &CellCtx::modelless(0));
            assert_eq!(r.tests, 25, "{name}");
            assert!(r.best_ms.is_finite(), "{name}");
        }
    }
}
