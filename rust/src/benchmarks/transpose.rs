//! Matrix transposition (Table 2: 8 dims, 1,784 configs).
//!
//! The classic out-of-place transpose: reads are coalesced, writes are
//! transposed. Staging through a shared-memory tile re-coalesces the
//! writes; padding avoids shared bank conflicts; diagonal block
//! reordering avoids DRAM partition camping. No floating-point work at
//! all — the kernel stresses LDST/INT issue and the memory hierarchy,
//! exercising the expert system's non-FP paths.

use super::{Benchmark, Input};
use crate::gpusim::Workload;
use crate::tuning::{Config, ParamDef, Space};

pub struct Transpose;

impl Benchmark for Transpose {
    fn name(&self) -> &'static str {
        "transpose"
    }

    fn space(&self) -> Space {
        let params = vec![
            ParamDef::new("TILE_X", &[8, 16, 32, 64]),
            ParamDef::new("TILE_Y", &[8, 16, 32, 64]),
            ParamDef::new("WPT_X", &[1, 2, 4]),
            ParamDef::new("WPT_Y", &[1, 2, 4]),
            ParamDef::new("USE_SHARED", &[0, 1]),
            ParamDef::new("PADDING", &[0, 1]),
            ParamDef::new("DIAGONAL", &[0, 1]),
            ParamDef::new("VECTOR", &[1, 2, 4]),
        ];
        Space::enumerate("transpose", params, |v| {
            let (tx, ty, wx, wy, sh, pad, _diag, vec) =
                (v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]);
            let threads = (tx / wx) * (ty / wy);
            tx % (wx * vec) == 0
                && ty % wy == 0
                && (32..=1024).contains(&threads)
                // padding & conflicts only meaningful with shared tiles
                && (sh == 1 || pad == 0)
                && (sh == 1 || vec <= 2) // transposed vector stores need staging
        })
    }

    fn default_input(&self) -> Input {
        // §4.6: 8192 x 8192
        Input::new("8192x8192", &[8192, 8192])
    }

    /// §4.6 variants: the small square fits mostly in L2 (the
    /// partition-camping and write-scatter penalties lose their bite),
    /// the 4:1 rectangle changes which tile shapes divide the matrix —
    /// both move the optimum away from the default's.
    fn inputs(&self) -> Vec<Input> {
        vec![
            self.default_input(),
            Input::new("2048x2048", &[2048, 2048]),
            Input::new("16384x4096", &[16384, 4096]),
        ]
    }

    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
        let tx = space.value(cfg, "TILE_X") as f64;
        let ty = space.value(cfg, "TILE_Y") as f64;
        let wx = space.value(cfg, "WPT_X") as f64;
        let wy = space.value(cfg, "WPT_Y") as f64;
        let shared = space.value(cfg, "USE_SHARED") as f64;
        let pad = space.value(cfg, "PADDING") as f64;
        let diag = space.value(cfg, "DIAGONAL") as f64;
        let vec = space.value(cfg, "VECTOR") as f64;

        let rows = input.dim(0);
        let cols = input.dim(1);
        let elems = rows * cols;
        let bytes = elems * 4.0;

        let block_size = (tx / wx) * (ty / wy);
        let threads = elems / (wx * wy);
        let elems_per_thread = wx * wy;

        // --- instruction mix (no FP at all) ----------------------------
        let int = 14.0 + elems_per_thread * (3.0 / vec) + if diag > 0.5 { 6.0 } else { 0.0 };
        let ldst = elems_per_thread * 2.0 / vec
            + shared * elems_per_thread * 2.0 / vec;
        let cont = 2.0 + elems_per_thread / vec;
        let misc = 2.0;

        // --- memory traffic ---------------------------------------------
        // coalescing width: tiles narrower than a 128-byte cache line
        // fetch whole lines but use only tile_x*4 bytes of each.
        let line_waste = (128.0 / (tx * 4.0 / vec)).max(1.0).min(4.0);
        // reads are coalesced; writes: without shared staging each warp
        // scatters across 32 cache lines -> 8x sector inflation.
        let gread = bytes * line_waste;
        let write_inflation = if shared > 0.5 {
            1.0
        } else {
            // vector width worsens scatter granularity slightly
            8.0 * (1.0 + 0.1 * (vec - 1.0))
        };
        let gwrite = bytes * write_inflation;

        // shared tile traffic + bank conflicts when unpadded and the
        // tile stride hits the 32-bank period.
        let (shr_ld, shr_st) = if shared > 0.5 {
            let conflict = if pad > 0.5 {
                1.0
            } else if (tx as i64) % 32 == 0 {
                8.0 // full-period conflicts on the transposed read
            } else if (tx as i64) % 16 == 0 {
                4.0
            } else {
                1.5
            };
            (bytes * conflict, bytes)
        } else {
            (0.0, 0.0)
        };

        // partition camping: without diagonal reordering, column-order
        // blocks hammer one DRAM partition -> effective-bandwidth loss
        // modeled as extra sector traffic.
        let camping = if diag > 0.5 { 1.0 } else { 1.18 };

        Workload {
            threads,
            block_size,
            regs_per_thread: 12.0 + 2.0 * elems_per_thread + 2.0 * vec,
            shared_bytes_per_block: shared
                * (tx + pad * vec) * ty * 4.0,
            int: int * threads,
            ldst: ldst * threads,
            cont: cont * threads,
            misc: misc * threads,
            gread: gread * camping,
            gwrite: gwrite * camping,
            tex_fraction: 0.2,
            tex_footprint_per_sm: tx * ty * 4.0,
            l2_footprint: bytes * 2.0,
            shared_load_bytes: shr_ld,
            shared_store_bytes: shr_st,
            divergence: 0.02,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{simulate, GpuSpec};

    #[test]
    fn space_dims_and_size() {
        let s = Transpose.space();
        assert_eq!(s.dims(), 8);
        assert!((700..=4000).contains(&s.len()), "{}", s.len());
    }

    #[test]
    fn no_fp_work() {
        let s = Transpose.space();
        let w = Transpose.workload(&s, &s.configs[0], &Transpose.default_input());
        assert_eq!(w.fp32, 0.0);
        assert_eq!(w.fp64, 0.0);
        assert!(w.ldst > 0.0);
    }

    #[test]
    fn shared_staging_beats_naive_writes() {
        let s = Transpose.space();
        let input = Transpose.default_input();
        let gpu = GpuSpec::gtx1070();
        let pick = |sh: i64, pad: i64| {
            s.configs
                .iter()
                .find(|c| {
                    s.value(c, "USE_SHARED") == sh
                        && s.value(c, "PADDING") == pad
                        && s.value(c, "TILE_X") == 32
                        && s.value(c, "TILE_Y") == 32
                        && s.value(c, "WPT_X") == 1
                        && s.value(c, "WPT_Y") == 4
                        && s.value(c, "DIAGONAL") == 1
                        && s.value(c, "VECTOR") == 1
                })
                .unwrap()
        };
        let naive = simulate(&gpu, &Transpose.workload(&s, pick(0, 0), &input));
        let tiled = simulate(&gpu, &Transpose.workload(&s, pick(1, 1), &input));
        assert!(tiled.runtime_ms < naive.runtime_ms);
    }

    #[test]
    fn padding_fixes_bank_conflicts() {
        let s = Transpose.space();
        let input = Transpose.default_input();
        let find = |pad: i64| {
            s.configs
                .iter()
                .find(|c| {
                    s.value(c, "USE_SHARED") == 1
                        && s.value(c, "PADDING") == pad
                        && s.value(c, "TILE_X") == 32
                        && s.value(c, "TILE_Y") == 32
                        && s.value(c, "WPT_X") == 1
                        && s.value(c, "WPT_Y") == 1
                        && s.value(c, "DIAGONAL") == 1
                        && s.value(c, "VECTOR") == 1
                })
                .unwrap()
        };
        let unpadded = Transpose.workload(&s, find(0), &input);
        let padded = Transpose.workload(&s, find(1), &input);
        assert!(unpadded.shared_load_bytes > 4.0 * padded.shared_load_bytes);
    }

    #[test]
    fn bytes_scale_with_input() {
        let s = Transpose.space();
        let small = Transpose.workload(
            &s,
            &s.configs[0],
            &Input::new("s", &[1024, 1024]),
        );
        let large = Transpose.workload(
            &s,
            &s.configs[0],
            &Input::new("l", &[4096, 4096]),
        );
        assert!((large.gread / small.gread - 16.0).abs() < 1e-9);
    }
}
