//! 2D convolution, 15×15 filter (Table 2: 10 dims, 3,928 configs).
//!
//! The CLTune-style convolution space: thread-block shape, per-thread
//! work, staging of the input tile and/or filter coefficients in local
//! memory, loop unrolling, vector loads and tile padding. Heavy
//! constraint pruning (the paper notes only 0.025 % of the cross product
//! survives in their space; ours prunes less aggressively but the same
//! way — divisibility + resource sanity).

use super::{Benchmark, Input};
use crate::gpusim::Workload;
use crate::tuning::{Config, ParamDef, Space};

/// Filter half-size: 15×15 taps.
const FILTER: f64 = 15.0;

pub struct Convolution;

impl Benchmark for Convolution {
    fn name(&self) -> &'static str {
        "convolution"
    }

    fn space(&self) -> Space {
        let params = vec![
            ParamDef::new("TBX", &[8, 16, 32, 64]),
            ParamDef::new("TBY", &[8, 16, 32]),
            ParamDef::new("WPTX", &[1, 2, 4]),
            ParamDef::new("WPTY", &[1, 2, 4]),
            ParamDef::new("LOCAL", &[0, 1, 2]),
            ParamDef::new("CONST_FILTER", &[0, 1]),
            ParamDef::new("UNROLL", &[1, 3, 5, 15]),
            ParamDef::new("PADDING", &[0, 1]),
            ParamDef::new("VECTOR", &[1, 2, 4]),
            ParamDef::new("REORDER", &[0, 1]),
        ];
        Space::enumerate("convolution", params, |v| {
            let (tbx, tby, wptx, wpty, local, _cf, _unroll, pad, vec, _ro) = (
                v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7], v[8], v[9],
            );
            let block = tbx * tby;
            let tile_x = tbx * wptx;
            let tile_y = tby * wpty;
            (64..=512).contains(&block)
                && tbx % vec == 0
                && vec <= wptx
                && wptx * wpty <= 8
                && (local == 2 || pad == 0) // padding only with tile staging
                // staged input tile must fit 48 KB of shared memory
                && (local != 2
                    || ((tile_x + FILTER as i64 - 1 + pad)
                        * (tile_y + FILTER as i64 - 1)
                        * 4)
                        <= 48 * 1024
                )
        })
    }

    fn default_input(&self) -> Input {
        // §4.6: 4096×4096 image
        Input::new("4096x4096", &[4096, 4096])
    }

    /// §4.6 variants: the small square image cuts the thread count 16×
    /// (parallelism starts to matter against the per-thread tile work),
    /// and the wide-skewed image stretches each tile row's L2 footprint
    /// (`w_img × (tile_y + filter)`), shifting pressure toward the
    /// memory hierarchy.
    fn inputs(&self) -> Vec<Input> {
        vec![
            self.default_input(),
            Input::new("1024x1024", &[1024, 1024]),
            Input::new("16384x512", &[16384, 512]),
        ]
    }

    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
        let tbx = space.value(cfg, "TBX") as f64;
        let tby = space.value(cfg, "TBY") as f64;
        let wptx = space.value(cfg, "WPTX") as f64;
        let wpty = space.value(cfg, "WPTY") as f64;
        let local = space.value(cfg, "LOCAL") as f64;
        let cf = space.value(cfg, "CONST_FILTER") as f64;
        let unroll = space.value(cfg, "UNROLL") as f64;
        let pad = space.value(cfg, "PADDING") as f64;
        let vec = space.value(cfg, "VECTOR") as f64;
        let reorder = space.value(cfg, "REORDER") as f64;

        let w_img = input.dim(0);
        let h_img = input.dim(1);
        let outputs = w_img * h_img;
        let per_thread = wptx * wpty;
        let threads = outputs / per_thread;
        let block_size = tbx * tby;
        let blocks = threads / block_size;

        let taps = FILTER * FILTER;

        // --- per-thread instructions -------------------------------------
        let fp32 = 2.0 * taps * per_thread;
        let int = 12.0
            + taps * per_thread * (1.2 / unroll + 0.4 / vec)
            + reorder * 8.0;
        let cont = (FILTER / unroll) * FILTER + 4.0;
        let ldst = taps * per_thread / vec
            + cf * 0.0 // constant-cache filter loads bypass LSU accounting
            + (1.0 - cf) * taps * 0.2;
        let misc = if local > 0.5 { 4.0 } else { 0.0 };

        // --- registers -----------------------------------------------------
        let regs = 16.0
            + per_thread * (2.0 + 0.15 * unroll)
            + 2.0 * vec
            + if local > 1.5 { 6.0 } else { 0.0 };

        // --- memory traffic -------------------------------------------------
        let tile_x = tbx * wptx;
        let tile_y = tby * wpty;
        let halo_tile = (tile_x + FILTER - 1.0) * (tile_y + FILTER - 1.0);
        let gread = if local > 1.5 {
            // input tile staged once per block
            blocks * halo_tile * 4.0
        } else {
            // direct reads: every tap per output issues an L1tex request;
            // spatial locality within the warp absorbs roughly half.
            threads * taps * per_thread * 4.0 / vec * 0.5
        } + (1.0 - cf) * blocks * taps * 4.0; // filter reloads
        let gwrite = outputs * 4.0;

        let (shr_ld, shr_st, shr_bytes) = if local > 1.5 {
            let conflict = if pad > 0.5 { 1.0 } else { 2.0 };
            (
                threads as f64 * taps * per_thread * 4.0 * 0.5 * conflict,
                blocks * halo_tile * 4.0,
                (tile_x + FILTER - 1.0 + pad) * (tile_y + FILTER - 1.0) * 4.0,
            )
        } else if local > 0.5 {
            // filter in shared memory
            (threads * taps * 4.0 * 0.3, blocks * taps * 4.0, taps * 4.0)
        } else {
            (0.0, 0.0, 0.0)
        };

        Workload {
            threads,
            block_size,
            regs_per_thread: regs,
            shared_bytes_per_block: shr_bytes,
            fp32: fp32 * threads,
            int: int * threads,
            cont: cont * threads,
            ldst: ldst * threads,
            misc: misc * threads,
            bconv: 2.0 * threads,
            gread,
            gwrite,
            tex_fraction: if local > 1.5 { 0.3 } else { 0.85 },
            tex_footprint_per_sm: halo_tile * 4.0 + cf * taps * 4.0,
            l2_footprint: (w_img * (tile_y + FILTER)) * 4.0,
            shared_load_bytes: shr_ld,
            shared_store_bytes: shr_st,
            divergence: 0.03 + reorder * 0.01,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::record_space;
    use crate::gpusim::GpuSpec;

    #[test]
    fn space_dims_and_size() {
        let s = Convolution.space();
        assert_eq!(s.dims(), 10);
        assert!((1500..=9000).contains(&s.len()), "{}", s.len());
    }

    #[test]
    fn shared_tile_fits_constraint() {
        let s = Convolution.space();
        for c in s.configs.iter().step_by(7) {
            if s.value(c, "LOCAL") == 2 {
                let tile_x = s.value(c, "TBX") * s.value(c, "WPTX");
                let tile_y = s.value(c, "TBY") * s.value(c, "WPTY");
                let bytes = (tile_x + 14 + s.value(c, "PADDING"))
                    * (tile_y + 14)
                    * 4;
                assert!(bytes <= 48 * 1024);
            }
        }
    }

    #[test]
    fn staging_cuts_global_reads() {
        let s = Convolution.space();
        let input = Convolution.default_input();
        let find = |local: i64| {
            s.configs
                .iter()
                .find(|c| {
                    s.value(c, "LOCAL") == local
                        && s.value(c, "TBX") == 32
                        && s.value(c, "TBY") == 8
                        && s.value(c, "WPTX") == 2
                        && s.value(c, "WPTY") == 2
                        && s.value(c, "VECTOR") == 1
                        && s.value(c, "CONST_FILTER") == 1
                        && s.value(c, "UNROLL") == 5
                        && s.value(c, "PADDING") == 0
                        && s.value(c, "REORDER") == 0
                })
                .unwrap()
        };
        let direct = Convolution.workload(&s, find(0), &input);
        let staged = Convolution.workload(&s, find(2), &input);
        assert!(staged.gread < direct.gread);
    }

    #[test]
    fn hard_space_has_few_well_performing_configs() {
        // Table 4: convolution is the hardest space for random search.
        let rec = record_space(
            &Convolution,
            &GpuSpec::gtx1070(),
            &Convolution.default_input(),
        );
        let frac =
            rec.well_performing_count(1.1) as f64 / rec.space.len() as f64;
        assert!(frac < 0.08, "well-performing fraction {frac}");
    }
}
