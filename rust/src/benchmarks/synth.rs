//! Synthetic ≥1M-configuration benchmark — the large-space stress
//! fixture behind the on-demand recording path.
//!
//! Not part of any paper experiment: the paper's largest space is
//! GEMM-full (205k configs), but the follow-up tuning literature
//! evaluates on 10⁵–10⁶+ spaces, and the serve-heavy-traffic north star
//! needs the architecture to hold at that scale. `synth-grid` is a
//! GEMM-like tiled kernel model over 10 four-valued parameters — a full
//! cross product of exactly 4¹⁰ = 1,048,576 configurations, stored
//! *implicitly* (odometer decode, zero per-config memory) so the lazy
//! tuning path can be exercised and benchmarked without ever
//! materializing the space.

use super::{Benchmark, Input, RecordingMode};
use crate::gpusim::Workload;
use crate::tuning::{Config, ParamDef, Space};

pub struct SynthGrid;

impl Benchmark for SynthGrid {
    fn name(&self) -> &'static str {
        "synth-grid"
    }

    fn space(&self) -> Space {
        // 10 params × 4 values, unconstrained: 4^10 = 1,048,576.
        let params = vec![
            ParamDef::new("BLOCK_X", &[8, 16, 32, 64]),
            ParamDef::new("BLOCK_Y", &[2, 4, 8, 16]),
            ParamDef::new("TILE_M", &[1, 2, 4, 8]),
            ParamDef::new("TILE_N", &[1, 2, 4, 8]),
            ParamDef::new("UNROLL", &[1, 2, 4, 8]),
            ParamDef::new("VECTOR", &[1, 2, 4, 8]),
            ParamDef::new("PREFETCH", &[0, 1, 2, 4]),
            ParamDef::new("USE_SMEM", &[0, 1, 2, 3]),
            ParamDef::new("SPLIT_K", &[1, 2, 4, 8]),
            ParamDef::new("SWIZZLE", &[0, 1, 2, 3]),
        ];
        Space::enumerate_implicit("synth-grid", params)
    }

    fn default_input(&self) -> Input {
        Input::new("4096", &[4096])
    }

    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
        let bx = space.value(cfg, "BLOCK_X") as f64;
        let by = space.value(cfg, "BLOCK_Y") as f64;
        let tm = space.value(cfg, "TILE_M") as f64;
        let tn = space.value(cfg, "TILE_N") as f64;
        let unroll = space.value(cfg, "UNROLL") as f64;
        let vec = space.value(cfg, "VECTOR") as f64;
        let pf = space.value(cfg, "PREFETCH") as f64;
        let smem = space.value(cfg, "USE_SMEM") as f64;
        let sk = space.value(cfg, "SPLIT_K") as f64;
        let sw = space.value(cfg, "SWIZZLE") as f64;

        let n = input.dim(0);
        let block_size = bx * by;
        let tile = tm * tn;
        // each thread owns a TILE_M×TILE_N output tile; SPLIT_K
        // parallelizes the reduction at the cost of a merge pass
        let threads = (n * n / tile).max(1.0) * sk;

        // inner-product work per thread: 2 flops per MAC over n/SPLIT_K
        // k-steps, amortized by vector loads and unrolling
        let k_steps = n / sk;
        let fp32 = 2.0 * k_steps * tile;
        let int = 12.0 + k_steps * (2.0 / unroll + 2.0 / vec) + 4.0 * sw;
        let cont = k_steps / unroll + 8.0;
        let ldst = k_steps * (tm + tn) / vec + tile;
        let misc = 2.0 + pf;
        let bconv = 2.0;

        // registers: accumulator tile + staging for vector loads and
        // prefetch double-buffers — the spill cliff lives up here
        let regs = 14.0 + 2.0 * tile + 2.0 * vec + 3.0 * pf + smem;

        // memory traffic: operand reads shrink with shared-memory
        // blocking, writes grow with SPLIT_K partial sums
        let reuse = 1.0 + smem * (tm + tn) / 4.0;
        let gread = threads * k_steps * (tm + tn) * 4.0 / reuse / vec.sqrt();
        let gwrite = n * n * 4.0 * sk;

        let warp_fill = (block_size / 32.0).min(1.0);
        let divergence = (1.0 - warp_fill) * 0.8 + 0.02;

        Workload {
            threads,
            block_size,
            regs_per_thread: regs,
            fp32: fp32 * threads,
            int: int * threads,
            cont: cont * threads,
            ldst: ldst * threads,
            misc: misc * threads,
            bconv: bconv * threads,
            gread,
            gwrite,
            tex_fraction: if smem > 0.5 { 0.3 } else { 0.7 },
            tex_footprint_per_sm: n * 4.0 * (tm + tn),
            l2_footprint: n * n * 4.0 / reuse,
            divergence,
            ..Default::default()
        }
    }

    fn recording_mode(&self) -> RecordingMode {
        RecordingMode::OnDemand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{simulate, GpuSpec};

    #[test]
    fn space_is_implicit_and_exceeds_a_million() {
        let s = SynthGrid.space();
        assert!(s.is_implicit());
        assert_eq!(s.len(), 1 << 20);
        assert_eq!(s.dims(), 10);
        assert!(s.configs.is_empty(), "must not materialize configs");
    }

    #[test]
    fn sampled_workloads_are_sane() {
        let s = SynthGrid.space();
        let input = SynthGrid.default_input();
        let gpu = GpuSpec::gtx1070();
        // a deterministic scatter across the full index range
        for i in (0..s.len()).step_by(65_537) {
            let cfg = s.config_at(i);
            let w = SynthGrid.workload(&s, &cfg, &input);
            assert!(w.threads > 0.0);
            assert!(w.total_inst() > 0.0);
            let sim = simulate(&gpu, &w);
            assert!(
                sim.runtime_ms.is_finite() && sim.runtime_ms > 0.0,
                "bad runtime at {i}"
            );
        }
    }

    #[test]
    fn configs_actually_differ_in_performance() {
        // the space must be non-trivial for searchers: runtimes at
        // scattered indices should span a real range
        let s = SynthGrid.space();
        let input = SynthGrid.default_input();
        let gpu = GpuSpec::rtx2080();
        let mut lo = f64::MAX;
        let mut hi = 0.0f64;
        for i in (0..s.len()).step_by(131_071) {
            let cfg = s.config_at(i);
            let t = simulate(&gpu, &SynthGrid.workload(&s, &cfg, &input))
                .runtime_ms;
            lo = lo.min(t);
            hi = hi.max(t);
        }
        assert!(hi / lo > 2.0, "runtime spread too flat: {lo}..{hi}");
    }
}
