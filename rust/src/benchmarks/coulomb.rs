//! Direct Coulomb Summation 3D (paper §2, Listing 1; Table 2: 7 dims,
//! 210 configs).
//!
//! Tuning parameters mirror the KTT CUDA benchmark:
//! * `BLOCK_X`, `BLOCK_Y` — thread-block shape over the XY grid plane;
//! * `Z_ITER` — thread coarsening along Z (the paper's `Z_ITERATIONS`):
//!   amortizes atom loads and the invariant `dx²+dy²` across Z slices at
//!   the cost of registers and parallelism;
//! * `INNER_UNROLL` — unroll factor of the atom loop (fewer branches,
//!   more registers);
//! * `USE_SOA` — structure-of-arrays atom layout (better coalescing /
//!   read-path locality);
//! * `VECTOR` — vector width of atom loads (fewer ld/st instructions).

use super::{Benchmark, Input};
use crate::gpusim::Workload;
use crate::tuning::{Config, ParamDef, Space};

pub struct Coulomb;

impl Benchmark for Coulomb {
    fn name(&self) -> &'static str {
        "coulomb"
    }

    fn space(&self) -> Space {
        let params = vec![
            ParamDef::new("BLOCK_X", &[4, 8, 16, 32]),
            ParamDef::new("BLOCK_Y", &[1, 2, 4, 8]),
            ParamDef::new("Z_ITER", &[1, 2, 4, 8, 16, 32]),
            ParamDef::new("INNER_UNROLL", &[1, 2, 4]),
            ParamDef::new("USE_SOA", &[0, 1]),
            ParamDef::new("VECTOR", &[1, 2]),
            ParamDef::new("SLICE_FACTOR", &[1, 2]),
        ];
        Space::enumerate("coulomb", params, |v| {
            let (bx, by, zi, unroll, _soa, vec, slice) =
                (v[0], v[1], v[2], v[3], v[4], v[5], v[6]);
            let block = bx * by;
            // sane CUDA launch shapes (the paper's spaces avoid sub-warp
            // blocks and register-explosion corners)
            (64..=512).contains(&block)
                && zi * unroll <= 64
                && unroll <= zi
                && (vec == 1 || zi >= 2) // vector loads only pay off coarsened
                && slice <= zi
        })
    }

    fn default_input(&self) -> Input {
        // §4.6: grid 256^3, 256 atoms
        Input::new("grid256_atoms256", &[256, 256])
    }

    /// §2.3's two contrasting workloads next to the default: few atoms
    /// shrink the per-thread loop (loop overhead and parallelism take
    /// over from FP throughput), while the tiny-grid/many-atoms
    /// instance inverts the balance entirely — the bottleneck shift
    /// the input-portability experiments need.
    fn inputs(&self) -> Vec<Input> {
        vec![
            self.default_input(),
            Input::new("grid256_atoms64", &[256, 64]),
            Input::new("grid25_atoms4096", &[25, 4096]),
        ]
    }

    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
        let bx = space.value(cfg, "BLOCK_X") as f64;
        let by = space.value(cfg, "BLOCK_Y") as f64;
        let zi = space.value(cfg, "Z_ITER") as f64;
        let unroll = space.value(cfg, "INNER_UNROLL") as f64;
        let soa = space.value(cfg, "USE_SOA") as f64;
        let vec = space.value(cfg, "VECTOR") as f64;
        let slice = space.value(cfg, "SLICE_FACTOR") as f64;

        let g = input.dim(0); // grid size per dimension
        let n = input.dim(1); // atoms
        let points = g * g * g;
        let threads = (points / zi).max(1.0);
        let block_size = bx * by;

        // --- per-thread instruction counts ---------------------------
        // per atom: 5 invariant flops (dx,dy,dz diffs + dx²+dy²), then
        // per coarsened z point: rsqrt (1) + fma (2) + dz update (1).
        let fp32 = n * (5.0 + 4.0 * zi) + 3.0 * zi;
        // index arithmetic + loop counters; unrolling divides loop
        // overhead, vector loads halve address math.
        let int = 18.0 + n * (2.0 / unroll + 2.0 / vec) + 2.0 * zi;
        let cont = n / unroll + zi;
        let ldst = n * 4.0 / vec + zi;
        let misc = n * 1.0 * zi * 0.25; // rsqrt special-function slots
        let bconv = 4.0;

        // --- registers -------------------------------------------------
        // energyValue[Z_ITER] array + unroll-duplicated live ranges
        // (unrolling the atom loop keeps `unroll` atoms' worth of dX/dY/dZ
        // live per coarsened Z point) + vector load staging. At high
        // zi×unroll this crosses the 255-register ceiling and spills —
        // the LOC_O signal the expert system reacts to.
        let regs =
            16.0 + zi * (1.2 + 1.6 * unroll) + 3.0 * vec + 2.0 * slice;

        // --- memory traffic ---------------------------------------------
        // atoms are broadcast per warp: requests per warp per pass.
        let warps = threads / 32.0;
        let atom_bytes = if soa > 0.5 { 12.0 + 4.0 } else { 16.0 };
        // SoA layout coalesces perfectly; AoS wastes part of each sector.
        let read_eff = if soa > 0.5 { 1.0 } else { 1.25 };
        let gread = warps * n * atom_bytes * read_eff / vec.sqrt();
        let gwrite = points * 4.0;

        // boundary handling + partial warps
        let warp_fill = (block_size / 32.0).min(1.0);
        let divergence = (1.0 - warp_fill) * 0.9 + 0.02;

        let mut w = Workload {
            threads,
            block_size,
            regs_per_thread: regs,
            fp32: fp32 * threads,
            int: int * threads,
            cont: cont * threads,
            ldst: ldst * threads,
            misc: misc * threads,
            bconv: bconv * threads,
            gread,
            gwrite,
            tex_fraction: if soa > 0.5 { 0.95 } else { 0.75 },
            tex_footprint_per_sm: n * atom_bytes,
            l2_footprint: n * atom_bytes + gwrite * 0.1,
            divergence,
            ..Default::default()
        };
        // SLICE_FACTOR: trades one extra pass over atoms for smaller
        // per-pass footprint (a blocking knob for huge atom counts).
        if slice > 1.0 {
            w.tex_footprint_per_sm /= slice;
            w.int += 8.0 * threads;
            w.cont += (n / unroll) * threads * (slice - 1.0) * 0.02;
        }
        w
    }

    fn instruction_bound(&self) -> bool {
        true // the paper treats Coulomb as compute-bound
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{simulate, GpuSpec};

    #[test]
    fn space_has_paper_dims() {
        let s = Coulomb.space();
        assert_eq!(s.dims(), 7);
        assert!(s.len() >= 100, "{}", s.len());
    }

    #[test]
    fn constraints_hold_everywhere() {
        let s = Coulomb.space();
        for c in &s.configs {
            let block = s.value(c, "BLOCK_X") * s.value(c, "BLOCK_Y");
            assert!((64..=512).contains(&block));
            assert!(s.value(c, "Z_ITER") * s.value(c, "INNER_UNROLL") <= 64);
            assert!(s.value(c, "INNER_UNROLL") <= s.value(c, "Z_ITER"));
        }
    }

    #[test]
    fn coarsening_reduces_fp32_like_fig1() {
        // Figure 1: FP operations fall monotonically with coarsening.
        let s = Coulomb.space();
        let input = Coulomb.default_input();
        let mut prev = f64::MAX;
        for zi in [1, 2, 4, 8, 16, 32] {
            let cfg = s
                .configs
                .iter()
                .find(|c| {
                    s.value(c, "Z_ITER") == zi
                        && s.value(c, "BLOCK_X") == 16
                        && s.value(c, "BLOCK_Y") == 8
                        && s.value(c, "INNER_UNROLL") == 1
                        && s.value(c, "USE_SOA") == 1
                        && s.value(c, "VECTOR") == 1
                        && s.value(c, "SLICE_FACTOR") == 1
                })
                .unwrap();
            let w = Coulomb.workload(&s, cfg, &input);
            assert!(w.fp32 < prev, "zi={zi}");
            prev = w.fp32;
        }
    }

    #[test]
    fn extreme_coarsening_lowers_occupancy() {
        let s = Coulomb.space();
        let input = Coulomb.default_input();
        let gpu = GpuSpec::gtx1070();
        let pick = |zi: i64| {
            s.configs
                .iter()
                .find(|c| {
                    s.value(c, "Z_ITER") == zi
                        && s.value(c, "INNER_UNROLL") == 1
                        && s.value(c, "BLOCK_X") == 16
                        && s.value(c, "BLOCK_Y") == 8
                        && s.value(c, "USE_SOA") == 1
                        && s.value(c, "VECTOR") == 1
                        && s.value(c, "SLICE_FACTOR") == 1
                })
                .unwrap()
        };
        let low = simulate(&gpu, &Coulomb.workload(&s, pick(1), &input));
        let high = simulate(&gpu, &Coulomb.workload(&s, pick(16), &input));
        assert!(high.occupancy.occupancy < low.occupancy.occupancy);
    }

    #[test]
    fn best_zi_is_interior() {
        // the paper's §2.3 narrative: neither zi=1 nor zi=32 is optimal
        // on the default input/GPU — the sweet spot is interior.
        let rec = super::super::record_space(
            &Coulomb,
            &GpuSpec::gtx1070(),
            &Coulomb.default_input(),
        );
        let best = &rec.space.configs[rec.best_index()];
        let zi = rec.space.value(best, "Z_ITER");
        assert!(zi > 1 && zi < 32, "best Z_ITER={zi}");
    }
}
