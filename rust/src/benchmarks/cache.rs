//! Process-wide cache of exhaustively recorded tuning spaces.
//!
//! Recording a space is by far the most expensive primitive in the
//! harness (|space| simulator evaluations), and the paper's evaluation
//! replays the *same* `(benchmark, GPU, input)` spaces across dozens of
//! tables, figures and repetition loops. The cache guarantees each such
//! space is enumerated and simulated **exactly once per process**, no
//! matter how many threads ask for it concurrently: the map lock is
//! held only to hand out a per-key [`OnceLock`] slot, so distinct
//! spaces record in parallel while racing requests for the same space
//! block on one recording.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::{record_space, Benchmark, Input};
use crate::gpusim::GpuSpec;
use crate::tuning::RecordedSpace;

/// Cache key: benchmark name, the GPU's full spec (all fields are
/// public, so a caller may hand in a registry-named spec with tweaked
/// parameters — e.g. a bandwidth sweep — and must not receive the
/// stock recording), and input name + dimensions (two inputs may share
/// a display name but differ in size).
type SpaceKey = (String, String, String);

type Slot = Arc<OnceLock<Arc<RecordedSpace>>>;

static CACHE: OnceLock<Mutex<HashMap<SpaceKey, Slot>>> = OnceLock::new();
/// How many times each key was actually recorded (test instrumentation
/// for the exactly-once guarantee).
static RECORDINGS: OnceLock<Mutex<HashMap<SpaceKey, usize>>> = OnceLock::new();

fn key_of(bench: &dyn Benchmark, gpu: &GpuSpec, input: &Input) -> SpaceKey {
    (
        bench.name().to_string(),
        format!("{gpu:?}"),
        format!("{}:{:?}", input.name, input.dims),
    )
}

/// Fetch the recorded space for `(bench, gpu, input)`, recording it on
/// first use. Concurrent callers for the same key all receive the same
/// `Arc`; the recording itself runs exactly once.
pub fn cached_space(
    bench: &dyn Benchmark,
    gpu: &GpuSpec,
    input: &Input,
) -> Arc<RecordedSpace> {
    let key = key_of(bench, gpu, input);
    let slot: Slot = {
        let mut map = CACHE
            .get_or_init(Default::default)
            .lock()
            .expect("space cache poisoned");
        map.entry(key.clone()).or_default().clone()
    };
    slot.get_or_init(|| {
        *RECORDINGS
            .get_or_init(Default::default)
            .lock()
            .expect("recording counter poisoned")
            .entry(key.clone())
            .or_insert(0) += 1;
        Arc::new(record_space(bench, gpu, input))
    })
    .clone()
}

/// Number of times this `(bench, gpu, input)` space has been recorded
/// in this process — `1` after any number of [`cached_space`] calls.
pub fn recorded_count(bench: &dyn Benchmark, gpu: &GpuSpec, input: &Input) -> usize {
    RECORDINGS
        .get_or_init(Default::default)
        .lock()
        .expect("recording counter poisoned")
        .get(&key_of(bench, gpu, input))
        .copied()
        .unwrap_or(0)
}

/// Number of distinct spaces currently cached.
pub fn cached_spaces() -> usize {
    CACHE
        .get_or_init(Default::default)
        .lock()
        .expect("space cache poisoned")
        .len()
}

#[cfg(test)]
mod tests {
    use super::super::Coulomb;
    use super::*;

    #[test]
    fn same_key_returns_same_arc_and_records_once() {
        let gpu = GpuSpec::gtx750();
        let input = Input::new("cache-unit-test", &[32, 64]);
        let a = cached_space(&Coulomb, &gpu, &input);
        let b = cached_space(&Coulomb, &gpu, &input);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(recorded_count(&Coulomb, &gpu, &input), 1);
    }

    #[test]
    fn different_inputs_are_distinct_entries() {
        let gpu = GpuSpec::gtx750();
        let a = cached_space(&Coulomb, &gpu, &Input::new("cache-ua", &[32, 64]));
        let b = cached_space(&Coulomb, &gpu, &Input::new("cache-ub", &[64, 32]));
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(cached_spaces() >= 2);
    }

    #[test]
    fn tweaked_spec_is_a_distinct_entry() {
        // all GpuSpec fields are public; a sweep over a tweaked spec
        // must never be served another spec's recording
        let stock = GpuSpec::gtx750();
        let input = Input::new("cache-tweak", &[32, 64]);
        let a = cached_space(&Coulomb, &stock, &input);
        let mut tweaked = GpuSpec::gtx750();
        tweaked.dram_bw *= 2.0;
        let b = cached_space(&Coulomb, &tweaked, &input);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(recorded_count(&Coulomb, &tweaked, &input), 1);
    }

    #[test]
    fn cached_matches_direct_recording() {
        let gpu = GpuSpec::gtx680();
        let input = Coulomb.default_input();
        let cached = cached_space(&Coulomb, &gpu, &input);
        let direct = record_space(&Coulomb, &gpu, &input);
        assert_eq!(cached.space.len(), direct.space.len());
        assert_eq!(cached.best_time(), direct.best_time());
        assert_eq!(cached.gpu, direct.gpu);
    }
}
