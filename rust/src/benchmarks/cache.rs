//! Process-wide cache of exhaustively recorded tuning spaces and their
//! derived prediction matrices.
//!
//! Recording a space is by far the most expensive primitive in the
//! harness (|space| simulator evaluations), and the paper's evaluation
//! replays the *same* `(benchmark, GPU, input)` spaces across dozens of
//! tables, figures, repetition loops and — since the serve layer —
//! concurrent cache-miss searches. The cache guarantees each such space
//! is enumerated and simulated **exactly once per process**, no matter
//! how many threads ask for it concurrently; the dense
//! [`PredictionMatrix`] derived from each recording is shared the same
//! way, so every profile search over a given endpoint scores the same
//! `Arc`.
//!
//! Both caches are [`OnceMap`]s: the map lock is held only to hand out
//! a per-key slot, so distinct spaces record in parallel while racing
//! requests for the same space block on one recording. A panicking
//! recording leaves its slot empty and the maps unpoisoned
//! (`util::sync` recovers the guard), so one crashed worker can never
//! brick every later request — a prerequisite for a long-lived serve
//! process.

use std::sync::{Arc, Mutex, OnceLock};

use super::{by_name, record_space, Benchmark, Input, OnDemandRecorder};
use crate::gpusim::GpuSpec;
use crate::model::PredictionMatrix;
use crate::tuning::RecordedSpace;
use crate::util::sync::{lock_unpoisoned, OnceMap};

/// Cache key: benchmark name, the GPU's full spec (all fields are
/// public, so a caller may hand in a registry-named spec with tweaked
/// parameters — e.g. a bandwidth sweep — and must not receive the
/// stock recording), and input name + dimensions (two inputs may share
/// a display name but differ in size).
type SpaceKey = (String, String, String);

static CACHE: OnceMap<SpaceKey, Arc<RecordedSpace>> = OnceMap::new();
static MATRICES: OnceMap<SpaceKey, Arc<PredictionMatrix>> = OnceMap::new();
static RECORDERS: OnceMap<SpaceKey, Arc<OnDemandRecorder>> = OnceMap::new();
/// How many times each key was actually recorded (test instrumentation
/// for the exactly-once guarantee). Counts successful recordings only:
/// a panicking recording leaves both the slot and the counter
/// untouched, so retries keep the count honest.
static RECORDINGS: OnceLock<Mutex<std::collections::HashMap<SpaceKey, usize>>> =
    OnceLock::new();

fn key_of(bench: &dyn Benchmark, gpu: &GpuSpec, input: &Input) -> SpaceKey {
    (
        bench.name().to_string(),
        format!("{gpu:?}"),
        format!("{}:{:?}", input.name, input.dims),
    )
}

/// Fetch the recorded space for `(bench, gpu, input)`, recording it on
/// first use. Concurrent callers for the same key all receive the same
/// `Arc`; the recording itself runs exactly once.
pub fn cached_space(
    bench: &dyn Benchmark,
    gpu: &GpuSpec,
    input: &Input,
) -> Arc<RecordedSpace> {
    let key = key_of(bench, gpu, input);
    CACHE.get_or_init(&key, || {
        let rec = Arc::new(record_space(bench, gpu, input));
        *lock_unpoisoned(RECORDINGS.get_or_init(Default::default))
            .entry(key.clone())
            .or_insert(0) += 1;
        rec
    })
}

/// Fetch the shared [`PredictionMatrix`] for `(bench, gpu, input)`,
/// deriving it from the cached recording on first use. Concurrent
/// callers all receive the same `Arc`, so every profile search over an
/// endpoint scores one dense matrix instead of rebuilding it per job.
pub fn cached_matrix(
    bench: &dyn Benchmark,
    gpu: &GpuSpec,
    input: &Input,
) -> Arc<PredictionMatrix> {
    let key = key_of(bench, gpu, input);
    MATRICES.get_or_init(&key, || {
        Arc::new(PredictionMatrix::from_recorded(&cached_space(
            bench, gpu, input,
        )))
    })
}

/// Fetch the shared [`OnDemandRecorder`] for `(bench, gpu, input)` —
/// the lazy counterpart of [`cached_space`], for benchmarks whose
/// [`recording_mode`] is `OnDemand`. All concurrent jobs tuning the
/// same endpoint share one memo, so a configuration is simulated at
/// most once per process no matter how many searches visit it.
///
/// [`recording_mode`]: super::Benchmark::recording_mode
pub fn cached_recorder(
    bench: &dyn Benchmark,
    gpu: &GpuSpec,
    input: &Input,
) -> Arc<OnDemandRecorder> {
    let key = key_of(bench, gpu, input);
    RECORDERS.get_or_init(&key, || {
        let owned = by_name(bench.name()).unwrap_or_else(|| {
            panic!("benchmark {:?} not in registry", bench.name())
        });
        Arc::new(OnDemandRecorder::new(owned, gpu.clone(), input.clone()))
    })
}

/// Number of times this `(bench, gpu, input)` space has been recorded
/// in this process — `1` after any number of [`cached_space`] calls.
pub fn recorded_count(bench: &dyn Benchmark, gpu: &GpuSpec, input: &Input) -> usize {
    lock_unpoisoned(RECORDINGS.get_or_init(Default::default))
        .get(&key_of(bench, gpu, input))
        .copied()
        .unwrap_or(0)
}

/// Number of distinct spaces currently cached.
pub fn cached_spaces() -> usize {
    CACHE.len()
}

#[cfg(test)]
mod tests {
    use super::super::Coulomb;
    use super::*;
    use crate::tuning::{Config, Space, Workload};
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn same_key_returns_same_arc_and_records_once() {
        let gpu = GpuSpec::gtx750();
        let input = Input::new("cache-unit-test", &[32, 64]);
        let a = cached_space(&Coulomb, &gpu, &input);
        let b = cached_space(&Coulomb, &gpu, &input);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(recorded_count(&Coulomb, &gpu, &input), 1);
    }

    #[test]
    fn different_inputs_are_distinct_entries() {
        let gpu = GpuSpec::gtx750();
        let a = cached_space(&Coulomb, &gpu, &Input::new("cache-ua", &[32, 64]));
        let b = cached_space(&Coulomb, &gpu, &Input::new("cache-ub", &[64, 32]));
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(cached_spaces() >= 2);
    }

    #[test]
    fn tweaked_spec_is_a_distinct_entry() {
        // all GpuSpec fields are public; a sweep over a tweaked spec
        // must never be served another spec's recording
        let stock = GpuSpec::gtx750();
        let input = Input::new("cache-tweak", &[32, 64]);
        let a = cached_space(&Coulomb, &stock, &input);
        let mut tweaked = GpuSpec::gtx750();
        tweaked.dram_bw *= 2.0;
        let b = cached_space(&Coulomb, &tweaked, &input);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(recorded_count(&Coulomb, &tweaked, &input), 1);
    }

    #[test]
    fn cached_matches_direct_recording() {
        let gpu = GpuSpec::gtx680();
        let input = Coulomb.default_input();
        let cached = cached_space(&Coulomb, &gpu, &input);
        let direct = record_space(&Coulomb, &gpu, &input);
        assert_eq!(cached.space.len(), direct.space.len());
        assert_eq!(cached.best_time(), direct.best_time());
        assert_eq!(cached.gpu, direct.gpu);
    }

    #[test]
    fn matrix_is_shared_and_matches_direct_derivation() {
        let gpu = GpuSpec::gtx750();
        let input = Input::new("cache-matrix", &[32, 64]);
        let a = cached_matrix(&Coulomb, &gpu, &input);
        let b = cached_matrix(&Coulomb, &gpu, &input);
        assert!(Arc::ptr_eq(&a, &b));
        // deriving the matrix must not re-record the space
        assert_eq!(recorded_count(&Coulomb, &gpu, &input), 1);
        let direct =
            PredictionMatrix::from_recorded(&cached_space(&Coulomb, &gpu, &input));
        assert_eq!(a.n_configs(), direct.n_configs());
    }

    #[test]
    fn recorder_is_shared_and_memo_is_process_wide() {
        let gpu = GpuSpec::gtx750();
        let input = Input::new("cache-recorder", &[64]);
        let bench = super::super::by_name("synth-grid").unwrap();
        let a = cached_recorder(bench.as_ref(), &gpu, &input);
        let b = cached_recorder(bench.as_ref(), &gpu, &input);
        assert!(Arc::ptr_eq(&a, &b));
        let _ = a.record(42);
        assert_eq!(b.visited(), 1, "memo must be shared through the cache");
    }

    /// A benchmark whose first recording panics (space enumeration
    /// blows up), then behaves like [`Coulomb`] — the injected failure
    /// for the poison-cascade regression test below.
    struct PanicsOnce;

    static ARMED: AtomicBool = AtomicBool::new(true);

    impl Benchmark for PanicsOnce {
        fn name(&self) -> &'static str {
            "cache-panics-once"
        }
        fn space(&self) -> Space {
            if ARMED.swap(false, Ordering::SeqCst) {
                panic!("injected recording failure");
            }
            Coulomb.space()
        }
        fn default_input(&self) -> Input {
            Coulomb.default_input()
        }
        fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
            Coulomb.workload(space, cfg, input)
        }
    }

    #[test]
    fn panicking_recording_does_not_brick_the_cache() {
        let gpu = GpuSpec::gtx750();
        let input = Input::new("cache-panic", &[32, 64]);
        let attempt =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                cached_space(&PanicsOnce, &gpu, &input)
            }));
        assert!(attempt.is_err(), "first recording must panic");
        // The failed recording counted nothing and poisoned nothing:
        // the same key retries cleanly...
        assert_eq!(recorded_count(&PanicsOnce, &gpu, &input), 0);
        let rec = cached_space(&PanicsOnce, &gpu, &input);
        assert!(!rec.space.is_empty());
        assert_eq!(recorded_count(&PanicsOnce, &gpu, &input), 1);
        // ...and unrelated keys were never at risk.
        let other = cached_space(&Coulomb, &gpu, &input);
        assert!(!other.space.is_empty());
    }
}
