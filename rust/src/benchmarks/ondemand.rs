//! On-demand (lazy) recording of vast tuning spaces.
//!
//! Eager recording ([`super::record_space`]) enumerates and simulates
//! every configuration — O(|space|) simulator calls and O(|space|)
//! memory before a single search step runs. That is the right trade for
//! the paper's 10²–10⁴-config spaces, whose recordings are replayed
//! across dozens of repetitions, but it caps the architecture far below
//! production-sized spaces: GEMM-full (205k) was carved out entirely
//! and a ≥1M-config space was unrepresentable.
//!
//! An [`OnDemandRecorder`] inverts the cost model: it holds only the
//! space geometry (implicit spaces store *no* configurations at all —
//! see [`Space::enumerate_implicit`]) and simulates a configuration the
//! first time any searcher visits it, memoizing the [`Record`] so
//! repeated visits — and concurrent jobs sharing the recorder through
//! [`super::cached_recorder`] — pay once. Because the gpusim engine is
//! a pure function of (GPU, workload), an on-demand record is
//! bit-for-bit identical to the record eager recording would have
//! produced at the same index; a property test pins that.
//!
//! [`Space::enumerate_implicit`]: crate::tuning::Space::enumerate_implicit

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::{Benchmark, Input};
use crate::gpusim::{simulate, GpuSpec};
use crate::tuning::{Record, Space};
use crate::util::sync::lock_unpoisoned;

/// Lazily simulates and memoizes records for one
/// (benchmark, GPU, input) endpoint. Thread-safe; share via `Arc`.
pub struct OnDemandRecorder {
    bench: Box<dyn Benchmark>,
    gpu: GpuSpec,
    input: Input,
    space: Arc<Space>,
    memo: Mutex<HashMap<usize, Record>>,
}

impl OnDemandRecorder {
    pub fn new(bench: Box<dyn Benchmark>, gpu: GpuSpec, input: Input) -> Self {
        let space = Arc::new(bench.space());
        OnDemandRecorder {
            bench,
            gpu,
            input,
            space,
            memo: Mutex::new(HashMap::new()),
        }
    }

    pub fn space(&self) -> &Space {
        &self.space
    }

    pub fn space_arc(&self) -> Arc<Space> {
        Arc::clone(&self.space)
    }

    pub fn gpu(&self) -> &GpuSpec {
        &self.gpu
    }

    pub fn input(&self) -> &Input {
        &self.input
    }

    /// The record for configuration `idx`, simulating it on first
    /// visit. The simulation runs outside the memo lock so concurrent
    /// visits to *different* configurations never serialize; a racing
    /// double-simulation of the same index is harmless (pure function —
    /// both produce identical bits) and the first insert wins.
    pub fn record(&self, idx: usize) -> Record {
        if let Some(r) = lock_unpoisoned(&self.memo).get(&idx) {
            return r.clone();
        }
        let cfg = self.space.config_at(idx);
        let w = self.bench.workload(&self.space, &cfg, &self.input);
        let sim = simulate(&self.gpu, &w);
        let rec = Record {
            runtime_ms: sim.runtime_ms,
            counters: sim.counters,
        };
        lock_unpoisoned(&self.memo)
            .entry(idx)
            .or_insert(rec)
            .clone()
    }

    /// Runtime of configuration `idx` (simulating on first visit).
    pub fn runtime_ms(&self, idx: usize) -> f64 {
        self.record(idx).runtime_ms
    }

    /// How many distinct configurations have been simulated — the
    /// bounded-memory acceptance metric: after a lazy tuning run this
    /// must be ≪ |space|.
    pub fn visited(&self) -> usize {
        lock_unpoisoned(&self.memo).len()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{by_name, record_space, Coulomb, SynthGrid};
    use super::*;

    #[test]
    fn on_demand_records_match_eager_bit_for_bit() {
        let gpu = GpuSpec::gtx1070();
        let input = Coulomb.default_input();
        let eager = record_space(&Coulomb, &gpu, &input);
        let lazy = OnDemandRecorder::new(
            Box::new(Coulomb),
            gpu.clone(),
            input.clone(),
        );
        for idx in (0..eager.space.len()).step_by(7) {
            let want = &eager.records[idx];
            let got = lazy.record(idx);
            assert_eq!(
                got.runtime_ms.to_bits(),
                want.runtime_ms.to_bits(),
                "runtime at {idx}"
            );
            for (g, w) in got.counters.0.iter().zip(want.counters.0.iter()) {
                assert_eq!(g.to_bits(), w.to_bits(), "counter at {idx}");
            }
        }
    }

    #[test]
    fn memoization_counts_distinct_visits_only() {
        let lazy = OnDemandRecorder::new(
            Box::new(Coulomb),
            GpuSpec::gtx750(),
            Coulomb.default_input(),
        );
        let a = lazy.record(3);
        let b = lazy.record(3);
        assert_eq!(a.runtime_ms.to_bits(), b.runtime_ms.to_bits());
        let _ = lazy.record(5);
        assert_eq!(lazy.visited(), 2);
    }

    #[test]
    fn million_config_recorder_is_cheap_until_visited() {
        let bench = by_name("synth-grid").unwrap();
        let lazy = OnDemandRecorder::new(
            bench,
            GpuSpec::rtx2080(),
            SynthGrid.default_input(),
        );
        assert!(lazy.space().len() >= 1_000_000);
        assert!(lazy.space().is_implicit());
        assert_eq!(lazy.visited(), 0);
        // touching a handful of far-apart indices simulates exactly those
        for idx in [0, 999_999, 524_287, 1] {
            let r = lazy.record(idx);
            assert!(r.runtime_ms.is_finite() && r.runtime_ms > 0.0);
        }
        assert_eq!(lazy.visited(), 4);
    }
}
