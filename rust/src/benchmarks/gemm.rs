//! GEMM (Table 2: reduced space 10 dims / ~5.8k configs from CLBlast;
//! full space 14 dims / ~205k configs from CLTune).
//!
//! Parameter vocabulary follows CLBlast [24]:
//! * `MWG`, `NWG` — per-workgroup output tile;
//! * `KWG` — K-panel staged per iteration;
//! * `MDIMC`, `NDIMC` — thread grid inside a workgroup (each thread
//!   computes an (MWG/MDIMC)×(NWG/NDIMC) register tile);
//! * `MDIMA`, `NDIMB` — cooperative load shapes for the A/B panels;
//! * `KWI` — inner unroll of the K loop;
//! * `VWM`, `VWN` — vector widths for loads/stores.
//!
//! The full space adds CLTune's `SA`, `SB` (stage A/B in shared memory)
//! and `STRM`, `STRN` (strided thread access), with the reduced space
//! pinned at SA=SB=1, STRM=STRN=0 like the paper's CLBlast subset.

use super::{Benchmark, Input};
use crate::gpusim::Workload;
use crate::tuning::{Config, ParamDef, Space};

pub struct Gemm;
pub struct GemmFull;

fn gemm_params(full: bool) -> Vec<ParamDef> {
    let mut p = vec![
        ParamDef::new("MWG", &[16, 32, 64, 128]),
        ParamDef::new("NWG", &[16, 32, 64, 128]),
        ParamDef::new("KWG", &[16, 32]),
        ParamDef::new("MDIMC", &[8, 16, 32]),
        ParamDef::new("NDIMC", &[8, 16, 32]),
        ParamDef::new("MDIMA", &[8, 16, 32]),
        ParamDef::new("NDIMB", &[8, 16, 32]),
        ParamDef::new("KWI", &[2, 8]),
        ParamDef::new("VWM", &[1, 2, 4, 8]),
        ParamDef::new("VWN", &[1, 2, 4, 8]),
    ];
    if full {
        p.push(ParamDef::new("SA", &[0, 1]));
        p.push(ParamDef::new("SB", &[0, 1]));
        p.push(ParamDef::new("STRM", &[0, 1]));
        p.push(ParamDef::new("STRN", &[0, 1]));
    }
    p
}

/// CLBlast-style legality constraints.
fn gemm_ok(v: &[i64], full: bool) -> bool {
    let (mwg, nwg, kwg) = (v[0], v[1], v[2]);
    let (mdimc, ndimc, mdima, ndimb) = (v[3], v[4], v[5], v[6]);
    let (kwi, vwm, vwn) = (v[7], v[8], v[9]);
    let block = mdimc * ndimc;
    let ok = kwg % kwi == 0
        && mwg % (mdimc * vwm) == 0
        && nwg % (ndimc * vwn) == 0
        && mwg % (mdima * vwm) == 0
        && nwg % (ndimb * vwn) == 0
        && block % mdima == 0
        && block % ndimb == 0
        && kwg % (block / mdima) == 0
        && kwg % (block / ndimb) == 0
        && (64..=1024).contains(&block)
        && block % 32 == 0 // warp-multiple workgroups
        && (mwg / mdimc) * (nwg / ndimc) <= 32; // bounded register tile
    if !ok {
        return false;
    }
    if full {
        let (sa, sb, strm, strn) = (v[10], v[11], v[12], v[13]);
        // strided access only applies to vectorized, non-staged operands
        if strm == 1 && (vwm == 1 || sa == 1) {
            return false;
        }
        if strn == 1 && (vwn == 1 || sb == 1) {
            return false;
        }
    }
    true
}

fn gemm_space(name: &str, full: bool) -> Space {
    Space::enumerate(name, gemm_params(full), |v| gemm_ok(v, full))
}

fn gemm_workload(space: &Space, cfg: &Config, input: &Input, full: bool) -> Workload {
    let g = |n: &str| space.value(cfg, n) as f64;
    let (mwg, nwg, kwg) = (g("MWG"), g("NWG"), g("KWG"));
    let (mdimc, ndimc) = (g("MDIMC"), g("NDIMC"));
    let (mdima, ndimb) = (g("MDIMA"), g("NDIMB"));
    let (kwi, vwm, vwn) = (g("KWI"), g("VWM"), g("VWN"));
    let (sa, sb, strm, strn) = if full {
        (g("SA"), g("SB"), g("STRM"), g("STRN"))
    } else {
        (1.0, 1.0, 0.0, 0.0)
    };

    let (m, n, k) = (input.dim(0), input.dim(1), input.dim(2));
    // tail padding: tiles cover ceil(m/MWG) — undersized inputs waste work
    let tiles_m = (m / mwg).ceil().max(1.0);
    let tiles_n = (n / nwg).ceil().max(1.0);
    let m_eff = tiles_m * mwg;
    let n_eff = tiles_n * nwg;

    let wpt_m = mwg / mdimc;
    let wpt_n = nwg / ndimc;
    let block_size = mdimc * ndimc;
    let blocks = tiles_m * tiles_n;
    let threads = blocks * block_size;

    // --- per-thread instruction counts --------------------------------
    let fp32 = 2.0 * k * wpt_m * wpt_n;
    let ldst = k * (wpt_m / vwm + wpt_n / vwn)
        + wpt_m * wpt_n / vwm
        + sa * (k / kwg) * (mwg * kwg / block_size) / vwm
        + sb * (k / kwg) * (nwg * kwg / block_size) / vwn;
    let int = (k / kwi) * (6.0 + (wpt_m + wpt_n) * 0.5)
        + k * 0.5
        + 20.0
        + (strm + strn) * k * 0.3; // strided index arithmetic
    let cont = (k / kwg) * (kwg / kwi + 2.0) + 4.0;
    let misc = (sa + sb) * (k / kwg) * 2.0; // barriers
    let bconv = 2.0;

    // --- registers ------------------------------------------------------
    let regs = 14.0
        + wpt_m * wpt_n
        + 1.5 * (wpt_m + wpt_n)
        + 1.5 * (vwm + vwn)
        + (1.0 - sa) * 4.0
        + (1.0 - sb) * 4.0;

    // --- memory traffic ---------------------------------------------------
    // staged operands are read once per block; unstaged operands issue
    // per-thread requests (NDIMC-/MDIMC-fold redundancy absorbed by the
    // read path caches).
    let a_bytes_block = mwg * k * 4.0;
    let b_bytes_block = nwg * k * 4.0;
    let a_redundancy = if sa > 0.5 { 1.0 } else { ndimc };
    let b_redundancy = if sb > 0.5 { 1.0 } else { mdimc };
    // cooperative-load shape mismatch costs extra transactions
    let a_shape_penalty = 1.0 + 0.08 * (mdima.log2() - 3.0).abs();
    let b_shape_penalty = 1.0 + 0.08 * (ndimb.log2() - 3.0).abs();
    let stride_penalty_a = 1.0 + 0.2 * strm;
    let stride_penalty_b = 1.0 + 0.2 * strn;
    let gread = blocks
        * (a_bytes_block * a_redundancy * a_shape_penalty * stride_penalty_a
            + b_bytes_block * b_redundancy * b_shape_penalty * stride_penalty_b);
    let gwrite = m_eff * n_eff * 4.0;

    // shared-memory traffic for the staged panels
    let shr_st = blocks * (sa * a_bytes_block + sb * b_bytes_block);
    let shr_ld = threads
        * k
        * (sa * wpt_m + sb * wpt_n)
        * 4.0
        / ((vwm + vwn) * 0.5);

    Workload {
        threads,
        block_size,
        regs_per_thread: regs,
        shared_bytes_per_block: (sa * mwg + sb * nwg) * kwg * 4.0,
        fp32: fp32 * threads,
        int: int * threads,
        ldst: ldst * threads,
        cont: cont * threads,
        misc: misc * threads,
        bconv: bconv * threads,
        gread,
        gwrite,
        tex_fraction: 0.4 + 0.3 * (2.0 - sa - sb) / 2.0,
        tex_footprint_per_sm: (mwg + nwg) * kwg * 4.0,
        l2_footprint: (m_eff * k + k * n_eff) * 4.0,
        shared_load_bytes: shr_ld,
        shared_store_bytes: shr_st,
        divergence: 0.01,
        ..Default::default()
    }
}

/// §4.6 (Table 7) variants: the small square turns tail padding into
/// the dominant cost for big tiles, and the two 16-row/16-column
/// skews penalize whichever workgroup dimension overhangs the thin
/// axis — the classic input-sensitivity of GEMM tile shapes.
const GEMM_INPUTS: &[(&str, [u64; 3])] = &[
    ("2048x2048", [2048, 2048, 2048]),
    ("128x128", [128, 128, 128]),
    ("16x4096", [16, 4096, 4096]),
    ("4096x16", [4096, 16, 4096]),
];

impl Benchmark for Gemm {
    fn name(&self) -> &'static str {
        "gemm"
    }

    fn space(&self) -> Space {
        gemm_space("gemm", false)
    }

    fn default_input(&self) -> Input {
        Input::new("2048x2048", &[2048, 2048, 2048])
    }

    fn inputs(&self) -> Vec<Input> {
        GEMM_INPUTS
            .iter()
            .map(|(n, d)| Input::new(n, d))
            .collect()
    }

    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
        gemm_workload(space, cfg, input, false)
    }
}

impl Benchmark for GemmFull {
    fn name(&self) -> &'static str {
        "gemm-full"
    }

    fn space(&self) -> Space {
        gemm_space("gemm-full", true)
    }

    fn default_input(&self) -> Input {
        Input::new("2048x2048", &[2048, 2048, 2048])
    }

    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
        gemm_workload(space, cfg, input, true)
    }

    /// §4.6: in the evaluation matrices the full space is only
    /// searched (with a model trained on the reduced space); the
    /// 205k-config recording cost is reserved for the dedicated fig8
    /// driver. Tuning/serving plan runners now go through the
    /// on-demand recorder instead of rejecting this benchmark;
    /// training-based plans (transfer/sweep) still refuse it.
    fn recording_mode(&self) -> super::RecordingMode {
        super::RecordingMode::OnDemand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::record_space;
    use crate::gpusim::GpuSpec;

    #[test]
    fn reduced_space_dims() {
        let s = Gemm.space();
        assert_eq!(s.dims(), 10);
    }

    #[test]
    fn full_space_contains_reduced_parameters() {
        let s = GemmFull.space();
        assert_eq!(s.dims(), 14);
        for p in Gemm.space().params {
            assert!(s.param_index(&p.name).is_some(), "{}", p.name);
        }
    }

    #[test]
    fn constraints_hold() {
        let s = Gemm.space();
        for c in s.configs.iter().step_by(13) {
            let mwg = s.value(c, "MWG");
            let mdimc = s.value(c, "MDIMC");
            let vwm = s.value(c, "VWM");
            assert_eq!(mwg % (mdimc * vwm), 0);
            let block = mdimc * s.value(c, "NDIMC");
            assert!((64..=1024).contains(&block));
        }
    }

    #[test]
    fn bigger_tiles_reduce_traffic() {
        let s = Gemm.space();
        let input = Gemm.default_input();
        let find = |mwg: i64| {
            s.configs
                .iter()
                .find(|c| {
                    s.value(c, "MWG") == mwg
                        && s.value(c, "NWG") == mwg
                        && s.value(c, "KWG") == 32
                        && s.value(c, "MDIMC") == 16
                        && s.value(c, "NDIMC") == 16
                        && s.value(c, "MDIMA") == 16
                        && s.value(c, "NDIMB") == 16
                        && s.value(c, "VWM") == 1
                        && s.value(c, "VWN") == 1
                        && s.value(c, "KWI") == 2
                })
                .unwrap()
        };
        let small = Gemm.workload(&s, find(32), &input);
        let large = Gemm.workload(&s, find(64), &input);
        assert!(large.gread < small.gread);
    }

    #[test]
    fn tiny_input_penalizes_big_tiles() {
        // Table 7 premise: on 16×4096 the big-tile config wastes work.
        let s = Gemm.space();
        let rec_big = record_space(
            &Gemm,
            &GpuSpec::gtx1070(),
            &Input::new("16x4096", &[16, 4096, 4096]),
        );
        let best = &rec_big.space.configs[rec_big.best_index()];
        assert!(
            s.value(best, "MWG") <= 32,
            "best MWG on 16-row input = {}",
            s.value(best, "MWG")
        );
    }

    #[test]
    fn optimum_differs_between_square_and_rect() {
        let a = record_space(
            &Gemm,
            &GpuSpec::gtx1070(),
            &Input::new("2048", &[2048, 2048, 2048]),
        );
        let b = record_space(
            &Gemm,
            &GpuSpec::gtx1070(),
            &Input::new("rect", &[16, 4096, 4096]),
        );
        assert_ne!(a.best_index(), b.best_index());
    }
}
