//! The paper's benchmark set (Table 2) as analytic workload models.
//!
//! Each benchmark defines (a) its tuning parameters and constraints —
//! mirroring the KTT/CLBlast/CLTune spaces the paper used — and (b) a
//! function mapping (configuration, input) to a device-independent
//! [`Workload`] descriptor. The [`crate::gpusim`] engine turns that into
//! runtimes and performance counters per device.
//!
//! | Benchmark    | dims (paper) | configs (paper) |
//! |--------------|--------------|-----------------|
//! | Convolution  | 10           | 3,928           |
//! | Coulomb 3D   | 7            | 210             |
//! | GEMM         | 10           | 5,788           |
//! | GEMM full    | 14           | 205,216         |
//! | Transpose    | 8            | 1,784           |
//! | N-body       | 7            | 3,134           |
//!
//! Our spaces match the dimensionality and the order of magnitude (the
//! exact counts depend on value sets that the paper does not fully
//! enumerate).

mod cache;
mod convolution;
mod coulomb;
mod gemm;
mod nbody;
mod ondemand;
mod synth;
mod transpose;

pub use cache::{
    cached_matrix, cached_recorder, cached_space, cached_spaces,
    recorded_count,
};
pub use convolution::Convolution;
pub use coulomb::Coulomb;
pub use gemm::{Gemm, GemmFull};
pub use nbody::NBody;
pub use ondemand::OnDemandRecorder;
pub use synth::SynthGrid;
pub use transpose::Transpose;

use crate::gpusim::{simulate, GpuSpec, Workload};
use crate::tuning::{Config, Record, RecordedSpace, Space};

/// Problem-input descriptor (sizes only; synthetic data).
#[derive(Debug, Clone, PartialEq)]
pub struct Input {
    pub name: String,
    pub dims: Vec<u64>,
}

impl Input {
    pub fn new(name: &str, dims: &[u64]) -> Self {
        Input {
            name: name.to_string(),
            dims: dims.to_vec(),
        }
    }

    pub fn dim(&self, i: usize) -> f64 {
        self.dims[i] as f64
    }
}

/// A tunable GPU kernel benchmark.
pub trait Benchmark: Send + Sync {
    fn name(&self) -> &'static str;

    /// Enumerate the constraint-pruned tuning space.
    fn space(&self) -> Space;

    /// The input used when none is specified (the paper's §4.6 sizes).
    fn default_input(&self) -> Input;

    /// The input registry exercised by the input-portability
    /// experiments (§4.6): must contain [`default_input`] and, for the
    /// five evaluation benchmarks, at least one variant whose
    /// size/shape shifts the bottleneck (so the transfer matrix's
    /// input axis measures something). Plan axes address these by name
    /// or via the [`resolve_input`] selectors.
    ///
    /// [`default_input`]: Benchmark::default_input
    fn inputs(&self) -> Vec<Input> {
        vec![self.default_input()]
    }

    /// Analytic workload of one configuration on one input.
    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload;

    /// Is this kernel known to be instruction-bound? (Sets the expert
    /// system's `inst_reaction` to 0.5 instead of 0.7 — paper §3.5.2.)
    fn instruction_bound(&self) -> bool {
        false
    }

    /// How this benchmark's space is recorded for tuning. The default
    /// is [`RecordingMode::Eager`] — enumerate and simulate everything
    /// up front, which is what every existing report golden assumes.
    /// Vast spaces (GEMM-full's 205k configs, the synthetic ≥1M grid)
    /// declare [`RecordingMode::OnDemand`] and are tuned against an
    /// [`OnDemandRecorder`] that simulates only visited configurations.
    /// This retires the old `exhaustively_recordable` carve-out: no
    /// benchmark is rejected by tuning/serving plan runners any more —
    /// only *training*-based plans (transfer/sweep), which genuinely
    /// need the whole space as a dataset, still require `Eager`.
    fn recording_mode(&self) -> RecordingMode {
        RecordingMode::Eager
    }
}

/// Recording strategy for a benchmark's tuning space — see
/// [`Benchmark::recording_mode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordingMode {
    /// Enumerate and simulate the full space up front
    /// ([`record_space`]); recordings and prediction matrices are
    /// process-cached per (benchmark, GPU, input).
    Eager,
    /// Simulate configurations lazily as searchers visit them,
    /// memoized per (benchmark, GPU, input) — memory and time scale
    /// with configurations *visited*, not with |space|.
    OnDemand,
}

/// All benchmarks: the paper's Table 2 set in order, plus the synthetic
/// large-space grid (not part of any paper experiment — it exists to
/// exercise the ≥1M-config on-demand path).
pub fn all() -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(Convolution),
        Box::new(Coulomb),
        Box::new(Gemm),
        Box::new(GemmFull),
        Box::new(Transpose),
        Box::new(NBody),
        Box::new(SynthGrid),
    ]
}

/// The five benchmarks used in the searcher-step experiments (GEMM full
/// is only searched, never exhaustively recorded — §4.6).
pub fn evaluation_set() -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(Coulomb),
        Box::new(Transpose),
        Box::new(Gemm),
        Box::new(NBody),
        Box::new(Convolution),
    ]
}

pub fn by_name(name: &str) -> Option<Box<dyn Benchmark>> {
    let needle = name.to_ascii_lowercase();
    all()
        .into_iter()
        .find(|b| b.name().to_ascii_lowercase() == needle)
}

/// Input-axis selector resolving to the benchmark's default input.
pub const DEFAULT_INPUT_SELECTOR: &str = "default";
/// Input-axis selector resolving to the first §4.6 variant that
/// differs from the default — a benchmark-independent way to spell
/// "some other input" across a multi-benchmark plan axis (concrete
/// input names are per-benchmark).
pub const ALT_INPUT_SELECTOR: &str = "alt";

/// Resolve an input selector against a benchmark's input registry:
/// `"default"` → [`Benchmark::default_input`], `"alt"` → the first
/// entry of [`Benchmark::inputs`] whose name differs from the default,
/// anything else → the input with that exact name. `None` when the
/// benchmark defines no such input (plan validation turns that into a
/// typed [`PlanError::UnknownInput`]).
///
/// [`PlanError::UnknownInput`]: crate::harness::PlanError::UnknownInput
pub fn resolve_input(bench: &dyn Benchmark, selector: &str) -> Option<Input> {
    match selector {
        DEFAULT_INPUT_SELECTOR => Some(bench.default_input()),
        ALT_INPUT_SELECTOR => {
            let default = bench.default_input();
            bench.inputs().into_iter().find(|i| i.name != default.name)
        }
        name => bench.inputs().into_iter().find(|i| i.name == name),
    }
}

/// Exhaustively explore a benchmark's tuning space on a simulated GPU —
/// the paper's §4.1 methodology ("perform an exhaustive exploration of
/// the entire tuning space and save the tuning results").
pub fn record_space(
    bench: &dyn Benchmark,
    gpu: &GpuSpec,
    input: &Input,
) -> RecordedSpace {
    let space = bench.space();
    // index-driven so both dense and implicit spaces record correctly
    let records: Vec<Record> = (0..space.len())
        .map(|i| {
            let cfg = space.config_at(i);
            let w = bench.workload(&space, &cfg, input);
            let sim = simulate(gpu, &w);
            Record {
                runtime_ms: sim.runtime_ms,
                counters: sim.counters,
            }
        })
        .collect();
    RecordedSpace::new(space, records, gpu.name, &input.name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_seven_benchmarks() {
        // Table 2's six plus the synthetic ≥1M-config grid
        let names: Vec<_> = all().iter().map(|b| b.name()).collect();
        assert_eq!(names.len(), 7);
        assert!(names.contains(&"coulomb"));
        assert!(names.contains(&"gemm-full"));
        assert!(names.contains(&"synth-grid"));
    }

    #[test]
    fn recording_modes_are_as_declared() {
        for b in all() {
            let expect_lazy =
                b.name() == "gemm-full" || b.name() == "synth-grid";
            assert_eq!(
                b.recording_mode() == RecordingMode::OnDemand,
                expect_lazy,
                "{}",
                b.name()
            );
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("GEMM").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn every_evaluation_benchmark_has_portability_inputs() {
        // the input-portability matrix needs every benchmark to expose
        // the default plus at least one §4.6 variant, under unique
        // names, with the default present in the registry
        for bench in evaluation_set() {
            let inputs = bench.inputs();
            let default = bench.default_input();
            assert!(
                inputs.len() >= 2,
                "{}: only {} input(s)",
                bench.name(),
                inputs.len()
            );
            assert!(
                inputs.iter().any(|i| i.name == default.name),
                "{}: default input missing from inputs()",
                bench.name()
            );
            let mut names: Vec<&str> =
                inputs.iter().map(|i| i.name.as_str()).collect();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), inputs.len(), "{}", bench.name());
        }
    }

    #[test]
    fn input_selectors_resolve() {
        for bench in evaluation_set() {
            let default =
                resolve_input(bench.as_ref(), DEFAULT_INPUT_SELECTOR)
                    .unwrap();
            assert_eq!(default.name, bench.default_input().name);
            let alt =
                resolve_input(bench.as_ref(), ALT_INPUT_SELECTOR).unwrap();
            assert_ne!(alt.name, default.name, "{}", bench.name());
            // concrete names resolve to themselves; unknowns to None
            let by_name_res =
                resolve_input(bench.as_ref(), &alt.name).unwrap();
            assert_eq!(by_name_res.name, alt.name);
            assert_eq!(by_name_res.dims, alt.dims);
            assert!(resolve_input(bench.as_ref(), "no-such-input").is_none());
        }
    }

    #[test]
    fn space_sizes_match_paper_order_of_magnitude() {
        // paper Table 2: coulomb 210, transpose 1784, gemm 5788,
        // nbody 3134, convolution 3928, gemm-full 205216
        let expect: &[(&str, usize, usize)] = &[
            ("coulomb", 100, 800),
            ("transpose", 700, 4_000),
            ("gemm", 2_000, 12_000),
            ("nbody", 1_200, 7_000),
            ("convolution", 1_500, 9_000),
        ];
        for (name, lo, hi) in expect {
            let n = by_name(name).unwrap().space().len();
            assert!(
                (lo..=hi).contains(&&n),
                "{name}: {n} outside [{lo}, {hi}]"
            );
        }
        let full = by_name("gemm-full").unwrap().space().len();
        assert!(full > 50_000, "gemm-full too small: {full}");
    }

    #[test]
    fn dims_match_paper_table2() {
        for (name, dims) in [
            ("convolution", 10),
            ("coulomb", 7),
            ("gemm", 10),
            ("gemm-full", 14),
            ("transpose", 8),
            ("nbody", 7),
        ] {
            assert_eq!(
                by_name(name).unwrap().space().dims(),
                dims,
                "{name}"
            );
        }
    }

    #[test]
    fn workloads_are_sane_everywhere() {
        // every config of every (non-huge) benchmark yields a positive,
        // finite workload and simulated runtime
        for bench in evaluation_set() {
            let space = bench.space();
            let input = bench.default_input();
            let gpu = GpuSpec::gtx1070();
            for cfg in space.configs.iter().step_by(17) {
                let w = bench.workload(&space, cfg, &input);
                assert!(w.threads > 0.0, "{}: no threads", bench.name());
                assert!(w.total_inst() > 0.0);
                let sim = simulate(&gpu, &w);
                assert!(
                    sim.runtime_ms.is_finite() && sim.runtime_ms > 0.0,
                    "{}: bad runtime",
                    bench.name()
                );
            }
        }
    }

    #[test]
    fn optimum_moves_across_gpus() {
        // The premise of the portability experiments: at least some
        // benchmarks must have different best configs on different GPUs.
        let mut moved = 0;
        for bench in evaluation_set() {
            let input = bench.default_input();
            let a = record_space(bench.as_ref(), &GpuSpec::gtx680(), &input);
            let b = record_space(bench.as_ref(), &GpuSpec::rtx2080(), &input);
            if a.best_index() != b.best_index() {
                moved += 1;
            }
        }
        assert!(moved >= 2, "only {moved} benchmarks moved their optimum");
    }

    #[test]
    fn recorded_space_well_performing_fraction_reasonable() {
        for bench in evaluation_set() {
            let rec = record_space(
                bench.as_ref(),
                &GpuSpec::gtx1070(),
                &bench.default_input(),
            );
            let frac = rec.well_performing_count(1.1) as f64
                / rec.space.len() as f64;
            assert!(
                frac < 0.55,
                "{}: {}% well-performing — space trivially easy",
                bench.name(),
                frac * 100.0
            );
        }
    }
}
