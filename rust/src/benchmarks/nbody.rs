//! N-body simulation step (Table 2: 7 dims, 3,134 configs).
//!
//! All-pairs gravitational interaction — O(n²) compute over O(n) data,
//! so heavily compute-bound at large n; at small n it turns
//! parallelism-bound, moving the optimum (the paper's Fig. 6 uses both
//! 16,384- and 131,072-body instances).

use super::{Benchmark, Input};
use crate::gpusim::Workload;
use crate::tuning::{Config, ParamDef, Space};

pub struct NBody;

impl Benchmark for NBody {
    fn name(&self) -> &'static str {
        "nbody"
    }

    fn space(&self) -> Space {
        let params = vec![
            ParamDef::new("BLOCK", &[64, 128, 256, 512]),
            ParamDef::new("OUTER_UNROLL", &[1, 2, 4, 8]),
            ParamDef::new("INNER_UNROLL", &[1, 2, 4, 8, 16, 32]),
            ParamDef::new("TILE", &[1, 2, 4]),
            ParamDef::new("USE_SHARED", &[0, 1]),
            ParamDef::new("USE_SOA", &[0, 1]),
            ParamDef::new("VECTOR", &[1, 2, 4]),
        ];
        Space::enumerate("nbody", params, |v| {
            let (block, ou, iu, tile, sh, _soa, vec) =
                (v[0], v[1], v[2], v[3], v[4], v[5], v[6]);
            ou * iu <= 64
                && (sh == 1 || tile == 1) // tiling is a shared-memory schedule
                && vec <= 1 + ou // vector loads need coarsening to feed them
                && block * ou <= 4096
        })
    }

    fn default_input(&self) -> Input {
        // §4.6: 16,384 bodies (and 131,072 for the large instance)
        Input::new("n16384", &[16384])
    }

    /// §4.6 variants: exactly the paper's two instances (fig6 plots
    /// both, so this registry is deliberately not widened further) —
    /// at 131,072 bodies kernels run long enough that gathering
    /// counters dominates, the known limitation the paper reports.
    fn inputs(&self) -> Vec<Input> {
        vec![self.default_input(), Input::new("n131072", &[131072])]
    }

    fn workload(&self, space: &Space, cfg: &Config, input: &Input) -> Workload {
        let block = space.value(cfg, "BLOCK") as f64;
        let ou = space.value(cfg, "OUTER_UNROLL") as f64;
        let iu = space.value(cfg, "INNER_UNROLL") as f64;
        let tile = space.value(cfg, "TILE") as f64;
        let shared = space.value(cfg, "USE_SHARED") as f64;
        let soa = space.value(cfg, "USE_SOA") as f64;
        let vec = space.value(cfg, "VECTOR") as f64;

        let n = input.dim(0);
        let threads = (n / ou).max(1.0);

        // --- per-thread instructions ------------------------------------
        // per interaction: 3 diffs + dot (5) + rsqrt (1+3 misc) + 3 fma
        // (6) + softening (2) ≈ 17 fp32; outer coarsening amortizes the
        // i-body load but not the j-loop.
        let fp32 = n * ou * 17.0 + ou * 12.0;
        let int = 16.0 + n * (1.5 / iu + 1.0 / vec) + ou * 4.0;
        let cont = n / iu + 4.0;
        let misc = n * ou * 3.0 * 0.25 + shared * (n / (block * tile)) * 2.0;
        let body_bytes = if soa > 0.5 { 12.0 } else { 16.0 };
        let ldst = n * (ou / vec) * 0.5 + n * body_bytes / 16.0 / vec;

        // --- registers ----------------------------------------------------
        let regs =
            20.0 + ou * (5.0 + 0.35 * iu) + 3.0 * vec + shared * 4.0;

        // --- memory traffic -----------------------------------------------
        let warps = threads / 32.0;
        let gread = if shared > 0.5 {
            // each block stages all bodies through shared memory once
            (threads / block) * n * body_bytes
        } else {
            // warp-broadcast reads served by the read-only path
            warps * n * body_bytes * if soa > 0.5 { 1.0 } else { 1.25 }
        };
        let gwrite = n * body_bytes;

        let (shr_ld, shr_st) = if shared > 0.5 {
            (threads * n * body_bytes / vec / tile.sqrt(), (threads / block) * n * body_bytes)
        } else {
            (0.0, 0.0)
        };

        let warp_fill = (block / 32.0).min(1.0);

        Workload {
            threads,
            block_size: block,
            regs_per_thread: regs,
            shared_bytes_per_block: shared * block * tile * body_bytes,
            fp32: fp32 * threads,
            int: int * threads,
            cont: cont * threads,
            misc: misc * threads,
            ldst: ldst * threads,
            bconv: 2.0 * threads,
            gread,
            gwrite,
            tex_fraction: if soa > 0.5 { 0.9 } else { 0.7 },
            tex_footprint_per_sm: n * body_bytes / tile,
            l2_footprint: n * body_bytes,
            shared_load_bytes: shr_ld,
            shared_store_bytes: shr_st,
            divergence: (1.0 - warp_fill) * 0.9 + 0.01,
            ..Default::default()
        }
    }

    fn instruction_bound(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::record_space;
    use crate::gpusim::GpuSpec;

    #[test]
    fn space_dims_and_size() {
        let s = NBody.space();
        assert_eq!(s.dims(), 7);
        assert!((1200..=7000).contains(&s.len()), "{}", s.len());
    }

    #[test]
    fn compute_bound_at_default_size() {
        let rec = record_space(
            &NBody,
            &GpuSpec::gtx1070(),
            &NBody.default_input(),
        );
        let best = &rec.records[rec.best_index()];
        use crate::counters::Counter;
        assert!(
            best.counters.get(Counter::InstIssueU)
                > best.counters.get(Counter::DramU) * 10.0,
            "best n-body config should be compute-bound"
        );
    }

    #[test]
    fn optimum_differs_across_input_sizes() {
        let small = record_space(
            &NBody,
            &GpuSpec::rtx2080(),
            &Input::new("s", &[16384]),
        );
        let large = record_space(
            &NBody,
            &GpuSpec::rtx2080(),
            &Input::new("l", &[131072]),
        );
        // best runtimes scale superlinearly (O(n²) work)
        assert!(large.best_time() > 10.0 * small.best_time());
    }

    #[test]
    fn outer_unroll_reduces_reads() {
        let s = NBody.space();
        let input = NBody.default_input();
        let find = |ou: i64| {
            s.configs
                .iter()
                .find(|c| {
                    s.value(c, "OUTER_UNROLL") == ou
                        && s.value(c, "BLOCK") == 256
                        && s.value(c, "INNER_UNROLL") == 1
                        && s.value(c, "USE_SHARED") == 0
                        && s.value(c, "USE_SOA") == 1
                        && s.value(c, "VECTOR") == 1
                        && s.value(c, "TILE") == 1
                })
                .unwrap()
        };
        let w1 = NBody.workload(&s, find(1), &input);
        let w4 = NBody.workload(&s, find(4), &input);
        assert!(w4.gread < w1.gread);
        assert!(w4.threads < w1.threads);
    }
}
