//! Experiment registry: plan-hash provenance + KPI trend tracking.
//!
//! Every MATRIX/TRANSFER/SWEEP/BENCH report carries a stable **plan
//! hash** ([`plan_hash`]: FNV-1a over the canonical compact JSON of the
//! report schema version + plan echo — axes, seeds, budgets; never
//! provenance) and a **provenance block** ([`Provenance`]: commit,
//! toolchain cachekey and creation timestamp, all sourced from the
//! environment with stable defaults so report bytes stay deterministic
//! — the `--jobs 1` vs `--jobs 8` byte-identity contract and the CI
//! golden gates are unaffected by who runs the plan or when).
//!
//! [`extract_rows`] lowers a report into flat [`RegistryRow`]s (one per
//! cell KPI), which a [`RegistryStore`] persists: [`MemStore`] for
//! in-process use, [`CsvStore`] for the append-only on-disk registry
//! (`registry/pcat.csv`). Rows whose report schema version the
//! registry does not know are a typed [`RegistryError::UnknownSchema`]
//! — never a silent skip — so a schema bump forces an explicit
//! migration instead of quietly corrupting the trend series.
//!
//! [`compare_rows`] evaluates typed per-KPI tolerances
//! ([`Tolerance`]: optional hard `min`/`max` bounds on the current
//! value plus `abs` + `rel` drift allowances against the baseline,
//! directional so improvements never fail) and returns pass/fail
//! findings — the primitive `pcat registry compare` and the CI
//! `registry-gate` lane turn into a per-PR perf/quality trend gate.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::csv;
use crate::util::hash::fnv1a_hex;
use crate::util::json::{obj, Value};
use crate::util::stats::median;

/// Report schema versions, centralized here and used by the emitters
/// ([`super::PlanReport`], [`super::TransferReport`],
/// [`super::SweepReport`], [`super::ServeReport`], the bench JSON
/// sink) so the known-schema list below can never drift from what the
/// reports actually say.
pub const PLAN_REPORT_SCHEMA: &str = "pcat-plan-report/v1";
pub const TRANSFER_REPORT_SCHEMA: &str = "pcat-transfer-report/v3";
pub const SWEEP_REPORT_SCHEMA: &str = "pcat-sweep-report/v1";
pub const BENCH_REPORT_SCHEMA: &str = "pcat-bench-report/v1";
pub const SERVE_REPORT_SCHEMA: &str = "pcat-serve-report/v1";

/// Every report schema the registry can ingest. Anything else —
/// including *older* versions of these schemas — is
/// [`RegistryError::UnknownSchema`].
pub const KNOWN_REPORT_SCHEMAS: [&str; 5] = [
    PLAN_REPORT_SCHEMA,
    TRANSFER_REPORT_SCHEMA,
    SWEEP_REPORT_SCHEMA,
    BENCH_REPORT_SCHEMA,
    SERVE_REPORT_SCHEMA,
];

/// Column order of the registry CSV (also its header line).
pub const REGISTRY_HEADER: [&str; 9] = [
    "schema",
    "plan",
    "plan_hash",
    "commit",
    "created_at",
    "toolchain",
    "scope",
    "kpi",
    "value",
];

/// Stable plan fingerprint: FNV-1a over the canonical **compact** JSON
/// of `{"plan": <plan echo>, "schema": <report schema>}`. The plan
/// echo carries every axis, the seeds and the budget; provenance and
/// results are deliberately excluded, so the hash is a pure function
/// of *what was asked for* — identical across `--jobs` counts,
/// commits, machines and reruns, and different the moment any axis,
/// seed or schema version changes.
pub fn plan_hash(schema: &str, plan: &Value) -> String {
    let canonical = obj(vec![
        ("plan", plan.clone()),
        ("schema", Value::from(schema)),
    ])
    .to_string_pretty(0);
    fnv1a_hex(canonical.as_bytes())
}

/// Environment variables the provenance block reads. Timestamps and
/// identities come from the *environment*, never from the hasher or
/// the clock, so reports (and registry rows) stay deterministic: two
/// runs in the same environment produce identical bytes.
pub const ENV_COMMIT: &str = "PCAT_COMMIT";
pub const ENV_CREATED_AT: &str = "PCAT_CREATED_AT";
pub const ENV_TOOLCHAIN: &str = "PCAT_TOOLCHAIN";

/// Report provenance: who/when/what produced a report. Deliberately
/// stable defaults (the exemplar registries pin `created_at` to the
/// epoch and `commit` to `"unknown"` for the same reason): a report
/// generated with no environment set is byte-identical everywhere,
/// which is what keeps the golden gates meaningful.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    pub commit: String,
    pub created_at: String,
    pub toolchain: String,
}

impl Provenance {
    pub const DEFAULT_COMMIT: &'static str = "unknown";
    pub const DEFAULT_CREATED_AT: &'static str = "1970-01-01T00:00:00Z";
    pub const DEFAULT_TOOLCHAIN: &'static str = "unknown";

    /// Provenance for a freshly generated report: environment
    /// variables with stable defaults.
    pub fn from_env() -> Provenance {
        Provenance::resolve_with(|k| std::env::var(k).ok(), None)
    }

    /// Provenance for registry rows extracted from `report`: an
    /// environment variable set *at append time* wins over the block
    /// embedded in the report (CI appends golden-stable reports while
    /// still stamping the real commit into the rows), which wins over
    /// the defaults.
    pub fn for_rows(report: &Value) -> Provenance {
        let embedded = report.as_obj().and_then(|o| o.get("provenance"));
        Provenance::resolve_with(|k| std::env::var(k).ok(), embedded)
    }

    /// The resolution order, parameterized over the environment lookup
    /// so tests never mutate real process environment (env mutation
    /// races with the byte-identity tests running in parallel).
    fn resolve_with(
        lookup: impl Fn(&str) -> Option<String>,
        report: Option<&Value>,
    ) -> Provenance {
        let field = |env: &str, key: &str, default: &str| {
            lookup(env)
                .or_else(|| {
                    report
                        .and_then(|p| p.as_obj())
                        .and_then(|o| o.get(key))
                        .and_then(|v| v.as_str())
                        .map(str::to_string)
                })
                .unwrap_or_else(|| default.to_string())
        };
        Provenance {
            commit: field(ENV_COMMIT, "commit", Self::DEFAULT_COMMIT),
            created_at: field(
                ENV_CREATED_AT,
                "created_at",
                Self::DEFAULT_CREATED_AT,
            ),
            toolchain: field(
                ENV_TOOLCHAIN,
                "toolchain",
                Self::DEFAULT_TOOLCHAIN,
            ),
        }
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("commit", Value::from(self.commit.clone())),
            ("created_at", Value::from(self.created_at.clone())),
            ("toolchain", Value::from(self.toolchain.clone())),
        ])
    }
}

/// One registry row: a single KPI value of a single cell of a single
/// report, keyed by (plan name, plan hash, scope, kpi) and stamped
/// with the report's provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct RegistryRow {
    /// Report schema version ([`KNOWN_REPORT_SCHEMAS`]).
    pub schema: String,
    /// Plan name (`matrix`, `transfer-oracle`, `transfer-tree`,
    /// `sweep`, `bench`, or a `--plan` override).
    pub plan: String,
    pub plan_hash: String,
    pub commit: String,
    pub created_at: String,
    pub toolchain: String,
    /// Cell coordinates inside the plan
    /// (e.g. `coulomb/gtx1070->rtx2080:.../profile`).
    pub scope: String,
    pub kpi: String,
    pub value: f64,
}

impl RegistryRow {
    fn to_record(&self) -> String {
        csv::write_record(&[
            &self.schema,
            &self.plan,
            &self.plan_hash,
            &self.commit,
            &self.created_at,
            &self.toolchain,
            &self.scope,
            &self.kpi,
            &fmt_value(self.value),
        ])
    }

    fn from_record(
        fields: &[String],
        line: usize,
    ) -> Result<RegistryRow, RegistryError> {
        if fields.len() != REGISTRY_HEADER.len() {
            return Err(RegistryError::Malformed(format!(
                "row {line}: expected {} columns, got {}",
                REGISTRY_HEADER.len(),
                fields.len()
            )));
        }
        let schema = fields[0].clone();
        if !KNOWN_REPORT_SCHEMAS.contains(&schema.as_str()) {
            return Err(RegistryError::UnknownSchema(schema));
        }
        let value: f64 = fields[8].parse().map_err(|_| {
            RegistryError::Malformed(format!(
                "row {line}: value {:?} is not a number",
                fields[8]
            ))
        })?;
        Ok(RegistryRow {
            schema,
            plan: fields[1].clone(),
            plan_hash: fields[2].clone(),
            commit: fields[3].clone(),
            created_at: fields[4].clone(),
            toolchain: fields[5].clone(),
            scope: fields[6].clone(),
            kpi: fields[7].clone(),
            value,
        })
    }
}

/// Canonical number spelling shared with the JSON writer (integers
/// render without a fractional part), so a CSV write → parse → write
/// round trip is byte-exact.
fn fmt_value(v: f64) -> String {
    Value::from(v).to_string_pretty(0)
}

/// Typed registry failure classes — callers match on these instead of
/// parsing message strings (same convention as
/// [`super::PlanError`]).
#[derive(Debug, Clone, PartialEq)]
pub enum RegistryError {
    /// A report (or a persisted row) carries a schema version the
    /// registry does not know. Rejecting is deliberate: silently
    /// skipping would let a schema bump hollow out the trend series.
    UnknownSchema(String),
    /// Structurally broken input: missing keys, wrong column counts,
    /// non-numeric values, header mismatch.
    Malformed(String),
    /// Filesystem failure (missing registry file, unreadable path).
    Io(String),
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::UnknownSchema(s) => write!(
                f,
                "unknown report schema {s:?}; the registry ingests: {}",
                KNOWN_REPORT_SCHEMAS.join(", ")
            ),
            RegistryError::Malformed(m) => write!(f, "malformed registry data: {m}"),
            RegistryError::Io(m) => write!(f, "registry I/O error: {m}"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// Storage backend for registry rows. Two implementations today
/// ([`MemStore`], [`CsvStore`]); the tuning-as-a-service direction can
/// add SQLite or a network store behind the same trait.
pub trait RegistryStore {
    /// Append rows (append-only: existing rows are never rewritten).
    fn append(&mut self, rows: &[RegistryRow]) -> Result<(), RegistryError>;
    /// Load every row, in append order.
    fn load(&self) -> Result<Vec<RegistryRow>, RegistryError>;
}

/// In-memory store (tests, service embedding).
#[derive(Debug, Default)]
pub struct MemStore {
    rows: Vec<RegistryRow>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }
}

impl RegistryStore for MemStore {
    fn append(&mut self, rows: &[RegistryRow]) -> Result<(), RegistryError> {
        validate_rows(rows)?;
        self.rows.extend(rows.iter().cloned());
        Ok(())
    }

    fn load(&self) -> Result<Vec<RegistryRow>, RegistryError> {
        Ok(self.rows.clone())
    }
}

/// Append-only CSV store (`registry/pcat.csv`): a header line followed
/// by one record per row. The header is validated on every touch so a
/// foreign CSV cannot be silently extended with incompatible columns.
#[derive(Debug, Clone)]
pub struct CsvStore {
    path: PathBuf,
}

impl CsvStore {
    pub fn new(path: impl Into<PathBuf>) -> CsvStore {
        CsvStore { path: path.into() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn header_line() -> String {
        csv::write_record(&REGISTRY_HEADER)
    }
}

impl RegistryStore for CsvStore {
    fn append(&mut self, rows: &[RegistryRow]) -> Result<(), RegistryError> {
        validate_rows(rows)?;
        let mut text = match std::fs::read_to_string(&self.path) {
            Ok(existing) => {
                check_header(&existing, &self.path)?;
                existing
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                format!("{}\n", Self::header_line())
            }
            Err(e) => {
                return Err(RegistryError::Io(format!(
                    "reading {}: {e}",
                    self.path.display()
                )))
            }
        };
        if !text.ends_with('\n') {
            text.push('\n');
        }
        for row in rows {
            text.push_str(&row.to_record());
            text.push('\n');
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| {
                    RegistryError::Io(format!(
                        "creating {}: {e}",
                        dir.display()
                    ))
                })?;
            }
        }
        std::fs::write(&self.path, text).map_err(|e| {
            RegistryError::Io(format!("writing {}: {e}", self.path.display()))
        })
    }

    fn load(&self) -> Result<Vec<RegistryRow>, RegistryError> {
        let text = std::fs::read_to_string(&self.path).map_err(|e| {
            RegistryError::Io(format!("reading {}: {e}", self.path.display()))
        })?;
        check_header(&text, &self.path)?;
        let records = csv::parse(&text)
            .map_err(|e| RegistryError::Malformed(e.to_string()))?;
        records
            .iter()
            .skip(1) // header
            .enumerate()
            .map(|(i, fields)| RegistryRow::from_record(fields, i + 2))
            .collect()
    }
}

fn validate_rows(rows: &[RegistryRow]) -> Result<(), RegistryError> {
    for r in rows {
        if !KNOWN_REPORT_SCHEMAS.contains(&r.schema.as_str()) {
            return Err(RegistryError::UnknownSchema(r.schema.clone()));
        }
    }
    Ok(())
}

fn check_header(text: &str, path: &Path) -> Result<(), RegistryError> {
    let first = text.lines().next().unwrap_or("");
    if first != CsvStore::header_line() {
        return Err(RegistryError::Malformed(format!(
            "{} does not start with the registry header ({}); got {:?}",
            path.display(),
            CsvStore::header_line(),
            first
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Report → rows extraction
// ---------------------------------------------------------------------------

fn get<'a>(v: &'a Value, key: &str) -> Result<&'a Value, RegistryError> {
    v.as_obj().and_then(|o| o.get(key)).ok_or_else(|| {
        RegistryError::Malformed(format!("missing report key {key:?}"))
    })
}

fn get_str<'a>(v: &'a Value, key: &str) -> Result<&'a str, RegistryError> {
    get(v, key)?.as_str().ok_or_else(|| {
        RegistryError::Malformed(format!("report key {key:?} is not a string"))
    })
}

fn get_f64(v: &Value, key: &str) -> Result<f64, RegistryError> {
    get(v, key)?.as_f64().ok_or_else(|| {
        RegistryError::Malformed(format!("report key {key:?} is not a number"))
    })
}

fn get_arr<'a>(v: &'a Value, key: &str) -> Result<&'a [Value], RegistryError> {
    get(v, key)?.as_arr().ok_or_else(|| {
        RegistryError::Malformed(format!("report key {key:?} is not an array"))
    })
}

/// Lower a report document into registry rows — one row per (cell,
/// KPI). The report's embedded `plan_hash` is preferred (it is part of
/// the deterministic byte contract); reports from before the stamping
/// era fall back to hashing the embedded plan echo. `plan_override`
/// replaces the derived plan name (`--plan` on the CLI).
///
/// KPIs per report kind:
/// * **matrix** — per aggregate cell: `mean_tests_to_wp`,
///   `mean_best_ms`, `mean_cost_s`, `wp_rate`; under an active fault
///   profile additionally `failure_rate`, `mean_retries`,
///   `mean_wasted_cost_s` (and the plan name gains a `-<profile>`
///   suffix, so hostile lanes keep their own trend series); when the
///   plan arms the stopping criteria additionally one `stop_<reason>`
///   count KPI per observed stop reason.
/// * **transfer** — per aggregate cell: `median_tests_to_wp`,
///   `median_best_over_oracle`, `mean_cost_s`, `wp_rate` (plus the
///   same fault KPIs and plan-name suffix under faults); per source
///   endpoint: `median_mae`, `median_r2`.
/// * **sweep** — per cell: `median_tests_to_wp`,
///   `median_best_over_oracle`, `median_mae`, `median_r2`.
/// * **bench** — per result: `mean_ms`, `min_ms`; every derived
///   scalar; the timed smoke matrix's `wall_s` (scoring-round
///   latency) when present.
/// * **serve** — aggregate `load` scope: `throughput_rps`, `hit_rate`,
///   `mean_latency_s`, `p50/p95/p99_latency_s`, `fills`; per warm
///   endpoint: `best_ms` (cold endpoints carry no answer to trend).
pub fn extract_rows(
    report: &Value,
    plan_override: Option<&str>,
) -> Result<Vec<RegistryRow>, RegistryError> {
    let schema = get_str(report, "schema")?.to_string();
    if !KNOWN_REPORT_SCHEMAS.contains(&schema.as_str()) {
        return Err(RegistryError::UnknownSchema(schema));
    }
    let plan_echo = get(report, "plan").cloned().unwrap_or_else(|_| obj(vec![]));
    let hash = match report.as_obj().and_then(|o| o.get("plan_hash")) {
        Some(Value::Str(h)) => h.clone(),
        _ => plan_hash(&schema, &plan_echo),
    };
    let prov = Provenance::for_rows(report);

    // fault-injected lanes get their own plan-name suffix: a hostile
    // run's failure rates and step counts must never be compared
    // against (or shadow) the fault-free baseline's trend series
    let fault_suffix = plan_echo
        .as_obj()
        .and_then(|o| o.get("fault_profile"))
        .and_then(|v| v.as_str())
        .map(|p| format!("-{p}"))
        .unwrap_or_default();
    let derived_plan_name = match schema.as_str() {
        PLAN_REPORT_SCHEMA => format!("matrix{fault_suffix}"),
        TRANSFER_REPORT_SCHEMA => {
            // oracle and tree lanes share cell scopes, so the model
            // kind must live in the plan name or the two lanes would
            // shadow each other in the (plan, scope, kpi) key space
            let model = plan_echo
                .as_obj()
                .and_then(|o| o.get("model"))
                .and_then(|v| v.as_str())
                .unwrap_or("oracle");
            format!("transfer-{model}{fault_suffix}")
        }
        SWEEP_REPORT_SCHEMA => "sweep".to_string(),
        BENCH_REPORT_SCHEMA => "bench".to_string(),
        SERVE_REPORT_SCHEMA => "serve".to_string(),
        _ => unreachable!("schema validated above"),
    };
    let plan_name = plan_override.unwrap_or(&derived_plan_name).to_string();

    let row = |scope: String, kpi: &str, value: f64| RegistryRow {
        schema: schema.clone(),
        plan: plan_name.clone(),
        plan_hash: hash.clone(),
        commit: prov.commit.clone(),
        created_at: prov.created_at.clone(),
        toolchain: prov.toolchain.clone(),
        scope,
        kpi: kpi.to_string(),
        value,
    };

    let mut rows = Vec::new();
    match schema.as_str() {
        PLAN_REPORT_SCHEMA => {
            for a in get_arr(report, "aggregates")? {
                let mut target = get_str(a, "gpu")?.to_string();
                // input key only exists on plans with a real input axis
                if let Some(input) =
                    a.as_obj().and_then(|o| o.get("input")).and_then(|v| v.as_str())
                {
                    target = format!("{target}:{input}");
                }
                let scope = format!(
                    "{}/{}/{}",
                    get_str(a, "benchmark")?,
                    target,
                    get_str(a, "searcher")?
                );
                rows.push(row(
                    scope.clone(),
                    "mean_tests_to_wp",
                    get_f64(a, "mean_tests_to_wp")?,
                ));
                rows.push(row(
                    scope.clone(),
                    "mean_best_ms",
                    get_f64(a, "mean_best_ms")?,
                ));
                rows.push(row(
                    scope.clone(),
                    "mean_cost_s",
                    get_f64(a, "mean_cost_s")?,
                ));
                push_fault_kpis(&mut rows, &row, &scope, a)?;
                push_stop_kpis(&mut rows, &row, &scope, a);
                rows.push(row(scope, "wp_rate", wp_rate(a)?));
            }
        }
        TRANSFER_REPORT_SCHEMA => {
            for a in get_arr(report, "aggregates")? {
                let scope = format!(
                    "{}/{}:{}->{}:{}/{}",
                    get_str(a, "benchmark")?,
                    get_str(a, "source_gpu")?,
                    get_str(a, "source_input")?,
                    get_str(a, "target_gpu")?,
                    get_str(a, "target_input")?,
                    get_str(a, "searcher")?
                );
                rows.push(row(
                    scope.clone(),
                    "median_tests_to_wp",
                    get_f64(a, "median_tests_to_wp")?,
                ));
                rows.push(row(
                    scope.clone(),
                    "median_best_over_oracle",
                    get_f64(a, "median_best_over_oracle")?,
                ));
                rows.push(row(
                    scope.clone(),
                    "mean_cost_s",
                    get_f64(a, "mean_cost_s")?,
                ));
                push_fault_kpis(&mut rows, &row, &scope, a)?;
                rows.push(row(scope, "wp_rate", wp_rate(a)?));
            }
            for q in get_arr(report, "model_quality")? {
                let scope = format!(
                    "model/{}/{}:{}",
                    get_str(q, "benchmark")?,
                    get_str(q, "source_gpu")?,
                    get_str(q, "source_input")?
                );
                let maes = counter_metric(q, "mae")?;
                let r2s = counter_metric(q, "r2")?;
                rows.push(row(scope.clone(), "median_mae", median(&maes)));
                rows.push(row(scope, "median_r2", median(&r2s)));
            }
        }
        SWEEP_REPORT_SCHEMA => {
            for c in get_arr(report, "cells")? {
                let scope = format!(
                    "{}/{}@{}/{}",
                    get_str(c, "benchmark")?,
                    get_str(c, "model")?,
                    fmt_value(get_f64(c, "fraction")?),
                    get_str(c, "searcher")?
                );
                rows.push(row(
                    scope.clone(),
                    "median_tests_to_wp",
                    get_f64(c, "median_tests_to_wp")?,
                ));
                rows.push(row(
                    scope.clone(),
                    "median_best_over_oracle",
                    get_f64(c, "median_best_over_oracle")?,
                ));
                rows.push(row(
                    scope.clone(),
                    "median_mae",
                    get_f64(c, "median_mae")?,
                ));
                rows.push(row(scope, "median_r2", get_f64(c, "median_r2")?));
            }
        }
        BENCH_REPORT_SCHEMA => {
            for r in get_arr(report, "results")? {
                let scope = format!("result/{}", get_str(r, "name")?);
                rows.push(row(
                    scope.clone(),
                    "mean_ms",
                    get_f64(r, "mean_ms")?,
                ));
                rows.push(row(scope, "min_ms", get_f64(r, "min_ms")?));
            }
            if let Some(derived) =
                report.as_obj().and_then(|o| o.get("derived")).and_then(|v| v.as_obj())
            {
                for (name, v) in derived {
                    if let Some(x) = v.as_f64() {
                        rows.push(row("derived".to_string(), name, x));
                    }
                }
            }
            // scripts/bench.sh merges the timed smoke matrix in after
            // the bench run — the scoring-round-latency trend KPI
            if let Some(sm) =
                report.as_obj().and_then(|o| o.get("smoke_matrix"))
            {
                if let Ok(wall) = get_f64(sm, "wall_s") {
                    rows.push(row("smoke_matrix".to_string(), "wall_s", wall));
                }
            }
        }
        SERVE_REPORT_SCHEMA => {
            let results = get(report, "results")?;
            for kpi in [
                "throughput_rps",
                "hit_rate",
                "mean_latency_s",
                "p50_latency_s",
                "p95_latency_s",
                "p99_latency_s",
                "fills",
            ] {
                rows.push(row(
                    "load".to_string(),
                    kpi,
                    get_f64(results, kpi)?,
                ));
            }
            for e in get_arr(report, "endpoints")? {
                // cold endpoints serialize best_ms as null — never
                // answered, so there is no quality value to trend
                let best = e
                    .as_obj()
                    .and_then(|o| o.get("best_ms"))
                    .and_then(|v| v.as_f64());
                if let Some(best) = best {
                    let scope = format!(
                        "{}/{}:{}",
                        get_str(e, "benchmark")?,
                        get_str(e, "gpu")?,
                        get_str(e, "input")?
                    );
                    rows.push(row(scope, "best_ms", best));
                }
            }
        }
        _ => unreachable!("schema validated above"),
    }
    Ok(rows)
}

/// Fault-accounting KPIs of one aggregate cell, if present. The keys
/// exist only under an active fault profile (the conditional
/// serialization contract), so presence is the signal — but once one
/// fault key exists, all three must, hence `get_f64` errors instead of
/// skipping.
fn push_fault_kpis(
    rows: &mut Vec<RegistryRow>,
    row: &impl Fn(String, &str, f64) -> RegistryRow,
    scope: &str,
    cell: &Value,
) -> Result<(), RegistryError> {
    let present = cell
        .as_obj()
        .map_or(false, |o| o.contains_key("failure_rate"));
    if !present {
        return Ok(());
    }
    for kpi in ["failure_rate", "mean_retries", "mean_wasted_cost_s"] {
        rows.push(row(scope.to_string(), kpi, get_f64(cell, kpi)?));
    }
    Ok(())
}

/// Stop-reason counts of one aggregate cell, if present. The `stops`
/// object exists only when the plan arms the stopping criteria (the
/// same conditional-serialization contract as the fault keys); each
/// reason becomes a `stop_<reason>` KPI so armed plans can trend *why*
/// their searchers terminate, not just how fast they converge.
fn push_stop_kpis(
    rows: &mut Vec<RegistryRow>,
    row: &impl Fn(String, &str, f64) -> RegistryRow,
    scope: &str,
    cell: &Value,
) {
    let stops = match cell.as_obj().and_then(|o| o.get("stops")) {
        Some(v) => v,
        None => return,
    };
    if let Some(o) = stops.as_obj() {
        for (reason, count) in o {
            if let Some(n) = count.as_f64() {
                rows.push(row(
                    scope.to_string(),
                    &format!("stop_{reason}"),
                    n,
                ));
            }
        }
    }
}

/// `wp_hits / runs` of one aggregate/cell object (0 when `runs` is 0).
fn wp_rate(cell: &Value) -> Result<f64, RegistryError> {
    let runs = get_f64(cell, "runs")?;
    let hits = get_f64(cell, "wp_hits")?;
    Ok(if runs > 0.0 { hits / runs } else { 0.0 })
}

/// One per-counter metric column of an `EndpointQuality` JSON block.
fn counter_metric(q: &Value, key: &str) -> Result<Vec<f64>, RegistryError> {
    get_arr(q, "counters")?
        .iter()
        .map(|c| get_f64(c, key))
        .collect()
}

// ---------------------------------------------------------------------------
// Typed KPI tolerances + comparison
// ---------------------------------------------------------------------------

/// Which direction of drift degrades the KPI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Steps, latency, error metrics: only an *increase* beyond the
    /// allowance fails; improvements always pass.
    LowerIsBetter,
    /// Hit rates, R²: only a *decrease* beyond the allowance fails.
    HigherIsBetter,
    /// Determinism-style KPIs: any drift beyond the allowance fails.
    TwoSided,
}

/// Typed tolerance for one KPI: optional hard `min`/`max` bounds on
/// the **current** value, plus an `abs` + `rel` drift allowance
/// against the **baseline** value (allowed drift =
/// `abs + rel × |baseline|`), applied directionally.
#[derive(Debug, Clone)]
pub struct Tolerance {
    /// KPI name this tolerance applies to (exact match).
    pub kpi: String,
    pub direction: Direction,
    pub abs: f64,
    pub rel: f64,
    pub min: Option<f64>,
    pub max: Option<f64>,
}

impl Tolerance {
    pub fn new(kpi: &str, direction: Direction, abs: f64, rel: f64) -> Self {
        Tolerance {
            kpi: kpi.to_string(),
            direction,
            abs,
            rel,
            min: None,
            max: None,
        }
    }

    /// Catch-all used for KPIs with no listed tolerance: two-sided
    /// 25% relative drift.
    pub fn fallback() -> Self {
        Tolerance::new("*", Direction::TwoSided, 1e-9, 0.25)
    }

    /// Evaluate `current` against `baseline`. `Ok(())` on pass;
    /// `Err(bound)` names the violated bound (rendered into the
    /// pass/fail table and the CLI error).
    pub fn check(&self, baseline: f64, current: f64) -> Result<(), String> {
        if let Some(min) = self.min {
            if current < min {
                return Err(format!("value {current} < hard min {min}"));
            }
        }
        if let Some(max) = self.max {
            if current > max {
                return Err(format!("value {current} > hard max {max}"));
            }
        }
        let allowed = self.abs + self.rel * baseline.abs();
        let bound = |limit: f64, cmp: &str| {
            format!(
                "value {current} {cmp} {limit} (baseline {baseline}, \
                 allowance abs {} + rel {})",
                self.abs, self.rel
            )
        };
        match self.direction {
            Direction::LowerIsBetter if current > baseline + allowed => {
                Err(bound(baseline + allowed, ">"))
            }
            Direction::HigherIsBetter if current < baseline - allowed => {
                Err(bound(baseline - allowed, "<"))
            }
            Direction::TwoSided if (current - baseline).abs() > allowed => {
                Err(if current > baseline {
                    bound(baseline + allowed, ">")
                } else {
                    bound(baseline - allowed, "<")
                })
            }
            _ => Ok(()),
        }
    }
}

/// The default tolerance table for the KPIs [`extract_rows`] emits.
/// Convergence/latency KPIs are `LowerIsBetter` with generous
/// allowances (searcher medians are noisy at smoke scale); quality
/// KPIs are directional with hard bounds where the metric has a
/// closed range.
pub fn default_tolerances() -> Vec<Tolerance> {
    use Direction::*;
    let t = Tolerance::new;
    vec![
        // convergence: median/mean steps-to-within-X% may regress by
        // at most 25% + 2 steps before the gate trips
        t("median_tests_to_wp", LowerIsBetter, 2.0, 0.25),
        t("mean_tests_to_wp", LowerIsBetter, 2.0, 0.25),
        // tuned-result quality
        t("mean_best_ms", LowerIsBetter, 1e-9, 0.10),
        t("median_best_over_oracle", LowerIsBetter, 0.02, 0.10),
        Tolerance {
            min: Some(0.0),
            max: Some(1.0),
            ..t("wp_rate", HigherIsBetter, 0.15, 0.0)
        },
        // simulated tuning cost
        t("mean_cost_s", LowerIsBetter, 0.5, 0.25),
        // fault robustness (hostile smoke lane): rate is a closed-range
        // ratio by construction, the other two absorb retry noise
        Tolerance {
            min: Some(0.0),
            max: Some(1.0),
            ..t("failure_rate", LowerIsBetter, 0.05, 0.25)
        },
        t("mean_retries", LowerIsBetter, 1.0, 0.25),
        t("mean_wasted_cost_s", LowerIsBetter, 0.5, 0.25),
        // model quality
        t("median_mae", LowerIsBetter, 1e-6, 0.25),
        Tolerance {
            max: Some(1.0 + 1e-9),
            ..t("median_r2", HigherIsBetter, 0.02, 0.05)
        },
        // bench latencies (scoring-round + smoke wall clock): wall
        // clock on shared CI runners is noisy, hence the wide band
        t("mean_ms", LowerIsBetter, 0.05, 0.30),
        t("min_ms", LowerIsBetter, 0.05, 0.30),
        t("wall_s", LowerIsBetter, 0.5, 0.30),
        // serving KPIs: all simulated, so bands are tight. Hit rate is
        // a closed-range ratio; fills is an exact integer invariant
        // (== logical misses), so any drift at all is a regression.
        Tolerance {
            min: Some(0.0),
            max: Some(1.0),
            ..t("hit_rate", HigherIsBetter, 0.05, 0.0)
        },
        t("throughput_rps", HigherIsBetter, 1e-9, 0.25),
        t("mean_latency_s", LowerIsBetter, 1e-6, 0.25),
        t("p50_latency_s", LowerIsBetter, 1e-6, 0.25),
        t("p95_latency_s", LowerIsBetter, 1e-6, 0.25),
        t("p99_latency_s", LowerIsBetter, 1e-6, 0.25),
        t("fills", TwoSided, 0.5, 0.0),
        // served answer quality per endpoint
        t("best_ms", LowerIsBetter, 1e-9, 0.10),
    ]
}

fn tolerance_for<'a>(tols: &'a [Tolerance], kpi: &str) -> Option<&'a Tolerance> {
    tols.iter().find(|t| t.kpi == kpi)
}

/// Outcome of one compared (plan, scope, kpi) key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompareStatus {
    Pass,
    Fail,
    /// Present in the current rows only (a new cell/KPI — informational).
    New,
    /// Present in the baseline only (a cell/KPI disappeared —
    /// informational, surfaced so coverage loss is visible).
    Gone,
}

impl CompareStatus {
    pub fn name(&self) -> &'static str {
        match self {
            CompareStatus::Pass => "PASS",
            CompareStatus::Fail => "FAIL",
            CompareStatus::New => "NEW",
            CompareStatus::Gone => "GONE",
        }
    }
}

/// One row of the compare verdict: the key, both values and the bound
/// that passed or failed.
#[derive(Debug, Clone)]
pub struct CompareFinding {
    pub plan: String,
    pub scope: String,
    pub kpi: String,
    pub baseline: Option<f64>,
    pub current: Option<f64>,
    pub status: CompareStatus,
    /// Violated bound on `Fail` (value, limit, allowance); empty on
    /// `Pass`.
    pub bound: String,
}

/// Latest row per (plan, scope, kpi): newest `created_at` wins
/// (ISO-8601 strings compare lexicographically), with ties broken on
/// append order — later row wins. The tie-break matters on the
/// deterministic CI path, which deliberately leaves `PCAT_CREATED_AT`
/// unset so *every* row shares the constant default timestamp; without
/// it the join would be ambiguous there. It also keeps the join total
/// when registries are merged out of chronological order.
fn latest_by_key(
    rows: &[RegistryRow],
) -> BTreeMap<(String, String, String), &RegistryRow> {
    let mut map: BTreeMap<(String, String, String), &RegistryRow> =
        BTreeMap::new();
    for r in rows {
        let key = (r.plan.clone(), r.scope.clone(), r.kpi.clone());
        match map.get(&key) {
            Some(prev) if prev.created_at > r.created_at => {}
            _ => {
                map.insert(key, r);
            }
        }
    }
    map
}

/// Compare the latest current rows against the latest baseline rows
/// under the given tolerances. Keys present on only one side become
/// informational `New`/`Gone` findings (never failures); keys present
/// on both are checked and become `Pass`/`Fail`. Output is sorted by
/// (plan, scope, kpi) — deterministic for rendering and tests.
pub fn compare_rows(
    baseline: &[RegistryRow],
    current: &[RegistryRow],
    tolerances: &[Tolerance],
) -> Vec<CompareFinding> {
    let base = latest_by_key(baseline);
    let cur = latest_by_key(current);
    let fallback = Tolerance::fallback();
    let mut keys: Vec<&(String, String, String)> =
        base.keys().chain(cur.keys()).collect();
    keys.sort();
    keys.dedup();
    keys.into_iter()
        .map(|key| {
            let (plan, scope, kpi) = key.clone();
            let b = base.get(key).map(|r| r.value);
            let c = cur.get(key).map(|r| r.value);
            let (status, bound) = match (b, c) {
                (Some(bv), Some(cv)) => {
                    let tol =
                        tolerance_for(tolerances, &kpi).unwrap_or(&fallback);
                    match tol.check(bv, cv) {
                        Ok(()) => (CompareStatus::Pass, String::new()),
                        Err(bound) => (CompareStatus::Fail, bound),
                    }
                }
                (None, Some(_)) => (CompareStatus::New, String::new()),
                (Some(_), None) => (CompareStatus::Gone, String::new()),
                (None, None) => unreachable!("key from one of the maps"),
            };
            CompareFinding {
                plan,
                scope,
                kpi,
                baseline: b,
                current: c,
                status,
                bound,
            }
        })
        .collect()
}

/// Did any compared key fail?
pub fn has_failures(findings: &[CompareFinding]) -> bool {
    findings.iter().any(|f| f.status == CompareStatus::Fail)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_row(kpi: &str, value: f64) -> RegistryRow {
        RegistryRow {
            schema: PLAN_REPORT_SCHEMA.to_string(),
            plan: "matrix".to_string(),
            plan_hash: "0123456789abcdef".to_string(),
            commit: "unknown".to_string(),
            created_at: Provenance::DEFAULT_CREATED_AT.to_string(),
            toolchain: "unknown".to_string(),
            scope: "coulomb/gtx1070/profile".to_string(),
            kpi: kpi.to_string(),
            value,
        }
    }

    #[test]
    fn plan_hash_depends_on_schema_and_plan_only() {
        let plan = obj(vec![("seeds", Value::from(3usize))]);
        let a = plan_hash(PLAN_REPORT_SCHEMA, &plan);
        assert_eq!(a, plan_hash(PLAN_REPORT_SCHEMA, &plan));
        assert_eq!(a.len(), 16);
        assert_ne!(a, plan_hash(SWEEP_REPORT_SCHEMA, &plan));
        let other = obj(vec![("seeds", Value::from(4usize))]);
        assert_ne!(a, plan_hash(PLAN_REPORT_SCHEMA, &other));
    }

    #[test]
    fn provenance_resolution_order_env_report_default() {
        let report_prov = obj(vec![
            ("commit", Value::from("reportsha")),
            ("created_at", Value::from("2026-01-01T00:00:00Z")),
            ("toolchain", Value::from("rustc-x")),
        ]);
        // no env, no report: defaults
        let p = Provenance::resolve_with(|_| None, None);
        assert_eq!(p.commit, Provenance::DEFAULT_COMMIT);
        assert_eq!(p.created_at, Provenance::DEFAULT_CREATED_AT);
        // report wins over defaults
        let p = Provenance::resolve_with(|_| None, Some(&report_prov));
        assert_eq!(p.commit, "reportsha");
        assert_eq!(p.toolchain, "rustc-x");
        // env wins over report
        let p = Provenance::resolve_with(
            |k| (k == ENV_COMMIT).then(|| "envsha".to_string()),
            Some(&report_prov),
        );
        assert_eq!(p.commit, "envsha");
        assert_eq!(p.created_at, "2026-01-01T00:00:00Z");
    }

    #[test]
    fn mem_store_round_trips_and_rejects_unknown_schema() {
        let mut store = MemStore::new();
        let rows = vec![sample_row("mean_tests_to_wp", 12.5)];
        store.append(&rows).unwrap();
        assert_eq!(store.load().unwrap(), rows);
        let mut bad = sample_row("x", 1.0);
        bad.schema = "pcat-plan-report/v99".to_string();
        assert_eq!(
            store.append(&[bad]),
            Err(RegistryError::UnknownSchema(
                "pcat-plan-report/v99".to_string()
            ))
        );
    }

    #[test]
    fn csv_store_round_trips_bit_identically() {
        let dir = std::env::temp_dir().join("pcat_registry_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.csv");
        std::fs::remove_file(&path).ok();
        let mut store = CsvStore::new(&path);
        let rows = vec![
            sample_row("mean_tests_to_wp", 12.5),
            sample_row("mean_best_ms", 0.03125),
            sample_row("wp_rate", 1.0),
        ];
        store.append(&rows[..2]).unwrap();
        store.append(&rows[2..]).unwrap(); // append-only across calls
        let loaded = store.load().unwrap();
        assert_eq!(loaded, rows);
        // a second write of the loaded rows produces identical bytes
        let text = std::fs::read_to_string(&path).unwrap();
        let path2 = dir.join("roundtrip2.csv");
        std::fs::remove_file(&path2).ok();
        let mut store2 = CsvStore::new(&path2);
        store2.append(&loaded).unwrap();
        assert_eq!(std::fs::read_to_string(&path2).unwrap(), text);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn csv_store_rejects_unknown_schema_rows_on_load() {
        let dir = std::env::temp_dir().join("pcat_registry_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("badschema.csv");
        let text = format!(
            "{}\npcat-bench/v0,bench,00,unknown,t,unknown,s,kpi,1\n",
            csv::write_record(&REGISTRY_HEADER)
        );
        std::fs::write(&path, text).unwrap();
        let err = CsvStore::new(&path).load().unwrap_err();
        assert_eq!(
            err,
            RegistryError::UnknownSchema("pcat-bench/v0".to_string())
        );
        // the error formats with the known-schema list, not just a name
        assert!(err.to_string().contains(PLAN_REPORT_SCHEMA));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_store_rejects_foreign_header() {
        let dir = std::env::temp_dir().join("pcat_registry_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("foreign.csv");
        std::fs::write(&path, "a,b,c\n1,2,3\n").unwrap();
        assert!(matches!(
            CsvStore::new(&path).load(),
            Err(RegistryError::Malformed(_))
        ));
        assert!(matches!(
            CsvStore::new(&path).append(&[sample_row("k", 1.0)]),
            Err(RegistryError::Malformed(_))
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn extract_rejects_unknown_report_schema() {
        let report = parse(
            r#"{"schema": "pcat-plan-report/v99", "plan": {}, "aggregates": []}"#,
        )
        .unwrap();
        assert_eq!(
            extract_rows(&report, None),
            Err(RegistryError::UnknownSchema(
                "pcat-plan-report/v99".to_string()
            ))
        );
    }

    #[test]
    fn extract_serve_report_rows() {
        let report = parse(
            r#"{
                "schema": "pcat-serve-report/v1",
                "plan": {"base_seed": "0", "requests": 400},
                "plan_hash": "cafe1234",
                "provenance": {
                    "commit": "unknown",
                    "created_at": "1970-01-01T00:00:00Z",
                    "toolchain": "unknown"
                },
                "endpoints": [
                    {"benchmark": "coulomb", "gpu": "gtx1070",
                     "input": "default", "requests": 300, "hits": 299,
                     "misses": 1, "best_ms": 1.25, "config": [1, 2]},
                    {"benchmark": "transpose", "gpu": "gtx750",
                     "input": "default", "requests": 0, "hits": 0,
                     "misses": 0, "best_ms": null, "config": null}
                ],
                "results": {
                    "requests": 400, "hits": 399, "misses": 1,
                    "fills": 1, "prewarmed": 3, "hit_rate": 0.9975,
                    "mean_latency_s": 0.0001, "p50_latency_s": 0.00005,
                    "p95_latency_s": 0.00005, "p99_latency_s": 0.0002,
                    "total_cost_s": 0.04, "throughput_rps": 10000.0
                }
            }"#,
        )
        .unwrap();
        let rows = extract_rows(&report, None).unwrap();
        // 7 aggregate KPIs + 1 warm endpoint (the cold one is skipped)
        assert_eq!(rows.len(), 8);
        assert!(rows.iter().all(|r| r.plan == "serve"));
        assert!(rows.iter().all(|r| r.plan_hash == "cafe1234"));
        let load = |kpi: &str| {
            rows.iter()
                .find(|r| r.scope == "load" && r.kpi == kpi)
                .map(|r| r.value)
        };
        assert_eq!(load("hit_rate"), Some(0.9975));
        assert_eq!(load("throughput_rps"), Some(10000.0));
        assert_eq!(load("fills"), Some(1.0));
        let ep = rows
            .iter()
            .find(|r| r.scope == "coulomb/gtx1070:default")
            .unwrap();
        assert_eq!(ep.kpi, "best_ms");
        assert_eq!(ep.value, 1.25);
        assert!(!rows
            .iter()
            .any(|r| r.scope.starts_with("transpose/")));
        // every serve KPI has a gate tolerance configured
        let tols = default_tolerances();
        for r in &rows {
            assert!(
                tolerance_for(&tols, &r.kpi).is_some(),
                "no tolerance for serve KPI {}",
                r.kpi
            );
        }
    }

    #[test]
    fn tolerance_abs_vs_rel() {
        // pure absolute allowance
        let abs = Tolerance::new("k", Direction::LowerIsBetter, 2.0, 0.0);
        assert!(abs.check(10.0, 12.0).is_ok()); // exactly at the bound
        assert!(abs.check(10.0, 12.1).is_err());
        // pure relative allowance: 25% of baseline
        let rel = Tolerance::new("k", Direction::LowerIsBetter, 0.0, 0.25);
        assert!(rel.check(100.0, 125.0).is_ok());
        assert!(rel.check(100.0, 125.5).is_err());
        // the two compose additively
        let both = Tolerance::new("k", Direction::LowerIsBetter, 2.0, 0.25);
        assert!(both.check(100.0, 127.0).is_ok());
        assert!(both.check(100.0, 127.5).is_err());
        // rel scales with |baseline|, so a zero baseline leaves only abs
        assert!(rel.check(0.0, 0.1).is_err());
        assert!(abs.check(0.0, 1.9).is_ok());
    }

    #[test]
    fn tolerance_directions() {
        let lower = Tolerance::new("k", Direction::LowerIsBetter, 1.0, 0.0);
        // improvements never fail, however large
        assert!(lower.check(100.0, 1.0).is_ok());
        assert!(lower.check(100.0, 102.0).is_err());
        let higher = Tolerance::new("k", Direction::HigherIsBetter, 1.0, 0.0);
        assert!(higher.check(0.5, 1.0).is_ok());
        assert!(higher.check(0.5, 0.4).is_ok()); // within abs 1.0
        assert!(higher.check(2.0, 0.5).is_err());
        let two = Tolerance::new("k", Direction::TwoSided, 1.0, 0.0);
        assert!(two.check(10.0, 10.9).is_ok());
        assert!(two.check(10.0, 11.5).is_err());
        assert!(two.check(10.0, 8.5).is_err());
    }

    #[test]
    fn tolerance_min_max_edges() {
        let t = Tolerance {
            min: Some(0.0),
            max: Some(1.0),
            ..Tolerance::new("wp_rate", Direction::HigherIsBetter, 10.0, 0.0)
        };
        // hard bounds trump the (here huge) drift allowance
        assert!(t.check(0.5, 1.5).is_err());
        assert!(t.check(0.5, -0.1).is_err());
        // exactly on the bounds passes
        assert!(t.check(0.5, 1.0).is_ok());
        assert!(t.check(0.5, 0.0).is_ok());
        // the failure message names the violated bound
        let msg = t.check(0.5, 1.5).unwrap_err();
        assert!(msg.contains("hard max"), "{msg}");
    }

    #[test]
    fn compare_flags_failures_new_and_gone() {
        let base = vec![
            sample_row("mean_tests_to_wp", 10.0),
            sample_row("mean_cost_s", 1.0),
        ];
        let mut degraded = sample_row("mean_tests_to_wp", 100.0);
        degraded.scope = base[0].scope.clone();
        let mut extra = sample_row("mean_best_ms", 0.5);
        extra.scope = "coulomb/gtx1070/random".to_string();
        let cur = vec![degraded, extra];
        let findings = compare_rows(&base, &cur, &default_tolerances());
        assert!(has_failures(&findings));
        let fail = findings
            .iter()
            .find(|f| f.status == CompareStatus::Fail)
            .unwrap();
        assert_eq!(fail.kpi, "mean_tests_to_wp");
        assert_eq!(fail.current, Some(100.0));
        assert!(fail.bound.contains("100"), "bound: {}", fail.bound);
        assert!(findings.iter().any(|f| f.status == CompareStatus::New));
        assert!(findings.iter().any(|f| f.status == CompareStatus::Gone));
        // New/Gone alone are never failures
        let informational: Vec<RegistryRow> = Vec::new();
        let only_new = compare_rows(&informational, &base, &default_tolerances());
        assert!(!has_failures(&only_new));
        assert!(only_new.iter().all(|f| f.status == CompareStatus::New));
    }

    #[test]
    fn compare_uses_latest_row_per_key() {
        let base = vec![sample_row("mean_tests_to_wp", 10.0)];
        // an older bad value followed by a newer good one: the series'
        // latest entry is what counts
        let cur = vec![
            sample_row("mean_tests_to_wp", 500.0),
            sample_row("mean_tests_to_wp", 10.5),
        ];
        let findings = compare_rows(&base, &cur, &default_tolerances());
        assert!(!has_failures(&findings));
        assert_eq!(findings[0].current, Some(10.5));
    }

    #[test]
    fn equal_timestamps_tie_break_on_append_order() {
        // The deterministic CI path unsets PCAT_CREATED_AT, so every
        // row shares the constant default timestamp; the latest-row
        // join must still be unambiguous: later append wins.
        let a = sample_row("mean_tests_to_wp", 500.0);
        let b = sample_row("mean_tests_to_wp", 10.5);
        assert_eq!(a.created_at, b.created_at);
        let rows = vec![a, b];
        let latest = latest_by_key(&rows);
        assert_eq!(latest.len(), 1);
        assert_eq!(latest.values().next().unwrap().value, 10.5);
    }

    #[test]
    fn newer_timestamp_beats_later_append() {
        // Merged registries can interleave timestamps out of append
        // order; the row with the newest created_at wins regardless of
        // its position in the file.
        let mut newer = sample_row("mean_tests_to_wp", 7.0);
        newer.created_at = "2026-02-01T00:00:00Z".to_string();
        let mut older = sample_row("mean_tests_to_wp", 900.0);
        older.created_at = "2026-01-01T00:00:00Z".to_string();
        let rows = vec![newer, older];
        let latest = latest_by_key(&rows);
        assert_eq!(latest.values().next().unwrap().value, 7.0);
    }

    #[test]
    fn fault_lanes_get_their_own_plan_name_and_kpis() {
        let report = parse(
            r#"{"schema": "pcat-plan-report/v1",
                "plan": {"fault_profile": "hostile"},
                "aggregates": [{"benchmark": "coulomb", "gpu": "gtx1070",
                    "searcher": "random", "runs": 2, "wp_hits": 1,
                    "mean_tests_to_wp": 5, "mean_best_ms": 1,
                    "mean_cost_s": 2, "failure_rate": 0.2,
                    "mean_retries": 1.5, "mean_wasted_cost_s": 0.4}]}"#,
        )
        .unwrap();
        let rows = extract_rows(&report, None).unwrap();
        // the hostile lane keeps its own trend series
        assert!(rows.iter().all(|r| r.plan == "matrix-hostile"));
        for kpi in ["failure_rate", "mean_retries", "mean_wasted_cost_s"] {
            assert!(
                rows.iter().any(|r| r.kpi == kpi),
                "missing fault KPI {kpi}"
            );
        }
        // a fault-free report keeps the baseline name and no fault KPIs
        let clean = parse(
            r#"{"schema": "pcat-plan-report/v1", "plan": {},
                "aggregates": [{"benchmark": "coulomb", "gpu": "gtx1070",
                    "searcher": "random", "runs": 2, "wp_hits": 1,
                    "mean_tests_to_wp": 5, "mean_best_ms": 1,
                    "mean_cost_s": 2}]}"#,
        )
        .unwrap();
        let rows = extract_rows(&clean, None).unwrap();
        assert!(rows.iter().all(|r| r.plan == "matrix"));
        assert!(rows.iter().all(|r| r.kpi != "failure_rate"));
    }

    #[test]
    fn fault_tolerances_are_directional_with_hard_range() {
        let tols = default_tolerances();
        let t = tols.iter().find(|t| t.kpi == "failure_rate").unwrap();
        assert!(t.check(0.2, 0.1).is_ok(), "improvement must pass");
        assert!(t.check(0.2, 0.5).is_err(), "large regression must fail");
        assert!(t.check(0.2, 1.1).is_err(), "hard max 1.0 must trip");
        assert!(tols.iter().any(|t| t.kpi == "mean_retries"));
        assert!(tols.iter().any(|t| t.kpi == "mean_wasted_cost_s"));
    }

    #[test]
    fn value_formatting_matches_json_writer() {
        assert_eq!(fmt_value(42.0), "42");
        assert_eq!(fmt_value(0.25), "0.25");
        assert_eq!(fmt_value(-1.5), "-1.5");
        // round-trips exactly through parse
        for v in [42.0, 0.25, 1.0 / 3.0, 123456.789] {
            let s = fmt_value(v);
            assert_eq!(s.parse::<f64>().unwrap(), v);
        }
    }
}
