//! Sample-efficiency sensitivity sweep: convergence vs training
//! fraction.
//!
//! The paper's method is only economical because the source model is
//! supposed to work when trained on a *fraction* of the tuning space
//! ("requires the tuning space to be sampled on any GPU", §5) — and
//! the sample-size literature (PAPERS.md: "The Impact of Sample
//! Sizes", "Benchmarking optimization algorithms for auto-tuning GPU
//! kernels") says such a claim needs a controlled sweep, not a single
//! point. [`SweepPlan`] crosses `train-fraction × model × benchmark`
//! on one fixed (source GPU → target GPU) endpoint pair and reports,
//! per combination: the per-cell convergence statistics (median
//! tests-to-well-performing with the same deterministic bootstrap CI
//! the transfer report uses), the source model's quality at that
//! fraction (median MAE / R² from [`EndpointQuality`]), and the
//! aggregated step-domain best-so-far curve
//! ([`super::aggregate_step_curves`] via the transfer report).
//!
//! Each combination is executed as a [`TransferPlan`] — the sweep is a
//! thin deterministic driver over the transfer subsystem, so every
//! guarantee transfers verbatim: RNG streams ignore the model kind and
//! the fraction (common random numbers — a fraction changes the
//! *model*, never the search's luck), recordings come from the
//! process-wide cache (recorded once across all combinations), and
//! serial/parallel runs produce byte-identical `SWEEP_REPORT.json`
//! documents, which CI smoke-gates against a golden. Model-independent
//! searchers (random, …) run **once** as a `"baseline"` lane instead
//! of once per combination — see [`run_sweep_plan`].
//!
//! The oracle source reads exact counters and has nothing to train, so
//! [`SweepPlan::combos`] collapses every `(Oracle, fraction)` pair to
//! a single `(Oracle, 1.0)` reference row instead of re-running
//! identical jobs per fraction.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{obj, Value};

use super::convergence::StepCurvePoint;
use super::plan::{
    reads_model, validate_fraction, validate_gpus, validate_searchers,
    validate_trainable_benchmarks, PlanError,
};
use super::registry;
use super::transfer::{
    run_transfer_plan, ModelSource, TransferPlan, TransferReport,
};
use crate::searcher::FaultProfile;

/// A train-fraction × model × benchmark sensitivity grid over one
/// (source GPU → target GPU) endpoint pair.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub benchmarks: Vec<String>,
    /// GPU the source model is sampled/trained on.
    pub source_gpu: String,
    /// GPU the search runs on (may equal `source_gpu`; a differing
    /// pair measures sample efficiency *under* hardware portability).
    pub target_gpu: String,
    /// Training fractions to sweep, each in `(0, 1]`.
    pub fractions: Vec<f64>,
    /// Model sources to cross with the fractions (oracle rows collapse
    /// to one fraction-independent reference, see [`SweepPlan::combos`]).
    pub models: Vec<ModelSource>,
    pub searchers: Vec<String>,
    /// Seeded repetitions per cell.
    pub seeds: usize,
    pub base_seed: u64,
    pub max_tests: usize,
    pub within_frac: f64,
}

impl SweepPlan {
    /// The full sensitivity sweep: 5 benchmarks, the paper's §4.4
    /// cross-generation pair (gtx1070 → rtx2080), five fractions, tree
    /// model plus the oracle reference.
    pub fn full(seeds: usize, base_seed: u64) -> Self {
        SweepPlan {
            benchmarks: ["coulomb", "transpose", "gemm", "nbody", "convolution"]
                .map(String::from)
                .to_vec(),
            source_gpu: "gtx1070".into(),
            target_gpu: "rtx2080".into(),
            fractions: vec![0.05, 0.1, 0.25, 0.5, 1.0],
            models: vec![ModelSource::Tree, ModelSource::Oracle],
            searchers: vec!["random".into(), "profile".into()],
            seeds,
            base_seed,
            max_tests: 1000,
            within_frac: 0.10,
        }
    }

    /// The CI smoke sweep: 1 benchmark, the cross-generation pair,
    /// three fractions × {tree, oracle-reference} — small enough to
    /// gate a PR, wide enough to exercise fractional sampling, quality
    /// metrics and the curve embedding end-to-end.
    pub fn smoke(base_seed: u64) -> Self {
        SweepPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpu: "gtx1070".into(),
            target_gpu: "rtx2080".into(),
            fractions: vec![0.25, 0.5, 1.0],
            models: vec![ModelSource::Tree, ModelSource::Oracle],
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed,
            max_tests: 60,
            within_frac: 0.10,
        }
    }

    /// The (model, fraction) combinations actually executed, in
    /// deterministic plan order (models outer, fractions inner).
    /// Oracle rows are fraction-independent (exact counters, nothing
    /// to train), so they collapse to a single `(Oracle, 1.0)` entry —
    /// re-running them per fraction would duplicate byte-identical
    /// jobs.
    pub fn combos(&self) -> Vec<(ModelSource, f64)> {
        let mut out: Vec<(ModelSource, f64)> = Vec::new();
        for &m in &self.models {
            match m {
                ModelSource::Oracle => {
                    if !out.contains(&(ModelSource::Oracle, 1.0)) {
                        out.push((ModelSource::Oracle, 1.0));
                    }
                }
                ModelSource::Tree => {
                    for &f in &self.fractions {
                        if !out.contains(&(ModelSource::Tree, f)) {
                            out.push((ModelSource::Tree, f));
                        }
                    }
                }
            }
        }
        out
    }

    /// The [`TransferPlan`] realizing one (model, fraction) combination
    /// over the given searcher subset — the single place the sweep's
    /// axes are lowered onto the transfer subsystem.
    fn transfer_plan(
        &self,
        model: ModelSource,
        fraction: f64,
        searchers: Vec<String>,
    ) -> TransferPlan {
        TransferPlan {
            benchmarks: self.benchmarks.clone(),
            source_gpus: vec![self.source_gpu.clone()],
            source_inputs: vec!["default".into()],
            target_gpus: vec![self.target_gpu.clone()],
            target_inputs: vec!["default".into()],
            model,
            train_fraction: fraction,
            searchers,
            seeds: self.seeds,
            base_seed: self.base_seed,
            max_tests: self.max_tests,
            within_frac: self.within_frac,
            include_curves: true,
            // the sweep studies model quality vs sample budget; fault
            // robustness has its own lanes in the other harnesses
            fault_profile: FaultProfile::None,
        }
    }

    /// Typed validation, sharing every axis helper with the other plan
    /// flavours; each fraction must lie in `(0, 1]`
    /// ([`PlanError::InvalidFraction`]).
    pub fn validate(&self) -> Result<(), PlanError> {
        // training-based: the sweep samples rows of an exhaustive
        // recording, so on-demand benchmarks are rejected up front
        validate_trainable_benchmarks("benchmarks", &self.benchmarks)?;
        validate_gpus("source_gpu", std::slice::from_ref(&self.source_gpu))?;
        validate_gpus("target_gpu", std::slice::from_ref(&self.target_gpu))?;
        if self.fractions.is_empty() {
            return Err(PlanError::EmptyAxis("fractions"));
        }
        for &f in &self.fractions {
            validate_fraction("fractions", f)?;
        }
        if self.models.is_empty() {
            return Err(PlanError::EmptyAxis("models"));
        }
        validate_searchers("searchers", &self.searchers)?;
        if self.seeds == 0 {
            return Err(PlanError::EmptyAxis("seeds"));
        }
        Ok(())
    }

    fn to_json(&self) -> Value {
        obj(vec![
            ("benchmarks", Value::from(self.benchmarks.clone())),
            ("source_gpu", Value::from(self.source_gpu.clone())),
            ("target_gpu", Value::from(self.target_gpu.clone())),
            (
                "fractions",
                Value::Arr(
                    self.fractions.iter().map(|&f| Value::from(f)).collect(),
                ),
            ),
            (
                "models",
                Value::from(
                    self.models
                        .iter()
                        .map(|m| m.name().to_string())
                        .collect::<Vec<_>>(),
                ),
            ),
            ("searchers", Value::from(self.searchers.clone())),
            ("seeds", Value::from(self.seeds)),
            // string for the same 2^53 reason as the other plan echoes
            ("base_seed", Value::from(self.base_seed.to_string())),
            ("max_tests", Value::from(self.max_tests)),
            ("within_frac", Value::from(self.within_frac)),
        ])
    }
}

/// One (benchmark, model, fraction, searcher) point of the sweep.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub benchmark: String,
    /// Model-source name (`"oracle"` | `"tree"`), or `"baseline"` for
    /// the once-run model-independent searcher lane (random etc.),
    /// whose quality columns are zeroed — no model is read there.
    pub model: &'static str,
    pub fraction: f64,
    pub searcher: String,
    pub runs: usize,
    pub wp_hits: usize,
    pub median_tests_to_wp: f64,
    /// Deterministic percentile-bootstrap CI around the median above
    /// (inherited from the transfer aggregates).
    pub tests_to_wp_ci: (f64, f64),
    pub mean_tests_to_wp: f64,
    pub median_best_over_oracle: f64,
    /// Source-model quality at this fraction: median MAE / R² across
    /// the modeled counters (0 / 1 for the oracle reference).
    pub median_mae: f64,
    pub median_r2: f64,
    /// Rows the source model trained on.
    pub n_train: usize,
    /// Aggregated step-domain best-so-far curve for this cell
    /// ([`super::aggregate_step_curves`] output, via the transfer
    /// report).
    pub curve: Vec<StepCurvePoint>,
}

/// A completed sweep: one [`SweepCell`] per (combination, benchmark,
/// searcher), in deterministic plan order.
pub struct SweepReport {
    pub plan: SweepPlan,
    pub cells: Vec<SweepCell>,
}

impl SweepReport {
    /// Deterministic JSON document (`SWEEP_REPORT.json`).
    pub fn to_json(&self) -> Value {
        let cells: Vec<Value> = self
            .cells
            .iter()
            .map(|c| {
                obj(vec![
                    ("benchmark", Value::from(c.benchmark.clone())),
                    ("model", Value::from(c.model)),
                    ("fraction", Value::from(c.fraction)),
                    ("searcher", Value::from(c.searcher.clone())),
                    ("runs", Value::from(c.runs)),
                    ("wp_hits", Value::from(c.wp_hits)),
                    (
                        "median_tests_to_wp",
                        Value::from(c.median_tests_to_wp),
                    ),
                    ("tests_to_wp_ci_lo", Value::from(c.tests_to_wp_ci.0)),
                    ("tests_to_wp_ci_hi", Value::from(c.tests_to_wp_ci.1)),
                    ("mean_tests_to_wp", Value::from(c.mean_tests_to_wp)),
                    (
                        "median_best_over_oracle",
                        Value::from(c.median_best_over_oracle),
                    ),
                    ("median_mae", Value::from(c.median_mae)),
                    ("median_r2", Value::from(c.median_r2)),
                    ("n_train", Value::from(c.n_train)),
                    (
                        "curve",
                        Value::Arr(
                            c.curve
                                .iter()
                                .map(|p| {
                                    obj(vec![
                                        ("step", Value::from(p.step)),
                                        (
                                            "median_ms",
                                            Value::from(p.median_ms),
                                        ),
                                        ("mean_ms", Value::from(p.mean_ms)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let plan = self.plan.to_json();
        let plan_hash =
            registry::plan_hash(registry::SWEEP_REPORT_SCHEMA, &plan);
        obj(vec![
            ("schema", Value::from(registry::SWEEP_REPORT_SCHEMA)),
            ("plan", plan),
            ("plan_hash", Value::from(plan_hash)),
            ("provenance", registry::Provenance::from_env().to_json()),
            ("cells", Value::Arr(cells)),
        ])
    }

    /// The canonical byte representation compared by the smoke gate.
    pub fn to_pretty_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty(1);
        s.push('\n');
        s
    }

    pub fn write_to(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_pretty_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// One summary line per cell (profile rows carry the model-quality
    /// columns; the random baseline is model-independent).
    pub fn summary_lines(&self) -> Vec<String> {
        self.cells
            .iter()
            .map(|c| {
                format!(
                    "{:<12} {:<7} f={:<5} {:<10} steps {:>6.1} \
                     [{:>6.1}, {:>6.1}]  best {:>5.2}x  mae {:>10.3} \
                     r2 {:>6.3}  n_train {:>5}",
                    c.benchmark,
                    c.model,
                    c.fraction,
                    c.searcher,
                    c.median_tests_to_wp,
                    c.tests_to_wp_ci.0,
                    c.tests_to_wp_ci.1,
                    c.median_best_over_oracle,
                    c.median_mae,
                    c.median_r2,
                    c.n_train,
                )
            })
            .collect()
    }
}

/// Extract [`SweepCell`]s from one lowered transfer report. `quality`
/// is false for the baseline lane, whose searchers never read the
/// source model — its rows carry zeroed quality columns instead of a
/// misleading endpoint fit.
fn extract_cells(
    report: &TransferReport,
    model: &'static str,
    fraction: f64,
    quality: bool,
    cells: &mut Vec<SweepCell>,
) {
    let curves = report.step_curves();
    for a in report.aggregate_rows() {
        let q = if quality {
            // one source endpoint per benchmark in a lowered plan
            report
                .model_quality
                .iter()
                .find(|q| q.benchmark == a.benchmark)
        } else {
            None
        };
        let curve = curves
            .iter()
            .find(|(id, _)| {
                id.benchmark == a.benchmark && id.searcher == a.searcher
            })
            .map(|(_, pts)| pts.clone())
            .unwrap_or_default();
        cells.push(SweepCell {
            benchmark: a.benchmark.clone(),
            model,
            fraction,
            searcher: a.searcher.clone(),
            runs: a.runs,
            wp_hits: a.wp_hits,
            median_tests_to_wp: a.median_tests_to_wp,
            tests_to_wp_ci: a.tests_to_wp_ci,
            mean_tests_to_wp: a.mean_tests_to_wp,
            median_best_over_oracle: a.median_best_over_oracle,
            median_mae: q.map(|q| q.median_mae()).unwrap_or(0.0),
            median_r2: q.map(|q| q.median_r2()).unwrap_or(0.0),
            n_train: q.map(|q| q.n_train).unwrap_or(0),
            curve,
        });
    }
}

/// Execute a sweep with up to `jobs` worker threads: one baseline
/// [`TransferPlan`] for the model-independent searchers (run **once**
/// — their RNG streams ignore the model and the fraction, so running
/// them per combination would repeat byte-identical searches; the
/// transfer runner's own fan-out dedup only covers one plan, not a
/// sequence of them), then one [`TransferPlan`] per (model, fraction)
/// combination over the model-reading searchers, in plan order.
///
/// Determinism is inherited wholesale from the transfer runner — every
/// lowered report is a pure function of its plan, the combinations are
/// lowered in a fixed order, and the extraction only reads aggregate
/// rows (sorted key order) and the endpoint-quality list (plan order).
/// Worker count affects wall-clock and nothing else; the recording
/// cache makes the recordings a one-time cost across all combinations.
pub fn run_sweep_plan(plan: &SweepPlan, jobs: usize) -> Result<SweepReport> {
    plan.validate()?;

    // reads_model is spec-backed: any registry spec string partitions
    // correctly, including parameterized ("ga:pop=20") and augmented
    // ("profile+de") forms
    let (dependent, independent): (Vec<String>, Vec<String>) = plan
        .searchers
        .iter()
        .cloned()
        .partition(|s| reads_model(s));

    let mut cells: Vec<SweepCell> = Vec::new();
    if !independent.is_empty() {
        // baseline lane: the oracle matrix is built (cheaply, no
        // training) but never read by these searchers; label the rows
        // "baseline" with zeroed quality columns
        let tp = plan.transfer_plan(ModelSource::Oracle, 1.0, independent);
        let report = run_transfer_plan(&tp, jobs)?;
        extract_cells(&report, "baseline", 1.0, false, &mut cells);
    }
    if !dependent.is_empty() {
        for (model, fraction) in plan.combos() {
            let tp =
                plan.transfer_plan(model, fraction, dependent.clone());
            let report = run_transfer_plan(&tp, jobs)?;
            extract_cells(
                &report,
                model.name(),
                fraction,
                true,
                &mut cells,
            );
        }
    }

    Ok(SweepReport {
        plan: plan.clone(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepPlan {
        SweepPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpu: "gtx1070".into(),
            target_gpu: "gtx1070".into(),
            fractions: vec![0.5, 1.0],
            models: vec![ModelSource::Tree, ModelSource::Oracle],
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 7,
            max_tests: 40,
            within_frac: 0.10,
        }
    }

    #[test]
    fn combos_collapse_the_oracle_reference() {
        let plan = tiny();
        assert_eq!(
            plan.combos(),
            vec![
                (ModelSource::Tree, 0.5),
                (ModelSource::Tree, 1.0),
                (ModelSource::Oracle, 1.0),
            ]
        );
        // duplicate fractions collapse too
        let mut plan = tiny();
        plan.fractions = vec![0.5, 0.5];
        assert_eq!(plan.combos().len(), 2);
    }

    #[test]
    fn validation_rejects_bad_axes_with_typed_errors() {
        let mut plan = tiny();
        plan.fractions = vec![];
        assert_eq!(plan.validate(), Err(PlanError::EmptyAxis("fractions")));
        let mut plan = tiny();
        plan.fractions = vec![0.5, 1.5];
        match plan.validate() {
            Err(PlanError::InvalidFraction { axis, value }) => {
                assert_eq!(axis, "fractions");
                assert_eq!(value, 1.5);
            }
            other => panic!("got {other:?}"),
        }
        let mut plan = tiny();
        plan.models = vec![];
        assert_eq!(plan.validate(), Err(PlanError::EmptyAxis("models")));
        let mut plan = tiny();
        plan.target_gpu = "titan".into();
        assert_eq!(plan.validate(), Err(PlanError::UnknownGpu("titan".into())));
        let mut plan = tiny();
        plan.benchmarks = vec!["gemm-full".into()];
        assert_eq!(
            plan.validate(),
            Err(PlanError::NoRecording("gemm-full".into()))
        );
        assert!(tiny().validate().is_ok());
        // the runner surfaces validation before any recording
        let mut plan = tiny();
        plan.fractions = vec![0.0];
        assert!(run_sweep_plan(&plan, 2).is_err());
    }

    #[test]
    fn serial_and_parallel_sweeps_are_byte_identical() {
        let plan = tiny();
        let a = run_sweep_plan(&plan, 1).unwrap().to_pretty_string();
        let b = run_sweep_plan(&plan, 8).unwrap().to_pretty_string();
        assert_eq!(a, b);
        assert!(a.contains("\"schema\": \"pcat-sweep-report/v1\""));
        assert!(a.contains("\"curve\""));
    }

    #[test]
    fn cells_cover_the_grid_and_carry_quality() {
        let plan = tiny();
        let report = run_sweep_plan(&plan, 4).unwrap();
        // 1 baseline (random, run once) + 3 combos × 1 profile row
        assert_eq!(report.cells.len(), 4);
        for c in &report.cells {
            assert_eq!(c.runs, plan.seeds);
            assert!(!c.curve.is_empty(), "curves embedded");
            let (lo, hi) = c.tests_to_wp_ci;
            assert!(lo <= c.median_tests_to_wp && c.median_tests_to_wp <= hi);
        }
        // the model-independent random searcher runs exactly once —
        // its streams ignore model and fraction, so per-combo re-runs
        // would duplicate byte-identical searches — and carries no
        // model-quality numbers
        let randoms: Vec<&SweepCell> = report
            .cells
            .iter()
            .filter(|c| c.searcher == "random")
            .collect();
        assert_eq!(randoms.len(), 1);
        assert_eq!(randoms[0].model, "baseline");
        assert_eq!(randoms[0].median_mae, 0.0);
        assert_eq!(randoms[0].n_train, 0);
        // oracle reference: exact-zero model error
        let oracle = report
            .cells
            .iter()
            .find(|c| c.model == "oracle" && c.searcher == "profile")
            .unwrap();
        assert_eq!(oracle.median_mae, 0.0);
        assert_eq!(oracle.median_r2, 1.0);
        assert!(oracle.n_train > 0);
        // tree rows: n_train follows the fraction
        let half = report
            .cells
            .iter()
            .find(|c| c.model == "tree" && c.fraction == 0.5)
            .unwrap();
        let full = report
            .cells
            .iter()
            .find(|c| c.model == "tree" && c.fraction == 1.0)
            .unwrap();
        assert!(half.n_train < full.n_train);
    }

    #[test]
    fn model_independent_only_plans_skip_the_combo_lane() {
        // a searcher axis with no model reader still validates and
        // produces only the baseline lane (and vice versa: no
        // EmptyAxis from an empty lowered searcher list)
        let mut plan = tiny();
        plan.searchers = vec!["random".into()];
        let report = run_sweep_plan(&plan, 2).unwrap();
        assert_eq!(report.cells.len(), 1);
        assert_eq!(report.cells[0].model, "baseline");
        let mut plan = tiny();
        plan.searchers = vec!["profile".into()];
        let report = run_sweep_plan(&plan, 2).unwrap();
        assert_eq!(report.cells.len(), 3);
        assert!(report.cells.iter().all(|c| c.searcher == "profile"));
    }
}
