//! Convergence statistics (§4.6 and the transfer-matrix evaluation):
//! best-so-far curves in the step and time domains, steps-to-within-X%
//! of the oracle best, and order-invariant aggregation over
//! repetitions — with the paper's plotting convention for time-domain
//! curves (start at the time when *all* repetitions have at least one
//! finished kernel).
//!
//! Every aggregation here is a pure function of the *multiset* of input
//! runs: values are sorted before any floating-point reduction, so
//! permuting the input runs can never change a single output bit. The
//! transfer report's byte-identity contract leans on that.

use std::sync::Arc;

use crate::searcher::{Budget, CostModel, ReplayEnv, Searcher};
use crate::tuning::RecordedSpace;
use crate::util::stats::{mean, median, stddev};

use super::par_map_seeds;

/// One aggregated point of a convergence curve.
#[derive(Debug, Clone)]
pub struct ConvergencePoint {
    pub t_s: f64,
    pub mean_ms: f64,
    pub std_ms: f64,
}

/// One aggregated point of a step-domain best-so-far curve.
#[derive(Debug, Clone)]
pub struct StepCurvePoint {
    /// 1-based empirical-test count.
    pub step: usize,
    pub median_ms: f64,
    pub mean_ms: f64,
}

/// Monotone non-increasing best-so-far transform of a runtime trace.
pub fn best_so_far(runtimes: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(runtimes.len());
    let mut best = f64::INFINITY;
    for &r in runtimes {
        best = best.min(r);
        out.push(best);
    }
    out
}

/// 1-based number of empirical tests until a runtime within
/// `(1 + frac)×` of `oracle_best_ms` is found; `None` if never.
///
/// `frac = 0.10` is the paper's well-performing threshold (§4.1);
/// `frac = 0.0` asks for the oracle best itself, so on a trace whose
/// minimum *is* the oracle best it returns the argmin step.
pub fn steps_to_within(
    runtimes: &[f64],
    oracle_best_ms: f64,
    frac: f64,
) -> Option<usize> {
    let thr = oracle_best_ms * (1.0 + frac);
    runtimes.iter().position(|&r| r <= thr).map(|p| p + 1)
}

/// Aggregate per-run runtime traces into a per-step median/mean
/// best-so-far curve.
///
/// Runs may have different lengths (searches stop early at their
/// threshold): a finished run keeps contributing its final best to
/// later steps, so every grid point averages over *all* runs and the
/// curve stays monotone non-increasing. Output is invariant to the
/// order of `runs` (values are sorted before reduction). Generic over
/// `AsRef<[f64]>` so callers can pass owned traces (`Vec<f64>`) or
/// borrowed slices without cloning.
pub fn aggregate_step_curves<R: AsRef<[f64]>>(
    runs: &[R],
) -> Vec<StepCurvePoint> {
    let max_len = runs.iter().map(|r| r.as_ref().len()).max().unwrap_or(0);
    let curves: Vec<Vec<f64>> =
        runs.iter().map(|r| best_so_far(r.as_ref())).collect();
    let mut out = Vec::with_capacity(max_len);
    for s in 0..max_len {
        let mut at_s: Vec<f64> = curves
            .iter()
            .filter(|c| !c.is_empty())
            .map(|c| c[s.min(c.len() - 1)])
            .collect();
        if at_s.is_empty() {
            continue;
        }
        at_s.sort_by(f64::total_cmp);
        out.push(StepCurvePoint {
            step: s + 1,
            median_ms: median(&at_s),
            mean_ms: mean(&at_s),
        });
    }
    out
}

/// Aggregate (time, best-so-far) staircases on a regular `grid_points`
/// grid over `[t_start, horizon_s]`, where `t_start` is the paper's
/// plotting convention — the moment every run has one finished kernel.
///
/// Pure aggregation core of [`aggregate_convergence`]; output is
/// invariant to the order of `staircases`. Generic over
/// `AsRef<[(f64, f64)]>` so callers can pass owned staircases
/// (`Vec<(f64, f64)>`) or borrowed slices without cloning — the
/// transfer report borrows its per-job traces.
pub fn aggregate_staircases<S: AsRef<[(f64, f64)]>>(
    staircases: &[S],
    horizon_s: f64,
    grid_points: usize,
) -> Vec<ConvergencePoint> {
    let t_start = staircases
        .iter()
        .filter_map(|st| st.as_ref().first().map(|p| p.0))
        .fold(0.0f64, f64::max);

    let mut out = Vec::with_capacity(grid_points);
    for gi in 0..grid_points {
        let t = t_start
            + (horizon_s - t_start)
                * (gi as f64 / (grid_points.saturating_sub(1).max(1)) as f64);
        let mut at_t: Vec<f64> = staircases
            .iter()
            .filter_map(|st| best_at(st.as_ref(), t))
            .collect();
        if at_t.is_empty() {
            continue;
        }
        // sorted reduction: permuting the input runs must not change
        // the floating-point sum order (total_cmp: fault-injected runs
        // can legitimately carry non-finite bests)
        at_t.sort_by(f64::total_cmp);
        out.push(ConvergencePoint {
            t_s: t,
            mean_ms: mean(&at_t),
            std_ms: stddev(&at_t),
        });
    }
    out
}

/// Aggregate (cost, best-so-far) staircases on a grid whose horizon is
/// the **latest final-step time** across runs — the transfer report's
/// time-domain curves, where no fixed wall-clock horizon exists (jobs
/// stop at their own budget, at different costs). Runs that finish
/// early keep contributing their final best to later grid points (the
/// staircase semantics of [`best_at`]). Order-invariant like
/// everything in this module: the horizon is a max, the reductions are
/// sorted.
pub fn aggregate_time_curves<S: AsRef<[(f64, f64)]>>(
    staircases: &[S],
    grid_points: usize,
) -> Vec<ConvergencePoint> {
    let horizon = staircases
        .iter()
        .filter_map(|st| st.as_ref().last().map(|p| p.0))
        .fold(0.0f64, f64::max);
    aggregate_staircases(staircases, horizon, grid_points)
}

/// Run `make(seed)` searchers `reps` times for `horizon_s` of simulated
/// tuning time each, and aggregate best-so-far on a regular grid.
#[allow(clippy::too_many_arguments)]
pub fn aggregate_convergence<'a, F>(
    rec: &Arc<RecordedSpace>,
    gpu: &crate::gpusim::GpuSpec,
    cost: &CostModel,
    reps: usize,
    horizon_s: f64,
    grid_points: usize,
    seed_base: u64,
    make: F,
) -> Vec<ConvergencePoint>
where
    F: Fn(u64) -> Box<dyn Searcher + 'a> + Sync,
{
    let staircases: Vec<Vec<(f64, f64)>> = par_map_seeds(reps, &|seed| {
        let mut env =
            ReplayEnv::new(Arc::clone(rec), gpu.clone(), cost.clone());
        let mut s = make(seed_base.wrapping_add(seed));
        let trace = s.run(&mut env, &Budget::seconds(horizon_s));
        trace.convergence()
    });
    aggregate_staircases(&staircases, horizon_s, grid_points)
}

/// Best runtime achieved by a staircase at or before time `t`.
fn best_at(staircase: &[(f64, f64)], t: f64) -> Option<f64> {
    let mut best = None;
    for &(ct, v) in staircase {
        if ct <= t {
            best = Some(v);
        } else {
            break;
        }
    }
    best
}

/// Render aggregated curves as CSV (series, t, mean, std).
pub fn curves_csv(series: &[(&str, &[ConvergencePoint])]) -> String {
    let mut out = String::from("series,t_s,mean_ms,std_ms\n");
    for (name, pts) in series {
        for p in pts.iter() {
            out.push_str(&format!(
                "{name},{:.3},{:.6},{:.6}\n",
                p.t_s, p.mean_ms, p.std_ms
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{cached_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::RandomSearcher;

    #[test]
    fn best_at_respects_time() {
        let st = vec![(1.0, 10.0), (2.0, 5.0), (3.0, 4.0)];
        assert_eq!(best_at(&st, 0.5), None);
        assert_eq!(best_at(&st, 1.5), Some(10.0));
        assert_eq!(best_at(&st, 10.0), Some(4.0));
    }

    #[test]
    fn curves_monotone_nonincreasing() {
        let gpu = GpuSpec::gtx1070();
        let rec = cached_space(&Coulomb, &gpu, &Coulomb.default_input());
        let pts = aggregate_convergence(
            &rec,
            &gpu,
            &CostModel::default(),
            20,
            20.0,
            15,
            0,
            |s| Box::new(RandomSearcher::new(s)),
        );
        assert!(pts.len() >= 5);
        for w in pts.windows(2) {
            assert!(
                w[1].mean_ms <= w[0].mean_ms + 1e-9,
                "mean best-so-far must not increase"
            );
        }
    }

    #[test]
    fn best_so_far_is_monotone_prefix_min() {
        assert_eq!(
            best_so_far(&[5.0, 7.0, 3.0, 4.0]),
            vec![5.0, 5.0, 3.0, 3.0]
        );
        assert!(best_so_far(&[]).is_empty());
    }

    #[test]
    fn steps_to_within_thresholds() {
        let r = [5.0, 3.0, 1.0, 2.0];
        assert_eq!(steps_to_within(&r, 1.0, 0.0), Some(3));
        assert_eq!(steps_to_within(&r, 1.0, 2.5), Some(2));
        assert_eq!(steps_to_within(&r, 0.5, 0.1), None);
        assert_eq!(steps_to_within(&[], 1.0, 0.0), None);
    }

    #[test]
    fn step_curves_carry_finished_runs_forward() {
        // run A stops after 2 tests (found its threshold), run B keeps
        // going: A's final best keeps contributing at steps 3 and 4
        let runs = vec![vec![4.0, 2.0], vec![8.0, 6.0, 5.0, 1.0]];
        let pts = aggregate_step_curves(&runs);
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].step, 1);
        assert_eq!(pts[0].mean_ms, 6.0); // (4 + 8) / 2
        assert_eq!(pts[2].mean_ms, 3.5); // (2 + 5) / 2
        assert_eq!(pts[3].mean_ms, 1.5); // (2 + 1) / 2
        for w in pts.windows(2) {
            assert!(w[1].median_ms <= w[0].median_ms + 1e-12);
            assert!(w[1].mean_ms <= w[0].mean_ms + 1e-12);
        }
        assert!(aggregate_step_curves::<Vec<f64>>(&[]).is_empty());
    }

    #[test]
    fn time_curves_span_to_the_latest_finisher() {
        // run A stops at t=2, run B at t=5: the grid must reach 5 and
        // A's final best keeps contributing there
        let a = vec![(1.0, 10.0), (2.0, 4.0)];
        let b = vec![(1.5, 8.0), (5.0, 2.0)];
        let pts = aggregate_time_curves(&[a.clone(), b.clone()], 9);
        assert!(!pts.is_empty());
        assert!((pts.last().unwrap().t_s - 5.0).abs() < 1e-12);
        // at the horizon both runs contribute their final bests
        assert_eq!(pts.last().unwrap().mean_ms, 3.0); // (4 + 2) / 2
        for w in pts.windows(2) {
            assert!(w[1].t_s >= w[0].t_s);
            assert!(w[1].mean_ms <= w[0].mean_ms + 1e-12);
        }
        // order invariance comes from max + the sorted reductions
        let rev = aggregate_time_curves(&[b, a], 9);
        assert_eq!(pts.len(), rev.len());
        for (x, y) in pts.iter().zip(&rev) {
            assert_eq!(x.t_s, y.t_s);
            assert_eq!(x.mean_ms, y.mean_ms);
            assert_eq!(x.std_ms, y.std_ms);
        }
        assert!(aggregate_time_curves::<Vec<(f64, f64)>>(&[], 9).is_empty());
    }

    #[test]
    fn aggregate_staircases_is_order_invariant() {
        let a = vec![(1.0, 10.0), (3.0, 4.0)];
        let b = vec![(2.0, 8.0), (4.0, 2.0)];
        let c = vec![(1.5, 9.0)];
        let fwd = aggregate_staircases(&[a.clone(), b.clone(), c.clone()], 6.0, 9);
        let rev = aggregate_staircases(&[c, b, a], 6.0, 9);
        assert_eq!(fwd.len(), rev.len());
        for (x, y) in fwd.iter().zip(&rev) {
            assert_eq!(x.t_s, y.t_s);
            assert_eq!(x.mean_ms, y.mean_ms);
            assert_eq!(x.std_ms, y.std_ms);
        }
    }

    #[test]
    fn csv_format() {
        let pts = vec![ConvergencePoint {
            t_s: 1.0,
            mean_ms: 2.0,
            std_ms: 0.5,
        }];
        let csv = curves_csv(&[("random", &pts)]);
        assert!(csv.starts_with("series,t_s"));
        assert!(csv.contains("random,1.000"));
    }
}
