//! Time-domain convergence aggregation (§4.6): the searcher's best
//! kernel runtime as a function of elapsed tuning time, averaged over
//! repetitions, with the paper's plotting convention — curves start at
//! the time when *all* repetitions have at least one finished kernel.

use std::sync::Arc;

use crate::searcher::{Budget, CostModel, ReplayEnv, Searcher};
use crate::tuning::RecordedSpace;
use crate::util::stats::{mean, stddev};

use super::par_map_seeds;

/// One aggregated point of a convergence curve.
#[derive(Debug, Clone)]
pub struct ConvergencePoint {
    pub t_s: f64,
    pub mean_ms: f64,
    pub std_ms: f64,
}

/// Run `make(seed)` searchers `reps` times for `horizon_s` of simulated
/// tuning time each, and aggregate best-so-far on a regular grid.
#[allow(clippy::too_many_arguments)]
pub fn aggregate_convergence<'a, F>(
    rec: &Arc<RecordedSpace>,
    gpu: &crate::gpusim::GpuSpec,
    cost: &CostModel,
    reps: usize,
    horizon_s: f64,
    grid_points: usize,
    seed_base: u64,
    make: F,
) -> Vec<ConvergencePoint>
where
    F: Fn(u64) -> Box<dyn Searcher + 'a> + Sync,
{
    let staircases: Vec<Vec<(f64, f64)>> = par_map_seeds(reps, &|seed| {
        let mut env =
            ReplayEnv::new(Arc::clone(rec), gpu.clone(), cost.clone());
        let mut s = make(seed_base.wrapping_add(seed));
        let trace = s.run(&mut env, &Budget::seconds(horizon_s));
        trace.convergence()
    });

    // the paper plots from the moment every run has one finished kernel
    let t_start = staircases
        .iter()
        .filter_map(|st| st.first().map(|p| p.0))
        .fold(0.0f64, f64::max);

    let mut out = Vec::with_capacity(grid_points);
    for gi in 0..grid_points {
        let t = t_start
            + (horizon_s - t_start) * (gi as f64 / (grid_points - 1) as f64);
        let at_t: Vec<f64> = staircases
            .iter()
            .filter_map(|st| best_at(st, t))
            .collect();
        if at_t.is_empty() {
            continue;
        }
        out.push(ConvergencePoint {
            t_s: t,
            mean_ms: mean(&at_t),
            std_ms: stddev(&at_t),
        });
    }
    out
}

/// Best runtime achieved by a staircase at or before time `t`.
fn best_at(staircase: &[(f64, f64)], t: f64) -> Option<f64> {
    let mut best = None;
    for &(ct, v) in staircase {
        if ct <= t {
            best = Some(v);
        } else {
            break;
        }
    }
    best
}

/// Render aggregated curves as CSV (series, t, mean, std).
pub fn curves_csv(series: &[(&str, &[ConvergencePoint])]) -> String {
    let mut out = String::from("series,t_s,mean_ms,std_ms\n");
    for (name, pts) in series {
        for p in pts.iter() {
            out.push_str(&format!(
                "{name},{:.3},{:.6},{:.6}\n",
                p.t_s, p.mean_ms, p.std_ms
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{cached_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::RandomSearcher;

    #[test]
    fn best_at_respects_time() {
        let st = vec![(1.0, 10.0), (2.0, 5.0), (3.0, 4.0)];
        assert_eq!(best_at(&st, 0.5), None);
        assert_eq!(best_at(&st, 1.5), Some(10.0));
        assert_eq!(best_at(&st, 10.0), Some(4.0));
    }

    #[test]
    fn curves_monotone_nonincreasing() {
        let gpu = GpuSpec::gtx1070();
        let rec = cached_space(&Coulomb, &gpu, &Coulomb.default_input());
        let pts = aggregate_convergence(
            &rec,
            &gpu,
            &CostModel::default(),
            20,
            20.0,
            15,
            0,
            |s| Box::new(RandomSearcher::new(s)),
        );
        assert!(pts.len() >= 5);
        for w in pts.windows(2) {
            assert!(
                w[1].mean_ms <= w[0].mean_ms + 1e-9,
                "mean best-so-far must not increase"
            );
        }
    }

    #[test]
    fn csv_format() {
        let pts = vec![ConvergencePoint {
            t_s: 1.0,
            mean_ms: 2.0,
            std_ms: 0.5,
        }];
        let csv = curves_csv(&[("random", &pts)]);
        assert!(csv.starts_with("series,t_s"));
        assert!(csv.contains("random,1.000"));
    }
}
