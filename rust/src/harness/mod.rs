//! Experiment harness: regenerates every table and figure of the
//! paper's evaluation section (§4) and writes markdown + CSV reports.
//!
//! | id     | paper artifact | scenario |
//! |--------|----------------|----------|
//! | table2 | Table 2 | tuning-space sizes |
//! | table4 | Table 4 | random-search steps to 1.1× best |
//! | table5 | Table 5 | proposed vs random, exact PCs, same GPU |
//! | table6 | Table 6 | hardware portability (model GPU × tuning GPU) |
//! | table7 | Table 7 | input portability (GEMM sizes) |
//! | table8 | Table 8 | Starchart vs random |
//! | table9 | Table 9 | Starchart@1070 vs proposed@1070, on RTX 2080 |
//! | fig1   | Figure 1 | TP→PC stability across GPU/input |
//! | fig3–8 | Figures 3–8 | time-domain convergence |
//! | fig9–13| Figures 9–13 | vs Basin Hopping (time + iterations) |
//! | ablation_* | — | design-choice ablations called out in DESIGN.md |
//!
//! Beyond the per-artifact drivers, three job-matrix runners execute
//! whole evaluation grids on the shared worker pool with byte-identical
//! `--jobs`-invariant reports: [`ExperimentPlan`] (benchmark × GPU ×
//! input × searcher × seed, same-cell), [`TransferPlan`] (benchmark ×
//! source (GPU, input) × target (GPU, input) × searcher × seed — the
//! paper's train-on-A / tune-on-B portability experiment over **both**
//! axes the paper claims, with a pluggable source-model kind:
//! [`ModelSource::Oracle`] exact PCs or [`ModelSource::Tree`] trained
//! decision trees, trained on a `train_fraction` stratified sample of
//! the source recording with per-endpoint MAE/RMSE/R² quality metrics
//! embedded in the report), and [`SweepPlan`] (the sample-efficiency
//! sensitivity sweep: train-fraction × model × benchmark convergence
//! curves, `pcat sweep`).
//!
//! Every runner's report is experiment-registry material: it carries a
//! [`plan_hash`] + [`Provenance`] identity stamp, [`extract_rows`]
//! flattens its KPIs into [`RegistryRow`]s, and [`compare_rows`] gates
//! them against a blessed baseline under typed [`Tolerance`]s
//! (`pcat registry append|query|compare`).
//!
//! The serving layer turns the same machinery into
//! tuning-as-a-service: [`ServeEngine`] answers (benchmark, GPU,
//! input) → best-config queries from a [`TuningStore`] (in-memory or
//! versioned JSON file, exportable for pre-warming), searching on miss
//! exactly once per endpoint; [`run_load_plan`] replays a seeded
//! Zipf request mix against it and emits a registry-stamped
//! [`ServeReport`] with throughput, hit-rate and latency-percentile
//! KPIs (`pcat serve`, `pcat serve-query`, `pcat cache`).

mod convergence;
mod figures;
mod loadgen;
mod plan;
mod registry;
mod serve;
mod steps;
mod sweep;
mod tables;
mod transfer;

pub use convergence::{
    aggregate_convergence, aggregate_staircases, aggregate_step_curves,
    aggregate_time_curves, best_so_far, steps_to_within, ConvergencePoint,
    StepCurvePoint,
};
pub use loadgen::{
    run_load_plan, EndpointReport, LoadPlan, LoadResults, ServeReport,
    HIT_LATENCY_S,
};
pub use plan::{
    run_plan, AggregateRow, ExperimentPlan, JobResult, JobSpec, PlanError,
    PlanReport, PLAN_SEARCHERS,
};
pub use registry::{
    compare_rows, default_tolerances, extract_rows, has_failures, plan_hash,
    CompareFinding, CompareStatus, CsvStore, Direction, MemStore, Provenance,
    RegistryError, RegistryRow, RegistryStore, Tolerance,
    BENCH_REPORT_SCHEMA, KNOWN_REPORT_SCHEMAS, PLAN_REPORT_SCHEMA,
    REGISTRY_HEADER, SERVE_REPORT_SCHEMA, SWEEP_REPORT_SCHEMA,
    TRANSFER_REPORT_SCHEMA,
};
pub use serve::{
    export_store, import_store, render_store, JsonFileStore, MemTuningStore,
    QueryOutcome, ServeConfig, ServeEngine, ServeError, ServeKey, TuningEntry,
    TuningStore, TUNING_STORE_SCHEMA,
};
pub use steps::{avg_steps_to_well_performing, par_map_seeds};
pub use sweep::{run_sweep_plan, SweepCell, SweepPlan, SweepReport};
pub use tables::{
    model_quality_matrix, registry_compare_table, registry_query_table,
    robustness_table, searcher_ranking, sweep_matrix, transfer_input_matrix,
    transfer_matrix,
};
pub use transfer::{
    run_transfer_plan, CellId, CounterQuality, EndpointQuality, ModelSource,
    TransferAggregate, TransferJobResult, TransferJobSpec, TransferPlan,
    TransferReport,
};

use std::path::Path;

use anyhow::{bail, Context, Result};

/// One regenerated paper artifact.
pub struct Report {
    pub id: &'static str,
    pub title: String,
    /// Markdown body (tables, notes, ASCII charts).
    pub markdown: String,
    /// Machine-readable companions: (file stem, CSV content).
    pub csvs: Vec<(String, String)>,
}

impl Report {
    pub fn write_to(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let md = format!("# {} — {}\n\n{}", self.id, self.title, self.markdown);
        std::fs::write(dir.join(format!("{}.md", self.id)), md)
            .with_context(|| format!("writing {}", self.id))?;
        for (stem, csv) in &self.csvs {
            std::fs::write(dir.join(format!("{stem}.csv")), csv)?;
        }
        Ok(())
    }
}

/// Experiment knobs shared by all drivers.
#[derive(Debug, Clone)]
pub struct ExperimentOpts {
    /// Repetitions for step-count statistics (paper: 1000).
    pub reps: usize,
    /// Repetitions for time-domain statistics (paper: 100).
    pub time_reps: usize,
    /// RNG stream base.
    pub seed: u64,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        ExperimentOpts {
            reps: 1000,
            time_reps: 100,
            seed: 0,
        }
    }
}

/// All experiment ids, in the paper's order.
pub const ALL_EXPERIMENTS: [&str; 18] = [
    "table2", "table4", "table5", "table6", "table7", "table8", "table9",
    "fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9_13",
    "ablation_n", "ablation_model", "ablation_local",
];

/// Run one experiment by id.
pub fn run_experiment(id: &str, opts: &ExperimentOpts) -> Result<Report> {
    Ok(match id {
        "table2" => tables::table2(),
        "table4" => tables::table4(opts),
        "table5" => tables::table5(opts),
        "table6" => tables::table6(opts),
        "table7" => tables::table7(opts),
        "table8" => tables::table8(opts),
        "table9" => tables::table9(opts),
        "fig1" => figures::fig1(),
        "fig3" => figures::fig_convergence("fig3", "gemm", opts),
        "fig4" => figures::fig_convergence("fig4", "convolution", opts),
        "fig5" => figures::fig5_transpose_check(opts),
        "fig6" => figures::fig6_nbody_sizes(opts),
        "fig7" => figures::fig_convergence("fig7", "coulomb", opts),
        "fig8" => figures::fig8_gemm_full(opts),
        "fig9_13" => figures::fig9_13_basin_hopping(opts),
        "ablation_n" => tables::ablation_profile_interval(opts),
        "ablation_model" => tables::ablation_model_kind(opts),
        "ablation_local" => tables::ablation_local_search(opts),
        other => bail!(
            "unknown experiment {other:?}; known: {}",
            ALL_EXPERIMENTS.join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_is_an_error() {
        assert!(run_experiment("table99", &ExperimentOpts::default()).is_err());
    }

    #[test]
    fn table2_runs_instantly() {
        let r = run_experiment("table2", &ExperimentOpts::default()).unwrap();
        assert_eq!(r.id, "table2");
        assert!(r.markdown.contains("coulomb"));
    }

    #[test]
    fn report_writes_files() {
        let r = Report {
            id: "table2",
            title: "t".into(),
            markdown: "body".into(),
            csvs: vec![("table2_data".into(), "a,b\n1,2\n".into())],
        };
        let dir = std::env::temp_dir().join("pcat_test_report");
        r.write_to(&dir).unwrap();
        assert!(dir.join("table2.md").exists());
        assert!(dir.join("table2_data.csv").exists());
        std::fs::remove_dir_all(dir).ok();
    }
}
