//! Tuning-as-a-service: a persistent, versioned tuning cache plus
//! query engine (`pcat serve-query` / `pcat cache export|import`).
//!
//! The paper's promise — a counter-trained model makes tuning results
//! *reusable* — only pays off in production if "best config for
//! (benchmark, GPU, input)" is answered without re-searching. This
//! module is that serving layer:
//!
//! * [`TuningStore`] abstracts the answer cache. [`MemTuningStore`]
//!   serves from memory; [`JsonFileStore`] persists every fill to a
//!   versioned JSON document (schema [`TUNING_STORE_SCHEMA`]) whose
//!   bytes equal its own [`export_store`] rendering, so a store file
//!   can be shipped with a deployment and imported to kill cold starts
//!   (the kubecl exemplar's pre-warming story).
//! * [`ServeEngine`] is the query engine. Reads go through the store
//!   and the process-wide `Arc`-shared recording/matrix caches
//!   ([`crate::benchmarks::cached_space`] /
//!   [`crate::benchmarks::cached_matrix`]); a miss falls through to a
//!   bounded profile search over the replay environment and persists
//!   the result stamped with a plan hash + provenance identity.
//!   Concurrent misses for one endpoint are collapsed onto a single
//!   search by an [`OnceMap`] slot, so every answer is computed
//!   **exactly once per process** no matter how many worker threads
//!   race on it.
//!
//! **Determinism contract:** an entry is a pure function of the
//! endpoint key and the engine's [`ServeConfig`] — the search seed
//! derives from `(base seed, benchmark, gpu, input)` via
//! [`stream_seed`], never from scheduling — so serial and concurrent
//! query mixes produce byte-identical answers (asserted by the
//! `tests/serve.rs` hammer and the CI serve smoke lane).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::benchmarks::{self, RecordingMode};
use crate::coordinator::Tuner;
use crate::gpusim::GpuSpec;
use crate::searcher::{
    Budget, CellCtx, CostModel, ModelCtx, OnDemandEnv, SearcherSpec,
};
use crate::util::json::{obj, Value};
use crate::util::rng::stream_seed;
use crate::util::sync::{lock_unpoisoned, OnceMap};

use super::plan::{
    inst_reaction_for, validate_benchmarks, validate_gpus, validate_inputs,
    PlanError,
};
use super::registry::{plan_hash, Provenance};

/// Version tag of the on-disk tuning-store document. Bump on any
/// incompatible entry-layout change; [`import_store`] rejects every
/// other value (including older versions).
pub const TUNING_STORE_SCHEMA: &str = "pcat-tuning-store/v1";

/// One serving endpoint: canonical benchmark name, canonical GPU name,
/// concrete input name. Construct via [`ServeKey::resolve`] so
/// case-insensitive aliases (`Coulomb`, `GTX-1070`, `default`) collapse
/// onto one cache key.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServeKey {
    pub benchmark: String,
    pub gpu: String,
    pub input: String,
}

impl ServeKey {
    /// Validate and canonicalize an endpoint. Rejects unknown names
    /// and input selectors the benchmark lacks. Benchmarks of either
    /// recording mode serve: eager ones replay their cached recording,
    /// on-demand ones (GEMM-full, synth-grid) search lazily through
    /// the shared recorder on the first miss.
    pub fn resolve(
        benchmark: &str,
        gpu: &str,
        input: &str,
    ) -> Result<ServeKey, ServeError> {
        let benches = vec![benchmark.to_string()];
        validate_benchmarks("benchmark", &benches)?;
        validate_gpus("gpu", &[gpu.to_string()])?;
        validate_inputs("input", &benches, &[input.to_string()])?;
        let bench = benchmarks::by_name(benchmark).expect("validated");
        let spec = GpuSpec::by_name(gpu).expect("validated");
        let concrete = benchmarks::resolve_input(bench.as_ref(), input)
            .expect("validated");
        Ok(ServeKey {
            benchmark: bench.name().to_string(),
            gpu: spec.name.to_string(),
            input: concrete.name,
        })
    }

    fn to_fields(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("benchmark", Value::from(self.benchmark.clone())),
            ("gpu", Value::from(self.gpu.clone())),
            ("input", Value::from(self.input.clone())),
        ]
    }
}

impl std::fmt::Display for ServeKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}:{}", self.benchmark, self.gpu, self.input)
    }
}

/// One cached answer: the winning configuration plus enough identity
/// (search recipe hash, provenance) to audit where it came from.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningEntry {
    /// Winning configuration, in the space's parameter order.
    pub config: Vec<i64>,
    pub best_ms: f64,
    /// Empirical tests the search spent before its budget tripped.
    pub tests: usize,
    pub profiled_tests: usize,
    /// Simulated search cost, seconds — a miss's serving latency.
    pub cost_s: f64,
    /// Searcher that produced the entry (always `"profile"` today).
    pub searcher: String,
    /// Search recipe: budget cap and RNG base the entry derives from.
    pub max_tests: usize,
    pub base_seed: u64,
    /// FNV-1a hash of the search recipe (schema + key + budget + seed)
    /// — same identity scheme as the experiment reports.
    pub plan_hash: String,
    pub provenance: Provenance,
}

impl TuningEntry {
    pub fn to_json(&self, key: &ServeKey) -> Value {
        let mut fields = key.to_fields();
        fields.extend(vec![
            (
                "config",
                Value::Arr(
                    self.config.iter().map(|&v| Value::from(v)).collect(),
                ),
            ),
            ("best_ms", Value::from(self.best_ms)),
            ("tests", Value::from(self.tests)),
            ("profiled_tests", Value::from(self.profiled_tests)),
            ("cost_s", Value::from(self.cost_s)),
            ("searcher", Value::from(self.searcher.clone())),
            ("max_tests", Value::from(self.max_tests)),
            // u64 seeds ride as strings (f64 would corrupt > 2^53)
            ("base_seed", Value::from(self.base_seed.to_string())),
            ("plan_hash", Value::from(self.plan_hash.clone())),
            ("provenance", self.provenance.to_json()),
        ]);
        obj(fields)
    }

    fn from_json(v: &Value) -> Result<(ServeKey, TuningEntry), ServeError> {
        let field = |k: &str| {
            v.get(k).map_err(|_| {
                ServeError::Malformed(format!("entry missing key {k:?}"))
            })
        };
        let str_field = |k: &str| -> Result<String, ServeError> {
            field(k)?.as_str().map(str::to_string).ok_or_else(|| {
                ServeError::Malformed(format!("entry key {k:?} not a string"))
            })
        };
        let num_field = |k: &str| -> Result<f64, ServeError> {
            field(k)?.as_f64().ok_or_else(|| {
                ServeError::Malformed(format!("entry key {k:?} not a number"))
            })
        };
        let key = ServeKey {
            benchmark: str_field("benchmark")?,
            gpu: str_field("gpu")?,
            input: str_field("input")?,
        };
        let config = field("config")?
            .as_arr()
            .ok_or_else(|| {
                ServeError::Malformed("entry config not an array".into())
            })?
            .iter()
            .map(|c| {
                c.as_i64().ok_or_else(|| {
                    ServeError::Malformed(
                        "entry config value not an integer".into(),
                    )
                })
            })
            .collect::<Result<Vec<i64>, ServeError>>()?;
        let base_seed = str_field("base_seed")?.parse::<u64>().map_err(|_| {
            ServeError::Malformed("entry base_seed not a u64 string".into())
        })?;
        let prov = field("provenance")?;
        let prov_field = |k: &str| -> Result<String, ServeError> {
            prov.get(k)
                .ok()
                .and_then(|x| x.as_str())
                .map(str::to_string)
                .ok_or_else(|| {
                    ServeError::Malformed(format!(
                        "entry provenance missing {k:?}"
                    ))
                })
        };
        let entry = TuningEntry {
            config,
            best_ms: num_field("best_ms")?,
            tests: num_field("tests")? as usize,
            profiled_tests: num_field("profiled_tests")? as usize,
            cost_s: num_field("cost_s")?,
            searcher: str_field("searcher")?,
            max_tests: num_field("max_tests")? as usize,
            base_seed,
            plan_hash: str_field("plan_hash")?,
            provenance: Provenance {
                commit: prov_field("commit")?,
                created_at: prov_field("created_at")?,
                toolchain: prov_field("toolchain")?,
            },
        };
        Ok((key, entry))
    }
}

/// Serving-layer error: plan-style validation failures plus store
/// (de)serialization and I/O problems.
#[derive(Debug)]
pub enum ServeError {
    Plan(PlanError),
    /// Store document schema is not [`TUNING_STORE_SCHEMA`].
    UnknownSchema(String),
    Malformed(String),
    Io(String),
}

impl From<PlanError> for ServeError {
    fn from(e: PlanError) -> Self {
        ServeError::Plan(e)
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Plan(e) => write!(f, "{e}"),
            ServeError::UnknownSchema(s) => write!(
                f,
                "unknown tuning-store schema {s:?}; this build reads \
                 {TUNING_STORE_SCHEMA:?}"
            ),
            ServeError::Malformed(m) => {
                write!(f, "malformed tuning store: {m}")
            }
            ServeError::Io(m) => write!(f, "tuning store I/O: {m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// The answer cache behind the serve engine. Implementations must be
/// safe to hammer from the worker pool; `get` is the concurrent read
/// path, `put` the (rarer) fill path.
pub trait TuningStore: Send + Sync {
    fn get(&self, key: &ServeKey) -> Option<TuningEntry>;
    fn put(&self, key: &ServeKey, entry: &TuningEntry)
        -> Result<(), ServeError>;
    /// All entries in sorted key order (the canonical export order).
    fn entries(&self) -> Vec<(ServeKey, TuningEntry)>;
    fn len(&self) -> usize {
        self.entries().len()
    }
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Render a store as its canonical, versioned JSON document — sorted
/// entries under the [`TUNING_STORE_SCHEMA`] tag. [`JsonFileStore`]
/// writes exactly these bytes, so exporting a file-backed store
/// reproduces its own file byte-for-byte.
pub fn export_store(store: &dyn TuningStore) -> Value {
    store_doc(&store.entries())
}

fn store_doc(entries: &[(ServeKey, TuningEntry)]) -> Value {
    obj(vec![
        (
            "entries",
            Value::Arr(
                entries.iter().map(|(k, e)| e.to_json(k)).collect(),
            ),
        ),
        ("schema", Value::from(TUNING_STORE_SCHEMA)),
    ])
}

/// The rendered form shared by [`export_store`] output and the
/// [`JsonFileStore`] file.
pub fn render_store(doc: &Value) -> String {
    let mut s = doc.to_string_pretty(1);
    s.push('\n');
    s
}

/// Load every entry of an exported document into `store` (schema
/// checked, existing keys overwritten). Returns the number of entries
/// imported.
pub fn import_store(
    store: &dyn TuningStore,
    doc: &Value,
) -> Result<usize, ServeError> {
    let entries = parse_store_doc(doc)?;
    let n = entries.len();
    for (key, entry) in &entries {
        store.put(key, entry)?;
    }
    Ok(n)
}

fn parse_store_doc(
    doc: &Value,
) -> Result<Vec<(ServeKey, TuningEntry)>, ServeError> {
    let schema = doc
        .get("schema")
        .ok()
        .and_then(|v| v.as_str())
        .ok_or_else(|| {
            ServeError::Malformed("store document has no schema".into())
        })?;
    if schema != TUNING_STORE_SCHEMA {
        return Err(ServeError::UnknownSchema(schema.to_string()));
    }
    let arr = doc
        .get("entries")
        .ok()
        .and_then(|v| v.as_arr().map(<[Value]>::to_vec))
        .ok_or_else(|| {
            ServeError::Malformed("store document has no entries array".into())
        })?;
    arr.iter().map(TuningEntry::from_json).collect()
}

/// In-memory [`TuningStore`] — the default backend for `pcat serve`
/// load generation and tests.
#[derive(Default)]
pub struct MemTuningStore {
    entries: Mutex<BTreeMap<ServeKey, TuningEntry>>,
}

impl MemTuningStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl TuningStore for MemTuningStore {
    fn get(&self, key: &ServeKey) -> Option<TuningEntry> {
        lock_unpoisoned(&self.entries).get(key).cloned()
    }

    fn put(
        &self,
        key: &ServeKey,
        entry: &TuningEntry,
    ) -> Result<(), ServeError> {
        lock_unpoisoned(&self.entries).insert(key.clone(), entry.clone());
        Ok(())
    }

    fn entries(&self) -> Vec<(ServeKey, TuningEntry)> {
        lock_unpoisoned(&self.entries)
            .iter()
            .map(|(k, e)| (k.clone(), e.clone()))
            .collect()
    }

    fn len(&self) -> usize {
        lock_unpoisoned(&self.entries).len()
    }
}

/// On-disk [`TuningStore`]: a JSON document (schema
/// [`TUNING_STORE_SCHEMA`]) rewritten atomically-enough for a single
/// process on every fill. Opening a missing file starts empty; opening
/// an existing one validates the schema and loads every entry.
pub struct JsonFileStore {
    path: PathBuf,
    entries: Mutex<BTreeMap<ServeKey, TuningEntry>>,
}

impl JsonFileStore {
    pub fn open(path: &Path) -> Result<JsonFileStore, ServeError> {
        let mut entries = BTreeMap::new();
        if path.exists() {
            let text = std::fs::read_to_string(path).map_err(|e| {
                ServeError::Io(format!("reading {}: {e}", path.display()))
            })?;
            let doc = crate::util::json::parse(&text).map_err(|e| {
                ServeError::Malformed(format!("{}: {e}", path.display()))
            })?;
            for (key, entry) in parse_store_doc(&doc)? {
                entries.insert(key, entry);
            }
        }
        Ok(JsonFileStore {
            path: path.to_path_buf(),
            entries: Mutex::new(entries),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn persist(
        &self,
        entries: &BTreeMap<ServeKey, TuningEntry>,
    ) -> Result<(), ServeError> {
        let flat: Vec<(ServeKey, TuningEntry)> = entries
            .iter()
            .map(|(k, e)| (k.clone(), e.clone()))
            .collect();
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| {
                ServeError::Io(format!("creating {}: {e}", dir.display()))
            })?;
        }
        std::fs::write(&self.path, render_store(&store_doc(&flat))).map_err(
            |e| ServeError::Io(format!("writing {}: {e}", self.path.display())),
        )
    }
}

impl TuningStore for JsonFileStore {
    fn get(&self, key: &ServeKey) -> Option<TuningEntry> {
        lock_unpoisoned(&self.entries).get(key).cloned()
    }

    fn put(
        &self,
        key: &ServeKey,
        entry: &TuningEntry,
    ) -> Result<(), ServeError> {
        // hold the lock across the write so concurrent fills can never
        // interleave a torn document
        let mut entries = lock_unpoisoned(&self.entries);
        entries.insert(key.clone(), entry.clone());
        self.persist(&entries)
    }

    fn entries(&self) -> Vec<(ServeKey, TuningEntry)> {
        lock_unpoisoned(&self.entries)
            .iter()
            .map(|(k, e)| (k.clone(), e.clone()))
            .collect()
    }

    fn len(&self) -> usize {
        lock_unpoisoned(&self.entries).len()
    }
}

/// Engine knobs; an entry is a pure function of (key, this config).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// RNG stream base for miss searches.
    pub base_seed: u64,
    /// Budget cap per miss search (the convergence threshold is the
    /// usual 1.1× best-time, same as the plan runners).
    pub max_tests: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            base_seed: 0,
            max_tests: 400,
        }
    }
}

/// One answered query: the entry plus whether it was served without
/// running a search in this call (`hit`).
#[derive(Debug, Clone)]
pub struct QueryOutcome {
    pub key: ServeKey,
    pub entry: TuningEntry,
    /// `false` exactly when this call ran (and persisted) the search.
    pub hit: bool,
}

/// The query engine: concurrent read path over the store +
/// `Arc`-shared caches, exactly-once write path on miss.
pub struct ServeEngine {
    store: Arc<dyn TuningStore>,
    cfg: ServeConfig,
    /// Collapses concurrent misses for one endpoint onto one search.
    inflight: OnceMap<ServeKey, TuningEntry>,
    fills: AtomicUsize,
}

impl ServeEngine {
    pub fn new(store: Arc<dyn TuningStore>, cfg: ServeConfig) -> ServeEngine {
        ServeEngine {
            store,
            cfg,
            inflight: OnceMap::new(),
            fills: AtomicUsize::new(0),
        }
    }

    pub fn store(&self) -> &Arc<dyn TuningStore> {
        &self.store
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Searches this engine has run (and persisted) so far — equals
    /// the number of distinct endpoints that ever missed.
    pub fn fills(&self) -> usize {
        self.fills.load(Ordering::SeqCst)
    }

    /// Answer "best config for this endpoint". Hits return the stored
    /// entry; misses run one bounded profile search (concurrent misses
    /// for the same endpoint share it) and persist the result.
    pub fn query(&self, key: &ServeKey) -> Result<QueryOutcome, ServeError> {
        // re-resolve: keys are plain data, so a hand-built or imported
        // key must be validated before it can reach the search path
        let key = ServeKey::resolve(&key.benchmark, &key.gpu, &key.input)?;
        if let Some(entry) = self.store.get(&key) {
            return Ok(QueryOutcome {
                key,
                entry,
                hit: true,
            });
        }
        let (entry, ran) = self
            .inflight
            .get_or_init_tracked(&key, || self.search(&key));
        if ran {
            self.fills.fetch_add(1, Ordering::SeqCst);
            self.store.put(&key, &entry)?;
        }
        Ok(QueryOutcome {
            key,
            entry,
            hit: !ran,
        })
    }

    /// The miss path: bounded profile search seeded purely by the
    /// endpoint key — over the shared recording and prediction matrix
    /// (eager benchmarks), or lazily through the shared on-demand
    /// recorder (large-space benchmarks; nothing space-sized is ever
    /// materialized, and the memo carries over between misses).
    fn search(&self, key: &ServeKey) -> TuningEntry {
        let bench =
            benchmarks::by_name(&key.benchmark).expect("resolved serve key");
        let gpu = GpuSpec::by_name(&key.gpu).expect("resolved serve key");
        let input = benchmarks::resolve_input(bench.as_ref(), &key.input)
            .expect("resolved serve key");
        let seed = stream_seed(
            self.cfg.base_seed,
            &[&key.benchmark, &key.gpu, &key.input, "serve"],
            0,
        );
        let inst_reaction = inst_reaction_for(bench.as_ref());
        let profile = SearcherSpec::parse("profile").expect("registry name");
        let result = match bench.recording_mode() {
            RecordingMode::Eager => {
                let rec =
                    benchmarks::cached_space(bench.as_ref(), &gpu, &input);
                let matrix =
                    benchmarks::cached_matrix(bench.as_ref(), &gpu, &input);
                let thr = rec.best_time() * 1.1;
                let ctx = CellCtx::new(
                    ModelCtx::Eager { matrix },
                    inst_reaction,
                    0,
                );
                Tuner::replay(rec, gpu, CostModel::default())
                    .with_budget(Budget::until(thr, self.cfg.max_tests))
                    .with_seed(seed)
                    .run(&profile, &ctx)
            }
            RecordingMode::OnDemand => {
                let recorder =
                    benchmarks::cached_recorder(bench.as_ref(), &gpu, &input);
                let ctx = CellCtx::new(
                    ModelCtx::Lazy {
                        recorder: Arc::clone(&recorder),
                    },
                    inst_reaction,
                    0,
                );
                // no known best to stop at — run to the test budget
                Tuner::over(Box::new(OnDemandEnv::new(
                    recorder,
                    CostModel::default(),
                )))
                .with_budget(Budget::tests(self.cfg.max_tests))
                .with_seed(seed)
                .run(&profile, &ctx)
            }
        };
        TuningEntry {
            config: result.best_config.0.clone(),
            best_ms: result.best_ms,
            tests: result.tests,
            profiled_tests: result.profiled_tests,
            cost_s: result.cost_s,
            searcher: "profile".to_string(),
            max_tests: self.cfg.max_tests,
            base_seed: self.cfg.base_seed,
            plan_hash: recipe_hash(key, &self.cfg),
            provenance: Provenance::from_env(),
        }
    }
}

/// The entry's identity: FNV-1a over the canonical search recipe, same
/// scheme as the experiment reports — a pure function of *what was
/// asked for*, identical across thread counts, machines and reruns.
fn recipe_hash(key: &ServeKey, cfg: &ServeConfig) -> String {
    let mut fields = key.to_fields();
    fields.extend(vec![
        ("base_seed", Value::from(cfg.base_seed.to_string())),
        ("max_tests", Value::from(cfg.max_tests)),
        ("searcher", Value::from("profile")),
    ]);
    plan_hash(TUNING_STORE_SCHEMA, &obj(fields))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> ServeKey {
        ServeKey::resolve("coulomb", "gtx1070", "default").unwrap()
    }

    #[test]
    fn resolve_canonicalizes_and_validates() {
        let k = ServeKey::resolve("Coulomb", "GTX-1070", "default").unwrap();
        assert_eq!(k, key());
        assert!(matches!(
            ServeKey::resolve("nope", "gtx1070", "default"),
            Err(ServeError::Plan(PlanError::UnknownBenchmark(_)))
        ));
        // the carve-out is retired: on-demand benchmarks serve too
        assert!(ServeKey::resolve("gemm-full", "gtx1070", "default").is_ok());
        assert!(ServeKey::resolve("synth-grid", "gtx1070", "default").is_ok());
        assert!(matches!(
            ServeKey::resolve("coulomb", "gtx9999", "default"),
            Err(ServeError::Plan(PlanError::UnknownGpu(_)))
        ));
        assert!(matches!(
            ServeKey::resolve("coulomb", "gtx1070", "no-such-input"),
            Err(ServeError::Plan(PlanError::UnknownInput(_, _)))
        ));
    }

    #[test]
    fn on_demand_endpoint_serves_without_materializing_the_space() {
        // a ≥1M-config endpoint must answer its first miss in bounded
        // work: the lazy search simulates only what it visits/scores
        let engine = ServeEngine::new(
            Arc::new(MemTuningStore::new()),
            ServeConfig {
                base_seed: 23,
                max_tests: 18,
            },
        );
        let k = ServeKey::resolve("synth-grid", "gtx1070", "default").unwrap();
        let first = engine.query(&k).unwrap();
        assert!(!first.hit);
        assert_eq!(first.entry.tests, 18);
        assert!(first.entry.best_ms.is_finite());
        assert_eq!(first.entry.config.len(), 10);
        let second = engine.query(&k).unwrap();
        assert!(second.hit);
        assert_eq!(first.entry, second.entry);
    }

    #[test]
    fn miss_then_hit_returns_identical_entry() {
        let engine = ServeEngine::new(
            Arc::new(MemTuningStore::new()),
            ServeConfig {
                base_seed: 11,
                max_tests: 60,
            },
        );
        let k = key();
        let first = engine.query(&k).unwrap();
        assert!(!first.hit);
        assert_eq!(engine.fills(), 1);
        let second = engine.query(&k).unwrap();
        assert!(second.hit);
        assert_eq!(engine.fills(), 1);
        assert_eq!(first.entry, second.entry);
        assert!(!first.entry.config.is_empty());
        assert!(first.entry.best_ms.is_finite());
    }

    #[test]
    fn entries_are_pure_functions_of_key_and_config() {
        let cfg = ServeConfig {
            base_seed: 5,
            max_tests: 60,
        };
        let a = ServeEngine::new(Arc::new(MemTuningStore::new()), cfg.clone());
        let b = ServeEngine::new(Arc::new(MemTuningStore::new()), cfg);
        assert_eq!(
            a.query(&key()).unwrap().entry,
            b.query(&key()).unwrap().entry
        );
    }

    #[test]
    fn entry_json_round_trips() {
        let engine = ServeEngine::new(
            Arc::new(MemTuningStore::new()),
            ServeConfig::default(),
        );
        let out = engine.query(&key()).unwrap();
        let v = out.entry.to_json(&out.key);
        let (k2, e2) = TuningEntry::from_json(&v).unwrap();
        assert_eq!(k2, out.key);
        assert_eq!(e2, out.entry);
    }

    #[test]
    fn import_rejects_wrong_schema() {
        let store = MemTuningStore::new();
        let doc = obj(vec![
            ("entries", Value::Arr(vec![])),
            ("schema", Value::from("pcat-tuning-store/v0")),
        ]);
        assert!(matches!(
            import_store(&store, &doc),
            Err(ServeError::UnknownSchema(_))
        ));
    }

    #[test]
    fn export_import_round_trip_is_byte_identical() {
        let store = MemTuningStore::new();
        let engine =
            ServeEngine::new(Arc::new(MemTuningStore::new()), ServeConfig {
                base_seed: 3,
                max_tests: 60,
            });
        let out = engine.query(&key()).unwrap();
        store.put(&out.key, &out.entry).unwrap();
        let doc = export_store(&store);
        let twin = MemTuningStore::new();
        assert_eq!(import_store(&twin, &doc).unwrap(), 1);
        assert_eq!(
            render_store(&export_store(&twin)),
            render_store(&doc)
        );
        assert_eq!(twin.get(&out.key).unwrap(), out.entry);
    }
}
