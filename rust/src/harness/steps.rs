//! Step-count statistics: the "number of empirical tests to reach a
//! well-performing configuration" metric (§4.1), averaged over many
//! repetitions of the stochastic search — parallelized across seeds on
//! the shared job pool ([`crate::util::pool`]).

use std::sync::Arc;

use crate::searcher::{Budget, CostModel, ReplayEnv, Searcher};
use crate::tuning::RecordedSpace;
use crate::util::pool;
use crate::util::stats::mean;

/// Map `f` over seeds `0..reps` on the shared pool, preserving order.
/// Results are independent of the worker count (`--jobs`).
pub fn par_map_seeds<T, F>(reps: usize, f: &F) -> Vec<T>
where
    T: Send,
    F: Fn(u64) -> T + Sync,
{
    pool::par_map(reps, &|i| f(i as u64))
}

/// Average number of empirical tests a searcher needs to find a
/// configuration within 1.1× of the exhaustive best (§4.1), over `reps`
/// independent runs.
///
/// `make` builds a fresh searcher for a seed; the searcher runs until it
/// hits the threshold (model-build steps excluded from the stop check
/// but included in the count, matching Table 8's accounting). The
/// recording is shared by reference across all repetitions.
pub fn avg_steps_to_well_performing<'a, F>(
    rec: &Arc<RecordedSpace>,
    gpu: &crate::gpusim::GpuSpec,
    reps: usize,
    seed_base: u64,
    make: F,
) -> f64
where
    F: Fn(u64) -> Box<dyn Searcher + 'a> + Sync,
{
    let thr = rec.best_time() * 1.1;
    let counts = par_map_seeds(reps, &|seed| {
        let mut env =
            ReplayEnv::new(Arc::clone(rec), gpu.clone(), CostModel::default());
        let mut searcher = make(seed_base.wrapping_add(seed));
        let trace = env_run(&mut *searcher, &mut env, thr);
        trace as f64
    });
    mean(&counts)
}

fn env_run(
    searcher: &mut dyn Searcher,
    env: &mut ReplayEnv,
    thr: f64,
) -> usize {
    let trace = searcher.run(env, &Budget::until(thr, usize::MAX));
    trace
        .tests_to_threshold(thr)
        .unwrap_or(trace.len().max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{cached_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::RandomSearcher;

    #[test]
    fn par_map_preserves_order() {
        let out = par_map_seeds(100, &|s| s * 2);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 2);
        }
    }

    #[test]
    fn par_map_zero_reps() {
        let out: Vec<u64> = par_map_seeds(0, &|s| s);
        assert!(out.is_empty());
    }

    #[test]
    fn random_steps_match_analytic_expectation() {
        // with w well-performing configs out of n, random-without-
        // replacement needs (n+1)/(w+1) tests in expectation
        let gpu = GpuSpec::gtx1070();
        let rec = cached_space(&Coulomb, &gpu, &Coulomb.default_input());
        let n = rec.space.len() as f64;
        let w = rec.well_performing_count(1.1) as f64;
        let expect = (n + 1.0) / (w + 1.0);
        let got = avg_steps_to_well_performing(&rec, &gpu, 400, 0, |s| {
            Box::new(RandomSearcher::new(s))
        });
        assert!(
            (got - expect).abs() < expect * 0.25,
            "got {got}, analytic {expect}"
        );
    }
}
