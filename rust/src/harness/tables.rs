//! Table reproductions (paper §4.3–§4.8) plus two design-choice
//! ablations called out in DESIGN.md.

use std::sync::Arc;

use crate::benchmarks::{self, cached_space, Benchmark};
use crate::gpusim::GpuSpec;
use crate::model::{
    dataset_from_recorded, DecisionTreeModel, OracleModel, PrecomputedModel,
    RegressionModel, TpPcModel,
};
use crate::searcher::{
    Budget, CostModel, EvalEnv, ProfileSearcher, RandomSearcher, ReplayEnv,
    Searcher, Starchart,
};
use crate::tuning::RecordedSpace;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::{markdown, speedup};

use super::plan::PlanReport;
use super::registry::{CompareFinding, RegistryRow};
use super::steps::{avg_steps_to_well_performing, par_map_seeds};
use super::sweep::SweepReport;
use super::transfer::{TransferAggregate, TransferPlan, TransferReport};
use super::{ExperimentOpts, Report};

/// The five benchmarks of the step-count experiments, in Table 4 order.
fn eval_benchmarks() -> Vec<Box<dyn Benchmark>> {
    benchmarks::evaluation_set()
}

/// Paper's Table 4 values (rows in eval order, columns in GPU order),
/// cited for side-by-side comparison in the generated reports.
const PAPER_TABLE4: [[f64; 4]; 5] = [
    [19.0, 21.0, 34.0, 16.0],
    [192.0, 24.0, 10.0, 47.0],
    [146.0, 248.0, 450.0, 260.0],
    [27.0, 10.0, 37.0, 39.0],
    [327.0, 702.0, 349.0, 568.0],
];

/// Paper's Table 5 improvement factors.
const PAPER_TABLE5: [[f64; 4]; 5] = [
    [3.8, 5.25, 5.67, 3.2],
    [3.62, 2.0, 1.43, 1.12],
    [5.41, 7.75, 8.88, 10.83],
    [1.93, 2.5, 2.85, 3.25],
    [8.18, 10.32, 15.86, 14.56],
];

fn inst_reaction_for(b: &dyn Benchmark) -> f64 {
    if b.instruction_bound() {
        crate::expert::INST_BOUND_REACTION
    } else {
        crate::expert::DEFAULT_INST_REACTION
    }
}

fn random_avg(
    rec: &Arc<RecordedSpace>,
    gpu: &GpuSpec,
    opts: &ExperimentOpts,
) -> f64 {
    avg_steps_to_well_performing(rec, gpu, opts.reps, opts.seed, |s| {
        Box::new(RandomSearcher::new(s))
    })
}

fn profile_avg(
    rec: &Arc<RecordedSpace>,
    gpu: &GpuSpec,
    model: &(dyn TpPcModel + Sync),
    inst_reaction: f64,
    opts: &ExperimentOpts,
) -> f64 {
    avg_steps_to_well_performing(rec, gpu, opts.reps, opts.seed ^ 0x9e37, |s| {
        Box::new(ProfileSearcher::new(model, inst_reaction, s))
    })
}

/// Train a decision-tree TP→PC model on a recorded space and precompute
/// its predictions over `target` (the space being tuned).
fn trained_model(
    model_rec: &RecordedSpace,
    target: &RecordedSpace,
    seed: u64,
) -> PrecomputedModel {
    let mut rng = Rng::new(seed);
    let ds = dataset_from_recorded(model_rec, 1.0, &mut rng);
    let dtm = DecisionTreeModel::train(&ds, &model_rec.gpu, &mut rng);
    PrecomputedModel::over(&target.space, &dtm)
}

// ---------------------------------------------------------------------
// Table 2 — benchmark spaces
// ---------------------------------------------------------------------

pub fn table2() -> Report {
    let paper: &[(&str, usize, usize)] = &[
        ("convolution", 10, 3_928),
        ("coulomb", 7, 210),
        ("gemm", 10, 5_788),
        ("gemm-full", 14, 205_216),
        ("transpose", 8, 1_784),
        ("nbody", 7, 3_134),
    ];
    let mut rows = Vec::new();
    for (name, paper_dims, paper_cfgs) in paper {
        let b = benchmarks::by_name(name).unwrap();
        let s = b.space();
        rows.push(vec![
            name.to_string(),
            format!("{} (paper {})", s.dims(), paper_dims),
            format!("{} (paper {})", s.len(), paper_cfgs),
        ]);
    }
    Report {
        id: "table2",
        title: "Benchmarks: dimensions and tuning-space sizes".into(),
        markdown: markdown(&["benchmark", "dimensions", "configurations"], &rows),
        csvs: vec![],
    }
}

// ---------------------------------------------------------------------
// Table 4 — random search baseline
// ---------------------------------------------------------------------

pub fn table4(opts: &ExperimentOpts) -> Report {
    let gpus = GpuSpec::all();
    let mut rows = Vec::new();
    let mut csv = String::from("benchmark,gpu,steps,paper\n");
    for (bi, b) in eval_benchmarks().iter().enumerate() {
        let mut row = vec![b.name().to_string()];
        for (gi, gpu) in gpus.iter().enumerate() {
            let rec = cached_space(b.as_ref(), gpu, &b.default_input());
            let steps = random_avg(&rec, gpu, opts);
            row.push(format!(
                "{:.0} (paper {:.0})",
                steps, PAPER_TABLE4[bi][gi]
            ));
            csv.push_str(&format!(
                "{},{},{:.2},{}\n",
                b.name(),
                gpu.name,
                steps,
                PAPER_TABLE4[bi][gi]
            ));
        }
        rows.push(row);
    }
    Report {
        id: "table4",
        title: format!(
            "Average empirical tests for random search (reps={})",
            opts.reps
        ),
        markdown: markdown(
            &["benchmark", "GTX680", "GTX750", "GTX1070", "RTX2080"],
            &rows,
        ),
        csvs: vec![("table4_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Table 5 — proposed searcher with exact PCs (oracle), same GPU
// ---------------------------------------------------------------------

pub fn table5(opts: &ExperimentOpts) -> Report {
    let gpus = GpuSpec::all();
    let mut rows = Vec::new();
    let mut csv = String::from("benchmark,gpu,random,profile,improvement,paper\n");
    for (bi, b) in eval_benchmarks().iter().enumerate() {
        let mut row = vec![b.name().to_string()];
        for (gi, gpu) in gpus.iter().enumerate() {
            let rec = cached_space(b.as_ref(), gpu, &b.default_input());
            let rand = random_avg(&rec, gpu, opts);
            let oracle = OracleModel::new(&rec);
            let prof = profile_avg(
                &rec,
                gpu,
                &oracle,
                inst_reaction_for(b.as_ref()),
                opts,
            );
            let imp = rand / prof.max(1.0);
            row.push(format!(
                "{} (paper {})",
                speedup(imp),
                speedup(PAPER_TABLE5[bi][gi])
            ));
            csv.push_str(&format!(
                "{},{},{:.2},{:.2},{:.3},{}\n",
                b.name(),
                gpu.name,
                rand,
                prof,
                imp,
                PAPER_TABLE5[bi][gi]
            ));
        }
        rows.push(row);
    }
    Report {
        id: "table5",
        title: format!(
            "Improvement of the profile searcher over random (exact PCs, \
             same architecture; reps={})",
            opts.reps
        ),
        markdown: markdown(
            &["benchmark", "GTX680", "GTX750", "GTX1070", "RTX2080"],
            &rows,
        ),
        csvs: vec![("table5_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Table 6 — hardware portability of the model
// ---------------------------------------------------------------------

pub fn table6(opts: &ExperimentOpts) -> Report {
    let gpus = GpuSpec::all();
    let mut md = String::new();
    let mut csv =
        String::from("benchmark,tune_gpu,model_gpu,random,profile,improvement\n");
    for b in eval_benchmarks() {
        // records per GPU (model side and tuning side use the same)
        let recs: Vec<Arc<RecordedSpace>> = gpus
            .iter()
            .map(|g| cached_space(b.as_ref(), g, &b.default_input()))
            .collect();
        // decision-tree models trained per model-GPU; predictions are
        // precomputed over the benchmark's (shared) space
        let models: Vec<PrecomputedModel> = gpus
            .iter()
            .enumerate()
            .map(|(i, _)| trained_model(&recs[i], &recs[i], opts.seed + i as u64))
            .collect();

        let mut rows = Vec::new();
        for (ti, tune_gpu) in gpus.iter().enumerate() {
            let rand = random_avg(&recs[ti], tune_gpu, opts);
            let mut row = vec![tune_gpu.name.to_string()];
            for (mi, _model_gpu) in gpus.iter().enumerate() {
                let prof = profile_avg(
                    &recs[ti],
                    tune_gpu,
                    &models[mi],
                    inst_reaction_for(b.as_ref()),
                    opts,
                );
                let imp = rand / prof.max(1.0);
                row.push(speedup(imp));
                csv.push_str(&format!(
                    "{},{},{},{:.2},{:.2},{:.3}\n",
                    b.name(),
                    tune_gpu.name,
                    gpus[mi].name,
                    rand,
                    prof,
                    imp
                ));
            }
            rows.push(row);
        }
        md.push_str(&format!("\n## {} benchmark\n\n", b.name()));
        md.push_str(
            "Rows: GPU used for tuning. Columns: GPU the model was \
             trained on.\n\n",
        );
        md.push_str(&markdown(
            &["tuned on ↓", "GTX680", "GTX750", "GTX1070", "RTX2080"],
            &rows,
        ));
    }
    Report {
        id: "table6",
        title: format!(
            "Model portability across hardware (decision-tree model; reps={})",
            opts.reps
        ),
        markdown: md,
        csvs: vec![("table6_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Table 7 — input portability (GEMM, GTX 1070)
// ---------------------------------------------------------------------

pub fn table7(opts: &ExperimentOpts) -> Report {
    let gpu = GpuSpec::gtx1070();
    let gemm = benchmarks::by_name("gemm").unwrap();
    let inputs = gemm.inputs();
    let recs: Vec<Arc<RecordedSpace>> = inputs
        .iter()
        .map(|i| cached_space(gemm.as_ref(), &gpu, i))
        .collect();
    let models: Vec<PrecomputedModel> = (0..inputs.len())
        .map(|i| trained_model(&recs[i], &recs[i], opts.seed + 31 + i as u64))
        .collect();

    let mut rows = Vec::new();
    let mut csv =
        String::from("tune_input,model_input,random,profile,improvement\n");
    for (ti, input) in inputs.iter().enumerate() {
        let rand = random_avg(&recs[ti], &gpu, opts);
        let mut row = vec![input.name.clone()];
        for (mi, _src) in inputs.iter().enumerate() {
            let prof = profile_avg(&recs[ti], &gpu, &models[mi], 0.7, opts);
            let imp = rand / prof.max(1.0);
            row.push(speedup(imp));
            csv.push_str(&format!(
                "{},{},{:.2},{:.2},{:.3}\n",
                input.name, inputs[mi].name, rand, prof, imp
            ));
        }
        rows.push(row);
    }
    let header: Vec<String> = std::iter::once("tuned input ↓".to_string())
        .chain(inputs.iter().map(|i| i.name.clone()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    Report {
        id: "table7",
        title: format!(
            "Model portability across GEMM inputs on GTX 1070 (reps={})",
            opts.reps
        ),
        markdown: markdown(&header_refs, &rows),
        csvs: vec![("table7_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Table 8 — Starchart vs random
// ---------------------------------------------------------------------

pub fn table8(opts: &ExperimentOpts) -> Report {
    let mut md = String::new();
    let mut csv = String::from(
        "gpu,benchmark,model_build,tuning,random\n",
    );
    for gpu in [GpuSpec::gtx1070(), GpuSpec::rtx2080()] {
        let mut rows = Vec::new();
        for b in eval_benchmarks() {
            let rec = cached_space(b.as_ref(), &gpu, &b.default_input());
            let thr = rec.best_time() * 1.1;
            let reps = opts.reps.min(200); // Starchart sweeps most of small spaces
            let stats: Vec<(f64, f64)> = par_map_seeds(reps, &|seed| {
                let mut env = ReplayEnv::new(
                    rec.clone(),
                    gpu.clone(),
                    CostModel::default(),
                );
                let mut s = Starchart::new(opts.seed ^ (seed * 7 + 1));
                let trace = s.run(&mut env, &Budget::until(thr, usize::MAX));
                let build = trace.build_steps() as f64;
                let total = trace
                    .tests_to_threshold(thr)
                    .unwrap_or(trace.len()) as f64;
                (build, (total - build).max(0.0))
            });
            let build = mean(&stats.iter().map(|s| s.0).collect::<Vec<_>>());
            let tune = mean(&stats.iter().map(|s| s.1).collect::<Vec<_>>());
            let rand = random_avg(&rec, &gpu, opts);
            rows.push(vec![
                b.name().to_string(),
                format!("{build:.0}"),
                format!("{tune:.0}"),
                format!("{rand:.0}"),
            ]);
            csv.push_str(&format!(
                "{},{},{:.2},{:.2},{:.2}\n",
                gpu.name,
                b.name(),
                build,
                tune,
                rand
            ));
        }
        md.push_str(&format!("\n## {}\n\n", gpu.name));
        md.push_str(&markdown(
            &["benchmark", "model build", "tuning", "random"],
            &rows,
        ));
    }
    Report {
        id: "table8",
        title: "Starchart (regression trees) vs random search".into(),
        markdown: md,
        csvs: vec![("table8_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Table 9 — Starchart@1070 vs proposed@1070, tuning RTX 2080
// ---------------------------------------------------------------------

pub fn table9(opts: &ExperimentOpts) -> Report {
    let gpu_model = GpuSpec::gtx1070();
    let gpu_tune = GpuSpec::rtx2080();
    let mut rows = Vec::new();
    let mut csv = String::from("benchmark,starchart_1070,proposed_1070\n");
    for b in eval_benchmarks() {
        let rec_model =
            cached_space(b.as_ref(), &gpu_model, &b.default_input());
        let rec_tune = cached_space(b.as_ref(), &gpu_tune, &b.default_input());
        let thr = rec_tune.best_time() * 1.1;
        let reps = opts.reps.min(200);

        // Starchart: train the runtime tree on 1070 data, reuse on 2080.
        let sc_steps: Vec<f64> = par_map_seeds(reps, &|seed| {
            let mut env1 = ReplayEnv::new(
                rec_model.clone(),
                gpu_model.clone(),
                CostModel::default(),
            );
            let mut s1 = Starchart::new(opts.seed ^ (seed * 13 + 5));
            let thr1 = rec_model.best_time() * 1.1;
            s1.run(&mut env1, &Budget::until(thr1, usize::MAX));
            let tree = s1.trained_tree.expect("tree trained");

            let mut env2 = ReplayEnv::new(
                rec_tune.clone(),
                gpu_tune.clone(),
                CostModel::default(),
            );
            let mut s2 =
                Starchart::with_pretrained(opts.seed ^ (seed * 17 + 3), tree);
            let trace = s2.run(&mut env2, &Budget::until(thr, usize::MAX));
            trace.tests_to_threshold(thr).unwrap_or(trace.len()) as f64
        });

        // Proposed: decision-tree TP→PC model from 1070, tuning 2080.
        let model = trained_model(&rec_model, &rec_tune, opts.seed + 77);
        let prof = profile_avg(
            &rec_tune,
            &gpu_tune,
            &model,
            inst_reaction_for(b.as_ref()),
            opts,
        );

        let sc = mean(&sc_steps);
        rows.push(vec![
            b.name().to_string(),
            format!("{sc:.0}"),
            format!("{prof:.0}"),
        ]);
        csv.push_str(&format!("{},{:.2},{:.2}\n", b.name(), sc, prof));
    }
    Report {
        id: "table9",
        title: "Models trained on GTX 1070, tuning RTX 2080: Starchart vs \
                proposed searcher (empirical tuning steps)"
            .into(),
        markdown: markdown(
            &["benchmark", "SC@1070", "proposed@1070"],
            &rows,
        ),
        csvs: vec![("table9_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Ablations (DESIGN.md §5): the paper's design choices
// ---------------------------------------------------------------------

/// Ablation: the profiling interval `n` (Algorithm 1's unprofiled steps
/// per round; paper default 5) trades profiling overhead against
/// reaction latency.
pub fn ablation_profile_interval(opts: &ExperimentOpts) -> Report {
    let gpu = GpuSpec::gtx1070();
    let gemm = benchmarks::by_name("gemm").unwrap();
    let rec = cached_space(gemm.as_ref(), &gpu, &gemm.default_input());
    let oracle = OracleModel::new(&rec);
    let thr = rec.best_time() * 1.1;

    let mut rows = Vec::new();
    let mut csv = String::from("n,steps,cost_s\n");
    for n in [1usize, 3, 5, 10, 20] {
        let reps = opts.reps.min(300);
        let stats: Vec<(f64, f64)> = par_map_seeds(reps, &|seed| {
            let mut env = ReplayEnv::new(
                rec.clone(),
                gpu.clone(),
                CostModel::default(),
            );
            let mut s = ProfileSearcher::new(&oracle, 0.7, seed);
            s.n_unprofiled = n;
            let trace = s.run(&mut env, &Budget::until(thr, usize::MAX));
            let steps =
                trace.tests_to_threshold(thr).unwrap_or(trace.len());
            let cost = trace
                .cost_to_threshold(thr)
                .unwrap_or(env.cost_so_far());
            (steps as f64, cost)
        });
        let steps = mean(&stats.iter().map(|s| s.0).collect::<Vec<_>>());
        let cost = mean(&stats.iter().map(|s| s.1).collect::<Vec<_>>());
        rows.push(vec![
            n.to_string(),
            format!("{steps:.1}"),
            format!("{cost:.1}"),
        ]);
        csv.push_str(&format!("{n},{steps:.3},{cost:.3}\n"));
    }
    Report {
        id: "ablation_n",
        title: "Ablation: unprofiled steps per profiling round (GEMM, \
                GTX 1070, oracle PCs)"
            .into(),
        markdown: markdown(&["n", "steps to 1.1×", "cost (s)"], &rows),
        csvs: vec![("ablation_n_data".into(), csv)],
    }
}

/// Ablation: global scoring vs the §3.9.1 neighbourhood-restricted
/// (local) variant, which also bounds the per-round scoring cost on
/// huge spaces (footnote 5).
pub fn ablation_local_search(opts: &ExperimentOpts) -> Report {
    let gpu = GpuSpec::rtx2080();
    let mut rows = Vec::new();
    let mut csv = String::from("benchmark,variant,steps\n");
    for name in ["coulomb", "gemm"] {
        let b = benchmarks::by_name(name).unwrap();
        let rec = cached_space(b.as_ref(), &gpu, &b.default_input());
        let oracle = OracleModel::new(&rec);
        let ir = inst_reaction_for(b.as_ref());
        let thr = rec.best_time() * 1.1;
        let reps = opts.reps.min(300);
        for (label, radius) in
            [("global", None), ("local r=1", Some(1)), ("local r=2", Some(2))]
        {
            let steps: Vec<f64> = par_map_seeds(reps, &|seed| {
                let mut env = ReplayEnv::new(
                    rec.clone(),
                    gpu.clone(),
                    CostModel::default(),
                );
                let mut s = ProfileSearcher::new(&oracle, ir, seed);
                if let Some(r) = radius {
                    s = s.with_neighbourhood(r);
                }
                let trace = s.run(&mut env, &Budget::until(thr, usize::MAX));
                trace.tests_to_threshold(thr).unwrap_or(trace.len()) as f64
            });
            let avg = mean(&steps);
            rows.push(vec![
                name.to_string(),
                label.to_string(),
                format!("{avg:.1}"),
            ]);
            csv.push_str(&format!("{name},{label},{avg:.3}\n"));
        }
    }
    Report {
        id: "ablation_local",
        title: "Ablation: global vs neighbourhood-restricted scoring \
                (§3.9.1; RTX 2080, oracle PCs)"
            .into(),
        markdown: markdown(&["benchmark", "variant", "steps to 1.1×"], &rows),
        csvs: vec![("ablation_local_data".into(), csv)],
    }
}

/// Ablation: model family (oracle vs decision tree vs regression).
pub fn ablation_model_kind(opts: &ExperimentOpts) -> Report {
    let gpu = GpuSpec::gtx1070();
    let mut rows = Vec::new();
    let mut csv = String::from("benchmark,model,steps,improvement\n");
    for name in ["coulomb", "gemm"] {
        let b = benchmarks::by_name(name).unwrap();
        let rec = cached_space(b.as_ref(), &gpu, &b.default_input());
        let rand = random_avg(&rec, &gpu, opts);
        let ir = inst_reaction_for(b.as_ref());

        let oracle = OracleModel::new(&rec);
        let mut rng = Rng::new(opts.seed + 5);
        let ds = dataset_from_recorded(&rec, 1.0, &mut rng);
        let dtm = DecisionTreeModel::train(&ds, gpu.name, &mut rng);
        let dtm_pre = PrecomputedModel::over(&rec.space, &dtm);
        let reg = RegressionModel::train(&rec.space, &ds, gpu.name, &mut rng);
        let reg_pre = PrecomputedModel::over(&rec.space, &reg);

        let entries: Vec<(&str, &(dyn TpPcModel + Sync))> = vec![
            ("oracle", &oracle),
            ("decision_tree", &dtm_pre),
            ("regression", &reg_pre),
        ];
        for (label, model) in entries {
            let prof = profile_avg(&rec, &gpu, model, ir, opts);
            let imp = rand / prof.max(1.0);
            rows.push(vec![
                name.to_string(),
                label.to_string(),
                format!("{prof:.1}"),
                speedup(imp),
            ]);
            csv.push_str(&format!(
                "{name},{label},{prof:.3},{imp:.3}\n"
            ));
        }
    }
    Report {
        id: "ablation_model",
        title: "Ablation: TP→PC model family (GTX 1070, same-GPU model)"
            .into(),
        markdown: markdown(
            &["benchmark", "model", "steps to 1.1×", "improvement"],
            &rows,
        ),
        csvs: vec![("ablation_model_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Transfer matrix — the paper-style train-on-A / tune-on-B table
// ---------------------------------------------------------------------

/// Which searcher a transfer grid reads its values from, plus whether
/// a random baseline exists to normalize against. Grid values come
/// from the profile searcher when present; any other plan still
/// renders its first searcher's medians instead of an all-dash grid.
fn grid_value_searcher(plan: &TransferPlan) -> (&str, bool) {
    let has_random = plan.searchers.iter().any(|s| s == "random");
    let has_profile = plan.searchers.iter().any(|s| s == "profile");
    let value = if has_profile {
        "profile"
    } else if has_random {
        "random"
    } else {
        plan.searchers
            .first()
            .map(String::as_str)
            .unwrap_or("profile")
    };
    (value, has_random)
}

/// Format one grid cell: improvement over the random baseline on the
/// same target when a baseline exists, raw median steps otherwise.
fn grid_cell_value(
    a: &TransferAggregate,
    random: Option<&TransferAggregate>,
    normalize: bool,
    mark: &str,
) -> String {
    if normalize {
        let rand = random.map(|r| r.median_tests_to_wp).unwrap_or(0.0);
        let imp = rand / a.median_tests_to_wp.max(1.0);
        format!("{}{mark}", speedup(imp))
    } else {
        format!("{:.1}{mark}", a.median_tests_to_wp)
    }
}

/// Render a [`TransferReport`] as the paper's Table 6 shape: one
/// source-GPU × target-GPU grid per benchmark, rows = GPU tuned on,
/// columns = GPU the model was sampled on. On plans with input axes,
/// each GPU cell shows the benchmark's **default-input diagonal**
/// (source input == target input == default) when recorded, falling
/// back to the first recorded input pair — the input axis gets its own
/// grid from [`transfer_input_matrix`].
///
/// When the plan includes the `random` baseline, each cell shows the
/// improvement factor (median random steps ÷ median profile steps, on
/// the same target); otherwise the raw median profile steps. Cells
/// whose cross-generation restriction dropped counters are marked `†`
/// with a legend below the grid.
pub fn transfer_matrix(report: &TransferReport) -> String {
    // default input name per benchmark, for the preferred-cell rule
    let defaults: std::collections::BTreeMap<&str, String> = report
        .plan
        .benchmarks
        .iter()
        .filter_map(|b| {
            benchmarks::by_name(b)
                .map(|bn| (b.as_str(), bn.default_input().name))
        })
        .collect();
    // index the cells once, preferring the default/default input pair:
    // the full plan has hundreds of aggregate rows, so per-cell linear
    // scans would be O(cells × rows)
    let mut index: std::collections::BTreeMap<
        (&str, &str, &str, &str),
        &TransferAggregate,
    > = std::collections::BTreeMap::new();
    for a in report.aggregate_rows() {
        let key = (
            a.benchmark.as_str(),
            a.source_gpu.as_str(),
            a.target_gpu.as_str(),
            a.searcher.as_str(),
        );
        let is_default = defaults
            .get(a.benchmark.as_str())
            .map(|d| a.source_input == *d && a.target_input == *d)
            .unwrap_or(false);
        match index.entry(key) {
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(a);
            }
            std::collections::btree_map::Entry::Occupied(mut e) => {
                if is_default {
                    e.insert(a);
                }
            }
        }
    }
    let cell = |b: &str, s: &str, t: &str, searcher: &str| {
        index.get(&(b, s, t, searcher)).copied()
    };
    let (value_searcher, has_random) = grid_value_searcher(&report.plan);
    let normalize = has_random && value_searcher == "profile";

    let mut md = String::new();
    for b in &report.plan.benchmarks {
        let mut rows = Vec::new();
        let mut any_dropped = false;
        for t in &report.plan.target_gpus {
            let mut row = vec![t.clone()];
            for s in &report.plan.source_gpus {
                let Some(a) = cell(b, s, t, value_searcher) else {
                    row.push("-".into());
                    continue;
                };
                let mark = if a.dropped_counters.is_empty() {
                    ""
                } else {
                    any_dropped = true;
                    "†"
                };
                row.push(grid_cell_value(
                    a,
                    cell(b, s, t, "random"),
                    normalize,
                    mark,
                ));
            }
            rows.push(row);
        }
        let header: Vec<String> =
            std::iter::once("tuned on ↓ \\ model from →".to_string())
                .chain(report.plan.source_gpus.iter().cloned())
                .collect();
        let header_refs: Vec<&str> =
            header.iter().map(|s| s.as_str()).collect();
        md.push_str(&format!("\n## {b}\n\n"));
        md.push_str(&markdown(&header_refs, &rows));
        if any_dropped {
            md.push_str(
                "\n† cross-generation pair: counters unsupported by \
                 either side were dropped from scoring (see report \
                 `dropped_counters`).\n",
            );
        }
    }
    md
}

/// Render a [`TransferReport`]'s **input axis** as the paper's Table 7
/// shape: one source-input × target-input grid per (benchmark, GPU)
/// the plan covers on both GPU axes with more than one input pair —
/// rows = input tuned on, columns = input the model was sampled on.
/// Cell values follow the same improvement-over-random convention as
/// [`transfer_matrix`]. Returns an empty string when the plan has no
/// input dimension to show (single input pair everywhere), so callers
/// can print it unconditionally.
pub fn transfer_input_matrix(report: &TransferReport) -> String {
    let (value_searcher, has_random) = grid_value_searcher(&report.plan);
    let normalize = has_random && value_searcher == "profile";

    let mut md = String::new();
    for b in &report.plan.benchmarks {
        for g in &report.plan.target_gpus {
            if !report.plan.source_gpus.contains(g) {
                continue;
            }
            // the same-GPU diagonal isolates the input axis (no
            // hardware change, no counter-generation restriction)
            let diagonal: Vec<&TransferAggregate> = report
                .aggregate_rows()
                .iter()
                .filter(|a| {
                    a.benchmark == *b
                        && a.source_gpu == *g
                        && a.target_gpu == *g
                })
                .collect();
            // observed input axes, in sorted (aggregate) order
            let mut s_inputs: Vec<&str> = Vec::new();
            let mut t_inputs: Vec<&str> = Vec::new();
            for a in diagonal.iter().filter(|a| a.searcher == value_searcher)
            {
                if !s_inputs.contains(&a.source_input.as_str()) {
                    s_inputs.push(&a.source_input);
                }
                if !t_inputs.contains(&a.target_input.as_str()) {
                    t_inputs.push(&a.target_input);
                }
            }
            if s_inputs.len() * t_inputs.len() < 2 {
                continue; // no input dimension to show on this GPU
            }
            let cell = |si: &str, ti: &str, searcher: &str| {
                diagonal.iter().copied().find(|a| {
                    a.source_input == si
                        && a.target_input == ti
                        && a.searcher == searcher
                })
            };
            let mut rows = Vec::new();
            for ti in &t_inputs {
                let mut row = vec![ti.to_string()];
                for si in &s_inputs {
                    match cell(si, ti, value_searcher) {
                        Some(a) => row.push(grid_cell_value(
                            a,
                            cell(si, ti, "random"),
                            normalize,
                            "",
                        )),
                        None => row.push("-".into()),
                    }
                }
                rows.push(row);
            }
            let header: Vec<String> =
                std::iter::once("tuned input ↓ \\ model from →".to_string())
                    .chain(s_inputs.iter().map(|s| s.to_string()))
                    .collect();
            let header_refs: Vec<&str> =
                header.iter().map(|s| s.as_str()).collect();
            md.push_str(&format!("\n## {b} @ {g} (input × input)\n\n"));
            md.push_str(&markdown(&header_refs, &rows));
        }
    }
    md
}

/// Render a [`TransferReport`]'s per-source-endpoint model quality as
/// a grid: one table per benchmark, rows = modeled counters, columns =
/// source endpoints (`gpu:input`), cell = R² of the trained source
/// model on the recording's held-out remainder (the full recording at
/// `train_fraction = 1.0`). Two summary rows carry the median MAE and
/// median R² across counters. Empty when the report carries no quality
/// entries, so callers can print unconditionally.
pub fn model_quality_matrix(report: &TransferReport) -> String {
    let mut md = String::new();
    for b in &report.plan.benchmarks {
        let endpoints: Vec<&crate::harness::EndpointQuality> = report
            .model_quality
            .iter()
            .filter(|q| q.benchmark == *b)
            .collect();
        if endpoints.is_empty() {
            continue;
        }
        let header: Vec<String> = std::iter::once("counter".to_string())
            .chain(endpoints.iter().map(|q| {
                format!("{}:{}", q.source_gpu, q.source_input)
            }))
            .collect();
        let header_refs: Vec<&str> =
            header.iter().map(|s| s.as_str()).collect();
        let n_counters = endpoints[0].counters.len();
        let mut rows = Vec::new();
        for ci in 0..n_counters {
            let mut row = vec![endpoints[0].counters[ci].counter.to_string()];
            for q in &endpoints {
                row.push(format!("{:.3}", q.counters[ci].r2));
            }
            rows.push(row);
        }
        let mut mae_row = vec!["median MAE".to_string()];
        let mut r2_row = vec!["median R²".to_string()];
        for q in &endpoints {
            mae_row.push(format!("{:.3}", q.median_mae()));
            r2_row.push(format!("{:.3}", q.median_r2()));
        }
        rows.push(mae_row);
        rows.push(r2_row);
        // the fraction actually applied at these endpoints (1.0 for
        // the oracle source regardless of the plan knob)
        md.push_str(&format!(
            "\n## {b} — source-model quality (R² per counter, \
             train fraction {})\n\n",
            endpoints[0].train_fraction
        ));
        md.push_str(&markdown(&header_refs, &rows));
    }
    md
}

/// Render a [`SweepReport`] as a convergence-vs-fraction grid: one
/// table per benchmark, rows = training fractions, one column per
/// model source with the profile searcher's median tests-to-wp (and
/// its bootstrap CI), plus the model's median MAE at that fraction and
/// the fraction-independent random baseline. The shape the sample-size
/// literature asks for: does convergence survive smaller samples?
pub fn sweep_matrix(report: &SweepReport) -> String {
    let mut md = String::new();
    for b in &report.plan.benchmarks {
        let cells: Vec<_> = report
            .cells
            .iter()
            .filter(|c| c.benchmark == *b)
            .collect();
        if cells.is_empty() {
            continue;
        }
        let random = cells
            .iter()
            .find(|c| c.searcher == "random")
            .map(|c| c.median_tests_to_wp);
        let mut rows = Vec::new();
        for c in cells.iter().filter(|c| c.searcher == "profile") {
            rows.push(vec![
                c.model.to_string(),
                format!("{}", c.fraction),
                format!("{}", c.n_train),
                format!(
                    "{:.1} [{:.1}, {:.1}]",
                    c.median_tests_to_wp,
                    c.tests_to_wp_ci.0,
                    c.tests_to_wp_ci.1
                ),
                match random {
                    Some(r) => {
                        speedup(r / c.median_tests_to_wp.max(1.0))
                    }
                    None => "-".into(),
                },
                format!("{:.3}", c.median_mae),
                format!("{:.3}", c.median_r2),
            ]);
        }
        md.push_str(&format!(
            "\n## {b} — convergence vs training fraction \
             ({} → {}{})\n\n",
            report.plan.source_gpu,
            report.plan.target_gpu,
            match random {
                Some(r) => format!(", random baseline {r:.1} steps"),
                None => String::new(),
            }
        ));
        md.push_str(&markdown(
            &[
                "model",
                "fraction",
                "n_train",
                "median steps [95% CI]",
                "vs random",
                "median MAE",
                "median R²",
            ],
            &rows,
        ));
    }
    md
}

/// Render a [`PlanReport`]'s fault accounting as a markdown table: one
/// row per (benchmark, GPU[, input], searcher) cell with its failure
/// rate, mean transient retries and mean wasted tuning cost. Empty on
/// fault-free plans, so callers can print it unconditionally next to
/// the main matrix summary.
pub fn robustness_table(report: &PlanReport) -> String {
    if !report.plan.has_faults() {
        return String::new();
    }
    let with_input = report.plan.has_input_axis();
    let rows: Vec<Vec<String>> = report
        .aggregate_rows()
        .iter()
        .map(|a| {
            let mut row = vec![a.benchmark.clone(), a.gpu.clone()];
            if with_input {
                row.push(a.input.clone());
            }
            row.extend([
                a.searcher.clone(),
                format!("{:.1}%", a.failure_rate * 100.0),
                format!("{:.2}", a.mean_retries),
                format!("{:.2}", a.mean_wasted_cost_s),
            ]);
            row
        })
        .collect();
    let mut header = vec!["benchmark", "gpu"];
    if with_input {
        header.push("input");
    }
    header.extend([
        "searcher",
        "failure rate",
        "mean retries",
        "wasted cost (s)",
    ]);
    format!(
        "\n## Robustness under `{}` fault profile\n\n{}",
        report.plan.fault_profile.name(),
        markdown(&header, &rows)
    )
}

/// Rank a [`PlanReport`]'s searcher zoo: one row per searcher string,
/// pooled across every (benchmark, GPU, input) cell, ordered by mean
/// tests-to-well-performing (the paper's convergence KPI) ascending.
/// When the plan arms the stopping criteria, a final column summarizes
/// why the searcher's jobs stopped. Empty on single-strategy plans, so
/// callers can print it unconditionally next to the matrix summary.
pub fn searcher_ranking(report: &PlanReport) -> String {
    if report.plan.searchers.len() < 2 {
        return String::new();
    }
    struct Pool {
        runs: usize,
        wp_hits: usize,
        tests_to_wp: f64,
        best_ms: f64,
        cost_s: f64,
        stops: std::collections::BTreeMap<&'static str, usize>,
    }
    let mut pools: Vec<(String, Pool)> = Vec::new();
    for a in report.aggregate_rows() {
        let idx = match pools.iter().position(|(s, _)| *s == a.searcher) {
            Some(i) => i,
            None => {
                pools.push((
                    a.searcher.clone(),
                    Pool {
                        runs: 0,
                        wp_hits: 0,
                        tests_to_wp: 0.0,
                        best_ms: 0.0,
                        cost_s: 0.0,
                        stops: Default::default(),
                    },
                ));
                pools.len() - 1
            }
        };
        let pool = &mut pools[idx].1;
        pool.tests_to_wp += a.mean_tests_to_wp * a.runs as f64;
        pool.best_ms += a.mean_best_ms * a.runs as f64;
        pool.cost_s += a.mean_cost_s * a.runs as f64;
        pool.runs += a.runs;
        pool.wp_hits += a.wp_hits;
        for (reason, n) in &a.stop_counts {
            *pool.stops.entry(reason).or_insert(0) += *n;
        }
    }
    pools.sort_by(|a, b| {
        (a.1.tests_to_wp / a.1.runs.max(1) as f64)
            .total_cmp(&(b.1.tests_to_wp / b.1.runs.max(1) as f64))
    });
    let with_stops = report.plan.has_stopping();
    let rows: Vec<Vec<String>> = pools
        .iter()
        .enumerate()
        .map(|(rank, (name, p))| {
            let n = p.runs.max(1) as f64;
            let mut row = vec![
                format!("{}", rank + 1),
                name.clone(),
                format!("{:.1}", p.tests_to_wp / n),
                format!("{:.0}%", p.wp_hits as f64 / n * 100.0),
                format!("{:.4}", p.best_ms / n),
                format!("{:.1}", p.cost_s / n),
            ];
            if with_stops {
                row.push(
                    p.stops
                        .iter()
                        .map(|(r, c)| format!("{r}:{c}"))
                        .collect::<Vec<_>>()
                        .join(" "),
                );
            }
            row
        })
        .collect();
    let mut header = vec![
        "rank",
        "searcher",
        "mean tests→wp",
        "wp rate",
        "mean best (ms)",
        "mean cost (s)",
    ];
    if with_stops {
        header.push("stop reasons");
    }
    format!("\n## Searcher zoo ranking\n\n{}", markdown(&header, &rows))
}

/// Registry rows as a markdown table (`pcat registry query`): one row
/// per registry entry, in store (append) order.
pub fn registry_query_table(rows: &[RegistryRow]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.plan.clone(),
                r.plan_hash.clone(),
                r.scope.clone(),
                r.kpi.clone(),
                format!("{}", r.value),
                r.commit.clone(),
                r.created_at.clone(),
            ]
        })
        .collect();
    markdown(
        &["plan", "plan_hash", "scope", "kpi", "value", "commit", "created_at"],
        &body,
    )
}

/// Compare verdict as a markdown table (`pcat registry compare`): one
/// row per compared (plan, scope, kpi) key, naming the violated bound
/// on failures so the CI log says *which* KPI drifted and by how much.
pub fn registry_compare_table(findings: &[CompareFinding]) -> String {
    let fmt = |v: Option<f64>| match v {
        Some(x) => format!("{x}"),
        None => "-".to_string(),
    };
    let body: Vec<Vec<String>> = findings
        .iter()
        .map(|f| {
            vec![
                f.status.name().to_string(),
                f.plan.clone(),
                f.scope.clone(),
                f.kpi.clone(),
                fmt(f.baseline),
                fmt(f.current),
                f.bound.clone(),
            ]
        })
        .collect();
    markdown(
        &["status", "plan", "scope", "kpi", "baseline", "current", "bound"],
        &body,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::{run_transfer_plan, TransferPlan};

    fn tiny() -> ExperimentOpts {
        ExperimentOpts {
            reps: 12,
            time_reps: 5,
            seed: 1,
        }
    }

    #[test]
    fn table4_contains_all_cells() {
        let r = table4(&tiny());
        assert_eq!(r.markdown.matches("paper").count(), 20);
        assert!(r.csvs[0].1.lines().count() > 20);
    }

    #[test]
    fn table5_reports_improvements() {
        let r = table5(&ExperimentOpts {
            reps: 10,
            ..tiny()
        });
        assert!(r.markdown.contains("×"));
        // csv has 20 data rows
        assert_eq!(r.csvs[0].1.lines().count(), 21);
    }

    #[test]
    fn table7_square_matrix() {
        let r = table7(&tiny());
        assert_eq!(r.csvs[0].1.lines().count(), 17);
    }

    #[test]
    fn transfer_matrix_renders_grid_and_mismatch_legend() {
        let plan = TransferPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpus: vec!["gtx1070".into(), "rtx2080".into()],
            source_inputs: vec!["default".into()],
            target_gpus: vec!["gtx1070".into()],
            target_inputs: vec!["default".into()],
            model: crate::harness::ModelSource::Oracle,
            train_fraction: 1.0,
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 3,
            max_tests: 40,
            within_frac: 0.10,
            include_curves: false,
            fault_profile: crate::searcher::FaultProfile::None,
        };
        let report = run_transfer_plan(&plan, 4).unwrap();
        let md = transfer_matrix(&report);
        assert!(md.contains("## coulomb"));
        assert!(md.contains("gtx1070"));
        assert!(md.contains("×"), "improvement factors rendered");
        // the rtx2080→gtx1070 column crosses the generation boundary
        assert!(md.contains('†') && md.contains("dropped"));
        // no input dimension in this plan → no input grid at all
        assert!(transfer_input_matrix(&report).is_empty());
    }

    #[test]
    fn transfer_input_matrix_renders_the_table7_shape() {
        let plan = TransferPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpus: vec!["gtx1070".into()],
            source_inputs: vec!["default".into(), "alt".into()],
            target_gpus: vec!["gtx1070".into()],
            target_inputs: vec!["default".into(), "alt".into()],
            model: crate::harness::ModelSource::Oracle,
            train_fraction: 1.0,
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 3,
            max_tests: 40,
            within_frac: 0.10,
            include_curves: false,
            fault_profile: crate::searcher::FaultProfile::None,
        };
        let report = run_transfer_plan(&plan, 4).unwrap();
        let md = transfer_input_matrix(&report);
        assert!(md.contains("## coulomb @ gtx1070 (input × input)"));
        // both concrete input names appear as axis labels
        assert!(md.contains("grid256_atoms256"));
        assert!(md.contains("grid256_atoms64"));
        assert!(md.contains("×"), "improvement factors rendered");
        // and the GPU grid still renders its default-input diagonal
        assert!(transfer_matrix(&report).contains("## coulomb"));
    }

    #[test]
    fn model_quality_matrix_renders_per_counter_grid() {
        let plan = TransferPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpus: vec!["gtx1070".into(), "rtx2080".into()],
            source_inputs: vec!["default".into()],
            target_gpus: vec!["gtx1070".into()],
            target_inputs: vec!["default".into()],
            model: crate::harness::ModelSource::Tree,
            train_fraction: 0.5,
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 3,
            max_tests: 40,
            within_frac: 0.10,
            include_curves: false,
            fault_profile: crate::searcher::FaultProfile::None,
        };
        let report = run_transfer_plan(&plan, 4).unwrap();
        let md = model_quality_matrix(&report);
        assert!(md.contains("source-model quality"));
        assert!(md.contains("train fraction 0.5"));
        // both endpoints as columns, counters as rows, summary rows
        assert!(md.contains("gtx1070:grid256_atoms256"));
        assert!(md.contains("rtx2080:grid256_atoms256"));
        assert!(md.contains("INST_F32"));
        assert!(md.contains("median MAE"));
        assert!(md.contains("median R²"));
    }

    #[test]
    fn robustness_table_renders_only_under_faults() {
        use crate::harness::{run_plan, ExperimentPlan};
        use crate::searcher::FaultProfile;
        let mut plan = ExperimentPlan::smoke(0);
        plan.benchmarks = vec!["coulomb".into()];
        plan.searchers = vec!["random".into()];
        plan.seeds = 2;
        let clean = run_plan(&plan, 2).unwrap();
        assert!(robustness_table(&clean).is_empty());
        plan.fault_profile = FaultProfile::Hostile;
        let faulty = run_plan(&plan, 2).unwrap();
        let md = robustness_table(&faulty);
        assert!(md.contains("hostile"));
        assert!(md.contains("failure rate"));
        assert!(md.contains("coulomb"));
    }

    #[test]
    fn sweep_matrix_renders_fraction_rows() {
        use crate::harness::{run_sweep_plan, SweepPlan};
        let plan = SweepPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpu: "gtx1070".into(),
            target_gpu: "gtx1070".into(),
            fractions: vec![0.5, 1.0],
            models: vec![
                crate::harness::ModelSource::Tree,
                crate::harness::ModelSource::Oracle,
            ],
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 3,
            max_tests: 40,
            within_frac: 0.10,
        };
        let report = run_sweep_plan(&plan, 4).unwrap();
        let md = sweep_matrix(&report);
        assert!(md.contains("## coulomb — convergence vs training fraction"));
        assert!(md.contains("random baseline"));
        // one profile row per combo: tree×2 fractions + oracle ref
        assert_eq!(md.matches("| tree |").count(), 2);
        assert_eq!(md.matches("| oracle |").count(), 1);
        assert!(md.contains("×"), "vs-random factors rendered");
    }
}
