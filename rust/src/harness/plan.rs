//! Parallel, cache-backed experiment runner.
//!
//! [`ExperimentPlan`] describes the paper's evaluation as a job matrix
//! (benchmark × GPU × input × searcher × seed, §4), expanded into
//! independent [`JobSpec`]s and executed across the shared worker
//! pool. Every job replays a [`RecordedSpace`] obtained from the
//! process-wide cache ([`crate::benchmarks::cached_space`]), so each
//! space is enumerated and simulated exactly once per process instead
//! of once per run.
//!
//! **Determinism contract:** a job's result is a pure function of the
//! plan and its coordinates — per-job RNG streams are derived with
//! [`crate::util::rng::stream_seed`] from `(base seed, benchmark, gpu,
//! input, searcher, lane)`, never from scheduling; the default input
//! contributes **no** stream tag, so historical default-input plans
//! keep their exact streams (and, since input fields serialize only on
//! plans with a real input axis, their exact report bytes). Serial
//! (`jobs = 1`) and parallel (`jobs = N`) executions therefore produce
//! byte-identical JSON reports, which is exactly what the CI smoke
//! gate asserts.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::benchmarks::{
    self, cached_recorder, cached_space, OnDemandRecorder, RecordingMode,
};
use crate::coordinator::Tuner;
use crate::harness::registry;
use crate::gpusim::GpuSpec;
use crate::model::PredictionMatrix;
use crate::searcher::{
    Budget, CellCtx, CostModel, FaultModel, FaultProfile, FaultStats,
    FaultyEnv, ModelCtx, OnDemandEnv, ReplayEnv, SearcherSpec, SpecError,
};
use crate::tuning::RecordedSpace;
use crate::util::json::{obj, Value};
use crate::util::pool;
use crate::util::rng::stream_seed;
use crate::util::stats::mean;

/// Canonical searcher names every plan runner accepts — the historical
/// five plus the zoo (arxiv 2210.01465). Any [`SearcherSpec`] string
/// (`"ga:pop=20"`, `"profile+de"`) is also a valid axis entry; this
/// list is what `full()` fans out over and what error messages cite.
pub const PLAN_SEARCHERS: [&str; 8] = [
    "random",
    "profile",
    "basin_hopping",
    "annealing",
    "starchart",
    "ga",
    "de",
    "dual_annealing",
];

/// Typed validation error shared by every plan flavour
/// ([`ExperimentPlan`], [`crate::harness::TransferPlan`]): callers can
/// match on the failure class instead of parsing message strings, and
/// the `NoRecording` variant stops a *training-based* plan from
/// silently scheduling a benchmark whose space is never exhaustively
/// recorded (sampling a recording that does not exist trains nothing).
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    /// A plan axis (benchmarks/GPUs/searchers/seeds) is empty.
    EmptyAxis(&'static str),
    UnknownBenchmark(String),
    UnknownGpu(String),
    UnknownSearcher(String),
    /// A training fraction outside `(0, 1]` (or non-finite): sampling
    /// zero rows of a recording trains nothing, and more than the
    /// whole recording does not exist. `axis` names the offending plan
    /// field (`train_fraction` for transfer plans, `fractions` for the
    /// sweep axis).
    InvalidFraction { axis: &'static str, value: f64 },
    /// Known benchmark, but its space is tuned lazily
    /// ([`crate::benchmarks::Benchmark::recording_mode`] is
    /// `OnDemand`), so no exhaustive recording exists for a
    /// training-based plan (transfer/sweep) to sample from. Replay
    /// plans and the serve layer accept these benchmarks — they run
    /// through the on-demand recorder instead.
    NoRecording(String),
    /// `(benchmark, selector)`: an input-axis selector that some
    /// benchmark of the plan cannot resolve — the cross product would
    /// need a source or target recording that can never exist, so the
    /// plan is rejected up front instead of panicking mid-fan-out.
    UnknownInput(String, String),
    /// A probability-like knob outside `[0, 1]` (or non-finite) —
    /// e.g. the serve load generator's `miss_ratio`, where both
    /// endpoints are meaningful (0 = fully pre-warmed, 1 = fully
    /// cold), unlike the strictly positive training fractions.
    InvalidRatio { axis: &'static str, value: f64 },
    /// A knob that only needs to be finite and non-negative — e.g. the
    /// load generator's Zipf exponent, where `0` (uniform popularity)
    /// is meaningful but there is no upper bound to enforce.
    InvalidKnob { axis: &'static str, value: f64 },
    /// A searcher axis entry that names a known strategy but fails spec
    /// validation (unknown parameter, out-of-domain value, malformed
    /// syntax, bad composition) — `error` carries the typed
    /// [`SpecError`]'s rendering.
    InvalidSearcher { spec: String, error: String },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::EmptyAxis(axis) => {
                write!(f, "empty plan axis {axis:?}")
            }
            PlanError::UnknownBenchmark(b) => {
                write!(f, "unknown benchmark {b:?} in plan")
            }
            PlanError::UnknownGpu(g) => write!(f, "unknown GPU {g:?} in plan"),
            PlanError::UnknownSearcher(s) => write!(
                f,
                "unknown searcher {s:?} in plan; known: {}",
                PLAN_SEARCHERS.join(", ")
            ),
            PlanError::NoRecording(b) => write!(
                f,
                "benchmark {b:?} is tuned on demand: its space is never \
                 exhaustively recorded (§4.6), so a training-based plan \
                 has no recording to sample from — schedule it into a \
                 search plan or the serve layer instead"
            ),
            PlanError::UnknownInput(b, i) => write!(
                f,
                "benchmark {b:?} has no input {i:?} in plan; selectors \
                 are \"default\", \"alt\", or an input name listed by \
                 `pcat list`"
            ),
            PlanError::InvalidFraction { axis, value } => write!(
                f,
                "invalid training fraction {value} in plan axis \
                 {axis:?}: must be within (0, 1] (1.0 = the full \
                 recording, the pre-sampling behaviour)"
            ),
            PlanError::InvalidRatio { axis, value } => write!(
                f,
                "invalid ratio {value} in plan axis {axis:?}: must be \
                 within [0, 1]"
            ),
            PlanError::InvalidKnob { axis, value } => write!(
                f,
                "invalid value {value} for plan knob {axis:?}: must be \
                 finite and non-negative"
            ),
            PlanError::InvalidSearcher { spec, error } => {
                write!(f, "invalid searcher spec {spec:?} in plan: {error}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// Shared axis validation — every benchmark name must exist. Both
/// recording modes are tunable here: eager benchmarks replay their
/// cached recording, on-demand benchmarks run through the lazy
/// recorder, so search plans and the serve layer accept the whole
/// registry.
pub(crate) fn validate_benchmarks(
    axis: &'static str,
    names: &[String],
) -> Result<(), PlanError> {
    if names.is_empty() {
        return Err(PlanError::EmptyAxis(axis));
    }
    for b in names {
        if benchmarks::by_name(b).is_none() {
            return Err(PlanError::UnknownBenchmark(b.clone()));
        }
    }
    Ok(())
}

/// Axis validation for training-based plans (transfer/sweep): the plan
/// samples rows of an exhaustive recording to train a model, so every
/// benchmark must additionally be recorded eagerly
/// ([`RecordingMode::Eager`]) — an on-demand space has no recording to
/// sample from.
pub(crate) fn validate_trainable_benchmarks(
    axis: &'static str,
    names: &[String],
) -> Result<(), PlanError> {
    validate_benchmarks(axis, names)?;
    for b in names {
        let bench = benchmarks::by_name(b).expect("validated above");
        if bench.recording_mode() != RecordingMode::Eager {
            return Err(PlanError::NoRecording(b.clone()));
        }
    }
    Ok(())
}

/// Shared axis validation: every GPU name must resolve to a spec.
pub(crate) fn validate_gpus(
    axis: &'static str,
    names: &[String],
) -> Result<(), PlanError> {
    if names.is_empty() {
        return Err(PlanError::EmptyAxis(axis));
    }
    for g in names {
        if GpuSpec::by_name(g).is_none() {
            return Err(PlanError::UnknownGpu(g.clone()));
        }
    }
    Ok(())
}

/// Shared axis validation for input-selector axes: every selector must
/// resolve ([`crate::benchmarks::resolve_input`]) for **every**
/// benchmark of the plan — a selector one benchmark lacks would need a
/// recording that can never exist. Unknown benchmark names are skipped
/// here; [`validate_benchmarks`] owns reporting those.
pub(crate) fn validate_inputs(
    axis: &'static str,
    bench_names: &[String],
    selectors: &[String],
) -> Result<(), PlanError> {
    if selectors.is_empty() {
        return Err(PlanError::EmptyAxis(axis));
    }
    for b in bench_names {
        let Some(bench) = benchmarks::by_name(b) else {
            continue;
        };
        for sel in selectors {
            if benchmarks::resolve_input(bench.as_ref(), sel).is_none() {
                return Err(PlanError::UnknownInput(b.clone(), sel.clone()));
            }
        }
    }
    Ok(())
}

/// Shared fraction validation: training fractions must be finite and
/// within `(0, 1]` ([`PlanError::InvalidFraction`] otherwise). Used by
/// [`crate::harness::TransferPlan`] (`train_fraction`) and
/// [`crate::harness::SweepPlan`] (the `fractions` axis).
pub(crate) fn validate_fraction(
    axis: &'static str,
    value: f64,
) -> Result<(), PlanError> {
    if value.is_finite() && value > 0.0 && value <= 1.0 {
        Ok(())
    } else {
        Err(PlanError::InvalidFraction { axis, value })
    }
}

/// Shared ratio validation: probability-like knobs must be finite and
/// within `[0, 1]` ([`PlanError::InvalidRatio`] otherwise). Used by
/// [`crate::harness::LoadPlan`] (`miss_ratio`).
pub(crate) fn validate_ratio(
    axis: &'static str,
    value: f64,
) -> Result<(), PlanError> {
    if value.is_finite() && (0.0..=1.0).contains(&value) {
        Ok(())
    } else {
        Err(PlanError::InvalidRatio { axis, value })
    }
}

/// Shared knob validation: scale-like knobs (the load generator's Zipf
/// exponent) must be finite and non-negative
/// ([`PlanError::InvalidKnob`] otherwise).
pub(crate) fn validate_knob(
    axis: &'static str,
    value: f64,
) -> Result<(), PlanError> {
    if value.is_finite() && value >= 0.0 {
        Ok(())
    } else {
        Err(PlanError::InvalidKnob { axis, value })
    }
}

/// Resolve an input-selector axis for one benchmark into
/// `(concrete input name, is the benchmark's default)` pairs —
/// order-preserving, deduped by concrete name so overlapping selectors
/// (`default` plus its concrete spelling) never expand a cell twice.
/// Unresolvable selectors pass through verbatim so validation still
/// names the offender. Shared by [`ExperimentPlan::jobs`] and
/// [`crate::harness::TransferPlan::jobs`], so the two planners cannot
/// diverge on selector semantics.
pub(crate) fn resolve_input_axis(
    bench_name: &str,
    selectors: &[String],
) -> Vec<(String, bool)> {
    let bench = benchmarks::by_name(bench_name);
    let resolve = |sel: &str| -> (String, bool) {
        match bench
            .as_ref()
            .and_then(|bn| benchmarks::resolve_input(bn.as_ref(), sel))
        {
            Some(input) => {
                let is_default = bench
                    .as_ref()
                    .map(|bn| bn.default_input().name == input.name)
                    .unwrap_or(false);
                (input.name, is_default)
            }
            // unvalidated plan: pass the selector through so
            // validation still names the offender
            None => (
                sel.to_string(),
                sel == benchmarks::DEFAULT_INPUT_SELECTOR,
            ),
        }
    };
    let mut axis: Vec<(String, bool)> = Vec::new();
    for sel in selectors {
        let entry = resolve(sel);
        if !axis.iter().any(|(n, _)| *n == entry.0) {
            axis.push(entry);
        }
    }
    axis
}

/// Shared axis validation: every searcher entry must parse as a
/// [`SearcherSpec`] — the same parser that later builds the searcher,
/// so validation and dispatch cannot drift. Unknown strategy names keep
/// their historical typed error; known names with bad parameters get
/// the spec layer's diagnosis verbatim.
pub(crate) fn validate_searchers(
    axis: &'static str,
    names: &[String],
) -> Result<(), PlanError> {
    if names.is_empty() {
        return Err(PlanError::EmptyAxis(axis));
    }
    for s in names {
        match SearcherSpec::parse(s) {
            Ok(_) => {}
            Err(SpecError::Unknown(name)) => {
                return Err(PlanError::UnknownSearcher(name));
            }
            Err(e) => {
                return Err(PlanError::InvalidSearcher {
                    spec: s.clone(),
                    error: e.to_string(),
                });
            }
        }
    }
    Ok(())
}

/// A benchmark × GPU × input × searcher × seed job matrix.
#[derive(Debug, Clone)]
pub struct ExperimentPlan {
    pub benchmarks: Vec<String>,
    pub gpus: Vec<String>,
    /// Input selectors (`"default"`, `"alt"`, or concrete names from
    /// [`crate::benchmarks::Benchmark::inputs`]), resolved per
    /// benchmark at expansion. The historical plans pinned the default
    /// input; a `["default"]` axis reproduces them **bit-for-bit** —
    /// same RNG streams (the default input adds no stream tag, exactly
    /// like [`crate::harness::TransferPlan`]'s convention) and the
    /// same report bytes (input fields are only serialized when the
    /// plan actually has an input dimension).
    pub inputs: Vec<String>,
    pub searchers: Vec<String>,
    /// Seeded repetitions per (benchmark, gpu, searcher) cell.
    pub seeds: usize,
    /// Base seed every per-job RNG stream is derived from.
    pub base_seed: u64,
    /// Per-job cap on empirical tests (each job also stops early once it
    /// finds a configuration within 1.1× of the exhaustive best).
    pub max_tests: usize,
    /// Embed the full per-job trace in the JSON report.
    pub include_traces: bool,
    /// Fault/noise injection profile
    /// ([`crate::searcher::FaultProfile`]). `None` (the default) keeps
    /// the replay environment untouched — same streams, same report
    /// bytes as before the fault layer existed; fault fields serialize
    /// only when a profile is active, mirroring the input-axis
    /// convention.
    pub fault_profile: FaultProfile,
    /// Principled stopping (arxiv 2203.13577): end a job after this
    /// many consecutive tests without improvement. `None` (the
    /// default) keeps the historical budgets — and, like the fault and
    /// input conventions, keeps stopping fields out of the report
    /// bytes entirely.
    pub patience: Option<usize>,
    /// Relative-improvement epsilon sharpening the patience rule: a
    /// test only resets the counter when it beats the incumbent best
    /// by more than this fraction. Inert unless `patience` is set.
    pub epsilon: f64,
}

impl ExperimentPlan {
    /// The paper's evaluation matrix (§4), extended with the zoo: 5
    /// benchmarks × 4 GPUs × (8 base searchers + 1 augmented lane) ×
    /// `seeds` repetitions — the nightly full matrix ranks every
    /// strategy the registry knows.
    pub fn full(seeds: usize, base_seed: u64) -> Self {
        let mut searchers = PLAN_SEARCHERS.map(String::from).to_vec();
        searchers.push("profile+ga".into());
        ExperimentPlan {
            benchmarks: ["coulomb", "transpose", "gemm", "nbody", "convolution"]
                .map(String::from)
                .to_vec(),
            gpus: ["gtx680", "gtx750", "gtx1070", "rtx2080"]
                .map(String::from)
                .to_vec(),
            inputs: vec!["default".into()],
            searchers,
            seeds,
            base_seed,
            max_tests: 1000,
            include_traces: false,
            fault_profile: FaultProfile::None,
            patience: None,
            epsilon: 0.0,
        }
    }

    /// The CI smoke matrix: 2 benchmarks × 1 GPU × the 9-strategy zoo
    /// (8 base searchers + one `profile+` composition) × 3 seeds —
    /// small enough to gate a PR, rich enough to exercise the cache,
    /// every searcher family and the aggregation path. `random` and
    /// `profile` stay first so the historical lanes keep their
    /// positions (and their RNG streams — searcher strings are the
    /// stream tags, independent of axis order).
    pub fn smoke(base_seed: u64) -> Self {
        ExperimentPlan {
            benchmarks: vec!["coulomb".into(), "transpose".into()],
            gpus: vec!["gtx1070".into()],
            inputs: vec!["default".into()],
            searchers: vec![
                "random".into(),
                "profile".into(),
                "basin_hopping".into(),
                "starchart".into(),
                "annealing".into(),
                "ga".into(),
                "de".into(),
                "dual_annealing".into(),
                "profile+ga".into(),
            ],
            seeds: 3,
            base_seed,
            max_tests: 80,
            include_traces: true,
            fault_profile: FaultProfile::None,
            patience: None,
            epsilon: 0.0,
        }
    }

    /// Does this plan have an input dimension beyond the historical
    /// pinned default? Serialization keys off this so `["default"]`
    /// plans keep producing the exact pre-input-axis report bytes.
    pub fn has_input_axis(&self) -> bool {
        self.inputs.len() != 1
            || self.inputs[0] != benchmarks::DEFAULT_INPUT_SELECTOR
    }

    /// Does this plan inject faults? Fault fields (plan echo, per-job
    /// and per-cell accounting) serialize only when it does, so
    /// `fault_profile: none` plans keep their exact pre-fault-layer
    /// report bytes and plan hashes.
    pub fn has_faults(&self) -> bool {
        self.fault_profile.is_active()
    }

    /// Does this plan arm the principled stopping criteria? Stopping
    /// fields (plan echo, per-job stop reasons, per-cell stop counts)
    /// serialize only when it does — same bit-for-bit convention as
    /// the input axis and the fault layer.
    pub fn has_stopping(&self) -> bool {
        self.patience.is_some()
    }

    /// Expand into jobs, in deterministic plan order. Input selectors
    /// resolve to concrete per-benchmark names here (shared
    /// [`resolve_input_axis`] helper with the transfer planner), so
    /// report keys and RNG tags always carry canonical names and
    /// overlapping selectors collapse to one axis entry.
    pub fn jobs(&self) -> Vec<JobSpec> {
        let mut out = Vec::new();
        for b in &self.benchmarks {
            let inputs = resolve_input_axis(b, &self.inputs);
            for g in &self.gpus {
                for (input, input_default) in &inputs {
                    for s in &self.searchers {
                        for lane in 0..self.seeds {
                            out.push(JobSpec {
                                benchmark: b.clone(),
                                gpu: g.clone(),
                                input: input.clone(),
                                input_default: *input_default,
                                searcher: s.clone(),
                                lane,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Resolve every name up front so job closures cannot fail later.
    /// The checks themselves are the hoisted helpers shared with
    /// [`crate::harness::TransferPlan`], so no plan flavour can skip
    /// the recordability gate.
    pub fn validate(&self) -> Result<(), PlanError> {
        validate_benchmarks("benchmarks", &self.benchmarks)?;
        validate_gpus("gpus", &self.gpus)?;
        validate_inputs("inputs", &self.benchmarks, &self.inputs)?;
        validate_searchers("searchers", &self.searchers)?;
        if self.seeds == 0 {
            return Err(PlanError::EmptyAxis("seeds"));
        }
        Ok(())
    }

    fn to_json(&self) -> Value {
        let mut fields = vec![
            ("benchmarks", Value::from(self.benchmarks.clone())),
            ("gpus", Value::from(self.gpus.clone())),
            ("searchers", Value::from(self.searchers.clone())),
            ("seeds", Value::from(self.seeds)),
            // as a string: JSON numbers are f64 and would corrupt
            // seeds above 2^53, breaking re-runs from the report
            ("base_seed", Value::from(self.base_seed.to_string())),
            ("max_tests", Value::from(self.max_tests)),
        ];
        if self.has_input_axis() {
            // only when the plan genuinely has an input dimension:
            // default-input plans must keep their pre-axis bytes
            fields.push(("inputs", Value::from(self.inputs.clone())));
        }
        if self.has_faults() {
            // same convention as the input axis: only active fault
            // profiles appear in the plan echo (and thus the plan hash)
            fields.push((
                "fault_profile",
                Value::from(self.fault_profile.name()),
            ));
        }
        if self.has_stopping() {
            fields.push((
                "patience",
                Value::from(self.patience.expect("has_stopping")),
            ));
            fields.push(("epsilon", Value::from(self.epsilon)));
        }
        obj(fields)
    }
}

/// One independent job of the matrix. `input` carries a *resolved*
/// concrete input name, not a selector.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub benchmark: String,
    pub gpu: String,
    pub input: String,
    /// Is `input` the benchmark's default? (Decides the RNG tag shape
    /// — see [`rng_seed`](JobSpec::rng_seed).)
    pub input_default: bool,
    pub searcher: String,
    /// Repetition index within the cell.
    pub lane: usize,
}

impl JobSpec {
    /// The job's private RNG stream seed — a pure function of the plan
    /// seed and the job coordinates. The default input adds **no**
    /// stream tag (same convention as
    /// [`crate::harness::TransferJobSpec::rng_seed`]): default-input
    /// jobs keep the exact streams of the pre-input-axis plans, and
    /// same-(GPU, default input) transfer diagonals keep reproducing
    /// them. Non-default inputs get their own streams.
    pub fn rng_seed(&self, base_seed: u64) -> u64 {
        if self.input_default {
            stream_seed(
                base_seed,
                &[&self.benchmark, &self.gpu, &self.searcher],
                self.lane as u64,
            )
        } else {
            stream_seed(
                base_seed,
                &[&self.benchmark, &self.gpu, &self.input, &self.searcher],
                self.lane as u64,
            )
        }
    }

    /// Seed of the *cell* fault stream: keyed by the hardware cell
    /// (benchmark, gpu, input) only — never searcher or lane — so a
    /// persistently broken config is broken for every searcher and
    /// every repetition on that cell, the way a real compile failure
    /// would be. Default inputs add no tag (the [`rng_seed`] shape).
    ///
    /// [`rng_seed`]: JobSpec::rng_seed
    pub fn fault_cell_seed(&self, base_seed: u64) -> u64 {
        if self.input_default {
            stream_seed(
                base_seed,
                &[&self.benchmark, &self.gpu, "fault-cell"],
                0,
            )
        } else {
            stream_seed(
                base_seed,
                &[&self.benchmark, &self.gpu, &self.input, "fault-cell"],
                0,
            )
        }
    }

    /// Seed of the per-job fault stream (transient flips, noise,
    /// dropout): the job's own coordinates plus a `"faults"` tag, so it
    /// is decorrelated from the searcher stream and scheduling-free.
    pub fn fault_job_seed(&self, base_seed: u64) -> u64 {
        if self.input_default {
            stream_seed(
                base_seed,
                &[&self.benchmark, &self.gpu, &self.searcher, "faults"],
                self.lane as u64,
            )
        } else {
            stream_seed(
                base_seed,
                &[
                    &self.benchmark,
                    &self.gpu,
                    &self.input,
                    &self.searcher,
                    "faults",
                ],
                self.lane as u64,
            )
        }
    }
}

/// Outcome of one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub spec: JobSpec,
    pub best_ms: f64,
    /// Empirical tests performed.
    pub tests: usize,
    pub profiled_tests: usize,
    /// 1-based test count at which a well-performing (≤1.1× best)
    /// configuration was found, if any.
    pub tests_to_wp: Option<usize>,
    /// Simulated tuning cost, seconds.
    pub cost_s: f64,
    /// (config index, runtime ms, profiled) per step; empty unless the
    /// plan asked for traces (a full 10k-job matrix would otherwise
    /// retain hundreds of MB it never serializes).
    pub trace: Vec<(usize, f64, bool)>,
    /// Fault accounting for this job; `None` on fault-free plans.
    pub faults: Option<FaultStats>,
    /// Which budget criterion ended the search
    /// ([`crate::searcher::StopReason::name`]); `None` unless the plan
    /// arms the stopping criteria.
    pub stop: Option<&'static str>,
}

/// Shared per-(benchmark, gpu) context, built once before the fan-out.
struct PlanCell {
    data: CellData,
    gpu: GpuSpec,
    inst_reaction: f64,
}

/// How a cell's space is evaluated — matches the benchmark's
/// [`RecordingMode`].
enum CellData {
    /// The historical replay path: exhaustive recording plus the dense
    /// oracle prediction matrix, shared by every seed-repetition of the
    /// cell — the profile jobs score against this instead of rebuilding
    /// per-run prediction tables (§Perf).
    Eager {
        rec: Arc<RecordedSpace>,
        matrix: Arc<PredictionMatrix>,
    },
    /// The large-space path: configurations are simulated the first
    /// time any job visits them and memoized process-wide. Nothing
    /// space-sized is materialized, and the true best runtime is
    /// unknown — lazy jobs run to their test budget and report
    /// convergence post-hoc.
    Lazy { recorder: Arc<OnDemandRecorder> },
}

/// The expert reaction strength for a benchmark's boundedness class —
/// the one knob the profile arm needs besides the matrix. Shared by
/// the plan pre-pass and the serve engine's cache-miss search so the
/// two cannot drift.
pub(crate) fn inst_reaction_for(bench: &dyn benchmarks::Benchmark) -> f64 {
    if bench.instruction_bound() {
        crate::expert::INST_BOUND_REACTION
    } else {
        crate::expert::DEFAULT_INST_REACTION
    }
}

/// Does this searcher spec consume the cell's model — i.e. can its
/// results differ across the *source* axis of a transfer plan? Asked
/// of the spec layer, so the transfer fan-out's source-axis
/// deduplication is mechanically tied to how searchers are actually
/// built: any spec the parser marks model-reading (`profile`, every
/// `profile+<base>` composition) fans out per source; everything else
/// dedups. Unparseable names land on the model-free side — validation
/// rejects them before any fan-out cares.
pub(crate) fn reads_model(name: &str) -> bool {
    SearcherSpec::parse(name)
        .map(|s| s.reads_model())
        .unwrap_or(false)
}

/// The cell's searcher-construction context: its model state (dense
/// matrix on eager cells, shared recorder on lazy ones) plus the
/// benchmark's reaction strength. The seed is a placeholder — the
/// [`Tuner`] overrides it with the job's stream seed.
fn cell_searcher_ctx(data: &CellData, inst_reaction: f64) -> CellCtx {
    let model = match data {
        CellData::Eager { matrix, .. } => ModelCtx::Eager {
            matrix: Arc::clone(matrix),
        },
        CellData::Lazy { recorder } => ModelCtx::Lazy {
            recorder: Arc::clone(recorder),
        },
    };
    CellCtx::new(model, inst_reaction, 0)
}

/// Run one job through the [`Tuner`] facade. The searcher is built by
/// [`SearcherSpec::build`] — the same dispatch point the transfer
/// runner, the serve engine and the CLI use, so a spec that validates
/// always constructs.
fn run_job(spec: &JobSpec, plan: &ExperimentPlan, ctx: &PlanCell) -> JobResult {
    let sspec = SearcherSpec::parse(&spec.searcher).expect("plan validated");
    let sctx = cell_searcher_ctx(&ctx.data, ctx.inst_reaction);
    // Eager cells stop early at 1.1× the known best (the paper's
    // well-performing threshold); lazy cells have no known best, so
    // they run to the test budget and convergence is judged post-hoc.
    let thr = match &ctx.data {
        CellData::Eager { rec, .. } => Some(rec.best_time() * 1.1),
        CellData::Lazy { .. } => None,
    };
    let mut budget = match thr {
        Some(thr) => Budget::until(thr, plan.max_tests),
        None => Budget::tests(plan.max_tests),
    };
    if let Some(k) = plan.patience {
        budget = budget.with_patience(k).with_epsilon(plan.epsilon);
    }
    let seed = spec.rng_seed(plan.base_seed);

    // fault-free plans take the exact historical path (no wrapper, no
    // stats); active profiles wrap the cell's env in a FaultyEnv whose
    // streams derive from the plan coordinates, never from scheduling
    let (result, faults) = if plan.has_faults() {
        let stats = Arc::new(Mutex::new(FaultStats::default()));
        let model = FaultModel::for_profile(plan.fault_profile);
        let cell_seed = spec.fault_cell_seed(plan.base_seed);
        let job_seed = spec.fault_job_seed(plan.base_seed);
        let env: Box<dyn crate::searcher::EvalEnv> = match &ctx.data {
            CellData::Eager { rec, .. } => Box::new(FaultyEnv::new(
                ReplayEnv::new(
                    Arc::clone(rec),
                    ctx.gpu.clone(),
                    CostModel::default(),
                ),
                model,
                cell_seed,
                job_seed,
                Arc::clone(&stats),
            )),
            CellData::Lazy { recorder } => Box::new(FaultyEnv::new(
                OnDemandEnv::new(Arc::clone(recorder), CostModel::default()),
                model,
                cell_seed,
                job_seed,
                Arc::clone(&stats),
            )),
        };
        let result = Tuner::over(env)
            .with_budget(budget.clone())
            .with_seed(seed)
            .run(&sspec, &sctx);
        let faults = crate::util::sync::lock_unpoisoned(&stats).clone();
        (result, Some(faults))
    } else {
        let tuner = match &ctx.data {
            CellData::Eager { rec, .. } => Tuner::replay(
                Arc::clone(rec),
                ctx.gpu.clone(),
                CostModel::default(),
            ),
            CellData::Lazy { recorder } => Tuner::over(Box::new(
                OnDemandEnv::new(Arc::clone(recorder), CostModel::default()),
            )),
        };
        let result = tuner
            .with_budget(budget.clone())
            .with_seed(seed)
            .run(&sspec, &sctx);
        (result, None)
    };

    JobResult {
        spec: spec.clone(),
        best_ms: result.best_ms,
        tests: result.tests,
        profiled_tests: result.profiled_tests,
        tests_to_wp: thr.and_then(|t| result.trace.tests_to_threshold(t)),
        cost_s: result.cost_s,
        // stop accounting only when the plan arms the criteria — the
        // reason is recomputed post-hoc from the budget and the trace
        stop: if plan.has_stopping() {
            Some(budget.stop_reason(&result.trace, result.cost_s).name())
        } else {
            None
        },
        trace: if plan.include_traces {
            result
                .trace
                .steps
                .iter()
                .map(|s| (s.idx, s.runtime_ms, s.profiled))
                .collect()
        } else {
            Vec::new()
        },
        faults,
    }
}

/// A completed plan: per-job results in plan order.
pub struct PlanReport {
    pub plan: ExperimentPlan,
    pub results: Vec<JobResult>,
}

/// Aggregated statistics for one (benchmark, gpu, input, searcher)
/// cell.
#[derive(Debug, Clone)]
pub struct AggregateRow {
    pub benchmark: String,
    pub gpu: String,
    /// Resolved input name (the default input on historical plans).
    pub input: String,
    pub searcher: String,
    pub runs: usize,
    pub wp_hits: usize,
    pub mean_tests_to_wp: f64,
    pub mean_best_ms: f64,
    pub mean_cost_s: f64,
    /// Failed runs / total tests over the cell, in `[0, 1]`; zero on
    /// fault-free plans (serialized only when faults are active).
    pub failure_rate: f64,
    /// Mean transient retries per job.
    pub mean_retries: f64,
    /// Mean tuning cost wasted on failed attempts per job, seconds.
    pub mean_wasted_cost_s: f64,
    /// How many of the cell's runs ended under each stopping criterion
    /// ([`crate::searcher::StopReason::name`] → count, sorted by
    /// reason). Empty (and unserialized) unless the plan arms the
    /// stopping criteria.
    pub stop_counts: BTreeMap<&'static str, usize>,
}

impl PlanReport {
    /// Deterministic JSON document: plan echo, per-job records (plan
    /// order) and per-cell aggregates.
    pub fn to_json(&self) -> Value {
        let jobs: Vec<Value> = self
            .results
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("benchmark", Value::from(r.spec.benchmark.clone())),
                    ("gpu", Value::from(r.spec.gpu.clone())),
                    ("searcher", Value::from(r.spec.searcher.clone())),
                    ("lane", Value::from(r.spec.lane)),
                ];
                if self.plan.has_input_axis() {
                    fields.push(("input", Value::from(r.spec.input.clone())));
                }
                fields.extend(vec![
                    ("best_ms", Value::from(r.best_ms)),
                    ("tests", Value::from(r.tests)),
                    ("profiled_tests", Value::from(r.profiled_tests)),
                    (
                        "tests_to_wp",
                        r.tests_to_wp.map(Value::from).unwrap_or(Value::Null),
                    ),
                    ("cost_s", Value::from(r.cost_s)),
                ]);
                if let Some(f) = &r.faults {
                    fields.extend(vec![
                        ("failed_runs", Value::from(f.failed_runs)),
                        ("retries", Value::from(f.retries)),
                        ("wasted_cost_s", Value::from(f.wasted_cost_s)),
                    ]);
                }
                if let Some(stop) = r.stop {
                    fields.push(("stop", Value::from(stop)));
                }
                if self.plan.include_traces {
                    fields.push((
                        "trace",
                        Value::Arr(
                            r.trace
                                .iter()
                                .map(|&(idx, ms, profiled)| {
                                    obj(vec![
                                        ("idx", Value::from(idx)),
                                        ("ms", Value::from(ms)),
                                        ("profiled", Value::from(profiled)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                obj(fields)
            })
            .collect();

        let aggregates: Vec<Value> = self
            .aggregate_rows()
            .iter()
            .map(|a| {
                let mut fields = vec![
                    ("benchmark", Value::from(a.benchmark.clone())),
                    ("gpu", Value::from(a.gpu.clone())),
                    ("searcher", Value::from(a.searcher.clone())),
                    ("runs", Value::from(a.runs)),
                    ("wp_hits", Value::from(a.wp_hits)),
                    ("mean_tests_to_wp", Value::from(a.mean_tests_to_wp)),
                    ("mean_best_ms", Value::from(a.mean_best_ms)),
                    ("mean_cost_s", Value::from(a.mean_cost_s)),
                ];
                if self.plan.has_input_axis() {
                    fields.push(("input", Value::from(a.input.clone())));
                }
                if self.plan.has_faults() {
                    fields.extend(vec![
                        ("failure_rate", Value::from(a.failure_rate)),
                        ("mean_retries", Value::from(a.mean_retries)),
                        (
                            "mean_wasted_cost_s",
                            Value::from(a.mean_wasted_cost_s),
                        ),
                    ]);
                }
                if self.plan.has_stopping() {
                    fields.push((
                        "stops",
                        obj(a
                            .stop_counts
                            .iter()
                            .map(|(&k, &v)| (k, Value::from(v)))
                            .collect()),
                    ));
                }
                obj(fields)
            })
            .collect();

        let plan = self.plan.to_json();
        let plan_hash = registry::plan_hash(registry::PLAN_REPORT_SCHEMA, &plan);
        obj(vec![
            ("schema", Value::from(registry::PLAN_REPORT_SCHEMA)),
            ("plan", plan),
            ("plan_hash", Value::from(plan_hash)),
            ("provenance", registry::Provenance::from_env().to_json()),
            ("jobs", Value::Arr(jobs)),
            ("aggregates", Value::Arr(aggregates)),
        ])
    }

    /// Per-(benchmark, gpu, input, searcher) aggregates, in sorted key
    /// order (on default-only plans the input component is constant,
    /// so the ordering matches the historical three-part key).
    pub fn aggregate_rows(&self) -> Vec<AggregateRow> {
        type Key = (String, String, String, String);
        let mut cells: BTreeMap<Key, Vec<&JobResult>> = BTreeMap::new();
        for r in &self.results {
            cells
                .entry((
                    r.spec.benchmark.clone(),
                    r.spec.gpu.clone(),
                    r.spec.input.clone(),
                    r.spec.searcher.clone(),
                ))
                .or_default()
                .push(r);
        }
        cells
            .into_iter()
            .map(|((benchmark, gpu, input, searcher), rs)| {
                let steps: Vec<f64> = rs
                    .iter()
                    .map(|r| r.tests_to_wp.unwrap_or(r.tests) as f64)
                    .collect();
                let bests: Vec<f64> = rs.iter().map(|r| r.best_ms).collect();
                let costs: Vec<f64> = rs.iter().map(|r| r.cost_s).collect();
                // denominator is *attempts* (every retried transient
                // attempt is both a failure and an attempt), keeping
                // the rate within [0, 1] by construction
                let total_attempts: usize = rs
                    .iter()
                    .map(|r| {
                        r.tests
                            + r.faults.as_ref().map(|f| f.retries).unwrap_or(0)
                    })
                    .sum();
                let failed: usize = rs
                    .iter()
                    .filter_map(|r| r.faults.as_ref())
                    .map(|f| f.failed_runs)
                    .sum();
                let retries: Vec<f64> = rs
                    .iter()
                    .map(|r| {
                        r.faults
                            .as_ref()
                            .map(|f| f.retries as f64)
                            .unwrap_or(0.0)
                    })
                    .collect();
                let wasted: Vec<f64> = rs
                    .iter()
                    .map(|r| {
                        r.faults
                            .as_ref()
                            .map(|f| f.wasted_cost_s)
                            .unwrap_or(0.0)
                    })
                    .collect();
                let mut stop_counts: BTreeMap<&'static str, usize> =
                    BTreeMap::new();
                for r in rs.iter().filter_map(|r| r.stop) {
                    *stop_counts.entry(r).or_default() += 1;
                }
                AggregateRow {
                    benchmark,
                    gpu,
                    input,
                    searcher,
                    runs: rs.len(),
                    wp_hits: rs
                        .iter()
                        .filter(|r| r.tests_to_wp.is_some())
                        .count(),
                    mean_tests_to_wp: mean(&steps),
                    mean_best_ms: mean(&bests),
                    mean_cost_s: mean(&costs),
                    failure_rate: if total_attempts == 0 {
                        0.0
                    } else {
                        failed as f64 / total_attempts as f64
                    },
                    mean_retries: mean(&retries),
                    mean_wasted_cost_s: mean(&wasted),
                    stop_counts,
                }
            })
            .collect()
    }

    /// The canonical byte representation compared by the smoke gate.
    pub fn to_pretty_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty(1);
        s.push('\n');
        s
    }

    pub fn write_to(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_pretty_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// One summary line per aggregate cell, for CLI output. The target
    /// column shows `gpu:input` when the plan has an input dimension.
    pub fn summary_lines(&self) -> Vec<String> {
        let with_input = self.plan.has_input_axis();
        self.aggregate_rows()
            .iter()
            .map(|a| {
                let target = if with_input {
                    format!("{}:{}", a.gpu, a.input)
                } else {
                    a.gpu.clone()
                };
                format!(
                    "{:<12} {:<8} {:<14} steps {:>7.1}  best {:>9.4} ms  \
                     cost {:>7.1} s",
                    a.benchmark,
                    target,
                    a.searcher,
                    a.mean_tests_to_wp,
                    a.mean_best_ms,
                    a.mean_cost_s,
                )
            })
            .collect()
    }
}

/// Execute a plan with up to `jobs` worker threads.
///
/// Recording and oracle prediction-matrix construction happen once per
/// distinct (benchmark, gpu, input) cell in a deterministic pre-pass;
/// the fan-out then only replays cached data and scores against the
/// shared matrix, so worker count affects wall-clock and nothing else.
pub fn run_plan(plan: &ExperimentPlan, jobs: usize) -> Result<PlanReport> {
    plan.validate()?;

    // Pre-pass over the (benchmark, gpu, input) cross product on the
    // same pool: recording is the dominant cold-start cost and the
    // cache records distinct keys concurrently. Order-preserving
    // par_map keeps the cell list (and thus everything downstream)
    // deterministic. Selectors resolve per benchmark, deduped, so a
    // cell is never recorded (or keyed) twice.
    let mut keys: Vec<(String, String, benchmarks::Input)> = Vec::new();
    for b in &plan.benchmarks {
        let bench = benchmarks::by_name(b).expect("validated");
        for g in &plan.gpus {
            for (name, _) in resolve_input_axis(b, &plan.inputs) {
                let input = benchmarks::resolve_input(bench.as_ref(), &name)
                    .expect("validated");
                keys.push((b.clone(), g.clone(), input));
            }
        }
    }
    let ctxs = pool::par_map_jobs(keys.len(), jobs, &|i| {
        let (b, g, input) = &keys[i];
        let bench = benchmarks::by_name(b).expect("validated");
        let gpu = GpuSpec::by_name(g).expect("validated");
        let inst_reaction = inst_reaction_for(bench.as_ref());
        let data = match bench.recording_mode() {
            // shared dense oracle matrix from the process-wide cache:
            // the serve engine and every later plan over this endpoint
            // score the same Arc (densified straight from the recording
            // — no HashMap<Config, CounterVec> is ever built here)
            RecordingMode::Eager => CellData::Eager {
                rec: cached_space(bench.as_ref(), &gpu, input),
                matrix: benchmarks::cached_matrix(bench.as_ref(), &gpu, input),
            },
            // nothing is simulated up front: the shared recorder fills
            // its memo as jobs visit configurations
            RecordingMode::OnDemand => CellData::Lazy {
                recorder: cached_recorder(bench.as_ref(), &gpu, input),
            },
        };
        PlanCell {
            data,
            gpu,
            inst_reaction,
        }
    });
    let cells: BTreeMap<(String, String, String), PlanCell> = keys
        .into_iter()
        .map(|(b, g, input)| (b, g, input.name))
        .zip(ctxs)
        .collect();

    let specs = plan.jobs();
    let results = pool::par_map_jobs(specs.len(), jobs, &|i| {
        let spec = &specs[i];
        let ctx = &cells[&(
            spec.benchmark.clone(),
            spec.gpu.clone(),
            spec.input.clone(),
        )];
        run_job(spec, plan, ctx)
    });

    Ok(PlanReport {
        plan: plan.clone(),
        results,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExperimentPlan {
        ExperimentPlan {
            benchmarks: vec!["coulomb".into()],
            gpus: vec!["gtx1070".into()],
            inputs: vec!["default".into()],
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 5,
            max_tests: 40,
            include_traces: true,
            fault_profile: FaultProfile::None,
            patience: None,
            epsilon: 0.0,
        }
    }

    #[test]
    fn plan_expansion_order_and_count() {
        let plan = ExperimentPlan::smoke(0);
        let jobs = plan.jobs();
        // 2 benchmarks × 1 gpu × 9-strategy zoo × 3 seeds
        assert_eq!(jobs.len(), 2 * 9 * 3);
        assert_eq!(jobs[0].benchmark, "coulomb");
        assert_eq!(jobs[0].searcher, "random");
        assert_eq!(jobs[0].lane, 0);
        assert_eq!(jobs[1].lane, 1);
        assert_eq!(jobs[3].searcher, "profile");
        // the zoo rides behind the historical lanes, augmented last
        assert_eq!(jobs[15].searcher, "ga");
        assert_eq!(jobs[24].searcher, "profile+ga");
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn searcher_axis_accepts_specs_and_rejects_bad_ones() {
        // parameterized and composed specs validate like plain names
        let mut plan = tiny();
        plan.searchers = vec![
            "ga:pop=8,mutation=0.2".into(),
            "profile+de:radius=1".into(),
        ];
        assert!(plan.validate().is_ok());
        // a known searcher with a bad parameter is typed InvalidSearcher
        plan.searchers = vec!["ga:population=8".into()];
        match plan.validate() {
            Err(PlanError::InvalidSearcher { spec, error }) => {
                assert_eq!(spec, "ga:population=8");
                assert!(error.contains("population"));
            }
            other => panic!("expected InvalidSearcher, got {other:?}"),
        }
        // reads_model follows the spec layer
        assert!(reads_model("profile"));
        assert!(reads_model("profile+ga"));
        assert!(reads_model("profile:inst_reaction=0.6"));
        assert!(!reads_model("ga"));
        assert!(!reads_model("nonsense"));
    }

    #[test]
    fn validate_rejects_unknowns_with_typed_errors() {
        let mut plan = tiny();
        plan.searchers = vec!["quantum".into()];
        assert_eq!(
            plan.validate(),
            Err(PlanError::UnknownSearcher("quantum".into()))
        );
        let mut plan = tiny();
        plan.benchmarks = vec!["nope".into()];
        assert_eq!(
            plan.validate(),
            Err(PlanError::UnknownBenchmark("nope".into()))
        );
        let mut plan = tiny();
        plan.gpus = vec!["titan".into()];
        assert_eq!(plan.validate(), Err(PlanError::UnknownGpu("titan".into())));
        let mut plan = tiny();
        plan.seeds = 0;
        assert_eq!(plan.validate(), Err(PlanError::EmptyAxis("seeds")));
        assert!(tiny().validate().is_ok());
    }

    #[test]
    fn on_demand_benchmarks_validate_into_search_plans() {
        // the historical carve-out is retired: gemm-full (205k configs,
        // tuned on demand) now schedules into a search plan like any
        // other benchmark — only training-based plans still reject it
        let mut plan = tiny();
        plan.benchmarks = vec!["gemm-full".into()];
        assert!(plan.validate().is_ok());
        assert_eq!(
            validate_trainable_benchmarks(
                "benchmarks",
                &["gemm-full".to_string()]
            ),
            Err(PlanError::NoRecording("gemm-full".into()))
        );
        // and the trainable rejection formats with an explanation
        let msg = PlanError::NoRecording("gemm-full".into()).to_string();
        assert!(msg.contains("gemm-full") && msg.contains("recorded"));
    }

    #[test]
    fn lazy_plan_tunes_a_million_config_space_end_to_end() {
        // the tentpole contract: a ≥1M-config benchmark runs through
        // the standard plan machinery — fan-out, determinism, faults —
        // without ever materializing its space
        let mut plan = tiny();
        plan.benchmarks = vec!["synth-grid".into()];
        plan.gpus = vec!["gtx1070".into()];
        plan.searchers = vec!["profile".into(), "random".into()];
        plan.seeds = 2;
        plan.max_tests = 18;
        let report = run_plan(&plan, 2).unwrap();
        assert_eq!(report.results.len(), 4);
        for r in &report.results {
            assert_eq!(r.tests, 18);
            assert!(r.best_ms.is_finite());
            // no known best on the lazy path → no threshold metric
            assert_eq!(r.tests_to_wp, None);
        }
        // jobs=1 and jobs=8 must still agree byte-for-byte
        let serial = run_plan(&plan, 1).unwrap();
        assert_eq!(
            report.to_json().to_string_pretty(1),
            serial.to_json().to_string_pretty(1)
        );
    }

    #[test]
    fn input_axis_expands_resolves_and_tags_streams() {
        let mut plan = tiny();
        plan.inputs = vec!["default".into(), "alt".into()];
        assert!(plan.has_input_axis());
        assert!(plan.validate().is_ok());
        let jobs = plan.jobs();
        // 1 benchmark × 1 gpu × (input × searcher × lane)
        assert_eq!(jobs.len(), 2 * 2 * 2);
        assert_eq!(jobs[0].input, "grid256_atoms256");
        assert!(jobs[0].input_default);
        assert_eq!(jobs[4].input, "grid256_atoms64");
        assert!(!jobs[4].input_default);
        // default-input jobs keep the historical three-tag stream;
        // non-default inputs get their own
        assert_eq!(
            jobs[0].rng_seed(5),
            stream_seed(5, &["coulomb", "gtx1070", "random"], 0)
        );
        assert_eq!(
            jobs[4].rng_seed(5),
            stream_seed(
                5,
                &["coulomb", "gtx1070", "grid256_atoms64", "random"],
                0
            )
        );
        assert_ne!(jobs[0].rng_seed(5), jobs[4].rng_seed(5));
        // overlapping selectors collapse to one axis entry
        plan.inputs = vec!["default".into(), "grid256_atoms256".into()];
        assert_eq!(plan.jobs().len(), tiny().jobs().len());
    }

    #[test]
    fn input_axis_validation_and_unknown_selectors() {
        let mut plan = tiny();
        plan.inputs = vec![];
        assert_eq!(plan.validate(), Err(PlanError::EmptyAxis("inputs")));
        let mut plan = tiny();
        plan.inputs = vec!["grid999".into()];
        assert_eq!(
            plan.validate(),
            Err(PlanError::UnknownInput("coulomb".into(), "grid999".into()))
        );
    }

    #[test]
    fn default_input_plans_serialize_without_input_fields() {
        // the bit-for-bit contract with pre-input-axis reports: a
        // ["default"] axis must not leak new keys into the JSON
        let plan = tiny();
        assert!(!plan.has_input_axis());
        let report = run_plan(&plan, 2).unwrap();
        let text = report.to_pretty_string();
        assert!(!text.contains("\"inputs\""));
        assert!(!text.contains("\"input\""));
        // a real input axis does serialize, in plan echo, jobs and
        // aggregates
        let mut plan = tiny();
        plan.inputs = vec!["default".into(), "alt".into()];
        let report = run_plan(&plan, 2).unwrap();
        let text = report.to_pretty_string();
        assert!(text.contains("\"inputs\""));
        assert!(text.contains("\"input\": \"grid256_atoms64\""));
        assert_eq!(report.aggregate_rows().len(), 4);
        for a in report.aggregate_rows() {
            assert_eq!(a.runs, plan.seeds, "cell double-counted");
        }
    }

    #[test]
    fn invalid_fraction_is_typed_and_formats() {
        assert!(validate_fraction("train_fraction", 1.0).is_ok());
        assert!(validate_fraction("train_fraction", 0.25).is_ok());
        for bad in [0.0, -0.5, 1.5, f64::NAN, f64::INFINITY] {
            let err = validate_fraction("train_fraction", bad).unwrap_err();
            match err {
                PlanError::InvalidFraction { axis, .. } => {
                    assert_eq!(axis, "train_fraction")
                }
                other => panic!("wrong error {other:?}"),
            }
        }
        let msg = validate_fraction("fractions", 2.0)
            .unwrap_err()
            .to_string();
        assert!(msg.contains("fractions") && msg.contains("(0, 1]"));
    }

    #[test]
    fn job_seeds_are_distinct_per_lane_and_searcher() {
        let plan = tiny();
        let jobs = plan.jobs();
        let mut seeds: Vec<u64> =
            jobs.iter().map(|j| j.rng_seed(plan.base_seed)).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), jobs.len());
    }

    #[test]
    fn serial_and_parallel_runs_are_byte_identical() {
        let plan = tiny();
        let a = run_plan(&plan, 1).unwrap().to_pretty_string();
        let b = run_plan(&plan, 8).unwrap().to_pretty_string();
        assert_eq!(a, b);
        assert!(a.contains("\"schema\": \"pcat-plan-report/v1\""));
    }

    #[test]
    fn faultless_plans_serialize_without_fault_fields() {
        // the bit-for-bit contract: fault_profile none leaks no new
        // keys into the JSON (plan echo, jobs or aggregates)
        let plan = tiny();
        assert!(!plan.has_faults());
        let text = run_plan(&plan, 2).unwrap().to_pretty_string();
        for key in [
            "fault_profile",
            "failed_runs",
            "retries",
            "wasted_cost_s",
            "failure_rate",
        ] {
            assert!(!text.contains(key), "leaked {key:?}");
        }
    }

    #[test]
    fn unarmed_stopping_serializes_no_new_fields() {
        // the bit-for-bit contract, third verse: patience None leaks
        // no stopping keys into plan echo, jobs or aggregates
        let plan = tiny();
        assert!(!plan.has_stopping());
        let text = run_plan(&plan, 2).unwrap().to_pretty_string();
        for key in ["\"patience\"", "\"epsilon\"", "\"stop\"", "\"stops\""] {
            assert!(!text.contains(key), "leaked {key}");
        }
    }

    #[test]
    fn armed_stopping_reports_per_job_reasons_and_cell_counts() {
        let plan = ExperimentPlan {
            patience: Some(5),
            epsilon: 0.01,
            max_tests: 60,
            ..tiny()
        };
        assert!(plan.has_stopping());
        let report = run_plan(&plan, 2).unwrap();
        for r in &report.results {
            let stop = r.stop.expect("armed plans account every job");
            assert!(
                ["threshold", "patience", "tests", "cost", "exhausted"]
                    .contains(&stop)
            );
            // a patience stop can never exceed the hard test cap
            assert!(r.tests <= plan.max_tests);
        }
        for a in report.aggregate_rows() {
            let total: usize = a.stop_counts.values().sum();
            assert_eq!(total, a.runs, "every run has exactly one reason");
        }
        let text = report.to_pretty_string();
        assert!(text.contains("\"patience\": 5"));
        assert!(text.contains("\"epsilon\": 0.01"));
        assert!(text.contains("\"stop\""));
        assert!(text.contains("\"stops\""));
        // stopping changes budgets, not streams: serial == parallel
        assert_eq!(
            run_plan(&plan, 1).unwrap().to_pretty_string(),
            run_plan(&plan, 8).unwrap().to_pretty_string()
        );
    }

    #[test]
    fn zoo_smoke_plan_is_jobs_independent() {
        // the full 9-strategy smoke zoo, shrunk to one seed for test
        // wall-clock: serial and parallel runs stay byte-identical
        let plan = ExperimentPlan {
            seeds: 1,
            max_tests: 30,
            ..ExperimentPlan::smoke(3)
        };
        let a = run_plan(&plan, 1).unwrap().to_pretty_string();
        let b = run_plan(&plan, 8).unwrap().to_pretty_string();
        assert_eq!(a, b);
        for s in &plan.searchers {
            assert!(a.contains(&format!("\"searcher\": \"{s}\"")), "{s}");
        }
    }

    #[test]
    fn hostile_runs_complete_and_account_for_faults() {
        // the whole zoo — population, annealing and augmented lanes
        // included — must survive a hostile fault profile with sane
        // accounting, not just the historical five
        let plan = ExperimentPlan {
            fault_profile: FaultProfile::Hostile,
            searchers: vec![
                "random".into(),
                "profile".into(),
                "basin_hopping".into(),
                "annealing".into(),
                "starchart".into(),
                "ga".into(),
                "de".into(),
                "dual_annealing".into(),
                "profile+ga".into(),
            ],
            max_tests: 60,
            ..tiny()
        };
        let report = run_plan(&plan, 2).unwrap();
        // every searcher completed and the accounting is present
        assert_eq!(report.results.len(), 9 * plan.seeds);
        assert!(report.results.iter().all(|r| r.faults.is_some()));
        let total_failed: usize = report
            .results
            .iter()
            .map(|r| r.faults.as_ref().unwrap().failed_runs)
            .sum();
        assert!(total_failed > 0, "hostile profile failed nothing");
        for a in report.aggregate_rows() {
            assert!((0.0..=1.0).contains(&a.failure_rate));
            assert!(a.mean_wasted_cost_s >= 0.0);
        }
        let text = report.to_pretty_string();
        assert!(text.contains("\"fault_profile\": \"hostile\""));
        assert!(text.contains("\"failure_rate\""));
    }

    #[test]
    fn fault_injection_is_jobs_independent_and_seed_stable() {
        let plan = ExperimentPlan {
            fault_profile: FaultProfile::Hostile,
            ..tiny()
        };
        let a = run_plan(&plan, 1).unwrap().to_pretty_string();
        let b = run_plan(&plan, 8).unwrap().to_pretty_string();
        assert_eq!(a, b, "fault streams must not depend on scheduling");
        // same seed reruns reproduce the exact fault sequence
        let c = run_plan(&plan, 4).unwrap().to_pretty_string();
        assert_eq!(a, c);
        // a different base seed draws a different fault sequence
        let plan2 = ExperimentPlan {
            base_seed: 6,
            ..plan.clone()
        };
        assert_ne!(a, run_plan(&plan2, 1).unwrap().to_pretty_string());
    }

    #[test]
    fn fault_cell_seed_ignores_searcher_and_lane() {
        let plan = tiny();
        let jobs = plan.jobs();
        // random lane 0/1 and profile lane 0 share one cell stream
        let seeds: Vec<u64> = jobs
            .iter()
            .map(|j| j.fault_cell_seed(plan.base_seed))
            .collect();
        assert!(seeds.windows(2).all(|w| w[0] == w[1]));
        // but job fault streams are all distinct
        let mut js: Vec<u64> = jobs
            .iter()
            .map(|j| j.fault_job_seed(plan.base_seed))
            .collect();
        js.sort_unstable();
        js.dedup();
        assert_eq!(js.len(), jobs.len());
        // and decorrelated from the searcher streams
        for j in &jobs {
            assert_ne!(j.fault_job_seed(plan.base_seed), j.rng_seed(plan.base_seed));
        }
    }

    #[test]
    fn report_has_jobs_and_aggregates() {
        let plan = tiny();
        let report = run_plan(&plan, 4).unwrap();
        assert_eq!(report.results.len(), 4);
        let v = report.to_json();
        assert_eq!(v.get("jobs").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.get("aggregates").unwrap().as_arr().unwrap().len(), 2);
        // every job found a finite best and ran at least one test
        for r in &report.results {
            assert!(r.best_ms.is_finite());
            assert!(r.tests >= 1);
            assert!(r.tests <= plan.max_tests);
        }
        assert!(!report.summary_lines().is_empty());
    }
}
