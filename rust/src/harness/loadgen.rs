//! Deterministic load generator for the serving layer: replay a seeded
//! request mix against a [`ServeEngine`](super::serve::ServeEngine) and
//! report throughput, hit rate and latency percentiles as a
//! registry-stamped `SERVE_REPORT.json`.
//!
//! Everything the report contains is a pure function of the
//! [`LoadPlan`]:
//!
//! * The endpoint universe is the plan's `benchmarks × gpus × inputs`
//!   cross product in plan order.
//! * Which endpoints start **warm** is a seeded permutation of that
//!   universe (`miss_ratio` controls how many stay cold), pre-filled
//!   through the engine before the clock starts — the kubecl-style
//!   "ship a cache file with the deployment" scenario.
//! * The request mix is Zipf-distributed over the universe (exponent
//!   `zipf_s`; `0` = uniform), drawn from its own RNG stream.
//! * Hit/miss accounting is **logical**: a request misses iff it is the
//!   first occurrence of a cold endpoint in the mix. This matches what
//!   a serial replay of the same mix observes, so the counts — and the
//!   report bytes — are identical for `--jobs 1` and `--jobs 8`, even
//!   though under concurrency a racing request may physically wait on
//!   another thread's in-flight search.
//! * Latencies are **simulated**, not wall-clock: a hit costs
//!   [`HIT_LATENCY_S`], a (logical) miss additionally pays the filled
//!   entry's deterministic search cost `cost_s`. Wall-clock latency
//!   would differ across thread counts and machines; simulated latency
//!   keeps the percentiles golden-gateable while still being driven by
//!   real per-endpoint search costs.
//!
//! The exactly-once invariant is externally checked: the engine's fill
//! counter must equal the number of logical misses — if concurrent
//! requests ever double-searched an endpoint, `run_load_plan` reports
//! it as a hard error rather than a skewed percentile.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use crate::util::json::{obj, Value};
use crate::util::rng::{stream_seed, Rng};
use crate::util::{pool, stats};

use super::plan::{
    resolve_input_axis, validate_benchmarks, validate_gpus, validate_inputs,
    validate_knob, validate_ratio, PlanError,
};
use super::registry::{plan_hash, Provenance, SERVE_REPORT_SCHEMA};
use super::serve::{
    ServeConfig, ServeEngine, ServeKey, TuningStore,
};

/// Simulated service overhead of answering from the store, seconds.
/// Every request pays it; a logical miss additionally pays the search
/// cost of the entry that fills the endpoint.
pub const HIT_LATENCY_S: f64 = 5e-5;

/// A seeded serving workload: endpoint axes, request count and mix
/// shape. The report is a pure function of this struct.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadPlan {
    pub benchmarks: Vec<String>,
    pub gpus: Vec<String>,
    /// Input selectors, resolved per benchmark like every other plan.
    pub inputs: Vec<String>,
    /// Requests to draw from the mix.
    pub requests: usize,
    /// Zipf popularity exponent over the endpoint universe
    /// (`0` = uniform, larger = more skew toward early endpoints).
    pub zipf_s: f64,
    /// Fraction of the endpoint universe left cold at start; the rest
    /// is pre-warmed through the engine before the run.
    pub miss_ratio: f64,
    pub base_seed: u64,
    /// Budget cap per miss search.
    pub max_tests: usize,
}

impl LoadPlan {
    /// The nightly serving matrix: every recordable benchmark × all
    /// four GPUs, a skewed mix with a mostly-warm store.
    pub fn full(base_seed: u64) -> Self {
        LoadPlan {
            benchmarks: ["coulomb", "transpose", "gemm", "nbody", "convolution"]
                .map(String::from)
                .to_vec(),
            gpus: ["gtx680", "gtx750", "gtx1070", "rtx2080"]
                .map(String::from)
                .to_vec(),
            inputs: vec!["default".into()],
            requests: 100_000,
            zipf_s: 1.1,
            miss_ratio: 0.25,
            base_seed,
            max_tests: 400,
        }
    }

    /// The CI smoke workload: 4 endpoints, half cold, a mix small
    /// enough to gate a PR but large enough that every endpoint is hit
    /// from multiple workers.
    pub fn smoke(base_seed: u64) -> Self {
        LoadPlan {
            benchmarks: vec!["coulomb".into(), "transpose".into()],
            gpus: vec!["gtx1070".into(), "gtx750".into()],
            inputs: vec!["default".into()],
            requests: 400,
            zipf_s: 1.0,
            miss_ratio: 0.5,
            base_seed,
            max_tests: 80,
        }
    }

    pub fn validate(&self) -> Result<(), PlanError> {
        validate_benchmarks("benchmarks", &self.benchmarks)?;
        validate_gpus("gpus", &self.gpus)?;
        validate_inputs("inputs", &self.benchmarks, &self.inputs)?;
        validate_ratio("miss_ratio", self.miss_ratio)?;
        validate_knob("zipf_s", self.zipf_s)?;
        if self.requests == 0 {
            return Err(PlanError::EmptyAxis("requests"));
        }
        Ok(())
    }

    /// The endpoint universe in plan order: benchmarks × gpus ×
    /// resolved inputs. Canonical keys — the plan must already be
    /// validated.
    fn endpoints(&self) -> Vec<ServeKey> {
        let mut keys = Vec::new();
        for b in &self.benchmarks {
            for g in &self.gpus {
                for (input, _) in resolve_input_axis(b, &self.inputs) {
                    keys.push(
                        ServeKey::resolve(b, g, &input)
                            .expect("plan validated"),
                    );
                }
            }
        }
        keys
    }

    pub fn to_json(&self) -> Value {
        let strs = |xs: &[String]| {
            Value::Arr(xs.iter().map(|s| Value::from(s.clone())).collect())
        };
        obj(vec![
            // u64 seeds ride as strings (f64 would corrupt > 2^53)
            ("base_seed", Value::from(self.base_seed.to_string())),
            ("benchmarks", strs(&self.benchmarks)),
            ("gpus", strs(&self.gpus)),
            ("inputs", strs(&self.inputs)),
            ("max_tests", Value::from(self.max_tests)),
            ("miss_ratio", Value::from(self.miss_ratio)),
            ("requests", Value::from(self.requests)),
            ("zipf_s", Value::from(self.zipf_s)),
        ])
    }
}

/// Logical per-endpoint accounting plus the stored answer (if the
/// endpoint was ever filled or pre-warmed).
#[derive(Debug, Clone)]
pub struct EndpointReport {
    pub key: ServeKey,
    pub requests: usize,
    pub hits: usize,
    pub misses: usize,
    /// `None` when the mix never touched the endpoint and it was not
    /// pre-warmed, so the store holds no answer for it.
    pub best_ms: Option<f64>,
    pub config: Option<Vec<i64>>,
}

/// Aggregate results of one load run.
#[derive(Debug, Clone)]
pub struct LoadResults {
    pub requests: usize,
    pub hits: usize,
    pub misses: usize,
    /// Searches the engine ran during the timed run — the exactly-once
    /// invariant makes this equal `misses`.
    pub fills: usize,
    /// Endpoints pre-filled before the clock started.
    pub prewarmed: usize,
    pub hit_rate: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    /// Sum of simulated request latencies, seconds.
    pub total_cost_s: f64,
    pub throughput_rps: f64,
}

/// A completed load run: the plan echo, per-endpoint accounting and
/// aggregate serving KPIs, stamped with plan hash + provenance.
pub struct ServeReport {
    pub plan: LoadPlan,
    pub endpoints: Vec<EndpointReport>,
    pub results: LoadResults,
}

impl ServeReport {
    pub fn to_json(&self) -> Value {
        let plan = self.plan.to_json();
        let hash = plan_hash(SERVE_REPORT_SCHEMA, &plan);
        let endpoints = self
            .endpoints
            .iter()
            .map(|e| {
                obj(vec![
                    ("benchmark", Value::from(e.key.benchmark.clone())),
                    ("gpu", Value::from(e.key.gpu.clone())),
                    ("input", Value::from(e.key.input.clone())),
                    ("requests", Value::from(e.requests)),
                    ("hits", Value::from(e.hits)),
                    ("misses", Value::from(e.misses)),
                    (
                        "best_ms",
                        e.best_ms.map(Value::from).unwrap_or(Value::Null),
                    ),
                    (
                        "config",
                        e.config
                            .as_ref()
                            .map(|c| {
                                Value::Arr(
                                    c.iter()
                                        .map(|&v| Value::from(v))
                                        .collect(),
                                )
                            })
                            .unwrap_or(Value::Null),
                    ),
                ])
            })
            .collect();
        let r = &self.results;
        let results = obj(vec![
            ("fills", Value::from(r.fills)),
            ("hit_rate", Value::from(r.hit_rate)),
            ("hits", Value::from(r.hits)),
            ("mean_latency_s", Value::from(r.mean_latency_s)),
            ("misses", Value::from(r.misses)),
            ("p50_latency_s", Value::from(r.p50_latency_s)),
            ("p95_latency_s", Value::from(r.p95_latency_s)),
            ("p99_latency_s", Value::from(r.p99_latency_s)),
            ("prewarmed", Value::from(r.prewarmed)),
            ("requests", Value::from(r.requests)),
            ("throughput_rps", Value::from(r.throughput_rps)),
            ("total_cost_s", Value::from(r.total_cost_s)),
        ]);
        obj(vec![
            ("endpoints", Value::Arr(endpoints)),
            ("plan", plan),
            ("plan_hash", Value::from(hash)),
            ("provenance", Provenance::from_env().to_json()),
            ("results", results),
            ("schema", Value::from(SERVE_REPORT_SCHEMA)),
        ])
    }

    pub fn to_pretty_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty(1);
        s.push('\n');
        s
    }

    pub fn write_to(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_pretty_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Aggregate + per-endpoint summary lines for CLI output.
    pub fn summary_lines(&self) -> Vec<String> {
        let r = &self.results;
        let mut lines = vec![
            format!(
                "requests {:>6}  hit rate {:>6.1}%  misses {:>4}  \
                 fills {:>4}  prewarmed {:>4}",
                r.requests,
                r.hit_rate * 100.0,
                r.misses,
                r.fills,
                r.prewarmed,
            ),
            format!(
                "latency p50 {:>9.3} ms  p95 {:>9.3} ms  p99 {:>9.3} ms  \
                 throughput {:>9.1} req/s",
                r.p50_latency_s * 1e3,
                r.p95_latency_s * 1e3,
                r.p99_latency_s * 1e3,
                r.throughput_rps,
            ),
        ];
        for e in &self.endpoints {
            lines.push(format!(
                "{:<32} requests {:>6}  hits {:>6}  misses {:>4}  best {}",
                e.key.to_string(),
                e.requests,
                e.hits,
                e.misses,
                e.best_ms
                    .map(|b| format!("{b:>9.4} ms"))
                    .unwrap_or_else(|| "     (cold)".to_string()),
            ));
        }
        lines
    }
}

/// Seeded Fisher–Yates permutation of `0..n` from its own RNG stream.
fn warm_permutation(n: usize, base_seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(stream_seed(base_seed, &["loadgen", "warm"], 0));
    let mut idx: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        idx.swap(i, rng.below(i + 1));
    }
    idx
}

/// Draw the request mix: Zipf weights `1/(rank+1)^s` over the universe
/// in plan order, sampled by inverse CDF from the mix stream.
fn request_mix(plan: &LoadPlan, n_endpoints: usize) -> Vec<usize> {
    let mut rng =
        Rng::new(stream_seed(plan.base_seed, &["loadgen", "mix"], 0));
    let weights: Vec<f64> = (0..n_endpoints)
        .map(|rank| 1.0 / ((rank + 1) as f64).powf(plan.zipf_s))
        .collect();
    let mut cum = Vec::with_capacity(n_endpoints);
    let mut total = 0.0;
    for w in &weights {
        total += w;
        cum.push(total);
    }
    (0..plan.requests)
        .map(|_| {
            let r = rng.f64() * total;
            cum.partition_point(|&c| c <= r).min(n_endpoints - 1)
        })
        .collect()
}

/// Run a load plan against a store: pre-warm, replay the mix across
/// `jobs` workers, verify the exactly-once invariant and aggregate the
/// serving KPIs. The report is byte-identical for any `jobs`.
pub fn run_load_plan(
    plan: &LoadPlan,
    store: Arc<dyn TuningStore>,
    jobs: usize,
) -> Result<ServeReport> {
    plan.validate()?;
    let keys = plan.endpoints();
    let n = keys.len();
    let engine = ServeEngine::new(store, ServeConfig {
        base_seed: plan.base_seed,
        max_tests: plan.max_tests,
    });

    // pre-warm a seeded subset of the universe through the ordinary
    // query path, so warm entries are bit-for-bit what a fill produces
    let n_warm = ((1.0 - plan.miss_ratio) * n as f64).round() as usize;
    let perm = warm_permutation(n, plan.base_seed);
    for &i in perm.iter().take(n_warm) {
        engine
            .query(&keys[i])
            .with_context(|| format!("pre-warming {}", keys[i]))?;
    }
    let prewarm_fills = engine.fills();

    // logical hit/miss classification: a request misses iff it is the
    // first occurrence of an endpoint the store cannot answer yet —
    // exactly what a serial replay of this mix observes
    let mix = request_mix(plan, n);
    let mut known: Vec<bool> = keys
        .iter()
        .map(|k| engine.store().get(k).is_some())
        .collect();
    let miss_of_request: Vec<bool> = mix
        .iter()
        .map(|&i| {
            let miss = !known[i];
            known[i] = true;
            miss
        })
        .collect();

    // the timed run: replay the mix across the worker pool
    let outcomes = pool::par_map_jobs(plan.requests, jobs, &|r| {
        engine.query(&keys[mix[r]])
    });
    let mut entries_by_endpoint: Vec<Option<f64>> = vec![None; n];
    for (r, outcome) in outcomes.iter().enumerate() {
        match outcome {
            Ok(out) => {
                entries_by_endpoint[mix[r]] = Some(out.entry.cost_s);
            }
            Err(e) => bail!("request {r} ({}) failed: {e}", keys[mix[r]]),
        }
    }
    let fills = engine.fills() - prewarm_fills;

    // exactly-once invariant: every logical miss ran one search, and
    // nothing else did — a violation means the inflight dedup broke
    let misses = miss_of_request.iter().filter(|&&m| m).count();
    if fills != misses {
        bail!(
            "serve fill accounting broken: {fills} searches ran for \
             {misses} logical misses"
        );
    }

    // simulated latencies: deterministic per request, so percentiles
    // are identical across jobs counts
    let latencies: Vec<f64> = mix
        .iter()
        .zip(&miss_of_request)
        .map(|(&i, &miss)| {
            let mut lat = HIT_LATENCY_S;
            if miss {
                lat += entries_by_endpoint[i]
                    .expect("missed endpoint was filled");
            }
            lat
        })
        .collect();
    let total_cost_s: f64 = latencies.iter().sum();

    let mut endpoints = Vec::with_capacity(n);
    for (i, key) in keys.iter().enumerate() {
        let requests = mix.iter().filter(|&&m| m == i).count();
        let misses = mix
            .iter()
            .zip(&miss_of_request)
            .filter(|(&m, &miss)| m == i && miss)
            .count();
        let entry = engine.store().get(key);
        endpoints.push(EndpointReport {
            key: key.clone(),
            requests,
            hits: requests - misses,
            misses,
            best_ms: entry.as_ref().map(|e| e.best_ms),
            config: entry.map(|e| e.config),
        });
    }

    let hits = plan.requests - misses;
    let results = LoadResults {
        requests: plan.requests,
        hits,
        misses,
        fills,
        prewarmed: prewarm_fills,
        hit_rate: hits as f64 / plan.requests as f64,
        mean_latency_s: stats::mean(&latencies),
        p50_latency_s: stats::quantile(&latencies, 0.50),
        p95_latency_s: stats::quantile(&latencies, 0.95),
        p99_latency_s: stats::quantile(&latencies, 0.99),
        total_cost_s,
        throughput_rps: plan.requests as f64 / total_cost_s,
    };

    Ok(ServeReport {
        plan: plan.clone(),
        endpoints,
        results,
    })
}

#[cfg(test)]
mod tests {
    use super::super::serve::MemTuningStore;
    use super::*;

    #[test]
    fn smoke_plan_validates() {
        assert_eq!(LoadPlan::smoke(0).validate(), Ok(()));
        assert_eq!(LoadPlan::full(0).validate(), Ok(()));
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        let mut p = LoadPlan::smoke(0);
        p.miss_ratio = 1.5;
        assert_eq!(
            p.validate(),
            Err(PlanError::InvalidRatio {
                axis: "miss_ratio",
                value: 1.5
            })
        );
        let mut p = LoadPlan::smoke(0);
        p.zipf_s = -1.0;
        assert_eq!(
            p.validate(),
            Err(PlanError::InvalidKnob {
                axis: "zipf_s",
                value: -1.0
            })
        );
        let mut p = LoadPlan::smoke(0);
        p.requests = 0;
        assert_eq!(p.validate(), Err(PlanError::EmptyAxis("requests")));
        // on-demand benchmarks are valid serve traffic now that the
        // miss path searches lazily instead of recording exhaustively
        let mut p = LoadPlan::smoke(0);
        p.benchmarks = vec!["gemm-full".into()];
        assert!(p.validate().is_ok());
    }

    #[test]
    fn mix_is_deterministic_and_skewed() {
        let plan = LoadPlan::smoke(7);
        let a = request_mix(&plan, 4);
        let b = request_mix(&plan, 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), plan.requests);
        assert!(a.iter().all(|&i| i < 4));
        // zipf_s = 1.0 must favour rank 0 over rank 3
        let count = |xs: &[usize], v: usize| {
            xs.iter().filter(|&&x| x == v).count()
        };
        assert!(count(&a, 0) > count(&a, 3));
    }

    #[test]
    fn warm_permutation_is_seeded_and_complete() {
        let a = warm_permutation(16, 3);
        assert_eq!(a, warm_permutation(16, 3));
        assert_ne!(a, warm_permutation(16, 4));
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<usize>>());
    }

    #[test]
    fn report_counts_are_consistent() {
        let mut plan = LoadPlan::smoke(1);
        plan.requests = 60;
        plan.max_tests = 40;
        let report = run_load_plan(
            &plan,
            Arc::new(MemTuningStore::new()),
            2,
        )
        .unwrap();
        let r = &report.results;
        assert_eq!(r.requests, 60);
        assert_eq!(r.hits + r.misses, r.requests);
        assert_eq!(r.fills, r.misses);
        assert!((0.0..=1.0).contains(&r.hit_rate));
        assert!(r.p50_latency_s <= r.p95_latency_s);
        assert!(r.p95_latency_s <= r.p99_latency_s);
        assert!(r.throughput_rps > 0.0);
        let per_endpoint: usize =
            report.endpoints.iter().map(|e| e.requests).sum();
        assert_eq!(per_endpoint, r.requests);
        let per_endpoint_misses: usize =
            report.endpoints.iter().map(|e| e.misses).sum();
        assert_eq!(per_endpoint_misses, r.misses);
    }
}
