//! Cross-hardware transfer evaluation: train-on-A / tune-on-B.
//!
//! The paper's headline claim is *portability* — a counter-based model
//! sampled on one GPU steers the search on different, even unseen,
//! hardware (§4.4, Table 6). [`TransferPlan`] turns that claim into a
//! job matrix: the full cross product `(benchmark × source GPU ×
//! target GPU × searcher × seed)`, where the profile searcher's
//! [`PredictionMatrix`] is built from the **source** GPU's recording
//! while the search itself replays the **target** GPU's recording.
//!
//! Sharing discipline (§Perf): each `(benchmark, source)` model matrix
//! is built exactly once and shared via `Arc` across *every* target
//! cell and seed-repetition that consumes it; recordings come from the
//! process-wide space cache, so each `(benchmark, GPU)` space is
//! enumerated once per process no matter how many cells touch it.
//!
//! Counter-generation mismatches (pre-Volta source vs Volta+ target or
//! vice versa) are handled by restricting the matrix to the counters
//! both generations support ([`PredictionMatrix::restricted_to`]):
//! the mismatched ΔPC components are dropped from scoring — a
//! documented, regression-tested fallback, never a panic. The
//! restriction applies **iff the two generations differ**: a
//! same-generation pair (including every same-GPU diagonal cell)
//! shares one self-consistent metric set and scores it in full, which
//! keeps same-GPU transfer cells bit-identical to the plain
//! [`ExperimentPlan`] path for identical seeds. Consequence worth
//! knowing when reading a Table 6 column: a same-generation source may
//! score counters (today: `LOC_O`) that a cross-generation source on
//! the same target cannot — each source uses the richest counter set
//! that transfers to that target, and the per-cell `dropped_counters`
//! field makes the difference explicit.
//!
//! **Determinism contract** (same as [`ExperimentPlan`]): a job's
//! result is a pure function of the plan and its coordinates. The RNG
//! stream is keyed by `(base seed, benchmark, target GPU, searcher,
//! lane)` — deliberately *not* by the source GPU, so (a) same-GPU
//! cells reproduce `ExperimentPlan` runs exactly and (b) different
//! sources are compared on identical search randomness (common random
//! numbers: the only varying factor in a source column is the model).
//! Serial and parallel executions produce byte-identical
//! `TRANSFER_REPORT.json` documents; CI smoke-gates that.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::benchmarks::{self, cached_space};
use crate::coordinator::Tuner;
use crate::counters::CounterSet;
use crate::gpusim::GpuSpec;
use crate::model::PredictionMatrix;
use crate::searcher::{Budget, CostModel};
use crate::tuning::RecordedSpace;
use crate::util::json::{obj, Value};
use crate::util::pool;
use crate::util::rng::stream_seed;
use crate::util::stats::{bootstrap_ci, mean, median};

use super::convergence::{
    aggregate_step_curves, steps_to_within, StepCurvePoint,
};
use super::plan::{
    reads_model, searcher_choice, validate_benchmarks, validate_gpus,
    validate_searchers, PlanError,
};

/// Bootstrap resamples per cell CI (fixed: part of the report's
/// deterministic byte contract).
const BOOTSTRAP_ITERS: usize = 200;
/// Cell confidence level for the tests-to-wp median CI.
const BOOTSTRAP_CONFIDENCE: f64 = 0.95;

/// A benchmark × source-GPU × target-GPU × searcher × seed job matrix.
#[derive(Debug, Clone)]
pub struct TransferPlan {
    pub benchmarks: Vec<String>,
    /// GPUs the model (prediction matrix) is built from.
    pub source_gpus: Vec<String>,
    /// GPUs the search actually runs on.
    pub target_gpus: Vec<String>,
    pub searchers: Vec<String>,
    /// Seeded repetitions per (benchmark, source, target, searcher).
    pub seeds: usize,
    /// Base seed every per-job RNG stream is derived from.
    pub base_seed: u64,
    /// Per-job cap on empirical tests (jobs also stop early at 1.1× of
    /// the target's exhaustive best, like [`super::ExperimentPlan`]).
    pub max_tests: usize,
    /// The "within X of the oracle best" fraction reported per job
    /// (0.10 = the paper's well-performing threshold).
    pub within_frac: f64,
    /// Embed per-cell aggregated best-so-far step curves in the report.
    pub include_curves: bool,
}

impl TransferPlan {
    /// The paper's §4.4 hardware-portability matrix: 5 benchmarks ×
    /// 4×4 GPU pairs × {random, profile} × `seeds` repetitions.
    pub fn full(seeds: usize, base_seed: u64) -> Self {
        let gpus: Vec<String> = ["gtx680", "gtx750", "gtx1070", "rtx2080"]
            .map(String::from)
            .to_vec();
        TransferPlan {
            benchmarks: ["coulomb", "transpose", "gemm", "nbody", "convolution"]
                .map(String::from)
                .to_vec(),
            source_gpus: gpus.clone(),
            target_gpus: gpus,
            searchers: vec!["random".into(), "profile".into()],
            seeds,
            base_seed,
            max_tests: 1000,
            within_frac: 0.10,
            include_curves: false,
        }
    }

    /// The CI smoke matrix: 2 benchmarks × 2×2 GPU pairs (crossing the
    /// Pascal/Turing counter-generation boundary in both directions,
    /// plus both same-GPU diagonals) × 2 searchers × 2 seeds.
    pub fn smoke(base_seed: u64) -> Self {
        let pair: Vec<String> = vec!["gtx1070".into(), "rtx2080".into()];
        TransferPlan {
            benchmarks: vec!["coulomb".into(), "transpose".into()],
            source_gpus: pair.clone(),
            target_gpus: pair,
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed,
            max_tests: 80,
            within_frac: 0.10,
            include_curves: true,
        }
    }

    /// Expand into jobs, in deterministic plan order.
    pub fn jobs(&self) -> Vec<TransferJobSpec> {
        let mut out = Vec::new();
        for b in &self.benchmarks {
            for s in &self.source_gpus {
                for t in &self.target_gpus {
                    for sr in &self.searchers {
                        for lane in 0..self.seeds {
                            out.push(TransferJobSpec {
                                benchmark: b.clone(),
                                source_gpu: s.clone(),
                                target_gpu: t.clone(),
                                searcher: sr.clone(),
                                lane,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Resolve every name up front (shared helpers with
    /// [`super::ExperimentPlan`]) so job closures cannot fail later —
    /// in particular, a benchmark with no recordable space is a typed
    /// [`PlanError::NoRecording`], not a silent multi-hour hang.
    pub fn validate(&self) -> Result<(), PlanError> {
        validate_benchmarks("benchmarks", &self.benchmarks)?;
        validate_gpus("source_gpus", &self.source_gpus)?;
        validate_gpus("target_gpus", &self.target_gpus)?;
        validate_searchers("searchers", &self.searchers)?;
        if self.seeds == 0 {
            return Err(PlanError::EmptyAxis("seeds"));
        }
        Ok(())
    }

    fn to_json(&self) -> Value {
        obj(vec![
            ("benchmarks", Value::from(self.benchmarks.clone())),
            ("source_gpus", Value::from(self.source_gpus.clone())),
            ("target_gpus", Value::from(self.target_gpus.clone())),
            ("searchers", Value::from(self.searchers.clone())),
            ("seeds", Value::from(self.seeds)),
            // string for the same 2^53 reason as ExperimentPlan
            ("base_seed", Value::from(self.base_seed.to_string())),
            ("max_tests", Value::from(self.max_tests)),
            ("within_frac", Value::from(self.within_frac)),
        ])
    }
}

/// One independent job of the transfer matrix.
#[derive(Debug, Clone)]
pub struct TransferJobSpec {
    pub benchmark: String,
    pub source_gpu: String,
    pub target_gpu: String,
    pub searcher: String,
    /// Repetition index within the cell.
    pub lane: usize,
}

impl TransferJobSpec {
    /// The job's private RNG stream seed. Keyed by the *target* GPU
    /// only (not the source): identical to
    /// [`super::JobSpec::rng_seed`] for the same (benchmark, GPU,
    /// searcher, lane), which is what makes same-GPU transfer cells
    /// reproduce `ExperimentPlan` results bit-for-bit, and which
    /// pairs every source column on common random numbers.
    ///
    /// Names are hashed *verbatim* as stream tags: alias spellings
    /// (`GTX-1070` vs `gtx1070`) would produce different streams, so
    /// the CLI canonicalizes axis names before building the plan.
    pub fn rng_seed(&self, base_seed: u64) -> u64 {
        stream_seed(
            base_seed,
            &[&self.benchmark, &self.target_gpu, &self.searcher],
            self.lane as u64,
        )
    }
}

/// Outcome of one transfer job.
#[derive(Debug, Clone)]
pub struct TransferJobResult {
    pub spec: TransferJobSpec,
    pub best_ms: f64,
    /// Best found, as a multiple of the target's exhaustive best.
    pub over_oracle: f64,
    /// Empirical tests performed.
    pub tests: usize,
    pub profiled_tests: usize,
    /// 1-based test count reaching 1.1× of the target's best, if any.
    /// Deliberately computed from the same threshold as the budget's
    /// early stop (and as [`super::ExperimentPlan`]'s `tests_to_wp`) —
    /// the fixed well-performing contract of §4.1.
    pub tests_to_wp: Option<usize>,
    /// 1-based test count reaching `(1 + within_frac)×` of the
    /// target's best, if any — the *plan-configurable* slack. With the
    /// default `within_frac = 0.10` this coincides with `tests_to_wp`
    /// (1.0 + 0.10 rounds to the same f64 as 1.1); the two fields stay
    /// separate because `tests_to_wp` is pinned to the §4.1 contract
    /// while this one follows the plan.
    pub steps_to_within: Option<usize>,
    /// Simulated tuning cost, seconds.
    pub cost_s: f64,
    /// Per-step runtimes, kept for curve aggregation (never serialized
    /// per job — cells serialize aggregated curves). Empty unless the
    /// plan asked for curves: a full 16k-job matrix would otherwise
    /// retain ~100 MB of traces it never reads (the per-job statistics
    /// above are computed before the trace is dropped).
    pub runtimes: Vec<f64>,
}

/// Shared per-(benchmark, source, target) context.
struct TransferCell {
    rec_target: Arc<RecordedSpace>,
    gpu_target: GpuSpec,
    /// Source-GPU model matrix — the same `Arc` for every target cell
    /// and repetition when the counter generations agree; a restricted
    /// copy (intersection of the two generations' counters) otherwise.
    matrix: Arc<PredictionMatrix>,
    inst_reaction: f64,
    /// 1.1× early-stop threshold on the target.
    thr_ms: f64,
    oracle_best_ms: f64,
}

fn run_transfer_job(
    spec: &TransferJobSpec,
    plan: &TransferPlan,
    cell: &TransferCell,
) -> TransferJobResult {
    let choice =
        searcher_choice(&spec.searcher, &cell.matrix, cell.inst_reaction);
    // Early-stop at the *stricter* of the 1.1× well-performing
    // contract and the plan's within_frac, so a sub-10% slack stays
    // measurable instead of being censored by the 1.1× stop. For
    // within_frac >= 0.10 (every shipped plan) this is bit-identical
    // to oracle × 1.1 (1.0 + 0.10 rounds to the same f64 as 1.1), so
    // the same-GPU ExperimentPlan reproduction contract is unaffected;
    // a stricter plan trades that contract for an unbiased metric.
    let stop_ms = cell
        .thr_ms
        .min(cell.oracle_best_ms * (1.0 + plan.within_frac));
    let result = Tuner::replay(
        Arc::clone(&cell.rec_target),
        cell.gpu_target.clone(),
        CostModel::default(),
    )
    .with_budget(Budget::until(stop_ms, plan.max_tests))
    .with_seed(spec.rng_seed(plan.base_seed))
    .run(choice);

    let runtimes: Vec<f64> =
        result.trace.steps.iter().map(|s| s.runtime_ms).collect();
    TransferJobResult {
        spec: spec.clone(),
        best_ms: result.best_ms,
        over_oracle: result.best_ms / cell.oracle_best_ms,
        tests: result.tests,
        profiled_tests: result.profiled_tests,
        tests_to_wp: result.trace.tests_to_threshold(cell.thr_ms),
        steps_to_within: steps_to_within(
            &runtimes,
            cell.oracle_best_ms,
            plan.within_frac,
        ),
        cost_s: result.cost_s,
        runtimes: if plan.include_curves {
            runtimes
        } else {
            Vec::new()
        },
    }
}

/// Aggregated statistics for one (benchmark, source, target, searcher)
/// cell: per-cell medians with bootstrap confidence intervals.
#[derive(Debug, Clone)]
pub struct TransferAggregate {
    pub benchmark: String,
    pub source_gpu: String,
    pub target_gpu: String,
    pub searcher: String,
    pub runs: usize,
    pub wp_hits: usize,
    pub median_tests_to_wp: f64,
    /// 95% percentile-bootstrap CI around the median above.
    pub tests_to_wp_ci: (f64, f64),
    pub mean_tests_to_wp: f64,
    pub median_best_over_oracle: f64,
    pub mean_cost_s: f64,
    /// Counter abbreviations dropped by the cross-generation
    /// restriction (empty for same-generation pairs).
    pub dropped_counters: Vec<String>,
}

/// A completed transfer plan: per-job results in plan order, plus the
/// per-cell counter-restriction record.
pub struct TransferReport {
    pub plan: TransferPlan,
    pub results: Vec<TransferJobResult>,
    /// (benchmark, source, target) → dropped counter abbreviations.
    pub dropped: BTreeMap<(String, String, String), Vec<String>>,
    /// Per-cell aggregates (sorted key order), computed once at
    /// construction — serialization, the CLI summary and the table
    /// renderer all read this cache instead of re-running the
    /// per-cell bootstrap.
    aggregates: Vec<TransferAggregate>,
}

/// Report cell key: (benchmark, source, target, searcher).
type CellKey = (String, String, String, String);

/// The one per-cell group-by shared by aggregates and curves, so the
/// two can never partition the same report differently.
fn group_by_cell<'a, T>(
    results: &'a [TransferJobResult],
    value: impl Fn(&'a TransferJobResult) -> T,
) -> BTreeMap<CellKey, Vec<T>> {
    let mut cells: BTreeMap<CellKey, Vec<T>> = BTreeMap::new();
    for r in results {
        cells
            .entry((
                r.spec.benchmark.clone(),
                r.spec.source_gpu.clone(),
                r.spec.target_gpu.clone(),
                r.spec.searcher.clone(),
            ))
            .or_default()
            .push(value(r));
    }
    cells
}

/// Group `results` into per-cell aggregates, in sorted key order.
fn compute_aggregates(
    plan: &TransferPlan,
    results: &[TransferJobResult],
    dropped: &BTreeMap<(String, String, String), Vec<String>>,
) -> Vec<TransferAggregate> {
    group_by_cell(results, |r| r)
        .into_iter()
        .map(|((benchmark, source_gpu, target_gpu, searcher), rs)| {
            // unreached-threshold runs count their full length,
            // like ExperimentPlan's aggregates
            let steps: Vec<f64> = rs
                .iter()
                .map(|r| r.tests_to_wp.unwrap_or(r.tests) as f64)
                .collect();
            let overs: Vec<f64> = rs.iter().map(|r| r.over_oracle).collect();
            let costs: Vec<f64> = rs.iter().map(|r| r.cost_s).collect();
            let ci_seed = stream_seed(
                plan.base_seed,
                &[&benchmark, &source_gpu, &target_gpu, &searcher, "ci"],
                0,
            );
            let tests_to_wp_ci = bootstrap_ci(
                &steps,
                BOOTSTRAP_ITERS,
                BOOTSTRAP_CONFIDENCE,
                ci_seed,
            );
            let cell_dropped = dropped
                .get(&(
                    benchmark.clone(),
                    source_gpu.clone(),
                    target_gpu.clone(),
                ))
                .cloned()
                .unwrap_or_default();
            TransferAggregate {
                runs: rs.len(),
                wp_hits: rs
                    .iter()
                    .filter(|r| r.tests_to_wp.is_some())
                    .count(),
                median_tests_to_wp: median(&steps),
                tests_to_wp_ci,
                mean_tests_to_wp: mean(&steps),
                median_best_over_oracle: median(&overs),
                mean_cost_s: mean(&costs),
                dropped_counters: cell_dropped,
                benchmark,
                source_gpu,
                target_gpu,
                searcher,
            }
        })
        .collect()
}

impl TransferReport {
    /// Assemble a report, computing the per-cell aggregates once.
    pub fn new(
        plan: TransferPlan,
        results: Vec<TransferJobResult>,
        dropped: BTreeMap<(String, String, String), Vec<String>>,
    ) -> Self {
        let aggregates = compute_aggregates(&plan, &results, &dropped);
        TransferReport {
            plan,
            results,
            dropped,
            aggregates,
        }
    }

    /// Per-cell aggregates, in sorted key order (cached).
    pub fn aggregate_rows(&self) -> &[TransferAggregate] {
        &self.aggregates
    }

    /// Per-cell aggregated best-so-far step curves (sorted key order).
    /// Curves are empty when the plan did not ask for them — per-job
    /// traces are dropped at job completion in that case.
    pub fn step_curves(&self) -> Vec<(CellKey, Vec<StepCurvePoint>)> {
        // borrow the per-job traces: cloning 16k × 1000-step traces
        // per call would dwarf the aggregation itself
        group_by_cell(&self.results, |r| r.runtimes.as_slice())
            .into_iter()
            .map(|(k, runs)| (k, aggregate_step_curves(&runs)))
            .collect()
    }

    /// Deterministic JSON document: plan echo, per-job records (plan
    /// order), per-cell aggregates and (optionally) step curves.
    pub fn to_json(&self) -> Value {
        let jobs: Vec<Value> = self
            .results
            .iter()
            .map(|r| {
                obj(vec![
                    ("benchmark", Value::from(r.spec.benchmark.clone())),
                    ("source_gpu", Value::from(r.spec.source_gpu.clone())),
                    ("target_gpu", Value::from(r.spec.target_gpu.clone())),
                    ("searcher", Value::from(r.spec.searcher.clone())),
                    ("lane", Value::from(r.spec.lane)),
                    ("best_ms", Value::from(r.best_ms)),
                    ("over_oracle", Value::from(r.over_oracle)),
                    ("tests", Value::from(r.tests)),
                    ("profiled_tests", Value::from(r.profiled_tests)),
                    (
                        "tests_to_wp",
                        r.tests_to_wp.map(Value::from).unwrap_or(Value::Null),
                    ),
                    (
                        "steps_to_within",
                        r.steps_to_within
                            .map(Value::from)
                            .unwrap_or(Value::Null),
                    ),
                    ("cost_s", Value::from(r.cost_s)),
                ])
            })
            .collect();

        let aggregates: Vec<Value> = self
            .aggregate_rows()
            .iter()
            .map(|a| {
                obj(vec![
                    ("benchmark", Value::from(a.benchmark.clone())),
                    ("source_gpu", Value::from(a.source_gpu.clone())),
                    ("target_gpu", Value::from(a.target_gpu.clone())),
                    ("searcher", Value::from(a.searcher.clone())),
                    ("runs", Value::from(a.runs)),
                    ("wp_hits", Value::from(a.wp_hits)),
                    (
                        "median_tests_to_wp",
                        Value::from(a.median_tests_to_wp),
                    ),
                    ("tests_to_wp_ci_lo", Value::from(a.tests_to_wp_ci.0)),
                    ("tests_to_wp_ci_hi", Value::from(a.tests_to_wp_ci.1)),
                    ("mean_tests_to_wp", Value::from(a.mean_tests_to_wp)),
                    (
                        "median_best_over_oracle",
                        Value::from(a.median_best_over_oracle),
                    ),
                    ("mean_cost_s", Value::from(a.mean_cost_s)),
                    (
                        "dropped_counters",
                        Value::from(a.dropped_counters.clone()),
                    ),
                ])
            })
            .collect();

        let mut fields = vec![
            ("schema", Value::from("pcat-transfer-report/v1")),
            ("plan", self.plan.to_json()),
            ("jobs", Value::Arr(jobs)),
            ("aggregates", Value::Arr(aggregates)),
        ];
        if self.plan.include_curves {
            let curves: Vec<Value> = self
                .step_curves()
                .into_iter()
                .map(|((b, s, t, sr), pts)| {
                    obj(vec![
                        ("benchmark", Value::from(b)),
                        ("source_gpu", Value::from(s)),
                        ("target_gpu", Value::from(t)),
                        ("searcher", Value::from(sr)),
                        (
                            "points",
                            Value::Arr(
                                pts.iter()
                                    .map(|p| {
                                        obj(vec![
                                            ("step", Value::from(p.step)),
                                            (
                                                "median_ms",
                                                Value::from(p.median_ms),
                                            ),
                                            (
                                                "mean_ms",
                                                Value::from(p.mean_ms),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect();
            fields.push(("curves", Value::Arr(curves)));
        }
        obj(fields)
    }

    /// The canonical byte representation compared by the smoke gate.
    pub fn to_pretty_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty(1);
        s.push('\n');
        s
    }

    pub fn write_to(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_pretty_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// One summary line per aggregate cell, for CLI output.
    pub fn summary_lines(&self) -> Vec<String> {
        self.aggregate_rows()
            .iter()
            .map(|a| {
                format!(
                    "{:<12} {:>8} -> {:<8} {:<14} steps {:>6.1} \
                     [{:>6.1}, {:>6.1}]  best {:>5.2}x oracle{}",
                    a.benchmark,
                    a.source_gpu,
                    a.target_gpu,
                    a.searcher,
                    a.median_tests_to_wp,
                    a.tests_to_wp_ci.0,
                    a.tests_to_wp_ci.1,
                    a.median_best_over_oracle,
                    if a.dropped_counters.is_empty() {
                        String::new()
                    } else {
                        format!("  (dropped {})", a.dropped_counters.join(","))
                    },
                )
            })
            .collect()
    }
}

/// Execute a transfer plan with up to `jobs` worker threads.
///
/// Three deterministic pre-passes on the shared pool precede the
/// fan-out: (1) record every distinct (benchmark, GPU) space once (the
/// process cache dedupes against everything else in the process);
/// (2) build every distinct (benchmark, source) prediction matrix once;
/// (3) assemble per-(benchmark, source, target) cells, reusing the
/// source matrix `Arc` whenever the counter generations agree and one
/// restricted copy per distinct target generation when they do not.
/// The fan-out then only replays cached data, so worker count affects
/// wall-clock and nothing else.
pub fn run_transfer_plan(
    plan: &TransferPlan,
    jobs: usize,
) -> Result<TransferReport> {
    plan.validate()?;

    // distinct GPU axis (sources ∪ targets), order-preserving
    let mut gpu_axis: Vec<String> = Vec::new();
    for g in plan.source_gpus.iter().chain(&plan.target_gpus) {
        if !gpu_axis.contains(g) {
            gpu_axis.push(g.clone());
        }
    }

    // (1) recordings
    let rec_keys: Vec<(String, String)> = plan
        .benchmarks
        .iter()
        .flat_map(|b| gpu_axis.iter().map(move |g| (b.clone(), g.clone())))
        .collect();
    let recs_v = pool::par_map_jobs(rec_keys.len(), jobs, &|i| {
        let (b, g) = &rec_keys[i];
        let bench = benchmarks::by_name(b).expect("validated");
        let gpu = GpuSpec::by_name(g).expect("validated");
        cached_space(bench.as_ref(), &gpu, &bench.default_input())
    });
    let recs: BTreeMap<(String, String), Arc<RecordedSpace>> =
        rec_keys.into_iter().zip(recs_v).collect();

    // (2) one prediction matrix per distinct (benchmark, source)
    let mut src_keys: Vec<(String, String)> = Vec::new();
    for b in &plan.benchmarks {
        for s in &plan.source_gpus {
            let k = (b.clone(), s.clone());
            if !src_keys.contains(&k) {
                src_keys.push(k);
            }
        }
    }
    let mats_v = pool::par_map_jobs(src_keys.len(), jobs, &|i| {
        let rec = &recs[&src_keys[i]];
        Arc::new(PredictionMatrix::from_recorded(rec))
    });
    let matrices: BTreeMap<(String, String), Arc<PredictionMatrix>> =
        src_keys.into_iter().zip(mats_v).collect();

    // (3) cells
    let mut cells: BTreeMap<(String, String, String), TransferCell> =
        BTreeMap::new();
    let mut dropped: BTreeMap<(String, String, String), Vec<String>> =
        BTreeMap::new();
    for b in &plan.benchmarks {
        let bench = benchmarks::by_name(b).expect("validated");
        let inst_reaction = if bench.instruction_bound() {
            crate::expert::INST_BOUND_REACTION
        } else {
            crate::expert::DEFAULT_INST_REACTION
        };
        for s in &plan.source_gpus {
            let gpu_source = GpuSpec::by_name(s).expect("validated");
            let src_set = gpu_source.counter_set();
            let base = &matrices[&(b.clone(), s.clone())];
            // restriction depends only on the target's counter
            // generation, so all cross-generation targets of one
            // source share a single restricted Arc instead of cloning
            // the dense data per cell
            let mut restricted: Vec<(CounterSet, Arc<PredictionMatrix>)> =
                Vec::new();
            for t in &plan.target_gpus {
                let key = (b.clone(), s.clone(), t.clone());
                if cells.contains_key(&key) {
                    continue;
                }
                let gpu_target = GpuSpec::by_name(t).expect("validated");
                let tgt_set = gpu_target.counter_set();
                // owned lookup first: an `if let` on the cache's iter
                // would hold the borrow across the arm that pushes
                let cached = restricted
                    .iter()
                    .find(|(set, _)| *set == tgt_set)
                    .map(|(_, m)| Arc::clone(m));
                let matrix = if src_set == tgt_set {
                    Arc::clone(base)
                } else if let Some(m) = cached {
                    m
                } else {
                    let m = Arc::new(
                        base.as_ref()
                            .clone()
                            .restricted_to(src_set, tgt_set),
                    );
                    restricted.push((tgt_set, Arc::clone(&m)));
                    m
                };
                let drops: Vec<String> = matrix
                    .dropped_counters()
                    .iter()
                    .map(|c| c.abbr().to_string())
                    .collect();
                let rec_target = Arc::clone(&recs[&(b.clone(), t.clone())]);
                let oracle_best_ms = rec_target.best_time();
                dropped.insert(key.clone(), drops);
                cells.insert(
                    key,
                    TransferCell {
                        rec_target,
                        gpu_target,
                        matrix,
                        inst_reaction,
                        thr_ms: oracle_best_ms * 1.1,
                        oracle_best_ms,
                    },
                );
            }
        }
    }

    // Fan-out with source-axis deduplication: only searchers that
    // read the source matrix ([`reads_model`], kept next to the
    // dispatch in plan.rs) can differ across sources — for every
    // other searcher a job's outcome is a pure function of
    // (benchmark, target, searcher, lane) (the RNG stream
    // deliberately ignores the source), so the full 4×4 matrix would
    // re-run each random baseline identically once per source column.
    // Run each distinct job once and replicate the result into every
    // source row (same values, relabelled spec) — byte-identical to
    // the naive fan-out.
    let specs = plan.jobs();
    let mut unique: Vec<usize> = Vec::new();
    let mut run_of: Vec<usize> = Vec::with_capacity(specs.len());
    let mut seen: BTreeMap<(String, String, String, usize), usize> =
        BTreeMap::new();
    for (i, s) in specs.iter().enumerate() {
        if reads_model(&s.searcher) {
            run_of.push(unique.len());
            unique.push(i);
            continue;
        }
        let key = (
            s.benchmark.clone(),
            s.target_gpu.clone(),
            s.searcher.clone(),
            s.lane,
        );
        if let Some(&u) = seen.get(&key) {
            run_of.push(u);
        } else {
            seen.insert(key, unique.len());
            run_of.push(unique.len());
            unique.push(i);
        }
    }
    let ran = pool::par_map_jobs(unique.len(), jobs, &|u| {
        let spec = &specs[unique[u]];
        let cell = &cells[&(
            spec.benchmark.clone(),
            spec.source_gpu.clone(),
            spec.target_gpu.clone(),
        )];
        run_transfer_job(spec, plan, cell)
    });
    let results: Vec<TransferJobResult> = specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let mut r = ran[run_of[i]].clone();
            r.spec = spec.clone();
            r
        })
        .collect();

    Ok(TransferReport::new(plan.clone(), results, dropped))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TransferPlan {
        TransferPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpus: vec!["gtx1070".into(), "rtx2080".into()],
            target_gpus: vec!["gtx1070".into()],
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 5,
            max_tests: 40,
            within_frac: 0.10,
            include_curves: true,
        }
    }

    #[test]
    fn plan_expansion_order_and_count() {
        let plan = TransferPlan::smoke(0);
        let jobs = plan.jobs();
        assert_eq!(jobs.len(), 2 * 2 * 2 * 2 * 2);
        assert_eq!(jobs[0].benchmark, "coulomb");
        assert_eq!(jobs[0].source_gpu, "gtx1070");
        assert_eq!(jobs[0].target_gpu, "gtx1070");
        assert_eq!(jobs[0].searcher, "random");
        assert_eq!(jobs[1].lane, 1);
        assert_eq!(jobs[2].searcher, "profile");
        assert_eq!(jobs[4].target_gpu, "rtx2080");
    }

    #[test]
    fn validate_uses_shared_typed_errors() {
        let mut plan = tiny();
        plan.source_gpus = vec![];
        assert_eq!(
            plan.validate(),
            Err(PlanError::EmptyAxis("source_gpus"))
        );
        let mut plan = tiny();
        plan.target_gpus = vec!["titan".into()];
        assert_eq!(plan.validate(), Err(PlanError::UnknownGpu("titan".into())));
        let mut plan = tiny();
        plan.benchmarks = vec!["gemm-full".into()];
        assert_eq!(
            plan.validate(),
            Err(PlanError::NoRecording("gemm-full".into()))
        );
        assert!(tiny().validate().is_ok());
        // and the runner surfaces it before recording anything
        let mut plan = tiny();
        plan.benchmarks = vec!["gemm-full".into()];
        assert!(run_transfer_plan(&plan, 2).is_err());
    }

    #[test]
    fn seed_streams_ignore_source_gpu() {
        let plan = tiny();
        let jobs = plan.jobs();
        // same (benchmark, target, searcher, lane), different source
        let a = jobs
            .iter()
            .find(|j| j.source_gpu == "gtx1070" && j.searcher == "profile")
            .unwrap();
        let b = jobs
            .iter()
            .find(|j| {
                j.source_gpu == "rtx2080"
                    && j.searcher == "profile"
                    && j.lane == a.lane
            })
            .unwrap();
        assert_eq!(a.rng_seed(5), b.rng_seed(5));
        // …but distinct across searchers and lanes
        assert_ne!(
            stream_seed(5, &["coulomb", "gtx1070", "random"], 0),
            stream_seed(5, &["coulomb", "gtx1070", "profile"], 0)
        );
    }

    #[test]
    fn serial_and_parallel_runs_are_byte_identical() {
        let plan = tiny();
        let a = run_transfer_plan(&plan, 1).unwrap().to_pretty_string();
        let b = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
        assert_eq!(a, b);
        assert!(a.contains("\"schema\": \"pcat-transfer-report/v1\""));
        assert!(a.contains("\"curves\""));
    }

    #[test]
    fn cross_generation_cells_record_dropped_counters() {
        let plan = tiny();
        let report = run_transfer_plan(&plan, 4).unwrap();
        // rtx2080 (VoltaPlus) model steering gtx1070 (PreVolta): LOC_O
        // dropped; same-generation (and same-GPU) cell: nothing dropped
        let rows = report.aggregate_rows();
        let cross = rows
            .iter()
            .find(|a| a.source_gpu == "rtx2080" && a.searcher == "profile")
            .unwrap();
        assert_eq!(cross.dropped_counters, vec!["LOC_O".to_string()]);
        let same = rows
            .iter()
            .find(|a| a.source_gpu == "gtx1070" && a.searcher == "profile")
            .unwrap();
        assert!(same.dropped_counters.is_empty());
    }

    #[test]
    fn matrix_independent_searchers_are_shared_across_sources() {
        // random never reads the source model and its RNG stream
        // ignores the source axis, so every source column must carry
        // identical values while keeping its own spec label (the
        // deduplicated fan-out replicates instead of re-running)
        let plan = tiny();
        let report = run_transfer_plan(&plan, 4).unwrap();
        // results come back in plan order with faithful spec labels
        for (spec, r) in plan.jobs().iter().zip(&report.results) {
            assert_eq!(spec.source_gpu, r.spec.source_gpu);
            assert_eq!(spec.searcher, r.spec.searcher);
            assert_eq!(spec.lane, r.spec.lane);
        }
        for r in report
            .results
            .iter()
            .filter(|r| r.spec.searcher == "random")
        {
            let twin = report
                .results
                .iter()
                .find(|o| {
                    o.spec.searcher == "random"
                        && o.spec.benchmark == r.spec.benchmark
                        && o.spec.target_gpu == r.spec.target_gpu
                        && o.spec.lane == r.spec.lane
                        && o.spec.source_gpu != r.spec.source_gpu
                })
                .expect("two source columns in the tiny plan");
            assert_eq!(r.best_ms, twin.best_ms);
            assert_eq!(r.tests, twin.tests);
            assert_eq!(r.cost_s, twin.cost_s);
        }
    }

    #[test]
    fn traces_are_dropped_when_curves_are_off() {
        // the full 16k-job matrix must not retain ~100 MB of per-step
        // traces it never serializes: runtimes are kept only when the
        // plan asks for curves, and every per-job statistic is already
        // computed before the trace is dropped
        let mut plan = tiny();
        plan.include_curves = false;
        let report = run_transfer_plan(&plan, 2).unwrap();
        assert!(report.results.iter().all(|r| r.runtimes.is_empty()));
        assert!(report
            .step_curves()
            .iter()
            .all(|(_, pts)| pts.is_empty()));
        let text = report.to_pretty_string();
        assert!(!text.contains("\"curves\""));
        for r in &report.results {
            assert!(r.best_ms.is_finite());
            assert!(r.tests >= 1);
        }
    }

    #[test]
    fn aggregates_carry_bootstrap_cis_around_the_median() {
        let plan = tiny();
        let report = run_transfer_plan(&plan, 4).unwrap();
        for a in report.aggregate_rows() {
            assert_eq!(a.runs, 2);
            let (lo, hi) = a.tests_to_wp_ci;
            assert!(
                lo <= a.median_tests_to_wp && a.median_tests_to_wp <= hi,
                "CI [{lo}, {hi}] excludes median {}",
                a.median_tests_to_wp
            );
        }
    }
}
