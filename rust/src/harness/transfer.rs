//! Portability evaluation: train-on-(GPU, input)-A / tune-on-(GPU,
//! input)-B.
//!
//! The paper's headline claim is *portability* — a counter-based model
//! sampled on one (GPU, input) pair steers the search on different,
//! even unseen, hardware **and problem inputs** (§4.4 Table 6, §4.6
//! Table 7). [`TransferPlan`] turns both axes into one job matrix: the
//! full cross product `(benchmark × source (GPU, input) × target
//! (GPU, input) × searcher × seed)`, where the profile searcher's
//! [`PredictionMatrix`] is built from the **source** endpoint's
//! recording while the search itself replays the **target** endpoint's
//! recording.
//!
//! The source side's matrix comes from a pluggable [`ModelSource`]:
//! [`ModelSource::Oracle`] reads the exact recorded counters (the
//! paper's §4.3 setting isolating expert-system quality from model
//! error), [`ModelSource::Tree`] trains per-counter
//! [`DecisionTreeModel`]s on the source recording (§3.4.2 — the model
//! the paper actually ships) and densifies their predictions through
//! [`PredictionMatrix::build`]. The tree source trains on
//! `train_fraction` of the recording (a deterministic stratified
//! sample, [`crate::model::stratified_indices`]) — the paper's §5
//! partial-exploration setting — and every source endpoint's model
//! quality (per-counter MAE/RMSE/R² vs the held-out remainder) is
//! computed once in the pre-pass and embedded in the schema-v3 report
//! as [`EndpointQuality`].
//!
//! Sharing discipline (§Perf): each `(benchmark, source GPU, source
//! input)` model matrix is built (and, for the tree source, trained)
//! exactly once and shared via `Arc` across *every* target cell and
//! seed-repetition that consumes it; recordings come from the
//! process-wide space cache, so each `(benchmark, GPU, input)` space
//! is enumerated once per process no matter how many cells touch it.
//!
//! Counter-generation mismatches (pre-Volta source vs Volta+ target or
//! vice versa) are handled by restricting the matrix to the counters
//! both generations support ([`PredictionMatrix::restricted_to`]):
//! the mismatched ΔPC components are dropped from scoring — a
//! documented, regression-tested fallback, never a panic. The
//! restriction applies **iff the two generations differ**: a
//! same-generation pair (including every same-GPU diagonal cell)
//! shares one self-consistent metric set and scores it in full, which
//! keeps same-(GPU, input) oracle transfer cells bit-identical to the
//! plain [`ExperimentPlan`] path for identical seeds. Input mismatches
//! need no analogous fallback — every benchmark input shares one
//! tuning space, so a source matrix always covers the target's
//! configurations; an input *name* no benchmark defines is a typed
//! [`PlanError::UnknownInput`] at validation, never a panic mid-plan.
//!
//! **Determinism contract** (same as [`ExperimentPlan`]): a job's
//! result is a pure function of the plan and its coordinates. The RNG
//! stream is keyed by `(base seed, benchmark, target GPU, target
//! input, searcher, lane)` — deliberately *not* by the source endpoint
//! or the model kind, so (a) same-(GPU, default input) cells reproduce
//! `ExperimentPlan` runs exactly and (b) different sources and model
//! kinds are compared on identical search randomness (common random
//! numbers: the only varying factor in a source column is the model).
//! The default target input contributes **no** stream tag — that is
//! what collapses the diagonal onto `ExperimentPlan`'s streams. Tree
//! training draws from its own stream keyed by the source coordinates,
//! so worker count and scheduling never touch it. Serial and parallel
//! executions produce byte-identical `TRANSFER_REPORT.json` documents;
//! CI smoke-gates that for both model sources.
//!
//! [`ExperimentPlan`]: super::ExperimentPlan
//! [`DecisionTreeModel`]: crate::model::DecisionTreeModel

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::benchmarks::{self, cached_space, resolve_input, Input};
use crate::coordinator::Tuner;
use crate::counters::CounterSet;
use crate::gpusim::GpuSpec;
use crate::model::{
    dataset_from_indices, dataset_full, sample_size, stratified_indices,
    DecisionTreeModel, PredictionMatrix, MODELED_COUNTERS,
};
use crate::searcher::{
    Budget, CellCtx, CostModel, FaultModel, FaultProfile, FaultStats,
    FaultyEnv, ModelCtx, ReplayEnv, SearcherSpec,
};
use crate::tuning::RecordedSpace;
use crate::util::json::{obj, Value};
use crate::util::pool;
use crate::util::rng::{stream_seed, Rng};
use crate::util::stats::{bootstrap_ci, mae, mean, median, r_squared, rmse};

use super::convergence::{
    aggregate_step_curves, aggregate_time_curves, steps_to_within,
    ConvergencePoint, StepCurvePoint,
};
use super::plan::{
    reads_model, resolve_input_axis, validate_fraction, validate_gpus,
    validate_inputs, validate_searchers, validate_trainable_benchmarks,
    PlanError,
};
use super::registry;

/// Bootstrap resamples per cell CI (fixed: part of the report's
/// deterministic byte contract).
const BOOTSTRAP_ITERS: usize = 200;
/// Cell confidence level for the tests-to-wp median CI.
const BOOTSTRAP_CONFIDENCE: f64 = 0.95;
/// Grid resolution of the per-cell time-domain curves. Fixed: part of
/// the report's deterministic byte contract.
const TIME_GRID_POINTS: usize = 32;

/// Where the source side's prediction matrix comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelSource {
    /// Exact recorded counters of the source endpoint (§4.3: isolates
    /// expert-system quality from model error).
    Oracle,
    /// Per-counter decision trees trained on the source recording
    /// (§3.4.2: the trained-model setting the paper's portability
    /// tables actually use).
    Tree,
}

impl ModelSource {
    /// CLI/report spelling.
    pub fn name(&self) -> &'static str {
        match self {
            ModelSource::Oracle => "oracle",
            ModelSource::Tree => "tree",
        }
    }

    /// Parse a CLI spelling (`--model {oracle,tree}`).
    pub fn parse(s: &str) -> Option<ModelSource> {
        match s.to_ascii_lowercase().as_str() {
            "oracle" => Some(ModelSource::Oracle),
            "tree" | "decision_tree" | "decision-tree" => {
                Some(ModelSource::Tree)
            }
            _ => None,
        }
    }
}

/// A benchmark × source-(GPU, input) × target-(GPU, input) × searcher
/// × seed job matrix.
#[derive(Debug, Clone)]
pub struct TransferPlan {
    pub benchmarks: Vec<String>,
    /// GPUs the model (prediction matrix) is built from.
    pub source_gpus: Vec<String>,
    /// Input selectors on the model side: `"default"`, `"alt"`, or a
    /// concrete input name from [`crate::benchmarks::Benchmark::inputs`].
    pub source_inputs: Vec<String>,
    /// GPUs the search actually runs on.
    pub target_gpus: Vec<String>,
    /// Input selectors on the tuning side (same vocabulary).
    pub target_inputs: Vec<String>,
    /// How the source matrix is built (exact PCs vs trained trees).
    pub model: ModelSource,
    /// Fraction of each source recording the tree source trains on
    /// (§5: the method only pays off when the source model works from
    /// a *partial* exploration). Sampling is stratified over the
    /// space, nested across fractions and keyed by the source
    /// endpoint's own RNG stream
    /// ([`crate::model::stratified_indices`]), so it is byte-identical
    /// across `--jobs`. `1.0` trains on the full recording —
    /// bit-for-bit the pre-fraction behaviour (no sampling randomness
    /// is consumed). The oracle source reads exact counters and
    /// ignores this knob. Must lie in `(0, 1]`
    /// ([`PlanError::InvalidFraction`] otherwise).
    pub train_fraction: f64,
    pub searchers: Vec<String>,
    /// Seeded repetitions per cell.
    pub seeds: usize,
    /// Base seed every per-job RNG stream is derived from.
    pub base_seed: u64,
    /// Per-job cap on empirical tests (jobs also stop early at 1.1× of
    /// the target's exhaustive best, like [`super::ExperimentPlan`]).
    pub max_tests: usize,
    /// The "within X of the oracle best" fraction reported per job
    /// (0.10 = the paper's well-performing threshold).
    pub within_frac: f64,
    /// Embed per-cell aggregated best-so-far curves (step **and** time
    /// domain) in the report.
    pub include_curves: bool,
    /// Fault/noise injection on the **target** environment
    /// ([`crate::searcher::FaultProfile`]). Streams are keyed by the
    /// target endpoint only (like [`rng_seed`]), so the source-axis
    /// deduplication stays valid and every source column faces the
    /// identical hostile hardware. `None` keeps the exact
    /// pre-fault-layer bytes.
    ///
    /// [`rng_seed`]: TransferJobSpec::rng_seed
    pub fault_profile: FaultProfile,
}

impl TransferPlan {
    /// The paper's §4.4 hardware-portability matrix: 5 benchmarks ×
    /// 4×4 GPU pairs (default inputs) × {random, profile} × `seeds`
    /// repetitions. Widen the input axes (`--inputs`) for the §4.6
    /// input-portability experiment.
    pub fn full(seeds: usize, base_seed: u64) -> Self {
        let gpus: Vec<String> = ["gtx680", "gtx750", "gtx1070", "rtx2080"]
            .map(String::from)
            .to_vec();
        TransferPlan {
            benchmarks: ["coulomb", "transpose", "gemm", "nbody", "convolution"]
                .map(String::from)
                .to_vec(),
            source_gpus: gpus.clone(),
            source_inputs: vec!["default".into()],
            target_gpus: gpus,
            target_inputs: vec!["default".into()],
            model: ModelSource::Oracle,
            train_fraction: 1.0,
            searchers: vec!["random".into(), "profile".into()],
            seeds,
            base_seed,
            max_tests: 1000,
            within_frac: 0.10,
            include_curves: false,
            fault_profile: FaultProfile::None,
        }
    }

    /// The CI smoke matrix: 2 benchmarks × 2×2 GPU pairs (crossing the
    /// Pascal/Turing counter-generation boundary in both directions,
    /// plus both same-GPU diagonals) × 2×2 input pairs (default and
    /// the first §4.6 variant, crossing the input axis both ways) ×
    /// 2 searchers × 2 seeds. The model source stays a knob: CI runs
    /// the gate once with `Oracle` and once with `Tree`.
    pub fn smoke(base_seed: u64) -> Self {
        let pair: Vec<String> = vec!["gtx1070".into(), "rtx2080".into()];
        TransferPlan {
            benchmarks: vec!["coulomb".into(), "transpose".into()],
            source_gpus: pair.clone(),
            source_inputs: vec!["default".into(), "alt".into()],
            target_gpus: pair,
            target_inputs: vec!["default".into(), "alt".into()],
            model: ModelSource::Oracle,
            train_fraction: 1.0,
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed,
            max_tests: 80,
            within_frac: 0.10,
            include_curves: true,
            fault_profile: FaultProfile::None,
        }
    }

    /// Does this plan inject faults? (Serialization gate, like
    /// [`super::ExperimentPlan::has_faults`].)
    pub fn has_faults(&self) -> bool {
        self.fault_profile.is_active()
    }

    /// Expand into jobs, in deterministic plan order. Input selectors
    /// are resolved to concrete input names here (via the same
    /// [`resolve_input`] the validator uses), so specs, report keys
    /// and RNG tags always carry canonical names no matter how the
    /// plan spelled the axis — and selectors that resolve to the
    /// *same* input (`--inputs default,2048x2048` on GEMM) collapse to
    /// one axis entry per benchmark, so a cell is never expanded (and
    /// its aggregate never double-counted) twice.
    pub fn jobs(&self) -> Vec<TransferJobSpec> {
        let mut out = Vec::new();
        for b in &self.benchmarks {
            // resolved (name, is-default) axes, order-preserving and
            // deduped — the [`resolve_input_axis`] helper shared with
            // [`super::ExperimentPlan`]
            let source_inputs = resolve_input_axis(b, &self.source_inputs);
            let target_inputs = resolve_input_axis(b, &self.target_inputs);
            for s in &self.source_gpus {
                for (source_input, _) in &source_inputs {
                    for t in &self.target_gpus {
                        for (target_input, target_default) in &target_inputs
                        {
                            for sr in &self.searchers {
                                for lane in 0..self.seeds {
                                    out.push(TransferJobSpec {
                                        benchmark: b.clone(),
                                        source_gpu: s.clone(),
                                        source_input: source_input.clone(),
                                        target_gpu: t.clone(),
                                        target_input: target_input.clone(),
                                        target_default: *target_default,
                                        searcher: sr.clone(),
                                        lane,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Resolve every name up front (shared helpers with
    /// [`super::ExperimentPlan`]) so job closures cannot fail later —
    /// in particular, a benchmark tuned on demand (no exhaustive
    /// recording to train from) is a typed [`PlanError::NoRecording`]
    /// and an input selector some benchmark cannot resolve is a typed
    /// [`PlanError::UnknownInput`], not a panic inside the fan-out.
    pub fn validate(&self) -> Result<(), PlanError> {
        // training-based: models are fit on sampled recording rows
        validate_trainable_benchmarks("benchmarks", &self.benchmarks)?;
        validate_gpus("source_gpus", &self.source_gpus)?;
        validate_gpus("target_gpus", &self.target_gpus)?;
        validate_inputs("source_inputs", &self.benchmarks, &self.source_inputs)?;
        validate_inputs("target_inputs", &self.benchmarks, &self.target_inputs)?;
        validate_fraction("train_fraction", self.train_fraction)?;
        validate_searchers("searchers", &self.searchers)?;
        if self.seeds == 0 {
            return Err(PlanError::EmptyAxis("seeds"));
        }
        Ok(())
    }

    fn to_json(&self) -> Value {
        let mut fields = vec![
            ("benchmarks", Value::from(self.benchmarks.clone())),
            ("source_gpus", Value::from(self.source_gpus.clone())),
            ("source_inputs", Value::from(self.source_inputs.clone())),
            ("target_gpus", Value::from(self.target_gpus.clone())),
            ("target_inputs", Value::from(self.target_inputs.clone())),
            ("model", Value::from(self.model.name())),
            ("train_fraction", Value::from(self.train_fraction)),
            ("searchers", Value::from(self.searchers.clone())),
            ("seeds", Value::from(self.seeds)),
            // string for the same 2^53 reason as ExperimentPlan
            ("base_seed", Value::from(self.base_seed.to_string())),
            ("max_tests", Value::from(self.max_tests)),
            ("within_frac", Value::from(self.within_frac)),
        ];
        if self.has_faults() {
            // serialized (and hashed) only when active, so fault-free
            // plans keep their exact plan hashes
            fields.push((
                "fault_profile",
                Value::from(self.fault_profile.name()),
            ));
        }
        obj(fields)
    }
}

/// One independent job of the transfer matrix. Input fields carry
/// *resolved* concrete names, not selectors.
#[derive(Debug, Clone)]
pub struct TransferJobSpec {
    pub benchmark: String,
    pub source_gpu: String,
    pub source_input: String,
    pub target_gpu: String,
    pub target_input: String,
    /// Is `target_input` the benchmark's default input? (Decides the
    /// RNG tag shape — see [`rng_seed`](TransferJobSpec::rng_seed).)
    pub target_default: bool,
    pub searcher: String,
    /// Repetition index within the cell.
    pub lane: usize,
}

impl TransferJobSpec {
    /// The job's private RNG stream seed. Keyed by the *target*
    /// endpoint only (GPU + input, never the source or the model
    /// kind), which pairs every source column and both model kinds on
    /// common random numbers. The default target input adds **no**
    /// tag: the stream collapses to [`super::JobSpec::rng_seed`] for
    /// the same (benchmark, GPU, searcher, lane), which is what makes
    /// same-(GPU, default input) transfer cells reproduce
    /// `ExperimentPlan` results bit-for-bit.
    ///
    /// Names are hashed *verbatim* as stream tags: alias spellings
    /// (`GTX-1070` vs `gtx1070`) would produce different streams, so
    /// the CLI canonicalizes GPU names and [`TransferPlan::jobs`]
    /// resolves input selectors before any stream is derived.
    pub fn rng_seed(&self, base_seed: u64) -> u64 {
        if self.target_default {
            stream_seed(
                base_seed,
                &[&self.benchmark, &self.target_gpu, &self.searcher],
                self.lane as u64,
            )
        } else {
            stream_seed(
                base_seed,
                &[
                    &self.benchmark,
                    &self.target_gpu,
                    &self.target_input,
                    &self.searcher,
                ],
                self.lane as u64,
            )
        }
    }

    /// Cell fault-stream seed: target endpoint only (no source, no
    /// searcher, no lane) — persistent config verdicts belong to the
    /// hardware, so every source column, searcher and repetition on one
    /// target faces the same broken configs. Matches
    /// [`super::JobSpec::fault_cell_seed`] on same-(GPU, default input)
    /// cells.
    pub fn fault_cell_seed(&self, base_seed: u64) -> u64 {
        if self.target_default {
            stream_seed(
                base_seed,
                &[&self.benchmark, &self.target_gpu, "fault-cell"],
                0,
            )
        } else {
            stream_seed(
                base_seed,
                &[
                    &self.benchmark,
                    &self.target_gpu,
                    &self.target_input,
                    "fault-cell",
                ],
                0,
            )
        }
    }

    /// Per-job fault-stream seed: target coordinates plus a `"faults"`
    /// tag — deliberately source-free so the source-axis deduplication
    /// of non-model searchers stays byte-exact under injection.
    pub fn fault_job_seed(&self, base_seed: u64) -> u64 {
        if self.target_default {
            stream_seed(
                base_seed,
                &[
                    &self.benchmark,
                    &self.target_gpu,
                    &self.searcher,
                    "faults",
                ],
                self.lane as u64,
            )
        } else {
            stream_seed(
                base_seed,
                &[
                    &self.benchmark,
                    &self.target_gpu,
                    &self.target_input,
                    &self.searcher,
                    "faults",
                ],
                self.lane as u64,
            )
        }
    }
}

/// Report cell coordinates: everything but the lane.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CellId {
    pub benchmark: String,
    pub source_gpu: String,
    pub source_input: String,
    pub target_gpu: String,
    pub target_input: String,
    pub searcher: String,
}

impl CellId {
    fn of(spec: &TransferJobSpec) -> CellId {
        CellId {
            benchmark: spec.benchmark.clone(),
            source_gpu: spec.source_gpu.clone(),
            source_input: spec.source_input.clone(),
            target_gpu: spec.target_gpu.clone(),
            target_input: spec.target_input.clone(),
            searcher: spec.searcher.clone(),
        }
    }
}

/// Outcome of one transfer job.
#[derive(Debug, Clone)]
pub struct TransferJobResult {
    pub spec: TransferJobSpec,
    pub best_ms: f64,
    /// Best found, as a multiple of the target's exhaustive best.
    pub over_oracle: f64,
    /// Empirical tests performed.
    pub tests: usize,
    pub profiled_tests: usize,
    /// 1-based test count reaching 1.1× of the target's best, if any.
    /// Deliberately computed from the same threshold as the budget's
    /// early stop (and as [`super::ExperimentPlan`]'s `tests_to_wp`) —
    /// the fixed well-performing contract of §4.1.
    pub tests_to_wp: Option<usize>,
    /// 1-based test count reaching `(1 + within_frac)×` of the
    /// target's best, if any — the *plan-configurable* slack. With the
    /// default `within_frac = 0.10` this coincides with `tests_to_wp`
    /// (1.0 + 0.10 rounds to the same f64 as 1.1); the two fields stay
    /// separate because `tests_to_wp` is pinned to the §4.1 contract
    /// while this one follows the plan.
    pub steps_to_within: Option<usize>,
    /// Simulated tuning cost, seconds.
    pub cost_s: f64,
    /// Per-step runtimes, kept for step-curve aggregation (never
    /// serialized per job — cells serialize aggregated curves). Empty
    /// unless the plan asked for curves: a full 16k-job matrix would
    /// otherwise retain ~100 MB of traces it never reads (the per-job
    /// statistics above are computed before the trace is dropped).
    pub runtimes: Vec<f64>,
    /// (cumulative cost s, best-so-far ms) staircase, kept for
    /// time-domain curve aggregation under the same `include_curves`
    /// gate as `runtimes`.
    pub staircase: Vec<(f64, f64)>,
    /// Fault accounting for this job; `None` on fault-free plans.
    pub faults: Option<FaultStats>,
}

/// Shared per-(benchmark, source endpoint, target endpoint) context.
struct TransferCell {
    rec_target: Arc<RecordedSpace>,
    gpu_target: GpuSpec,
    /// Source-endpoint model matrix — the same `Arc` for every target
    /// cell and repetition when the counter generations agree; a
    /// restricted copy (intersection of the two generations' counters)
    /// otherwise.
    matrix: Arc<PredictionMatrix>,
    inst_reaction: f64,
    /// 1.1× early-stop threshold on the target.
    thr_ms: f64,
    oracle_best_ms: f64,
}

fn run_transfer_job(
    spec: &TransferJobSpec,
    plan: &TransferPlan,
    cell: &TransferCell,
) -> TransferJobResult {
    let sspec =
        SearcherSpec::parse(&spec.searcher).expect("plan validated");
    // model-reading lanes score the *source* endpoint's matrix against
    // the target replay — the transfer setting's whole point
    let sctx = CellCtx::new(
        ModelCtx::Eager {
            matrix: Arc::clone(&cell.matrix),
        },
        cell.inst_reaction,
        0,
    );
    // Early-stop at the *stricter* of the 1.1× well-performing
    // contract and the plan's within_frac, so a sub-10% slack stays
    // measurable instead of being censored by the 1.1× stop. For
    // within_frac >= 0.10 (every shipped plan) this is bit-identical
    // to oracle × 1.1 (1.0 + 0.10 rounds to the same f64 as 1.1), so
    // the same-(GPU, input) ExperimentPlan reproduction contract is
    // unaffected; a stricter plan trades that contract for an
    // unbiased metric.
    let stop_ms = cell
        .thr_ms
        .min(cell.oracle_best_ms * (1.0 + plan.within_frac));
    let budget = Budget::until(stop_ms, plan.max_tests);
    let seed = spec.rng_seed(plan.base_seed);
    let (result, faults) = if plan.has_faults() {
        // Wrap the replay environment in the fault injector. Streams
        // are keyed off target-side plan coordinates only, so the
        // source-axis deduplication below stays byte-exact.
        let stats = Arc::new(Mutex::new(FaultStats::default()));
        let env = FaultyEnv::new(
            ReplayEnv::new(
                Arc::clone(&cell.rec_target),
                cell.gpu_target.clone(),
                CostModel::default(),
            ),
            FaultModel::for_profile(plan.fault_profile),
            spec.fault_cell_seed(plan.base_seed),
            spec.fault_job_seed(plan.base_seed),
            Arc::clone(&stats),
        );
        let result = Tuner::over(Box::new(env))
            .with_budget(budget)
            .with_seed(seed)
            .run(&sspec, &sctx);
        let stats = crate::util::sync::lock_unpoisoned(&stats).clone();
        (result, Some(stats))
    } else {
        let result = Tuner::replay(
            Arc::clone(&cell.rec_target),
            cell.gpu_target.clone(),
            CostModel::default(),
        )
        .with_budget(budget)
        .with_seed(seed)
        .run(&sspec, &sctx);
        (result, None)
    };

    let runtimes: Vec<f64> =
        result.trace.steps.iter().map(|s| s.runtime_ms).collect();
    TransferJobResult {
        spec: spec.clone(),
        best_ms: result.best_ms,
        over_oracle: result.best_ms / cell.oracle_best_ms,
        tests: result.tests,
        profiled_tests: result.profiled_tests,
        tests_to_wp: result.trace.tests_to_threshold(cell.thr_ms),
        steps_to_within: steps_to_within(
            &runtimes,
            cell.oracle_best_ms,
            plan.within_frac,
        ),
        cost_s: result.cost_s,
        staircase: if plan.include_curves {
            result.trace.convergence()
        } else {
            Vec::new()
        },
        runtimes: if plan.include_curves {
            runtimes
        } else {
            Vec::new()
        },
        faults,
    }
}

/// Goodness-of-fit of one modeled counter's source-side predictions
/// against the recording.
#[derive(Debug, Clone)]
pub struct CounterQuality {
    /// Counter abbreviation ([`crate::counters::Counter::abbr`]).
    pub counter: &'static str,
    pub mae: f64,
    pub rmse: f64,
    pub r2: f64,
}

/// Per-source-endpoint model quality: how well the source matrix
/// (trained trees, or the oracle itself) predicts the recorded
/// counters — computed **once** per (benchmark, source GPU, source
/// input) in the deterministic pre-pass and embedded in the report, so
/// portability numbers can be read next to the model error that
/// produced them (ROADMAP item (d)).
///
/// Metrics are evaluated on the **held-out remainder** of the
/// recording (the configurations the fractional sampler did not hand
/// to training) whenever that remainder is non-empty; at
/// `train_fraction = 1.0` there is no remainder, so they fall back to
/// the full recording — the training split — and `holdout` is false.
/// The oracle source reproduces the recording by construction, so its
/// metrics are exactly zero error (R² = 1) at any fraction — a
/// property-tested calibration anchor for the pipeline.
#[derive(Debug, Clone)]
pub struct EndpointQuality {
    pub benchmark: String,
    pub source_gpu: String,
    pub source_input: String,
    /// The fraction actually **applied** to this endpoint's training —
    /// the plan's `train_fraction` for the tree source, always `1.0`
    /// for the oracle (which ignores the knob).
    pub train_fraction: f64,
    /// Rows the model trained on.
    pub n_train: usize,
    /// Rows the metrics were evaluated on.
    pub n_eval: usize,
    /// True when the evaluation rows are a held-out remainder disjoint
    /// from training; false when they are the full recording.
    pub holdout: bool,
    /// Per-counter fit, in [`MODELED_COUNTERS`] order.
    pub counters: Vec<CounterQuality>,
}

impl EndpointQuality {
    /// Median MAE across the modeled counters — the one-number summary
    /// the sweep report tracks against the training fraction.
    pub fn median_mae(&self) -> f64 {
        median(&self.counters.iter().map(|c| c.mae).collect::<Vec<_>>())
    }

    /// Median R² across the modeled counters.
    pub fn median_r2(&self) -> f64 {
        median(&self.counters.iter().map(|c| c.r2).collect::<Vec<_>>())
    }

    fn to_json(&self) -> Value {
        obj(vec![
            ("benchmark", Value::from(self.benchmark.clone())),
            ("source_gpu", Value::from(self.source_gpu.clone())),
            ("source_input", Value::from(self.source_input.clone())),
            ("train_fraction", Value::from(self.train_fraction)),
            ("n_train", Value::from(self.n_train)),
            ("n_eval", Value::from(self.n_eval)),
            ("holdout", Value::from(self.holdout)),
            (
                "counters",
                Value::Arr(
                    self.counters
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("counter", Value::from(c.counter)),
                                ("mae", Value::from(c.mae)),
                                ("rmse", Value::from(c.rmse)),
                                ("r2", Value::from(c.r2)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Aggregated statistics for one cell: per-cell medians with bootstrap
/// confidence intervals.
#[derive(Debug, Clone)]
pub struct TransferAggregate {
    pub benchmark: String,
    pub source_gpu: String,
    pub source_input: String,
    pub target_gpu: String,
    pub target_input: String,
    pub searcher: String,
    pub runs: usize,
    pub wp_hits: usize,
    pub median_tests_to_wp: f64,
    /// 95% percentile-bootstrap CI around the median above.
    pub tests_to_wp_ci: (f64, f64),
    pub mean_tests_to_wp: f64,
    pub median_best_over_oracle: f64,
    pub mean_cost_s: f64,
    /// Counter abbreviations dropped by the cross-generation
    /// restriction (empty for same-generation pairs).
    pub dropped_counters: Vec<String>,
    /// Failed attempts over total attempts (tests + retries) across the
    /// cell's runs; 0.0 on fault-free plans.
    pub failure_rate: f64,
    /// Mean transient-retry count per run; 0.0 on fault-free plans.
    pub mean_retries: f64,
    /// Mean simulated seconds billed to failed/retried attempts per
    /// run; 0.0 on fault-free plans.
    pub mean_wasted_cost_s: f64,
}

/// A completed transfer plan: per-job results in plan order, plus the
/// per-GPU-pair counter-restriction record.
pub struct TransferReport {
    pub plan: TransferPlan,
    pub results: Vec<TransferJobResult>,
    /// (benchmark, source GPU, target GPU) → dropped counter
    /// abbreviations (restriction depends only on the GPU generations,
    /// never on the inputs).
    pub dropped: BTreeMap<(String, String, String), Vec<String>>,
    /// Per-source-endpoint model quality (MAE/RMSE/R² per modeled
    /// counter vs the recording's held-out remainder), in plan order —
    /// computed once in the pre-pass, embedded under `model_quality`
    /// in the schema-v3 document.
    pub model_quality: Vec<EndpointQuality>,
    /// Per-cell aggregates (sorted key order), computed once at
    /// construction — serialization, the CLI summary and the table
    /// renderers all read this cache instead of re-running the
    /// per-cell bootstrap.
    aggregates: Vec<TransferAggregate>,
}

/// The one per-cell group-by shared by aggregates and both curve
/// domains, so the three can never partition the same report
/// differently.
fn group_by_cell<'a, T>(
    results: &'a [TransferJobResult],
    value: impl Fn(&'a TransferJobResult) -> T,
) -> BTreeMap<CellId, Vec<T>> {
    let mut cells: BTreeMap<CellId, Vec<T>> = BTreeMap::new();
    for r in results {
        cells.entry(CellId::of(&r.spec)).or_default().push(value(r));
    }
    cells
}

/// Group `results` into per-cell aggregates, in sorted key order.
fn compute_aggregates(
    plan: &TransferPlan,
    results: &[TransferJobResult],
    dropped: &BTreeMap<(String, String, String), Vec<String>>,
) -> Vec<TransferAggregate> {
    group_by_cell(results, |r| r)
        .into_iter()
        .map(|(id, rs)| {
            // unreached-threshold runs count their full length,
            // like ExperimentPlan's aggregates
            let steps: Vec<f64> = rs
                .iter()
                .map(|r| r.tests_to_wp.unwrap_or(r.tests) as f64)
                .collect();
            let overs: Vec<f64> = rs.iter().map(|r| r.over_oracle).collect();
            let costs: Vec<f64> = rs.iter().map(|r| r.cost_s).collect();
            let ci_seed = stream_seed(
                plan.base_seed,
                &[
                    &id.benchmark,
                    &id.source_gpu,
                    &id.source_input,
                    &id.target_gpu,
                    &id.target_input,
                    &id.searcher,
                    "ci",
                ],
                0,
            );
            let tests_to_wp_ci = bootstrap_ci(
                &steps,
                BOOTSTRAP_ITERS,
                BOOTSTRAP_CONFIDENCE,
                ci_seed,
            );
            let cell_dropped = dropped
                .get(&(
                    id.benchmark.clone(),
                    id.source_gpu.clone(),
                    id.target_gpu.clone(),
                ))
                .cloned()
                .unwrap_or_default();
            // fault accounting: failure rate over *attempts* (trace
            // steps + transient retries), so retried-then-failed runs
            // cannot push the rate past 1.0
            let mut failed = 0u64;
            let mut retries = 0u64;
            let mut wasted = 0.0f64;
            let mut attempts = 0u64;
            for r in &rs {
                attempts += r.tests as u64;
                if let Some(f) = &r.faults {
                    failed += f.failed_runs;
                    retries += f.retries;
                    wasted += f.wasted_cost_s;
                    attempts += f.retries;
                }
            }
            let n = rs.len() as f64;
            TransferAggregate {
                failure_rate: if attempts == 0 {
                    0.0
                } else {
                    failed as f64 / attempts as f64
                },
                mean_retries: retries as f64 / n,
                mean_wasted_cost_s: wasted / n,
                runs: rs.len(),
                wp_hits: rs
                    .iter()
                    .filter(|r| r.tests_to_wp.is_some())
                    .count(),
                median_tests_to_wp: median(&steps),
                tests_to_wp_ci,
                mean_tests_to_wp: mean(&steps),
                median_best_over_oracle: median(&overs),
                mean_cost_s: mean(&costs),
                dropped_counters: cell_dropped,
                benchmark: id.benchmark,
                source_gpu: id.source_gpu,
                source_input: id.source_input,
                target_gpu: id.target_gpu,
                target_input: id.target_input,
                searcher: id.searcher,
            }
        })
        .collect()
}

impl TransferReport {
    /// Assemble a report, computing the per-cell aggregates once.
    pub fn new(
        plan: TransferPlan,
        results: Vec<TransferJobResult>,
        dropped: BTreeMap<(String, String, String), Vec<String>>,
        model_quality: Vec<EndpointQuality>,
    ) -> Self {
        let aggregates = compute_aggregates(&plan, &results, &dropped);
        TransferReport {
            plan,
            results,
            dropped,
            model_quality,
            aggregates,
        }
    }

    /// Per-cell aggregates, in sorted key order (cached).
    pub fn aggregate_rows(&self) -> &[TransferAggregate] {
        &self.aggregates
    }

    /// Per-cell aggregated best-so-far step curves (sorted key order).
    /// Curves are empty when the plan did not ask for them — per-job
    /// traces are dropped at job completion in that case.
    pub fn step_curves(&self) -> Vec<(CellId, Vec<StepCurvePoint>)> {
        // borrow the per-job traces: cloning 16k × 1000-step traces
        // per call would dwarf the aggregation itself
        group_by_cell(&self.results, |r| r.runtimes.as_slice())
            .into_iter()
            .map(|(k, runs)| (k, aggregate_step_curves(&runs)))
            .collect()
    }

    /// Per-cell aggregated best-so-far curves over the simulated
    /// tuning-cost axis (sorted key order) — the time-domain view the
    /// benchmarking literature asks searcher comparisons to include.
    /// Empty like [`step_curves`](TransferReport::step_curves) when
    /// the plan did not ask for curves.
    pub fn time_curves(&self) -> Vec<(CellId, Vec<ConvergencePoint>)> {
        group_by_cell(&self.results, |r| r.staircase.as_slice())
            .into_iter()
            .map(|(k, st)| {
                let pts = aggregate_time_curves(&st, TIME_GRID_POINTS);
                (k, pts)
            })
            .collect()
    }

    /// Deterministic JSON document: plan echo, per-job records (plan
    /// order), per-cell aggregates and (optionally) step- plus
    /// time-domain curves.
    pub fn to_json(&self) -> Value {
        let jobs: Vec<Value> = self
            .results
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("benchmark", Value::from(r.spec.benchmark.clone())),
                    ("source_gpu", Value::from(r.spec.source_gpu.clone())),
                    (
                        "source_input",
                        Value::from(r.spec.source_input.clone()),
                    ),
                    ("target_gpu", Value::from(r.spec.target_gpu.clone())),
                    (
                        "target_input",
                        Value::from(r.spec.target_input.clone()),
                    ),
                    ("searcher", Value::from(r.spec.searcher.clone())),
                    ("lane", Value::from(r.spec.lane)),
                    ("best_ms", Value::from(r.best_ms)),
                    ("over_oracle", Value::from(r.over_oracle)),
                    ("tests", Value::from(r.tests)),
                    ("profiled_tests", Value::from(r.profiled_tests)),
                    (
                        "tests_to_wp",
                        r.tests_to_wp.map(Value::from).unwrap_or(Value::Null),
                    ),
                    (
                        "steps_to_within",
                        r.steps_to_within
                            .map(Value::from)
                            .unwrap_or(Value::Null),
                    ),
                    ("cost_s", Value::from(r.cost_s)),
                ];
                if let Some(f) = &r.faults {
                    // only present under an active fault profile, so
                    // fault-free reports keep their exact bytes
                    fields.extend([
                        ("failed_runs", Value::from(f.failed_runs)),
                        ("retries", Value::from(f.retries)),
                        ("wasted_cost_s", Value::from(f.wasted_cost_s)),
                    ]);
                }
                obj(fields)
            })
            .collect();

        let has_faults = self.plan.has_faults();
        let aggregates: Vec<Value> = self
            .aggregate_rows()
            .iter()
            .map(|a| {
                let mut fields = vec![
                    ("benchmark", Value::from(a.benchmark.clone())),
                    ("source_gpu", Value::from(a.source_gpu.clone())),
                    ("source_input", Value::from(a.source_input.clone())),
                    ("target_gpu", Value::from(a.target_gpu.clone())),
                    ("target_input", Value::from(a.target_input.clone())),
                    ("searcher", Value::from(a.searcher.clone())),
                    ("runs", Value::from(a.runs)),
                    ("wp_hits", Value::from(a.wp_hits)),
                    (
                        "median_tests_to_wp",
                        Value::from(a.median_tests_to_wp),
                    ),
                    ("tests_to_wp_ci_lo", Value::from(a.tests_to_wp_ci.0)),
                    ("tests_to_wp_ci_hi", Value::from(a.tests_to_wp_ci.1)),
                    ("mean_tests_to_wp", Value::from(a.mean_tests_to_wp)),
                    (
                        "median_best_over_oracle",
                        Value::from(a.median_best_over_oracle),
                    ),
                    ("mean_cost_s", Value::from(a.mean_cost_s)),
                    (
                        "dropped_counters",
                        Value::from(a.dropped_counters.clone()),
                    ),
                ];
                if has_faults {
                    fields.extend([
                        ("failure_rate", Value::from(a.failure_rate)),
                        ("mean_retries", Value::from(a.mean_retries)),
                        (
                            "mean_wasted_cost_s",
                            Value::from(a.mean_wasted_cost_s),
                        ),
                    ]);
                }
                obj(fields)
            })
            .collect();

        let plan = self.plan.to_json();
        let plan_hash =
            registry::plan_hash(registry::TRANSFER_REPORT_SCHEMA, &plan);
        let mut fields = vec![
            ("schema", Value::from(registry::TRANSFER_REPORT_SCHEMA)),
            ("plan", plan),
            ("plan_hash", Value::from(plan_hash)),
            ("provenance", registry::Provenance::from_env().to_json()),
            ("jobs", Value::Arr(jobs)),
            ("aggregates", Value::Arr(aggregates)),
            (
                "model_quality",
                Value::Arr(
                    self.model_quality
                        .iter()
                        .map(|q| q.to_json())
                        .collect(),
                ),
            ),
        ];
        if self.plan.include_curves {
            // one entry per cell carrying BOTH curve domains; the two
            // group-bys share group_by_cell, so the zip below pairs
            // identical keys by construction (asserted anyway)
            let steps = self.step_curves();
            let times = self.time_curves();
            let curves: Vec<Value> = steps
                .into_iter()
                .zip(times)
                .map(|((id, pts), (tid, tpts))| {
                    debug_assert_eq!(id, tid);
                    obj(vec![
                        ("benchmark", Value::from(id.benchmark)),
                        ("source_gpu", Value::from(id.source_gpu)),
                        ("source_input", Value::from(id.source_input)),
                        ("target_gpu", Value::from(id.target_gpu)),
                        ("target_input", Value::from(id.target_input)),
                        ("searcher", Value::from(id.searcher)),
                        (
                            "points",
                            Value::Arr(
                                pts.iter()
                                    .map(|p| {
                                        obj(vec![
                                            ("step", Value::from(p.step)),
                                            (
                                                "median_ms",
                                                Value::from(p.median_ms),
                                            ),
                                            (
                                                "mean_ms",
                                                Value::from(p.mean_ms),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        (
                            "time",
                            Value::Arr(
                                tpts.iter()
                                    .map(|p| {
                                        obj(vec![
                                            ("t_s", Value::from(p.t_s)),
                                            (
                                                "mean_ms",
                                                Value::from(p.mean_ms),
                                            ),
                                            (
                                                "std_ms",
                                                Value::from(p.std_ms),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect();
            fields.push(("curves", Value::Arr(curves)));
        }
        obj(fields)
    }

    /// The canonical byte representation compared by the smoke gate.
    pub fn to_pretty_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty(1);
        s.push('\n');
        s
    }

    pub fn write_to(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_pretty_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// One summary line per aggregate cell, for CLI output.
    pub fn summary_lines(&self) -> Vec<String> {
        self.aggregate_rows()
            .iter()
            .map(|a| {
                format!(
                    "{:<12} {}:{} -> {}:{} {:<10} steps {:>6.1} \
                     [{:>6.1}, {:>6.1}]  best {:>5.2}x oracle{}",
                    a.benchmark,
                    a.source_gpu,
                    a.source_input,
                    a.target_gpu,
                    a.target_input,
                    a.searcher,
                    a.median_tests_to_wp,
                    a.tests_to_wp_ci.0,
                    a.tests_to_wp_ci.1,
                    a.median_best_over_oracle,
                    if a.dropped_counters.is_empty() {
                        String::new()
                    } else {
                        format!("  (dropped {})", a.dropped_counters.join(","))
                    },
                )
            })
            .collect()
    }
}

/// Per-counter fit of a source matrix against its recording, on the
/// rows in `eval` — a pure (matrix, recording, row set) function, so
/// quality is byte-stable wherever the matrix is.
fn quality_on(
    matrix: &PredictionMatrix,
    rec: &RecordedSpace,
    eval: &[usize],
) -> Vec<CounterQuality> {
    MODELED_COUNTERS
        .iter()
        .enumerate()
        .map(|(j, &c)| {
            let col = matrix.column(j);
            let pred: Vec<f64> = eval.iter().map(|&i| col[i]).collect();
            let truth: Vec<f64> = eval
                .iter()
                .map(|&i| rec.records[i].counters.get(c))
                .collect();
            CounterQuality {
                counter: c.abbr(),
                mae: mae(&pred, &truth),
                rmse: rmse(&pred, &truth),
                r2: r_squared(&pred, &truth),
            }
        })
        .collect()
}

/// Build the source-side prediction matrix for one (benchmark, source
/// GPU, source input) recording, per the plan's [`ModelSource`] and
/// `train_fraction`, together with its [`EndpointQuality`].
///
/// The tree path is deterministic by construction: the training RNG
/// stream is keyed by the source coordinates (never by scheduling),
/// the dataset is a pure function of that stream and the fraction
/// ([`stratified_indices`] at `< 1.0`; the full recording in canonical
/// space order via [`dataset_full`] at `1.0`, consuming no sampling
/// randomness — bit-for-bit the pre-fraction behaviour), and
/// [`DecisionTreeModel::train`] collects its per-counter trees in
/// `MODELED_COUNTERS` order regardless of thread interleaving — so
/// `--jobs 1` and `--jobs 8` build bit-identical matrices.
fn build_source_model(
    model: ModelSource,
    base_seed: u64,
    train_fraction: f64,
    benchmark: &str,
    source_gpu: &str,
    source_input: &str,
    rec: &RecordedSpace,
) -> (PredictionMatrix, EndpointQuality) {
    let n = rec.space.len();
    let (matrix, train_idx): (PredictionMatrix, Vec<usize>) = match model {
        // the oracle reads exact counters — no training, no sampling
        ModelSource::Oracle => {
            (PredictionMatrix::from_recorded(rec), (0..n).collect())
        }
        ModelSource::Tree => {
            let mut rng = Rng::new(stream_seed(
                base_seed,
                &[benchmark, source_gpu, source_input, "train"],
                0,
            ));
            let (ds, train_idx) = if train_fraction >= 1.0 {
                (dataset_full(rec), (0..n).collect())
            } else {
                let idx = stratified_indices(
                    n,
                    sample_size(n, train_fraction),
                    &mut rng,
                );
                (dataset_from_indices(rec, &idx), idx)
            };
            let tree = DecisionTreeModel::train(
                &ds,
                &format!("{source_gpu}/{source_input}"),
                &mut rng,
            );
            (PredictionMatrix::build(&rec.space, &tree), train_idx)
        }
    };
    // evaluation rows: the held-out remainder when any, else the full
    // recording (= the training split at fraction 1.0)
    let mut is_train = vec![false; n];
    for &i in &train_idx {
        is_train[i] = true;
    }
    let holdout = train_idx.len() < n;
    let eval: Vec<usize> = if holdout {
        (0..n).filter(|&i| !is_train[i]).collect()
    } else {
        (0..n).collect()
    };
    let quality = EndpointQuality {
        benchmark: benchmark.to_string(),
        source_gpu: source_gpu.to_string(),
        source_input: source_input.to_string(),
        // the fraction actually APPLIED, not the plan echo: the oracle
        // reads exact counters and ignores the knob, so reporting the
        // plan's sub-1.0 fraction for it would claim a sampling that
        // never happened
        train_fraction: match model {
            ModelSource::Oracle => 1.0,
            ModelSource::Tree => train_fraction,
        },
        n_train: train_idx.len(),
        n_eval: eval.len(),
        holdout,
        counters: quality_on(&matrix, rec, &eval),
    };
    (matrix, quality)
}

/// Execute a transfer plan with up to `jobs` worker threads.
///
/// Three deterministic pre-passes on the shared pool precede the
/// fan-out: (1) record every distinct (benchmark, GPU, input) endpoint
/// once (the process cache dedupes against everything else in the
/// process); (2) build — and for [`ModelSource::Tree`], train — every
/// distinct (benchmark, source GPU, source input) prediction matrix
/// once; (3) assemble per-(benchmark, source endpoint, target
/// endpoint) cells, reusing the source matrix `Arc` whenever the
/// counter generations agree and one restricted copy per distinct
/// target generation when they do not. The fan-out then only replays
/// cached data, so worker count affects wall-clock and nothing else.
pub fn run_transfer_plan(
    plan: &TransferPlan,
    jobs: usize,
) -> Result<TransferReport> {
    plan.validate()?;

    // resolved (benchmark, selector) → Input, shared by both axes
    let mut sel_inputs: BTreeMap<(String, String), Input> = BTreeMap::new();
    for b in &plan.benchmarks {
        let bench = benchmarks::by_name(b).expect("validated");
        for sel in plan.source_inputs.iter().chain(&plan.target_inputs) {
            sel_inputs
                .entry((b.clone(), sel.clone()))
                .or_insert_with(|| {
                    resolve_input(bench.as_ref(), sel).expect("validated")
                });
        }
    }

    // (1) recordings: distinct (benchmark, GPU, input) endpoints,
    // order-preserving (sources before targets)
    let mut rec_keys: Vec<(String, String, Input)> = Vec::new();
    {
        let mut seen: BTreeSet<(String, String, String)> = BTreeSet::new();
        for b in &plan.benchmarks {
            for (gpus, sels) in [
                (&plan.source_gpus, &plan.source_inputs),
                (&plan.target_gpus, &plan.target_inputs),
            ] {
                for g in gpus.iter() {
                    for sel in sels.iter() {
                        let input = &sel_inputs[&(b.clone(), sel.clone())];
                        if seen.insert((
                            b.clone(),
                            g.clone(),
                            input.name.clone(),
                        )) {
                            rec_keys.push((b.clone(), g.clone(), input.clone()));
                        }
                    }
                }
            }
        }
    }
    let recs_v = pool::par_map_jobs(rec_keys.len(), jobs, &|i| {
        let (b, g, input) = &rec_keys[i];
        let bench = benchmarks::by_name(b).expect("validated");
        let gpu = GpuSpec::by_name(g).expect("validated");
        cached_space(bench.as_ref(), &gpu, input)
    });
    let recs: BTreeMap<(String, String, String), Arc<RecordedSpace>> = rec_keys
        .iter()
        .map(|(b, g, i)| (b.clone(), g.clone(), i.name.clone()))
        .zip(recs_v)
        .collect();

    // (2) one prediction matrix per distinct (benchmark, source GPU,
    // source input) — trained here for the tree source, so training
    // cost is paid once per endpoint, not once per cell
    let mut src_keys: Vec<(String, String, String)> = Vec::new();
    for b in &plan.benchmarks {
        for s in &plan.source_gpus {
            for sel in &plan.source_inputs {
                let name = sel_inputs[&(b.clone(), sel.clone())].name.clone();
                let k = (b.clone(), s.clone(), name);
                if !src_keys.contains(&k) {
                    src_keys.push(k);
                }
            }
        }
    }
    let model = plan.model;
    let base_seed = plan.base_seed;
    let train_fraction = plan.train_fraction;
    let mats_v = pool::par_map_jobs(src_keys.len(), jobs, &|i| {
        let (b, g, input) = &src_keys[i];
        let rec = &recs[&src_keys[i]];
        let (matrix, quality) = build_source_model(
            model,
            base_seed,
            train_fraction,
            b,
            g,
            input,
            rec,
        );
        (Arc::new(matrix), quality)
    });
    // model quality in src_keys order (deterministic plan order) — the
    // report embeds it verbatim
    let model_quality: Vec<EndpointQuality> =
        mats_v.iter().map(|(_, q)| q.clone()).collect();
    let matrices: BTreeMap<(String, String, String), Arc<PredictionMatrix>> =
        src_keys
            .into_iter()
            .zip(mats_v.into_iter().map(|(m, _)| m))
            .collect();

    // (3) cells
    type EndpointKey = (String, String, String, String, String);
    let mut cells: BTreeMap<EndpointKey, TransferCell> = BTreeMap::new();
    let mut dropped: BTreeMap<(String, String, String), Vec<String>> =
        BTreeMap::new();
    for b in &plan.benchmarks {
        let bench = benchmarks::by_name(b).expect("validated");
        let inst_reaction = if bench.instruction_bound() {
            crate::expert::INST_BOUND_REACTION
        } else {
            crate::expert::DEFAULT_INST_REACTION
        };
        for s in &plan.source_gpus {
            let gpu_source = GpuSpec::by_name(s).expect("validated");
            let src_set = gpu_source.counter_set();
            for s_sel in &plan.source_inputs {
                let si =
                    sel_inputs[&(b.clone(), s_sel.clone())].name.clone();
                let base = &matrices[&(b.clone(), s.clone(), si.clone())];
                // restriction depends only on the target's counter
                // generation, so all cross-generation targets of one
                // source matrix share a single restricted Arc instead
                // of cloning the dense data per cell
                let mut restricted: Vec<(CounterSet, Arc<PredictionMatrix>)> =
                    Vec::new();
                for t in &plan.target_gpus {
                    let gpu_target = GpuSpec::by_name(t).expect("validated");
                    let tgt_set = gpu_target.counter_set();
                    // owned lookup first: an `if let` on the cache's
                    // iter would hold the borrow across the arm that
                    // pushes
                    let cached = restricted
                        .iter()
                        .find(|(set, _)| *set == tgt_set)
                        .map(|(_, m)| Arc::clone(m));
                    let matrix = if src_set == tgt_set {
                        Arc::clone(base)
                    } else if let Some(m) = cached {
                        m
                    } else {
                        let m = Arc::new(
                            base.as_ref()
                                .clone()
                                .restricted_to(src_set, tgt_set),
                        );
                        restricted.push((tgt_set, Arc::clone(&m)));
                        m
                    };
                    let drops: Vec<String> = matrix
                        .dropped_counters()
                        .iter()
                        .map(|c| c.abbr().to_string())
                        .collect();
                    dropped
                        .entry((b.clone(), s.clone(), t.clone()))
                        .or_insert(drops);
                    for t_sel in &plan.target_inputs {
                        let ti = sel_inputs[&(b.clone(), t_sel.clone())]
                            .name
                            .clone();
                        let key = (
                            b.clone(),
                            s.clone(),
                            si.clone(),
                            t.clone(),
                            ti.clone(),
                        );
                        if cells.contains_key(&key) {
                            continue;
                        }
                        let rec_target = Arc::clone(
                            &recs[&(b.clone(), t.clone(), ti.clone())],
                        );
                        let oracle_best_ms = rec_target.best_time();
                        cells.insert(
                            key,
                            TransferCell {
                                rec_target,
                                gpu_target: gpu_target.clone(),
                                matrix: Arc::clone(&matrix),
                                inst_reaction,
                                thr_ms: oracle_best_ms * 1.1,
                                oracle_best_ms,
                            },
                        );
                    }
                }
            }
        }
    }

    // Fan-out with source-axis deduplication: only searchers that
    // read the source matrix ([`reads_model`], kept next to the
    // dispatch in plan.rs) can differ across sources — for every
    // other searcher a job's outcome is a pure function of
    // (benchmark, target GPU, target input, searcher, lane) (the RNG
    // stream deliberately ignores the source), so the full matrix
    // would re-run each random baseline identically once per source
    // column. Run each distinct job once and replicate the result
    // into every source row (same values, relabelled spec) —
    // byte-identical to the naive fan-out.
    let specs = plan.jobs();
    let mut unique: Vec<usize> = Vec::new();
    let mut run_of: Vec<usize> = Vec::with_capacity(specs.len());
    let mut seen: BTreeMap<(String, String, String, String, usize), usize> =
        BTreeMap::new();
    for (i, s) in specs.iter().enumerate() {
        if reads_model(&s.searcher) {
            run_of.push(unique.len());
            unique.push(i);
            continue;
        }
        let key = (
            s.benchmark.clone(),
            s.target_gpu.clone(),
            s.target_input.clone(),
            s.searcher.clone(),
            s.lane,
        );
        if let Some(&u) = seen.get(&key) {
            run_of.push(u);
        } else {
            seen.insert(key, unique.len());
            run_of.push(unique.len());
            unique.push(i);
        }
    }
    let ran = pool::par_map_jobs(unique.len(), jobs, &|u| {
        let spec = &specs[unique[u]];
        let cell = &cells[&(
            spec.benchmark.clone(),
            spec.source_gpu.clone(),
            spec.source_input.clone(),
            spec.target_gpu.clone(),
            spec.target_input.clone(),
        )];
        run_transfer_job(spec, plan, cell)
    });
    let results: Vec<TransferJobResult> = specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let mut r = ran[run_of[i]].clone();
            r.spec = spec.clone();
            r
        })
        .collect();

    Ok(TransferReport::new(plan.clone(), results, dropped, model_quality))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TransferPlan {
        TransferPlan {
            benchmarks: vec!["coulomb".into()],
            source_gpus: vec!["gtx1070".into(), "rtx2080".into()],
            source_inputs: vec!["default".into()],
            target_gpus: vec!["gtx1070".into()],
            target_inputs: vec!["default".into()],
            model: ModelSource::Oracle,
            train_fraction: 1.0,
            searchers: vec!["random".into(), "profile".into()],
            seeds: 2,
            base_seed: 5,
            max_tests: 40,
            within_frac: 0.10,
            include_curves: true,
            fault_profile: FaultProfile::None,
        }
    }

    #[test]
    fn model_source_parses_and_names() {
        assert_eq!(ModelSource::parse("oracle"), Some(ModelSource::Oracle));
        assert_eq!(ModelSource::parse("Tree"), Some(ModelSource::Tree));
        assert_eq!(
            ModelSource::parse("decision_tree"),
            Some(ModelSource::Tree)
        );
        assert_eq!(ModelSource::parse("svm"), None);
        assert_eq!(ModelSource::Oracle.name(), "oracle");
        assert_eq!(ModelSource::Tree.name(), "tree");
    }

    #[test]
    fn plan_expansion_order_and_count() {
        let plan = TransferPlan::smoke(0);
        let jobs = plan.jobs();
        // b × sg × si × tg × ti × searcher × lane
        assert_eq!(jobs.len(), 2 * 2 * 2 * 2 * 2 * 2 * 2);
        assert_eq!(jobs[0].benchmark, "coulomb");
        assert_eq!(jobs[0].source_gpu, "gtx1070");
        assert_eq!(jobs[0].source_input, "grid256_atoms256");
        assert_eq!(jobs[0].target_gpu, "gtx1070");
        assert_eq!(jobs[0].target_input, "grid256_atoms256");
        assert!(jobs[0].target_default);
        assert_eq!(jobs[0].searcher, "random");
        assert_eq!(jobs[1].lane, 1);
        assert_eq!(jobs[2].searcher, "profile");
        // target-input axis flips after searchers × lanes
        assert_eq!(jobs[4].target_input, "grid256_atoms64");
        assert!(!jobs[4].target_default);
        // target-GPU axis flips after inputs × searchers × lanes
        assert_eq!(jobs[8].target_gpu, "rtx2080");
        // source-input axis flips after the whole target block
        assert_eq!(jobs[16].source_input, "grid256_atoms64");
    }

    #[test]
    fn selectors_resolve_to_concrete_names() {
        let mut plan = tiny();
        plan.source_inputs = vec!["alt".into()];
        plan.target_inputs = vec!["grid256_atoms256".into()];
        let jobs = plan.jobs();
        assert_eq!(jobs[0].source_input, "grid256_atoms64");
        // a concrete spelling of the default input is still the
        // default for RNG-tag purposes
        assert_eq!(jobs[0].target_input, "grid256_atoms256");
        assert!(jobs[0].target_default);
    }

    #[test]
    fn overlapping_selectors_collapse_to_one_cell() {
        // "default" and the default's concrete name resolve to the
        // same input: the axis must dedup, or every cell would run
        // twice and its aggregate double-count observations (runs,
        // wp_hits, and a spuriously narrow bootstrap CI)
        let mut plan = tiny();
        plan.source_inputs =
            vec!["default".into(), "grid256_atoms256".into()];
        plan.target_inputs =
            vec!["default".into(), "grid256_atoms256".into()];
        assert!(plan.validate().is_ok());
        assert_eq!(plan.jobs().len(), tiny().jobs().len());
        let report = run_transfer_plan(&plan, 2).unwrap();
        assert_eq!(report.results.len(), tiny().jobs().len());
        for a in report.aggregate_rows() {
            assert_eq!(a.runs, plan.seeds, "cell double-counted");
        }
    }

    #[test]
    fn validate_uses_shared_typed_errors() {
        let mut plan = tiny();
        plan.source_gpus = vec![];
        assert_eq!(
            plan.validate(),
            Err(PlanError::EmptyAxis("source_gpus"))
        );
        let mut plan = tiny();
        plan.target_gpus = vec!["titan".into()];
        assert_eq!(plan.validate(), Err(PlanError::UnknownGpu("titan".into())));
        let mut plan = tiny();
        plan.benchmarks = vec!["gemm-full".into()];
        assert_eq!(
            plan.validate(),
            Err(PlanError::NoRecording("gemm-full".into()))
        );
        let mut plan = tiny();
        plan.source_inputs = vec![];
        assert_eq!(
            plan.validate(),
            Err(PlanError::EmptyAxis("source_inputs"))
        );
        let mut plan = tiny();
        plan.target_inputs = vec!["grid999".into()];
        assert_eq!(
            plan.validate(),
            Err(PlanError::UnknownInput(
                "coulomb".into(),
                "grid999".into()
            ))
        );
        assert!(tiny().validate().is_ok());
        // and the runner surfaces it before recording anything
        let mut plan = tiny();
        plan.benchmarks = vec!["gemm-full".into()];
        assert!(run_transfer_plan(&plan, 2).is_err());
    }

    #[test]
    fn seed_streams_ignore_source_endpoint_and_model() {
        let mut plan = tiny();
        plan.source_inputs = vec!["default".into(), "alt".into()];
        let jobs = plan.jobs();
        // same (benchmark, target endpoint, searcher, lane), different
        // source GPU and source input
        let a = jobs
            .iter()
            .find(|j| {
                j.source_gpu == "gtx1070"
                    && j.source_input == "grid256_atoms256"
                    && j.searcher == "profile"
            })
            .unwrap();
        let b = jobs
            .iter()
            .find(|j| {
                j.source_gpu == "rtx2080"
                    && j.source_input == "grid256_atoms64"
                    && j.searcher == "profile"
                    && j.lane == a.lane
            })
            .unwrap();
        assert_eq!(a.rng_seed(5), b.rng_seed(5));
        // the model kind is not part of the stream either: rng_seed
        // reads only the spec, and specs carry no model field
        // …but streams stay distinct across searchers and lanes
        assert_ne!(
            stream_seed(5, &["coulomb", "gtx1070", "random"], 0),
            stream_seed(5, &["coulomb", "gtx1070", "profile"], 0)
        );
        // a non-default target input gets its own stream
        let c = TransferJobSpec {
            target_input: "grid256_atoms64".into(),
            target_default: false,
            ..a.clone()
        };
        assert_ne!(a.rng_seed(5), c.rng_seed(5));
    }

    #[test]
    fn serial_and_parallel_runs_are_byte_identical() {
        let plan = tiny();
        let a = run_transfer_plan(&plan, 1).unwrap().to_pretty_string();
        let b = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
        assert_eq!(a, b);
        assert!(a.contains("\"schema\": \"pcat-transfer-report/v3\""));
        assert!(a.contains("\"curves\""));
        assert!(a.contains("\"time\""));
        assert!(a.contains("\"model\": \"oracle\""));
        assert!(a.contains("\"model_quality\""));
        assert!(a.contains("\"train_fraction\": 1"));
    }

    #[test]
    fn faultless_transfer_serializes_without_fault_fields() {
        // the conditional-serialization contract: a fault-free plan's
        // report must not gain a single byte from this subsystem
        let report = run_transfer_plan(&tiny(), 2).unwrap();
        assert!(report.results.iter().all(|r| r.faults.is_none()));
        let text = report.to_pretty_string();
        assert!(!text.contains("\"fault_profile\""));
        assert!(!text.contains("\"failed_runs\""));
        assert!(!text.contains("\"failure_rate\""));
        assert!(!text.contains("\"wasted_cost_s\""));
    }

    #[test]
    fn hostile_transfer_is_jobs_independent_and_accounted() {
        let plan = TransferPlan {
            fault_profile: FaultProfile::Hostile,
            ..tiny()
        };
        let a = run_transfer_plan(&plan, 1).unwrap();
        let b = run_transfer_plan(&plan, 8).unwrap();
        assert_eq!(a.to_pretty_string(), b.to_pretty_string());
        let text = a.to_pretty_string();
        assert!(text.contains("\"fault_profile\": \"hostile\""));
        assert!(text.contains("\"failed_runs\""));
        assert!(text.contains("\"failure_rate\""));
        // every job completed with a bounded fault ledger
        assert!(a.results.iter().all(|r| r.faults.is_some()));
        for agg in a.aggregate_rows() {
            assert!(
                (0.0..=1.0).contains(&agg.failure_rate),
                "failure_rate {} out of [0, 1]",
                agg.failure_rate
            );
            assert!(agg.mean_retries >= 0.0);
            assert!(agg.mean_wasted_cost_s >= 0.0);
        }
        // a hostile profile genuinely perturbs the search
        assert_ne!(
            text,
            run_transfer_plan(&tiny(), 8).unwrap().to_pretty_string()
        );
    }

    #[test]
    fn fault_streams_ignore_source_endpoint() {
        // fault seeds are keyed off the target side only, so the
        // source-axis deduplication of non-model searchers stays
        // byte-exact under injection — and a given target's broken
        // configs are broken for every searcher and lane
        let mut plan = TransferPlan {
            fault_profile: FaultProfile::Flaky,
            ..tiny()
        };
        plan.source_inputs = vec!["default".into(), "alt".into()];
        let jobs = plan.jobs();
        let a = &jobs[0];
        let b = jobs
            .iter()
            .find(|j| {
                j.source_gpu != a.source_gpu
                    && j.searcher == a.searcher
                    && j.lane == a.lane
            })
            .unwrap();
        assert_eq!(a.fault_cell_seed(5), b.fault_cell_seed(5));
        assert_eq!(a.fault_job_seed(5), b.fault_job_seed(5));
        let c = jobs
            .iter()
            .find(|j| j.searcher != a.searcher && j.lane == a.lane)
            .unwrap();
        assert_eq!(a.fault_cell_seed(5), c.fault_cell_seed(5));
        assert_ne!(a.fault_job_seed(5), c.fault_job_seed(5));
        // and on the default (GPU, input) cell the transfer fault cell
        // agrees with the matrix harness's, so the same hardware
        // breaks the same way in both harnesses
        let matrix_cell =
            stream_seed(5, &["coulomb", a.target_gpu.as_str(), "fault-cell"], 0);
        assert_eq!(a.fault_cell_seed(5), matrix_cell);

        let report = run_transfer_plan(&plan, 4).unwrap();
        for r in report
            .results
            .iter()
            .filter(|r| r.spec.searcher == "random")
        {
            let twin = report
                .results
                .iter()
                .find(|o| {
                    o.spec.searcher == "random"
                        && o.spec.target_gpu == r.spec.target_gpu
                        && o.spec.target_input == r.spec.target_input
                        && o.spec.lane == r.spec.lane
                        && (o.spec.source_gpu != r.spec.source_gpu
                            || o.spec.source_input != r.spec.source_input)
                })
                .expect("several source columns in the plan");
            assert_eq!(r.best_ms, twin.best_ms);
            assert_eq!(r.faults, twin.faults);
        }
    }

    #[test]
    fn tree_model_runs_are_byte_identical_too() {
        // the tree source trains models in the pre-pass; training must
        // be a pure function of the plan, not of worker scheduling
        let plan = TransferPlan {
            model: ModelSource::Tree,
            ..tiny()
        };
        let a = run_transfer_plan(&plan, 1).unwrap().to_pretty_string();
        let b = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
        assert_eq!(a, b);
        assert!(a.contains("\"model\": \"tree\""));
    }

    #[test]
    fn fractional_tree_training_is_deterministic_across_jobs() {
        // the acceptance shape: a partial-exploration tree source must
        // keep the byte contract — sampling draws from the endpoint's
        // own stream, never from worker scheduling
        let plan = TransferPlan {
            model: ModelSource::Tree,
            train_fraction: 0.25,
            ..tiny()
        };
        let a = run_transfer_plan(&plan, 1).unwrap();
        let b = run_transfer_plan(&plan, 8).unwrap();
        assert_eq!(a.to_pretty_string(), b.to_pretty_string());
        assert!(a
            .to_pretty_string()
            .contains("\"train_fraction\": 0.25"));
        // quality was evaluated on a genuine held-out remainder
        for q in &a.model_quality {
            assert!(q.holdout, "{}: no holdout at fraction 0.25", q.benchmark);
            assert!(q.n_train > 0 && q.n_eval > 0);
            assert!(q.n_train < q.n_eval, "0.25 of the space trains");
            assert_eq!(q.counters.len(), MODELED_COUNTERS.len());
        }
        // and the fraction genuinely changes the trained model
        let full = run_transfer_plan(
            &TransferPlan {
                model: ModelSource::Tree,
                ..tiny()
            },
            8,
        )
        .unwrap();
        assert_ne!(a.to_pretty_string(), full.to_pretty_string());
    }

    #[test]
    fn invalid_train_fractions_are_typed_errors() {
        for bad in [0.0, -1.0, 1.25, f64::NAN] {
            let plan = TransferPlan {
                train_fraction: bad,
                ..tiny()
            };
            match plan.validate() {
                Err(PlanError::InvalidFraction { axis, .. }) => {
                    assert_eq!(axis, "train_fraction")
                }
                other => panic!("fraction {bad}: got {other:?}"),
            }
            assert!(run_transfer_plan(&plan, 2).is_err());
        }
    }

    #[test]
    fn oracle_quality_is_exact_zero_error() {
        // the oracle matrix *is* the recording: MAE = RMSE = 0 and
        // R² = 1 on every modeled counter — the calibration anchor for
        // the quality pipeline
        let report = run_transfer_plan(&tiny(), 2).unwrap();
        assert_eq!(report.model_quality.len(), 2, "one entry per endpoint");
        for q in &report.model_quality {
            assert!(!q.holdout);
            assert_eq!(q.n_train, q.n_eval);
            for c in &q.counters {
                assert_eq!(c.mae, 0.0, "{}: MAE", c.counter);
                assert_eq!(c.rmse, 0.0, "{}: RMSE", c.counter);
                assert_eq!(c.r2, 1.0, "{}: R²", c.counter);
            }
            assert_eq!(q.median_mae(), 0.0);
            assert_eq!(q.median_r2(), 1.0);
        }
    }

    #[test]
    fn cross_generation_cells_record_dropped_counters() {
        let plan = tiny();
        let report = run_transfer_plan(&plan, 4).unwrap();
        // rtx2080 (VoltaPlus) model steering gtx1070 (PreVolta): LOC_O
        // dropped; same-generation (and same-GPU) cell: nothing dropped
        let rows = report.aggregate_rows();
        let cross = rows
            .iter()
            .find(|a| a.source_gpu == "rtx2080" && a.searcher == "profile")
            .unwrap();
        assert_eq!(cross.dropped_counters, vec!["LOC_O".to_string()]);
        let same = rows
            .iter()
            .find(|a| a.source_gpu == "gtx1070" && a.searcher == "profile")
            .unwrap();
        assert!(same.dropped_counters.is_empty());
    }

    #[test]
    fn matrix_independent_searchers_are_shared_across_sources() {
        // random never reads the source model and its RNG stream
        // ignores the source axes, so every source column must carry
        // identical values while keeping its own spec label (the
        // deduplicated fan-out replicates instead of re-running)
        let mut plan = tiny();
        plan.source_inputs = vec!["default".into(), "alt".into()];
        let report = run_transfer_plan(&plan, 4).unwrap();
        // results come back in plan order with faithful spec labels
        for (spec, r) in plan.jobs().iter().zip(&report.results) {
            assert_eq!(spec.source_gpu, r.spec.source_gpu);
            assert_eq!(spec.source_input, r.spec.source_input);
            assert_eq!(spec.searcher, r.spec.searcher);
            assert_eq!(spec.lane, r.spec.lane);
        }
        for r in report
            .results
            .iter()
            .filter(|r| r.spec.searcher == "random")
        {
            let twin = report
                .results
                .iter()
                .find(|o| {
                    o.spec.searcher == "random"
                        && o.spec.benchmark == r.spec.benchmark
                        && o.spec.target_gpu == r.spec.target_gpu
                        && o.spec.target_input == r.spec.target_input
                        && o.spec.lane == r.spec.lane
                        && (o.spec.source_gpu != r.spec.source_gpu
                            || o.spec.source_input != r.spec.source_input)
                })
                .expect("several source columns in the plan");
            assert_eq!(r.best_ms, twin.best_ms);
            assert_eq!(r.tests, twin.tests);
            assert_eq!(r.cost_s, twin.cost_s);
        }
    }

    #[test]
    fn traces_are_dropped_when_curves_are_off() {
        // the full 16k-job matrix must not retain ~100 MB of per-step
        // traces it never serializes: runtimes and staircases are kept
        // only when the plan asks for curves, and every per-job
        // statistic is already computed before the trace is dropped
        let mut plan = tiny();
        plan.include_curves = false;
        let report = run_transfer_plan(&plan, 2).unwrap();
        assert!(report.results.iter().all(|r| r.runtimes.is_empty()));
        assert!(report.results.iter().all(|r| r.staircase.is_empty()));
        assert!(report
            .step_curves()
            .iter()
            .all(|(_, pts)| pts.is_empty()));
        assert!(report
            .time_curves()
            .iter()
            .all(|(_, pts)| pts.is_empty()));
        let text = report.to_pretty_string();
        assert!(!text.contains("\"curves\""));
        for r in &report.results {
            assert!(r.best_ms.is_finite());
            assert!(r.tests >= 1);
        }
    }

    #[test]
    fn aggregates_carry_bootstrap_cis_around_the_median() {
        let plan = tiny();
        let report = run_transfer_plan(&plan, 4).unwrap();
        for a in report.aggregate_rows() {
            assert_eq!(a.runs, 2);
            let (lo, hi) = a.tests_to_wp_ci;
            assert!(
                lo <= a.median_tests_to_wp && a.median_tests_to_wp <= hi,
                "CI [{lo}, {hi}] excludes median {}",
                a.median_tests_to_wp
            );
        }
    }

    #[test]
    fn time_curves_span_the_cost_axis() {
        let report = run_transfer_plan(&tiny(), 2).unwrap();
        for (id, pts) in report.time_curves() {
            assert!(!pts.is_empty(), "{id:?}: empty time curve");
            // grid is increasing in t and best-so-far non-increasing
            for w in pts.windows(2) {
                assert!(w[1].t_s >= w[0].t_s, "{id:?}: t grid not sorted");
                assert!(
                    w[1].mean_ms <= w[0].mean_ms + 1e-9,
                    "{id:?}: mean best-so-far increased over time"
                );
            }
        }
    }
}
