//! Figure reproductions: the TP→PC stability plot (Fig. 1), the
//! time-domain convergence figures (Figs. 3–8, §4.6) and the Basin
//! Hopping comparison (Figs. 9–13, §4.7).
//!
//! Every figure is emitted as a CSV series (machine-readable artifact)
//! plus an ASCII rendering in the markdown report.

use crate::benchmarks::{self, cached_space, Benchmark, Coulomb, Input};
use crate::counters::Counter;
use crate::gpusim::GpuSpec;
use crate::model::{
    dataset_from_recorded, DecisionTreeModel, PrecomputedModel, RemappedModel,
};
use crate::searcher::{
    BasinHopping, CostModel, ProfileSearcher, RandomSearcher,
};
use crate::tuning::RecordedSpace;
use crate::util::rng::Rng;
use crate::util::table::{ascii_chart, markdown};

use super::convergence::{aggregate_convergence, curves_csv, ConvergencePoint};
use super::steps::avg_steps_to_well_performing;
use super::{ExperimentOpts, Report};

// ---------------------------------------------------------------------
// Figure 1 — stability of TP→PC_ops across GPU and input
// ---------------------------------------------------------------------

pub fn fig1() -> Report {
    // the paper's setup: Coulomb, large gridbox on GTX 750 vs small
    // gridbox on GTX 1070; sweep the coarsening parameter
    let setups = [
        (GpuSpec::gtx750(), Input::new("large", &[256, 128])),
        (GpuSpec::gtx1070(), Input::new("small", &[64, 2048])),
    ];
    let tracked = [
        ("runtime", None),
        ("L2_RT", Some(Counter::L2Rt)),
        ("TEX_RWT", Some(Counter::TexRwt)),
        ("INST_F32", Some(Counter::InstF32)),
    ];

    let mut csv = String::from("setup,series,z_iter,normalized\n");
    let mut md = String::new();
    let mut chart_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for (gpu, input) in &setups {
        let rec = cached_space(&Coulomb, gpu, input);
        let s = &rec.space;
        // fixed slice through the space, sweeping Z_ITER (as in Fig. 1)
        let sweep: Vec<usize> = [1i64, 2, 4, 8, 16, 32]
            .iter()
            .filter_map(|&zi| {
                s.configs.iter().position(|c| {
                    s.value(c, "Z_ITER") == zi
                        && s.value(c, "BLOCK_X") == 16
                        && s.value(c, "BLOCK_Y") == 8
                        && s.value(c, "INNER_UNROLL") == 1
                        && s.value(c, "USE_SOA") == 1
                        && s.value(c, "VECTOR") == 1
                        && s.value(c, "SLICE_FACTOR") == 1
                })
            })
            .collect();

        let setup = format!("{}-{}", gpu.name, input.name);
        for (label, counter) in &tracked {
            let values: Vec<f64> = sweep
                .iter()
                .map(|&i| match counter {
                    None => rec.records[i].runtime_ms,
                    Some(c) => rec.records[i].counters.get(*c),
                })
                .collect();
            let max = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
            let pts: Vec<(f64, f64)> = sweep
                .iter()
                .zip(&values)
                .map(|(&i, v)| {
                    (s.value(&s.configs[i], "Z_ITER") as f64, v / max)
                })
                .collect();
            for (x, y) in &pts {
                csv.push_str(&format!("{setup},{label},{x},{y:.4}\n"));
            }
            chart_series.push((format!("{setup}/{label}"), pts));
        }
    }
    // chart only the runtime + INST_F32 series to stay readable
    let selected: Vec<(&str, &[(f64, f64)])> = chart_series
        .iter()
        .filter(|(n, _)| n.contains("runtime") || n.contains("INST_F32"))
        .map(|(n, p)| (n.as_str(), p.as_slice()))
        .collect();
    md.push_str(
        "Normalized runtime varies strongly across (GPU, input) setups \
         while normalized PC_ops (e.g. INST_F32) stay stable — the \
         paper's premise for a portable TP→PC model.\n\n```\n",
    );
    md.push_str(&ascii_chart(&selected, 64, 16));
    md.push_str("```\n");
    Report {
        id: "fig1",
        title: "Tuning parameter vs normalized runtime and PC_ops \
                (Coulomb, two GPU/input setups)"
            .into(),
        markdown: md,
        csvs: vec![("fig1_data".into(), csv)],
    }
}

// ---------------------------------------------------------------------
// Figures 3–8 — convergence in time (§4.6: RTX 2080, model from GTX 1070)
// ---------------------------------------------------------------------

/// Shared §4.6 setup: tune on RTX 2080 with a decision-tree model
/// trained on GTX 1070 data for the same benchmark/input.
fn model_1070_for(
    bench: &dyn Benchmark,
    input: &Input,
    target: &RecordedSpace,
    seed: u64,
) -> PrecomputedModel {
    let gpu_model = GpuSpec::gtx1070();
    let rec_model = cached_space(bench, &gpu_model, input);
    let mut rng = Rng::new(seed);
    let ds = dataset_from_recorded(&rec_model, 1.0, &mut rng);
    let dtm = DecisionTreeModel::train(&ds, "GTX1070", &mut rng);
    PrecomputedModel::over(&target.space, &dtm)
}

fn horizon_for(space_len: usize) -> f64 {
    (0.075 * space_len as f64).clamp(25.0, 300.0)
}

struct Curves {
    series: Vec<(String, Vec<ConvergencePoint>)>,
}

impl Curves {
    fn to_report(
        &self,
        id: &'static str,
        title: String,
        note: &str,
    ) -> Report {
        let chart: Vec<(&str, Vec<(f64, f64)>)> = self
            .series
            .iter()
            .map(|(n, pts)| {
                (
                    n.as_str(),
                    pts.iter().map(|p| (p.t_s, p.mean_ms)).collect(),
                )
            })
            .collect();
        let chart_refs: Vec<(&str, &[(f64, f64)])> = chart
            .iter()
            .map(|(n, p)| (*n, p.as_slice()))
            .collect();
        let mut md = format!("{note}\n\n```\n");
        md.push_str(&ascii_chart(&chart_refs, 64, 16));
        md.push_str("```\n");
        let csv_refs: Vec<(&str, &[ConvergencePoint])> = self
            .series
            .iter()
            .map(|(n, p)| (n.as_str(), p.as_slice()))
            .collect();
        Report {
            id,
            title,
            markdown: md,
            csvs: vec![(format!("{id}_data"), curves_csv(&csv_refs))],
        }
    }
}

fn convergence_setup(
    bench: &dyn Benchmark,
    input: &Input,
    cost: &CostModel,
    opts: &ExperimentOpts,
) -> Curves {
    let gpu = GpuSpec::rtx2080();
    let rec = cached_space(bench, &gpu, input);
    let model = model_1070_for(bench, input, &rec, opts.seed + 11);
    let ir = if bench.instruction_bound() { 0.5 } else { 0.7 };
    let horizon = horizon_for(rec.space.len());

    let random = aggregate_convergence(
        &rec,
        &gpu,
        cost,
        opts.time_reps,
        horizon,
        60,
        opts.seed,
        |s| Box::new(RandomSearcher::new(s)),
    );
    let profile = aggregate_convergence(
        &rec,
        &gpu,
        cost,
        opts.time_reps,
        horizon,
        60,
        opts.seed ^ 0xABCD,
        |s| Box::new(ProfileSearcher::new(&model, ir, s)),
    );
    Curves {
        series: vec![
            ("random".to_string(), random),
            ("profile".to_string(), profile),
        ],
    }
}

/// Figures 3 (GEMM), 4 (Convolution), 7 (Coulomb): default input,
/// no result check.
pub fn fig_convergence(
    id: &'static str,
    bench_name: &str,
    opts: &ExperimentOpts,
) -> Report {
    let bench = benchmarks::by_name(bench_name).unwrap();
    let input = bench.default_input();
    let curves =
        convergence_setup(bench.as_ref(), &input, &CostModel::default(), opts);
    curves.to_report(
        id,
        format!(
            "Convergence of {bench_name} ({}), RTX 2080, model from GTX \
             1070 (reps={})",
            input.name, opts.time_reps
        ),
        "Mean best-so-far kernel runtime vs tuning time.",
    )
}

/// Figure 5: Matrix transposition with and without result checking.
pub fn fig5_transpose_check(opts: &ExperimentOpts) -> Report {
    let bench = benchmarks::by_name("transpose").unwrap();
    let input = bench.default_input();
    let no_check =
        convergence_setup(bench.as_ref(), &input, &CostModel::default(), opts);
    let check = convergence_setup(
        bench.as_ref(),
        &input,
        &CostModel::with_check(),
        opts,
    );
    let mut series = Vec::new();
    for (n, p) in no_check.series {
        series.push((format!("{n}/nocheck"), p));
    }
    for (n, p) in check.series {
        series.push((format!("{n}/check"), p));
    }
    Curves { series }.to_report(
        "fig5",
        format!(
            "Convergence of Transpose ({}), RTX 2080, model from GTX 1070; \
             left=no result check, right=with check (reps={})",
            input.name, opts.time_reps
        ),
        "With result checking enabled, the constant per-test overhead \
         hides the profiling cost and the proposed searcher wins more \
         clearly (§4.6).",
    )
}

/// Figure 6: n-body at 16,384 and 131,072 bodies — profiling overhead
/// dominates on the long-running large instance.
pub fn fig6_nbody_sizes(opts: &ExperimentOpts) -> Report {
    let bench = benchmarks::by_name("nbody").unwrap();
    let mut series = Vec::new();
    for input in bench.inputs() {
        let curves = convergence_setup(
            bench.as_ref(),
            &input,
            &CostModel::default(),
            opts,
        );
        for (n, p) in curves.series {
            series.push((format!("{n}/{}", input.name), p));
        }
    }
    Curves { series }.to_report(
        "fig6",
        format!(
            "Convergence of n-body at two problem sizes, RTX 2080, model \
             from GTX 1070 (reps={})",
            opts.time_reps
        ),
        "At 131,072 bodies kernels run long, so gathering counters is \
         expensive and random search converges faster in wall-clock \
         (§4.6) — the known limitation the paper reports.",
    )
}

/// Figure 8: GEMM-full tuned with a model built from the *reduced* GEMM
/// space (<3 % of the parameters' cross product).
pub fn fig8_gemm_full(opts: &ExperimentOpts) -> Report {
    let gpu = GpuSpec::rtx2080();
    let full = benchmarks::by_name("gemm-full").unwrap();
    let reduced = benchmarks::by_name("gemm").unwrap();
    let input = full.default_input();
    let rec_full = cached_space(full.as_ref(), &gpu, &input);

    // model: decision trees trained on the REDUCED space from GTX 1070,
    // remapped onto the full space's parameter layout
    let rec_model =
        cached_space(reduced.as_ref(), &GpuSpec::gtx1070(), &input);
    let mut rng = Rng::new(opts.seed + 23);
    let ds = dataset_from_recorded(&rec_model, 1.0, &mut rng);
    let dtm = DecisionTreeModel::train(&ds, "GTX1070-gemm-reduced", &mut rng);
    let remapped =
        RemappedModel::new(&dtm, &rec_model.space, &rec_full.space).unwrap();
    let model = PrecomputedModel::over(&rec_full.space, &remapped);

    let horizon = 300.0;
    let reps = opts.time_reps.min(30); // 61k-config space — keep tractable
    let random = aggregate_convergence(
        &rec_full,
        &gpu,
        &CostModel::default(),
        reps,
        horizon,
        60,
        opts.seed,
        |s| Box::new(RandomSearcher::new(s)),
    );
    let profile = aggregate_convergence(
        &rec_full,
        &gpu,
        &CostModel::default(),
        reps,
        horizon,
        60,
        opts.seed ^ 0xF00,
        |s| Box::new(ProfileSearcher::new(&model, 0.7, s)),
    );
    Curves {
        series: vec![
            ("random".into(), random),
            ("profile(reduced-model)".into(), profile),
        ],
    }
    .to_report(
        "fig8",
        format!(
            "Convergence of GEMM-full ({} configs), RTX 2080, model from \
             the reduced GEMM space on GTX 1070 (reps={reps})",
            rec_full.space.len()
        ),
        "The model was trained on a tuning space lacking four of the \
         full space's parameters, yet still biases the search (§4.6).",
    )
}

// ---------------------------------------------------------------------
// Figures 9–13 — comparison to Basin Hopping (§4.7)
// ---------------------------------------------------------------------

pub fn fig9_13_basin_hopping(opts: &ExperimentOpts) -> Report {
    let gpu = GpuSpec::rtx2080();
    let mut md = String::new();
    let mut csvs = Vec::new();
    let mut iter_rows = Vec::new();
    for (fig_no, bench) in benchmarks::evaluation_set().iter().enumerate() {
        let input = bench.default_input();
        let rec = cached_space(bench.as_ref(), &gpu, &input);
        let model = model_1070_for(
            bench.as_ref(),
            &input,
            &rec,
            opts.seed + 41 + fig_no as u64,
        );
        let ir = if bench.instruction_bound() { 0.5 } else { 0.7 };
        let horizon = horizon_for(rec.space.len());

        // --- convergence in time ------------------------------------
        let random = aggregate_convergence(
            &rec, &gpu, &CostModel::default(), opts.time_reps, horizon, 50,
            opts.seed, |s| Box::new(RandomSearcher::new(s)),
        );
        let profile = aggregate_convergence(
            &rec, &gpu, &CostModel::default(), opts.time_reps, horizon, 50,
            opts.seed ^ 0x11, |s| Box::new(ProfileSearcher::new(&model, ir, s)),
        );
        // Kernel Tuner runs kernels 3× and is python-slow: §4.7 models
        // this with a higher per-test cost for Basin Hopping.
        let kt_cost = CostModel {
            compile_s: 0.45,
            searcher_s: 0.05,
            ..CostModel::default()
        };
        let basin = aggregate_convergence(
            &rec, &gpu, &kt_cost, opts.time_reps, horizon, 50,
            opts.seed ^ 0x22, |s| Box::new(BasinHopping::new(s)),
        );
        let series = [
            ("random", &random),
            ("profile", &profile),
            ("basin_hopping", &basin),
        ];
        let csv_refs: Vec<(&str, &[ConvergencePoint])> = series
            .iter()
            .map(|(n, p)| (*n, p.as_slice()))
            .collect();
        csvs.push((
            format!("fig9_13_{}_time", bench.name()),
            curves_csv(&csv_refs),
        ));
        let chart: Vec<(&str, Vec<(f64, f64)>)> = series
            .iter()
            .map(|(n, pts)| {
                (*n, pts.iter().map(|p| (p.t_s, p.mean_ms)).collect())
            })
            .collect();
        let chart_refs: Vec<(&str, &[(f64, f64)])> = chart
            .iter()
            .map(|(n, p)| (*n, p.as_slice()))
            .collect();
        md.push_str(&format!("\n## {} (fig {})\n\n```\n", bench.name(), 9 + fig_no));
        md.push_str(&ascii_chart(&chart_refs, 64, 14));
        md.push_str("```\n");

        // --- iterations to well-performing ---------------------------
        let reps = opts.reps.min(300);
        let rand_steps = avg_steps_to_well_performing(
            &rec, &gpu, reps, opts.seed, |s| {
                Box::new(RandomSearcher::new(s))
            },
        );
        let prof_steps = avg_steps_to_well_performing(
            &rec, &gpu, reps, opts.seed ^ 7, |s| {
                Box::new(ProfileSearcher::new(&model, ir, s))
            },
        );
        let bh_steps = avg_steps_to_well_performing(
            &rec, &gpu, reps, opts.seed ^ 13, |s| {
                Box::new(BasinHopping::new(s))
            },
        );
        iter_rows.push(vec![
            bench.name().to_string(),
            format!("{rand_steps:.0}"),
            format!("{bh_steps:.0}"),
            format!("{prof_steps:.0}"),
        ]);
    }
    md.push_str("\n## Empirical tests to reach 1.1× best\n\n");
    md.push_str(&markdown(
        &["benchmark", "random", "basin hopping", "proposed"],
        &iter_rows,
    ));
    Report {
        id: "fig9_13",
        title: format!(
            "KTT profile searcher vs Kernel-Tuner-style Basin Hopping, RTX \
             2080 (time reps={}, step reps≤300)",
            opts.time_reps
        ),
        markdown: md,
        csvs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_produces_stable_instf32_series() {
        let r = fig1();
        // INST_F32 normalized curves for both setups must be close
        // (the Eq. 4 stability premise) — parse them back from the CSV
        let csv = &r.csvs[0].1;
        let mut by_setup: std::collections::HashMap<String, Vec<f64>> =
            Default::default();
        for line in csv.lines().skip(1) {
            let f: Vec<&str> = line.split(',').collect();
            if f[1] == "INST_F32" {
                by_setup
                    .entry(f[0].to_string())
                    .or_default()
                    .push(f[3].parse().unwrap());
            }
        }
        let setups: Vec<&Vec<f64>> = by_setup.values().collect();
        assert_eq!(setups.len(), 2);
        assert_eq!(setups[0].len(), setups[1].len());
        for (a, b) in setups[0].iter().zip(setups[1]) {
            assert!(
                (a - b).abs() < 0.25,
                "INST_F32 curves diverge: {a} vs {b}"
            );
        }
    }

    #[test]
    fn horizon_clamps() {
        assert_eq!(horizon_for(10), 25.0);
        assert_eq!(horizon_for(100_000), 300.0);
    }

    #[test]
    fn fig7_small_run() {
        let opts = ExperimentOpts {
            reps: 5,
            time_reps: 5,
            seed: 2,
        };
        let r = fig_convergence("fig7", "coulomb", &opts);
        assert_eq!(r.id, "fig7");
        assert!(r.csvs[0].1.contains("profile"));
        assert!(r.csvs[0].1.contains("random"));
    }
}
