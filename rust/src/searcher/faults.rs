//! Deterministic fault-and-noise injection: survive hostile hardware.
//!
//! The replay layer is infallible and noiseless; real tuning spaces
//! are not. The kernel-tuner benchmarking literature (PAPERS.md:
//! arxiv 2303.08976, 2210.01465) treats failed/invalid configurations
//! and noisy objectives as first-class properties of these spaces:
//! configs fail outright (compile/launch errors, resource
//! exhaustion), profiled runs return partial or no counters, and
//! timings carry measurement noise. [`FaultyEnv`] wraps any
//! [`EvalEnv`] and injects exactly those failure modes, keyed off
//! [`crate::util::rng::stream_seed`] streams so injection is
//! reproducible, `--jobs`-independent and a pure function of the plan:
//!
//! * **persistent config failures** — a per-config verdict derived
//!   deterministically from the config index hashed against the
//!   *cell* seed (benchmark/GPU/input coordinates), so a broken
//!   config is broken for every searcher and every lane on that
//!   hardware, the way a real compile error would be;
//! * **transient failures** — per-attempt coin flips from the *job*
//!   fault stream, retried under a typed [`RetryPolicy`] with every
//!   attempt billed through the inner environment's cost model;
//! * **multiplicative log-normal runtime noise** — observed runtimes
//!   are scaled by `exp(σ·z)`, `z ~ N(0,1)`; the cost model keeps
//!   billing the true runtime (noise pollutes observations, not
//!   wall-clock);
//! * **counter dropout** — a profiled run succeeds but a
//!   deterministic subset of counters is missing (zeroed and listed
//!   in [`Measurement::dropped`] so the searcher can mask its
//!   reaction), or the whole profiling pass fails (`counters: None`
//!   with a valid runtime — the searcher degrades to a plain step).
//!
//! Failed runs return [`Measurement::failed`]: infinite runtime (so
//! best-so-far folds and thresholds ignore them naturally), no
//! counters, and a typed [`MeasureOutcome`]. Failure, retry and
//! wasted-cost counts accumulate in a shared [`FaultStats`] the
//! harness embeds in its reports.

use std::sync::{Arc, Mutex};

use crate::counters::ALL_COUNTERS;
use crate::gpusim::GpuSpec;
use crate::tuning::Space;
use crate::util::rng::{stream_seed, Rng};

use super::env::{EvalEnv, FailReason, MeasureOutcome, Measurement};

/// Named fault profile selecting a [`FaultModel`] — the
/// `--fault-profile {none,flaky,noisy,hostile}` axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultProfile {
    /// No injection at all: the wrapped environment's behaviour (and
    /// every report byte) is exactly the pre-fault-layer behaviour.
    #[default]
    None,
    /// Failure-dominated: persistent broken configs, transient
    /// hiccups with retries, occasional profile failures — no noise.
    Flaky,
    /// Noise-dominated: log-normal runtime noise and counter dropout
    /// — every config still works.
    Noisy,
    /// Everything at once, at the acceptance-criteria rates (≥10%
    /// persistent config failures, counter dropout, log-normal
    /// noise).
    Hostile,
}

impl FaultProfile {
    pub const ALL: [FaultProfile; 4] = [
        FaultProfile::None,
        FaultProfile::Flaky,
        FaultProfile::Noisy,
        FaultProfile::Hostile,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FaultProfile::None => "none",
            FaultProfile::Flaky => "flaky",
            FaultProfile::Noisy => "noisy",
            FaultProfile::Hostile => "hostile",
        }
    }

    /// Case-insensitive parse of the CLI spelling.
    pub fn parse(s: &str) -> Option<FaultProfile> {
        let lower = s.to_ascii_lowercase();
        FaultProfile::ALL
            .iter()
            .copied()
            .find(|p| p.name() == lower)
    }

    /// Does this profile inject anything at all?
    pub fn is_active(&self) -> bool {
        *self != FaultProfile::None
    }
}

/// Typed retry policy for transient failures: how many times one
/// `measure` call may attempt the run in total. Every attempt —
/// including the failed ones — is billed through the inner cost
/// model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per measurement (≥ 1); 1 means no retries.
    pub max_attempts: usize,
}

impl RetryPolicy {
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1 }
    }
}

/// The injection rates one [`FaultProfile`] lowers to. All rates are
/// probabilities in `[0, 1]`; a zero rate consumes no randomness, so
/// lighter profiles keep their fault streams short.
#[derive(Debug, Clone)]
pub struct FaultModel {
    pub profile: FaultProfile,
    /// Fraction of configs that fail on every attempt.
    pub persistent_rate: f64,
    /// Share of persistent failures that manifest as timeouts rather
    /// than hard failures.
    pub timeout_share: f64,
    /// Per-attempt probability of a transient failure.
    pub transient_rate: f64,
    pub retry: RetryPolicy,
    /// σ of the multiplicative log-normal runtime noise (0 = exact).
    pub noise_sigma: f64,
    /// Per-counter probability that a profiled run loses a counter.
    pub counter_dropout_rate: f64,
    /// Probability that a profiled run loses its *whole* counter set
    /// (the run itself still times correctly).
    pub profile_fail_rate: f64,
}

impl FaultModel {
    /// The rates behind each named profile. `hostile` meets the
    /// acceptance floor: ≥10% persistent config failures plus counter
    /// dropout plus log-normal noise.
    pub fn for_profile(profile: FaultProfile) -> FaultModel {
        let off = FaultModel {
            profile,
            persistent_rate: 0.0,
            timeout_share: 0.0,
            transient_rate: 0.0,
            retry: RetryPolicy::none(),
            noise_sigma: 0.0,
            counter_dropout_rate: 0.0,
            profile_fail_rate: 0.0,
        };
        match profile {
            FaultProfile::None => off,
            FaultProfile::Flaky => FaultModel {
                persistent_rate: 0.10,
                timeout_share: 0.25,
                transient_rate: 0.05,
                retry: RetryPolicy { max_attempts: 3 },
                profile_fail_rate: 0.05,
                ..off
            },
            FaultProfile::Noisy => FaultModel {
                noise_sigma: 0.05,
                counter_dropout_rate: 0.10,
                ..off
            },
            FaultProfile::Hostile => FaultModel {
                persistent_rate: 0.12,
                timeout_share: 0.25,
                transient_rate: 0.05,
                retry: RetryPolicy { max_attempts: 3 },
                noise_sigma: 0.10,
                counter_dropout_rate: 0.15,
                profile_fail_rate: 0.05,
                ..off
            },
        }
    }

    pub fn is_active(&self) -> bool {
        self.profile.is_active()
    }
}

/// Failure/retry/wasted-cost accounting, shared between the wrapper
/// and the harness via `Arc<Mutex<_>>` (the tuner owns the boxed env,
/// so the harness reads the stats through its own handle after the
/// search returns).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    /// `measure` calls that returned a non-[`MeasureOutcome::Ok`]
    /// measurement.
    pub failed_runs: usize,
    /// Transient attempts that were retried (each also billed).
    pub retries: usize,
    /// Simulated tuning cost spent on attempts that produced no
    /// usable runtime.
    pub wasted_cost_s: f64,
}

/// An [`EvalEnv`] wrapper injecting the faults of one [`FaultModel`].
///
/// Two decorrelated streams drive the injection: the **cell seed**
/// (hashed per config index) decides the persistent verdicts, so they
/// are a pure function of (plan seed, benchmark, GPU, input, config)
/// — identical for every searcher and lane on that cell; the **job
/// stream** drives transient flips, noise and dropout, advancing one
/// deterministic step pattern per `measure` call, so a same-seed
/// rerun reproduces the exact fault sequence and worker scheduling
/// can never reorder it.
pub struct FaultyEnv<E: EvalEnv> {
    inner: E,
    model: FaultModel,
    cell_seed: u64,
    rng: Rng,
    stats: Arc<Mutex<FaultStats>>,
}

impl<E: EvalEnv> FaultyEnv<E> {
    pub fn new(
        inner: E,
        model: FaultModel,
        cell_seed: u64,
        job_seed: u64,
        stats: Arc<Mutex<FaultStats>>,
    ) -> Self {
        FaultyEnv {
            inner,
            model,
            cell_seed,
            rng: Rng::new(job_seed),
            stats,
        }
    }

    /// The persistent verdict for config `idx`: `None` = healthy.
    /// Pure function of (cell seed, idx) — no stream state involved,
    /// so re-measuring a config cannot flip its verdict.
    fn persistent_verdict(&self, idx: usize) -> Option<MeasureOutcome> {
        if self.model.persistent_rate <= 0.0 {
            return None;
        }
        let u = hash_unit(stream_seed(
            self.cell_seed,
            &["persistent"],
            idx as u64,
        ));
        if u >= self.model.persistent_rate {
            return None;
        }
        let t = hash_unit(stream_seed(self.cell_seed, &["timeout"], idx as u64));
        Some(if t < self.model.timeout_share {
            MeasureOutcome::TimedOut
        } else {
            MeasureOutcome::Failed {
                reason: FailReason::Persistent,
            }
        })
    }

    fn note_failure(&self, wasted_s: f64) {
        // lock_unpoisoned: a worker panicking elsewhere (e.g. a hostile
        // recording) must not cascade into every later stats update —
        // the counters are consistent at every point a panic can unwind
        // through.
        let mut s = crate::util::sync::lock_unpoisoned(&self.stats);
        s.failed_runs += 1;
        s.wasted_cost_s += wasted_s;
    }
}

/// Map a hashed u64 onto [0, 1) (same mantissa trick as `Rng::f64`).
fn hash_unit(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl<E: EvalEnv> EvalEnv for FaultyEnv<E> {
    fn space(&self) -> &Space {
        self.inner.space()
    }

    fn measure(&mut self, idx: usize, profile: bool) -> Measurement {
        if !self.model.is_active() {
            // transparent passthrough: no stats, no randomness, byte-
            // identical behaviour to the bare environment
            return self.inner.measure(idx, profile);
        }
        if let Some(outcome) = self.persistent_verdict(idx) {
            // the doomed attempt is still billed (compiling a broken
            // config costs real time) but yields nothing
            let before = self.inner.cost_so_far();
            let _ = self.inner.measure(idx, profile);
            self.note_failure(self.inner.cost_so_far() - before);
            return Measurement::failed(outcome);
        }
        let attempts = self.model.retry.max_attempts.max(1);
        for attempt in 1..=attempts {
            let before = self.inner.cost_so_far();
            let mut m = self.inner.measure(idx, profile);
            if self.model.transient_rate > 0.0
                && self.rng.f64() < self.model.transient_rate
            {
                self.note_failure(self.inner.cost_so_far() - before);
                if attempt < attempts {
                    crate::util::sync::lock_unpoisoned(&self.stats)
                        .retries += 1;
                    continue;
                }
                return Measurement::failed(MeasureOutcome::Failed {
                    reason: FailReason::Transient,
                });
            }
            if self.model.noise_sigma > 0.0 {
                // multiplicative log-normal observation noise; the
                // inner env already billed the true runtime
                m.runtime_ms *=
                    (self.model.noise_sigma * self.rng.normal()).exp();
            }
            if profile && m.counters.is_some() {
                if self.model.profile_fail_rate > 0.0
                    && self.rng.f64() < self.model.profile_fail_rate
                {
                    // whole profiling pass failed: the runtime stands,
                    // the searcher falls back to a plain step
                    m.counters = None;
                } else if self.model.counter_dropout_rate > 0.0 {
                    let c = m.counters.as_mut().expect("checked above");
                    for &counter in ALL_COUNTERS.iter() {
                        if self.rng.f64() < self.model.counter_dropout_rate {
                            c.set(counter, 0.0);
                            m.dropped.push(counter);
                        }
                    }
                }
            }
            return m;
        }
        unreachable!("attempt loop always returns")
    }

    fn cost_so_far(&self) -> f64 {
        self.inner.cost_so_far()
    }

    fn gpu(&self) -> &GpuSpec {
        self.inner.gpu()
    }

    fn known_best_ms(&self) -> Option<f64> {
        self.inner.known_best_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::searcher::{CostModel, ReplayEnv};

    fn replay() -> ReplayEnv {
        let gpu = GpuSpec::gtx750();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    fn faulty(
        model: FaultModel,
        cell_seed: u64,
        job_seed: u64,
    ) -> (FaultyEnv<ReplayEnv>, Arc<Mutex<FaultStats>>) {
        let stats = Arc::new(Mutex::new(FaultStats::default()));
        let env = FaultyEnv::new(
            replay(),
            model,
            cell_seed,
            job_seed,
            Arc::clone(&stats),
        );
        (env, stats)
    }

    #[test]
    fn profile_parses_and_names() {
        for p in FaultProfile::ALL {
            assert_eq!(FaultProfile::parse(p.name()), Some(p));
        }
        assert_eq!(FaultProfile::parse("HOSTILE"), Some(FaultProfile::Hostile));
        assert_eq!(FaultProfile::parse("chaos"), None);
        assert!(!FaultProfile::None.is_active());
        assert!(FaultProfile::Hostile.is_active());
        assert_eq!(FaultProfile::default(), FaultProfile::None);
    }

    #[test]
    fn none_profile_is_transparent() {
        let mut bare = replay();
        let (mut env, stats) =
            faulty(FaultModel::for_profile(FaultProfile::None), 1, 2);
        for idx in [0, 3, 7] {
            for profile in [false, true] {
                let a = bare.measure(idx, profile);
                let b = env.measure(idx, profile);
                assert_eq!(a.runtime_ms, b.runtime_ms);
                assert_eq!(a.counters.is_some(), b.counters.is_some());
                assert!(b.is_ok());
                assert!(b.dropped.is_empty());
            }
        }
        assert_eq!(bare.cost_so_far(), env.cost_so_far());
        assert_eq!(*stats.lock().unwrap(), FaultStats::default());
    }

    #[test]
    fn persistent_verdicts_are_deterministic_and_config_keyed() {
        let model = FaultModel::for_profile(FaultProfile::Hostile);
        let (env_a, _) = faulty(model.clone(), 42, 0);
        // different job seed, same cell seed: identical verdicts —
        // a broken config is broken for every searcher and lane
        let (env_b, _) = faulty(model.clone(), 42, 999);
        let n = env_a.space().len();
        let verdicts: Vec<bool> = (0..n)
            .map(|i| env_a.persistent_verdict(i).is_some())
            .collect();
        for i in 0..n {
            assert_eq!(verdicts[i], env_b.persistent_verdict(i).is_some());
        }
        // the rate is roughly honoured (12% ± slack on a real space)
        let failed = verdicts.iter().filter(|&&v| v).count();
        let frac = failed as f64 / n as f64;
        assert!(
            (0.05..0.25).contains(&frac),
            "persistent fraction {frac} ({failed}/{n})"
        );
        // a different cell sees a different failure set
        let (env_c, _) = faulty(model, 43, 0);
        let other: Vec<bool> = (0..n)
            .map(|i| env_c.persistent_verdict(i).is_some())
            .collect();
        assert_ne!(verdicts, other);
        // and some verdicts are timeouts, some hard failures
        let kinds: Vec<MeasureOutcome> =
            (0..n).filter_map(|i| env_a.persistent_verdict(i)).collect();
        assert!(kinds.iter().any(|k| *k == MeasureOutcome::TimedOut));
        assert!(kinds.iter().any(|k| matches!(
            k,
            MeasureOutcome::Failed {
                reason: FailReason::Persistent
            }
        )));
    }

    #[test]
    fn persistent_failures_bill_and_count() {
        let model = FaultModel::for_profile(FaultProfile::Hostile);
        let (mut env, stats) = faulty(model, 42, 0);
        let broken = (0..env.space().len())
            .find(|&i| env.persistent_verdict(i).is_some())
            .expect("hostile profile fails some config");
        let m = env.measure(broken, false);
        assert!(!m.is_ok());
        assert!(m.runtime_ms.is_infinite());
        assert!(m.counters.is_none());
        let s = stats.lock().unwrap().clone();
        assert_eq!(s.failed_runs, 1);
        assert!(s.wasted_cost_s > 0.0);
        assert_eq!(s.wasted_cost_s, env.cost_so_far());
        // re-measuring cannot flip the verdict
        drop(s);
        let m2 = env.measure(broken, true);
        assert_eq!(m2.outcome, m.outcome);
    }

    #[test]
    fn transient_failures_retry_and_bill_every_attempt() {
        let mut model = FaultModel::for_profile(FaultProfile::Flaky);
        model.persistent_rate = 0.0;
        model.transient_rate = 1.0; // every attempt fails
        model.retry = RetryPolicy { max_attempts: 3 };
        model.profile_fail_rate = 0.0;
        let (mut env, stats) = faulty(model, 0, 7);
        let m = env.measure(0, false);
        assert_eq!(
            m.outcome,
            MeasureOutcome::Failed {
                reason: FailReason::Transient
            }
        );
        let s = stats.lock().unwrap().clone();
        assert_eq!(s.retries, 2, "3 attempts = 2 retries");
        assert_eq!(s.failed_runs, 3, "every attempt counted");
        // all three attempts billed and all wasted
        assert!((s.wasted_cost_s - env.cost_so_far()).abs() < 1e-12);
        let one_run = {
            let mut bare = replay();
            bare.measure(0, false);
            bare.cost_so_far()
        };
        assert!((env.cost_so_far() - 3.0 * one_run).abs() < 1e-9);
    }

    #[test]
    fn noise_is_multiplicative_and_seed_reproducible() {
        let model = FaultModel::for_profile(FaultProfile::Noisy);
        let (mut a, _) = faulty(model.clone(), 5, 17);
        let (mut b, _) = faulty(model.clone(), 5, 17);
        let (mut c, _) = faulty(model.clone(), 5, 18);
        let truth = replay().measure(4, false).runtime_ms;
        let ra = a.measure(4, false).runtime_ms;
        assert_eq!(ra, b.measure(4, false).runtime_ms, "same seed, same noise");
        assert_ne!(ra, c.measure(4, false).runtime_ms, "job streams differ");
        assert_ne!(ra, truth, "noise applied");
        assert!(ra > 0.0 && ra.is_finite(), "log-normal stays positive");
        // billing uses the true runtime, not the noisy observation
        let mut bare = replay();
        bare.measure(4, false);
        assert_eq!(a.cost_so_far(), bare.cost_so_far());
    }

    #[test]
    fn counter_dropout_zeroes_and_reports() {
        let mut model = FaultModel::for_profile(FaultProfile::Noisy);
        model.noise_sigma = 0.0;
        model.counter_dropout_rate = 1.0; // drop everything
        let (mut env, _) = faulty(model, 0, 3);
        let m = env.measure(2, true);
        assert!(m.is_ok());
        assert_eq!(m.dropped.len(), ALL_COUNTERS.len());
        let c = m.counters.expect("profile still yields a vector");
        assert!(c.iter().all(|(_, v)| v == 0.0));
        // plain runs never touch counters or the dropout stream
        let m2 = env.measure(3, false);
        assert!(m2.dropped.is_empty());
        assert!(m2.counters.is_none());
    }

    #[test]
    fn whole_profile_failure_keeps_the_runtime() {
        let mut model = FaultModel::for_profile(FaultProfile::Flaky);
        model.persistent_rate = 0.0;
        model.transient_rate = 0.0;
        model.profile_fail_rate = 1.0;
        let (mut env, stats) = faulty(model, 0, 9);
        let truth = replay().measure(5, true).runtime_ms;
        let m = env.measure(5, true);
        assert!(m.is_ok(), "the run itself succeeded");
        assert_eq!(m.runtime_ms, truth);
        assert!(m.counters.is_none(), "profiling pass failed");
        // a lost profile is not a failed run
        assert_eq!(stats.lock().unwrap().failed_runs, 0);
    }

    #[test]
    fn same_seed_reruns_reproduce_the_exact_fault_sequence() {
        let model = FaultModel::for_profile(FaultProfile::Hostile);
        let run = |job_seed: u64| -> (Vec<(f64, bool, usize)>, FaultStats) {
            let (mut env, stats) = faulty(model.clone(), 11, job_seed);
            let seq: Vec<(f64, bool, usize)> = (0..env.space().len().min(40))
                .map(|i| {
                    let m = env.measure(i, i % 3 == 0);
                    (m.runtime_ms, m.is_ok(), m.dropped.len())
                })
                .collect();
            let s = stats.lock().unwrap().clone();
            (seq, s)
        };
        let (seq_a, stats_a) = run(21);
        let (seq_b, stats_b) = run(21);
        assert_eq!(seq_a, seq_b);
        assert_eq!(stats_a, stats_b);
        let (seq_c, _) = run(22);
        assert_ne!(seq_a, seq_c, "different lanes see different faults");
    }
}
