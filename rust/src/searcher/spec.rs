//! The searcher registry: typed, parseable strategy specifications.
//!
//! [`SearcherSpec`] is the single construction point for every search
//! strategy the harness, the serve engine, and the CLI can run. A spec
//! is lifetime-free (model state rides in a [`CellCtx`], not in the
//! spec), parses from the CLI axis syntax
//!
//! ```text
//! random
//! profile:inst_reaction=0.6
//! ga:pop=20,mutation=0.1
//! profile+de              (Eq. 16 augmentation around a base searcher)
//! ```
//!
//! and builds a boxed [`Searcher`] via [`SearcherSpec::build`]. Unknown
//! names, unknown parameters, and out-of-domain values are typed
//! [`SpecError`]s, not panics. The per-strategy parameter tables that
//! drive validation are public (see [`registry`]) so `pcat list` prints
//! the registry without a second hand-maintained table.
//!
//! Canonical names are exactly the historical axis strings ("random",
//! "profile", "basin_hopping", "annealing", "starchart") plus the zoo
//! ("ga", "de", "dual_annealing", "profile+<base>"), so RNG stream
//! tags, plan hashes, and fault-free report bytes for pre-existing
//! plans are unchanged.

use std::fmt;
use std::sync::Arc;

use crate::benchmarks::OnDemandRecorder;
use crate::expert::DEFAULT_INST_REACTION;
use crate::model::PredictionMatrix;

use super::{
    BasinHopping, DifferentialEvolution, DualAnnealing, GeneticSearcher,
    LazyProfileSearcher, ProfileAugmented, ProfileSearcher, RandomSearcher,
    Searcher, SimulatedAnnealing, Starchart,
};

/// Where a model-reading searcher gets its predicted counters.
#[derive(Clone)]
pub enum ModelCtx {
    /// A densified prediction matrix covering the whole space — the
    /// eager (replay) cells of the harness.
    Eager { matrix: Arc<PredictionMatrix> },
    /// An on-demand recorder serving predictions lazily — the
    /// large-space cells, where densifying is off the table.
    Lazy { recorder: Arc<OnDemandRecorder> },
    /// No model available: only model-free searchers can build.
    None,
}

/// Everything a [`SearcherSpec`] needs to construct a searcher for one
/// harness cell: the cell's model context, its benchmark-derived
/// `inst_reaction` default (Eq. 15 — overridable per spec), and the
/// job's RNG stream seed.
#[derive(Clone)]
pub struct CellCtx {
    pub model: ModelCtx,
    pub inst_reaction: f64,
    pub seed: u64,
}

impl CellCtx {
    pub fn new(model: ModelCtx, inst_reaction: f64, seed: u64) -> CellCtx {
        CellCtx {
            model,
            inst_reaction,
            seed,
        }
    }

    /// A context with no model — enough for the model-free zoo.
    pub fn modelless(seed: u64) -> CellCtx {
        CellCtx {
            model: ModelCtx::None,
            inst_reaction: DEFAULT_INST_REACTION,
            seed,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> CellCtx {
        self.seed = seed;
        self
    }
}

/// What went wrong parsing a searcher spec.
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// The strategy name matches nothing in the registry.
    Unknown(String),
    /// The strategy exists but has no such tunable parameter.
    UnknownParam { searcher: String, param: String },
    /// The parameter exists but the value is unparseable or out of
    /// domain (counts must be integers ≥ 1, ratios in [0, 1], …).
    InvalidValue {
        searcher: String,
        param: String,
        value: String,
    },
    /// Malformed spec syntax (missing `=`, empty parameter list, a
    /// duplicated key, …).
    BadSyntax { spec: String, what: &'static str },
    /// `X+Y` composition where `X` is not `profile`, or `profile` is
    /// asked to augment itself.
    NotAugmentable { base: String },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Unknown(name) => write!(
                f,
                "unknown searcher {name:?} (known: {})",
                SearcherKind::all()
                    .iter()
                    .map(|k| k.canonical_name())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
            SpecError::UnknownParam { searcher, param } => write!(
                f,
                "searcher {searcher:?} has no parameter {param:?} \
                 (see `pcat list` for the registry)"
            ),
            SpecError::InvalidValue {
                searcher,
                param,
                value,
            } => write!(
                f,
                "invalid value {value:?} for parameter {param:?} of \
                 searcher {searcher:?}"
            ),
            SpecError::BadSyntax { spec, what } => {
                write!(f, "malformed searcher spec {spec:?}: {what}")
            }
            SpecError::NotAugmentable { base } => write!(
                f,
                "only `profile+<base>` composition is supported; \
                 {base:?} cannot augment (and `profile+profile` is \
                 redundant)"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// Domain a tunable parameter's value must lie in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    /// Integer ≥ 1 (population sizes, radii, step counts).
    Count,
    /// Real in [0, 1] (probabilities, cooling factors, thresholds).
    Ratio,
    /// Finite real > 0 (temperatures, differential weights).
    Positive,
}

impl ParamKind {
    fn admits(self, v: f64) -> bool {
        match self {
            ParamKind::Count => v.is_finite() && v >= 1.0 && v.fract() == 0.0,
            ParamKind::Ratio => v.is_finite() && (0.0..=1.0).contains(&v),
            ParamKind::Positive => v.is_finite() && v > 0.0,
        }
    }
}

/// One tunable parameter of a strategy: its name, domain, default (as
/// rendered by `pcat list`), and a one-line description.
#[derive(Debug, Clone, Copy)]
pub struct ParamInfo {
    pub name: &'static str,
    pub kind: ParamKind,
    pub default: &'static str,
    pub doc: &'static str,
}

const fn p(
    name: &'static str,
    kind: ParamKind,
    default: &'static str,
    doc: &'static str,
) -> ParamInfo {
    ParamInfo {
        name,
        kind,
        default,
        doc,
    }
}

const PROFILE_PARAMS: &[ParamInfo] = &[
    p(
        "inst_reaction",
        ParamKind::Ratio,
        "0.7 (0.5 on instruction-bound benchmarks)",
        "Eq. 15 bottleneck-reaction threshold",
    ),
    p(
        "n_unprofiled",
        ParamKind::Count,
        "5",
        "plain (unprofiled) steps per profiling round",
    ),
];

const BASIN_PARAMS: &[ParamInfo] = &[
    p(
        "temperature",
        ParamKind::Positive,
        "1.0",
        "Metropolis hop temperature, relative to the incumbent runtime",
    ),
    p(
        "hop_strength",
        ParamKind::Count,
        "2",
        "parameters flipped per hop",
    ),
];

const ANNEAL_PARAMS: &[ParamInfo] = &[
    p(
        "t0",
        ParamKind::Positive,
        "0.5",
        "initial temperature, as a fraction of the first runtime",
    ),
    p(
        "cooling",
        ParamKind::Ratio,
        "0.95",
        "multiplicative cooling per accepted move",
    ),
];

const GA_PARAMS: &[ParamInfo] = &[
    p("pop", ParamKind::Count, "16", "population size"),
    p(
        "mutation",
        ParamKind::Ratio,
        "0.1",
        "per-parameter mutation probability",
    ),
    p(
        "crossover",
        ParamKind::Ratio,
        "0.7",
        "probability of uniform crossover (vs. cloning the fitter parent)",
    ),
];

const DE_PARAMS: &[ParamInfo] = &[
    p("pop", ParamKind::Count, "16", "population size"),
    p(
        "f",
        ParamKind::Positive,
        "0.5",
        "differential weight applied to parameter-value positions",
    ),
    p("cr", ParamKind::Ratio, "0.9", "binomial crossover rate"),
];

const DUAL_PARAMS: &[ParamInfo] = &[
    p(
        "t0",
        ParamKind::Positive,
        "1.0",
        "initial temperature, relative to the incumbent runtime",
    ),
    p(
        "cooling",
        ParamKind::Ratio,
        "0.95",
        "multiplicative cooling per step (re-anneals when cold)",
    ),
];

/// Extra parameters every `profile+<base>` composition accepts on top
/// of the base's own.
const AUGMENT_PARAMS: &[ParamInfo] = &[
    p(
        "inst_reaction",
        ParamKind::Ratio,
        "0.7 (0.5 on instruction-bound benchmarks)",
        "Eq. 15 bottleneck-reaction threshold",
    ),
    p(
        "radius",
        ParamKind::Count,
        "2",
        "Hamming-ball radius scored around each base proposal",
    ),
];

/// The base strategies the registry knows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearcherKind {
    Random,
    Profile,
    BasinHopping,
    Starchart,
    Annealing,
    Genetic,
    DifferentialEvolution,
    DualAnnealing,
}

impl SearcherKind {
    pub fn all() -> [SearcherKind; 8] {
        [
            SearcherKind::Random,
            SearcherKind::Profile,
            SearcherKind::BasinHopping,
            SearcherKind::Starchart,
            SearcherKind::Annealing,
            SearcherKind::Genetic,
            SearcherKind::DifferentialEvolution,
            SearcherKind::DualAnnealing,
        ]
    }

    /// The canonical axis string — also the RNG stream tag, so these
    /// must never change for existing strategies.
    pub fn canonical_name(self) -> &'static str {
        match self {
            SearcherKind::Random => "random",
            SearcherKind::Profile => "profile",
            SearcherKind::BasinHopping => "basin_hopping",
            SearcherKind::Starchart => "starchart",
            SearcherKind::Annealing => "annealing",
            SearcherKind::Genetic => "ga",
            SearcherKind::DifferentialEvolution => "de",
            SearcherKind::DualAnnealing => "dual_annealing",
        }
    }

    fn from_name(name: &str) -> Option<SearcherKind> {
        match name {
            "random" => Some(SearcherKind::Random),
            "profile" => Some(SearcherKind::Profile),
            "basin_hopping" | "basin-hopping" => {
                Some(SearcherKind::BasinHopping)
            }
            "starchart" => Some(SearcherKind::Starchart),
            "annealing" => Some(SearcherKind::Annealing),
            "ga" | "genetic" => Some(SearcherKind::Genetic),
            "de" | "differential_evolution" => {
                Some(SearcherKind::DifferentialEvolution)
            }
            "dual_annealing" | "dual-annealing" => {
                Some(SearcherKind::DualAnnealing)
            }
            _ => None,
        }
    }

    pub fn params(self) -> &'static [ParamInfo] {
        match self {
            SearcherKind::Random | SearcherKind::Starchart => &[],
            SearcherKind::Profile => PROFILE_PARAMS,
            SearcherKind::BasinHopping => BASIN_PARAMS,
            SearcherKind::Annealing => ANNEAL_PARAMS,
            SearcherKind::Genetic => GA_PARAMS,
            SearcherKind::DifferentialEvolution => DE_PARAMS,
            SearcherKind::DualAnnealing => DUAL_PARAMS,
        }
    }

    pub fn doc(self) -> &'static str {
        match self {
            SearcherKind::Random => {
                "uniform random search without replacement (§4.3)"
            }
            SearcherKind::Profile => {
                "the paper's Algorithm 1: profile → bottlenecks → ΔPC → \
                 model-scored weighted steps"
            }
            SearcherKind::BasinHopping => {
                "greedy local descent + Metropolis hops (Kernel Tuner, §4.7)"
            }
            SearcherKind::Starchart => {
                "regression-tree surrogate: random build phase, then \
                 tree-guided exploitation (§4.8)"
            }
            SearcherKind::Annealing => {
                "simulated annealing over the Hamming-1 neighbourhood"
            }
            SearcherKind::Genetic => {
                "steady-state genetic algorithm: tournament selection, \
                 uniform crossover, per-parameter mutation (arxiv 2210.01465)"
            }
            SearcherKind::DifferentialEvolution => {
                "differential evolution (rand/1/bin) on parameter-value \
                 positions (arxiv 2210.01465)"
            }
            SearcherKind::DualAnnealing => {
                "generalized annealing: temperature-scaled global jumps, \
                 local descent on new incumbents, re-annealing restarts \
                 (arxiv 2210.01465)"
            }
        }
    }

    /// Can this strategy serve as the base of `profile+<base>`? The
    /// profile searcher itself cannot (it already scores with the
    /// model).
    pub fn augmentable(self) -> bool {
        self != SearcherKind::Profile
    }
}

/// One row of the searcher registry, for `pcat list`.
pub struct RegistryEntry {
    pub name: &'static str,
    pub doc: &'static str,
    pub params: &'static [ParamInfo],
    pub augmentable: bool,
}

/// The full registry, in canonical order — the same tables
/// [`SearcherSpec::parse`] validates against, so the listing can never
/// drift from what actually parses.
pub fn registry() -> Vec<RegistryEntry> {
    SearcherKind::all()
        .iter()
        .map(|&k| RegistryEntry {
            name: k.canonical_name(),
            doc: k.doc(),
            params: k.params(),
            augmentable: k.augmentable(),
        })
        .collect()
}

/// Extra parameters the `profile+` wrapper layer accepts (exported for
/// `pcat list`).
pub fn augment_params() -> &'static [ParamInfo] {
    AUGMENT_PARAMS
}

/// A parsed, validated search-strategy specification.
#[derive(Debug, Clone, PartialEq)]
pub struct SearcherSpec {
    kind: SearcherKind,
    /// Eq. 16 PC-model augmentation wrapped around the base
    /// (`profile+<base>` syntax).
    augmented: bool,
    /// Validated parameter overrides; keys are the `'static` names out
    /// of the registry tables.
    overrides: Vec<(&'static str, f64)>,
}

impl SearcherSpec {
    /// A bare spec for a base strategy, no overrides.
    pub fn base(kind: SearcherKind) -> SearcherSpec {
        SearcherSpec {
            kind,
            augmented: false,
            overrides: Vec::new(),
        }
    }

    /// Parse the CLI / plan-axis syntax:
    /// `name[+base][:key=value[,key=value…]]`.
    pub fn parse(spec: &str) -> Result<SearcherSpec, SpecError> {
        let trimmed = spec.trim();
        if trimmed.is_empty() {
            return Err(SpecError::BadSyntax {
                spec: spec.to_string(),
                what: "empty spec",
            });
        }
        let (names, params_str) = match trimmed.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (trimmed, None),
        };
        let (augmented, base_name) = match names.split_once('+') {
            Some((outer, base)) => {
                if SearcherKind::from_name(outer.trim())
                    != Some(SearcherKind::Profile)
                {
                    return Err(SpecError::NotAugmentable {
                        base: outer.trim().to_string(),
                    });
                }
                (true, base.trim())
            }
            None => (false, names),
        };
        let kind = SearcherKind::from_name(base_name)
            .ok_or_else(|| SpecError::Unknown(base_name.to_string()))?;
        if augmented && !kind.augmentable() {
            return Err(SpecError::NotAugmentable {
                base: base_name.to_string(),
            });
        }
        let mut out = SearcherSpec {
            kind,
            augmented,
            overrides: Vec::new(),
        };
        let Some(params_str) = params_str else {
            return Ok(out);
        };
        if params_str.trim().is_empty() {
            return Err(SpecError::BadSyntax {
                spec: spec.to_string(),
                what: "empty parameter list after ':'",
            });
        }
        for kv in params_str.split(',') {
            let Some((key, value)) = kv.split_once('=') else {
                return Err(SpecError::BadSyntax {
                    spec: spec.to_string(),
                    what: "expected key=value",
                });
            };
            let (key, value) = (key.trim(), value.trim());
            let info = out
                .allowed_params()
                .find(|i| i.name == key)
                .ok_or_else(|| SpecError::UnknownParam {
                    searcher: out.name(),
                    param: key.to_string(),
                })?;
            let parsed: f64 = value.parse().map_err(|_| {
                SpecError::InvalidValue {
                    searcher: out.name(),
                    param: key.to_string(),
                    value: value.to_string(),
                }
            })?;
            if !info.kind.admits(parsed) {
                return Err(SpecError::InvalidValue {
                    searcher: out.name(),
                    param: key.to_string(),
                    value: value.to_string(),
                });
            }
            if out.overrides.iter().any(|(k, _)| *k == info.name) {
                return Err(SpecError::BadSyntax {
                    spec: spec.to_string(),
                    what: "duplicate parameter",
                });
            }
            out.overrides.push((info.name, parsed));
        }
        Ok(out)
    }

    /// Every parameter this spec accepts: the base strategy's table,
    /// plus the wrapper layer's when augmented.
    fn allowed_params(&self) -> impl Iterator<Item = &'static ParamInfo> {
        let extra: &'static [ParamInfo] = if self.augmented {
            AUGMENT_PARAMS
        } else {
            &[]
        };
        self.kind.params().iter().chain(extra.iter())
    }

    pub fn kind(&self) -> SearcherKind {
        self.kind
    }

    pub fn is_augmented(&self) -> bool {
        self.augmented
    }

    /// Does running this spec require a model context (a trained TP→PC
    /// model or an on-demand recorder)? Drives the transfer harness's
    /// source-axis dedup and the sweep's baseline-lane partitioning.
    pub fn reads_model(&self) -> bool {
        self.augmented || self.kind == SearcherKind::Profile
    }

    /// The canonical rendering: `profile+ga:pop=20`. Round-trips
    /// through [`parse`](SearcherSpec::parse).
    pub fn name(&self) -> String {
        let base = self.kind.canonical_name();
        let mut out = if self.augmented {
            format!("profile+{base}")
        } else {
            base.to_string()
        };
        if !self.overrides.is_empty() {
            out.push(':');
            let kvs: Vec<String> = self
                .overrides
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect();
            out.push_str(&kvs.join(","));
        }
        out
    }

    /// A parameter override, if one was given.
    pub fn param(&self, name: &str) -> Option<f64> {
        self.overrides
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| *v)
    }

    /// Construct the searcher for one cell — the single dispatch point
    /// behind matrix, transfer, sweep, serve, and tune.
    ///
    /// # Panics
    ///
    /// When a model-reading spec is built against
    /// [`ModelCtx::None`] — plan validation guarantees model-reading
    /// lanes get a model, so hitting this is a harness bug, not a user
    /// error.
    pub fn build(&self, ctx: &CellCtx) -> Box<dyn Searcher> {
        let seed = ctx.seed;
        if self.kind == SearcherKind::Profile {
            let ir = self.param("inst_reaction").unwrap_or(ctx.inst_reaction);
            return match &ctx.model {
                ModelCtx::Eager { matrix } => {
                    let mut s =
                        ProfileSearcher::shared(Arc::clone(matrix), ir, seed);
                    if let Some(n) = self.param("n_unprofiled") {
                        s.n_unprofiled = n as usize;
                    }
                    Box::new(s)
                }
                ModelCtx::Lazy { recorder } => {
                    let mut s =
                        LazyProfileSearcher::new(Arc::clone(recorder), ir, seed);
                    if let Some(n) = self.param("n_unprofiled") {
                        s.n_unprofiled = n as usize;
                    }
                    Box::new(s)
                }
                ModelCtx::None => panic!(
                    "the profile searcher needs a model context (prediction \
                     matrix or on-demand recorder); this cell provides none"
                ),
            };
        }
        let base: Box<dyn Searcher> = match self.kind {
            SearcherKind::Random => Box::new(RandomSearcher::new(seed)),
            SearcherKind::BasinHopping => {
                let mut s = BasinHopping::new(seed);
                if let Some(t) = self.param("temperature") {
                    s.temperature = t;
                }
                if let Some(h) = self.param("hop_strength") {
                    s.hop_strength = h as usize;
                }
                Box::new(s)
            }
            SearcherKind::Starchart => Box::new(Starchart::new(seed)),
            SearcherKind::Annealing => {
                let mut s = SimulatedAnnealing::new(seed);
                if let Some(t) = self.param("t0") {
                    s.t0 = t;
                }
                if let Some(c) = self.param("cooling") {
                    s.cooling = c;
                }
                Box::new(s)
            }
            SearcherKind::Genetic => {
                let mut s = GeneticSearcher::new(seed);
                if let Some(n) = self.param("pop") {
                    s.pop_size = n as usize;
                }
                if let Some(m) = self.param("mutation") {
                    s.mutation = m;
                }
                if let Some(c) = self.param("crossover") {
                    s.crossover = c;
                }
                Box::new(s)
            }
            SearcherKind::DifferentialEvolution => {
                let mut s = DifferentialEvolution::new(seed);
                if let Some(n) = self.param("pop") {
                    s.pop_size = n as usize;
                }
                if let Some(f) = self.param("f") {
                    s.weight = f;
                }
                if let Some(c) = self.param("cr") {
                    s.cr = c;
                }
                Box::new(s)
            }
            SearcherKind::DualAnnealing => {
                let mut s = DualAnnealing::new(seed);
                if let Some(t) = self.param("t0") {
                    s.t0 = t;
                }
                if let Some(c) = self.param("cooling") {
                    s.cooling = c;
                }
                Box::new(s)
            }
            SearcherKind::Profile => unreachable!("handled above"),
        };
        if !self.augmented {
            return base;
        }
        let ir = self.param("inst_reaction").unwrap_or(ctx.inst_reaction);
        let mut aug = ProfileAugmented::new(base, ctx.model.clone(), ir);
        if let Some(r) = self.param("radius") {
            aug.radius = r as usize;
        }
        Box::new(aug)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_parse_to_themselves() {
        for kind in SearcherKind::all() {
            let name = kind.canonical_name();
            let spec = SearcherSpec::parse(name).unwrap();
            assert_eq!(spec.kind(), kind);
            assert_eq!(spec.name(), name);
            assert!(!spec.is_augmented());
        }
    }

    #[test]
    fn aliases_normalize() {
        let spec = SearcherSpec::parse("basin-hopping").unwrap();
        assert_eq!(spec.name(), "basin_hopping");
        assert_eq!(SearcherSpec::parse("genetic").unwrap().name(), "ga");
        assert_eq!(
            SearcherSpec::parse("differential_evolution").unwrap().name(),
            "de"
        );
    }

    #[test]
    fn params_parse_and_round_trip() {
        let spec = SearcherSpec::parse("ga:pop=20,mutation=0.1").unwrap();
        assert_eq!(spec.param("pop"), Some(20.0));
        assert_eq!(spec.param("mutation"), Some(0.1));
        assert_eq!(spec.param("crossover"), None);
        assert_eq!(spec.name(), "ga:pop=20,mutation=0.1");
        assert_eq!(SearcherSpec::parse(&spec.name()).unwrap(), spec);
        let spec = SearcherSpec::parse("profile:inst_reaction=0.6").unwrap();
        assert_eq!(spec.param("inst_reaction"), Some(0.6));
        assert!(spec.reads_model());
    }

    #[test]
    fn augmented_specs_parse() {
        let spec = SearcherSpec::parse("profile+ga").unwrap();
        assert!(spec.is_augmented());
        assert!(spec.reads_model());
        assert_eq!(spec.kind(), SearcherKind::Genetic);
        assert_eq!(spec.name(), "profile+ga");
        // wrapper-layer and base-layer params mix freely
        let spec =
            SearcherSpec::parse("profile+ga:pop=10,inst_reaction=0.6,radius=1")
                .unwrap();
        assert_eq!(spec.param("pop"), Some(10.0));
        assert_eq!(spec.param("inst_reaction"), Some(0.6));
        assert_eq!(spec.param("radius"), Some(1.0));
    }

    #[test]
    fn errors_are_typed() {
        assert_eq!(
            SearcherSpec::parse("pso"),
            Err(SpecError::Unknown("pso".to_string()))
        );
        assert_eq!(
            SearcherSpec::parse("ga:population=5"),
            Err(SpecError::UnknownParam {
                searcher: "ga".to_string(),
                param: "population".to_string(),
            })
        );
        // base searchers don't take the wrapper layer's params
        assert!(matches!(
            SearcherSpec::parse("ga:radius=2"),
            Err(SpecError::UnknownParam { .. })
        ));
        assert_eq!(
            SearcherSpec::parse("ga:pop=abc"),
            Err(SpecError::InvalidValue {
                searcher: "ga".to_string(),
                param: "pop".to_string(),
                value: "abc".to_string(),
            })
        );
        // out-of-domain: counts must be integral ≥ 1, ratios in [0,1]
        assert!(matches!(
            SearcherSpec::parse("ga:pop=0"),
            Err(SpecError::InvalidValue { .. })
        ));
        assert!(matches!(
            SearcherSpec::parse("ga:pop=2.5"),
            Err(SpecError::InvalidValue { .. })
        ));
        assert!(matches!(
            SearcherSpec::parse("ga:mutation=1.5"),
            Err(SpecError::InvalidValue { .. })
        ));
        assert!(matches!(
            SearcherSpec::parse("annealing:t0=-1"),
            Err(SpecError::InvalidValue { .. })
        ));
        assert!(matches!(
            SearcherSpec::parse("ga:pop"),
            Err(SpecError::BadSyntax { .. })
        ));
        assert!(matches!(
            SearcherSpec::parse("ga:"),
            Err(SpecError::BadSyntax { .. })
        ));
        assert!(matches!(
            SearcherSpec::parse(""),
            Err(SpecError::BadSyntax { .. })
        ));
        assert!(matches!(
            SearcherSpec::parse("ga:pop=5,pop=6"),
            Err(SpecError::BadSyntax { .. })
        ));
        assert_eq!(
            SearcherSpec::parse("ga+random"),
            Err(SpecError::NotAugmentable {
                base: "ga".to_string()
            })
        );
        assert_eq!(
            SearcherSpec::parse("profile+profile"),
            Err(SpecError::NotAugmentable {
                base: "profile".to_string()
            })
        );
        // errors render without panicking
        for e in [
            SearcherSpec::parse("pso").unwrap_err(),
            SearcherSpec::parse("ga:radius=2").unwrap_err(),
            SearcherSpec::parse("ga+random").unwrap_err(),
        ] {
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn registry_covers_every_kind_and_matches_parse() {
        let reg = registry();
        assert_eq!(reg.len(), SearcherKind::all().len());
        for entry in &reg {
            // every listed name parses
            let spec = SearcherSpec::parse(entry.name).unwrap();
            assert_eq!(spec.name(), entry.name);
            // every listed param is accepted with an in-domain value
            for info in entry.params {
                let v = match info.kind {
                    ParamKind::Count => "2",
                    ParamKind::Ratio => "0.5",
                    ParamKind::Positive => "0.5",
                };
                let s = format!("{}:{}={}", entry.name, info.name, v);
                SearcherSpec::parse(&s).unwrap_or_else(|e| {
                    panic!("registry param failed to parse: {s}: {e}")
                });
            }
            // every augmentable entry composes
            if entry.augmentable {
                let s = format!("profile+{}", entry.name);
                assert!(SearcherSpec::parse(&s).is_ok(), "{s}");
            }
        }
        assert!(!augment_params().is_empty());
    }

    #[test]
    fn model_free_specs_build_without_a_model() {
        let ctx = CellCtx::modelless(7);
        for name in [
            "random",
            "basin_hopping",
            "starchart",
            "annealing",
            "ga",
            "de",
            "dual_annealing",
            "ga:pop=4,mutation=0.5",
        ] {
            let spec = SearcherSpec::parse(name).unwrap();
            assert!(!spec.reads_model(), "{name}");
            let s = spec.build(&ctx);
            assert!(!s.name().is_empty());
        }
    }

    #[test]
    #[should_panic(expected = "needs a model context")]
    fn profile_without_model_panics_loudly() {
        let spec = SearcherSpec::parse("profile").unwrap();
        spec.build(&CellCtx::modelless(0));
    }
}
