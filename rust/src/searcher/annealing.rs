//! Simulated annealing — an extra optimization-based baseline used by
//! the ablation benches (not in the paper's comparison set, but a
//! common autotuning searcher, cf. [2, 33]).

use crate::util::rng::Rng;

use super::{budget_done, Budget, EvalEnv, Searcher, SearchTrace, Step};

pub struct SimulatedAnnealing {
    rng: Rng,
    /// Initial temperature as a fraction of the first runtime.
    pub t0: f64,
    /// Multiplicative cooling per accepted move.
    pub cooling: f64,
}

impl SimulatedAnnealing {
    pub fn new(seed: u64) -> Self {
        SimulatedAnnealing {
            rng: Rng::new(seed),
            t0: 0.5,
            cooling: 0.95,
        }
    }
}

impl Searcher for SimulatedAnnealing {
    fn name(&self) -> &'static str {
        "annealing"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // degenerate space: nothing to draw — empty trace, not a panic
        if size == 0 {
            return SearchTrace::default();
        }
        let mut trace = SearchTrace::default();
        let mut explored: Vec<Option<f64>> = vec![None; size];

        let mut current = self.rng.below(size);
        let m = env.measure(current, false);
        explored[current] = Some(m.runtime_ms);
        trace.push(Step {
            idx: current,
            runtime_ms: m.runtime_ms,
            profiled: false,
            cost_after_s: env.cost_so_far(),
            build: false,
        });
        let mut t_cur = m.runtime_ms;
        let mut temp = self.t0 * t_cur;

        while !budget_done(&trace, budget, env) {
            let from = env.space().config_at(current);
            let nbs: Vec<usize> = env
                .space()
                .neighbours(&from, 1)
                .into_iter()
                .filter(|&i| explored[i].is_none())
                .collect();
            let next = if nbs.is_empty() {
                let rest: Vec<usize> =
                    (0..size).filter(|&i| explored[i].is_none()).collect();
                if rest.is_empty() {
                    break;
                }
                *self.rng.choose(&rest)
            } else {
                *self.rng.choose(&nbs)
            };
            let m = env.measure(next, false);
            explored[next] = Some(m.runtime_ms);
            trace.push(Step {
                idx: next,
                runtime_ms: m.runtime_ms,
                profiled: false,
                cost_after_s: env.cost_so_far(),
                build: false,
            });
            // failed runs (infinite runtime) are never accepted as the
            // incumbent: the walk keeps exploring from where it stood
            let accept = m.is_ok()
                && (m.runtime_ms < t_cur
                    || self.rng.f64()
                        < (-(m.runtime_ms - t_cur) / temp.max(1e-12)).exp());
            if accept {
                current = next;
                t_cur = m.runtime_ms;
                if !temp.is_finite() {
                    // the walk started on a failed config (t0 × ∞):
                    // re-anchor the temperature on the first real runtime
                    temp = self.t0 * t_cur;
                }
                temp *= self.cooling;
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{CostModel, ReplayEnv};

    #[test]
    fn anneals_to_threshold() {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let thr = rec.best_time() * 1.15;
        let mut e = ReplayEnv::new(rec, gpu, CostModel::default());
        let trace = SimulatedAnnealing::new(11)
            .run(&mut e, &Budget::until(thr, 100_000));
        assert!(trace.steps.last().unwrap().runtime_ms <= thr);
    }

    #[test]
    fn unique_tests_and_termination() {
        let gpu = GpuSpec::gtx750();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let n = rec.space.len();
        let mut e = ReplayEnv::new(rec, gpu, CostModel::default());
        let trace =
            SimulatedAnnealing::new(7).run(&mut e, &Budget::tests(n * 2));
        assert_eq!(trace.len(), n);
    }
}
