//! Uniform random search without replacement — the paper's primary
//! baseline. The KTT spaces are designed to be "reasonably small", which
//! the paper notes should *not* discriminate random search (§4.2).

use crate::util::rng::Rng;

use super::{budget_done, Budget, EvalEnv, Searcher, SearchTrace, Step};

pub struct RandomSearcher {
    rng: Rng,
}

impl RandomSearcher {
    pub fn new(seed: u64) -> Self {
        RandomSearcher {
            rng: Rng::new(seed),
        }
    }
}

impl Searcher for RandomSearcher {
    fn name(&self) -> &'static str {
        "random"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let n = env.space().len();
        let mut order: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut order);
        let mut trace = SearchTrace::default();
        for idx in order {
            if budget_done(&trace, budget, env) {
                break;
            }
            let m = env.measure(idx, false);
            trace.push(Step {
                idx,
                runtime_ms: m.runtime_ms,
                profiled: false,
                cost_after_s: env.cost_so_far(),
                build: false,
            });
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{CostModel, ReplayEnv};

    fn env() -> ReplayEnv {
        let gpu = GpuSpec::gtx750();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    #[test]
    fn visits_unique_configs() {
        let mut e = env();
        let n = e.space().len();
        let trace = RandomSearcher::new(1).run(&mut e, &Budget::tests(n));
        assert_eq!(trace.len(), n);
        let mut seen: Vec<usize> = trace.steps.iter().map(|s| s.idx).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), n);
    }

    #[test]
    fn respects_test_budget() {
        let mut e = env();
        let trace = RandomSearcher::new(2).run(&mut e, &Budget::tests(10));
        assert_eq!(trace.len(), 10);
    }

    #[test]
    fn stops_at_threshold() {
        let mut e = env();
        let thr = e.recorded().best_time() * 1.1;
        let trace =
            RandomSearcher::new(3).run(&mut e, &Budget::until(thr, 100_000));
        assert!(trace.steps.last().unwrap().runtime_ms <= thr);
    }

    #[test]
    fn different_seeds_differ() {
        let t1 = RandomSearcher::new(1).run(&mut env(), &Budget::tests(5));
        let t2 = RandomSearcher::new(99).run(&mut env(), &Budget::tests(5));
        let i1: Vec<usize> = t1.steps.iter().map(|s| s.idx).collect();
        let i2: Vec<usize> = t2.steps.iter().map(|s| s.idx).collect();
        assert_ne!(i1, i2);
    }
}
