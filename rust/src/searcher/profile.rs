//! The profile-based searcher — the paper's Algorithm 1.
//!
//! Each profiling round:
//! 1. empirically measure the current `c_profile` *with* counters;
//! 2. run the expert system: bottlenecks (Eqs. 6–14) → ΔPC (Eq. 15);
//! 3. score every unexplored configuration with the TP→PC model
//!    (Eq. 16) and normalize (Eq. 17);
//! 4. take `n` weighted-random steps *without* profiling (plain runs are
//!    faster); the best runtime seen becomes the next `c_profile`.
//!
//! The model may have been trained on a different GPU or input — the
//! scoring compares model predictions for both configurations, never
//! model predictions against live measurements (§3.6).
//!
//! **Scoring engine (§Perf).** Step 3 is the hottest loop in the repo:
//! it touches the whole space every round and the harness repeats each
//! search across ~100 seeds. The searcher therefore runs on a columnar
//! [`PredictionMatrix`] — built once per run from any [`TpPcModel`], or
//! shared across all repetitions of a harness cell via
//! [`ProfileSearcher::shared`] — scores column-wise into a reusable
//! buffer, normalizes in place, and draws the weighted-random steps
//! from an O(log N) Fenwick sampler ([`WeightedIndex`]) instead of an
//! O(N) linear scan per draw.

use std::sync::Arc;

use crate::benchmarks::OnDemandRecorder;
use crate::expert::{
    active_deltas, analyze, normalize_scores_in_place, react, score_active,
};
use crate::model::{PredictionMatrix, TpPcModel};
use crate::util::fenwick::WeightedIndex;
use crate::util::rng::Rng;

use super::{budget_done, Budget, EvalEnv, Searcher, SearchTrace, Step};

/// Where the searcher's prediction matrix comes from.
enum Predictions<'m> {
    /// Densify `model` over the environment's space at the start of the
    /// run (compatibility path — one model evaluation per configuration
    /// per run, exactly what rebuilding `Vec<CounterVec>` used to cost).
    Model(&'m dyn TpPcModel),
    /// A prebuilt matrix shared (via `Arc`) across repetitions — the
    /// harness builds one per (benchmark, GPU) cell.
    Shared(Arc<PredictionMatrix>),
}

pub struct ProfileSearcher<'m> {
    predictions: Predictions<'m>,
    /// Steps without profiling per round (the paper's `n`, default 5).
    pub n_unprofiled: usize,
    /// The Eq. 15 threshold (0.7 default, 0.5 for instruction-bound).
    pub inst_reaction: f64,
    /// Restrict scoring to the Hamming-ball of this radius around the
    /// profiled configuration (the paper's §3.9.1 local-search variant
    /// and footnote-5 huge-space device). `None` = global (paper
    /// default).
    pub neighbourhood: Option<usize>,
    /// Worker threads for the global scoring round
    /// ([`PredictionMatrix::score_all_batched`] — bit-identical to the
    /// serial loop at any width). Defaults to 1: the harness already
    /// fans seed-repetitions across the pool, so per-search parallelism
    /// would oversubscribe it; single-search callers (serve cache
    /// misses, the benches) raise it.
    pub scoring_jobs: usize,
    rng: Rng,
}

impl<'m> ProfileSearcher<'m> {
    pub fn new(model: &'m dyn TpPcModel, inst_reaction: f64, seed: u64) -> Self {
        ProfileSearcher {
            predictions: Predictions::Model(model),
            n_unprofiled: 5,
            inst_reaction,
            neighbourhood: None,
            scoring_jobs: 1,
            rng: Rng::new(seed),
        }
    }

    /// Run over a prebuilt prediction matrix. The matrix must cover the
    /// exact space the searcher's environment replays; sharing one
    /// `Arc<PredictionMatrix>` across the ~100 seed-repetitions of a
    /// harness cell is what removes the per-run rebuild from the
    /// evaluation's critical path.
    ///
    /// The matrix may come from a GPU whose
    /// [`counter_set`](crate::gpusim::GpuSpec::counter_set) differs
    /// from the environment's — the cross-hardware transfer harness
    /// hands in matrices restricted to the counters both generations
    /// support ([`PredictionMatrix::restricted_to`]), and the scoring
    /// round silently drops ΔPC components on excluded columns instead
    /// of panicking.
    pub fn shared(
        matrix: Arc<PredictionMatrix>,
        inst_reaction: f64,
        seed: u64,
    ) -> ProfileSearcher<'static> {
        ProfileSearcher {
            predictions: Predictions::Shared(matrix),
            n_unprofiled: 5,
            inst_reaction,
            neighbourhood: None,
            scoring_jobs: 1,
            rng: Rng::new(seed),
        }
    }

    /// Local-search variant (§3.9.1): only configurations within
    /// `radius` parameter changes of the profiled configuration are
    /// scored each round; falls back to global scoring when the
    /// neighbourhood is exhausted.
    pub fn with_neighbourhood(mut self, radius: usize) -> Self {
        self.neighbourhood = Some(radius);
        self
    }

    /// Fan the global scoring round across `jobs` pool workers. The
    /// batched kernel preserves the serial loop's per-element
    /// arithmetic exactly, so traces are byte-identical at any width.
    pub fn with_scoring_jobs(mut self, jobs: usize) -> Self {
        self.scoring_jobs = jobs.max(1);
        self
    }
}

impl Searcher for ProfileSearcher<'_> {
    fn name(&self) -> &'static str {
        "profile"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // Degenerate space (e.g. a parameter whose value list is empty
        // enumerates to nothing): there is no configuration to draw, so
        // the search is trivially over — an empty trace, not a panic in
        // `rng.below(0)`.
        if size == 0 {
            return SearchTrace::default();
        }
        let matrix: Arc<PredictionMatrix> = match &self.predictions {
            Predictions::Model(m) => {
                Arc::new(PredictionMatrix::build(env.space(), *m))
            }
            Predictions::Shared(m) => Arc::clone(m),
        };
        assert_eq!(
            matrix.n_configs(),
            size,
            "prediction matrix covers a different space than the \
             environment replays"
        );
        // The local variant needs the space across measurement calls.
        // Build the neighbourhood index *before* cloning: the clone
        // shares the built Arc, so when the environment's space is the
        // harness's shared recording, all ~100 seed-repetitions reuse
        // one index instead of each rebuilding it.
        let local_space = self.neighbourhood.map(|_| {
            let space = env.space();
            space.neighbour_index();
            space.clone()
        });

        let mut explored = vec![false; size];
        // `selectable` mirrors `!explored` so the sampler's uniform
        // fallback can draw without rebuilding an eligibility mask per
        // draw. Failed configs are quarantined the same way: explored +
        // unselectable + zero sampler weight, so they are never
        // re-drawn (Algorithm 1 never revisits a plain step either).
        let mut selectable = vec![true; size];
        let mut trace = SearchTrace::default();
        // reusable per-round buffers: raw Eq. 16 scores / Eq. 17
        // weights, and the cumulative-weight sampler — no per-round
        // allocation
        let mut scores = vec![0.0f64; size];
        let mut sampler = WeightedIndex::new();

        let mut c_profile = self.rng.below(size);

        'outer: loop {
            if budget_done(&trace, budget, env) {
                break;
            }
            // --- profile the current configuration -----------------------
            let m = env.measure(c_profile, true);
            explored[c_profile] = true;
            selectable[c_profile] = false;
            trace.push(Step {
                idx: c_profile,
                runtime_ms: m.runtime_ms,
                profiled: true,
                cost_after_s: env.cost_so_far(),
                build: false,
            });
            // A failed or counter-less profiled run gives the expert
            // system nothing to react on: quarantine the config if it
            // failed outright, then fall back to profiling a fresh
            // uniform draw next round instead of ending the search.
            if !m.is_ok() || m.counters.is_none() {
                match next_unexplored(&explored, &mut self.rng) {
                    Some(next) => {
                        c_profile = next;
                        continue 'outer;
                    }
                    None => break 'outer,
                }
            }
            let mut t_best_round = m.runtime_ms;

            // --- expert system -------------------------------------------
            let counters = m.counters.expect("checked above");
            let bottlenecks = analyze(&counters, env.gpu());
            let mut delta = react(&bottlenecks, self.inst_reaction);
            // mask counters the profiler failed to collect: the scoring
            // round must not react on values we never observed
            for &c in &m.dropped {
                delta.0.set(c, 0.0);
            }

            // --- score the candidate set (Eqs. 16–17) --------------------
            // candidate set: whole space, or the §3.9.1 neighbourhood
            // (served by the space's indexed Hamming-ball generator)
            let candidates: Option<Vec<usize>> =
                self.neighbourhood.and_then(|radius| {
                    let space = local_space.as_ref().unwrap();
                    let from = space.config_at(c_profile);
                    let nb: Vec<usize> = space
                        .neighbours(&from, radius)
                        .into_iter()
                        .filter(|&i| !explored[i])
                        .collect();
                    // fall back to global when the ball is exhausted
                    (nb.len() >= self.n_unprofiled).then_some(nb)
                });

            let active = matrix.active_columns(&delta);
            match &candidates {
                None => {
                    // column-wise Eq. 16 over the whole space (fanned
                    // across the pool when `scoring_jobs` > 1; the
                    // batches preserve per-element arithmetic order, so
                    // the result is byte-identical to the serial loop),
                    // then exclude what's already explored
                    matrix.score_all_batched(
                        c_profile,
                        &active,
                        &mut scores,
                        self.scoring_jobs,
                    );
                    for (k, &done) in explored.iter().enumerate() {
                        if done {
                            scores[k] = f64::NEG_INFINITY;
                        }
                    }
                }
                Some(nb) => {
                    scores.fill(f64::NEG_INFINITY);
                    for &k in nb {
                        scores[k] = matrix.score_one(c_profile, &active, k);
                    }
                }
            }
            // Eq. 17 in place: finite raw scores become weights in
            // [0.0001, 256], excluded entries become weight 0
            normalize_scores_in_place(&mut scores);

            // --- n weighted-random plain steps ---------------------------
            // O(N) cumulative rebuild once per round (reusing the
            // sampler's buffers); every draw and every drawn-index
            // zeroing is O(log N)
            sampler.rebuild(&scores);
            for _ in 0..self.n_unprofiled {
                if budget_done(&trace, budget, env) {
                    break 'outer;
                }
                // degenerate-sampler edge: when every scored weight is
                // zero (mass-starved round under quarantine) fall back
                // to a uniform draw over what's still selectable
                // instead of ending the search early
                let Some(l) = sampler.sample_or_uniform(&mut self.rng, &selectable)
                else {
                    break 'outer; // nothing selectable left
                };
                let m = env.measure(l, false);
                explored[l] = true;
                selectable[l] = false;
                sampler.set(l, 0.0);
                trace.push(Step {
                    idx: l,
                    runtime_ms: m.runtime_ms,
                    profiled: false,
                    cost_after_s: env.cost_so_far(),
                    build: false,
                });
                // failed configs are quarantined above (explored +
                // unselectable + zero weight); their infinite runtime
                // also keeps them out of the best-of-round fold
                // Algorithm 1 line 20: the round's fastest kernel becomes
                // the next configuration to profile.
                if m.is_ok() && m.runtime_ms <= t_best_round {
                    t_best_round = m.runtime_ms;
                    c_profile = l;
                }
            }
            // If the profiled config stayed the round's best, re-profiling
            // it adds no information — hop to the best unexplored-scored
            // config's neighbourhood by keeping c_profile (the paper
            // re-profiles the incumbent; we follow the paper).
        }
        trace
    }
}

/// Uniform draw over the unexplored configurations (profile-fallback
/// path when a profiling round yields nothing to react on).
///
/// Zero-allocation: count the unexplored entries, draw a rank, scan to
/// the rank-th one. The retired implementation collected the unexplored
/// indices into a pool `Vec` (O(N) allocation *per fallback* — every
/// failed profiling round under a hostile fault profile) and indexed it
/// with `rng.below(pool.len())`; the pool listed indices ascending, so
/// rank `r` maps to the same configuration here off the same single
/// draw — traces are unchanged.
fn next_unexplored(explored: &[bool], rng: &mut Rng) -> Option<usize> {
    let count = explored.iter().filter(|&&done| !done).count();
    if count == 0 {
        return None;
    }
    let mut rank = rng.below(count);
    for (i, &done) in explored.iter().enumerate() {
        if !done {
            if rank == 0 {
                return Some(i);
            }
            rank -= 1;
        }
    }
    unreachable!("rank drawn below the counted unexplored entries")
}

/// Algorithm 1 over a space too large to densify — the lazy arm of the
/// scoring engine.
///
/// The eager [`ProfileSearcher`] needs a [`PredictionMatrix`] covering
/// the whole space (18 × N doubles) and O(N) buffers per round; at the
/// million-configuration scale that is hundreds of megabytes and a full
/// sweep of them every round. This variant keeps Algorithm 1's shape but
/// scores **only the Hamming-ball around the profiled configuration**
/// (the paper's footnote-5 huge-space device, hard-wired rather than
/// optional), with predictions served by an [`OnDemandRecorder`]: the
/// oracle model evaluated lazily and memoized, so a configuration is
/// simulated at most once per process no matter how many rounds or
/// concurrent searches touch it. Per-round state is O(|ball|); the only
/// space-sized allocation is the one-bit-per-config explored mask.
///
/// Scoring stays model-vs-model (§3.6): Eq. 16 compares the recorder's
/// predicted counters for the profiled and candidate configurations,
/// never predictions against the live measurement.
pub struct LazyProfileSearcher {
    recorder: Arc<OnDemandRecorder>,
    /// Steps without profiling per round (the paper's `n`, default 5).
    pub n_unprofiled: usize,
    /// The Eq. 15 threshold (0.7 default, 0.5 for instruction-bound).
    pub inst_reaction: f64,
    /// Hamming-ball radius scored each round (default 2: for the
    /// synthetic 10-parameter grid that is a few hundred candidates —
    /// enough signal for the weighted draw, negligible memory).
    pub radius: usize,
    rng: Rng,
}

impl LazyProfileSearcher {
    pub fn new(
        recorder: Arc<OnDemandRecorder>,
        inst_reaction: f64,
        seed: u64,
    ) -> Self {
        LazyProfileSearcher {
            recorder,
            n_unprofiled: 5,
            inst_reaction,
            radius: 2,
            rng: Rng::new(seed),
        }
    }

    pub fn with_radius(mut self, radius: usize) -> Self {
        self.radius = radius.max(1);
        self
    }
}

impl Searcher for LazyProfileSearcher {
    fn name(&self) -> &'static str {
        "profile-lazy"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        if size == 0 {
            return SearchTrace::default();
        }
        assert_eq!(
            self.recorder.space().len(),
            size,
            "on-demand recorder covers a different space than the \
             environment evaluates"
        );
        // Shares the recorder's space (and its lazily built neighbour
        // index) across rounds and across concurrent searches.
        let space = self.recorder.space_arc();
        space.neighbour_index();

        let mut explored = vec![false; size];
        let mut trace = SearchTrace::default();
        // O(|ball|) per-round working set — never space-sized
        let mut ball: Vec<usize> = Vec::new();
        let mut ball_scores: Vec<f64> = Vec::new();
        let mut eligible: Vec<bool> = Vec::new();
        let mut sampler = WeightedIndex::new();

        let mut c_profile = self.rng.below(size);

        'outer: loop {
            if budget_done(&trace, budget, env) {
                break;
            }
            // --- profile the current configuration -----------------------
            let m = env.measure(c_profile, true);
            explored[c_profile] = true;
            trace.push(Step {
                idx: c_profile,
                runtime_ms: m.runtime_ms,
                profiled: true,
                cost_after_s: env.cost_so_far(),
                build: false,
            });
            if !m.is_ok() || m.counters.is_none() {
                match next_unexplored(&explored, &mut self.rng) {
                    Some(next) => {
                        c_profile = next;
                        continue 'outer;
                    }
                    None => break 'outer,
                }
            }
            let mut t_best_round = m.runtime_ms;

            // --- expert system -------------------------------------------
            let counters = m.counters.expect("checked above");
            let bottlenecks = analyze(&counters, env.gpu());
            let mut delta = react(&bottlenecks, self.inst_reaction);
            for &c in &m.dropped {
                delta.0.set(c, 0.0);
            }
            let active = active_deltas(&delta);

            // --- score the unexplored ball (Eqs. 16–17) ------------------
            let from = space.config_at(c_profile);
            ball.clear();
            ball.extend(
                space
                    .neighbours(&from, self.radius)
                    .into_iter()
                    .filter(|&i| !explored[i]),
            );
            let pred_profile = self.recorder.record(c_profile).counters;
            ball_scores.clear();
            for &k in &ball {
                let pred_k = self.recorder.record(k).counters;
                ball_scores.push(score_active(&active, &pred_profile, &pred_k));
            }
            normalize_scores_in_place(&mut ball_scores);
            sampler.rebuild(&ball_scores);
            eligible.clear();
            eligible.resize(ball.len(), true);

            // --- n weighted-random plain steps ---------------------------
            for _ in 0..self.n_unprofiled {
                if budget_done(&trace, budget, env) {
                    break 'outer;
                }
                let l = match sampler.sample_or_uniform(&mut self.rng, &eligible)
                {
                    Some(pos) => {
                        eligible[pos] = false;
                        sampler.set(pos, 0.0);
                        ball[pos]
                    }
                    // ball exhausted (fully explored, or empty around a
                    // corner configuration): degrade to a uniform global
                    // draw instead of ending the search
                    None => match next_unexplored(&explored, &mut self.rng) {
                        Some(l) => l,
                        None => break 'outer,
                    },
                };
                let m = env.measure(l, false);
                explored[l] = true;
                trace.push(Step {
                    idx: l,
                    runtime_ms: m.runtime_ms,
                    profiled: false,
                    cost_after_s: env.cost_so_far(),
                    build: false,
                });
                // failed runs report infinite runtime, which the
                // best-of-round fold ignores naturally
                if m.is_ok() && m.runtime_ms <= t_best_round {
                    t_best_round = m.runtime_ms;
                    c_profile = l;
                }
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb, Transpose};
    use crate::gpusim::GpuSpec;
    use crate::model::OracleModel;
    use crate::searcher::{CostModel, RandomSearcher, ReplayEnv};
    use crate::util::stats::mean;

    fn replay(bench: &dyn Benchmark, gpu: GpuSpec) -> ReplayEnv {
        let rec = record_space(bench, &gpu, &bench.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    /// Average steps to a well-performing configuration over `reps`.
    fn avg_steps(
        mk: &mut dyn FnMut(u64, &mut ReplayEnv) -> SearchTrace,
        env_fn: &dyn Fn() -> ReplayEnv,
        reps: u64,
    ) -> f64 {
        let mut steps = Vec::new();
        for seed in 0..reps {
            let mut env = env_fn();
            let thr = env.recorded().best_time() * 1.1;
            let trace = mk(seed, &mut env);
            steps.push(
                trace.tests_to_threshold(thr).unwrap_or(trace.len()) as f64,
            );
        }
        mean(&steps)
    }

    #[test]
    fn profiled_and_plain_steps_interleave() {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        let mut env = ReplayEnv::new(rec, gpu, CostModel::default());
        let mut s = ProfileSearcher::new(&oracle, 0.5, 7);
        let trace = s.run(&mut env, &Budget::tests(24));
        assert_eq!(trace.len(), 24);
        // schedule: 1 profiled + 5 plain, repeated
        assert!(trace.steps[0].profiled);
        assert!(!trace.steps[1].profiled);
        assert!(trace.steps[6].profiled);
        let profiled = trace.steps.iter().filter(|s| s.profiled).count();
        assert_eq!(profiled, 4);
    }

    #[test]
    fn shared_matrix_run_is_identical_to_model_run() {
        // the harness's shared-Arc path and the per-run densify path
        // must be the same search, bit for bit: the matrix holds the
        // same predictions either way and the round arithmetic is shared
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        let matrix = Arc::new(PredictionMatrix::from_recorded(&rec));
        for seed in [0u64, 3, 19] {
            let steps = |trace: SearchTrace| {
                trace
                    .steps
                    .iter()
                    .map(|s| (s.idx, s.profiled))
                    .collect::<Vec<_>>()
            };
            let mut env_a =
                ReplayEnv::new(rec.clone(), gpu.clone(), CostModel::default());
            let via_model = steps(
                ProfileSearcher::new(&oracle, 0.5, seed)
                    .run(&mut env_a, &Budget::tests(30)),
            );
            let mut env_b =
                ReplayEnv::new(rec.clone(), gpu.clone(), CostModel::default());
            let via_shared = steps(
                ProfileSearcher::shared(Arc::clone(&matrix), 0.5, seed)
                    .run(&mut env_b, &Budget::tests(30)),
            );
            assert_eq!(via_model, via_shared, "seed {seed}");
        }
    }

    #[test]
    fn accepts_a_cross_counter_set_matrix() {
        // transfer harness path: the matrix comes from a GPU of the
        // other counter generation (restricted to the shared counters)
        // and the searcher must run to completion without panicking —
        // even when the expert reacts on a dropped counter
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let matrix = Arc::new(
            PredictionMatrix::from_recorded(&rec).restricted_to(
                GpuSpec::rtx2080().counter_set(), // VoltaPlus source
                gpu.counter_set(),                // PreVolta target
            ),
        );
        assert!(!matrix.dropped_counters().is_empty());
        for seed in [0u64, 9] {
            let mut env =
                ReplayEnv::new(rec.clone(), gpu.clone(), CostModel::default());
            let trace = ProfileSearcher::shared(Arc::clone(&matrix), 0.5, seed)
                .run(&mut env, &Budget::tests(30));
            assert_eq!(trace.len(), 30);
            assert!(trace.steps.iter().any(|s| s.profiled));
        }
    }

    #[test]
    fn beats_random_with_oracle_pcs_on_coulomb() {
        // the §4.3 experiment in miniature: oracle PCs, same GPU
        let gpu = GpuSpec::gtx1070();
        let env_fn = || replay(&Coulomb, GpuSpec::gtx1070());
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);

        let reps = 60;
        let rand_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                RandomSearcher::new(seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        let prof_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                ProfileSearcher::new(&oracle, 0.5, seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        assert!(
            prof_steps < rand_steps,
            "profile {prof_steps} vs random {rand_steps}"
        );
    }

    #[test]
    fn beats_random_on_transpose_memory_bound() {
        let gpu = GpuSpec::rtx2080();
        let env_fn = || replay(&Transpose, GpuSpec::rtx2080());
        let rec = record_space(&Transpose, &gpu, &Transpose.default_input());
        let oracle = OracleModel::new(&rec);
        let reps = 40;
        let rand_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                RandomSearcher::new(seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        let prof_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                ProfileSearcher::new(&oracle, 0.7, seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        assert!(
            prof_steps < rand_steps * 1.05,
            "profile {prof_steps} vs random {rand_steps}"
        );
    }

    #[test]
    fn local_variant_converges_and_terminates() {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let n = rec.space.len();
        let oracle = OracleModel::new(&rec);
        let thr = rec.best_time() * 1.1;
        let mut env = ReplayEnv::new(rec, gpu, CostModel::default());
        let mut s =
            ProfileSearcher::new(&oracle, 0.5, 11).with_neighbourhood(2);
        let trace = s.run(&mut env, &Budget::until(thr, n * 3));
        assert!(
            trace.steps.iter().any(|st| st.runtime_ms <= thr),
            "local variant failed to reach 1.1x best in {} steps",
            trace.len()
        );
    }

    #[test]
    fn exhausts_space_without_hanging() {
        let gpu = GpuSpec::gtx750();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let n = rec.space.len();
        let oracle = OracleModel::new(&rec);
        let mut env = ReplayEnv::new(rec, gpu, CostModel::default());
        let mut s = ProfileSearcher::new(&oracle, 0.5, 3);
        let trace = s.run(&mut env, &Budget::tests(n * 3));
        // profiled re-visits allowed; plain steps never repeat, so the
        // trace is bounded and the searcher terminates
        assert!(trace.len() <= n * 3);
    }

    #[test]
    fn survives_hostile_faults_and_never_reselects_quarantined() {
        use crate::searcher::{FaultModel, FaultProfile, FaultStats, FaultyEnv};
        use std::sync::{Arc, Mutex};

        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        for seed in [0u64, 5, 11] {
            let inner =
                ReplayEnv::new(rec.clone(), gpu.clone(), CostModel::default());
            let stats = Arc::new(Mutex::new(FaultStats::default()));
            let mut env = FaultyEnv::new(
                inner,
                FaultModel::for_profile(FaultProfile::Hostile),
                42,
                seed.wrapping_mul(7919) + 1,
                Arc::clone(&stats),
            );
            let trace = ProfileSearcher::new(&oracle, 0.5, seed)
                .run(&mut env, &Budget::tests(60));
            assert!(!trace.is_empty());
            // a quarantined (failed) config is never drawn again
            for step in trace.steps.iter().filter(|s| s.runtime_ms.is_infinite())
            {
                let times =
                    trace.steps.iter().filter(|s| s.idx == step.idx).count();
                assert_eq!(times, 1, "failed config {} re-selected", step.idx);
            }
            // hostile rates really did fail something across seeds — and
            // the search still made progress on the healthy remainder
            assert!(trace.steps.iter().any(|s| s.runtime_ms.is_finite()));
        }
    }

    #[test]
    fn whole_profile_failure_falls_back_instead_of_panicking() {
        use crate::searcher::{FaultModel, FaultProfile, FaultStats, FaultyEnv};
        use std::sync::{Arc, Mutex};

        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        // every profiling pass fails: the searcher must degrade to
        // uniform exploration rather than panic on missing counters
        let mut model = FaultModel::for_profile(FaultProfile::Flaky);
        model.persistent_rate = 0.0;
        model.transient_rate = 0.0;
        model.profile_fail_rate = 1.0;
        let inner = ReplayEnv::new(rec, gpu, CostModel::default());
        let stats = Arc::new(Mutex::new(FaultStats::default()));
        let mut env = FaultyEnv::new(inner, model, 1, 2, stats);
        let trace = ProfileSearcher::new(&oracle, 0.5, 4)
            .run(&mut env, &Budget::tests(30));
        assert_eq!(trace.len(), 30);
        assert!(trace.steps.iter().all(|s| s.runtime_ms.is_finite()));
        assert!(trace.steps.iter().all(|s| s.profiled));
    }

    /// Test stand-in for an environment over a degenerate space: any
    /// measurement would be a bug, so it panics.
    struct EmptyEnv {
        space: crate::tuning::Space,
        gpu: GpuSpec,
    }

    impl EvalEnv for EmptyEnv {
        fn space(&self) -> &crate::tuning::Space {
            &self.space
        }
        fn measure(
            &mut self,
            _idx: usize,
            _profile: bool,
        ) -> crate::searcher::Measurement {
            unreachable!("an empty space has nothing to measure")
        }
        fn cost_so_far(&self) -> f64 {
            0.0
        }
        fn gpu(&self) -> &GpuSpec {
            &self.gpu
        }
    }

    #[test]
    fn empty_space_returns_empty_trace_not_panic() {
        use crate::tuning::{ParamDef, Space};
        // a parameter whose value list became empty enumerates to a
        // zero-configuration space — `rng.below(0)` used to panic here
        let mut p = ParamDef::new("X", &[1]);
        p.values.clear();
        let space = Space::enumerate("empty", vec![p], |_| true);
        assert_eq!(space.len(), 0);

        let matrix = Arc::new(PredictionMatrix::from_fn(0, |_, _| 0.0));
        let mut env = EmptyEnv {
            space,
            gpu: GpuSpec::gtx750(),
        };
        let trace = ProfileSearcher::shared(matrix, 0.5, 1)
            .run(&mut env, &Budget::tests(10));
        assert!(trace.is_empty());
    }

    #[test]
    fn next_unexplored_matches_the_pool_reference_draw_for_draw() {
        // the zero-allocation rank-scan must select exactly what the
        // retired pool-collecting code selected off the same rng draw
        let patterns: [&[bool]; 4] = [
            &[false, true, false, true, true, false, false],
            &[true, true, true],
            &[false; 5],
            &[true, false],
        ];
        for (pi, explored) in patterns.iter().enumerate() {
            for seed in 0..20u64 {
                let got = next_unexplored(explored, &mut Rng::new(seed));
                let pool: Vec<usize> = explored
                    .iter()
                    .enumerate()
                    .filter(|(_, &done)| !done)
                    .map(|(i, _)| i)
                    .collect();
                let want = if pool.is_empty() {
                    None
                } else {
                    Some(pool[Rng::new(seed).below(pool.len())])
                };
                assert_eq!(got, want, "pattern {pi} seed {seed}");
            }
        }
    }

    #[test]
    fn scoring_jobs_do_not_change_the_trace() {
        // the batched global scoring round is byte-identical to the
        // serial one, so the whole search is too — at any worker count
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let matrix = Arc::new(PredictionMatrix::from_recorded(&rec));
        for seed in [0u64, 7] {
            let steps = |jobs: usize| {
                let mut env = ReplayEnv::new(
                    rec.clone(),
                    gpu.clone(),
                    CostModel::default(),
                );
                ProfileSearcher::shared(Arc::clone(&matrix), 0.5, seed)
                    .with_scoring_jobs(jobs)
                    .run(&mut env, &Budget::tests(30))
                    .steps
                    .iter()
                    .map(|s| (s.idx, s.profiled, s.runtime_ms.to_bits()))
                    .collect::<Vec<_>>()
            };
            assert_eq!(steps(1), steps(4), "seed {seed}");
        }
    }

    #[test]
    fn lazy_profile_tunes_a_million_config_space_in_bounded_memory() {
        use crate::benchmarks::{by_name, OnDemandRecorder};
        use crate::searcher::OnDemandEnv;

        let bench = by_name("synth-grid").unwrap();
        let gpu = GpuSpec::gtx1070();
        let input = bench.default_input();
        let recorder =
            Arc::new(OnDemandRecorder::new(bench, gpu, input));
        assert!(recorder.space().len() >= 1 << 20);

        let mut env =
            OnDemandEnv::new(Arc::clone(&recorder), CostModel::default());
        let trace = LazyProfileSearcher::new(Arc::clone(&recorder), 0.5, 7)
            .run(&mut env, &Budget::tests(24));
        assert_eq!(trace.len(), 24);
        // same 1 profiled + n plain schedule as the eager searcher
        assert!(trace.steps[0].profiled);
        assert!(!trace.steps[1].profiled);
        assert!(trace.steps.iter().all(|s| s.runtime_ms.is_finite()));
        // plain steps never repeat a configuration
        let mut plain: Vec<usize> = trace
            .steps
            .iter()
            .filter(|s| !s.profiled)
            .map(|s| s.idx)
            .collect();
        let n_plain = plain.len();
        plain.sort_unstable();
        plain.dedup();
        assert_eq!(plain.len(), n_plain);
        // the memo holds only the scored balls + visited configs — the
        // bounded-memory contract (vs 2^20 eager simulations)
        assert!(
            recorder.visited() < 10_000,
            "visited {} of {} configs",
            recorder.visited(),
            recorder.space().len()
        );
        // runtimes genuinely vary across the visited sample
        let lo = trace
            .steps
            .iter()
            .map(|s| s.runtime_ms)
            .fold(f64::MAX, f64::min);
        let hi = trace
            .steps
            .iter()
            .map(|s| s.runtime_ms)
            .fold(0.0f64, f64::max);
        assert!(hi > lo);
    }

    #[test]
    fn lazy_profile_works_on_small_eager_spaces_too() {
        use crate::benchmarks::OnDemandRecorder;
        use crate::searcher::OnDemandEnv;

        // the lazy arm is not restricted to huge spaces: over a small
        // dense space it must terminate and keep the plain-step
        // uniqueness invariant even once the space is nearly exhausted
        let bench = crate::benchmarks::by_name("coulomb").unwrap();
        let gpu = GpuSpec::gtx750();
        let input = bench.default_input();
        let n = bench.space().len();
        let recorder = Arc::new(OnDemandRecorder::new(bench, gpu, input));
        let mut env =
            OnDemandEnv::new(Arc::clone(&recorder), CostModel::default());
        let trace = LazyProfileSearcher::new(recorder, 0.5, 3)
            .with_radius(1)
            .run(&mut env, &Budget::tests(n * 3));
        assert!(trace.len() <= n * 3);
        assert!(!trace.is_empty());
    }
}
