//! The profile-based searcher — the paper's Algorithm 1.
//!
//! Each profiling round:
//! 1. empirically measure the current `c_profile` *with* counters;
//! 2. run the expert system: bottlenecks (Eqs. 6–14) → ΔPC (Eq. 15);
//! 3. score every unexplored configuration with the TP→PC model
//!    (Eq. 16) and normalize (Eq. 17);
//! 4. take `n` weighted-random steps *without* profiling (plain runs are
//!    faster); the best runtime seen becomes the next `c_profile`.
//!
//! The model may have been trained on a different GPU or input — the
//! scoring compares model predictions for both configurations, never
//! model predictions against live measurements (§3.6).

use crate::counters::CounterVec;
use crate::expert::{
    active_deltas, analyze, normalize_scores, react, score_active,
};
use crate::model::TpPcModel;
use crate::util::rng::Rng;

use super::{budget_done, Budget, EvalEnv, Searcher, SearchTrace, Step};

pub struct ProfileSearcher<'m> {
    model: &'m dyn TpPcModel,
    /// Steps without profiling per round (the paper's `n`, default 5).
    pub n_unprofiled: usize,
    /// The Eq. 15 threshold (0.7 default, 0.5 for instruction-bound).
    pub inst_reaction: f64,
    /// Restrict scoring to the Hamming-ball of this radius around the
    /// profiled configuration (the paper's §3.9.1 local-search variant
    /// and footnote-5 huge-space device). `None` = global (paper
    /// default).
    pub neighbourhood: Option<usize>,
    rng: Rng,
}

impl<'m> ProfileSearcher<'m> {
    pub fn new(model: &'m dyn TpPcModel, inst_reaction: f64, seed: u64) -> Self {
        ProfileSearcher {
            model,
            n_unprofiled: 5,
            inst_reaction,
            neighbourhood: None,
            rng: Rng::new(seed),
        }
    }

    /// Local-search variant (§3.9.1): only configurations within
    /// `radius` parameter changes of the profiled configuration are
    /// scored each round; falls back to global scoring when the
    /// neighbourhood is exhausted.
    pub fn with_neighbourhood(mut self, radius: usize) -> Self {
        self.neighbourhood = Some(radius);
        self
    }
}

impl Searcher for ProfileSearcher<'_> {
    fn name(&self) -> &'static str {
        "profile"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // Pre-compute model predictions for the whole space once — they
        // depend only on the configuration (hot path: Eq. 16 runs over
        // all unexplored configurations each round).
        let preds: Vec<CounterVec> = env
            .space()
            .configs
            .iter()
            .map(|c| self.model.predict(c))
            .collect();
        // the local variant needs the space across measurement calls
        let local_space = self.neighbourhood.map(|_| env.space().clone());

        let mut explored = vec![false; size];
        let mut trace = SearchTrace::default();
        let mut scores = vec![0.0f64; size];

        let mut c_profile = self.rng.below(size);

        'outer: loop {
            if budget_done(&trace, budget, env) {
                break;
            }
            // --- profile the current configuration -----------------------
            let m = env.measure(c_profile, true);
            explored[c_profile] = true;
            trace.push(Step {
                idx: c_profile,
                runtime_ms: m.runtime_ms,
                profiled: true,
                cost_after_s: env.cost_so_far(),
                build: false,
            });
            let mut t_best_round = m.runtime_ms;

            // --- expert system -------------------------------------------
            let counters = m.counters.expect("profiled run must yield counters");
            let bottlenecks = analyze(&counters, env.gpu());
            let delta = react(&bottlenecks, self.inst_reaction);

            // --- score the candidate set (Eqs. 16–17) --------------------
            // candidate set: whole space, or the §3.9.1 neighbourhood
            let candidates: Option<Vec<usize>> =
                self.neighbourhood.and_then(|radius| {
                    let space = local_space.as_ref().unwrap();
                    let from = &space.configs[c_profile];
                    let nb: Vec<usize> = space
                        .neighbours(from, radius)
                        .into_iter()
                        .filter(|&i| !explored[i])
                        .collect();
                    // fall back to global when the ball is exhausted
                    (nb.len() >= self.n_unprofiled).then_some(nb)
                });

            let pred_profile = &preds[c_profile];
            let active = active_deltas(&delta);
            match &candidates {
                None => {
                    for k in 0..size {
                        scores[k] = if explored[k] {
                            f64::NEG_INFINITY // flag: excluded
                        } else {
                            score_active(&active, pred_profile, &preds[k])
                        };
                    }
                }
                Some(nb) => {
                    scores.fill(f64::NEG_INFINITY);
                    for &k in nb {
                        scores[k] =
                            score_active(&active, pred_profile, &preds[k]);
                    }
                }
            }
            // normalize only the live entries
            {
                let mut live: Vec<f64> = scores
                    .iter()
                    .copied()
                    .filter(|s| s.is_finite())
                    .collect();
                if live.is_empty() {
                    break; // space exhausted
                }
                normalize_scores(&mut live);
                let mut it = live.into_iter();
                for s in scores.iter_mut() {
                    if s.is_finite() {
                        *s = it.next().unwrap();
                    } else {
                        *s = 0.0;
                    }
                }
            }

            // --- n weighted-random plain steps ---------------------------
            for _ in 0..self.n_unprofiled {
                if budget_done(&trace, budget, env) {
                    break 'outer;
                }
                let Some(l) = self.rng.choose_weighted(&scores) else {
                    break 'outer; // nothing selectable left
                };
                let m = env.measure(l, false);
                explored[l] = true;
                scores[l] = 0.0;
                trace.push(Step {
                    idx: l,
                    runtime_ms: m.runtime_ms,
                    profiled: false,
                    cost_after_s: env.cost_so_far(),
                    build: false,
                });
                // Algorithm 1 line 20: the round's fastest kernel becomes
                // the next configuration to profile.
                if m.runtime_ms <= t_best_round {
                    t_best_round = m.runtime_ms;
                    c_profile = l;
                }
            }
            // If the profiled config stayed the round's best, re-profiling
            // it adds no information — hop to the best unexplored-scored
            // config's neighbourhood by keeping c_profile (the paper
            // re-profiles the incumbent; we follow the paper).
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb, Transpose};
    use crate::gpusim::GpuSpec;
    use crate::model::OracleModel;
    use crate::searcher::{CostModel, RandomSearcher, ReplayEnv};
    use crate::util::stats::mean;

    fn replay(bench: &dyn Benchmark, gpu: GpuSpec) -> ReplayEnv {
        let rec = record_space(bench, &gpu, &bench.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    /// Average steps to a well-performing configuration over `reps`.
    fn avg_steps(
        mk: &mut dyn FnMut(u64, &mut ReplayEnv) -> SearchTrace,
        env_fn: &dyn Fn() -> ReplayEnv,
        reps: u64,
    ) -> f64 {
        let mut steps = Vec::new();
        for seed in 0..reps {
            let mut env = env_fn();
            let thr = env.recorded().best_time() * 1.1;
            let trace = mk(seed, &mut env);
            steps.push(
                trace.tests_to_threshold(thr).unwrap_or(trace.len()) as f64,
            );
        }
        mean(&steps)
    }

    #[test]
    fn profiled_and_plain_steps_interleave() {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);
        let mut env = ReplayEnv::new(rec, gpu, CostModel::default());
        let mut s = ProfileSearcher::new(&oracle, 0.5, 7);
        let trace = s.run(&mut env, &Budget::tests(24));
        assert_eq!(trace.len(), 24);
        // schedule: 1 profiled + 5 plain, repeated
        assert!(trace.steps[0].profiled);
        assert!(!trace.steps[1].profiled);
        assert!(trace.steps[6].profiled);
        let profiled = trace.steps.iter().filter(|s| s.profiled).count();
        assert_eq!(profiled, 4);
    }

    #[test]
    fn beats_random_with_oracle_pcs_on_coulomb() {
        // the §4.3 experiment in miniature: oracle PCs, same GPU
        let gpu = GpuSpec::gtx1070();
        let env_fn = || replay(&Coulomb, GpuSpec::gtx1070());
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let oracle = OracleModel::new(&rec);

        let reps = 60;
        let rand_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                RandomSearcher::new(seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        let prof_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                ProfileSearcher::new(&oracle, 0.5, seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        assert!(
            prof_steps < rand_steps,
            "profile {prof_steps} vs random {rand_steps}"
        );
    }

    #[test]
    fn beats_random_on_transpose_memory_bound() {
        let gpu = GpuSpec::rtx2080();
        let env_fn = || replay(&Transpose, GpuSpec::rtx2080());
        let rec = record_space(&Transpose, &gpu, &Transpose.default_input());
        let oracle = OracleModel::new(&rec);
        let reps = 40;
        let rand_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                RandomSearcher::new(seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        let prof_steps = avg_steps(
            &mut |seed, env| {
                let thr = env.recorded().best_time() * 1.1;
                ProfileSearcher::new(&oracle, 0.7, seed)
                    .run(env, &Budget::until(thr, 10_000))
            },
            &env_fn,
            reps,
        );
        assert!(
            prof_steps < rand_steps * 1.05,
            "profile {prof_steps} vs random {rand_steps}"
        );
    }

    #[test]
    fn local_variant_converges_and_terminates() {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let n = rec.space.len();
        let oracle = OracleModel::new(&rec);
        let thr = rec.best_time() * 1.1;
        let mut env = ReplayEnv::new(rec, gpu, CostModel::default());
        let mut s =
            ProfileSearcher::new(&oracle, 0.5, 11).with_neighbourhood(2);
        let trace = s.run(&mut env, &Budget::until(thr, n * 3));
        assert!(
            trace.steps.iter().any(|st| st.runtime_ms <= thr),
            "local variant failed to reach 1.1x best in {} steps",
            trace.len()
        );
    }

    #[test]
    fn exhausts_space_without_hanging() {
        let gpu = GpuSpec::gtx750();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let n = rec.space.len();
        let oracle = OracleModel::new(&rec);
        let mut env = ReplayEnv::new(rec, gpu, CostModel::default());
        let mut s = ProfileSearcher::new(&oracle, 0.5, 3);
        let trace = s.run(&mut env, &Budget::tests(n * 3));
        // profiled re-visits allowed; plain steps never repeat, so the
        // trace is bounded and the searcher terminates
        assert!(trace.len() <= n * 3);
    }
}
