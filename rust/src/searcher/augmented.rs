//! [`ProfileAugmented`] — the paper's Eq. 16 PC-model scoring grafted
//! onto *any* base searcher, so the profile method composes with (not
//! just competes against) the stronger baselines of the zoo.
//!
//! The combinator interposes a guided environment between the base
//! searcher and the real [`EvalEnv`]:
//!
//! * every `n+1`-th measurement is promoted to a *profiled* run (the
//!   paper's 1-profiled + `n`-plain cadence), and its counters feed the
//!   expert system: bottlenecks (Eqs. 6–14) → ΔPC (Eq. 15), with
//!   dropped counters masked exactly like Algorithm 1;
//! * every *plain* proposal the base makes is re-ranked against the
//!   model: the proposal and its unexplored Hamming ball (radius
//!   [`radius`](ProfileAugmented::radius)) are scored with Eq. 16
//!   relative to the last profiled configuration, and the measurement
//!   is redirected to the arg-max candidate. Eq. 17's normalization is
//!   monotone, so ranking raw scores picks the same winner without the
//!   weighted draw — the base searcher supplies the stochasticity here.
//!
//! The redirection is invisible to the base searcher (it receives the
//! real measurement of the substituted configuration), which keeps any
//! base strategy compatible; the authoritative trace — actual indices,
//! profiled flags, costs — is kept by the wrapper and returned from
//! [`Searcher::run`]. Scoring stays model-vs-model (§3.6): predictions
//! against predictions, never against live measurements. Works against
//! both model contexts: a densified [`PredictionMatrix`] (eager cells)
//! or an [`OnDemandRecorder`] (large-space cells — the ball-local
//! candidate set means nothing space-sized is ever touched).
//!
//! Determinism: the wrapper itself draws no randomness — redirection is
//! an arg-max with ascending-index tie-breaks — so a run is exactly as
//! deterministic as its base searcher.
//!
//! [`PredictionMatrix`]: crate::model::PredictionMatrix
//! [`OnDemandRecorder`]: crate::benchmarks::OnDemandRecorder

use std::sync::Arc;

use crate::benchmarks::OnDemandRecorder;
use crate::expert::{active_deltas, analyze, react};
use crate::gpusim::GpuSpec;
use crate::model::PredictionMatrix;
use crate::counters::CounterVec;
use crate::tuning::Space;

use super::{
    Budget, EvalEnv, Measurement, ModelCtx, Searcher, SearchTrace, Step,
};

/// Any base searcher, with its candidate proposals re-ranked by the
/// paper's PC-model scoring. Construct directly or via the
/// `"profile+<base>"` spec syntax.
pub struct ProfileAugmented<S: Searcher> {
    base: S,
    model: ModelCtx,
    /// The Eq. 15 threshold (0.7 default, 0.5 for instruction-bound).
    pub inst_reaction: f64,
    /// Hamming-ball radius scored around each base proposal.
    pub radius: usize,
    /// Plain steps between profiled runs (the paper's `n`, default 5).
    pub n_unprofiled: usize,
    name: &'static str,
}

/// `"profile+<base>"` — [`Searcher::name`] needs a `'static` str, so
/// the composed names are a closed table over the registry's
/// augmentable bases.
fn augmented_name(base: &str) -> &'static str {
    match base {
        "random" => "profile+random",
        "basin_hopping" => "profile+basin_hopping",
        "starchart" => "profile+starchart",
        "annealing" => "profile+annealing",
        "ga" => "profile+ga",
        "de" => "profile+de",
        "dual_annealing" => "profile+dual_annealing",
        _ => "profile+base",
    }
}

impl<S: Searcher> ProfileAugmented<S> {
    /// # Panics
    ///
    /// On [`ModelCtx::None`]: Eq. 16 scoring needs predicted counters.
    pub fn new(base: S, model: ModelCtx, inst_reaction: f64) -> Self {
        assert!(
            !matches!(model, ModelCtx::None),
            "profile augmentation needs a model context (prediction \
             matrix or on-demand recorder)"
        );
        let name = augmented_name(base.name());
        ProfileAugmented {
            base,
            model,
            inst_reaction,
            radius: 2,
            n_unprofiled: 5,
            name,
        }
    }
}

impl<S: Searcher> Searcher for ProfileAugmented<S> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        if size == 0 {
            return SearchTrace::default();
        }
        let (matrix, recorder) = match &self.model {
            ModelCtx::Eager { matrix } => {
                assert_eq!(
                    matrix.n_configs(),
                    size,
                    "prediction matrix covers a different space than the \
                     environment replays"
                );
                (Some(Arc::clone(matrix)), None)
            }
            ModelCtx::Lazy { recorder } => {
                assert_eq!(
                    recorder.space().len(),
                    size,
                    "on-demand recorder covers a different space than the \
                     environment evaluates"
                );
                (None, Some(Arc::clone(recorder)))
            }
            ModelCtx::None => unreachable!("rejected at construction"),
        };
        // Build the neighbour index before cloning so all runs share it.
        env.space().neighbour_index();
        let space = env.space().clone();
        let mut guided = GuidedEnv {
            inner: env,
            space,
            matrix,
            recorder,
            inst_reaction: self.inst_reaction,
            radius: self.radius,
            cadence: self.n_unprofiled + 1,
            explored: vec![false; size],
            log: SearchTrace::default(),
            measures: 0,
            c_profile: 0,
            active: Vec::new(),
            pred_profile: None,
            armed: false,
        };
        // The base's own trace records the indices it *proposed*; the
        // wrapper's log records what was actually measured — that log
        // is the authoritative trace.
        let _ = self.base.run(&mut guided, budget);
        guided.log
    }
}

/// The guided environment: measurements pass through to `inner`, plain
/// proposals are redirected to the best-scoring unexplored candidate in
/// their Hamming ball.
struct GuidedEnv<'a> {
    inner: &'a mut dyn EvalEnv,
    space: Space,
    matrix: Option<Arc<PredictionMatrix>>,
    recorder: Option<Arc<OnDemandRecorder>>,
    inst_reaction: f64,
    radius: usize,
    /// Every `cadence`-th measurement is profiled.
    cadence: usize,
    explored: Vec<bool>,
    log: SearchTrace,
    measures: usize,
    /// Reaction state, armed after the first successful profiled run.
    c_profile: usize,
    /// Eager: matrix (column, ΔPC) pairs; lazy: counter-slot deltas.
    active: Vec<(usize, f64)>,
    /// Lazy only: predicted counters of `c_profile`.
    pred_profile: Option<CounterVec>,
    armed: bool,
}

impl GuidedEnv<'_> {
    /// Eq. 16 for one candidate, relative to the last profiled config.
    fn score(&self, k: usize) -> f64 {
        match (&self.matrix, &self.recorder) {
            (Some(m), _) => m.score_one(self.c_profile, &self.active, k),
            (None, Some(r)) => crate::expert::score_active(
                &self.active,
                self.pred_profile.as_ref().expect("armed lazy reaction"),
                &r.record(k).counters,
            ),
            (None, None) => unreachable!("one scoring backend always set"),
        }
    }

    /// The best-scoring unexplored candidate among `idx` and its
    /// Hamming ball; ties keep the first seen (the proposal itself,
    /// then ascending neighbour order) — fully deterministic.
    fn redirect(&self, idx: usize) -> usize {
        let mut best_k: Option<usize> = None;
        let mut best_s = f64::NEG_INFINITY;
        let from = self.space.config_at(idx);
        let ball = self.space.neighbours(&from, self.radius);
        for k in std::iter::once(idx).chain(ball) {
            if self.explored[k] {
                continue;
            }
            // non-finite scores (reaction on a zero-prediction column)
            // never outrank a finite candidate; the first candidate —
            // the proposal itself, then ascending neighbour order —
            // wins ties, so redirection is fully deterministic
            let s = self.score(k);
            let s = if s.is_finite() { s } else { f64::NEG_INFINITY };
            if best_k.is_none() || s > best_s {
                best_k = Some(k);
                best_s = s;
            }
        }
        best_k.unwrap_or(idx)
    }

    /// Feed a profiled measurement's counters through the expert
    /// system and re-arm the scorer.
    fn arm(&mut self, target: usize, m: &Measurement) {
        let Some(counters) = &m.counters else {
            return;
        };
        if !m.is_ok() {
            return;
        }
        let bottlenecks = analyze(counters, self.inner.gpu());
        let mut delta = react(&bottlenecks, self.inst_reaction);
        // never react on counters the profiler failed to collect
        for &c in &m.dropped {
            delta.0.set(c, 0.0);
        }
        match (&self.matrix, &self.recorder) {
            (Some(matrix), _) => {
                self.active = matrix.active_columns(&delta);
            }
            (None, Some(recorder)) => {
                self.active = active_deltas(&delta);
                self.pred_profile = Some(recorder.record(target).counters);
            }
            (None, None) => unreachable!("one scoring backend always set"),
        }
        self.c_profile = target;
        self.armed = true;
    }
}

impl EvalEnv for GuidedEnv<'_> {
    fn space(&self) -> &Space {
        self.inner.space()
    }

    fn measure(&mut self, idx: usize, profile: bool) -> Measurement {
        let slot = self.measures;
        self.measures += 1;
        let profiled = profile || slot % self.cadence == 0;
        // profiled runs measure the base's own proposal (anchoring the
        // reaction to the base's trajectory); plain runs are redirected
        let target = if !profiled && self.armed {
            self.redirect(idx)
        } else {
            idx
        };
        let m = self.inner.measure(target, profiled);
        self.explored[target] = true;
        self.log.push(Step {
            idx: target,
            runtime_ms: m.runtime_ms,
            profiled,
            cost_after_s: self.inner.cost_so_far(),
            build: false,
        });
        if profiled {
            self.arm(target, &m);
        }
        m
    }

    fn cost_so_far(&self) -> f64 {
        self.inner.cost_so_far()
    }

    fn gpu(&self) -> &GpuSpec {
        self.inner.gpu()
    }

    fn known_best_ms(&self) -> Option<f64> {
        self.inner.known_best_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{
        Budget, CostModel, RandomSearcher, ReplayEnv, SearcherSpec,
    };
    use crate::tuning::ParamDef;

    fn env() -> ReplayEnv {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    fn eager_model(e: &ReplayEnv) -> ModelCtx {
        ModelCtx::Eager {
            matrix: Arc::new(PredictionMatrix::from_recorded(e.recorded())),
        }
    }

    #[test]
    fn runs_to_budget_with_profiled_cadence() {
        let mut e = env();
        let model = eager_model(&e);
        let mut s =
            ProfileAugmented::new(RandomSearcher::new(7), model, 0.5);
        let trace = s.run(&mut e, &Budget::tests(24));
        assert_eq!(trace.len(), 24);
        assert_eq!(s.name(), "profile+random");
        // 1 profiled + 5 plain cadence, like Algorithm 1
        assert!(trace.steps[0].profiled);
        assert!(!trace.steps[1].profiled);
        assert!(trace.steps[6].profiled);
        assert_eq!(trace.steps.iter().filter(|s| s.profiled).count(), 4);
    }

    #[test]
    fn deterministic_per_seed_and_unique_plain_steps() {
        let run = |seed| {
            let mut e = env();
            let model = eager_model(&e);
            ProfileAugmented::new(RandomSearcher::new(seed), model, 0.5)
                .run(&mut e, &Budget::tests(40))
                .steps
                .iter()
                .map(|s| (s.idx, s.profiled))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn empty_space_yields_empty_trace() {
        let mut p = ParamDef::new("X", &[1]);
        p.values.clear();
        let space = crate::tuning::Space::enumerate(
            "empty",
            vec![p],
            |_| true,
        );
        let model = ModelCtx::Eager {
            matrix: Arc::new(PredictionMatrix::from_fn(0, |_, _| 0.0)),
        };
        struct EmptyEnv {
            space: crate::tuning::Space,
            gpu: GpuSpec,
        }
        impl EvalEnv for EmptyEnv {
            fn space(&self) -> &crate::tuning::Space {
                &self.space
            }
            fn measure(&mut self, _: usize, _: bool) -> Measurement {
                unreachable!("no configuration to measure")
            }
            fn cost_so_far(&self) -> f64 {
                0.0
            }
            fn gpu(&self) -> &GpuSpec {
                &self.gpu
            }
        }
        let mut e = EmptyEnv {
            space,
            gpu: GpuSpec::gtx1070(),
        };
        let trace =
            ProfileAugmented::new(RandomSearcher::new(0), model, 0.5)
                .run(&mut e, &Budget::tests(10));
        assert!(trace.is_empty());
    }

    /// The satellite regression gate: Eq. 16 guidance must make random
    /// search strictly better (median steps to 1.1× best) on the smoke
    /// grid — the composition claim, tested like the PR-4 tree gate.
    #[test]
    fn augmented_random_beats_plain_random_median_steps() {
        let reps = 40u64;
        let median_steps = |augment: bool| {
            let mut steps: Vec<f64> = Vec::new();
            for seed in 0..reps {
                let mut e = env();
                let thr = e.recorded().best_time() * 1.1;
                let budget = Budget::until(thr, 10_000);
                let trace = if augment {
                    let model = eager_model(&e);
                    ProfileAugmented::new(
                        RandomSearcher::new(seed),
                        model,
                        0.5,
                    )
                    .run(&mut e, &budget)
                } else {
                    RandomSearcher::new(seed).run(&mut e, &budget)
                };
                steps.push(
                    trace.tests_to_threshold(thr).unwrap_or(trace.len())
                        as f64,
                );
            }
            steps.sort_by(f64::total_cmp);
            steps[steps.len() / 2]
        };
        let plain = median_steps(false);
        let augmented = median_steps(true);
        assert!(
            augmented < plain,
            "profile+random {augmented} vs random {plain} median steps"
        );
    }

    #[test]
    fn builds_through_the_spec_for_every_augmentable_base() {
        let e = env();
        for name in [
            "profile+random",
            "profile+ga",
            "profile+de",
            "profile+dual_annealing",
            "profile+annealing",
            "profile+basin_hopping",
            "profile+starchart",
        ] {
            let spec = SearcherSpec::parse(name).unwrap();
            assert!(spec.reads_model());
            let ctx = crate::searcher::CellCtx::new(eager_model(&e), 0.5, 1);
            let mut s = spec.build(&ctx);
            assert_eq!(s.name(), name);
            let mut fresh = env();
            let trace = s.run(&mut fresh, &Budget::tests(12));
            assert_eq!(trace.len(), 12);
        }
    }
}
