//! Differential evolution (DE/rand/1/bin) — a strong baseline from
//! "Benchmarking optimization algorithms for auto-tuning GPU kernels"
//! (arxiv 2210.01465).
//!
//! Discrete adaptation: genomes are per-parameter *value positions*
//! (indices into each [`ParamDef::values`] list), so the classic mutant
//! arithmetic `a + F·(b − c)` runs on ordinals, is rounded, and is
//! clamped to each parameter's domain. Trial vectors are produced by
//! binomial crossover (rate `cr`, one forced dimension), mapped back
//! onto space indices via [`Space::index_of`], and accepted greedily
//! against their target member. Trials the constraint pruned away (or
//! that were already measured) leave the target in place; a generation
//! that measures nothing injects a fresh random member instead, so the
//! search cannot spin without spending budget.
//!
//! Spaces too small for rand/1 donor selection (fewer than 4 members)
//! degrade to random sampling — correct, if uninteresting, at toy
//! sizes.

use crate::tuning::Config;
use crate::util::rng::Rng;

use super::{
    budget_done, draw_unmeasured, Budget, EvalEnv, Searcher, SearchTrace, Step,
};

struct Member {
    /// Per-dimension positions into `ParamDef::values`.
    pos: Vec<usize>,
    idx: usize,
    fit: f64,
}

pub struct DifferentialEvolution {
    rng: Rng,
    /// Population size (capped at the space size).
    pub pop_size: usize,
    /// Differential weight `F` applied to position deltas.
    pub weight: f64,
    /// Binomial crossover rate.
    pub cr: f64,
}

impl DifferentialEvolution {
    pub fn new(seed: u64) -> Self {
        DifferentialEvolution {
            rng: Rng::new(seed),
            pop_size: 16,
            weight: 0.5,
            cr: 0.9,
        }
    }

    fn eval(
        &mut self,
        env: &mut dyn EvalEnv,
        trace: &mut SearchTrace,
        measured: &mut [Option<f64>],
        idx: usize,
    ) -> f64 {
        if let Some(t) = measured[idx] {
            return t;
        }
        let m = env.measure(idx, false);
        measured[idx] = Some(m.runtime_ms);
        trace.push(Step {
            idx,
            runtime_ms: m.runtime_ms,
            profiled: false,
            cost_after_s: env.cost_so_far(),
            build: false,
        });
        m.runtime_ms
    }

    /// Three donor indices, distinct from each other and from `i`.
    /// Requires a population of at least 4.
    fn donors(&mut self, len: usize, i: usize) -> (usize, usize, usize) {
        let mut draw = |taken: &[usize]| loop {
            let k = self.rng.below(len);
            if !taken.contains(&k) {
                return k;
            }
        };
        let a = draw(&[i]);
        let b = draw(&[i, a]);
        let c = draw(&[i, a, b]);
        (a, b, c)
    }
}

/// Per-dimension positions of a configuration's values (first match —
/// deterministic even on degenerate duplicate-value spaces).
fn positions_of(space: &crate::tuning::Space, cfg: &Config) -> Vec<usize> {
    cfg.0
        .iter()
        .enumerate()
        .map(|(d, v)| {
            space.params[d]
                .values
                .iter()
                .position(|w| w == v)
                .expect("configuration value outside its parameter domain")
        })
        .collect()
}

impl Searcher for DifferentialEvolution {
    fn name(&self) -> &'static str {
        "de"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // degenerate space: nothing to draw — empty trace, not a panic
        if size == 0 {
            return SearchTrace::default();
        }
        env.space().neighbour_index();
        let space = env.space().clone();
        let dims = space.dims();

        let mut trace = SearchTrace::default();
        let mut measured: Vec<Option<f64>> = vec![None; size];

        // --- initial population --------------------------------------
        let target_pop = self.pop_size.min(size);
        let mut pop: Vec<Member> = Vec::with_capacity(target_pop);
        while pop.len() < target_pop && !budget_done(&trace, budget, env) {
            let Some(idx) = draw_unmeasured(&measured, &mut self.rng) else {
                break;
            };
            let fit = self.eval(env, &mut trace, &mut measured, idx);
            let pos = positions_of(&space, &space.config_at(idx));
            pop.push(Member { pos, idx, fit });
        }

        // rand/1 donor selection needs 4 distinct members; tiny spaces
        // (or tiny budgets) degrade to plain random sampling
        if pop.len() < 4 || dims == 0 {
            while !budget_done(&trace, budget, env) {
                match draw_unmeasured(&measured, &mut self.rng) {
                    Some(idx) => {
                        self.eval(env, &mut trace, &mut measured, idx);
                    }
                    None => break,
                }
            }
            return trace;
        }

        // --- generations ---------------------------------------------
        'outer: loop {
            let mut measured_this_gen = false;
            for i in 0..pop.len() {
                if budget_done(&trace, budget, env) {
                    break 'outer;
                }
                let (a, b, c) = self.donors(pop.len(), i);
                let jrand = self.rng.below(dims);
                let mut trial: Vec<usize> = Vec::with_capacity(dims);
                for d in 0..dims {
                    let take_mutant =
                        d == jrand || self.rng.f64() < self.cr;
                    if take_mutant {
                        let card = space.params[d].values.len();
                        let delta = pop[b].pos[d] as f64 - pop[c].pos[d] as f64;
                        let v = pop[a].pos[d] as f64 + self.weight * delta;
                        let v = v.round().clamp(0.0, (card - 1) as f64);
                        trial.push(v as usize);
                    } else {
                        trial.push(pop[i].pos[d]);
                    }
                }
                let cfg = Config(
                    trial
                        .iter()
                        .enumerate()
                        .map(|(d, &p)| space.params[d].values[p])
                        .collect(),
                );
                // pruned or already-measured trials leave the target in
                // place — the stagnation fallback below keeps progress
                let Some(idx) = space
                    .index_of(&cfg)
                    .filter(|&k| measured[k].is_none())
                else {
                    continue;
                };
                let fit = self.eval(env, &mut trace, &mut measured, idx);
                measured_this_gen = true;
                // greedy selection (failed runs — infinite fitness —
                // never replace a finite target)
                if fit < pop[i].fit {
                    pop[i] = Member {
                        pos: trial,
                        idx,
                        fit,
                    };
                }
            }
            if budget_done(&trace, budget, env) {
                break;
            }
            if !measured_this_gen {
                // the whole generation collapsed onto known ground:
                // inject a fresh random member over the worst slot
                let Some(idx) = draw_unmeasured(&measured, &mut self.rng)
                else {
                    break; // space exhausted
                };
                let fit = self.eval(env, &mut trace, &mut measured, idx);
                let pos = positions_of(&space, &space.config_at(idx));
                let worst = pop
                    .iter()
                    .enumerate()
                    .max_by(|(_, x), (_, y)| x.fit.total_cmp(&y.fit))
                    .map(|(k, _)| k)
                    .expect("population is non-empty");
                pop[worst] = Member { pos, idx, fit };
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{CostModel, ReplayEnv};

    fn env() -> ReplayEnv {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    #[test]
    fn no_repeated_tests_and_budget_respected() {
        let mut e = env();
        let trace =
            DifferentialEvolution::new(1).run(&mut e, &Budget::tests(60));
        assert_eq!(trace.len(), 60);
        let mut idx: Vec<usize> = trace.steps.iter().map(|s| s.idx).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 60, "each empirical test must be unique");
    }

    #[test]
    fn converges_on_small_space() {
        let mut e = env();
        let thr = e.recorded().best_time() * 1.15;
        let trace = DifferentialEvolution::new(5)
            .run(&mut e, &Budget::until(thr, 100_000));
        assert!(trace.steps.last().unwrap().runtime_ms <= thr);
    }

    #[test]
    fn exhausts_space_and_stops() {
        let mut e = env();
        let n = e.space().len();
        let trace =
            DifferentialEvolution::new(2).run(&mut e, &Budget::tests(n * 2));
        assert_eq!(trace.len(), n, "must stop after exhausting the space");
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            DifferentialEvolution::new(seed)
                .run(&mut env(), &Budget::tests(40))
                .steps
                .iter()
                .map(|s| s.idx)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }
}
