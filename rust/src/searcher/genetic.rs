//! Steady-state genetic algorithm — one of the strong baselines of
//! "Benchmarking optimization algorithms for auto-tuning GPU kernels"
//! (arxiv 2210.01465).
//!
//! Individuals are tuning configurations addressed by space index;
//! genomes are their per-parameter value vectors ([`Config`]s).
//! Selection is 2-way tournament, recombination is uniform crossover of
//! the parents' parameter values, mutation resamples a parameter's
//! value uniformly from its domain. A recombined child is mapped back
//! onto a space index via [`Space::index_of`]; children pruned away by
//! the space's constraint fall back to an unexplored Hamming-1
//! neighbour of the first parent, then to a global random draw — so
//! every generation measures exactly one *new* configuration and the
//! search always terminates.
//!
//! All randomness flows from the one seeded [`Rng`], so runs are
//! deterministic per (seed, space) and reports stay byte-identical
//! across `--jobs`.

use crate::tuning::Config;
use crate::util::rng::Rng;

use super::{
    budget_done, draw_unmeasured, Budget, EvalEnv, Searcher, SearchTrace, Step,
};

pub struct GeneticSearcher {
    rng: Rng,
    /// Population size (capped at the space size).
    pub pop_size: usize,
    /// Per-parameter mutation probability.
    pub mutation: f64,
    /// Probability of uniform crossover (vs. cloning the fitter parent).
    pub crossover: f64,
}

impl GeneticSearcher {
    pub fn new(seed: u64) -> Self {
        GeneticSearcher {
            rng: Rng::new(seed),
            pop_size: 16,
            mutation: 0.1,
            crossover: 0.7,
        }
    }

    /// Measure helper: record a step, maintain the measured cache.
    fn eval(
        &mut self,
        env: &mut dyn EvalEnv,
        trace: &mut SearchTrace,
        measured: &mut [Option<f64>],
        idx: usize,
    ) -> f64 {
        if let Some(t) = measured[idx] {
            return t; // cached — no new empirical test
        }
        let m = env.measure(idx, false);
        measured[idx] = Some(m.runtime_ms);
        trace.push(Step {
            idx,
            runtime_ms: m.runtime_ms,
            profiled: false,
            cost_after_s: env.cost_so_far(),
            build: false,
        });
        m.runtime_ms
    }

    /// 2-way tournament: draw two members, the faster wins (failed
    /// runs — infinite runtime — always lose; ties keep the first).
    fn tournament(&mut self, pop: &[(usize, f64)]) -> (usize, f64) {
        let a = pop[self.rng.below(pop.len())];
        let b = pop[self.rng.below(pop.len())];
        if b.1 < a.1 {
            b
        } else {
            a
        }
    }
}

impl Searcher for GeneticSearcher {
    fn name(&self) -> &'static str {
        "ga"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // degenerate space: nothing to draw — empty trace, not a panic
        if size == 0 {
            return SearchTrace::default();
        }
        // Clone shares the lazily built neighbour index (and, for
        // implicit grids, the odometer), so the crossover→index mapping
        // is cheap and shared across the harness's seed repetitions.
        env.space().neighbour_index();
        let space = env.space().clone();
        let dims = space.dims();

        let mut trace = SearchTrace::default();
        let mut measured: Vec<Option<f64>> = vec![None; size];

        // --- initial population --------------------------------------
        let target_pop = self.pop_size.max(2).min(size);
        let mut pop: Vec<(usize, f64)> = Vec::with_capacity(target_pop);
        while pop.len() < target_pop && !budget_done(&trace, budget, env) {
            let Some(idx) = draw_unmeasured(&measured, &mut self.rng) else {
                break;
            };
            let t = self.eval(env, &mut trace, &mut measured, idx);
            pop.push((idx, t));
        }
        if pop.is_empty() {
            return trace;
        }

        // --- steady-state generations --------------------------------
        while !budget_done(&trace, budget, env) {
            let pa = self.tournament(&pop);
            let pb = self.tournament(&pop);
            let a_cfg = space.config_at(pa.0);
            let b_cfg = space.config_at(pb.0);

            // uniform crossover (or clone the tournament-A parent)
            let mut child: Vec<i64> = if self.rng.f64() < self.crossover {
                (0..dims)
                    .map(|d| {
                        if self.rng.f64() < 0.5 {
                            a_cfg.0[d]
                        } else {
                            b_cfg.0[d]
                        }
                    })
                    .collect()
            } else {
                a_cfg.0.clone()
            };
            // per-parameter mutation: resample uniformly from the domain
            for d in 0..dims {
                if self.rng.f64() < self.mutation {
                    let values = &space.params[d].values;
                    child[d] = values[self.rng.below(values.len())];
                }
            }

            // map the genome back onto the space; children the
            // constraint pruned away (or that were already measured)
            // degrade to an unexplored neighbour of parent A, then to a
            // global draw — each iteration measures something new
            let idx = match space
                .index_of(&Config(child))
                .filter(|&i| measured[i].is_none())
            {
                Some(i) => i,
                None => {
                    let nbs: Vec<usize> = space
                        .neighbours(&a_cfg, 1)
                        .into_iter()
                        .filter(|&i| measured[i].is_none())
                        .collect();
                    if nbs.is_empty() {
                        match draw_unmeasured(&measured, &mut self.rng) {
                            Some(i) => i,
                            None => break, // space exhausted
                        }
                    } else {
                        *self.rng.choose(&nbs)
                    }
                }
            };
            let t = self.eval(env, &mut trace, &mut measured, idx);

            // replacement: the child ousts the worst member when it is
            // no worse (ties favour the newcomer, keeping drift alive);
            // the worst of a population with failures is always a
            // failure, so quarantined configs wash out first
            let (worst_pos, &(_, worst_t)) = pop
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| a.1.total_cmp(&b.1))
                .expect("population is non-empty");
            if t <= worst_t {
                pop[worst_pos] = (idx, t);
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{CostModel, ReplayEnv};

    fn env() -> ReplayEnv {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    #[test]
    fn no_repeated_tests_and_budget_respected() {
        let mut e = env();
        let trace = GeneticSearcher::new(1).run(&mut e, &Budget::tests(60));
        assert_eq!(trace.len(), 60);
        let mut idx: Vec<usize> = trace.steps.iter().map(|s| s.idx).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 60, "each empirical test must be unique");
    }

    #[test]
    fn converges_on_small_space() {
        let mut e = env();
        let thr = e.recorded().best_time() * 1.15;
        let trace =
            GeneticSearcher::new(5).run(&mut e, &Budget::until(thr, 100_000));
        assert!(trace.steps.last().unwrap().runtime_ms <= thr);
    }

    #[test]
    fn exhausts_space_and_stops() {
        let mut e = env();
        let n = e.space().len();
        let trace = GeneticSearcher::new(2).run(&mut e, &Budget::tests(n * 2));
        assert_eq!(trace.len(), n, "must stop after exhausting the space");
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            GeneticSearcher::new(seed)
                .run(&mut env(), &Budget::tests(40))
                .steps
                .iter()
                .map(|s| s.idx)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
