//! Dual annealing — the scipy-style generalized annealing baseline of
//! "Benchmarking optimization algorithms for auto-tuning GPU kernels"
//! (arxiv 2210.01465), reduced to the discrete replay setting.
//!
//! Three ingredients distinguish it from plain [`SimulatedAnnealing`]:
//!
//! 1. a temperature-scaled *visiting distribution* — while hot, the
//!    walk jumps anywhere in the space (heavy tails); as it cools the
//!    proposals shrink to the Hamming-1 neighbourhood;
//! 2. a greedy *local search* fired whenever a new incumbent best is
//!    found (the "dual" refinement phase);
//! 3. *re-annealing* — when the temperature bottoms out the schedule
//!    resets, so a long budget buys repeated global restarts instead
//!    of a frozen walk.
//!
//! Failed runs (infinite runtime) are never accepted as the incumbent,
//! mirroring the other walk-based searchers.
//!
//! [`SimulatedAnnealing`]: super::SimulatedAnnealing

use crate::util::rng::Rng;

use super::{
    budget_done, draw_unmeasured, Budget, EvalEnv, Searcher, SearchTrace, Step,
};

pub struct DualAnnealing {
    rng: Rng,
    /// Initial temperature, relative to the incumbent runtime.
    pub t0: f64,
    /// Multiplicative cooling per step.
    pub cooling: f64,
}

/// Temperature floor, as a fraction of `t0`, below which the schedule
/// re-anneals.
const RESTART_RATIO: f64 = 1e-3;

impl DualAnnealing {
    pub fn new(seed: u64) -> Self {
        DualAnnealing {
            rng: Rng::new(seed),
            t0: 1.0,
            cooling: 0.95,
        }
    }

    fn eval(
        &mut self,
        env: &mut dyn EvalEnv,
        trace: &mut SearchTrace,
        measured: &mut [Option<f64>],
        idx: usize,
    ) -> f64 {
        if let Some(t) = measured[idx] {
            return t;
        }
        let m = env.measure(idx, false);
        measured[idx] = Some(m.runtime_ms);
        trace.push(Step {
            idx,
            runtime_ms: m.runtime_ms,
            profiled: false,
            cost_after_s: env.cost_so_far(),
            build: false,
        });
        m.runtime_ms
    }
}

impl Searcher for DualAnnealing {
    fn name(&self) -> &'static str {
        "dual_annealing"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // degenerate space: nothing to draw — empty trace, not a panic
        if size == 0 {
            return SearchTrace::default();
        }
        env.space().neighbour_index();
        let space = env.space().clone();

        let mut trace = SearchTrace::default();
        let mut measured: Vec<Option<f64>> = vec![None; size];

        let mut current = self.rng.below(size);
        let mut t_cur = self.eval(env, &mut trace, &mut measured, current);
        let mut best = current;
        let mut t_best = t_cur;
        let mut temp = self.t0;

        while !budget_done(&trace, budget, env) {
            // --- visiting distribution -------------------------------
            // hot ⇒ global jump, cold ⇒ Hamming-1 step
            let p_jump = (temp / self.t0).min(1.0);
            let next = if self.rng.f64() < p_jump {
                match draw_unmeasured(&measured, &mut self.rng) {
                    Some(i) => i,
                    None => break, // space exhausted
                }
            } else {
                let from = space.config_at(current);
                let nbs: Vec<usize> = space
                    .neighbours(&from, 1)
                    .into_iter()
                    .filter(|&i| measured[i].is_none())
                    .collect();
                if nbs.is_empty() {
                    match draw_unmeasured(&measured, &mut self.rng) {
                        Some(i) => i,
                        None => break,
                    }
                } else {
                    *self.rng.choose(&nbs)
                }
            };
            let t_next = self.eval(env, &mut trace, &mut measured, next);

            // --- Metropolis acceptance on the relative delta ---------
            // failed runs (infinite runtime) are never accepted; a walk
            // that *started* on a failure re-anchors on the first
            // finite runtime
            let accept = t_next.is_finite()
                && (!t_cur.is_finite() || t_next < t_cur || {
                    let d = (t_next - t_cur) / t_cur.max(1e-12);
                    self.rng.f64() < (-d / temp.max(1e-12)).exp()
                });
            if accept {
                current = next;
                t_cur = t_next;
            }

            // --- local search on a new incumbent best ----------------
            if t_next < t_best {
                best = next;
                t_best = t_next;
                let mut improved = true;
                while improved && !budget_done(&trace, budget, env) {
                    improved = false;
                    let from = space.config_at(best);
                    let mut order: Vec<usize> = space
                        .neighbours(&from, 1)
                        .into_iter()
                        .filter(|&i| measured[i].is_none())
                        .collect();
                    self.rng.shuffle(&mut order);
                    for nb in order {
                        if budget_done(&trace, budget, env) {
                            break;
                        }
                        let t =
                            self.eval(env, &mut trace, &mut measured, nb);
                        if t < t_best {
                            best = nb;
                            t_best = t;
                            improved = true;
                            break; // first improvement
                        }
                    }
                }
                // resume the walk from the refined basin
                current = best;
                t_cur = t_best;
            }

            // --- cooling + re-annealing ------------------------------
            temp *= self.cooling;
            if temp < self.t0 * RESTART_RATIO {
                temp = self.t0; // re-anneal: the next proposal is global
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{CostModel, ReplayEnv};

    fn env() -> ReplayEnv {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    #[test]
    fn no_repeated_tests_and_budget_respected() {
        let mut e = env();
        let trace = DualAnnealing::new(1).run(&mut e, &Budget::tests(60));
        assert_eq!(trace.len(), 60);
        let mut idx: Vec<usize> = trace.steps.iter().map(|s| s.idx).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 60, "each empirical test must be unique");
    }

    #[test]
    fn converges_on_small_space() {
        let mut e = env();
        let thr = e.recorded().best_time() * 1.15;
        let trace =
            DualAnnealing::new(5).run(&mut e, &Budget::until(thr, 100_000));
        assert!(trace.steps.last().unwrap().runtime_ms <= thr);
    }

    #[test]
    fn exhausts_space_and_stops() {
        let mut e = env();
        let n = e.space().len();
        let trace = DualAnnealing::new(2).run(&mut e, &Budget::tests(n * 2));
        assert_eq!(trace.len(), n, "must stop after exhausting the space");
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            DualAnnealing::new(seed)
                .run(&mut env(), &Budget::tests(40))
                .steps
                .iter()
                .map(|s| s.idx)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }
}
