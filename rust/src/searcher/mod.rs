//! Tuning-space searchers.
//!
//! * [`ProfileSearcher`] — the paper's contribution (Algorithm 1):
//!   profile → bottlenecks → ΔPC → model-scored weighted-random steps.
//! * [`LazyProfileSearcher`] — Algorithm 1 over spaces too large to
//!   densify: neighbourhood-only scoring off an on-demand recorder
//!   (driven through [`OnDemandEnv`]), O(ball) per round.
//! * [`RandomSearcher`] — the primary baseline (§4.3–4.6).
//! * [`BasinHopping`] — the Kernel Tuner baseline (§4.7).
//! * [`Starchart`] — the regression-tree baseline (§4.8).
//! * [`SimulatedAnnealing`] — an extra optimization-based baseline used
//!   by the ablation benches.
//!
//! Searchers drive an [`EvalEnv`] (replayed recorded space, live
//! simulator, or the PJRT real-execution adapter) and produce a
//! [`SearchTrace`] that the harness converts into steps-to-convergence
//! and time-domain curves.

mod annealing;
mod basin_hopping;
mod env;
mod faults;
mod profile;
mod random;
mod starchart;

pub use annealing::SimulatedAnnealing;
pub use basin_hopping::BasinHopping;
pub use env::{
    CostModel, EvalEnv, FailReason, MeasureOutcome, Measurement, OnDemandEnv,
    ReplayEnv,
};
pub use faults::{FaultModel, FaultProfile, FaultStats, FaultyEnv, RetryPolicy};
pub use profile::{LazyProfileSearcher, ProfileSearcher};
pub use random::RandomSearcher;
pub use starchart::Starchart;

/// Search budget: whichever limit is hit first ends the search.
#[derive(Debug, Clone)]
pub struct Budget {
    /// Maximum empirical tests (kernel executions).
    pub max_tests: usize,
    /// Maximum accumulated tuning cost, seconds (compilation + runs +
    /// profiling overhead), for the time-domain experiments.
    pub max_cost_s: f64,
    /// Stop early once a runtime at or below this is found (used by the
    /// steps-to-well-performing experiments).
    pub stop_at_ms: Option<f64>,
}

impl Budget {
    pub fn tests(max_tests: usize) -> Budget {
        Budget {
            max_tests,
            max_cost_s: f64::INFINITY,
            stop_at_ms: None,
        }
    }

    pub fn seconds(max_cost_s: f64) -> Budget {
        Budget {
            max_tests: usize::MAX,
            max_cost_s,
            stop_at_ms: None,
        }
    }

    pub fn until(stop_at_ms: f64, max_tests: usize) -> Budget {
        Budget {
            max_tests,
            max_cost_s: f64::INFINITY,
            stop_at_ms: Some(stop_at_ms),
        }
    }
}

/// One empirical test in a search.
#[derive(Debug, Clone)]
pub struct Step {
    pub idx: usize,
    pub runtime_ms: f64,
    pub profiled: bool,
    /// Cumulative tuning cost after this step, seconds.
    pub cost_after_s: f64,
    /// True for steps spent building a surrogate model (Starchart's
    /// "model build" phase in Table 8).
    pub build: bool,
}

/// The full log of one search run.
#[derive(Debug, Clone, Default)]
pub struct SearchTrace {
    pub steps: Vec<Step>,
}

impl SearchTrace {
    pub fn push(&mut self, step: Step) {
        self.steps.push(step);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Best runtime seen within the first `n` steps.
    pub fn best_within(&self, n: usize) -> f64 {
        self.steps
            .iter()
            .take(n)
            .map(|s| s.runtime_ms)
            .fold(f64::INFINITY, f64::min)
    }

    /// Number of empirical tests until a runtime ≤ `threshold_ms` is
    /// found (1-based), or `None` if never reached.
    pub fn tests_to_threshold(&self, threshold_ms: f64) -> Option<usize> {
        self.steps
            .iter()
            .position(|s| s.runtime_ms <= threshold_ms)
            .map(|p| p + 1)
    }

    /// Tuning cost (seconds) until a runtime ≤ `threshold_ms` is found.
    pub fn cost_to_threshold(&self, threshold_ms: f64) -> Option<f64> {
        self.steps
            .iter()
            .find(|s| s.runtime_ms <= threshold_ms)
            .map(|s| s.cost_after_s)
    }

    /// (cost_seconds, best_so_far_ms) staircase for convergence plots.
    pub fn convergence(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(self.steps.len());
        let mut best = f64::INFINITY;
        for s in &self.steps {
            best = best.min(s.runtime_ms);
            out.push((s.cost_after_s, best));
        }
        out
    }

    /// Steps spent on model building (Starchart).
    pub fn build_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.build).count()
    }
}

/// A tuning-space search strategy.
///
/// `Send` so searchers can be constructed by one thread and driven by a
/// pool worker; all state beyond the (Sync) model reference is owned.
pub trait Searcher: Send {
    fn name(&self) -> &'static str;

    /// Run until the budget is exhausted (or the space is).
    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace;
}

/// Shared helper: should the search stop now?
pub(crate) fn budget_done(
    trace: &SearchTrace,
    budget: &Budget,
    env: &dyn EvalEnv,
) -> bool {
    if trace.len() >= budget.max_tests {
        return true;
    }
    if env.cost_so_far() >= budget.max_cost_s {
        return true;
    }
    if let Some(thr) = budget.stop_at_ms {
        // model-build measurements (Starchart) don't count as "found":
        // the protocol finishes training before exploiting the model
        if trace
            .steps
            .iter()
            .any(|s| !s.build && s.runtime_ms <= thr)
        {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(runtimes: &[f64]) -> SearchTrace {
        let mut t = SearchTrace::default();
        for (i, &r) in runtimes.iter().enumerate() {
            t.push(Step {
                idx: i,
                runtime_ms: r,
                profiled: false,
                cost_after_s: (i + 1) as f64,
                build: false,
            });
        }
        t
    }

    #[test]
    fn tests_to_threshold_is_one_based() {
        let t = trace(&[5.0, 3.0, 1.0, 2.0]);
        assert_eq!(t.tests_to_threshold(3.0), Some(2));
        assert_eq!(t.tests_to_threshold(1.0), Some(3));
        assert_eq!(t.tests_to_threshold(0.5), None);
    }

    #[test]
    fn convergence_is_monotone() {
        let t = trace(&[5.0, 7.0, 3.0, 4.0]);
        let c = t.convergence();
        assert_eq!(c.len(), 4);
        for w in c.windows(2) {
            assert!(w[1].1 <= w[0].1);
        }
        assert_eq!(c[3].1, 3.0);
    }

    #[test]
    fn best_within_prefix() {
        let t = trace(&[5.0, 2.0, 1.0]);
        assert_eq!(t.best_within(1), 5.0);
        assert_eq!(t.best_within(2), 2.0);
        assert_eq!(t.best_within(100), 1.0);
    }
}
