//! Tuning-space searchers.
//!
//! * [`ProfileSearcher`] — the paper's contribution (Algorithm 1):
//!   profile → bottlenecks → ΔPC → model-scored weighted-random steps.
//! * [`LazyProfileSearcher`] — Algorithm 1 over spaces too large to
//!   densify: neighbourhood-only scoring off an on-demand recorder
//!   (driven through [`OnDemandEnv`]), O(ball) per round.
//! * [`RandomSearcher`] — the primary baseline (§4.3–4.6).
//! * [`BasinHopping`] — the Kernel Tuner baseline (§4.7).
//! * [`Starchart`] — the regression-tree baseline (§4.8).
//! * [`SimulatedAnnealing`] — an extra optimization-based baseline used
//!   by the ablation benches.
//! * [`GeneticSearcher`], [`DifferentialEvolution`], [`DualAnnealing`]
//!   — the strong population/annealing baselines of the benchmarking
//!   follow-up literature (arxiv 2210.01465).
//! * [`ProfileAugmented`] — the paper's Eq. 16 PC-model scoring grafted
//!   onto *any* base searcher's candidate proposals, so the profile
//!   method composes with (not just competes against) the zoo.
//!
//! Strategies are named, parameterized, and constructed through
//! [`SearcherSpec`] (e.g. `"ga:pop=20,mutation=0.1"`,
//! `"profile+de"`) — the single dispatch point behind the matrix /
//! transfer / sweep / serve / tune entry points.
//!
//! Searchers drive an [`EvalEnv`] (replayed recorded space, live
//! simulator, or the PJRT real-execution adapter) and produce a
//! [`SearchTrace`] that the harness converts into steps-to-convergence
//! and time-domain curves.

mod annealing;
mod augmented;
mod basin_hopping;
mod de;
mod dual_annealing;
mod env;
mod faults;
mod genetic;
mod profile;
mod random;
mod spec;
mod starchart;

pub use annealing::SimulatedAnnealing;
pub use augmented::ProfileAugmented;
pub use basin_hopping::BasinHopping;
pub use de::DifferentialEvolution;
pub use dual_annealing::DualAnnealing;
pub use env::{
    CostModel, EvalEnv, FailReason, MeasureOutcome, Measurement, OnDemandEnv,
    ReplayEnv,
};
pub use faults::{FaultModel, FaultProfile, FaultStats, FaultyEnv, RetryPolicy};
pub use genetic::GeneticSearcher;
pub use profile::{LazyProfileSearcher, ProfileSearcher};
pub use random::RandomSearcher;
pub use spec::{
    augment_params, registry, CellCtx, ModelCtx, ParamInfo, RegistryEntry,
    SearcherSpec, SpecError,
};
pub use starchart::Starchart;

/// Search budget: whichever limit is hit first ends the search.
///
/// Construction composes: start from one of the thin entry points
/// ([`tests`](Budget::tests), [`seconds`](Budget::seconds),
/// [`until`](Budget::until) — all bit-identical to their historical
/// behaviour) and layer further criteria with the `with_*` builders,
/// e.g. `Budget::tests(n).with_patience(k).with_stop_at(ms)`.
///
/// Beyond the classic hard limits, the budget carries the principled
/// stopping rules of the sample-size literature (arxiv 2203.13577):
/// *patience* — stop after `k` consecutive tests without improvement —
/// optionally sharpened by a *relative-improvement epsilon* that only
/// counts a test as an improvement when it beats the incumbent best by
/// more than `eps` relative. All criteria are evaluated uniformly in
/// one place ([`budget_done`]), so every searcher honours every rule.
#[derive(Debug, Clone)]
pub struct Budget {
    /// Maximum empirical tests (kernel executions).
    pub max_tests: usize,
    /// Maximum accumulated tuning cost, seconds (compilation + runs +
    /// profiling overhead), for the time-domain experiments.
    pub max_cost_s: f64,
    /// Stop early once a runtime at or below this is found (used by the
    /// steps-to-well-performing experiments).
    pub stop_at_ms: Option<f64>,
    /// Stop after this many consecutive non-build tests without an
    /// improvement of the running best (`None` = no patience rule).
    pub patience: Option<usize>,
    /// Relative improvement a test must make over the incumbent best to
    /// reset the patience counter: `runtime < best · (1 − eps)`. With
    /// the default `0.0` any strict improvement counts. Inert unless
    /// `patience` is set.
    pub min_rel_improve: f64,
}

impl Budget {
    pub fn tests(max_tests: usize) -> Budget {
        Budget {
            max_tests,
            max_cost_s: f64::INFINITY,
            stop_at_ms: None,
            patience: None,
            min_rel_improve: 0.0,
        }
    }

    pub fn seconds(max_cost_s: f64) -> Budget {
        Budget::tests(usize::MAX).with_max_cost(max_cost_s)
    }

    pub fn until(stop_at_ms: f64, max_tests: usize) -> Budget {
        Budget::tests(max_tests).with_stop_at(stop_at_ms)
    }

    /// Cap the number of empirical tests.
    pub fn with_max_tests(mut self, max_tests: usize) -> Budget {
        self.max_tests = max_tests;
        self
    }

    /// Cap the accumulated tuning cost, seconds.
    pub fn with_max_cost(mut self, max_cost_s: f64) -> Budget {
        self.max_cost_s = max_cost_s;
        self
    }

    /// Stop once a runtime at or below `stop_at_ms` is found.
    pub fn with_stop_at(mut self, stop_at_ms: f64) -> Budget {
        self.stop_at_ms = Some(stop_at_ms);
        self
    }

    /// Stop after `k` consecutive tests without improvement.
    pub fn with_patience(mut self, k: usize) -> Budget {
        self.patience = Some(k);
        self
    }

    /// Only count improvements beating the best by more than `eps`
    /// relative (sharpens [`with_patience`](Budget::with_patience)).
    pub fn with_epsilon(mut self, eps: f64) -> Budget {
        self.min_rel_improve = eps;
        self
    }

    /// Why did (or would) a search with this budget stop, given its
    /// trace and final cost? Recomputed post-hoc by the harness for the
    /// per-searcher stopping accounting; priority mirrors the order the
    /// criteria fire in during the run (a threshold hit ends the search
    /// before the test cap can be the binding constraint).
    pub fn stop_reason(&self, trace: &SearchTrace, cost_s: f64) -> StopReason {
        if let Some(thr) = self.stop_at_ms {
            if trace.steps.iter().any(|s| !s.build && s.runtime_ms <= thr) {
                return StopReason::Threshold;
            }
        }
        if let Some(k) = self.patience {
            if tests_since_improvement(trace, self.min_rel_improve) >= k {
                return StopReason::Patience;
            }
        }
        if trace.len() >= self.max_tests {
            return StopReason::Tests;
        }
        if cost_s >= self.max_cost_s {
            return StopReason::Cost;
        }
        StopReason::Exhausted
    }
}

/// Which budget criterion ended a search (or `Exhausted`: the searcher
/// ran out of space before any limit bound).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// A runtime at or below `stop_at_ms` was found.
    Threshold,
    /// `patience` consecutive tests without (epsilon-)improvement.
    Patience,
    /// The `max_tests` cap.
    Tests,
    /// The `max_cost_s` cap.
    Cost,
    /// The space ran dry under every limit.
    Exhausted,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::Threshold => "threshold",
            StopReason::Patience => "patience",
            StopReason::Tests => "tests",
            StopReason::Cost => "cost",
            StopReason::Exhausted => "exhausted",
        }
    }
}

/// Uniform draw over the not-yet-measured configurations — the shared
/// global-restart / fallback device of the population and annealing
/// searchers. Zero-allocation rank scan, mirroring the profile
/// searcher's `next_unexplored`.
pub(crate) fn draw_unmeasured(
    measured: &[Option<f64>],
    rng: &mut crate::util::rng::Rng,
) -> Option<usize> {
    let count = measured.iter().filter(|m| m.is_none()).count();
    if count == 0 {
        return None;
    }
    let mut rank = rng.below(count);
    for (i, m) in measured.iter().enumerate() {
        if m.is_none() {
            if rank == 0 {
                return Some(i);
            }
            rank -= 1;
        }
    }
    unreachable!("rank drawn below the counted unmeasured entries")
}

/// Consecutive non-build tests since the last (epsilon-)improvement of
/// the running best. The first finite runtime always counts as an
/// improvement; an all-failures trace therefore never resets, so a
/// patience rule still terminates hostile-profile searches.
fn tests_since_improvement(trace: &SearchTrace, eps: f64) -> usize {
    let mut best = f64::INFINITY;
    let mut since = 0usize;
    for s in trace.steps.iter().filter(|s| !s.build) {
        if s.runtime_ms < best * (1.0 - eps) {
            best = s.runtime_ms;
            since = 0;
        } else {
            since += 1;
        }
    }
    since
}

/// One empirical test in a search.
#[derive(Debug, Clone)]
pub struct Step {
    pub idx: usize,
    pub runtime_ms: f64,
    pub profiled: bool,
    /// Cumulative tuning cost after this step, seconds.
    pub cost_after_s: f64,
    /// True for steps spent building a surrogate model (Starchart's
    /// "model build" phase in Table 8).
    pub build: bool,
}

/// The full log of one search run.
#[derive(Debug, Clone, Default)]
pub struct SearchTrace {
    pub steps: Vec<Step>,
}

impl SearchTrace {
    pub fn push(&mut self, step: Step) {
        self.steps.push(step);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Best runtime seen within the first `n` steps.
    pub fn best_within(&self, n: usize) -> f64 {
        self.steps
            .iter()
            .take(n)
            .map(|s| s.runtime_ms)
            .fold(f64::INFINITY, f64::min)
    }

    /// Number of empirical tests until a runtime ≤ `threshold_ms` is
    /// found (1-based), or `None` if never reached.
    pub fn tests_to_threshold(&self, threshold_ms: f64) -> Option<usize> {
        self.steps
            .iter()
            .position(|s| s.runtime_ms <= threshold_ms)
            .map(|p| p + 1)
    }

    /// Tuning cost (seconds) until a runtime ≤ `threshold_ms` is found.
    pub fn cost_to_threshold(&self, threshold_ms: f64) -> Option<f64> {
        self.steps
            .iter()
            .find(|s| s.runtime_ms <= threshold_ms)
            .map(|s| s.cost_after_s)
    }

    /// (cost_seconds, best_so_far_ms) staircase for convergence plots.
    pub fn convergence(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(self.steps.len());
        let mut best = f64::INFINITY;
        for s in &self.steps {
            best = best.min(s.runtime_ms);
            out.push((s.cost_after_s, best));
        }
        out
    }

    /// Steps spent on model building (Starchart).
    pub fn build_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.build).count()
    }
}

/// A tuning-space search strategy.
///
/// `Send` so searchers can be constructed by one thread and driven by a
/// pool worker; all state beyond the (Sync) model reference is owned.
pub trait Searcher: Send {
    fn name(&self) -> &'static str;

    /// Run until the budget is exhausted (or the space is).
    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace;
}

/// Boxed searchers search too — [`SearcherSpec::build`] hands out
/// `Box<dyn Searcher>`, and the [`ProfileAugmented`] combinator wraps
/// whatever base it is given, boxed or concrete.
impl<S: Searcher + ?Sized> Searcher for Box<S> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        (**self).run(env, budget)
    }
}

/// Shared helper: should the search stop now? The single place every
/// budget criterion — hard caps, threshold, patience — is evaluated, so
/// all searchers honour all stopping rules uniformly.
pub(crate) fn budget_done(
    trace: &SearchTrace,
    budget: &Budget,
    env: &dyn EvalEnv,
) -> bool {
    if trace.len() >= budget.max_tests {
        return true;
    }
    if env.cost_so_far() >= budget.max_cost_s {
        return true;
    }
    if let Some(thr) = budget.stop_at_ms {
        // model-build measurements (Starchart) don't count as "found":
        // the protocol finishes training before exploiting the model
        if trace
            .steps
            .iter()
            .any(|s| !s.build && s.runtime_ms <= thr)
        {
            return true;
        }
    }
    if let Some(k) = budget.patience {
        if tests_since_improvement(trace, budget.min_rel_improve) >= k {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(runtimes: &[f64]) -> SearchTrace {
        let mut t = SearchTrace::default();
        for (i, &r) in runtimes.iter().enumerate() {
            t.push(Step {
                idx: i,
                runtime_ms: r,
                profiled: false,
                cost_after_s: (i + 1) as f64,
                build: false,
            });
        }
        t
    }

    #[test]
    fn tests_to_threshold_is_one_based() {
        let t = trace(&[5.0, 3.0, 1.0, 2.0]);
        assert_eq!(t.tests_to_threshold(3.0), Some(2));
        assert_eq!(t.tests_to_threshold(1.0), Some(3));
        assert_eq!(t.tests_to_threshold(0.5), None);
    }

    #[test]
    fn convergence_is_monotone() {
        let t = trace(&[5.0, 7.0, 3.0, 4.0]);
        let c = t.convergence();
        assert_eq!(c.len(), 4);
        for w in c.windows(2) {
            assert!(w[1].1 <= w[0].1);
        }
        assert_eq!(c[3].1, 3.0);
    }

    #[test]
    fn best_within_prefix() {
        let t = trace(&[5.0, 2.0, 1.0]);
        assert_eq!(t.best_within(1), 5.0);
        assert_eq!(t.best_within(2), 2.0);
        assert_eq!(t.best_within(100), 1.0);
    }

    /// A no-cost env stand-in so `budget_done` can be probed directly.
    struct NoCost;
    impl EvalEnv for NoCost {
        fn space(&self) -> &crate::tuning::Space {
            unreachable!("budget tests never touch the space")
        }
        fn measure(&mut self, _: usize, _: bool) -> Measurement {
            unreachable!("budget tests never measure")
        }
        fn cost_so_far(&self) -> f64 {
            0.0
        }
        fn gpu(&self) -> &crate::gpusim::GpuSpec {
            unreachable!("budget tests never read the GPU")
        }
    }

    #[test]
    fn thin_wrappers_leave_new_criteria_disarmed() {
        for b in [Budget::tests(5), Budget::seconds(1.0), Budget::until(1.0, 5)]
        {
            assert_eq!(b.patience, None);
            assert_eq!(b.min_rel_improve, 0.0);
        }
        assert_eq!(Budget::seconds(2.5).max_cost_s, 2.5);
        assert_eq!(Budget::until(3.0, 7).stop_at_ms, Some(3.0));
        assert_eq!(Budget::until(3.0, 7).max_tests, 7);
    }

    #[test]
    fn builder_composes() {
        let b = Budget::tests(100)
            .with_patience(8)
            .with_epsilon(0.05)
            .with_stop_at(1.5)
            .with_max_cost(60.0);
        assert_eq!(b.max_tests, 100);
        assert_eq!(b.patience, Some(8));
        assert_eq!(b.min_rel_improve, 0.05);
        assert_eq!(b.stop_at_ms, Some(1.5));
        assert_eq!(b.max_cost_s, 60.0);
    }

    #[test]
    fn patience_stops_after_k_stale_tests() {
        let b = Budget::tests(1000).with_patience(3);
        // improving run: counter keeps resetting
        let t = trace(&[5.0, 4.0, 3.0, 2.0, 1.0]);
        assert!(!budget_done(&t, &b, &NoCost));
        // 3 stale tests after the improvement at step 2
        let t = trace(&[5.0, 4.0, 4.5, 4.6, 4.7]);
        assert!(budget_done(&t, &b, &NoCost));
        // only 2 stale tests: keep going
        let t = trace(&[5.0, 4.0, 4.5, 4.6]);
        assert!(!budget_done(&t, &b, &NoCost));
    }

    #[test]
    fn epsilon_discounts_marginal_improvements() {
        let b = Budget::tests(1000).with_patience(2).with_epsilon(0.10);
        // each step improves, but by less than 10% relative — stale
        let t = trace(&[5.0, 4.9, 4.85]);
        assert!(budget_done(&t, &b, &NoCost));
        // a >10% jump resets the counter
        let t = trace(&[5.0, 4.0, 3.9]);
        assert!(!budget_done(&t, &b, &NoCost));
    }

    #[test]
    fn patience_terminates_all_failure_traces() {
        // hostile profile: every run fails (infinite runtime) — nothing
        // ever counts as an improvement, so patience still binds
        let b = Budget::tests(1000).with_patience(4);
        let inf = f64::INFINITY;
        let t = trace(&[inf, inf, inf, inf]);
        assert!(budget_done(&t, &b, &NoCost));
    }

    #[test]
    fn stop_reason_accounts_for_the_binding_criterion() {
        let t = trace(&[5.0, 4.0, 4.5, 4.6, 4.7]);
        let b = Budget::tests(5);
        assert_eq!(b.stop_reason(&t, 0.0), StopReason::Tests);
        let b = Budget::tests(1000).with_patience(3);
        assert_eq!(b.stop_reason(&t, 0.0), StopReason::Patience);
        let b = Budget::until(4.0, 1000);
        assert_eq!(b.stop_reason(&t, 0.0), StopReason::Threshold);
        let b = Budget::tests(1000).with_max_cost(3.0);
        assert_eq!(b.stop_reason(&t, 3.5), StopReason::Cost);
        let b = Budget::tests(1000);
        assert_eq!(b.stop_reason(&t, 0.0), StopReason::Exhausted);
        assert_eq!(StopReason::Patience.name(), "patience");
    }
}
