//! Basin Hopping — the Kernel Tuner baseline (paper §4.7, [40]).
//!
//! Global/local hybrid: greedy first-improvement local search over the
//! Hamming-1 neighbourhood, and when a local minimum is reached, a
//! random "hop" (perturb a few parameters) with Metropolis acceptance at
//! temperature `T` — Kernel Tuner's default strategy shape.

use crate::util::rng::Rng;

use super::{budget_done, Budget, EvalEnv, Searcher, SearchTrace, Step};

pub struct BasinHopping {
    rng: Rng,
    /// Metropolis temperature, relative to the incumbent runtime.
    pub temperature: f64,
    /// Parameters flipped per hop.
    pub hop_strength: usize,
}

impl BasinHopping {
    pub fn new(seed: u64) -> Self {
        BasinHopping {
            rng: Rng::new(seed),
            temperature: 1.0,
            hop_strength: 2,
        }
    }

    /// Measure helper: record a step, maintain the explored set.
    fn eval(
        &mut self,
        env: &mut dyn EvalEnv,
        trace: &mut SearchTrace,
        explored: &mut [Option<f64>],
        idx: usize,
    ) -> f64 {
        if let Some(t) = explored[idx] {
            return t; // cached — no new empirical test
        }
        let m = env.measure(idx, false);
        explored[idx] = Some(m.runtime_ms);
        trace.push(Step {
            idx,
            runtime_ms: m.runtime_ms,
            profiled: false,
            cost_after_s: env.cost_so_far(),
            build: false,
        });
        m.runtime_ms
    }
}

impl Searcher for BasinHopping {
    fn name(&self) -> &'static str {
        "basin_hopping"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // degenerate space: nothing to draw — empty trace, not a panic
        if size == 0 {
            return SearchTrace::default();
        }
        let mut trace = SearchTrace::default();
        let mut explored: Vec<Option<f64>> = vec![None; size];

        // Precompute the neighbourhood structure lazily per visited node
        // (Hamming-1 lists are cheap relative to kernel runs but cached
        // to keep the searcher overhead down).
        let mut neighbours: Vec<Option<Vec<usize>>> = vec![None; size];

        let mut current = self.rng.below(size);
        let mut t_cur =
            self.eval(env, &mut trace, &mut explored, current);

        while !budget_done(&trace, budget, env) {
            // --- greedy local descent --------------------------------
            let mut improved = true;
            while improved && !budget_done(&trace, budget, env) {
                improved = false;
                if neighbours[current].is_none() {
                    let from = env.space().config_at(current);
                    neighbours[current] =
                        Some(env.space().neighbours(&from, 1));
                }
                let mut order = neighbours[current].clone().unwrap();
                self.rng.shuffle(&mut order);
                for nb in order {
                    if budget_done(&trace, budget, env) {
                        break;
                    }
                    if explored[nb].is_some() {
                        continue;
                    }
                    let t =
                        self.eval(env, &mut trace, &mut explored, nb);
                    if t < t_cur {
                        current = nb;
                        t_cur = t;
                        improved = true;
                        break; // first improvement
                    }
                }
            }

            if budget_done(&trace, budget, env) {
                break;
            }

            // --- hop -----------------------------------------------------
            let from = env.space().config_at(current);
            let candidates = env
                .space()
                .neighbours(&from, self.hop_strength)
                .into_iter()
                .filter(|&i| explored[i].is_none())
                .collect::<Vec<_>>();
            let next = if candidates.is_empty() {
                // restart anywhere unexplored
                let unexplored: Vec<usize> = (0..size)
                    .filter(|&i| explored[i].is_none())
                    .collect();
                if unexplored.is_empty() {
                    break;
                }
                *self.rng.choose(&unexplored)
            } else {
                *self.rng.choose(&candidates)
            };
            let t_next = self.eval(env, &mut trace, &mut explored, next);
            // Metropolis acceptance on the hop
            let accept = t_next < t_cur || {
                let d = (t_next - t_cur) / t_cur.max(1e-12);
                self.rng.f64() < (-d / self.temperature).exp()
            };
            if accept {
                current = next;
                t_cur = t_next;
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{CostModel, ReplayEnv};

    fn env() -> ReplayEnv {
        let gpu = GpuSpec::gtx1070();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    #[test]
    fn no_repeated_tests() {
        let mut e = env();
        let trace = BasinHopping::new(1).run(&mut e, &Budget::tests(80));
        let mut idx: Vec<usize> = trace.steps.iter().map(|s| s.idx).collect();
        let n = idx.len();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), n, "each empirical test must be unique");
    }

    #[test]
    fn converges_on_small_space() {
        let mut e = env();
        let thr = e.recorded().best_time() * 1.1;
        let trace =
            BasinHopping::new(5).run(&mut e, &Budget::until(thr, 100_000));
        assert!(trace.steps.last().unwrap().runtime_ms <= thr);
    }

    #[test]
    fn exhausts_space_and_stops() {
        let mut e = env();
        let n = e.space().len();
        let trace = BasinHopping::new(2).run(&mut e, &Budget::tests(n * 2));
        assert_eq!(trace.len(), n, "must stop after exhausting the space");
    }
}
