//! Starchart — the regression-tree baseline (paper §4.8, [18]).
//!
//! Protocol (as evaluated by the paper):
//! 1. measure 200 random validation configurations;
//! 2. train a runtime regression tree on a growing random sample
//!    (starting at 20) until the median relative prediction error on the
//!    validation set drops below 15 % or 200 training points are used —
//!    all these measurements are "model build" steps;
//! 3. rank all configurations by predicted runtime and empirically test
//!    them best-first until a well-performing one is found ("tuning"
//!    steps).
//!
//! The tree can also be exported and reused on a different GPU (the
//! §4.8 portability probe — Table 9).

use crate::model::RegressionTree;
use crate::util::rng::Rng;
use crate::util::stats::median_relative_error;

use super::{budget_done, Budget, EvalEnv, Searcher, SearchTrace, Step};

pub struct Starchart {
    rng: Rng,
    /// Validation-set size (paper: 200).
    pub validation_points: usize,
    /// Training growth step / start (paper: starts at 20).
    pub train_step: usize,
    /// Maximum training points (paper: 200).
    pub max_train: usize,
    /// Target median relative error (paper: 15 %).
    pub target_error: f64,
    /// A tree trained elsewhere (e.g. on another GPU): skips the model
    /// build phase — Table 9's portability scenario.
    pub pretrained: Option<RegressionTree>,
    /// The tree after `run` (for export to another GPU).
    pub trained_tree: Option<RegressionTree>,
}

impl Starchart {
    pub fn new(seed: u64) -> Self {
        Starchart {
            rng: Rng::new(seed),
            validation_points: 200,
            train_step: 20,
            max_train: 200,
            target_error: 0.15,
            pretrained: None,
            trained_tree: None,
        }
    }

    pub fn with_pretrained(seed: u64, tree: RegressionTree) -> Self {
        Starchart {
            pretrained: Some(tree),
            ..Self::new(seed)
        }
    }
}

fn features(env: &dyn EvalEnv, idx: usize) -> Vec<f64> {
    env.space()
        .config_at(idx)
        .0
        .iter()
        .map(|&v| v as f64)
        .collect()
}

impl Searcher for Starchart {
    fn name(&self) -> &'static str {
        "starchart"
    }

    fn run(&mut self, env: &mut dyn EvalEnv, budget: &Budget) -> SearchTrace {
        let size = env.space().len();
        // degenerate space: nothing to sample or rank — empty trace,
        // not a panic in the validation-set draw
        if size == 0 {
            return SearchTrace::default();
        }
        let mut trace = SearchTrace::default();
        let mut measured: Vec<Option<f64>> = vec![None; size];

        let eval = |env: &mut dyn EvalEnv,
                        trace: &mut SearchTrace,
                        measured: &mut Vec<Option<f64>>,
                        idx: usize,
                        build: bool|
         -> f64 {
            if let Some(t) = measured[idx] {
                return t;
            }
            let m = env.measure(idx, false);
            measured[idx] = Some(m.runtime_ms);
            trace.push(Step {
                idx,
                runtime_ms: m.runtime_ms,
                profiled: false,
                cost_after_s: env.cost_so_far(),
                build,
            });
            m.runtime_ms
        };

        // During the model-build phase only the hard limits (tests/cost)
        // apply: the protocol finishes training before exploiting the
        // model, even if a lucky sample was already well-performing —
        // the build cost is the point of the §4.8 comparison.
        let hard = |trace: &SearchTrace, env: &dyn EvalEnv| {
            trace.len() >= budget.max_tests
                || env.cost_so_far() >= budget.max_cost_s
        };

        let tree: Option<RegressionTree> = if let Some(t) =
            self.pretrained.clone()
        {
            Some(t)
        } else {
            // --- validation set ------------------------------------------
            let val_n = self.validation_points.min(size / 2).max(1);
            let val_idx = self.rng.sample_indices(size, val_n);
            let mut val_x: Vec<Vec<f64>> = Vec::with_capacity(val_n);
            let mut val_y = Vec::with_capacity(val_n);
            for &i in &val_idx {
                if hard(&trace, env) {
                    return trace;
                }
                let y = eval(env, &mut trace, &mut measured, i, true);
                // failed runs (infinite runtime) carry no target: keep
                // them out of the error estimate
                if y.is_finite() {
                    val_x.push(features(env, i));
                    val_y.push(y);
                }
            }

            // --- iterative training --------------------------------------
            let mut train_idx: Vec<usize> = Vec::new();
            let mut tree = None;
            let cap = self.max_train.min(size.saturating_sub(1)).max(1);
            loop {
                // grow the training sample
                let want = (train_idx.len() + self.train_step)
                    .min(self.max_train)
                    .min(size.saturating_sub(1));
                while train_idx.len() < want {
                    let cand = self.rng.below(size);
                    if !train_idx.contains(&cand) {
                        train_idx.push(cand);
                    }
                }
                let mut train_x = Vec::with_capacity(train_idx.len());
                let mut train_y = Vec::with_capacity(train_idx.len());
                for &i in &train_idx {
                    if hard(&trace, env) {
                        return trace;
                    }
                    let y = eval(env, &mut trace, &mut measured, i, true);
                    // same masking as validation: infinite targets would
                    // poison leaf means into NaN predictions
                    if y.is_finite() {
                        train_y.push(y);
                        train_x.push(features(env, i));
                    }
                }
                if train_y.is_empty() {
                    // every sampled config failed so far: keep growing,
                    // or give up on modelling entirely at the cap
                    if train_idx.len() >= cap {
                        break;
                    }
                    continue;
                }
                let t = RegressionTree::fit(&train_x, &train_y, 10, 2);
                let pred: Vec<f64> =
                    val_x.iter().map(|x| t.predict(x)).collect();
                let err = if val_y.is_empty() {
                    f64::INFINITY
                } else {
                    median_relative_error(&pred, &val_y)
                };
                tree = Some(t);
                if err < self.target_error || train_idx.len() >= cap {
                    break;
                }
            }
            tree
        };

        // --- exploitation: walk configs by predicted runtime ------------
        // (natural index order when no model could be trained at all)
        let mut order: Vec<usize> = (0..size).collect();
        if let Some(t) = &tree {
            let pred: Vec<f64> = (0..size)
                .map(|i| t.predict(&features(env, i)))
                .collect();
            // total_cmp: NaN-proof ordering even if a hostile profile
            // slips a degenerate prediction through
            order.sort_by(|&a, &b| pred[a].total_cmp(&pred[b]));
        }
        self.trained_tree = tree;
        for idx in order {
            if budget_done(&trace, budget, env) {
                break;
            }
            if measured[idx].is_some() {
                continue;
            }
            eval(env, &mut trace, &mut measured, idx, false);
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb, Transpose};
    use crate::gpusim::GpuSpec;
    use crate::searcher::{CostModel, ReplayEnv};

    fn env(gpu: GpuSpec) -> ReplayEnv {
        let rec = record_space(&Transpose, &gpu, &Transpose.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    #[test]
    fn build_then_tune_phases() {
        let mut e = env(GpuSpec::gtx1070());
        let thr = e.recorded().best_time() * 1.1;
        let mut s = Starchart::new(1);
        let trace = s.run(&mut e, &Budget::until(thr, 100_000));
        let build = trace.build_steps();
        assert!(build >= 20, "expected a model-build phase, got {build}");
        assert!(trace.len() > build, "expected tuning steps after build");
        assert!(s.trained_tree.is_some());
    }

    #[test]
    fn pretrained_skips_build() {
        // train on GTX 1070, reuse on RTX 2080 (Table 9 scenario)
        let mut e1 = env(GpuSpec::gtx1070());
        let thr1 = e1.recorded().best_time() * 1.1;
        let mut s1 = Starchart::new(2);
        s1.run(&mut e1, &Budget::until(thr1, 100_000));
        let tree = s1.trained_tree.unwrap();

        let mut e2 = env(GpuSpec::rtx2080());
        let thr2 = e2.recorded().best_time() * 1.1;
        let mut s2 = Starchart::with_pretrained(3, tree);
        let trace = s2.run(&mut e2, &Budget::until(thr2, 100_000));
        assert_eq!(trace.build_steps(), 0);
        assert!(!trace.is_empty());
    }

    #[test]
    fn small_space_does_not_overrun() {
        let gpu = GpuSpec::gtx750();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        let n = rec.space.len();
        let mut e = ReplayEnv::new(rec, gpu, CostModel::default());
        let mut s = Starchart::new(4);
        let trace = s.run(&mut e, &Budget::tests(10 * n));
        assert!(trace.len() <= n, "each config at most once");
    }
}
