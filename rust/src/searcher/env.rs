//! Evaluation environments: where the searcher's empirical tests run.
//!
//! [`ReplayEnv`] replays an exhaustively recorded space — the paper's
//! §4.1 methodology for the 1000-repetition step-count statistics — with
//! a cost model that accounts for compilation, kernel runs, the
//! profiling slowdown and optional result checking, so the time-domain
//! experiments (§4.6) can be reproduced as well.

use std::sync::Arc;

use crate::benchmarks::OnDemandRecorder;
use crate::counters::{Counter, CounterVec};
use crate::gpusim::GpuSpec;
use crate::tuning::{RecordedSpace, Space};

/// Why an empirical test produced no usable runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// The configuration itself is broken (compile/launch error,
    /// resource exhaustion): it fails on every attempt.
    Persistent,
    /// A one-off environment hiccup; retrying may succeed.
    Transient,
}

impl FailReason {
    pub fn name(&self) -> &'static str {
        match self {
            FailReason::Persistent => "persistent",
            FailReason::Transient => "transient",
        }
    }
}

/// Typed outcome of one empirical test. Anything but [`Ok`]
/// (`MeasureOutcome::Ok`) means `runtime_ms` is `f64::INFINITY` and
/// `counters` is `None` — searchers must branch on this instead of
/// trusting the runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeasureOutcome {
    /// The run completed; `runtime_ms` is valid (counters may still be
    /// missing on a profiled run whose profiling pass failed).
    Ok,
    /// The run failed outright.
    Failed { reason: FailReason },
    /// The run exceeded the watchdog limit (treated as a failure with
    /// its own label — timeouts dominate wasted cost in real tuning).
    TimedOut,
}

/// Result of one empirical test.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub runtime_ms: f64,
    /// Present only when the run was profiled (and the profiling pass
    /// did not fail).
    pub counters: Option<CounterVec>,
    /// What happened to the run. Infallible environments always report
    /// [`MeasureOutcome::Ok`].
    pub outcome: MeasureOutcome,
    /// Counters the profiler failed to collect this run (zeroed in
    /// `counters`); empty for healthy environments. Searchers mask
    /// these out of their scoring reaction.
    pub dropped: Vec<Counter>,
}

impl Measurement {
    /// A successful measurement (the only shape infallible
    /// environments produce).
    pub fn ok(runtime_ms: f64, counters: Option<CounterVec>) -> Measurement {
        Measurement {
            runtime_ms,
            counters,
            outcome: MeasureOutcome::Ok,
            dropped: Vec::new(),
        }
    }

    /// A failed measurement: infinite runtime (so best-so-far folds and
    /// thresholds ignore it naturally), no counters.
    pub fn failed(outcome: MeasureOutcome) -> Measurement {
        debug_assert!(outcome != MeasureOutcome::Ok);
        Measurement {
            runtime_ms: f64::INFINITY,
            counters: None,
            outcome,
            dropped: Vec::new(),
        }
    }

    /// Did the run produce a usable runtime?
    pub fn is_ok(&self) -> bool {
        self.outcome == MeasureOutcome::Ok
    }
}

/// Where empirical tests execute.
pub trait EvalEnv {
    fn space(&self) -> &Space;

    /// Run configuration `idx`; gather counters iff `profile`.
    fn measure(&mut self, idx: usize, profile: bool) -> Measurement;

    /// Accumulated tuning cost so far, in seconds.
    fn cost_so_far(&self) -> f64;

    /// The device tuning runs on (the expert system needs its core count
    /// and counter generation).
    fn gpu(&self) -> &GpuSpec;

    /// Best runtime in the space, if known (replay envs know it).
    fn known_best_ms(&self) -> Option<f64> {
        None
    }
}

/// Cost accounting for one empirical test (§4.6: profiled kernels run
/// slower; each test pays compilation; offline tuning adds a result
/// check).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Kernel compilation + launch pipeline per test, seconds.
    pub compile_s: f64,
    /// Result check (device→host copy + compare), seconds; 0 when
    /// disabled (dynamic-tuning setting).
    pub check_s: f64,
    /// Profiled runs replay the kernel once per counter group: the
    /// effective slowdown factor on the kernel runtime.
    pub profile_factor: f64,
    /// Fixed profiling overhead (CUPTI setup/teardown), seconds.
    pub profile_fixed_s: f64,
    /// Searcher overhead per selected configuration, seconds (the paper
    /// measures its python searcher's scoring cost; ours is measured by
    /// the benches and is orders of magnitude smaller).
    pub searcher_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            compile_s: 0.20,
            check_s: 0.0,
            profile_factor: 8.0,
            profile_fixed_s: 0.10,
            searcher_s: 0.002,
        }
    }
}

impl CostModel {
    /// §4.6 offline-tuning setting: result checking enabled.
    pub fn with_check() -> Self {
        CostModel {
            check_s: 0.35,
            ..Default::default()
        }
    }

    pub fn cost_of(&self, runtime_ms: f64, profile: bool) -> f64 {
        let run_s = runtime_ms / 1e3;
        let mut c = self.compile_s + run_s + self.check_s + self.searcher_s;
        if profile {
            c += run_s * (self.profile_factor - 1.0) + self.profile_fixed_s;
        }
        c
    }
}

/// Replay of an exhaustively recorded space.
///
/// Holds the recording behind an [`Arc`]: the harness repeats each
/// stochastic search up to 1000× across worker threads, and every
/// repetition shares one immutable recording instead of cloning it.
pub struct ReplayEnv {
    rec: Arc<RecordedSpace>,
    gpu: GpuSpec,
    cost: CostModel,
    spent_s: f64,
    /// Total measurements served (for tests/metrics).
    pub measurements: usize,
}

impl ReplayEnv {
    /// Accepts either an owned `RecordedSpace` (wrapped on the way in)
    /// or a shared `Arc<RecordedSpace>` from the process-wide cache.
    pub fn new(
        rec: impl Into<Arc<RecordedSpace>>,
        gpu: GpuSpec,
        cost: CostModel,
    ) -> Self {
        let rec = rec.into();
        assert_eq!(
            rec.gpu, gpu.name,
            "recorded space {} replayed against device {}",
            rec.gpu, gpu.name
        );
        ReplayEnv {
            rec,
            gpu,
            cost,
            spent_s: 0.0,
            measurements: 0,
        }
    }

    pub fn recorded(&self) -> &RecordedSpace {
        &self.rec
    }

    pub fn reset_cost(&mut self) {
        self.spent_s = 0.0;
        self.measurements = 0;
    }
}

impl EvalEnv for ReplayEnv {
    fn space(&self) -> &Space {
        &self.rec.space
    }

    fn measure(&mut self, idx: usize, profile: bool) -> Measurement {
        let r = &self.rec.records[idx];
        self.spent_s += self.cost.cost_of(r.runtime_ms, profile);
        self.measurements += 1;
        Measurement::ok(r.runtime_ms, profile.then(|| r.counters.clone()))
    }

    fn cost_so_far(&self) -> f64 {
        self.spent_s
    }

    fn gpu(&self) -> &GpuSpec {
        &self.gpu
    }

    fn known_best_ms(&self) -> Option<f64> {
        Some(self.rec.best_time())
    }
}

/// Lazy counterpart of [`ReplayEnv`]: empirical tests are served by an
/// [`OnDemandRecorder`], which simulates a configuration the first time
/// any search visits it and memoizes the record. Nothing space-sized is
/// ever materialized, so million-configuration spaces tune in bounded
/// memory; cost accounting is identical to [`ReplayEnv`].
///
/// Unlike a replay over an exhaustive recording, the true best runtime
/// is unknown (`known_best_ms` stays `None`): budgets must be test- or
/// cost-bounded, and convergence metrics are computed post-hoc from the
/// trace.
pub struct OnDemandEnv {
    recorder: Arc<OnDemandRecorder>,
    gpu: GpuSpec,
    cost: CostModel,
    spent_s: f64,
    /// Total measurements served (for tests/metrics).
    pub measurements: usize,
}

impl OnDemandEnv {
    pub fn new(recorder: Arc<OnDemandRecorder>, cost: CostModel) -> Self {
        let gpu = recorder.gpu().clone();
        OnDemandEnv {
            recorder,
            gpu,
            cost,
            spent_s: 0.0,
            measurements: 0,
        }
    }

    pub fn recorder(&self) -> &Arc<OnDemandRecorder> {
        &self.recorder
    }

    pub fn reset_cost(&mut self) {
        self.spent_s = 0.0;
        self.measurements = 0;
    }
}

impl EvalEnv for OnDemandEnv {
    fn space(&self) -> &Space {
        self.recorder.space()
    }

    fn measure(&mut self, idx: usize, profile: bool) -> Measurement {
        let r = self.recorder.record(idx);
        self.spent_s += self.cost.cost_of(r.runtime_ms, profile);
        self.measurements += 1;
        Measurement::ok(r.runtime_ms, profile.then(|| r.counters.clone()))
    }

    fn cost_so_far(&self) -> f64 {
        self.spent_s
    }

    fn gpu(&self) -> &GpuSpec {
        &self.gpu
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};

    fn env() -> ReplayEnv {
        let gpu = GpuSpec::gtx750();
        let rec = record_space(&Coulomb, &gpu, &Coulomb.default_input());
        ReplayEnv::new(rec, gpu, CostModel::default())
    }

    #[test]
    fn measure_returns_recorded_values() {
        let mut e = env();
        let want = e.recorded().records[3].runtime_ms;
        let m = e.measure(3, false);
        assert_eq!(m.runtime_ms, want);
        assert!(m.counters.is_none());
        assert!(m.is_ok());
        assert!(m.dropped.is_empty());
        let m2 = e.measure(3, true);
        assert!(m2.counters.is_some());
        assert_eq!(m2.outcome, MeasureOutcome::Ok);
    }

    #[test]
    fn profiling_costs_more() {
        let cm = CostModel::default();
        let plain = cm.cost_of(10.0, false);
        let prof = cm.cost_of(10.0, true);
        assert!(prof > plain);
        // slow kernels pay proportionally more for profiling (§4.6 n-body
        // large-instance effect)
        let slow_ratio = cm.cost_of(1000.0, true) / cm.cost_of(1000.0, false);
        let fast_ratio = cm.cost_of(1.0, true) / cm.cost_of(1.0, false);
        assert!(slow_ratio > fast_ratio);
    }

    #[test]
    fn cost_accumulates() {
        let mut e = env();
        assert_eq!(e.cost_so_far(), 0.0);
        e.measure(0, false);
        let c1 = e.cost_so_far();
        e.measure(1, true);
        assert!(e.cost_so_far() > c1);
        assert_eq!(e.measurements, 2);
        e.reset_cost();
        assert_eq!(e.cost_so_far(), 0.0);
    }

    #[test]
    #[should_panic]
    fn gpu_mismatch_panics() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let _ = ReplayEnv::new(rec, GpuSpec::gtx680(), CostModel::default());
    }
}
