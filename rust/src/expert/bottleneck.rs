//! Bottleneck analysis (paper §3.5.1, Equations 6–14).
//!
//! Reads a measured counter vector and produces a bottleneck vector
//! `B = [b_x]`, each component in [0, 1]: 0 = subsystem unstressed,
//! 1 = at its theoretical peak. The computation is written exactly as in
//! the paper; counters arrive in the pre-Volta scale (utilization ranks
//! 0–10, efficiencies 0–100 — the measurement layer normalizes Volta+
//! counters per Table 1).

use crate::counters::{Counter, CounterSet, CounterVec, INST_COUNTERS};
use crate::gpusim::GpuSpec;

/// The bottleneck vector (paper §3.5.1).
#[derive(Debug, Clone, Default)]
pub struct Bottlenecks {
    pub dram_read: f64,
    pub dram_write: f64,
    pub l2_read: f64,
    pub l2_write: f64,
    pub shared_read: f64,
    pub shared_write: f64,
    pub tex: f64,
    pub local: f64,
    /// Instruction-class bottlenecks, indexed parallel to
    /// [`INST_COUNTERS`] (F32, F64, INT, MISC, LDST, CONT, BCONV).
    pub inst: [f64; 7],
    pub issue: f64,
    pub sm: f64,
    pub paral: f64,
}

impl Bottlenecks {
    /// Max over all components — used by tests and diagnostics.
    pub fn max(&self) -> f64 {
        let mut m: f64 = 0.0;
        for v in self.all() {
            m = m.max(v);
        }
        m
    }

    pub fn all(&self) -> Vec<f64> {
        let mut v = vec![
            self.dram_read,
            self.dram_write,
            self.l2_read,
            self.l2_write,
            self.shared_read,
            self.shared_write,
            self.tex,
            self.local,
            self.issue,
            self.sm,
            self.paral,
        ];
        v.extend_from_slice(&self.inst);
        v
    }
}

/// Memory bottleneck helper: utilization (0–10 rank) weighted by the
/// read/write transaction split (Eqs. 6–7 and their shared/L2 analogues).
fn memory_pair(read_t: f64, write_t: f64, util_rank: f64) -> (f64, f64) {
    let total = read_t + write_t;
    if total <= 0.0 {
        return (0.0, 0.0);
    }
    let u = (util_rank / 10.0).clamp(0.0, 1.0);
    (read_t / total * u, write_t / total * u)
}

/// Run the bottleneck analysis for counters measured on `gpu`.
pub fn analyze(pc: &CounterVec, gpu: &GpuSpec) -> Bottlenecks {
    let g = |c: Counter| pc.get(c);
    let mut b = Bottlenecks::default();

    // --- memory subsystems (Eqs. 6, 7 + analogues) ---------------------
    (b.dram_read, b.dram_write) = memory_pair(
        g(Counter::DramRt),
        g(Counter::DramWt),
        g(Counter::DramU),
    );
    (b.l2_read, b.l2_write) =
        memory_pair(g(Counter::L2Rt), g(Counter::L2Wt), g(Counter::L2U));
    (b.shared_read, b.shared_write) =
        memory_pair(g(Counter::ShrLt), g(Counter::ShrWt), g(Counter::ShrU));

    // texture cache is read-only: plain rescale
    b.tex = (g(Counter::TexU) / 10.0).clamp(0.0, 1.0);

    // --- local memory (Eq. 8): overhead weighted by the most-stressed
    // level of the memory path that spills travel through --------------
    let mem_max = (g(Counter::DramU) / 10.0)
        .max(g(Counter::L2U) / 10.0)
        .max(g(Counter::TexU) / 10.0)
        .clamp(0.0, 1.0);
    b.local = (g(Counter::LocO) / 100.0).clamp(0.0, 1.0) * mem_max;

    // --- instruction bottlenecks (Eqs. 9–12) ----------------------------
    let warp_e = g(Counter::WarpE).max(100.0 / 32.0);
    let warp_np_e = g(Counter::WarpNpE).max(100.0 / 32.0);
    // Eq. 9: warp-level issues fitted back to thread-level capacity
    let ins_fitted =
        32.0 * g(Counter::InstExe) * (100.0 / warp_e) * (100.0 / warp_np_e);
    let ins_fitted = ins_fitted.max(1.0);

    // issue-slot utilization; Volta+ can dual-issue INT/FP so one full
    // pipe (50 %) counts as full utilization (§3.5.1).
    let ins_util = match gpu.counter_set() {
        CounterSet::PreVolta => g(Counter::InstIssueU) / 100.0,
        CounterSet::VoltaPlus => (g(Counter::InstIssueU) / 50.0).min(1.0),
    }
    .clamp(0.0, 1.0);

    let mut util_max: f64 = 0.0;
    for (i, c) in INST_COUNTERS.iter().enumerate() {
        let frac = (g(*c) / ins_fitted).clamp(0.0, 1.0);
        util_max = util_max.max(frac);
        // Eq. 10 (and analogues)
        b.inst[i] = frac * ins_util;
    }

    // Eq. 12: issue-slot headroom weighted by the dominant class
    b.issue = util_max * (100.0 - g(Counter::InstIssueU)).clamp(0.0, 100.0)
        / 100.0;

    // --- parallelism (Eqs. 13–14) ----------------------------------------
    b.sm = ((100.0 - g(Counter::SmE)) / 100.0).clamp(0.0, 1.0);
    let cores = gpu.cores() as f64;
    b.paral = ((cores * 5.0 - g(Counter::Threads)) / (cores * 5.0)).max(0.0);

    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GpuSpec;

    fn pc(pairs: &[(Counter, f64)]) -> CounterVec {
        let mut v = CounterVec::new();
        for &(c, x) in pairs {
            v.set(c, x);
        }
        v
    }

    #[test]
    fn eq6_eq7_split_by_transactions() {
        let v = pc(&[
            (Counter::DramRt, 300.0),
            (Counter::DramWt, 100.0),
            (Counter::DramU, 8.0),
        ]);
        let b = analyze(&v, &GpuSpec::gtx1070());
        assert!((b.dram_read - 0.75 * 0.8).abs() < 1e-12);
        assert!((b.dram_write - 0.25 * 0.8).abs() < 1e-12);
    }

    #[test]
    fn zero_transactions_no_bottleneck() {
        let v = pc(&[(Counter::DramU, 9.0)]);
        let b = analyze(&v, &GpuSpec::gtx1070());
        assert_eq!(b.dram_read, 0.0);
        assert_eq!(b.dram_write, 0.0);
    }

    #[test]
    fn eq8_local_weighted_by_memory_stress() {
        // high overhead but idle memory path => not a bottleneck
        let idle = pc(&[(Counter::LocO, 80.0), (Counter::DramU, 0.5)]);
        let b1 = analyze(&idle, &GpuSpec::gtx1070());
        assert!(b1.local < 0.05);
        // high overhead + saturated DRAM => real bottleneck
        let busy = pc(&[(Counter::LocO, 80.0), (Counter::DramU, 10.0)]);
        let b2 = analyze(&busy, &GpuSpec::gtx1070());
        assert!((b2.local - 0.8).abs() < 1e-12);
    }

    #[test]
    fn eq10_fp32_utilization() {
        // perfectly converged warps: ins_fitted = 32·INST_EXE
        let v = pc(&[
            (Counter::InstExe, 1000.0),
            (Counter::WarpE, 100.0),
            (Counter::WarpNpE, 100.0),
            (Counter::InstF32, 16000.0), // half the issue capacity
            (Counter::InstIssueU, 90.0),
        ]);
        let b = analyze(&v, &GpuSpec::gtx1070());
        assert!((b.inst[0] - 0.5 * 0.9).abs() < 1e-9);
    }

    #[test]
    fn volta_dual_issue_halves_the_bar() {
        let v = pc(&[
            (Counter::InstExe, 1000.0),
            (Counter::WarpE, 100.0),
            (Counter::WarpNpE, 100.0),
            (Counter::InstF32, 32000.0),
            (Counter::InstIssueU, 50.0),
        ]);
        let pre = analyze(&v, &GpuSpec::gtx1070());
        let post = analyze(&v, &GpuSpec::rtx2080());
        // 50% issue = half utilization pre-Volta, full on Volta+
        assert!((pre.inst[0] - 0.5).abs() < 1e-9);
        assert!((post.inst[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn eq12_issue_headroom() {
        let v = pc(&[
            (Counter::InstExe, 1000.0),
            (Counter::WarpE, 100.0),
            (Counter::WarpNpE, 100.0),
            (Counter::InstF32, 32000.0), // dominant class at capacity
            (Counter::InstIssueU, 40.0),
        ]);
        let b = analyze(&v, &GpuSpec::gtx1070());
        assert!((b.issue - 1.0 * 0.6).abs() < 1e-9);
    }

    #[test]
    fn eq13_eq14_parallelism() {
        let gpu = GpuSpec::gtx1070(); // 1920 cores
        let cores = gpu.cores() as f64;
        let v = pc(&[
            (Counter::SmE, 40.0),
            (Counter::Threads, cores * 2.5),
        ]);
        let b = analyze(&v, &gpu);
        assert!((b.sm - 0.6).abs() < 1e-12);
        assert!((b.paral - 0.5).abs() < 1e-12);
        // five threads per core zeroes the empirical bottleneck
        let v2 = pc(&[(Counter::Threads, cores * 5.0)]);
        assert_eq!(analyze(&v2, &gpu).paral, 0.0);
    }

    #[test]
    fn all_bottlenecks_bounded() {
        // randomized sanity: every component stays in [0,1]
        let mut rng = crate::util::rng::Rng::new(42);
        for _ in 0..500 {
            let mut v = CounterVec::new();
            for c in crate::counters::ALL_COUNTERS {
                let scale = match c {
                    Counter::DramU
                    | Counter::L2U
                    | Counter::TexU
                    | Counter::ShrU => 10.0,
                    Counter::SmE
                    | Counter::WarpE
                    | Counter::WarpNpE
                    | Counter::InstIssueU
                    | Counter::LocO => 100.0,
                    _ => 1e9,
                };
                v.set(c, rng.f64() * scale);
            }
            let b = analyze(&v, &GpuSpec::gtx680());
            for (i, x) in b.all().into_iter().enumerate() {
                assert!((0.0..=1.0).contains(&x), "component {i} = {x}");
            }
        }
    }
}
