//! ΔPC computation (paper §3.5.2, Eq. 15).
//!
//! Converts a bottleneck vector into the required change of PC_ops:
//! a vector ΔPC with components in [−1, 1] — negative means "the searcher
//! should prefer configurations that decrease this counter", positive
//! "increase it", zero "don't care".

use crate::counters::{Counter, CounterVec, INST_COUNTERS};

use super::Bottlenecks;

/// Default instruction-reaction threshold (§3.5.2).
pub const DEFAULT_INST_REACTION: f64 = 0.7;
/// Threshold when the user flags the problem as instruction-bound.
pub const INST_BOUND_REACTION: f64 = 0.5;

/// The required change of performance counters. Stored as a
/// [`CounterVec`] whose entries are deltas in [−1, 1]; only counters
/// participating in the reaction are non-zero.
#[derive(Debug, Clone, Default)]
pub struct DeltaPc(pub CounterVec);

impl DeltaPc {
    pub fn get(&self, c: Counter) -> f64 {
        self.0.get(c)
    }

    /// Counters with a non-zero required change.
    pub fn active(&self) -> Vec<(Counter, f64)> {
        self.0.iter().filter(|(_, v)| *v != 0.0).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.active().is_empty()
    }
}

/// Eq. 15: instruction deltas trigger only beyond `inst_reaction` —
/// instructions have low latency, so they only matter under high stress.
fn inst_delta(b: f64, inst_reaction: f64) -> f64 {
    if b <= inst_reaction {
        0.0
    } else {
        -((b - inst_reaction) / (1.0 - inst_reaction))
    }
}

/// Compute ΔPC_ops from bottlenecks (§3.5.2).
pub fn react(b: &Bottlenecks, inst_reaction: f64) -> DeltaPc {
    let mut d = CounterVec::new();

    // memory subsystems: inverse of the bottleneck value
    d.set(Counter::DramRt, -b.dram_read);
    d.set(Counter::DramWt, -b.dram_write);
    d.set(Counter::L2Rt, -b.l2_read);
    d.set(Counter::L2Wt, -b.l2_write);
    d.set(Counter::ShrLt, -b.shared_read);
    d.set(Counter::ShrWt, -b.shared_write);
    d.set(Counter::TexRwt, -b.tex);
    d.set(Counter::LocO, -b.local);

    // instruction classes: Eq. 15 (thresholded)
    for (i, c) in INST_COUNTERS.iter().enumerate() {
        d.set(*c, inst_delta(b.inst[i], inst_reaction));
    }

    // The issue bottleneck (Eq. 12) fires when issue slots sit idle
    // while one instruction class dominates — the kernel is
    // *latency-bound*. The paper reacts "analogously" to the other
    // instruction bottlenecks but does not name the counter; reducing
    // instruction counts does not fix latency-boundness, so we direct
    // the reaction at the parallelism counters (the §2.3 manual-tuning
    // narrative: "GPU occupancy low → set Z_ITERATIONS to a lower
    // value"). See DESIGN.md §Interpretation.
    let issue_push = -inst_delta(b.issue, inst_reaction); // in [0, 1]

    // parallelism: applied straightforwardly, *not* inverted —
    // Δpc_SM_E = b_sm and Δpc_global(threads) = b_paral
    d.set(Counter::SmE, b.sm.max(issue_push));
    d.set(Counter::Threads, b.paral.max(issue_push));

    DeltaPc(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq15_threshold_behaviour() {
        assert_eq!(inst_delta(0.69, 0.7), 0.0);
        assert_eq!(inst_delta(0.7, 0.7), 0.0);
        assert!((inst_delta(0.85, 0.7) + 0.5).abs() < 1e-12);
        assert!((inst_delta(1.0, 0.7) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn memory_deltas_are_inverted_bottlenecks() {
        let b = Bottlenecks {
            dram_read: 0.8,
            tex: 0.4,
            ..Default::default()
        };
        let d = react(&b, DEFAULT_INST_REACTION);
        assert_eq!(d.get(Counter::DramRt), -0.8);
        assert_eq!(d.get(Counter::TexRwt), -0.4);
        assert_eq!(d.get(Counter::L2Rt), -0.0);
    }

    #[test]
    fn parallelism_deltas_positive() {
        let b = Bottlenecks {
            sm: 0.6,
            paral: 0.3,
            ..Default::default()
        };
        let d = react(&b, DEFAULT_INST_REACTION);
        assert_eq!(d.get(Counter::SmE), 0.6);
        assert_eq!(d.get(Counter::Threads), 0.3);
    }

    #[test]
    fn instruction_bound_threshold_reacts_sooner() {
        let mut b = Bottlenecks::default();
        b.inst[0] = 0.6; // fp32
        let relaxed = react(&b, DEFAULT_INST_REACTION);
        let eager = react(&b, INST_BOUND_REACTION);
        assert_eq!(relaxed.get(Counter::InstF32), 0.0);
        assert!((eager.get(Counter::InstF32) + 0.2).abs() < 1e-12);
    }

    #[test]
    fn deltas_bounded() {
        let mut b = Bottlenecks {
            dram_read: 1.0,
            dram_write: 1.0,
            l2_read: 1.0,
            l2_write: 1.0,
            shared_read: 1.0,
            shared_write: 1.0,
            tex: 1.0,
            local: 1.0,
            issue: 1.0,
            sm: 1.0,
            paral: 1.0,
            ..Default::default()
        };
        b.inst = [1.0; 7];
        let d = react(&b, DEFAULT_INST_REACTION);
        for (_, v) in d.0.iter() {
            assert!((-1.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn no_bottlenecks_no_deltas_except_memory_zero() {
        let d = react(&Bottlenecks::default(), DEFAULT_INST_REACTION);
        assert!(d.is_empty());
    }
}
