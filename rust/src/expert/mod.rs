//! The expert system (paper §3.5): from measured performance counters to
//! bottlenecks (Eqs. 6–14), and from bottlenecks to a required
//! counter-change vector ΔPC_ops (Eq. 15); plus configuration scoring
//! (§3.6, Eqs. 16–17).

mod bottleneck;
mod reaction;
mod scoring;

pub use bottleneck::{analyze, Bottlenecks};
pub use reaction::{react, DeltaPc, DEFAULT_INST_REACTION, INST_BOUND_REACTION};
pub use scoring::{
    active_deltas, normalize_scores, normalize_scores_in_place, score,
    score_active, CUTOFF_GAMMA,
};
