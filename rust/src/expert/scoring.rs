//! Configuration scoring (paper §3.6, Eqs. 16–17).
//!
//! Given the required counter changes ΔPC for the profiled configuration
//! and *model-predicted* counters for both the profiled and a candidate
//! configuration, Eq. 16 scores how well the candidate moves each
//! counter in the required direction; Eq. 17 normalizes scores into
//! [0.0001, 256] for the weighted-random step.
//!
//! Both configurations are evaluated through the model (not the live
//! measurement) because autotuning may run on a different GPU/input than
//! the model was trained on — predicted and measured counters are not
//! directly comparable (§3.6).

use crate::counters::CounterVec;

use super::DeltaPc;

/// Cutoff threshold γ (Eq. 17): raw scores below it get the floor
/// probability.
pub const CUTOFF_GAMMA: f64 = -0.25;

/// Eq. 16, orientation-corrected:
///
/// s = Σ_p Δpc_p · (pc_p(candidate) − pc_p(profile)) /
///                (pc_p(candidate) + pc_p(profile))
///
/// summed over counters with non-zero predictions for both
/// configurations.
///
/// **Erratum note** (DESIGN.md §Erratum): the paper prints the numerator
/// as (profile − candidate), under which a candidate that *decreases* a
/// counter whose Δ is negative ("should decrease") would score
/// *negatively* — contradicting the stated semantics ("higher scores to
/// configurations which are predicted to change PC_ops in the required
/// way", §3.3) for every counter class. We implement the consistent
/// orientation: a candidate moving a counter in the direction of sign(Δ)
/// contributes positively, weighted by |Δ| and the relative change.
pub fn score(
    delta: &DeltaPc,
    pred_profile: &CounterVec,
    pred_candidate: &CounterVec,
) -> f64 {
    let mut s = 0.0;
    for (c, d) in delta.0.iter() {
        if d == 0.0 {
            continue;
        }
        let p = pred_profile.get(c);
        let q = pred_candidate.get(c);
        // PC_used (paper): both-zero counters carry no information and
        // the ratio is indeterminate — skip. One-sided zeros are kept:
        // (q-p)/(q+p) = ±1 is exactly the "counter fully eliminated /
        // introduced" signal (DESIGN.md §Erratum — the paper's stricter
        // rule starves configurations that remove a bottleneck outright).
        if p != 0.0 || q != 0.0 {
            s += d * (q - p) / (q + p);
        }
    }
    s
}

/// Hot-path variant of [`score`]: the Δ vector pre-extracted to its
/// non-zero (index, delta) pairs so the inner loop touches only active
/// counters (~8 of 25) — the searcher scores the whole space each
/// profiling round (§Perf).
#[inline]
pub fn score_active(
    active: &[(usize, f64)],
    pred_profile: &CounterVec,
    pred_candidate: &CounterVec,
) -> f64 {
    let mut s = 0.0;
    for &(i, d) in active {
        let p = pred_profile.0[i];
        let q = pred_candidate.0[i];
        if p != 0.0 || q != 0.0 {
            s += d * (q - p) / (q + p);
        }
    }
    s
}

/// Extract the non-zero components of a Δ vector for [`score_active`].
pub fn active_deltas(delta: &DeltaPc) -> Vec<(usize, f64)> {
    delta
        .0
        .iter()
        .enumerate()
        .filter(|(_, (_, d))| *d != 0.0)
        .map(|(i, (_, d))| (i, d))
        .collect()
}

/// Eq. 17 for the scoring engine's reusable buffer: normalize in place,
/// treating non-finite entries as *excluded* (weight 0.0).
///
/// The pre-engine searcher collected the finite entries into a
/// temporary, normalized that, and scattered the results back — three
/// allocations plus two extra passes per profiling round. This variant
/// produces exactly the same weights (identical min/max folds and
/// per-entry mapping over the finite entries, 0.0 for the rest) in two
/// allocation-free passes. Excluded entries are how the searcher flags
/// already-explored configurations (`NEG_INFINITY`) and, in the §3.9.1
/// local variant, everything outside the neighbourhood.
pub fn normalize_scores_in_place(scores: &mut [f64]) {
    let mut s_max = f64::MIN;
    let mut s_min = f64::MAX;
    let mut any_finite = false;
    for &s in scores.iter() {
        if s.is_finite() {
            any_finite = true;
            s_max = s_max.max(s);
            s_min = s_min.min(s);
        }
    }
    for s in scores.iter_mut() {
        let raw = *s;
        *s = if !raw.is_finite() || !any_finite {
            0.0
        } else if raw > 0.0 {
            let base = if s_max > 0.0 { 1.0 + raw / s_max } else { 1.0 };
            base.powi(8)
        } else if raw > CUTOFF_GAMMA {
            if s_min < 0.0 {
                (1.0 - raw / s_min).powi(8).max(0.0001)
            } else {
                0.0001
            }
        } else {
            0.0001
        };
    }
}

/// Eq. 17: normalize raw scores into [0.0001, 256], amplifying positive
/// scores into (1, 256] and keeping a small non-zero probability for
/// mildly negative ones (escape hatch from local optima / model error).
///
/// Every entry is assumed finite (see [`normalize_scores_in_place`] for
/// the engine variant that treats non-finite entries as excluded).
pub fn normalize_scores(scores: &mut [f64]) {
    let finite: Vec<f64> = scores.iter().copied().filter(|s| s.is_finite()).collect();
    if finite.is_empty() {
        return;
    }
    let s_max = finite.iter().copied().fold(f64::MIN, f64::max);
    let s_min = finite.iter().copied().fold(f64::MAX, f64::min);
    for s in scores.iter_mut() {
        let raw = *s;
        *s = if raw > 0.0 {
            let base = if s_max > 0.0 { 1.0 + raw / s_max } else { 1.0 };
            base.powi(8)
        } else if raw > CUTOFF_GAMMA {
            if s_min < 0.0 {
                (1.0 - raw / s_min).powi(8).max(0.0001)
            } else {
                0.0001
            }
        } else {
            0.0001
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::counters::Counter;

    fn delta(pairs: &[(Counter, f64)]) -> DeltaPc {
        let mut d = DeltaPc::default();
        for &(c, v) in pairs {
            d.0.set(c, v);
        }
        d
    }

    fn pc(pairs: &[(Counter, f64)]) -> CounterVec {
        let mut v = CounterVec::new();
        for &(c, x) in pairs {
            v.set(c, x);
        }
        v
    }

    #[test]
    fn eq16_rewards_movement_in_required_direction() {
        // DRAM reads should decrease (Δ = −0.8)
        let d = delta(&[(Counter::DramRt, -0.8)]);
        let prof = pc(&[(Counter::DramRt, 1000.0)]);
        let better = pc(&[(Counter::DramRt, 500.0)]);
        let worse = pc(&[(Counter::DramRt, 2000.0)]);
        let s_better = score(&d, &prof, &better);
        let s_worse = score(&d, &prof, &worse);
        assert!(s_better > 0.0, "decreasing a too-hot counter scores > 0");
        assert!(s_worse < 0.0, "increasing it scores < 0");
        assert!(s_better > s_worse);
    }

    #[test]
    fn eq16_parallelism_direction() {
        // threads should increase (Δ = +0.5)
        let d = delta(&[(Counter::Threads, 0.5)]);
        let prof = pc(&[(Counter::Threads, 1000.0)]);
        let more = pc(&[(Counter::Threads, 4000.0)]);
        assert!(score(&d, &prof, &more) > 0.0);
    }

    #[test]
    fn eq16_weighs_by_delta_magnitude() {
        let prof = pc(&[(Counter::DramRt, 100.0), (Counter::L2Rt, 100.0)]);
        let cand = pc(&[(Counter::DramRt, 50.0), (Counter::L2Rt, 50.0)]);
        let strong = delta(&[(Counter::DramRt, -1.0)]);
        let weak = delta(&[(Counter::DramRt, -0.2)]);
        assert!(
            score(&strong, &prof, &cand) > score(&weak, &prof, &cand)
        );
    }

    #[test]
    fn one_sided_zero_is_full_signal_both_zero_skipped() {
        let d = delta(&[(Counter::DramRt, -1.0), (Counter::TexRwt, -1.0)]);
        // candidate *introduces* TEX traffic the profile lacks: full
        // penalty −1·(50−0)/(50+0) = −1
        let prof = pc(&[(Counter::DramRt, 100.0), (Counter::TexRwt, 0.0)]);
        let cand = pc(&[(Counter::DramRt, 100.0), (Counter::TexRwt, 50.0)]);
        assert_eq!(score(&d, &prof, &cand), -1.0);
        // candidate *eliminates* DRAM reads: full reward
        let cand2 = pc(&[(Counter::DramRt, 0.0), (Counter::TexRwt, 0.0)]);
        assert_eq!(score(&d, &prof, &cand2), 1.0);
        // both-zero: no information, skipped
        let prof0 = pc(&[(Counter::DramRt, 0.0)]);
        let cand0 = pc(&[(Counter::DramRt, 0.0)]);
        assert_eq!(score(&d, &prof0, &cand0), 0.0);
    }

    #[test]
    fn eq17_bounds() {
        let mut s = vec![-5.0, -0.3, -0.1, 0.0, 0.2, 1.0, 3.0];
        normalize_scores(&mut s);
        for v in &s {
            assert!((0.0001..=256.0).contains(v), "{v}");
        }
        // γ cutoff: -5.0 and -0.3 floored
        assert_eq!(s[0], 0.0001);
        assert_eq!(s[1], 0.0001);
        // max positive hits 2^8
        assert!((s[6] - 256.0).abs() < 1e-9);
    }

    #[test]
    fn eq17_monotone_in_raw_score() {
        let mut s = vec![0.1, 0.5, 0.9, 1.2, 2.0];
        let orig = s.clone();
        normalize_scores(&mut s);
        for w in s.windows(2) {
            assert!(w[0] <= w[1], "normalization must preserve order");
        }
        assert_eq!(orig.len(), s.len());
    }

    #[test]
    fn eq17_positive_scores_amplified_above_one() {
        let mut s = vec![0.01, 1.0];
        normalize_scores(&mut s);
        assert!(s[0] > 1.0);
        assert!(s[1] > s[0]);
    }

    #[test]
    fn score_active_matches_score() {
        let d = delta(&[
            (Counter::DramRt, -0.8),
            (Counter::Threads, 0.5),
            (Counter::ShrLt, -0.2),
        ]);
        let active = active_deltas(&d);
        assert_eq!(active.len(), 3);
        let p = pc(&[
            (Counter::DramRt, 100.0),
            (Counter::Threads, 5000.0),
            (Counter::ShrLt, 40.0),
        ]);
        let q = pc(&[
            (Counter::DramRt, 60.0),
            (Counter::Threads, 9000.0),
            (Counter::ShrLt, 80.0),
        ]);
        assert!((score(&d, &p, &q) - score_active(&active, &p, &q)).abs() < 1e-15);
    }

    #[test]
    fn all_zero_scores_stay_floor_or_one() {
        let mut s = vec![0.0, 0.0];
        normalize_scores(&mut s);
        for v in &s {
            assert!((0.0001..=256.0).contains(v));
        }
    }

    #[test]
    fn in_place_matches_collect_scatter_flow() {
        // the exact flow the pre-engine searcher used: collect finite,
        // normalize, scatter back, zero the excluded entries
        let mixed = vec![
            f64::NEG_INFINITY,
            -5.0,
            -0.1,
            f64::NEG_INFINITY,
            0.0,
            0.4,
            2.0,
            f64::INFINITY,
            f64::NAN,
        ];
        let mut live: Vec<f64> =
            mixed.iter().copied().filter(|s| s.is_finite()).collect();
        normalize_scores(&mut live);
        let mut want = Vec::with_capacity(mixed.len());
        let mut it = live.into_iter();
        for s in &mixed {
            if s.is_finite() {
                want.push(it.next().unwrap());
            } else {
                want.push(0.0);
            }
        }
        let mut got = mixed.clone();
        normalize_scores_in_place(&mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn in_place_all_excluded_is_all_zero() {
        let mut s = vec![f64::NEG_INFINITY, f64::NAN, f64::INFINITY];
        normalize_scores_in_place(&mut s);
        assert_eq!(s, vec![0.0, 0.0, 0.0]);
        let mut empty: Vec<f64> = vec![];
        normalize_scores_in_place(&mut empty);
        assert!(empty.is_empty());
    }
}
