//! # pcat — Performance-Counter-Aided Tuning
//!
//! A reproduction of *"Using hardware performance counters to speed up
//! autotuning convergence on GPUs"* (Filipovič, Hozzová, Nezarat, Oľha,
//! Petrovič — 2021): a KTT-like generic GPU-kernel autotuning framework
//! whose tuning-space searcher is biased by hardware performance counters.
//!
//! ## Layout (three-layer rust + JAX + Pallas stack)
//!
//! * [`counters`] — the paper's Table 1: the counter taxonomy
//!   (`PC_ops` vs `PC_stress`), old (pre-Volta) and new (Volta+) names.
//! * [`gpusim`] — the hardware substrate the paper had and we do not: an
//!   analytic GPU performance-counter simulator with device specs
//!   mirroring the paper's four GPUs (see DESIGN.md §2 substitutions).
//! * [`tuning`] — tuning parameters, constraints, space enumeration and
//!   recorded (exhaustively explored) spaces — the paper's own replay
//!   methodology (§4.1).
//! * [`benchmarks`] — the paper's six tuning spaces (Coulomb 3D, Matrix
//!   transposition, GEMM, GEMM-full, n-body, Convolution) as analytic
//!   workload models over the simulator.
//! * [`model`] — ML models of the TP→PC_ops relation (§3.4): regression
//!   decision trees and least-squares quadratic regression, plus the
//!   dense [`model::PredictionMatrix`] the columnar scoring engine
//!   shares across seed-repetitions (§Perf).
//! * [`expert`] — the bottleneck-analysis + ΔPC expert system (§3.5,
//!   Eqs. 6–15).
//! * [`searcher`] — the profile-based searcher (Algorithm 1, Eqs. 16–17)
//!   and the baselines: random, Basin Hopping (Kernel Tuner) and
//!   Starchart regression-tree search.
//! * [`coordinator`] — the KTT-like public tuner API (L3).
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts:
//!   the *real* empirical-measurement path (L1/L2 product).
//! * [`harness`] — experiment drivers regenerating every table and
//!   figure of the paper's evaluation section.
//!
//! Python runs only at build time (`make artifacts`); the tuning loop is
//! pure rust.

pub mod benchmarks;
pub mod coordinator;
pub mod counters;
pub mod expert;
pub mod gpusim;
pub mod harness;
pub mod model;
pub mod runtime;
pub mod searcher;
pub mod tuning;
pub mod util;


pub use counters::{Counter, CounterVec};
pub use gpusim::GpuSpec;
pub use tuning::{Config, Space};
