//! The decision-tree TP→PC model (paper §3.4.2).
//!
//! For each modeled counter: generate a set of candidate trees (varying
//! depth/leaf-size — the paper "alters parent nodes"), train each on a
//! random 50 % of the explored data, evaluate MAE (tie-broken by RMSE)
//! on the other 50 %, and keep the winner.

use std::path::Path;

use anyhow::{Context, Result};

use crate::counters::CounterVec;
use crate::tuning::Config;
use crate::util::json::{self, obj, Value};
use crate::util::rng::Rng;
use crate::util::stats::{mae, rmse};

use super::training::{features_of, Dataset};
use super::tree::RegressionTree;
use super::{TpPcModel, MODELED_COUNTERS};

/// Candidate hyper-parameter grid.
const CANDIDATE_DEPTHS: [usize; 4] = [4, 6, 8, 12];
const CANDIDATE_MIN_LEAF: [usize; 2] = [2, 5];

/// Per-counter regression trees.
pub struct DecisionTreeModel {
    /// Parallel to [`MODELED_COUNTERS`].
    trees: Vec<RegressionTree>,
    /// Provenance, for reports (GPU/input the training data came from).
    pub trained_on: String,
}

impl DecisionTreeModel {
    /// Train on a dataset (paper: 50/50 random train/test split per
    /// candidate; lowest MAE wins, ties broken by RMSE).
    ///
    /// Deterministic for a fixed `(ds, rng)` pair: the only randomness
    /// is the split shuffle drawn from `rng` before any thread spawns,
    /// per-counter fits are pure functions of that split, and the
    /// trees are collected in [`MODELED_COUNTERS`] order regardless of
    /// thread interleaving — property-tested, and load-bearing for the
    /// transfer runner's `--jobs`-invariant byte contract.
    pub fn train(ds: &Dataset, trained_on: &str, rng: &mut Rng) -> Self {
        assert!(ds.len() >= 4, "need at least 4 samples");
        let n = ds.len();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let (train_idx, test_idx) = order.split_at(n / 2);

        let train_x: Vec<Vec<f64>> =
            train_idx.iter().map(|&i| ds.features[i].clone()).collect();
        let test_x: Vec<Vec<f64>> =
            test_idx.iter().map(|&i| ds.features[i].clone()).collect();

        // one tree per modeled counter; counters are independent, so
        // train them on all cores (perf: ~#cores× on the 18-counter set)
        let fit_counter = |c: crate::counters::Counter| {
            let train_y: Vec<f64> = train_idx
                .iter()
                .map(|&i| ds.targets[i].get(c))
                .collect();
            let test_y: Vec<f64> =
                test_idx.iter().map(|&i| ds.targets[i].get(c)).collect();

            let mut best: Option<(RegressionTree, f64, f64)> = None;
            for depth in CANDIDATE_DEPTHS {
                for min_leaf in CANDIDATE_MIN_LEAF {
                    let t = RegressionTree::fit(
                        &train_x, &train_y, depth, min_leaf,
                    );
                    let pred: Vec<f64> =
                        test_x.iter().map(|x| t.predict(x)).collect();
                    let m = mae(&pred, &test_y);
                    let r = rmse(&pred, &test_y);
                    let better = match &best {
                        None => true,
                        Some((_, bm, br)) => {
                            m < *bm || (m == *bm && r < *br)
                        }
                    };
                    if better {
                        best = Some((t, m, r));
                    }
                }
            }
            best.unwrap().0
        };
        let fit_ref = &fit_counter;
        let trees: Vec<RegressionTree> = std::thread::scope(|scope| {
            let handles: Vec<_> = MODELED_COUNTERS
                .iter()
                .map(|&c| scope.spawn(move || fit_ref(c)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        DecisionTreeModel {
            trees,
            trained_on: trained_on.to_string(),
        }
    }

    /// The trained tree for one modeled counter (`None` for counters
    /// outside [`MODELED_COUNTERS`]) — reports and property tests.
    pub fn tree_for(
        &self,
        c: crate::counters::Counter,
    ) -> Option<&RegressionTree> {
        MODELED_COUNTERS
            .iter()
            .position(|&m| m == c)
            .map(|j| &self.trees[j])
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("kind", Value::from("decision_tree")),
            ("trained_on", Value::from(self.trained_on.clone())),
            (
                "trees",
                Value::Obj(
                    MODELED_COUNTERS
                        .iter()
                        .zip(&self.trees)
                        .map(|(c, t)| (c.abbr().to_string(), t.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let trees_obj = v.get("trees")?.as_obj().context("trees")?;
        let mut trees = Vec::with_capacity(MODELED_COUNTERS.len());
        for c in MODELED_COUNTERS {
            let t = trees_obj
                .get(c.abbr())
                .with_context(|| format!("missing tree for {c}"))?;
            trees.push(RegressionTree::from_json(t)?);
        }
        Ok(DecisionTreeModel {
            trees,
            trained_on: v
                .get("trained_on")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty(1))
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }
}

impl TpPcModel for DecisionTreeModel {
    fn predict(&self, cfg: &Config) -> CounterVec {
        let x = features_of(cfg);
        let mut out = CounterVec::new();
        for (c, t) in MODELED_COUNTERS.iter().zip(&self.trees) {
            out.set(*c, t.predict(&x));
        }
        out
    }

    fn kind(&self) -> &'static str {
        "decision_tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::counters::Counter;
    use crate::gpusim::GpuSpec;
    use crate::model::dataset_from_recorded;

    fn trained() -> (DecisionTreeModel, crate::tuning::RecordedSpace) {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx1070(),
            &Coulomb.default_input(),
        );
        let mut rng = Rng::new(3);
        let ds = dataset_from_recorded(&rec, 1.0, &mut rng);
        (DecisionTreeModel::train(&ds, "gtx1070", &mut rng), rec)
    }

    #[test]
    fn predicts_instruction_counts_accurately() {
        let (m, rec) = trained();
        // relative error on the fp32 counter should be modest — the
        // relation TP→INST_F32 is smooth in this space.
        let mut rel_err = Vec::new();
        for (cfg, r) in rec.space.configs.iter().zip(&rec.records) {
            let truth = r.counters.get(Counter::InstF32);
            let pred = m.predict(cfg).get(Counter::InstF32);
            if truth > 0.0 {
                rel_err.push(((pred - truth) / truth).abs());
            }
        }
        let med = crate::util::stats::median(&rel_err);
        assert!(med < 0.25, "median rel err {med}");
    }

    #[test]
    fn ranks_coarsening_correctly() {
        // the model must order INST_F32 by Z_ITER (Fig. 1 stability)
        let (m, rec) = trained();
        let s = &rec.space;
        let pick = |zi: i64| {
            s.configs
                .iter()
                .find(|c| {
                    s.value(c, "Z_ITER") == zi
                        && s.value(c, "BLOCK_X") == 16
                        && s.value(c, "BLOCK_Y") == 8
                        && s.value(c, "INNER_UNROLL") == 1
                        && s.value(c, "USE_SOA") == 1
                        && s.value(c, "VECTOR") == 1
                        && s.value(c, "SLICE_FACTOR") == 1
                })
                .unwrap()
        };
        let f1 = m.predict(pick(1)).get(Counter::InstF32);
        let f32_ = m.predict(pick(32)).get(Counter::InstF32);
        assert!(f1 > f32_, "zi=1 must predict more FP32 ops than zi=32");
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (m, rec) = trained();
        let back = DecisionTreeModel::from_json(&m.to_json()).unwrap();
        for cfg in rec.space.configs.iter().step_by(29) {
            assert_eq!(m.predict(cfg), back.predict(cfg));
        }
        assert_eq!(back.trained_on, "gtx1070");
    }

    #[test]
    fn save_load_file() {
        let (m, _) = trained();
        let dir = std::env::temp_dir().join("pcat_test_dtm");
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        let back = DecisionTreeModel::load(&path).unwrap();
        assert_eq!(back.kind(), "decision_tree");
        std::fs::remove_dir_all(dir).ok();
    }
}
