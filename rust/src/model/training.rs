//! Training-set extraction from recorded tuning spaces.

use crate::counters::CounterVec;
use crate::tuning::{Config, RecordedSpace};
use crate::util::rng::Rng;

/// A (features, counter-targets) training set. Features are the raw
/// tuning-parameter values as f64 (trees are scale-invariant; the
/// regression model applies its own transform).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub features: Vec<Vec<f64>>,
    pub targets: Vec<CounterVec>,
    pub configs: Vec<Config>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.features.is_empty()
    }
}

/// Convert a configuration to a feature vector.
pub fn features_of(cfg: &Config) -> Vec<f64> {
    cfg.0.iter().map(|&v| v as f64).collect()
}

/// The whole recorded space, in canonical space order, as a training
/// set — the deterministic full-exploration variant the transfer
/// runner's tree source trains on. No sampling RNG touches it, so row
/// order (and therefore every float-accumulation order downstream in
/// tree fitting) is a pure function of the recording: byte-stable
/// across worker counts by construction. The train/test split inside
/// [`crate::model::DecisionTreeModel::train`] still draws from the
/// caller's seeded RNG.
pub fn dataset_full(rec: &RecordedSpace) -> Dataset {
    Dataset {
        features: rec.space.configs.iter().map(features_of).collect(),
        targets: rec.records.iter().map(|r| r.counters.clone()).collect(),
        configs: rec.space.configs.clone(),
    }
}

/// Sample `fraction` of a recorded space (without replacement) as a
/// training set. `fraction = 1.0` uses the whole space (the paper trains
/// on full or partial exhaustive explorations).
pub fn dataset_from_recorded(
    rec: &RecordedSpace,
    fraction: f64,
    rng: &mut Rng,
) -> Dataset {
    let n = rec.space.len();
    let k = ((n as f64 * fraction).round() as usize).clamp(1, n);
    let idx = rng.sample_indices(n, k);
    let mut ds = Dataset {
        features: Vec::with_capacity(k),
        targets: Vec::with_capacity(k),
        configs: Vec::with_capacity(k),
    };
    for i in idx {
        ds.features.push(features_of(&rec.space.configs[i]));
        ds.targets.push(rec.records[i].counters.clone());
        ds.configs.push(rec.space.configs[i].clone());
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;

    #[test]
    fn fraction_controls_size() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let mut rng = Rng::new(1);
        let half = dataset_from_recorded(&rec, 0.5, &mut rng);
        assert_eq!(half.len(), rec.space.len().div_ceil(2));
        let full = dataset_from_recorded(&rec, 1.0, &mut rng);
        assert_eq!(full.len(), rec.space.len());
    }

    #[test]
    fn dataset_full_is_the_space_in_order() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let ds = dataset_full(&rec);
        assert_eq!(ds.len(), rec.space.len());
        for (i, cfg) in rec.space.configs.iter().enumerate() {
            assert_eq!(&ds.configs[i], cfg);
            assert_eq!(ds.features[i], features_of(cfg));
            assert_eq!(ds.targets[i], rec.records[i].counters);
        }
    }

    #[test]
    fn features_match_configs() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let mut rng = Rng::new(2);
        let ds = dataset_from_recorded(&rec, 0.3, &mut rng);
        for (f, c) in ds.features.iter().zip(&ds.configs) {
            assert_eq!(f.len(), c.len());
            for (a, b) in f.iter().zip(&c.0) {
                assert_eq!(*a, *b as f64);
            }
        }
    }
}
