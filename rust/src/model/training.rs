//! Training-set extraction from recorded tuning spaces.
//!
//! Two flavours feed the model layer:
//!
//! * [`dataset_full`] — the whole recording in canonical space order,
//!   the deterministic full-exploration variant;
//! * [`dataset_from_recorded`] — the paper's partial-exploration
//!   setting: a deterministic, *stratified*, *nested* sample of the
//!   recording. The sampler draws exactly one scramble word from the
//!   caller's RNG (keyed by the source endpoint in the transfer
//!   runner), so the selected row set is a pure function of
//!   `(endpoint stream, fraction)` — byte-identical across worker
//!   counts — and samples at a larger fraction are supersets of
//!   samples at a smaller one under the same stream
//!   ([`stratified_indices`] documents the construction). At
//!   `fraction = 1.0` it short-circuits to [`dataset_full`] and
//!   consumes **no** randomness, which keeps full-dataset tree
//!   training bit-for-bit identical to the pre-fraction code path.

use crate::counters::CounterVec;
use crate::tuning::{Config, RecordedSpace};
use crate::util::rng::Rng;

/// A (features, counter-targets) training set. Features are the raw
/// tuning-parameter values as f64 (trees are scale-invariant; the
/// regression model applies its own transform).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub features: Vec<Vec<f64>>,
    pub targets: Vec<CounterVec>,
    pub configs: Vec<Config>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.features.is_empty()
    }
}

/// Convert a configuration to a feature vector.
pub fn features_of(cfg: &Config) -> Vec<f64> {
    cfg.0.iter().map(|&v| v as f64).collect()
}

/// The whole recorded space, in canonical space order, as a training
/// set — the deterministic full-exploration variant the transfer
/// runner's tree source trains on. No sampling RNG touches it, so row
/// order (and therefore every float-accumulation order downstream in
/// tree fitting) is a pure function of the recording: byte-stable
/// across worker counts by construction. The train/test split inside
/// [`crate::model::DecisionTreeModel::train`] still draws from the
/// caller's seeded RNG.
pub fn dataset_full(rec: &RecordedSpace) -> Dataset {
    Dataset {
        features: rec.space.configs.iter().map(features_of).collect(),
        targets: rec.records.iter().map(|r| r.counters.clone()).collect(),
        configs: rec.space.configs.clone(),
    }
}

/// Sample size for a fractional exploration: `round(n · fraction)`,
/// clamped into `[1, n]` (0 for an empty space).
pub fn sample_size(n: usize, fraction: f64) -> usize {
    if n == 0 {
        return 0;
    }
    ((n as f64 * fraction).round() as usize).clamp(1, n)
}

/// `k` distinct indices of `0..n`, stratified over the index range and
/// **nested** across `k` for a fixed RNG stream.
///
/// Construction: a seed-keyed permutation of `0..n` ordered by the
/// XOR-scrambled bit-reversal key `rev_bits(i) ^ scramble` (one
/// `scramble` word drawn from `rng` — the only randomness consumed).
/// Taking the `k` smallest keys:
///
/// * is **stratified**: bit reversal maps adjacent indices far apart,
///   so for any power-of-two `k` the selected indices form an exact
///   arithmetic progression across the (padded) index range, and
///   approximately even coverage otherwise — the canonical
///   (odometer-ordered) space is sampled across all parameter regions
///   instead of clustering;
/// * is **nested/monotone**: the key of an index does not depend on
///   `k`, so the selection at a larger `k` is a superset of the
///   selection at a smaller `k` under the same stream — the
///   sensitivity sweep's fractions measure *more data*, never
///   *different data*;
/// * is **deterministic** per (stream, n, k): one draw, then a pure
///   sort.
///
/// The returned indices are sorted ascending (canonical space order),
/// so downstream float-accumulation order is a pure function of the
/// selected set.
pub fn stratified_indices(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    let k = k.min(n);
    if n == 0 || k == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![0];
    }
    // bits = ceil(log2(n)); n >= 2 here so bits >= 1
    let bits = usize::BITS - (n - 1).leading_zeros();
    let mask: u64 = (1u64 << bits) - 1;
    let scramble = rng.next_u64() & mask;
    let mut keyed: Vec<(u64, usize)> = (0..n)
        .map(|i| (((i as u64).reverse_bits() >> (64 - bits)) ^ scramble, i))
        .collect();
    // keys are distinct (bit reversal is injective on 0..2^bits and
    // XOR is a bijection), so this sort has no ties to break
    keyed.sort_unstable();
    let mut idx: Vec<usize> = keyed[..k].iter().map(|&(_, i)| i).collect();
    idx.sort_unstable();
    idx
}

/// Materialize the rows at `idx` (ascending canonical order by
/// convention) as a training set.
pub fn dataset_from_indices(rec: &RecordedSpace, idx: &[usize]) -> Dataset {
    let mut ds = Dataset {
        features: Vec::with_capacity(idx.len()),
        targets: Vec::with_capacity(idx.len()),
        configs: Vec::with_capacity(idx.len()),
    };
    for &i in idx {
        ds.features.push(features_of(&rec.space.configs[i]));
        ds.targets.push(rec.records[i].counters.clone());
        ds.configs.push(rec.space.configs[i].clone());
    }
    ds
}

/// Sample `fraction` of a recorded space (without replacement) as a
/// training set — the paper's partial-exploration setting ("requires
/// the tuning space to be sampled on any GPU", §5).
///
/// `fraction = 1.0` (or more) short-circuits to [`dataset_full`]:
/// canonical row order, **no** RNG consumed — full-dataset training is
/// bit-for-bit the pre-fraction behaviour (regression-tested). Smaller
/// fractions select [`stratified_indices`]`(n, round(n·fraction))`,
/// deterministic per (RNG stream, fraction) and nested across
/// fractions on the same stream.
pub fn dataset_from_recorded(
    rec: &RecordedSpace,
    fraction: f64,
    rng: &mut Rng,
) -> Dataset {
    if fraction >= 1.0 {
        return dataset_full(rec);
    }
    let n = rec.space.len();
    let idx = stratified_indices(n, sample_size(n, fraction), rng);
    dataset_from_indices(rec, &idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;

    fn recorded() -> RecordedSpace {
        record_space(&Coulomb, &GpuSpec::gtx750(), &Coulomb.default_input())
    }

    #[test]
    fn fraction_controls_size() {
        let rec = recorded();
        let mut rng = Rng::new(1);
        let half = dataset_from_recorded(&rec, 0.5, &mut rng);
        assert_eq!(half.len(), sample_size(rec.space.len(), 0.5));
        let full = dataset_from_recorded(&rec, 1.0, &mut rng);
        assert_eq!(full.len(), rec.space.len());
        assert_eq!(sample_size(10, 0.0001), 1, "clamped to at least one row");
        assert_eq!(sample_size(10, 1.0), 10);
    }

    #[test]
    fn dataset_full_is_the_space_in_order() {
        let rec = recorded();
        let ds = dataset_full(&rec);
        assert_eq!(ds.len(), rec.space.len());
        for (i, cfg) in rec.space.configs.iter().enumerate() {
            assert_eq!(&ds.configs[i], cfg);
            assert_eq!(ds.features[i], features_of(cfg));
            assert_eq!(ds.targets[i], rec.records[i].counters);
        }
    }

    #[test]
    fn fraction_one_is_dataset_full_and_consumes_no_rng() {
        // the bit-for-bit contract: full-fraction sampling must leave
        // the caller's RNG stream untouched (tree training draws its
        // split shuffle from the same stream) and return canonical
        // space order
        let rec = recorded();
        let mut rng = Rng::new(9);
        let mut untouched = rng.clone();
        let ds = dataset_from_recorded(&rec, 1.0, &mut rng);
        assert_eq!(rng.next_u64(), untouched.next_u64(), "RNG was advanced");
        let full = dataset_full(&rec);
        assert_eq!(ds.configs, full.configs);
        assert_eq!(ds.features, full.features);
        assert_eq!(ds.targets, full.targets);
    }

    #[test]
    fn features_match_configs() {
        let rec = recorded();
        let mut rng = Rng::new(2);
        let ds = dataset_from_recorded(&rec, 0.3, &mut rng);
        for (f, c) in ds.features.iter().zip(&ds.configs) {
            assert_eq!(f.len(), c.len());
            for (a, b) in f.iter().zip(&c.0) {
                assert_eq!(*a, *b as f64);
            }
        }
    }

    #[test]
    fn stratified_indices_are_distinct_sorted_and_spread() {
        let mut rng = Rng::new(7);
        let n = 210;
        let k = 52;
        let idx = stratified_indices(n, k, &mut rng);
        assert_eq!(idx.len(), k);
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "not sorted/distinct: {idx:?}");
        }
        // stratification: every quarter of the index range gets a
        // meaningful share (a uniform shuffle can starve a quarter;
        // the bit-reversal construction cannot)
        for q in 0..4 {
            let lo = q * n / 4;
            let hi = (q + 1) * n / 4;
            let got = idx.iter().filter(|&&i| i >= lo && i < hi).count();
            assert!(
                got >= k / 8,
                "quarter {q} has only {got} of {k} samples: {idx:?}"
            );
        }
        // degenerate shapes
        assert_eq!(stratified_indices(0, 3, &mut rng), Vec::<usize>::new());
        assert_eq!(stratified_indices(1, 1, &mut rng), vec![0]);
        let all = stratified_indices(5, 9, &mut rng);
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn stratified_indices_nest_across_k() {
        // same stream state → sample at larger k contains the sample
        // at smaller k (the sweep's monotone-information contract)
        for seed in [0u64, 3, 11] {
            let small = stratified_indices(210, 21, &mut Rng::new(seed));
            let big = stratified_indices(210, 105, &mut Rng::new(seed));
            for i in &small {
                assert!(big.contains(i), "seed {seed}: {i} lost at larger k");
            }
        }
    }
}
