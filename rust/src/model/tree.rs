//! A CART-style regression tree (paper §3.4.2).
//!
//! Splits greedily on the feature/threshold pair maximizing Standard
//! Deviation Reduction (equivalently, minimizing the weighted child
//! MSE); leaves predict the mean target. Used both by the TP→PC
//! decision-tree model and by the Starchart baseline (runtime trees).

use crate::util::json::{obj, Value};

/// Flat node storage: indices into `nodes`.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    Leaf(f64),
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

#[derive(Debug, Clone, PartialEq)]
pub struct RegressionTree {
    pub nodes: Vec<Node>,
    pub max_depth: usize,
    pub min_leaf: usize,
}

fn mean(ys: &[f64]) -> f64 {
    ys.iter().sum::<f64>() / ys.len().max(1) as f64
}

fn sse(ys: &[f64]) -> f64 {
    let m = mean(ys);
    ys.iter().map(|y| (y - m) * (y - m)).sum()
}

impl RegressionTree {
    /// Fit on rows `xs` (feature vectors) with targets `ys`.
    pub fn fit(
        xs: &[Vec<f64>],
        ys: &[f64],
        max_depth: usize,
        min_leaf: usize,
    ) -> RegressionTree {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty(), "cannot fit a tree on no data");
        let mut tree = RegressionTree {
            nodes: Vec::new(),
            max_depth,
            min_leaf,
        };
        let idx: Vec<usize> = (0..xs.len()).collect();
        tree.build(xs, ys, &idx, 0);
        tree
    }

    fn build(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[f64],
        idx: &[usize],
        depth: usize,
    ) -> usize {
        let targets: Vec<f64> = idx.iter().map(|&i| ys[i]).collect();
        let node_sse = sse(&targets);
        if depth >= self.max_depth
            || idx.len() < 2 * self.min_leaf
            || node_sse <= 1e-12
        {
            let id = self.nodes.len();
            self.nodes.push(Node::Leaf(mean(&targets)));
            return id;
        }

        // Find the best (feature, threshold) by SSE reduction. Tuning
        // parameters have few distinct values, so aggregate
        // (count, sum, sum-of-squares) per value and scan thresholds
        // with prefix sums — O(n·F + U·F) per node instead of O(n²·F).
        let n_features = xs[idx[0]].len();
        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, child_sse)
        let mut groups: Vec<(f64, f64, f64, f64)> = Vec::new(); // value, n, Σy, Σy²
        for f in 0..n_features {
            groups.clear();
            // aggregate per distinct feature value (kept sorted)
            for &i in idx {
                let v = xs[i][f];
                let y = ys[i];
                match groups.binary_search_by(|g| g.0.partial_cmp(&v).unwrap())
                {
                    Ok(g) => {
                        groups[g].1 += 1.0;
                        groups[g].2 += y;
                        groups[g].3 += y * y;
                    }
                    Err(pos) => groups.insert(pos, (v, 1.0, y, y * y)),
                }
            }
            // prefix scan: left stats grow, right stats shrink
            let (mut tn, mut ts, mut tq) = (0.0, 0.0, 0.0);
            for g in &groups {
                tn += g.1;
                ts += g.2;
                tq += g.3;
            }
            let (mut ln, mut ls, mut lq) = (0.0f64, 0.0f64, 0.0f64);
            for w in 0..groups.len().saturating_sub(1) {
                ln += groups[w].1;
                ls += groups[w].2;
                lq += groups[w].3;
                let (rn, rs, rq) = (tn - ln, ts - ls, tq - lq);
                if (ln as usize) < self.min_leaf || (rn as usize) < self.min_leaf
                {
                    continue;
                }
                // SSE = Σy² − (Σy)²/n per side
                let child = (lq - ls * ls / ln) + (rq - rs * rs / rn);
                if best.as_ref().is_none_or(|(_, _, b)| child < *b) {
                    let thr = 0.5 * (groups[w].0 + groups[w + 1].0);
                    best = Some((f, thr, child));
                }
            }
        }

        let Some((feature, threshold, child_sse)) = best else {
            let id = self.nodes.len();
            self.nodes.push(Node::Leaf(mean(&targets)));
            return id;
        };
        if child_sse >= node_sse {
            let id = self.nodes.len();
            self.nodes.push(Node::Leaf(mean(&targets)));
            return id;
        }

        let (li, ri): (Vec<usize>, Vec<usize>) =
            idx.iter().partition(|&&i| xs[i][feature] <= threshold);
        // reserve this node's slot before recursing
        let id = self.nodes.len();
        self.nodes.push(Node::Leaf(0.0)); // placeholder
        let left = self.build(xs, ys, &li, depth + 1);
        let right = self.build(xs, ys, &ri, depth + 1);
        self.nodes[id] = Node::Split {
            feature,
            threshold,
            left,
            right,
        };
        id
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut at = 0usize;
        loop {
            match &self.nodes[at] {
                Node::Leaf(v) => return *v,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    at = if x[*feature] <= *threshold {
                        *left
                    } else {
                        *right
                    };
                }
            }
        }
    }

    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], at: usize) -> usize {
            match &nodes[at] {
                Node::Leaf(_) => 1,
                Node::Split { left, right, .. } => {
                    1 + walk(nodes, *left).max(walk(nodes, *right))
                }
            }
        }
        walk(&self.nodes, 0)
    }

    pub fn to_json(&self) -> Value {
        let nodes: Vec<Value> = self
            .nodes
            .iter()
            .map(|n| match n {
                Node::Leaf(v) => Value::Arr(vec![Value::from(*v)]),
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => Value::Arr(vec![
                    Value::from(*feature),
                    Value::from(*threshold),
                    Value::from(*left),
                    Value::from(*right),
                ]),
            })
            .collect();
        obj(vec![
            ("nodes", Value::Arr(nodes)),
            ("max_depth", Value::from(self.max_depth)),
            ("min_leaf", Value::from(self.min_leaf)),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<RegressionTree> {
        let nodes = v
            .get("nodes")?
            .as_arr()
            .unwrap_or_default()
            .iter()
            .map(|n| {
                let a = n.as_arr().unwrap_or_default();
                Ok(match a.len() {
                    1 => Node::Leaf(a[0].as_f64().unwrap_or(0.0)),
                    4 => Node::Split {
                        feature: a[0].as_i64().unwrap_or(0) as usize,
                        threshold: a[1].as_f64().unwrap_or(0.0),
                        left: a[2].as_i64().unwrap_or(0) as usize,
                        right: a[3].as_i64().unwrap_or(0) as usize,
                    },
                    _ => anyhow::bail!("bad tree node"),
                })
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(RegressionTree {
            nodes,
            max_depth: v.get("max_depth")?.as_i64().unwrap_or(0) as usize,
            min_leaf: v.get("min_leaf")?.as_i64().unwrap_or(1) as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_target_single_leaf() {
        let xs = vec![vec![1.0], vec![2.0], vec![3.0]];
        let ys = vec![5.0, 5.0, 5.0];
        let t = RegressionTree::fit(&xs, &ys, 8, 1);
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.predict(&[10.0]), 5.0);
    }

    #[test]
    fn splits_a_step_function_exactly() {
        let xs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> =
            (0..20).map(|i| if i < 10 { 1.0 } else { 9.0 }).collect();
        let t = RegressionTree::fit(&xs, &ys, 4, 1);
        assert_eq!(t.predict(&[3.0]), 1.0);
        assert_eq!(t.predict(&[15.0]), 9.0);
    }

    #[test]
    fn respects_max_depth() {
        let xs: Vec<Vec<f64>> = (0..64).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let t = RegressionTree::fit(&xs, &ys, 3, 1);
        assert!(t.depth() <= 4); // root + 3 levels
    }

    #[test]
    fn predictions_within_target_range() {
        let mut rng = crate::util::rng::Rng::new(5);
        let xs: Vec<Vec<f64>> =
            (0..200).map(|_| vec![rng.f64() * 8.0, rng.f64()]).collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| x[0] * x[0] + 3.0 * x[1]).collect();
        let lo = ys.iter().cloned().fold(f64::MAX, f64::min);
        let hi = ys.iter().cloned().fold(f64::MIN, f64::max);
        let t = RegressionTree::fit(&xs, &ys, 8, 2);
        for _ in 0..100 {
            let p = t.predict(&[rng.f64() * 20.0 - 5.0, rng.f64() * 2.0]);
            assert!(p >= lo - 1e-9 && p <= hi + 1e-9);
        }
    }

    #[test]
    fn two_feature_interaction_learned() {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for a in 0..8 {
            for b in 0..8 {
                xs.push(vec![a as f64, b as f64]);
                ys.push((a * b) as f64);
            }
        }
        let t = RegressionTree::fit(&xs, &ys, 6, 1);
        // reasonable accuracy on training points
        let mae: f64 = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| (t.predict(x) - y).abs())
            .sum::<f64>()
            / ys.len() as f64;
        assert!(mae < 3.0, "mae={mae}");
    }

    #[test]
    fn json_roundtrip() {
        let xs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..20).map(|i| (i * i) as f64).collect();
        let t = RegressionTree::fit(&xs, &ys, 5, 2);
        let back = RegressionTree::from_json(&t.to_json()).unwrap();
        assert_eq!(t, back);
        assert_eq!(t.predict(&[7.3]), back.predict(&[7.3]));
    }
}
